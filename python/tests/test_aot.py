"""AOT pipeline tests: HLO text generation, manifest integrity, round-trip.

The round-trip test compiles a lowered artifact back through xla_client and
executes it, proving the HLO text is self-contained (this is exactly what the
rust PJRT runtime does, minus the C API)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")

P = model.PRESETS["micro"]


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), presets=["micro"], verbose=False)
    return str(out), manifest


class TestLowering:
    def test_hlo_text_nonempty_and_parseable_header(self, built):
        out, manifest = built
        entry = manifest["entries"][0]
        text = open(os.path.join(out, entry["file"])).read()
        assert text.startswith("HloModule")
        assert "ENTRY" in text

    def test_every_entry_lowered(self, built):
        _, manifest = built
        names = {e["entry"] for e in manifest["entries"] if e["preset"] == "micro"}
        assert names == set(model.entry_specs(P, 2))

    def test_manifest_records_shapes(self, built):
        _, manifest = built
        for e in manifest["entries"]:
            assert e["inputs"] and e["outputs"]
            for s in e["inputs"] + e["outputs"]:
                assert "shape" in s and "dtype" in s

    def test_manifest_preset_hyperparams(self, built):
        _, manifest = built
        mp = manifest["presets"]["micro"]
        assert mp["channels"] == P.channels
        assert mp["n_res"] == P.n_res
        assert mp["block"] == P.block
        assert mp["h"] == pytest.approx(P.h)

    def test_manifest_json_loads(self, built):
        out, _ = built
        with open(os.path.join(out, "manifest.json")) as f:
            m = json.load(f)
        assert m["format"] == 1


class TestRoundTrip:
    def _run_artifact(self, out_dir, manifest, entry_name, args):
        from jax._src.lib import xla_client as xc

        entry = next(
            e for e in manifest["entries"]
            if e["entry"] == entry_name and e["preset"] == "micro"
        )
        text = open(os.path.join(out_dir, entry["file"])).read()
        # parse the HLO text back into a module, as the rust side does; the
        # authoritative execute-round-trip runs in rust (tests/pjrt_roundtrip)
        mod = xc._xla.hlo_module_from_text(text)
        assert mod.as_serialized_hlo_module_proto()
        # the ENTRY computation must declare one parameter per manifest input
        entry_line = next(
            l for l in text.splitlines() if l.startswith("ENTRY")
        )
        assert entry_line.count("parameter") == 0  # params are in the body
        n_params = sum(
            1 for l in text.splitlines() if " = " in l and " parameter(" in l
        )
        assert n_params >= len(entry["inputs"])
        return None

    def test_step_fwd_text_reparses_with_correct_arity(self, built):
        out, manifest = built
        self._run_artifact(out, manifest, "step_fwd", None)

    def test_block_fwd_text_reparses_with_correct_arity(self, built):
        out, manifest = built
        self._run_artifact(out, manifest, "block_fwd", None)

    def test_lowered_step_fwd_executes_same_as_eager(self):
        """jit-compiled lowering == eager execution for the exported fn."""
        fn, specs = model.entry_specs(P, 2)["step_fwd"]
        args = [
            jax.random.normal(jax.random.PRNGKey(i), s.shape, s.dtype)
            if s.dtype == jnp.float32
            else jnp.zeros(s.shape, s.dtype)
            for i, s in enumerate(specs)
        ]
        eager = fn(*args)
        compiled = jax.jit(fn)(*args)
        for a, b in zip(eager, compiled):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
