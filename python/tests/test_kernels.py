"""Pallas kernel vs pure-jnp reference — the core L1 correctness signal.

Hypothesis sweeps shapes (batch, channels, spatial, kernel size, tile sizes)
so padding/tiling edge cases in the fused matmul are exercised, not just the
preset shapes that get AOT-exported.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import conv as kconv
from compile.kernels import fused_matmul as fm
from compile.kernels import ref as kref
from compile.kernels import softmax_xent as kxent

jax.config.update("jax_platform_name", "cpu")

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# --------------------------------------------------------------------------
# fused_matmul
# --------------------------------------------------------------------------

class TestFusedMatmul:
    def test_linear_exact_tiles(self):
        x, w, b = rand(0, 256, 128), rand(1, 128, 128), rand(2, 128)
        out = fm.fused_matmul(x, w, b, epilogue=fm.EPILOGUE_LINEAR)
        np.testing.assert_allclose(out, x @ w + b, **TOL)

    def test_relu_epilogue(self):
        x, w, b = rand(3, 64, 32), rand(4, 32, 16), rand(5, 16)
        out = fm.fused_matmul(x, w, b, epilogue=fm.EPILOGUE_RELU)
        np.testing.assert_allclose(out, jnp.maximum(x @ w + b, 0), **TOL)

    def test_residual_epilogue(self):
        x, w, b = rand(6, 40, 24), rand(7, 24, 8), rand(8, 8)
        skip = rand(9, 40, 8)
        h = jnp.float32(0.125)
        out = fm.fused_matmul(x, w, b, epilogue=fm.EPILOGUE_RESIDUAL, skip=skip, h=h)
        np.testing.assert_allclose(out, skip + h * jnp.maximum(x @ w + b, 0), **TOL)

    def test_ragged_shapes_pad_correctly(self):
        # deliberately prime-ish dims — nothing divides the tile sizes
        x, w, b = rand(10, 97, 53), rand(11, 53, 11), rand(12, 11)
        out = fm.fused_matmul(x, w, b, epilogue=fm.EPILOGUE_LINEAR)
        np.testing.assert_allclose(out, x @ w + b, **TOL)

    def test_multi_k_tiles_accumulate(self):
        # K spans several tiles: exercises the scratch accumulator path
        x, w, b = rand(13, 32, 300), rand(14, 300, 8), rand(15, 8)
        out = fm.fused_matmul(x, w, b, epilogue=fm.EPILOGUE_LINEAR, tile_k=64)
        np.testing.assert_allclose(out, x @ w + b, rtol=1e-4, atol=1e-4)

    def test_rejects_bad_epilogue_combo(self):
        x, w, b = rand(16, 8, 8), rand(17, 8, 8), rand(18, 8)
        with pytest.raises(ValueError):
            fm.fused_matmul(x, w, b, epilogue=fm.EPILOGUE_RESIDUAL)  # no skip/h
        with pytest.raises(ValueError):
            fm.fused_matmul(x, w, b, epilogue="nonsense")

    @settings(deadline=None, max_examples=25)
    @given(
        m=st.integers(1, 70),
        k=st.integers(1, 70),
        n=st.integers(1, 40),
        tile=st.sampled_from([8, 16, 32, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_linear(self, m, k, n, tile, seed):
        kx, kw, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = jax.random.normal(kx, (m, k), jnp.float32)
        w = jax.random.normal(kw, (k, n), jnp.float32)
        b = jax.random.normal(kb, (n,), jnp.float32)
        out = fm.fused_matmul(x, w, b, epilogue=fm.EPILOGUE_LINEAR,
                              tile_m=tile, tile_n=tile, tile_k=tile)
        np.testing.assert_allclose(out, x @ w + b, rtol=1e-4, atol=1e-4)

    def test_vmem_budget_default_tiles(self):
        # the DESIGN.md §Perf claim: default tiles fit well under 16 MiB VMEM
        assert fm.vmem_bytes() < 8 * 1024 * 1024

    def test_mxu_utilization_estimate(self):
        assert fm.mxu_utilization_estimate(128, 128, 128) == 1.0
        assert fm.mxu_utilization_estimate(1, 1, 1) == pytest.approx(1 / 128**3)


# --------------------------------------------------------------------------
# conv / residual step
# --------------------------------------------------------------------------

class TestConv:
    def test_conv_relu_vs_ref(self):
        u, w, b = rand(20, 2, 8, 5, 5), rand(21, 4, 8, 3, 3), rand(22, 4)
        out = kconv.conv2d(u, w, b, pad=1, epilogue=fm.EPILOGUE_RELU)
        np.testing.assert_allclose(out, kref.conv_bias_relu_ref(u, w, b, 1), **TOL)

    def test_conv_7x7_shape_preserving(self):
        u, w, b = rand(23, 1, 4, 12, 12), rand(24, 4, 4, 7, 7), rand(25, 4)
        out = kconv.conv2d(u, w, b, pad=3, epilogue=fm.EPILOGUE_LINEAR)
        assert out.shape == (1, 4, 12, 12)
        np.testing.assert_allclose(out, kref.conv2d_ref(u, w, 3) + b[None, :, None, None], **TOL)

    def test_residual_step_vs_ref(self):
        u, w, b = rand(26, 2, 8, 7, 7), rand(27, 8, 8, 3, 3), rand(28, 8)
        h = jnp.float32(0.0625)
        out = kconv.residual_step(u, w, b, h, pad=1)
        np.testing.assert_allclose(out, kref.residual_step_ref(u, w, b, h, 1), **TOL)

    def test_residual_step_rejects_shrinking_pad(self):
        u, w, b = rand(29, 1, 4, 8, 8), rand(30, 4, 4, 7, 7), rand(31, 4)
        with pytest.raises(ValueError):
            kconv.residual_step(u, w, b, jnp.float32(0.1), pad=1)  # 7x7 pad1 shrinks

    def test_block_fwd_matches_repeated_steps(self):
        u0 = rand(32, 2, 4, 6, 6)
        ws, bs = rand(33, 3, 4, 4, 3, 3), rand(34, 3, 4)
        h = jnp.float32(0.25)
        states = kconv.block_fwd(u0, ws, bs, h, pad=1)
        u = u0
        for i in range(3):
            u = kref.residual_step_ref(u, ws[i], bs[i], h, 1)
            np.testing.assert_allclose(states[i], u, **TOL)

    def test_block_fwd_vs_ref(self):
        u0 = rand(35, 1, 8, 28, 28)
        ws, bs = rand(36, 4, 8, 8, 3, 3) * 0.1, rand(37, 4, 8)
        h = jnp.float32(0.0625)
        np.testing.assert_allclose(
            kconv.block_fwd(u0, ws, bs, h, pad=1),
            kref.block_fwd_ref(u0, ws, bs, h, 1), **TOL)

    def test_step_residual_zero_at_exact_state(self):
        u, w, b = rand(38, 2, 4, 6, 6), rand(39, 4, 4, 3, 3), rand(40, 4)
        h = jnp.float32(0.125)
        u_next = kref.residual_step_ref(u, w, b, h, 1)
        r = kconv.step_residual(u, u_next, w, b, h, pad=1)
        np.testing.assert_allclose(r, jnp.zeros_like(r), atol=2e-5)

    @settings(deadline=None, max_examples=15)
    @given(
        b=st.integers(1, 3),
        c=st.integers(1, 10),
        hw=st.integers(3, 12),
        k=st.sampled_from([1, 3, 5]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_residual_step(self, b, c, hw, k, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        u = jax.random.normal(ks[0], (b, c, hw, hw), jnp.float32)
        w = jax.random.normal(ks[1], (c, c, k, k), jnp.float32) * 0.2
        bias = jax.random.normal(ks[2], (c,), jnp.float32)
        h = jnp.float32(0.1)
        out = kconv.residual_step(u, w, bias, h, pad=k // 2)
        np.testing.assert_allclose(
            out, kref.residual_step_ref(u, w, bias, h, k // 2), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# softmax cross-entropy
# --------------------------------------------------------------------------

class TestSoftmaxXent:
    def test_vs_ref(self):
        logits = rand(50, 16, 10)
        labels = jnp.arange(16, dtype=jnp.int32) % 10
        np.testing.assert_allclose(
            kxent.softmax_xent(logits, labels),
            kref.softmax_xent_ref(logits, labels), **TOL)

    def test_single_row(self):
        logits = rand(51, 1, 10)
        labels = jnp.array([7], jnp.int32)
        np.testing.assert_allclose(
            kxent.softmax_xent(logits, labels),
            kref.softmax_xent_ref(logits, labels), **TOL)

    def test_large_logits_stable(self):
        logits = rand(52, 8, 10) * 1e4
        labels = jnp.zeros(8, jnp.int32)
        out = kxent.softmax_xent(logits, labels)
        ref = kref.softmax_xent_ref(logits, labels)
        assert jnp.isfinite(out)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-2)

    def test_uniform_logits_log_nclasses(self):
        logits = jnp.zeros((4, 10), jnp.float32)
        labels = jnp.array([0, 3, 5, 9], jnp.int32)
        np.testing.assert_allclose(
            kxent.softmax_xent(logits, labels), np.log(10.0), rtol=1e-6)

    @settings(deadline=None, max_examples=20)
    @given(b=st.integers(1, 150), ncls=st.integers(2, 20), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis(self, b, ncls, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        logits = jax.random.normal(k1, (b, ncls), jnp.float32) * 3
        labels = jax.random.randint(k2, (b,), 0, ncls, jnp.int32)
        np.testing.assert_allclose(
            kxent.softmax_xent(logits, labels),
            kref.softmax_xent_ref(logits, labels), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# reference self-consistency (the oracle itself has invariants)
# --------------------------------------------------------------------------

class TestRefInvariants:
    def test_adjoint_step_matches_full_vjp(self):
        u, w, b = rand(60, 2, 4, 6, 6), rand(61, 4, 4, 3, 3), rand(62, 4)
        h = jnp.float32(0.125)
        lam = rand(63, 2, 4, 6, 6)
        # contract λᵀ(∂Φ/∂u)v against finite differences of λᵀΦ(u+εv)
        v = rand(64, 2, 4, 6, 6)
        lam_prev = kref.adjoint_step_ref(u, w, b, h, 1, lam)
        eps = 1e-3
        f = lambda uu: jnp.vdot(lam, kref.residual_step_ref(uu, w, b, h, 1))
        fd = (f(u + eps * v) - f(u - eps * v)) / (2 * eps)
        np.testing.assert_allclose(jnp.vdot(lam_prev, v), fd, rtol=2e-2, atol=2e-2)

    def test_param_grad_matches_finite_difference(self):
        u, w, b = rand(65, 1, 2, 4, 4), rand(66, 2, 2, 3, 3), rand(67, 2)
        h = jnp.float32(0.25)
        lam = rand(68, 1, 2, 4, 4)
        dw, db = kref.step_param_grad_ref(u, w, b, h, 1, lam)
        eps = 1e-3
        g = lambda bb: jnp.vdot(lam, kref.residual_step_ref(u, w, bb, h, 1))
        fd0 = (g(b.at[0].add(eps)) - g(b.at[0].add(-eps))) / (2 * eps)
        np.testing.assert_allclose(db[0], fd0, rtol=2e-2, atol=2e-2)
        gw = lambda ww: jnp.vdot(lam, kref.residual_step_ref(u, ww, b, h, 1))
        fdw = (gw(w.at[0, 0, 1, 1].add(eps)) - gw(w.at[0, 0, 1, 1].add(-eps))) / (2 * eps)
        np.testing.assert_allclose(dw[0, 0, 1, 1], fdw, rtol=2e-2, atol=2e-2)
