"""Layer-2 model entry-point tests: shapes, numerics, and MG-relevant algebra.

These tests validate the exact functions that get AOT-lowered — if they pass
here, the HLO artifacts compute the same thing (lowering is semantics-
preserving; the rust integration tests then check the PJRT round-trip).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref as kref

jax.config.update("jax_platform_name", "cpu")

P = model.PRESETS["micro"]  # small and fast: C=2, 6x6, n_res=4, c=2
TOL = dict(rtol=2e-5, atol=2e-5)


def rand(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def micro_params(scale=0.3):
    wo = rand(1, P.channels, 1, P.kernel, P.kernel) * scale
    bo = rand(2, P.channels) * scale
    ws = rand(3, P.n_res, P.channels, P.channels, P.kernel, P.kernel) * scale
    bs = rand(4, P.n_res, P.channels) * scale
    wfc = rand(5, P.fc_in, P.n_classes) * scale
    bfc = rand(6, P.n_classes) * scale
    return wo, bo, ws, bs, wfc, bfc


class TestPresets:
    def test_registry_contains_exported_presets(self):
        assert {"mnist", "micro"} <= set(model.PRESETS)

    def test_h_is_t_over_n(self):
        p = model.PRESETS["mnist"]
        assert p.h == pytest.approx(p.t_final / p.n_res)

    def test_pad_preserves_shape(self):
        for p in model.PRESETS.values():
            assert 2 * p.pad + 1 == p.kernel  # shape-preserving

    def test_fc_in(self):
        p = model.PRESETS["mnist"]
        assert p.fc_in == p.channels * p.height * p.width

    def test_entry_specs_complete(self):
        entries = model.entry_specs(P, 2)
        expected = {
            "opening_fwd", "step_fwd", "block_fwd", "step_residual",
            "head_fwd", "serial_fwd", "head_vjp", "adjoint_step",
            "adjoint_block", "step_param_grad", "block_vjp",
        }
        assert set(entries) == expected


class TestForwardEntries:
    def test_opening_shape(self):
        y = rand(10, 2, 1, P.height, P.width)
        wo, bo, *_ = micro_params()
        (u0,) = model.opening_fwd(P, y, wo, bo)
        assert u0.shape == (2, P.channels, P.height, P.width)
        assert bool(jnp.all(u0 >= 0))  # ReLU output

    def test_serial_fwd_equals_unrolled_ref(self):
        y = rand(11, 2, 1, P.height, P.width)
        wo, bo, ws, bs, wfc, bfc = micro_params()
        labels = jnp.array([3, 7], jnp.int32)
        logits, loss, u_final = model.serial_fwd(P, y, wo, bo, ws, bs, wfc, bfc, labels)

        u = kref.conv_bias_relu_ref(y, wo, bo, P.pad)
        for i in range(P.n_res):
            u = kref.residual_step_ref(u, ws[i], bs[i], jnp.float32(P.h), P.pad)
        ref_logits, ref_loss = kref.head_fwd_ref(u, wfc, bfc, labels)
        np.testing.assert_allclose(u_final, u, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(logits, ref_logits, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(loss, ref_loss, rtol=1e-4, atol=1e-4)

    def test_block_fwd_composes_to_serial(self):
        # propagating block-by-block with block_fwd == whole-trunk propagation
        wo, bo, ws, bs, *_ = micro_params()
        u = rand(12, 2, P.channels, P.height, P.width)
        h = jnp.float32(P.h)
        via_blocks = u
        for blk in range(P.n_res // P.block):
            s = slice(blk * P.block, (blk + 1) * P.block)
            (states,) = model.block_fwd(P, via_blocks, ws[s], bs[s], h)
            via_blocks = states[-1]
        whole = kref.block_fwd_ref(u, ws, bs, h, P.pad)[-1]
        np.testing.assert_allclose(via_blocks, whole, rtol=1e-4, atol=1e-4)


class TestBackwardEntries:
    def test_head_vjp_matches_jax_grad(self):
        u = rand(20, 2, P.channels, P.height, P.width)
        *_, wfc, bfc = micro_params()
        labels = jnp.array([1, 2], jnp.int32)
        du, dwfc, dbfc = model.head_vjp(P, u, wfc, bfc, labels)
        assert du.shape == u.shape and dwfc.shape == wfc.shape and dbfc.shape == bfc.shape
        # loss decreases along -grad (first-order check)
        _, loss0 = kref.head_fwd_ref(u, wfc, bfc, labels)
        _, loss1 = kref.head_fwd_ref(u - 1e-2 * du, wfc, bfc, labels)
        assert loss1 < loss0

    def test_block_vjp_matches_autodiff_through_serial(self):
        u0 = rand(21, 1, P.channels, P.height, P.width)
        _, _, ws, bs, *_ = micro_params()
        wsb, bsb = ws[: P.block], bs[: P.block]
        h = jnp.float32(P.h)
        lam = rand(22, 1, P.channels, P.height, P.width)

        got_du0, got_dws, got_dbs = model.block_vjp(P, u0, wsb, bsb, h, lam)

        def f(uu, wws, bbs):
            return kref.block_fwd_ref(uu, wws, bbs, h, P.pad)[-1]

        _, vjp = jax.vjp(f, u0, wsb, bsb)
        ref_du0, ref_dws, ref_dbs = vjp(lam)
        np.testing.assert_allclose(got_du0, ref_du0, **TOL)
        np.testing.assert_allclose(got_dws, ref_dws, **TOL)
        np.testing.assert_allclose(got_dbs, ref_dbs, **TOL)

    def test_adjoint_block_equals_block_vjp_state_grad(self):
        """Adjoint recurrence through a block == VJP wrt the block input."""
        u0 = rand(23, 1, P.channels, P.height, P.width)
        _, _, ws, bs, *_ = micro_params()
        wsb, bsb = ws[: P.block], bs[: P.block]
        h = jnp.float32(P.h)
        lam = rand(24, 1, P.channels, P.height, P.width)

        # input states of each layer: u0, u1, ..., u_{c-1}
        states = kref.block_fwd_ref(u0, wsb, bsb, h, P.pad)
        us = jnp.concatenate([u0[None], states[:-1]], axis=0)
        lam0, lams = model.adjoint_block(P, us, wsb, bsb, h, lam)

        ref_du0, _, _ = model.block_vjp(P, u0, wsb, bsb, h, lam)
        np.testing.assert_allclose(lam0, ref_du0, **TOL)
        assert lams.shape == us.shape

    def test_param_grads_compose_block_vjp(self):
        """Layer-local param grads on exact states == block VJP param grads."""
        u0 = rand(25, 1, P.channels, P.height, P.width)
        _, _, ws, bs, *_ = micro_params()
        wsb, bsb = ws[: P.block], bs[: P.block]
        h = jnp.float32(P.h)
        lam = rand(26, 1, P.channels, P.height, P.width)

        states = kref.block_fwd_ref(u0, wsb, bsb, h, P.pad)
        us = jnp.concatenate([u0[None], states[:-1]], axis=0)
        # adjoints at the *output* of each layer i (= input adjoint of i+1)
        _, lams = model.adjoint_block(P, us, wsb, bsb, h, lam)
        lam_out = jnp.concatenate([lams[1:], lam[None]], axis=0)

        _, ref_dws, ref_dbs = model.block_vjp(P, u0, wsb, bsb, h, lam)
        for i in range(P.block):
            dw, db = model.step_param_grad(P, us[i], wsb[i], bsb[i], h, lam_out[i])
            np.testing.assert_allclose(dw, ref_dws[i], rtol=1e-4, atol=1e-4)
            np.testing.assert_allclose(db, ref_dbs[i], rtol=1e-4, atol=1e-4)


class TestMgAlgebra:
    """Sanity checks of the FAS identities the rust engine relies on."""

    def test_residual_vanishes_on_exact_trajectory(self):
        _, _, ws, bs, *_ = micro_params()
        u = rand(30, 1, P.channels, P.height, P.width)
        h = jnp.float32(P.h)
        traj = [u]
        for i in range(4):
            traj.append(kref.residual_step_ref(traj[-1], ws[i], bs[i], h, P.pad))
        for i in range(4):
            (r,) = model.step_residual(P, traj[i], traj[i + 1], ws[i], bs[i], h)
            np.testing.assert_allclose(r, jnp.zeros_like(r), atol=1e-4)

    def test_residual_detects_perturbation(self):
        _, _, ws, bs, *_ = micro_params()
        u = rand(31, 1, P.channels, P.height, P.width)
        h = jnp.float32(P.h)
        u1 = kref.residual_step_ref(u, ws[0], bs[0], h, P.pad)
        (r,) = model.step_residual(P, u, u1 + 0.1, ws[0], bs[0], h)
        assert float(jnp.abs(r).max()) > 0.05
