"""Pure-jnp reference oracle for every Pallas kernel.

These implementations are the correctness contract: each Pallas kernel in this
package must match its `*_ref` counterpart to float32 tolerance (enforced by
``python/tests/test_kernels.py``). They are also the building blocks for the
backward/VJP artifact entry points (we differentiate the reference path with
``jax.grad``; forward artifacts use the Pallas path, and the equality of the
two is what makes the gradients consistent).

All tensors are NCHW float32. Weights are ``[Cout, Cin, k, k]``; FC weights are
``[In, Out]``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(u: jax.Array, w: jax.Array, pad: int) -> jax.Array:
    """Plain 2-D convolution, NCHW / OIHW, unit stride, symmetric padding."""
    return jax.lax.conv_general_dilated(
        u,
        w,
        window_strides=(1, 1),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def conv_bias_relu_ref(u: jax.Array, w: jax.Array, b: jax.Array, pad: int) -> jax.Array:
    """F(u) = relu(conv(u, w) + b) — the paper's feature transformation."""
    return jax.nn.relu(conv2d_ref(u, w, pad) + b[None, :, None, None])


def residual_step_ref(
    u: jax.Array, w: jax.Array, b: jax.Array, h: jax.Array, pad: int
) -> jax.Array:
    """One residual block step: u + h * F(u; θ)   (paper eq. 1)."""
    return u + h * conv_bias_relu_ref(u, w, b, pad)


def block_fwd_ref(
    u0: jax.Array, ws: jax.Array, bs: jax.Array, h: jax.Array, pad: int
) -> jax.Array:
    """Sequential forward propagation through a block of ``c`` residual layers.

    ``ws``: [c, C, C, k, k], ``bs``: [c, C]. Returns the stacked states
    [c, B, C, H, W] — state ``i`` is the output of layer ``i`` of the block.
    """

    def step(u, wb):
        w, b = wb
        nxt = residual_step_ref(u, w, b, h, pad)
        return nxt, nxt

    _, states = jax.lax.scan(step, u0, (ws, bs))
    return states


def step_residual_ref(
    u_prev: jax.Array, u_cur: jax.Array, w: jax.Array, b: jax.Array, h: jax.Array, pad: int
) -> jax.Array:
    """MGRIT residual at one layer: r = Φ(u_prev) - u_cur  (paper eq. 19).

    With f_h = 0 away from the input layer, R = f - L(U) has components
    Φ(u^{n-1}) - u^n; we return that sign convention.
    """
    return residual_step_ref(u_prev, w, b, h, pad) - u_cur


def fc_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fully connected layer on flattened input: x @ w + b."""
    return x.reshape(x.shape[0], -1) @ w + b


def softmax_xent_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean cross-entropy of softmax(logits) against integer labels."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(logz - picked)


def head_fwd_ref(
    u: jax.Array, wfc: jax.Array, bfc: jax.Array, labels: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Classifier head: flatten → FC → softmax cross-entropy. Returns (logits, loss)."""
    logits = fc_ref(u, wfc, bfc)
    return logits, softmax_xent_ref(logits, labels)


def adjoint_step_ref(
    u: jax.Array, w: jax.Array, b: jax.Array, h: jax.Array, pad: int, lam_next: jax.Array
) -> jax.Array:
    """One step of the adjoint (backward) recurrence.

    λ^n = λ^{n+1} + h · (∂F/∂u(u^n))ᵀ λ^{n+1}, i.e. the VJP of the residual
    step at state u applied to λ^{n+1}. This is itself a (linear, reversed)
    residual network — the same MGRIT machinery applies to it.
    """
    _, vjp = jax.vjp(lambda uu: residual_step_ref(uu, w, b, h, pad), u)
    return vjp(lam_next)[0]


def step_param_grad_ref(
    u: jax.Array, w: jax.Array, b: jax.Array, h: jax.Array, pad: int, lam_next: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Per-layer parameter gradient: (∂(u + hF)/∂θ)ᵀ λ^{n+1} — local to a layer."""
    _, vjp = jax.vjp(lambda ww, bb: residual_step_ref(u, ww, bb, h, pad), w, b)
    return vjp(lam_next)
