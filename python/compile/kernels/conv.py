"""Convolution layers as im2col + the fused MXU matmul kernel.

The paper computes each residual layer with a CuDNN convolution kernel; on TPU
the same computation is a patch-matrix product (DESIGN.md §Hardware-Adaptation):

    conv(u, W)[b, o, y, x] = patches[b·H·W + y·W + x, :] @ W_mat[:, o]

``patches`` is the im2col matrix [B·H·W, Cin·k·k] extracted with
``lax.conv_general_dilated_patches`` (channel-major (C, k, k) flattening — the
ordering matches ``W.reshape(Cout, Cin·k·k)``, verified by the kernel tests),
and the product + bias + ReLU + residual skip all execute inside
``fused_matmul``'s epilogue, in VMEM.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import fused_matmul as fm


def _im2col(u: jax.Array, k: int, pad: int) -> jax.Array:
    """[B, C, H, W] → patch matrix [B·Ho·Wo, C·k·k] (unit stride)."""
    patches = jax.lax.conv_general_dilated_patches(
        u, (k, k), (1, 1), [(pad, pad), (pad, pad)]
    )  # [B, C*k*k, Ho, Wo]
    b, ckk, ho, wo = patches.shape
    return patches.transpose(0, 2, 3, 1).reshape(b * ho * wo, ckk), (b, ho, wo)


def _w_mat(w: jax.Array) -> jax.Array:
    """[Cout, Cin, k, k] → [Cin·k·k, Cout]."""
    cout = w.shape[0]
    return w.reshape(cout, -1).T


def conv2d(u: jax.Array, w: jax.Array, b: jax.Array, pad: int, *, epilogue: str) -> jax.Array:
    """conv + bias with a fused epilogue (linear or relu). NCHW → NCHW."""
    k = w.shape[-1]
    pm, (bsz, ho, wo) = _im2col(u, k, pad)
    out = fm.fused_matmul(pm, _w_mat(w), b, epilogue=epilogue)
    return out.reshape(bsz, ho, wo, w.shape[0]).transpose(0, 3, 1, 2)


def conv_bias_relu(u: jax.Array, w: jax.Array, b: jax.Array, pad: int) -> jax.Array:
    """F(u) = relu(conv(u, w) + b) via the Pallas kernel."""
    return conv2d(u, w, b, pad, epilogue=fm.EPILOGUE_RELU)


def residual_step(
    u: jax.Array, w: jax.Array, b: jax.Array, h: jax.Array, pad: int
) -> jax.Array:
    """One residual layer step u + h·relu(conv(u,W)+b), fully fused.

    The skip connection and the h-scaling ride in the matmul epilogue, so the
    whole step is a single kernel after im2col — the Layer-1 hot path.
    """
    k = w.shape[-1]
    pm, (bsz, ho, wo) = _im2col(u, k, pad)
    if (ho, wo) != u.shape[2:]:
        raise ValueError(
            f"residual step needs shape-preserving padding: in {u.shape[2:]}, out {(ho, wo)}"
        )
    skip = u.transpose(0, 2, 3, 1).reshape(bsz * ho * wo, u.shape[1])
    out = fm.fused_matmul(
        pm, _w_mat(w), b, epilogue=fm.EPILOGUE_RESIDUAL, skip=skip, h=h
    )
    return out.reshape(bsz, ho, wo, w.shape[0]).transpose(0, 3, 1, 2)


def block_fwd(
    u0: jax.Array, ws: jax.Array, bs: jax.Array, h: jax.Array, pad: int
) -> jax.Array:
    """F-relaxation unit: propagate sequentially through a block of c layers.

    Returns stacked states [c, B, C, H, W]. Lowered with ``lax.scan`` so the
    HLO stays O(1) in block size (a while loop over the layer axis) — the AOT
    artifact for c=4 is a few hundred KiB instead of an unrolled graph.
    """

    def step(u, wb):
        w, b = wb
        nxt = residual_step(u, w, b, h, pad)
        return nxt, nxt

    _, states = jax.lax.scan(step, u0, (ws, bs))
    return states


def step_residual(
    u_prev: jax.Array,
    u_cur: jax.Array,
    w: jax.Array,
    b: jax.Array,
    h: jax.Array,
    pad: int,
) -> jax.Array:
    """MGRIT layer residual r = Φ(u_prev) − u_cur (paper eq. 19 component)."""
    return residual_step(u_prev, w, b, h, pad) - u_cur
