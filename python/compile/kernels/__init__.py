"""Layer-1 Pallas kernels and their pure-jnp reference oracle.

Public surface:
- ``fused_matmul``: tiled MXU matmul with fused bias/ReLU/residual epilogue.
- ``conv``: conv layers as im2col + fused_matmul (residual_step, block_fwd).
- ``softmax_xent``: fused classifier-head loss.
- ``ref``: the correctness contract every kernel is tested against.
"""

from . import conv, fused_matmul, ref, softmax_xent  # noqa: F401
