"""Layer-1 Pallas kernel: fused row-wise softmax cross-entropy.

The classifier head's loss is a single VMEM-resident kernel: per row of
logits, compute a numerically-stable log-sum-exp and pick the label logit via
an iota comparison (one-hot matmul-free). Grid is 1-D over row tiles; the
class axis always fits one tile (10 classes here; pad to the 128-lane width).

Returns the per-row loss; the mean reduction happens in the caller so the
kernel stays shape-polymorphic over the batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 128


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _xent_kernel(logits_ref, labels_ref, loss_ref, *, n_classes: int):
    logits = logits_ref[...]  # [TR, Cp]
    labels = labels_ref[...]  # [TR, 1]
    # mask the class-padding lanes out of the reduction
    lane = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    neg_inf = jnp.full_like(logits, -jnp.inf)
    masked = jnp.where(lane < n_classes, logits, neg_inf)
    row_max = jnp.max(masked, axis=1, keepdims=True)
    shifted = jnp.where(lane < n_classes, masked - row_max, neg_inf)
    logz = jnp.log(jnp.sum(jnp.exp(shifted), axis=1, keepdims=True)) + row_max
    picked = jnp.sum(jnp.where(lane == labels, logits, 0.0), axis=1, keepdims=True)
    loss_ref[...] = logz - picked


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy over the batch. logits [B, C], labels [B] i32."""
    bsz, n_classes = logits.shape
    tr = min(TILE_ROWS, _ceil_to(bsz, 8))
    bp = _ceil_to(bsz, tr)
    cp = _ceil_to(n_classes, 128)

    lp = jnp.pad(logits, ((0, bp - bsz), (0, cp - n_classes)))
    # pad labels with -1 so padded rows pick nothing (their loss is discarded)
    labp = jnp.pad(labels.astype(jnp.int32), (0, bp - bsz), constant_values=-1)[:, None]

    per_row = pl.pallas_call(
        functools.partial(_xent_kernel, n_classes=n_classes),
        grid=(bp // tr,),
        in_specs=[
            pl.BlockSpec((tr, cp), lambda i: (i, 0)),
            pl.BlockSpec((tr, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tr, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), jnp.float32),
        interpret=True,
    )(lp, labp)
    return jnp.mean(per_row[:bsz, 0])
