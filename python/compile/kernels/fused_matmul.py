"""Layer-1 Pallas kernel: tiled MXU matmul with a fused residual epilogue.

This is the compute hot-spot of the paper's system: every residual layer step
``u + h * relu(conv(u, W) + b)`` is lowered to an im2col matrix product (see
``conv.py``) whose inner loop is this kernel. The GPU paper realizes the step
as CuDNN conv + activation kernels launched on a CUDA stream; the TPU rethink
(DESIGN.md §Hardware-Adaptation) maps it onto the MXU systolic array:

- grid = (M/TM, N/TN, K/TK); the K axis is the innermost (fastest) grid
  dimension, so each (i, j) output tile accumulates over K sub-tiles in a
  float32 VMEM scratch accumulator — the canonical MXU matmul schedule.
- the epilogue (bias add, ReLU, residual skip-add scaled by the ODE step h)
  executes in VMEM on the final K step — one HBM round-trip per layer instead
  of CuDNN's separate conv/bias/activation kernel launches.
- BlockSpecs express the HBM→VMEM streaming schedule the CUDA implementation
  expressed with threadblocks; independent layer blocks (the paper's streams)
  become independent grid slices.

The kernel always runs with ``interpret=True`` here: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute. The structure (tiling,
scratch accumulation, fused epilogue) is the TPU-ready part; interpret mode
gives bit-accurate numerics for the AOT artifacts.

VMEM budget per grid step (fp32): TM·TK + TK·TN + 2·TM·TN + TN floats.
With the default TM=TN=TK=128 that is 3·128² + 128 ≈ 196 KiB, far below the
≈16 MiB/core budget, leaving room for the pipelined double-buffering the
Mosaic compiler inserts for the streaming operands.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default MXU-shaped tiles. Shapes smaller than a tile are padded up by the
# wrappers below; pad cells multiply to zero so numerics are unaffected.
TILE_M = 128
TILE_N = 128
TILE_K = 128

# Epilogue modes (baked at trace time — each variant is its own artifact).
EPILOGUE_LINEAR = "linear"  # o = acc + b                  (FC head)
EPILOGUE_RELU = "relu"  # o = relu(acc + b)                (opening layer)
EPILOGUE_RESIDUAL = "residual"  # o = skip + h*relu(acc+b) (residual step)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _mm_kernel(x_ref, w_ref, b_ref, *rest, epilogue: str):
    """Grid (i, j, k): accumulate x[i,k] @ w[k,j] into VMEM scratch; fused
    epilogue on the last k step."""
    if epilogue == EPILOGUE_RESIDUAL:
        skip_ref, h_ref, o_ref, acc_ref = rest
    else:
        o_ref, acc_ref = rest

    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...] + b_ref[...]
        if epilogue == EPILOGUE_LINEAR:
            o_ref[...] = acc
        elif epilogue == EPILOGUE_RELU:
            o_ref[...] = jnp.maximum(acc, 0.0)
        else:  # EPILOGUE_RESIDUAL
            o_ref[...] = skip_ref[...] + h_ref[0, 0] * jnp.maximum(acc, 0.0)


def fused_matmul(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    epilogue: str = EPILOGUE_LINEAR,
    skip: Optional[jax.Array] = None,
    h: Optional[jax.Array] = None,
    tile_m: int = TILE_M,
    tile_n: int = TILE_N,
    tile_k: int = TILE_K,
) -> jax.Array:
    """o = epilogue(x @ w + b) with optional fused residual skip.

    x: [M, K], w: [K, N], b: [N]; skip: [M, N] and h: scalar () for the
    residual epilogue. Inputs are zero-padded to tile multiples and the
    result sliced back, so arbitrary shapes are accepted.
    """
    if epilogue not in (EPILOGUE_LINEAR, EPILOGUE_RELU, EPILOGUE_RESIDUAL):
        raise ValueError(f"unknown epilogue {epilogue!r}")
    if (epilogue == EPILOGUE_RESIDUAL) != (skip is not None and h is not None):
        raise ValueError("residual epilogue requires skip and h (and only it does)")

    m, kdim = x.shape
    k2, n = w.shape
    if kdim != k2 or b.shape != (n,):
        raise ValueError(f"shape mismatch: x{x.shape} w{w.shape} b{b.shape}")

    tm, tn, tk = min(tile_m, _ceil_to(m, 8)), min(tile_n, _ceil_to(n, 8)), min(
        tile_k, _ceil_to(kdim, 8)
    )
    mp, np_, kp = _ceil_to(m, tm), _ceil_to(n, tn), _ceil_to(kdim, tk)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - kdim)))
    wp = jnp.pad(w, ((0, kp - kdim), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n))[None, :]  # [1, Np] — broadcast over rows

    grid = (mp // tm, np_ // tn, kp // tk)
    in_specs = [
        pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
        pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        pl.BlockSpec((1, tn), lambda i, j, k: (0, j)),
    ]
    operands = [xp, wp, bp]
    if epilogue == EPILOGUE_RESIDUAL:
        skipp = jnp.pad(skip, ((0, mp - m), (0, np_ - n)))
        in_specs.append(pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)))
        # scalar h lives in a (1, 1) block broadcast to every grid step
        in_specs.append(pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)))
        operands.extend([skipp, jnp.asarray(h, jnp.float32).reshape(1, 1)])

    out = pl.pallas_call(
        functools.partial(_mm_kernel, epilogue=epilogue),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=True,
    )(*operands)
    return out[:m, :n]


def vmem_bytes(tile_m: int = TILE_M, tile_n: int = TILE_N, tile_k: int = TILE_K) -> int:
    """Static VMEM footprint estimate of one grid step (fp32, incl. the
    double-buffered copy Mosaic keeps for the streaming x/w operands)."""
    x_tile = tile_m * tile_k
    w_tile = tile_k * tile_n
    out_tile = tile_m * tile_n
    acc = tile_m * tile_n
    bias = tile_n
    return 4 * (2 * (x_tile + w_tile) + out_tile + acc + bias)


def mxu_utilization_estimate(
    m: int, n: int, k: int, tile_m: int = TILE_M, tile_n: int = TILE_N, tile_k: int = TILE_K
) -> float:
    """Fraction of MXU issue slots doing useful work: real FLOPs over FLOPs of
    the padded tile grid (the MXU runs full 128×128 passes regardless)."""
    mp, np_, kp = _ceil_to(m, tile_m), _ceil_to(n, tile_n), _ceil_to(k, tile_k)
    return (m * n * k) / float(mp * np_ * kp)
