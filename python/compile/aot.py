"""AOT compiler: lower every Layer-2 entry point to HLO text + a manifest.

Run once at build time (``make artifacts``); the rust coordinator loads the
results via PJRT and never imports Python.

Interchange format is **HLO text**, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs:
    artifacts/<preset>_<entry>_b<batch>.hlo.txt
    artifacts/manifest.json   — entry/preset/batch → file, arg shapes/dtypes,
                                output shapes, plus the preset hyperparameters
                                (the rust config system reads these back).
"""

from __future__ import annotations

import argparse
import json
import os

import jax

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_entry(fn, arg_specs) -> tuple[str, list]:
    lowered = jax.jit(fn).lower(*arg_specs)
    out_tree = jax.eval_shape(fn, *arg_specs)
    outs = jax.tree_util.tree_leaves(out_tree)
    return to_hlo_text(lowered), [_spec_json(o) for o in outs]


def build(out_dir: str, presets=None, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "presets": {}, "entries": []}
    for pname, p in model.PRESETS.items():
        if presets and pname not in presets:
            continue
        manifest["presets"][pname] = {
            "channels": p.channels, "kernel": p.kernel, "pad": p.pad,
            "height": p.height, "width": p.width, "n_res": p.n_res,
            "block": p.block, "t_final": p.t_final, "h": p.h,
            "n_classes": p.n_classes, "fc_in": p.fc_in,
            "batches": list(p.batches),
        }
        for batch in p.batches:
            for ename, (fn, specs) in model.entry_specs(p, batch).items():
                fname = f"{pname}_{ename}_b{batch}.hlo.txt"
                text, outs = lower_entry(fn, specs)
                with open(os.path.join(out_dir, fname), "w") as f:
                    f.write(text)
                manifest["entries"].append({
                    "preset": pname, "entry": ename, "batch": batch,
                    "file": fname,
                    "inputs": [_spec_json(s) for s in specs],
                    "outputs": outs,
                })
                if verbose:
                    print(f"  lowered {fname}  ({len(text) // 1024} KiB)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {len(manifest['entries'])} artifacts to {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--preset", action="append", help="limit to preset(s)")
    args = ap.parse_args()
    build(args.out, presets=args.preset)


if __name__ == "__main__":
    main()
