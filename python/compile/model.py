"""Layer-2: the JAX residual network and the MGRIT building-block entry points.

The rust coordinator never traces JAX — it executes a fixed menu of AOT-lowered
functions (one HLO artifact per entry × preset × batch size, see ``aot.py``).
This module defines that menu:

forward (Pallas hot path):
- ``opening_fwd``   input layer: conv(1→C) + bias + ReLU
- ``step_fwd``      one residual layer step u + h·F(u;θ)   (C-relaxation unit)
- ``block_fwd``     c sequential steps, states stacked      (F-relaxation unit)
- ``step_residual`` MGRIT layer residual Φ(u_prev) − u_cur  (eq. 19)
- ``head_fwd``      FC → fused softmax cross-entropy        (logits, loss)
- ``serial_fwd``    whole-network forward — the sequential baseline

backward (jnp reference path, differentiated with jax.vjp — consistent with
the Pallas forward because the kernel tests pin them together):
- ``head_vjp``        d(loss)/d(u, wfc, bfc)
- ``adjoint_step``    λ ← λ + h·(∂F/∂u)ᵀλ        (adjoint-MGRIT C-relaxation)
- ``adjoint_block``   c adjoint steps through a block (adjoint F-relaxation)
- ``step_param_grad`` per-layer (dW, db) from (u, λ_next) — layer-local
- ``block_vjp``       exact VJP through a block (PM/serial baseline training)

Every entry takes the ODE step ``h`` as a runtime scalar so a single artifact
serves every MG level (coarse levels use H = c·h).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import conv as kconv
from .kernels import fused_matmul as fm
from .kernels import ref as kref
from .kernels import softmax_xent as kxent


@dataclasses.dataclass(frozen=True)
class Preset:
    """Hyperparameters of one exported network configuration.

    Mirrors ``rust/src/model/spec.rs`` — the manifest carries these values so
    the rust side never hard-codes them.
    """

    name: str
    channels: int  # residual trunk width C
    kernel: int  # conv kernel size k (shape-preserving pad = k//2)
    height: int
    width: int
    n_res: int  # number of residual layers
    block: int  # MGRIT coarsening factor c == layers per block
    t_final: float  # ODE horizon T; fine-level h = T / n_res
    n_classes: int = 10
    batches: tuple = (1, 16)

    @property
    def pad(self) -> int:
        return self.kernel // 2

    @property
    def h(self) -> float:
        return self.t_final / self.n_res

    @property
    def fc_in(self) -> int:
        return self.channels * self.height * self.width


# The presets actually exported to artifacts/. `mnist` is the end-to-end
# training network; `micro` keeps rust integration tests fast. The fig6/fig7
# scaling presets exist only in the rust cost model (DESIGN.md §4) — their
# 4k-layer numerics would be identical per-layer artifacts at larger shapes.
PRESETS = {
    "mnist": Preset("mnist", channels=8, kernel=3, height=28, width=28,
                    n_res=32, block=4, t_final=2.0, batches=(1, 16)),
    "micro": Preset("micro", channels=2, kernel=3, height=6, width=6,
                    n_res=4, block=2, t_final=1.0, batches=(2,)),
}


# --------------------------------------------------------------------------
# forward entries (Pallas hot path)
# --------------------------------------------------------------------------

def opening_fwd(p: Preset, y, w, b):
    """Input layer: y [B,1,H,W] → u0 [B,C,H,W] = relu(conv(y,w)+b)."""
    return (kconv.conv2d(y, w, b, p.pad, epilogue=fm.EPILOGUE_RELU),)


def step_fwd(p: Preset, u, w, b, h):
    """One residual layer step (the C-relaxation unit)."""
    return (kconv.residual_step(u, w, b, h, p.pad),)


def block_fwd(p: Preset, u0, ws, bs, h):
    """F-relaxation unit: c steps, returns states [c,B,C,H,W]."""
    return (kconv.block_fwd(u0, ws, bs, h, p.pad),)


def step_residual(p: Preset, u_prev, u_cur, w, b, h):
    """MGRIT residual component r = Φ(u_prev) − u_cur."""
    return (kconv.step_residual(u_prev, u_cur, w, b, h, p.pad),)


def head_fwd(p: Preset, u, wfc, bfc, labels):
    """Classifier head: (logits [B,10], mean loss [])."""
    flat = u.reshape(u.shape[0], -1)
    logits = fm.fused_matmul(flat, wfc, bfc, epilogue=fm.EPILOGUE_LINEAR)
    return logits, kxent.softmax_xent(logits, labels)


def serial_fwd(p: Preset, y, wo, bo, ws, bs, wfc, bfc, labels):
    """Whole-network sequential forward — the paper's serial baseline.

    Returns (logits, loss, u_final). Uses the same Pallas kernels as the MG
    path so serial-vs-MG comparisons isolate the algorithm, not the kernels.
    """
    u0 = kconv.conv2d(y, wo, bo, p.pad, epilogue=fm.EPILOGUE_RELU)
    h = jnp.float32(p.h)
    states = kconv.block_fwd(u0, ws, bs, h, p.pad)
    u_final = states[-1]
    logits, loss = head_fwd(p, u_final, wfc, bfc, labels)
    return logits, loss, u_final


# --------------------------------------------------------------------------
# backward entries (reference path + jax.vjp)
# --------------------------------------------------------------------------

def head_vjp(p: Preset, u, wfc, bfc, labels):
    """Gradient of the head loss wrt (u, wfc, bfc); seeds the adjoint solve."""
    def loss_fn(uu, ww, bb):
        _, loss = kref.head_fwd_ref(uu, ww, bb, labels)
        return loss

    return jax.grad(loss_fn, argnums=(0, 1, 2))(u, wfc, bfc)


def adjoint_step(p: Preset, u, w, b, h, lam):
    """One adjoint step λ ← λ + h·(∂F/∂u(u))ᵀ λ."""
    return (kref.adjoint_step_ref(u, w, b, h, p.pad, lam),)


def adjoint_block(p: Preset, us, ws, bs, h, lam):
    """Adjoint F-relaxation through one block, reversed layer order.

    ``us`` [c,B,C,H,W] are the *input* states of layers c-1..0's steps (i.e.
    us[i] is the state the i-th layer consumed). Returns stacked adjoints
    [c,B,C,H,W] where out[i] = λ at the input of layer i, plus λ at block in.
    """

    def step(lam_next, xwb):
        u, w, b = xwb
        lam_prev = kref.adjoint_step_ref(u, w, b, h, p.pad, lam_next)
        return lam_prev, lam_prev

    lam0, lams = jax.lax.scan(step, lam, (us, ws, bs), reverse=True)
    return lam0, lams


def step_param_grad(p: Preset, u, w, b, h, lam):
    """Layer-local parameter gradient (dW, db) — embarrassingly parallel."""
    return kref.step_param_grad_ref(u, w, b, h, p.pad, lam)


def block_vjp(p: Preset, u0, ws, bs, h, lam):
    """Exact VJP through a block: (λ at block input, dWs, dbs).

    Used by the serial / model-partitioned training baselines; MG training
    uses adjoint_block + step_param_grad on MG-approximate states instead.
    """

    def f(uu, wws, bbs):
        states = kref.block_fwd_ref(uu, wws, bbs, h, p.pad)
        return states[-1]

    _, vjp = jax.vjp(f, u0, ws, bs)
    return vjp(lam)


# --------------------------------------------------------------------------
# entry registry: name → (fn, example-arg builder)
# --------------------------------------------------------------------------

def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def entry_specs(p: Preset, batch: int) -> dict[str, tuple[Callable, list]]:
    """The AOT menu: entry name → (python callable, example argument specs)."""
    c_, k, hh, ww = p.channels, p.kernel, p.height, p.width
    cb = p.block
    u = _f32(batch, c_, hh, ww)
    wconv = _f32(c_, c_, k, k)
    bconv = _f32(c_)
    ws = _f32(cb, c_, c_, k, k)
    bs = _f32(cb, c_)
    ws_all = _f32(p.n_res, c_, c_, k, k)
    bs_all = _f32(p.n_res, c_)
    hscalar = _f32()
    y = _f32(batch, 1, hh, ww)
    wo = _f32(c_, 1, k, k)
    wfc = _f32(p.fc_in, p.n_classes)
    bfc = _f32(p.n_classes)
    labels = _i32(batch)
    lam = u
    states = _f32(cb, batch, c_, hh, ww)

    def bind(fn):
        return lambda *args: fn(p, *args)

    return {
        "opening_fwd": (bind(opening_fwd), [y, wo, bconv]),
        "step_fwd": (bind(step_fwd), [u, wconv, bconv, hscalar]),
        "block_fwd": (bind(block_fwd), [u, ws, bs, hscalar]),
        "step_residual": (bind(step_residual), [u, u, wconv, bconv, hscalar]),
        "head_fwd": (bind(head_fwd), [u, wfc, bfc, labels]),
        "serial_fwd": (bind(serial_fwd), [y, wo, bconv, ws_all, bs_all, wfc, bfc, labels]),
        "head_vjp": (bind(head_vjp), [u, wfc, bfc, labels]),
        "adjoint_step": (bind(adjoint_step), [u, wconv, bconv, hscalar, lam]),
        "adjoint_block": (bind(adjoint_block), [states, ws, bs, hscalar, lam]),
        "step_param_grad": (bind(step_param_grad), [u, wconv, bconv, hscalar, lam]),
        "block_vjp": (bind(block_vjp), [u, ws, bs, hscalar, lam]),
    }
