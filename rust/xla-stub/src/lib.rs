//! Offline stand-in for the `xla` crate (PJRT bindings).
//!
//! The real crate links the PJRT C API shared library, which is not available
//! in this build environment. This stub reproduces exactly the API surface
//! `resnet-mgrit` uses so the crate always compiles and the pure-host paths
//! run untouched:
//!
//! - [`Literal`] is fully functional (an in-memory typed array) — the
//!   Tensor ↔ Literal conversion helpers and their tests work as-is;
//! - [`PjRtClient::cpu`] (and everything downstream of it) returns a clear
//!   "PJRT unavailable" error, which `resnet_mgrit::runtime` surfaces as the
//!   host-solver fallback.
//!
//! Replace the `xla = { path = "xla-stub" }` dependency with the real crate
//! to light up the AOT-artifact execution path; no call-site changes needed.

use std::path::Path;

/// Stub error type (the real crate's errors are also displayed as strings).
#[derive(Debug, Clone)]
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (built with the in-tree `xla` stub; \
         link the real `xla` crate to execute AOT artifacts)"
    ))
}

/// Element types the stub can carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Tuple,
}

mod private {
    /// Typed storage of one literal.
    #[derive(Debug, Clone, PartialEq)]
    pub enum Data {
        F32(Vec<f32>),
        I32(Vec<i32>),
        Tuple(Vec<super::Literal>),
    }

    pub trait Native: Copy {
        fn wrap(v: Vec<Self>) -> Data
        where
            Self: Sized;
        fn unwrap(d: &Data) -> Option<Vec<Self>>
        where
            Self: Sized;
        fn ty() -> super::ElementType;
    }

    impl Native for f32 {
        fn wrap(v: Vec<f32>) -> Data {
            Data::F32(v)
        }
        fn unwrap(d: &Data) -> Option<Vec<f32>> {
            match d {
                Data::F32(v) => Some(v.clone()),
                _ => None,
            }
        }
        fn ty() -> super::ElementType {
            super::ElementType::F32
        }
    }

    impl Native for i32 {
        fn wrap(v: Vec<i32>) -> Data {
            Data::I32(v)
        }
        fn unwrap(d: &Data) -> Option<Vec<i32>> {
            match d {
                Data::I32(v) => Some(v.clone()),
                _ => None,
            }
        }
        fn ty() -> super::ElementType {
            super::ElementType::S32
        }
    }
}

/// Rust scalar types a [`Literal`] can hold (f32 and i32 here).
pub trait NativeType: private::Native {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// A typed, shaped array value — fully functional in the stub.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: private::Data,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { dims: vec![v.len() as i64], data: T::wrap(v.to_vec()) }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: Vec::new(), data: T::wrap(vec![v]) }
    }

    /// Reinterpret with new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.element_count() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Copy the elements out as `Vec<T>`; errors on a dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
            .ok_or_else(|| Error(format!("literal dtype mismatch (have {:?})", self.ty())))
    }

    /// Shape of a non-tuple literal.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data {
            private::Data::Tuple(_) => Err(Error("tuple literal has no array shape".into())),
            _ => Ok(ArrayShape { dims: self.dims.clone(), ty: self.ty() }),
        }
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            private::Data::Tuple(v) => Ok(v),
            _ => Err(Error("not a tuple literal".into())),
        }
    }

    /// Build a tuple literal (test/interop helper; mirrors the real crate).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { dims: Vec::new(), data: private::Data::Tuple(parts) }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            private::Data::F32(v) => v.len(),
            private::Data::I32(v) => v.len(),
            private::Data::Tuple(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match &self.data {
            private::Data::F32(_) => ElementType::F32,
            private::Data::I32(_) => ElementType::S32,
            private::Data::Tuple(_) => ElementType::Tuple,
        }
    }
}

/// Shape (dims + element type) of a non-tuple literal.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Stub PJRT client: construction always fails with a clear message.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub compiled executable (unreachable: the client cannot be constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub device buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Stub HLO module handle.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error(format!(
            "cannot parse {}: HLO parsing requires the real `xla` crate (stub build)",
            path.as_ref().display()
        )))
    }
}

/// Stub computation handle.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.element_count(), 6);
        let r = l.reshape(&[2, 3]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(r.reshape(&[7]).is_err());
    }

    #[test]
    fn scalar_and_i32() {
        let s = Literal::scalar(0.25f32);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![0.25]);
        let labels = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(labels.element_count(), 3);
        assert_eq!(labels.array_shape().unwrap().ty(), ElementType::S32);
    }

    #[test]
    fn tuple_literals() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        assert!(t.array_shape().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(1.0f32).to_tuple().is_err());
    }

    #[test]
    fn pjrt_client_unavailable_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT runtime unavailable"));
        let err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("x.hlo.txt"));
    }
}
