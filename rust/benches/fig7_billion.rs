//! Bench: regenerate Fig 7 — the 2.07B-parameter network, MG vs
//! Model-Partitioned over 1..64 GPUs (simulated; the preset is
//! cost-model-only), with the paper's compute-ratio trend.

use resnet_mgrit::experiments::fig7;
use resnet_mgrit::util::bench::Suite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let mut suite = Suite::new("fig7_billion");
    let gpus: &[usize] = if quick { &[1, 4, 64] } else { &fig7::GPU_COUNTS };

    let table = fig7::run(gpus).expect("fig7");
    println!("{}", table.render());
    suite.table("fig7_rows", table.to_json_rows());

    suite.bench("simulate_fig7_mg_64gpu", || {
        let spec = resnet_mgrit::model::NetSpec::fig7();
        let _ = resnet_mgrit::experiments::fig6::simulate_mg(&spec, 64, 2, false).unwrap();
    });
    suite.finish();
}
