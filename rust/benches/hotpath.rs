//! Bench: the L3 hot paths (EXPERIMENTS.md §Perf) — host conv kernels,
//! residual-step + VJP, one MGRIT cycle, simulator event throughput, and
//! PJRT artifact execution overhead. These are the before/after numbers for
//! the optimization log.

use std::sync::Arc;

use resnet_mgrit::coordinator::{ParallelMgrit, Partition};
use resnet_mgrit::mgrit::{self, hierarchy::Hierarchy, taskgraph, MgritOptions};
use resnet_mgrit::model::{NetParams, NetSpec};
use resnet_mgrit::perfmodel::ClusterModel;
use resnet_mgrit::solver::host::HostSolver;
use resnet_mgrit::solver::BlockSolver;
use resnet_mgrit::tensor::{ops, vjp, Tensor};
use resnet_mgrit::util::bench::{black_box, Suite};
use resnet_mgrit::util::prng::Rng;

fn main() {
    let mut suite = Suite::new("hotpath");
    let mut rng = Rng::new(1);

    // L3 kernel: conv2d at the mnist preset shape (8ch 28x28 k3)
    let u = Tensor::randn(&[16, 8, 28, 28], 1.0, &mut rng);
    let w = Tensor::randn(&[8, 8, 3, 3], 0.2, &mut rng);
    let b = Tensor::randn(&[8], 0.2, &mut rng);
    suite.bench("conv2d_b16_c8_28x28_k3", || {
        black_box(ops::conv2d(&u, &w, 1).unwrap());
    });
    suite.bench("residual_step_b16_c8_28x28", || {
        black_box(ops::residual_step(&u, &w, &b, 0.0625, 1).unwrap());
    });
    let lam = Tensor::randn(&[16, 8, 28, 28], 1.0, &mut rng);
    suite.bench("residual_step_vjp_b16_c8_28x28", || {
        black_box(vjp::residual_step_vjp(&u, &w, &b, 0.0625, 1, &lam).unwrap());
    });

    // fig6 preset shape (4ch 24x24 k7)
    let u6 = Tensor::randn(&[1, 4, 24, 24], 1.0, &mut rng);
    let w6 = Tensor::randn(&[4, 4, 7, 7], 0.1, &mut rng);
    suite.bench("conv2d_b1_c4_24x24_k7", || {
        black_box(ops::conv2d(&u6, &w6, 3).unwrap());
    });

    // one full MGRIT cycle on the mnist preset (host numerics)
    let spec = Arc::new(NetSpec::mnist());
    let params = Arc::new(NetParams::init(&spec, 2).unwrap());
    let solver = HostSolver::new(spec.clone(), params).unwrap();
    let u0 = Tensor::randn(&[1, 8, 28, 28], 0.5, &mut rng);
    let opts = MgritOptions { max_cycles: 1, tol: 0.0, ..Default::default() };
    suite.bench("mgrit_cycle_mnist_b1", || {
        black_box(mgrit::solve_forward(&solver, 32, spec.h(), &u0, &opts).unwrap());
    });
    suite.bench("serial_fprop_mnist_b1", || {
        black_box(solver.block_fprop(0, 1, 32, spec.h(), &u0).unwrap());
    });

    // the dependency-driven DAG executor: one MGRIT cycle fanned out over
    // 4 worker threads (barrier-free schedule, bit-identical numerics)
    {
        let spec2 = Arc::new(NetSpec::mnist());
        let params2 = Arc::new(NetParams::init(&spec2, 2).unwrap());
        let sp = spec2.clone();
        let factory = move |_w: usize| HostSolver::new(sp.clone(), params2.clone());
        let hier = Hierarchy::two_level(32, spec2.h(), 4).unwrap();
        let mut driver = ParallelMgrit::new(factory, spec2.clone(), hier, 4, 1).unwrap();
        // clear the pool trace each iteration — it is an unbounded append-only
        // Vec, and thousands of timed iterations would skew the medians
        suite.bench("dag_executor_cycle_mnist_b1_4dev", || {
            driver.pool().clear_trace();
            black_box(driver.solve(&u0, &opts).unwrap());
        });
        // graph construction itself (built once per solve)
        suite.bench("build_mnist_vcycle_graph", || {
            black_box(driver.cycle_graph(&opts));
        });
        // the whole-training-step graph on the live executor (fwd + head +
        // adjoint + grads + SGD in one DAG), per-step and fused granularity
        let y = Tensor::randn(&[1, 1, 28, 28], 0.5, &mut rng);
        let labels = [3i32];
        let topts = MgritOptions::early_stopping(2);
        suite.bench("dag_executor_train_step_mnist_b1_4dev", || {
            driver.pool().clear_trace();
            black_box(driver.train_step(&y, &labels, &topts, 0.05).unwrap());
        });
        driver.set_granularity(resnet_mgrit::mgrit::Granularity::PerBlock);
        suite.bench("dag_executor_train_step_mnist_b1_4dev_per_block", || {
            driver.pool().clear_trace();
            black_box(driver.train_step(&y, &labels, &topts, 0.05).unwrap());
        });
        suite.bench("build_mnist_train_step_graph", || {
            black_box(driver.train_graph(&topts));
        });
        // the hybrid data×layer step: 2 micro-batches pipelined through one
        // composed graph vs 2 sequential single-instance steps
        driver.set_granularity(resnet_mgrit::mgrit::Granularity::PerStep);
        let y2 = Tensor::randn(&[2, 1, 28, 28], 0.5, &mut rng);
        let labels2 = [3i32, 5];
        suite.bench("dag_executor_train_step_micro2_mnist_b2_4dev", || {
            driver.pool().clear_trace();
            black_box(driver.train_step_micro(&y2, &labels2, &topts, 0.05, 2).unwrap());
        });
        suite.bench("build_mnist_train_step_graph_micro2", || {
            black_box(driver.train_graph_micro(&topts, 2).unwrap());
        });
    }

    // simulator throughput on the fig6 2-cycle schedule at 24 GPUs
    let fig6 = NetSpec::fig6();
    let hier = Hierarchy::build(fig6.n_res(), fig6.h(), 4, 8, 8).unwrap();
    let part = Partition::contiguous(hier.fine().blocks(4).len(), 24).unwrap();
    let g = taskgraph::mg_forward(&fig6, &hier, &part, 1, 2);
    println!("  (fig6 schedule: {} tasks)", g.n_tasks());
    suite.bench("simulate_fig6_24gpu_2cycles", || {
        black_box(
            resnet_mgrit::sim::simulate(&g, &ClusterModel::tx_gaia(24), false).unwrap(),
        );
    });

    // taskgraph generation itself
    suite.bench("build_fig6_taskgraph_2cycles", || {
        black_box(taskgraph::mg_forward(&fig6, &hier, &part, 1, 2));
    });

    suite.finish();
}
