//! Bench: the design-choice ablations DESIGN.md calls out — cycle count ×
//! relaxation kind (accuracy/work trade-off), coarsening factor, hierarchy
//! depth — real numerics + simulated cost.

use resnet_mgrit::experiments::ablations;
use resnet_mgrit::util::bench::Suite;

fn main() {
    let mut suite = Suite::new("ablations");

    let t = ablations::cycles_and_relax(20).expect("cycles/relax");
    println!("{}", t.render());
    suite.table("cycles_relax_rows", t.to_json_rows());

    let t = ablations::coarsening(21).expect("coarsening");
    println!("{}", t.render());
    suite.table("coarsening_rows", t.to_json_rows());

    let t = ablations::hierarchy_depth(16).expect("hierarchy");
    println!("{}", t.render());
    suite.table("hierarchy_rows", t.to_json_rows());

    suite.finish();
}
