//! Bench: regenerate Fig 6b (training-phase fwd prop: serial vs PM vs MG)
//! and Fig 6c (compute/communication decomposition) on the simulated
//! TX-GAIA cluster, plus the training-step timeline — the whole-training-step
//! graph scored by the simulator *and* observed on the live DAG executor.

use resnet_mgrit::experiments::fig6;
use resnet_mgrit::util::bench::Suite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let mut suite = Suite::new("fig6bc_training");
    let gpus: &[usize] = if quick { &[1, 4, 24] } else { &fig6::GPU_COUNTS };

    let b = fig6::fig6b(gpus).expect("fig6b");
    println!("{}", b.render());
    suite.table("fig6b_rows", b.to_json_rows());

    let c = fig6::fig6c(gpus).expect("fig6c");
    println!("{}", c.render());
    suite.table("fig6c_rows", c.to_json_rows());

    // the training-step timeline: simulated and observed on one graph
    let (depth, devices) = if quick { (32, 2) } else { (64, 4) };
    let (t, ascii) = fig6::training_timeline(depth, devices).expect("training timeline");
    println!("{}", t.render());
    println!("{ascii}");
    suite.table("training_timeline_rows", t.to_json_rows());

    // hybrid data×layer: M micro-batches pipelined through one graph
    let micro = if quick { 2 } else { 4 };
    let h = fig6::hybrid_timeline(depth, devices, micro).expect("hybrid timeline");
    println!("{}", h.render());
    suite.table("hybrid_rows", h.to_json_rows());

    suite.bench("simulate_mg_training_step_24gpu", || {
        let spec = resnet_mgrit::model::NetSpec::fig6();
        let _ = fig6::simulate_mg(&spec, 24, 2, true).unwrap();
    });
    suite.bench("live_train_step_depth32_2dev", || {
        let _ = fig6::live_training_timeline(32, 2, 2).unwrap();
    });
    suite.finish();
}
