//! Bench: regenerate Fig 6a — inference strong scaling of the 4,096-layer /
//! 3.25M-param network, serial vs MG over GPU counts (simulated TX-GAIA).

use resnet_mgrit::experiments::fig6;
use resnet_mgrit::util::bench::Suite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let mut suite = Suite::new("fig6a_inference");
    let gpus: &[usize] = if quick { &[1, 4, 24] } else { &fig6::GPU_COUNTS };

    let table = fig6::fig6a(gpus).expect("fig6a");
    println!("{}", table.render());
    suite.table("fig6a_rows", table.to_json_rows());

    suite.bench("simulate_mg_24gpu_inference", || {
        let spec = resnet_mgrit::model::NetSpec::fig6();
        let _ = fig6::simulate_mg(&spec, 24, 1, false).unwrap();
    });
    suite.finish();
}
