//! Bench: regenerate Fig 4 — residual convergence histories across depths
//! (real numerics, HostSolver), plus the timing of one MGRIT cycle per depth.
//! Run with `--quick` (or BENCH_QUICK=1) for the short sweep.

use resnet_mgrit::experiments::fig4;
use resnet_mgrit::util::bench::Suite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let mut suite = Suite::new("fig4_convergence");
    let depths: &[usize] = if quick { &[64, 128, 256] } else { &[128, 512, 2048] };
    let cycles = if quick { 4 } else { 8 };

    // the figure data
    let table = fig4::run(depths, cycles, 11).expect("fig4");
    println!("{}", table.render());
    suite.table("fig4_rows", table.to_json_rows());

    // cycle cost per depth (wall time of the real solve)
    for &d in depths {
        suite.bench(&format!("mgrit_solve_depth_{d}_x{cycles}cycles"), || {
            let _ = fig4::histories(&[d], cycles, 11).unwrap();
        });
    }
    suite.finish();
}
