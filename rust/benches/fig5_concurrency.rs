//! Bench: regenerate Fig 5 — the kernel-concurrency timeline within one
//! device, and the simulator's event throughput on that schedule.

use resnet_mgrit::experiments::fig5;
use resnet_mgrit::util::bench::Suite;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick") || std::env::var("BENCH_QUICK").is_ok();
    let mut suite = Suite::new("fig5_concurrency");
    let depth = if quick { 256 } else { 0 }; // 0 = full fig6 depth

    let (table, ascii) = fig5::run(depth).expect("fig5");
    println!("{}", table.render());
    println!("{ascii}");
    suite.table("fig5_rows", table.to_json_rows());

    suite.bench("simulate_one_mg_cycle_with_trace", || {
        let _ = fig5::simulate_timeline(depth).unwrap();
    });
    suite.finish();
}
