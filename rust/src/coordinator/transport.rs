//! The pluggable inter-node transport behind the sharded runtime.
//!
//! A [`super::streams::NodePools`] substrate owns one `StreamPool` per
//! modeled cluster node (the live half of `perfmodel::Topology`); every
//! **cross-node** `Comm` edge the executor retires becomes a real message
//! here — the producer's tensor is serialized ([`encode_tensor`]), carried
//! over a [`Transport`], and deserialized ([`decode_tensor`]) on the
//! destination node, so inter-node edges pay the explicit byte-copy path the
//! simulator already prices per tier (`ClusterModel::message_time`), while
//! intra-node edges stay `Arc<Tensor>` refcount bumps.
//!
//! [`InProc`] is the in-process reference implementation: serialized bytes
//! through bounded per-NIC send queues draining into per-node inboxes — the
//! same shape a socket transport would take (one ordered byte stream per
//! NIC), so swapping in a real fabric later only replaces the queue hop.
//! The wire format is explicit little-endian (rank, dims, f32 payload) and
//! round-trips bitwise; `tests` pin that property under `proptest_lite`.

use std::collections::VecDeque;
use std::sync::Mutex;

use anyhow::{anyhow, bail, ensure};

use crate::tensor::Tensor;
use crate::Result;

/// Traffic counters of one transport instance (monotone over its lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportStats {
    /// Messages accepted by [`Transport::send`] (loopback included).
    pub messages: usize,
    /// Total payload bytes accepted.
    pub bytes: usize,
    /// Messages whose source and destination node coincide. The executor
    /// never emits these (same-node edges stay shared memory), so a nonzero
    /// count outside targeted tests indicates a routing bug.
    pub loopback: usize,
}

/// A point-to-point inter-node message fabric: ordered, reliable delivery of
/// byte payloads between modeled nodes. Implementations must be callable
/// from the scheduler thread without blocking indefinitely; `send` followed
/// by `recv` on the destination is the executor's synchronous ship path.
pub trait Transport: Send + Sync {
    /// Number of node endpoints this transport connects.
    fn n_nodes(&self) -> usize;
    /// Enqueue `payload` from `src` to `dst` (both node indices).
    fn send(&self, src: usize, dst: usize, payload: Vec<u8>) -> Result<()>;
    /// Dequeue the oldest pending message addressed to `dst`. Erring on an
    /// empty inbox (rather than blocking) keeps a lost message a loud
    /// executor error instead of a hang.
    fn recv(&self, dst: usize) -> Result<Vec<u8>>;
    /// Snapshot of the traffic counters.
    fn stats(&self) -> TransportStats;
}

/// In-process [`Transport`]: per-NIC (per-source-node) send queues draining
/// into per-destination inboxes, all bounded by `cap` messages. Models the
/// one-ordered-stream-per-NIC discipline of a socket fabric without leaving
/// the address space.
pub struct InProc {
    n_nodes: usize,
    cap: usize,
    /// Per-source NIC send queue: `(dst, payload)` in send order.
    nics: Vec<Mutex<VecDeque<(usize, Vec<u8>)>>>,
    /// Per-destination delivery inbox.
    inboxes: Vec<Mutex<VecDeque<Vec<u8>>>>,
    stats: Mutex<TransportStats>,
}

impl InProc {
    /// Default bound on each NIC queue / inbox, in messages.
    pub const DEFAULT_CAP: usize = 1024;

    /// An `n_nodes`-endpoint fabric with the default queue bound.
    pub fn new(n_nodes: usize) -> InProc {
        InProc::with_capacity(n_nodes, InProc::DEFAULT_CAP)
    }

    /// An `n_nodes`-endpoint fabric bounding every NIC queue and inbox to
    /// `cap` messages; a send that would exceed a bound errors (explicit
    /// backpressure, never silent drop).
    pub fn with_capacity(n_nodes: usize, cap: usize) -> InProc {
        InProc {
            n_nodes,
            cap: cap.max(1),
            nics: (0..n_nodes).map(|_| Mutex::new(VecDeque::new())).collect(),
            inboxes: (0..n_nodes).map(|_| Mutex::new(VecDeque::new())).collect(),
            stats: Mutex::new(TransportStats::default()),
        }
    }

    fn lock<'a, T>(m: &'a Mutex<T>, what: &str) -> Result<std::sync::MutexGuard<'a, T>> {
        m.lock().map_err(|_| anyhow!("transport {what} lock poisoned"))
    }

    /// Drain `src`'s NIC queue into the destination inboxes, stopping at the
    /// first message whose inbox is full (NIC ordering is preserved).
    fn pump(&self, src: usize) -> Result<()> {
        let mut nic = Self::lock(&self.nics[src], "nic")?;
        while let Some((dst, payload)) = nic.front() {
            let mut inbox = Self::lock(&self.inboxes[*dst], "inbox")?;
            if inbox.len() >= self.cap {
                return Ok(());
            }
            inbox.push_back(payload.clone());
            drop(inbox);
            nic.pop_front();
        }
        Ok(())
    }
}

impl Transport for InProc {
    fn n_nodes(&self) -> usize {
        self.n_nodes
    }

    fn send(&self, src: usize, dst: usize, payload: Vec<u8>) -> Result<()> {
        ensure!(src < self.n_nodes, "transport send: src node {src} out of range");
        ensure!(dst < self.n_nodes, "transport send: dst node {dst} out of range");
        {
            let mut st = Self::lock(&self.stats, "stats")?;
            st.messages += 1;
            st.bytes += payload.len();
            if src == dst {
                st.loopback += 1;
            }
        }
        {
            let mut nic = Self::lock(&self.nics[src], "nic")?;
            if nic.len() >= self.cap {
                bail!("transport send: NIC queue of node {src} full ({} messages)", self.cap);
            }
            nic.push_back((dst, payload));
        }
        self.pump(src)
    }

    fn recv(&self, dst: usize) -> Result<Vec<u8>> {
        ensure!(dst < self.n_nodes, "transport recv: dst node {dst} out of range");
        // the fast path already delivered on send; re-pump every NIC in case
        // a full inbox deferred delivery earlier
        if Self::lock(&self.inboxes[dst], "inbox")?.is_empty() {
            for src in 0..self.n_nodes {
                self.pump(src)?;
            }
        }
        Self::lock(&self.inboxes[dst], "inbox")?
            .pop_front()
            .ok_or_else(|| anyhow!("transport recv: inbox of node {dst} empty (lost message?)"))
    }

    fn stats(&self) -> TransportStats {
        self.stats.lock().map(|s| *s).unwrap_or_default()
    }
}

/// Which execution substrate a run uses (the CLI `--transport` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportMode {
    /// One shared `StreamPool`, one address space (the legacy substrate).
    Shared,
    /// One `StreamPool` per modeled node behind an [`InProc`] transport:
    /// cross-node edges pay serialize→send→deserialize.
    InProc,
}

impl TransportMode {
    /// Parse a CLI spelling (`shared` | `inproc`).
    pub fn parse(s: &str) -> Result<TransportMode> {
        match s {
            "shared" => Ok(TransportMode::Shared),
            "inproc" | "in-proc" => Ok(TransportMode::InProc),
            other => bail!("unknown transport {other:?} (expected shared|inproc)"),
        }
    }

    /// The canonical CLI spelling.
    pub fn name(&self) -> &'static str {
        match self {
            TransportMode::Shared => "shared",
            TransportMode::InProc => "inproc",
        }
    }
}

/// Serialize a tensor to the explicit wire format: rank as `u32` LE, each
/// dim as `u64` LE, then the f32 payload LE. No compression, no implicit
/// layout — the bytes are the message the cost model prices.
pub fn encode_tensor(t: &Tensor) -> Vec<u8> {
    let dims = t.dims();
    let data = t.data();
    let mut out = Vec::with_capacity(4 + dims.len() * 8 + data.len() * 4);
    out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
    for &d in dims {
        out.extend_from_slice(&(d as u64).to_le_bytes());
    }
    for &x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Deserialize an [`encode_tensor`] message, validating every length so a
/// truncated or corrupt payload is a typed error, never a bad tensor.
pub fn decode_tensor(bytes: &[u8]) -> Result<Tensor> {
    fn take<'a>(bytes: &'a [u8], at: &mut usize, n: usize) -> Result<&'a [u8]> {
        let end = at
            .checked_add(n)
            .filter(|&e| e <= bytes.len())
            .ok_or_else(|| anyhow!("transport decode: truncated message ({} bytes)", bytes.len()))?;
        let s = &bytes[*at..end];
        *at = end;
        Ok(s)
    }
    let mut at = 0usize;
    let rank = u32::from_le_bytes(take(bytes, &mut at, 4)?.try_into()?) as usize;
    ensure!(rank <= 8, "transport decode: implausible rank {rank}");
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        dims.push(u64::from_le_bytes(take(bytes, &mut at, 8)?.try_into()?) as usize);
    }
    let len: usize = dims.iter().product();
    let n_payload = len.checked_mul(4).ok_or_else(|| anyhow!("transport decode: dims overflow"))?;
    let payload = take(bytes, &mut at, n_payload)?;
    ensure!(at == bytes.len(), "transport decode: {} trailing bytes", bytes.len() - at);
    let data = payload
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("chunks_exact(4)")))
        .collect();
    Tensor::new(dims, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::{self, gen_usize, gen_vec};

    #[test]
    fn prop_tensor_roundtrips_bitwise_through_the_transport() {
        // satellite: random tensor shapes survive serialize → send → recv →
        // deserialize with bit-identical dims and payload
        let fabric = InProc::new(3);
        proptest_lite::check_with(
            proptest_lite::Config { cases: 32, ..Default::default() },
            "transport_roundtrip",
            |rng| {
                let rank = gen_usize(rng, 1, 4);
                let dims: Vec<usize> = (0..rank).map(|_| gen_usize(rng, 1, 5)).collect();
                let len = dims.iter().product::<usize>();
                let t = Tensor::new(dims.clone(), gen_vec(rng, len, 1.5)).unwrap();
                let (src, dst) = (gen_usize(rng, 0, 2), gen_usize(rng, 0, 2));
                fabric.send(src, dst, encode_tensor(&t)).unwrap();
                let back = decode_tensor(&fabric.recv(dst).unwrap()).unwrap();
                assert_eq!(back.dims(), t.dims());
                assert_eq!(back.data(), t.data(), "payload must round-trip bitwise");
            },
        );
    }

    #[test]
    fn loopback_sends_are_counted_and_delivered() {
        // src == dst is legal at the transport layer (the executor elides it
        // — see the elision test in coordinator::executor) and is tallied
        // separately so a routing bug shows up in the stats
        let fabric = InProc::new(2);
        let t = Tensor::new(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        fabric.send(1, 1, encode_tensor(&t)).unwrap();
        let st = fabric.stats();
        assert_eq!((st.messages, st.loopback), (1, 1));
        let back = decode_tensor(&fabric.recv(1).unwrap()).unwrap();
        assert_eq!(back.data(), t.data());
    }

    #[test]
    fn messages_preserve_per_nic_order() {
        let fabric = InProc::new(2);
        for i in 0..5u8 {
            fabric.send(0, 1, vec![i]).unwrap();
        }
        let got: Vec<u8> = (0..5).map(|_| fabric.recv(1).unwrap()[0]).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        assert_eq!(fabric.stats().bytes, 5);
    }

    #[test]
    fn bounded_queues_backpressure_instead_of_dropping() {
        let fabric = InProc::with_capacity(2, 2);
        fabric.send(0, 1, vec![0]).unwrap();
        fabric.send(0, 1, vec![1]).unwrap();
        // inbox full: the third message parks on the NIC queue...
        fabric.send(0, 1, vec![2]).unwrap();
        fabric.send(0, 1, vec![3]).unwrap();
        // ...and a fifth exceeds the NIC bound loudly
        let err = fabric.send(0, 1, vec![4]).unwrap_err().to_string();
        assert!(err.contains("full"), "{err}");
        // draining the inbox re-pumps the parked messages in order
        let got: Vec<u8> = (0..4).map(|_| fabric.recv(1).unwrap()[0]).collect();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert!(fabric.recv(1).is_err(), "drained inbox must err, not block");
    }

    #[test]
    fn out_of_range_nodes_and_corrupt_payloads_are_typed_errors() {
        let fabric = InProc::new(2);
        assert!(fabric.send(2, 0, vec![]).is_err());
        assert!(fabric.send(0, 9, vec![]).is_err());
        assert!(fabric.recv(7).is_err());
        let t = Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let mut wire = encode_tensor(&t);
        wire.truncate(wire.len() - 3);
        assert!(decode_tensor(&wire).is_err(), "truncated payload must not decode");
        wire.extend_from_slice(&[0; 64]);
        assert!(decode_tensor(&wire).is_err(), "trailing garbage must not decode");
    }

    #[test]
    fn transport_mode_parses_cli_spellings() {
        assert_eq!(TransportMode::parse("shared").unwrap(), TransportMode::Shared);
        assert_eq!(TransportMode::parse("inproc").unwrap(), TransportMode::InProc);
        assert_eq!(TransportMode::parse("inproc").unwrap().name(), "inproc");
        assert!(TransportMode::parse("tcp").is_err());
    }
}
