//! The dependency-driven task-graph executor: runs an executable
//! [`TaskGraph`] on real tensors over a [`StreamPool`], dispatching each task
//! to its device's worker the moment its dependencies retire.
//!
//! This replaces the old per-phase barriers: C-relaxation and residual work
//! of one partition overlap F-relaxation of another, exactly as in the
//! simulated schedule (the paper's kernel-concurrency argument, Fig 5).
//!
//! ## Dependency-retirement protocol
//!
//! 1. in-degrees are counted per task; zero-degree tasks enter the ready set;
//! 2. ready **Comm** tasks retire immediately on the scheduler thread (local
//!    execution only *accounts* the transfer — the tensors share memory);
//! 3. ready **Kernel** tasks clone their input slots out of [`ExecState`]
//!    (the scheduler thread is the only state owner, so no locks), and are
//!    submitted to the worker owning `task.device`;
//! 4. each completion ([`JobDone`]) writes the task's single output slot
//!    back, decrements its dependents' counters, and pushes newly-ready
//!    tasks — completion order is irrelevant because the graph carries
//!    RAW/WAR/WAW edges for every slot (see `mgrit::taskgraph`);
//! 5. the run ends when every task has retired; a non-executable task
//!    (`op == None`) or an exhausted ready set with nothing in flight is an
//!    error, not a hang.
//!
//! Because each op performs the same f32 arithmetic in the same order as the
//! serial engine (`mgrit::fas`), any topological execution is bit-identical
//! to the serial solve — asserted by `tests/mgrit_integration.rs`.

use std::sync::mpsc::{channel, Sender};

use anyhow::{anyhow, bail};

use super::streams::{JobDone, StreamPool};
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind, TaskOp};
use crate::solver::{BlockSolver, SolverFactory};
use crate::tensor::Tensor;
use crate::Result;

/// The live MGRIT state the executor reads and writes: per level, the layer
/// states `u`, the FAS right-hand sides `g`, the C-point residuals `r`, and
/// the injection snapshots the correction consumes.
#[derive(Debug)]
pub struct ExecState {
    pub u: Vec<Vec<Tensor>>,
    g: Vec<Option<Vec<Tensor>>>,
    r: Vec<Vec<Option<Tensor>>>,
    inj: Vec<Vec<Option<Tensor>>>,
}

impl ExecState {
    /// Initial fine-level guess: every point of every level seeded with `u0`
    /// (same constant-in-depth guess as `LevelState::initial`); coarse
    /// right-hand sides start at zero.
    pub fn initial(hier: &Hierarchy, u0: &Tensor) -> ExecState {
        let u: Vec<Vec<Tensor>> =
            hier.levels.iter().map(|l| vec![u0.clone(); l.n_points]).collect();
        let g = hier
            .levels
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if i == 0 {
                    None
                } else {
                    Some(vec![Tensor::zeros(u0.dims()); l.n_points])
                }
            })
            .collect();
        let r = hier.levels.iter().map(|l| vec![None; l.n_points]).collect();
        let inj = hier.levels.iter().map(|l| vec![None; l.n_points]).collect();
        ExecState { u, g, r, inj }
    }

    /// Residual tensor at `(level, j)` if computed this run.
    pub fn residual(&self, level: usize, j: usize) -> Option<&Tensor> {
        self.r[level][j].as_ref()
    }

    /// Consume the state, returning the fine-level trajectory.
    pub fn into_fine_states(mut self) -> Vec<Tensor> {
        self.u.swap_remove(0)
    }
}

/// Aggregate record of one graph execution.
#[derive(Debug, Default, Clone)]
pub struct ExecReport {
    /// Boundary transfers retired (each is one activation crossing devices).
    pub comm_events: usize,
    /// Kernel tasks executed.
    pub kernels: usize,
    /// Φ applications performed (the solve's work measure).
    pub phi_evals: usize,
    /// Per-label worker-busy seconds, in first-seen order.
    pub phase_s: Vec<(&'static str, f64)>,
}

impl ExecReport {
    fn add_phase(&mut self, label: &'static str, secs: f64) {
        merge_phases(&mut self.phase_s, &[(label, secs)]);
    }
}

/// Execute `graph` on `pool`, mutating `st` in place.
pub fn execute<F: SolverFactory>(
    pool: &StreamPool<F>,
    hier: &Hierarchy,
    graph: &TaskGraph,
    st: &mut ExecState,
) -> Result<ExecReport> {
    let n = graph.tasks.len();
    let mut report = ExecReport::default();
    if n == 0 {
        return Ok(report);
    }
    let mut indeg = vec![0usize; n];
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for t in &graph.tasks {
        indeg[t.id] = t.deps.len();
        for &d in &t.deps {
            dependents[d].push(t.id);
        }
    }
    let (tx, rx) = channel::<JobDone<Tensor>>();
    let mut ready: Vec<usize> =
        graph.tasks.iter().filter(|t| t.deps.is_empty()).map(|t| t.id).collect();
    let mut in_flight = 0usize;
    let mut retired = 0usize;

    while retired < n {
        // dispatch everything currently ready; Comm tasks retire inline
        while let Some(id) = ready.pop() {
            let task = &graph.tasks[id];
            match &task.kind {
                TaskKind::Comm { .. } => {
                    report.comm_events += 1;
                    retired += 1;
                    for &d in &dependents[id] {
                        indeg[d] -= 1;
                        if indeg[d] == 0 {
                            ready.push(d);
                        }
                    }
                }
                TaskKind::Kernel { label, .. } => {
                    dispatch_kernel(pool, hier, st, task, *label, &tx)?;
                    in_flight += 1;
                }
            }
        }
        if retired == n {
            break;
        }
        if in_flight == 0 {
            bail!("executor stalled with {retired}/{n} tasks retired (cyclic dependencies?)");
        }
        let done = rx
            .recv()
            .map_err(|_| anyhow!("stream pool shut down with tasks in flight"))?;
        in_flight -= 1;
        let out = done
            .result
            .map_err(|e| anyhow!("task {} ({}): {e:#}", done.id, done.label))?;
        let op = graph.tasks[done.id]
            .op
            .ok_or_else(|| anyhow!("completed task {} has no payload", done.id))?;
        apply_output(hier, st, op, out)?;
        match op {
            TaskOp::PointUpdate { .. } | TaskOp::Residual { .. } | TaskOp::Restrict { .. } => {
                report.phi_evals += 1;
            }
            _ => {}
        }
        report.kernels += 1;
        report.add_phase(done.label, done.t_end - done.t_start);
        retired += 1;
        for &d in &dependents[done.id] {
            indeg[d] -= 1;
            if indeg[d] == 0 {
                ready.push(d);
            }
        }
    }
    Ok(report)
}

/// Clone a kernel task's inputs out of the state and submit it to its
/// device's worker. For `Restrict`, the injection (coarse initial guess +
/// correction snapshot) is applied at dispatch time: the graph's WAR edges
/// guarantee every reader of the old coarse slots has already completed.
fn dispatch_kernel<F: SolverFactory>(
    pool: &StreamPool<F>,
    hier: &Hierarchy,
    st: &mut ExecState,
    task: &Task,
    label: &'static str,
    tx: &Sender<JobDone<Tensor>>,
) -> Result<()> {
    let op = task
        .op
        .ok_or_else(|| anyhow!("task {} is not executable (op=None); this graph is cost-model-only", task.id))?;
    match op {
        TaskOp::PointUpdate { level, j } => {
            let lvl = &hier.levels[level];
            let theta = lvl.theta_idx(j - 1);
            let h = lvl.h;
            let u_prev = st.u[level][j - 1].clone();
            let gj = st.g[level].as_ref().map(|g| g[j].clone());
            pool.submit_job(task.device, label, task.id, tx.clone(), move |s: &F::Solver| {
                let mut v = s.step(theta, h, &u_prev)?;
                if let Some(g) = &gj {
                    v.axpy(1.0, g)?;
                }
                Ok(v)
            })
        }
        TaskOp::Residual { level, j } => {
            let lvl = &hier.levels[level];
            let theta = lvl.theta_idx(j - 1);
            let h = lvl.h;
            let u_prev = st.u[level][j - 1].clone();
            let u_cur = st.u[level][j].clone();
            let gj = st.g[level].as_ref().map(|g| g[j].clone());
            pool.submit_job(task.device, label, task.id, tx.clone(), move |s: &F::Solver| {
                let mut r = s.step(theta, h, &u_prev)?;
                if let Some(g) = &gj {
                    r.axpy(1.0, g)?;
                }
                r.axpy(-1.0, &u_cur)?;
                Ok(r)
            })
        }
        TaskOp::Restrict { level, j } => {
            let c = hier.coarsen;
            let coarse = &hier.levels[level + 1];
            let theta = coarse.theta_idx(j - 1);
            let h = coarse.h;
            let r = st.r[level][j * c]
                .clone()
                .ok_or_else(|| anyhow!("restrict({level},{j}): residual at point {} missing", j * c))?;
            let inj_prev = st.u[level][(j - 1) * c].clone();
            let inj_cur = st.u[level][j * c].clone();
            // inject the coarse initial guess + correction snapshot now —
            // safe because this task's WAR deps have already retired
            st.u[level + 1][j] = inj_cur.clone();
            st.inj[level + 1][j] = Some(inj_cur.clone());
            pool.submit_job(task.device, label, task.id, tx.clone(), move |s: &F::Solver| {
                let phi = s.step(theta, h, &inj_prev)?;
                let mut out = r;
                out.axpy(1.0, &inj_cur)?;
                out.axpy(-1.0, &phi)?;
                Ok(out)
            })
        }
        TaskOp::Correct { level, j } => {
            let c = hier.coarsen;
            let u_fine = st.u[level][j * c].clone();
            let u_coarse = st.u[level + 1][j].clone();
            let inj = st.inj[level + 1][j]
                .clone()
                .ok_or_else(|| anyhow!("correct({level},{j}): injection snapshot missing"))?;
            pool.submit_job(task.device, label, task.id, tx.clone(), move |_s: &F::Solver| {
                let delta = Tensor::sub(&u_coarse, &inj)?;
                let mut out = u_fine;
                out.axpy(1.0, &delta)?;
                Ok(out)
            })
        }
        TaskOp::Xfer => bail!("Xfer payload on a kernel task (graph bug)"),
    }
}

/// Write one completed kernel's output into its slot.
fn apply_output(hier: &Hierarchy, st: &mut ExecState, op: TaskOp, out: Tensor) -> Result<()> {
    match op {
        TaskOp::PointUpdate { level, j } => st.u[level][j] = out,
        TaskOp::Residual { level, j } => st.r[level][j] = Some(out),
        TaskOp::Restrict { level, j } => {
            match &mut st.g[level + 1] {
                Some(g) => g[j] = out,
                None => bail!("restrict into level {} with no rhs storage", level + 1),
            }
        }
        TaskOp::Correct { level, j } => st.u[level][j * hier.coarsen] = out,
        TaskOp::Xfer => bail!("Xfer payload completed as a kernel (graph bug)"),
    }
    Ok(())
}

/// Merge a per-label phase ledger into a cumulative one (driver helper);
/// same accumulate-by-label rule as [`ExecReport::add_phase`].
pub(crate) fn merge_phases(
    into: &mut Vec<(&'static str, f64)>,
    phases: &[(&'static str, f64)],
) {
    for &(label, secs) in phases {
        if let Some(e) = into.iter_mut().find(|(l, _)| *l == label) {
            e.1 += secs;
        } else {
            into.push((label, secs));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Partition;
    use crate::mgrit::fas::RelaxKind;
    use crate::mgrit::taskgraph;
    use crate::model::{NetParams, NetSpec};
    use crate::solver::host::HostSolver;
    use std::sync::Arc;

    fn setup() -> (Arc<NetSpec>, Hierarchy, Partition, StreamPool<impl SolverFactory<Solver = HostSolver>>, Tensor)
    {
        let spec = Arc::new(NetSpec::micro());
        let params = Arc::new(NetParams::init(&spec, 30).unwrap());
        let spec2 = spec.clone();
        let factory = move |_w: usize| HostSolver::new(spec2.clone(), params.clone());
        let hier = Hierarchy::two_level(4, spec.h(), 2).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let partition = Partition::contiguous(n_blocks, 2).unwrap();
        let pool = StreamPool::new(partition.n_devices(), factory).unwrap();
        let mut rng = crate::util::prng::Rng::new(31);
        let u0 = Tensor::randn(&[1, 2, 6, 6], 0.8, &mut rng);
        (spec, hier, partition, pool, u0)
    }

    #[test]
    fn vcycle_graph_executes_and_counts_work() {
        let (spec, hier, partition, pool, u0) = setup();
        let g = taskgraph::mg_vcycle(&spec, &hier, &partition, 1, RelaxKind::FCF);
        let mut st = ExecState::initial(&hier, &u0);
        let rep = execute(&pool, &hier, &g, &mut st).unwrap();
        assert!(rep.kernels > 0);
        assert!(rep.phi_evals > 0);
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "f_relax"));
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "coarse_solve"));
        // states moved away from the constant initial guess
        let moved = st.u[0][1..]
            .iter()
            .any(|u| crate::util::stats::rel_l2_err(u.data(), u0.data()) > 1e-6);
        assert!(moved, "executor did not update any state");
    }

    #[test]
    fn residual_check_fills_residual_slots() {
        let (spec, hier, partition, pool, u0) = setup();
        let g = taskgraph::residual_check(&spec, &hier, &partition, 1);
        let mut st = ExecState::initial(&hier, &u0);
        execute(&pool, &hier, &g, &mut st).unwrap();
        for cp in hier.fine().cpoints(hier.coarsen) {
            if cp > 0 {
                assert!(st.residual(0, cp).is_some(), "residual at {cp} missing");
            }
        }
    }

    #[test]
    fn non_executable_graph_is_rejected() {
        let (spec, hier, _partition, pool, u0) = setup();
        // serial_forward carries no payloads
        let g = taskgraph::serial_forward(&spec, 1, 1);
        let mut st = ExecState::initial(&hier, &u0);
        assert!(execute(&pool, &hier, &g, &mut st).is_err());
    }

    #[test]
    fn merge_phases_accumulates_by_label() {
        let mut acc: Vec<(&'static str, f64)> = vec![("a", 1.0)];
        merge_phases(&mut acc, &[("a", 2.0), ("b", 3.0)]);
        merge_phases(&mut acc, &[("b", 1.0)]);
        assert_eq!(acc, vec![("a", 3.0), ("b", 4.0)]);
    }
}
