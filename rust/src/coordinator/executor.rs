//! The dependency-driven task-graph executor: runs an executable
//! [`TaskGraph`] on real tensors over a [`StreamPool`], dispatching each task
//! to its device's worker the moment its dependencies retire.
//!
//! This replaces the old per-phase barriers: C-relaxation and residual work
//! of one partition overlap F-relaxation of another, exactly as in the
//! simulated schedule (the paper's kernel-concurrency argument, Fig 5) —
//! and, for the training graph, adjoint relaxation on early layers overlaps
//! parameter-gradient work on late layers.
//!
//! ## Dependency-retirement protocol
//!
//! 1. in-degrees are counted per task; zero-degree tasks enter the ready set;
//! 2. ready **Comm** tasks retire immediately on the scheduler thread (local
//!    execution only *accounts* the transfer — the tensors share memory);
//! 3. ready **Kernel** tasks clone their input slots out of [`ExecState`]
//!    (the scheduler thread is the only state owner, so no locks), and are
//!    submitted to the worker owning `task.device`;
//! 4. each completion ([`JobDone`]) writes the task's output slot(s) back,
//!    decrements its dependents' counters, and pushes newly-ready tasks —
//!    completion order is irrelevant because the graph carries RAW/WAR/WAW
//!    edges for every slot (see `mgrit::taskgraph`);
//! 5. the run ends when every task has retired; a non-executable task
//!    (`op == None`) or an exhausted ready set with nothing in flight is an
//!    error, not a hang.
//!
//! The training ops extend the same protocol: `Head` seeds the whole adjoint
//! slot set when it retires (every adjoint frontier starts at the head task,
//! so no adjoint work can observe unseeded state); `GradAccum` fills one
//! layer's sharded gradient slot; `ParamUpdate` writes the layer's fresh
//! parameters.
//!
//! Because each op performs the same f32 arithmetic in the same order as the
//! serial engines (`mgrit::fas` / `train::mg_step_serial`), any topological
//! execution is bit-identical to the serial solve — asserted by
//! `tests/mgrit_integration.rs`.

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

use anyhow::{anyhow, bail};

use super::streams::{JobDone, StreamPool};
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph::{Sys, Task, TaskGraph, TaskKind, TaskOp};
use crate::model::params::TrunkGradSlots;
use crate::model::NetParams;
use crate::solver::{BlockSolver, NetExecutor, SolverFactory};
use crate::tensor::Tensor;
use crate::Result;

/// The state slots of one MGRIT system (primal or adjoint): per level, the
/// point states `u`, the FAS right-hand sides `g`, the C-point residuals `r`,
/// and the injection snapshots the correction consumes.
#[derive(Debug)]
pub struct SysState {
    pub u: Vec<Vec<Tensor>>,
    g: Vec<Option<Vec<Tensor>>>,
    r: Vec<Vec<Option<Tensor>>>,
    inj: Vec<Vec<Option<Tensor>>>,
}

impl SysState {
    /// Every point of every level seeded with `seed` (the constant-in-depth
    /// initial guess of `LevelState::initial`); coarse right-hand sides zero.
    fn seeded(hier: &Hierarchy, seed: &Tensor) -> SysState {
        let u: Vec<Vec<Tensor>> =
            hier.levels.iter().map(|l| vec![seed.clone(); l.n_points]).collect();
        let g = hier
            .levels
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if i == 0 {
                    None
                } else {
                    Some(vec![Tensor::zeros(seed.dims()); l.n_points])
                }
            })
            .collect();
        let r = hier.levels.iter().map(|l| vec![None; l.n_points]).collect();
        let inj = hier.levels.iter().map(|l| vec![None; l.n_points]).collect();
        SysState { u, g, r, inj }
    }
}

/// Training-only state: the batch labels, the parameter snapshot the step
/// linearizes around, and the sharded per-layer output slots the fan-out
/// tasks fill independently.
#[derive(Debug)]
struct TrainState {
    labels: Vec<i32>,
    lr: f32,
    params: Arc<NetParams>,
    grads: TrunkGradSlots,
    new_trunk: TrunkGradSlots,
    head: Option<HeadOut>,
}

/// What the head task leaves behind on the scheduler side.
#[derive(Debug)]
struct HeadOut {
    loss: f64,
    dw_fc: Tensor,
    db_fc: Tensor,
}

/// The live state the executor reads and writes: the primal system, the
/// adjoint system (seeded by the `Head` task mid-graph), and the training
/// bookkeeping.
#[derive(Debug)]
pub struct ExecState {
    pri: SysState,
    adj: Option<SysState>,
    train: Option<TrainState>,
}

/// Everything a completed training graph produced, extracted from the state.
#[derive(Debug)]
pub struct TrainingOutputs {
    pub loss: f64,
    /// Fine-level forward trajectory u^0..u^N.
    pub states: Vec<Tensor>,
    /// Adjoints λ^0..λ^N (forward layer indexing).
    pub lams: Vec<Tensor>,
    /// Per-layer (dW, db) trunk gradients.
    pub trunk_grads: Vec<(Tensor, Tensor)>,
    /// Per-layer post-SGD trunk parameters.
    pub new_trunk: Vec<(Tensor, Tensor)>,
    pub dw_fc: Tensor,
    pub db_fc: Tensor,
}

impl ExecState {
    /// Forward-solve state: primal system seeded with `u0`, no training
    /// bookkeeping (graphs with training ops will be rejected at dispatch).
    pub fn initial(hier: &Hierarchy, u0: &Tensor) -> ExecState {
        ExecState { pri: SysState::seeded(hier, u0), adj: None, train: None }
    }

    /// Training-step state: as [`ExecState::initial`] plus the labels, the
    /// learning rate, and the parameter snapshot the `ParamUpdate` tasks
    /// update. The adjoint system is seeded by the `Head` task at runtime.
    pub fn initial_train(
        hier: &Hierarchy,
        u0: &Tensor,
        labels: &[i32],
        params: Arc<NetParams>,
        lr: f32,
    ) -> ExecState {
        let n_layers = hier.fine().n_points - 1;
        ExecState {
            pri: SysState::seeded(hier, u0),
            adj: None,
            train: Some(TrainState {
                labels: labels.to_vec(),
                lr,
                params,
                grads: TrunkGradSlots::new(n_layers),
                new_trunk: TrunkGradSlots::new(n_layers),
                head: None,
            }),
        }
    }

    fn sys(&self, s: Sys) -> Result<&SysState> {
        match s {
            Sys::Primal => Ok(&self.pri),
            Sys::Adjoint => self
                .adj
                .as_ref()
                .ok_or_else(|| anyhow!("adjoint state missing (Head task has not retired)")),
        }
    }

    fn sys_mut(&mut self, s: Sys) -> Result<&mut SysState> {
        match s {
            Sys::Primal => Ok(&mut self.pri),
            Sys::Adjoint => self
                .adj
                .as_mut()
                .ok_or_else(|| anyhow!("adjoint state missing (Head task has not retired)")),
        }
    }

    fn train(&self) -> Result<&TrainState> {
        self.train.as_ref().ok_or_else(|| {
            anyhow!("training op in a non-training run (use ExecState::initial_train)")
        })
    }

    fn train_mut(&mut self) -> Result<&mut TrainState> {
        self.train.as_mut().ok_or_else(|| {
            anyhow!("training op in a non-training run (use ExecState::initial_train)")
        })
    }

    /// Residual tensor at `(level, j)` of the primal system, if computed.
    pub fn residual(&self, level: usize, j: usize) -> Option<&Tensor> {
        self.pri.r[level][j].as_ref()
    }

    /// Consume the state, returning the fine-level trajectory.
    pub fn into_fine_states(mut self) -> Vec<Tensor> {
        self.pri.u.swap_remove(0)
    }

    /// Consume a completed training run into its outputs. Errors if the head
    /// never retired or any sharded slot is unfilled.
    pub fn into_training_outputs(self) -> Result<TrainingOutputs> {
        let adj = self.adj.ok_or_else(|| anyhow!("training run never seeded the adjoint"))?;
        let train = self
            .train
            .ok_or_else(|| anyhow!("not a training run (use ExecState::initial_train)"))?;
        let head = train.head.ok_or_else(|| anyhow!("head task never retired"))?;
        let mut pri = self.pri;
        let states = pri.u.swap_remove(0);
        let mut adj = adj;
        // μ^m = λ^{N−m} → reverse back to forward indexing
        let mut lams = adj.u.swap_remove(0);
        lams.reverse();
        Ok(TrainingOutputs {
            loss: head.loss,
            states,
            lams,
            trunk_grads: train.grads.into_pairs()?,
            new_trunk: train.new_trunk.into_pairs()?,
            dw_fc: head.dw_fc,
            db_fc: head.db_fc,
        })
    }
}

/// Typed result of one kernel task (the payload of [`JobDone`]).
#[derive(Debug)]
pub enum TaskOut {
    /// A single state/residual/rhs tensor.
    State(Tensor),
    /// The states of a fused F-span (`BlockRun`), in point order.
    States(Vec<Tensor>),
    /// A (weight, bias)-shaped pair: a layer gradient or updated parameters.
    Pair(Tensor, Tensor),
    /// Head forward + VJP output.
    Head { loss: f64, du: Tensor, dw_fc: Tensor, db_fc: Tensor },
}

/// Aggregate record of one graph execution.
#[derive(Debug, Default, Clone)]
pub struct ExecReport {
    /// Boundary transfers retired (each is one activation crossing devices).
    pub comm_events: usize,
    /// Kernel tasks executed.
    pub kernels: usize,
    /// Φ/Ψ applications performed (the solve's work measure).
    pub phi_evals: usize,
    /// Per-label worker-busy seconds, in first-seen order.
    pub phase_s: Vec<(&'static str, f64)>,
}

impl ExecReport {
    fn add_phase(&mut self, label: &'static str, secs: f64) {
        merge_phases(&mut self.phase_s, &[(label, secs)]);
    }
}

/// Execute `graph` on `pool`, mutating `st` in place.
pub fn execute<F: SolverFactory>(
    pool: &StreamPool<F>,
    hier: &Hierarchy,
    graph: &TaskGraph,
    st: &mut ExecState,
) -> Result<ExecReport>
where
    F::Solver: NetExecutor,
{
    let n = graph.tasks.len();
    let mut report = ExecReport::default();
    if n == 0 {
        return Ok(report);
    }
    let mut indeg = vec![0usize; n];
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for t in &graph.tasks {
        indeg[t.id] = t.deps.len();
        for &d in &t.deps {
            dependents[d].push(t.id);
        }
    }
    let (tx, rx) = channel::<JobDone<TaskOut>>();
    let mut ready: Vec<usize> =
        graph.tasks.iter().filter(|t| t.deps.is_empty()).map(|t| t.id).collect();
    let mut in_flight = 0usize;
    let mut retired = 0usize;

    while retired < n {
        // dispatch everything currently ready; Comm tasks retire inline
        while let Some(id) = ready.pop() {
            let task = &graph.tasks[id];
            match &task.kind {
                TaskKind::Comm { .. } => {
                    report.comm_events += 1;
                    retired += 1;
                    for &d in &dependents[id] {
                        indeg[d] -= 1;
                        if indeg[d] == 0 {
                            ready.push(d);
                        }
                    }
                }
                TaskKind::Kernel { label, .. } => {
                    dispatch_kernel(pool, hier, st, task, *label, &tx)?;
                    in_flight += 1;
                }
            }
        }
        if retired == n {
            break;
        }
        if in_flight == 0 {
            bail!("executor stalled with {retired}/{n} tasks retired (cyclic dependencies?)");
        }
        let done = rx
            .recv()
            .map_err(|_| anyhow!("stream pool shut down with tasks in flight"))?;
        in_flight -= 1;
        let out = done
            .result
            .map_err(|e| anyhow!("task {} ({}): {e:#}", done.id, done.label))?;
        let op = graph.tasks[done.id]
            .op
            .ok_or_else(|| anyhow!("completed task {} has no payload", done.id))?;
        apply_output(hier, st, op, out)?;
        match op {
            TaskOp::PointUpdate { .. } | TaskOp::Residual { .. } | TaskOp::Restrict { .. } => {
                report.phi_evals += 1;
            }
            TaskOp::BlockRun { j_first, j_last, .. } => {
                report.phi_evals += j_last - j_first + 1;
            }
            _ => {}
        }
        report.kernels += 1;
        report.add_phase(done.label, done.t_end - done.t_start);
        retired += 1;
        for &d in &dependents[done.id] {
            indeg[d] -= 1;
            if indeg[d] == 0 {
                ready.push(d);
            }
        }
    }
    Ok(report)
}

/// Forward fine state a Ψ application at (level, j−1 → j) linearizes around
/// — the same formula the graph builder used for the matching RAW edge.
fn rev_layer(hier: &Hierarchy, level: usize, j: usize) -> usize {
    hier.adjoint_state_index(level, j)
}

/// Clone a kernel task's inputs out of the state and submit it to its
/// device's worker. For `Restrict`, the injection (coarse initial guess +
/// correction snapshot) is applied at dispatch time: the graph's WAR edges
/// guarantee every reader of the old coarse slots has already completed.
/// Adjoint ops additionally clone the forward fine state they linearize
/// around (their RAW edges guarantee it is final).
fn dispatch_kernel<F: SolverFactory>(
    pool: &StreamPool<F>,
    hier: &Hierarchy,
    st: &mut ExecState,
    task: &Task,
    label: &'static str,
    tx: &Sender<JobDone<TaskOut>>,
) -> Result<()>
where
    F::Solver: NetExecutor,
{
    let op = task
        .op
        .ok_or_else(|| anyhow!("task {} is not executable (op=None); this graph is cost-model-only", task.id))?;
    match op {
        TaskOp::PointUpdate { sys, level, j } => {
            let lvl = &hier.levels[level];
            let theta = lvl.theta_idx(j - 1);
            let h = lvl.h;
            let ss = st.sys(sys)?;
            let u_prev = ss.u[level][j - 1].clone();
            let gj = ss.g[level].as_ref().map(|g| g[j].clone());
            match sys {
                Sys::Primal => {
                    pool.submit_job(task.device, label, task.id, tx.clone(), move |s: &F::Solver| {
                        let mut v = s.step(theta, h, &u_prev)?;
                        if let Some(g) = &gj {
                            v.axpy(1.0, g)?;
                        }
                        Ok(TaskOut::State(v))
                    })
                }
                Sys::Adjoint => {
                    let rev = rev_layer(hier, level, j);
                    let fwd = st.pri.u[0][rev].clone();
                    pool.submit_job(task.device, label, task.id, tx.clone(), move |s: &F::Solver| {
                        let mut v = s.adjoint_step(rev, h, &fwd, &u_prev)?;
                        if let Some(g) = &gj {
                            v.axpy(1.0, g)?;
                        }
                        Ok(TaskOut::State(v))
                    })
                }
            }
        }
        TaskOp::BlockRun { sys, level, j_first, j_last } => {
            let lvl = &hier.levels[level];
            let h = lvl.h;
            let stride = lvl.stride;
            let start_theta = lvl.theta_idx(j_first - 1);
            let count = j_last - j_first + 1;
            let ss = st.sys(sys)?;
            if ss.g[level].is_some() {
                bail!("BlockRun on a level with a right-hand side (graph bug)");
            }
            let u_prev = ss.u[level][j_first - 1].clone();
            match sys {
                Sys::Primal => {
                    // the solver's fused block path (one PJRT block artifact)
                    pool.submit_job(task.device, label, task.id, tx.clone(), move |s: &F::Solver| {
                        Ok(TaskOut::States(s.block_fprop(start_theta, stride, count, h, &u_prev)?))
                    })
                }
                Sys::Adjoint => {
                    let steps: Vec<(usize, Tensor)> = (j_first..=j_last)
                        .map(|j| {
                            let rev = rev_layer(hier, level, j);
                            (rev, st.pri.u[0][rev].clone())
                        })
                        .collect();
                    pool.submit_job(task.device, label, task.id, tx.clone(), move |s: &F::Solver| {
                        let mut out = Vec::with_capacity(steps.len());
                        let mut mu = u_prev;
                        for (rev, fwd) in &steps {
                            mu = s.adjoint_step(*rev, h, fwd, &mu)?;
                            out.push(mu.clone());
                        }
                        Ok(TaskOut::States(out))
                    })
                }
            }
        }
        TaskOp::Residual { sys, level, j } => {
            let lvl = &hier.levels[level];
            let theta = lvl.theta_idx(j - 1);
            let h = lvl.h;
            let ss = st.sys(sys)?;
            let u_prev = ss.u[level][j - 1].clone();
            let u_cur = ss.u[level][j].clone();
            let gj = ss.g[level].as_ref().map(|g| g[j].clone());
            let fwd = match sys {
                Sys::Primal => None,
                Sys::Adjoint => Some((rev_layer(hier, level, j), st.pri.u[0][rev_layer(hier, level, j)].clone())),
            };
            pool.submit_job(task.device, label, task.id, tx.clone(), move |s: &F::Solver| {
                let mut r = match &fwd {
                    None => s.step(theta, h, &u_prev)?,
                    Some((rev, f)) => s.adjoint_step(*rev, h, f, &u_prev)?,
                };
                if let Some(g) = &gj {
                    r.axpy(1.0, g)?;
                }
                r.axpy(-1.0, &u_cur)?;
                Ok(TaskOut::State(r))
            })
        }
        TaskOp::Restrict { sys, level, j } => {
            let c = hier.coarsen;
            let coarse = &hier.levels[level + 1];
            let theta = coarse.theta_idx(j - 1);
            let h = coarse.h;
            let (r, inj_prev, inj_cur) = {
                let ss = st.sys(sys)?;
                (
                    ss.r[level][j * c].clone().ok_or_else(|| {
                        anyhow!("restrict({level},{j}): residual at point {} missing", j * c)
                    })?,
                    ss.u[level][(j - 1) * c].clone(),
                    ss.u[level][j * c].clone(),
                )
            };
            let fwd = match sys {
                Sys::Primal => None,
                Sys::Adjoint => {
                    let rev = rev_layer(hier, level + 1, j);
                    Some((rev, st.pri.u[0][rev].clone()))
                }
            };
            // inject the coarse initial guess + correction snapshot now —
            // safe because this task's WAR deps have already retired
            {
                let sm = st.sys_mut(sys)?;
                sm.u[level + 1][j] = inj_cur.clone();
                sm.inj[level + 1][j] = Some(inj_cur.clone());
            }
            pool.submit_job(task.device, label, task.id, tx.clone(), move |s: &F::Solver| {
                let phi = match &fwd {
                    None => s.step(theta, h, &inj_prev)?,
                    Some((rev, f)) => s.adjoint_step(*rev, h, f, &inj_prev)?,
                };
                let mut out = r;
                out.axpy(1.0, &inj_cur)?;
                out.axpy(-1.0, &phi)?;
                Ok(TaskOut::State(out))
            })
        }
        TaskOp::Correct { sys, level, j } => {
            let c = hier.coarsen;
            let ss = st.sys(sys)?;
            let u_fine = ss.u[level][j * c].clone();
            let u_coarse = ss.u[level + 1][j].clone();
            let inj = ss.inj[level + 1][j]
                .clone()
                .ok_or_else(|| anyhow!("correct({level},{j}): injection snapshot missing"))?;
            pool.submit_job(task.device, label, task.id, tx.clone(), move |_s: &F::Solver| {
                let delta = Tensor::sub(&u_coarse, &inj)?;
                let mut out = u_fine;
                out.axpy(1.0, &delta)?;
                Ok(TaskOut::State(out))
            })
        }
        TaskOp::Head => {
            let n_last = hier.fine().n_points - 1;
            let u = st.pri.u[0][n_last].clone();
            let labels = st.train()?.labels.clone();
            pool.submit_job(task.device, label, task.id, tx.clone(), move |s: &F::Solver| {
                let (_logits, loss) = s.head(&u, &labels)?;
                let (du, dw_fc, db_fc) = s.head_vjp(&u, &labels)?;
                Ok(TaskOut::Head { loss, du, dw_fc, db_fc })
            })
        }
        TaskOp::GradAccum { layer } => {
            let h = hier.fine().h;
            let n_layers = hier.fine().n_points - 1;
            let u = st.pri.u[0][layer].clone();
            // λ^{layer+1} = μ^{N−1−layer}
            let lam = st.sys(Sys::Adjoint)?.u[0][n_layers - 1 - layer].clone();
            pool.submit_job(task.device, label, task.id, tx.clone(), move |s: &F::Solver| {
                let (dw, db) = s.param_grad(layer, h, &u, &lam)?;
                Ok(TaskOut::Pair(dw, db))
            })
        }
        TaskOp::ParamUpdate { layer } => {
            let tr = st.train()?;
            let (dw, db) = tr
                .grads
                .get(layer)
                .ok_or_else(|| anyhow!("param_update({layer}): gradient slot empty"))?
                .clone();
            let (w, b) = tr.params.trunk[layer].clone();
            let lr = tr.lr;
            pool.submit_job(task.device, label, task.id, tx.clone(), move |_s: &F::Solver| {
                let mut w2 = w;
                w2.axpy(-lr, &dw)?;
                let mut b2 = b;
                b2.axpy(-lr, &db)?;
                Ok(TaskOut::Pair(w2, b2))
            })
        }
        TaskOp::Xfer => bail!("Xfer payload on a kernel task (graph bug)"),
    }
}

impl TaskOut {
    /// Compact variant name for error messages (derived Debug would dump
    /// whole tensors).
    fn kind(&self) -> &'static str {
        match self {
            TaskOut::State(_) => "State",
            TaskOut::States(_) => "States",
            TaskOut::Pair(..) => "Pair",
            TaskOut::Head { .. } => "Head",
        }
    }
}

fn expect_state(out: TaskOut, what: &str) -> Result<Tensor> {
    match out {
        TaskOut::State(t) => Ok(t),
        other => bail!("{what}: expected a single state, got {}", other.kind()),
    }
}

/// Write one completed kernel's output into its slot(s).
fn apply_output(hier: &Hierarchy, st: &mut ExecState, op: TaskOp, out: TaskOut) -> Result<()> {
    match op {
        TaskOp::PointUpdate { sys, level, j } => {
            st.sys_mut(sys)?.u[level][j] = expect_state(out, "point_update")?;
        }
        TaskOp::BlockRun { sys, level, j_first, j_last } => {
            let kind = out.kind();
            let TaskOut::States(v) = out else {
                bail!("block_run: expected a state span, got {kind}");
            };
            if v.len() != j_last - j_first + 1 {
                bail!("block_run: span length {} != {}", v.len(), j_last - j_first + 1);
            }
            let ss = st.sys_mut(sys)?;
            for (k, t) in v.into_iter().enumerate() {
                ss.u[level][j_first + k] = t;
            }
        }
        TaskOp::Residual { sys, level, j } => {
            st.sys_mut(sys)?.r[level][j] = Some(expect_state(out, "residual")?);
        }
        TaskOp::Restrict { sys, level, j } => {
            let t = expect_state(out, "restrict")?;
            match &mut st.sys_mut(sys)?.g[level + 1] {
                Some(g) => g[j] = t,
                None => bail!("restrict into level {} with no rhs storage", level + 1),
            }
        }
        TaskOp::Correct { sys, level, j } => {
            st.sys_mut(sys)?.u[level][j * hier.coarsen] = expect_state(out, "correct")?;
        }
        TaskOp::Head => {
            let TaskOut::Head { loss, du, dw_fc, db_fc } = out else {
                bail!("head: wrong output kind");
            };
            // ∂loss/∂u^N seeds every adjoint slot (the constant-in-depth
            // initial guess of the adjoint MGRIT solve)
            st.adj = Some(SysState::seeded(hier, &du));
            st.train_mut()?.head = Some(HeadOut { loss, dw_fc, db_fc });
        }
        TaskOp::GradAccum { layer } => {
            let TaskOut::Pair(dw, db) = out else {
                bail!("param_grad: wrong output kind");
            };
            st.train_mut()?.grads.set(layer, dw, db)?;
        }
        TaskOp::ParamUpdate { layer } => {
            let TaskOut::Pair(w, b) = out else {
                bail!("param_update: wrong output kind");
            };
            st.train_mut()?.new_trunk.set(layer, w, b)?;
        }
        TaskOp::Xfer => bail!("Xfer payload completed as a kernel (graph bug)"),
    }
    Ok(())
}

/// Merge a per-label phase ledger into a cumulative one (driver helper);
/// same accumulate-by-label rule as [`ExecReport::add_phase`].
pub(crate) fn merge_phases(
    into: &mut Vec<(&'static str, f64)>,
    phases: &[(&'static str, f64)],
) {
    for &(label, secs) in phases {
        if let Some(e) = into.iter_mut().find(|(l, _)| *l == label) {
            e.1 += secs;
        } else {
            into.push((label, secs));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Partition;
    use crate::mgrit::fas::RelaxKind;
    use crate::mgrit::taskgraph::{self, Granularity};
    use crate::model::{NetParams, NetSpec};
    use crate::solver::host::HostSolver;
    use std::sync::Arc;

    fn setup() -> (Arc<NetSpec>, Hierarchy, Partition, StreamPool<impl SolverFactory<Solver = HostSolver>>, Tensor)
    {
        let spec = Arc::new(NetSpec::micro());
        let params = Arc::new(NetParams::init(&spec, 30).unwrap());
        let spec2 = spec.clone();
        let factory = move |_w: usize| HostSolver::new(spec2.clone(), params.clone());
        let hier = Hierarchy::two_level(4, spec.h(), 2).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let partition = Partition::contiguous(n_blocks, 2).unwrap();
        let pool = StreamPool::new(partition.n_devices(), factory).unwrap();
        let mut rng = crate::util::prng::Rng::new(31);
        let u0 = Tensor::randn(&[1, 2, 6, 6], 0.8, &mut rng);
        (spec, hier, partition, pool, u0)
    }

    #[test]
    fn vcycle_graph_executes_and_counts_work() {
        let (spec, hier, partition, pool, u0) = setup();
        let g = taskgraph::mg_vcycle(&spec, &hier, &partition, 1, RelaxKind::FCF);
        let mut st = ExecState::initial(&hier, &u0);
        let rep = execute(&pool, &hier, &g, &mut st).unwrap();
        assert!(rep.kernels > 0);
        assert!(rep.phi_evals > 0);
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "f_relax"));
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "coarse_solve"));
        // states moved away from the constant initial guess
        let moved = st.pri.u[0][1..]
            .iter()
            .any(|u| crate::util::stats::rel_l2_err(u.data(), u0.data()) > 1e-6);
        assert!(moved, "executor did not update any state");
    }

    #[test]
    fn per_block_vcycle_bit_matches_per_step() {
        let (spec, hier, partition, pool, u0) = setup();
        let gs = taskgraph::mg_vcycle_with(&spec, &hier, &partition, 1, RelaxKind::FCF, Granularity::PerStep);
        let gb = taskgraph::mg_vcycle_with(&spec, &hier, &partition, 1, RelaxKind::FCF, Granularity::PerBlock);
        let mut st_s = ExecState::initial(&hier, &u0);
        let mut st_b = ExecState::initial(&hier, &u0);
        let rep_s = execute(&pool, &hier, &gs, &mut st_s).unwrap();
        let rep_b = execute(&pool, &hier, &gb, &mut st_b).unwrap();
        // fused F-spans perform the identical arithmetic in the same order
        assert_eq!(rep_s.phi_evals, rep_b.phi_evals);
        let a = st_s.into_fine_states();
        let b = st_b.into_fine_states();
        for (x, y) in a.iter().zip(&b) {
            assert!(x.data() == y.data(), "per-block state differs bitwise");
        }
    }

    #[test]
    fn residual_check_fills_residual_slots() {
        let (spec, hier, partition, pool, u0) = setup();
        let g = taskgraph::residual_check(&spec, &hier, &partition, 1);
        let mut st = ExecState::initial(&hier, &u0);
        execute(&pool, &hier, &g, &mut st).unwrap();
        for cp in hier.fine().cpoints(hier.coarsen) {
            if cp > 0 {
                assert!(st.residual(0, cp).is_some(), "residual at {cp} missing");
            }
        }
    }

    #[test]
    fn non_executable_graph_is_rejected() {
        let (spec, hier, _partition, pool, u0) = setup();
        // serial_forward carries no payloads
        let g = taskgraph::serial_forward(&spec, 1, 1);
        let mut st = ExecState::initial(&hier, &u0);
        assert!(execute(&pool, &hier, &g, &mut st).is_err());
    }

    #[test]
    fn training_graph_without_train_state_is_rejected() {
        let (spec, hier, partition, pool, u0) = setup();
        let g = taskgraph::mg_train_step(
            &spec, &hier, &partition, 1, 1, RelaxKind::FCF, Granularity::PerStep,
        );
        let mut st = ExecState::initial(&hier, &u0);
        let err = execute(&pool, &hier, &g, &mut st).unwrap_err().to_string();
        assert!(err.contains("training"), "{err}");
    }

    #[test]
    fn training_graph_fills_all_sharded_slots() {
        let (spec, hier, partition, pool, u0) = setup();
        let params = Arc::new(NetParams::init(&spec, 30).unwrap());
        let g = taskgraph::mg_train_step(
            &spec, &hier, &partition, 1, 2, RelaxKind::FCF, Granularity::PerStep,
        );
        let labels = [3i32];
        let mut st = ExecState::initial_train(&hier, &u0, &labels, params.clone(), 0.05);
        let rep = execute(&pool, &hier, &g, &mut st).unwrap();
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "adj_f_relax"));
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "param_grad"));
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "param_update"));
        let out = st.into_training_outputs().unwrap();
        assert!(out.loss.is_finite());
        assert_eq!(out.states.len(), hier.fine().n_points);
        assert_eq!(out.lams.len(), hier.fine().n_points);
        assert_eq!(out.trunk_grads.len(), spec.n_res());
        assert_eq!(out.new_trunk.len(), spec.n_res());
        // updated params moved against the gradient direction
        for ((w_new, _), ((w_old, _), (dw, _))) in
            out.new_trunk.iter().zip(params.trunk.iter().zip(&out.trunk_grads))
        {
            let mut want = w_old.clone();
            want.axpy(-0.05, dw).unwrap();
            assert!(w_new.data() == want.data(), "param update is not θ − lr·g");
        }
    }

    #[test]
    fn merge_phases_accumulates_by_label() {
        let mut acc: Vec<(&'static str, f64)> = vec![("a", 1.0)];
        merge_phases(&mut acc, &[("a", 2.0), ("b", 3.0)]);
        merge_phases(&mut acc, &[("b", 1.0)]);
        assert_eq!(acc, vec![("a", 3.0), ("b", 4.0)]);
    }
}
