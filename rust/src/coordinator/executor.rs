//! The dependency-driven task-graph executor: runs an executable
//! [`TaskGraph`] on real tensors over a [`StreamPool`], dispatching each task
//! to its device's worker the moment its dependencies retire.
//!
//! This replaces the old per-phase barriers: C-relaxation and residual work
//! of one partition overlap F-relaxation of another, exactly as in the
//! simulated schedule (the paper's kernel-concurrency argument, Fig 5) —
//! and, for the training graph, adjoint relaxation on early layers overlaps
//! parameter-gradient work on late layers.
//!
//! The executor is a **multi-instance runtime**: a graph's tasks are
//! `(instance, task)` pairs, the live state is one [`ExecState`] per
//! instance inside a [`MultiExecState`], and a single scheduler drains the
//! union frontier of all instances over one pool. N concurrent
//! `mg_train_step` instances (micro-batches) therefore pipeline through the
//! same workers with no inter-instance barrier — instance k+1's forward
//! V-cycles fill the device gaps of instance k's adjoint/gradient wave,
//! joined only at the per-layer `ReduceGrad` tree.
//!
//! ## Dependency-retirement protocol
//!
//! 1. in-degrees are counted per task; zero-degree tasks enter the ready
//!    queue — a max-heap on the [`placement`](super::placement) dispatch
//!    priority whose ties break by **lowest task id**, so the default
//!    all-zero priorities degenerate to the legacy min-id order (earlier
//!    instances get queue priority — the pipelining skew) and a
//!    [`super::placement::Placement`]'s HEFT ranks advance the critical
//!    path first;
//! 2. ready **Comm** tasks retire immediately on the scheduler thread —
//!    intra-node the tensors share memory and local execution only
//!    *accounts* the transfer, while on a sharded
//!    [`super::streams::NodePools`] substrate a cross-node edge additionally
//!    ships the producer's slot bytes through the pool's
//!    [`super::transport::Transport`] (serialize → send → deserialize,
//!    verified bitwise — see `ship_comm`);
//! 3. ready **Kernel** tasks take `Arc` handles on their input slots out of
//!    their instance's [`ExecState`] (refcount bumps, not deep copies — the
//!    scheduler thread is the only state owner, so no locks), and are
//!    submitted to the worker owning `task.device`;
//! 4. each completion ([`JobDone`]) writes the task's output slot(s) back,
//!    decrements its dependents' counters, and pushes newly-ready tasks —
//!    completion order is irrelevant because the graph carries RAW/WAR/WAW
//!    edges for every slot (see `mgrit::taskgraph`);
//! 5. the run ends when every task has retired; a non-executable task
//!    (`op == None`) or an exhausted ready set with nothing in flight is an
//!    error, not a hang.
//!
//! The training ops extend the same protocol: `Head` seeds its instance's
//! adjoint slot set when it retires; `GradAccum` fills one layer's sharded
//! gradient slot in its instance; `ReduceGrad` folds instance gradients into
//! the shared per-layer reduction-tree slots (the root applies the 1/M
//! mean); `ParamUpdate` writes the layer's fresh shared parameters.
//!
//! Because each op performs the same f32 arithmetic in the same order as the
//! serial engines (`mgrit::fas` / `train::mg_step_serial` /
//! `train::mg_step_serial_micro`), any topological execution is bit-identical
//! to the serial solve — asserted by `tests/mgrit_integration.rs` and
//! `tests/hybrid_integration.rs`.

use std::collections::{BTreeMap, BinaryHeap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail};

use super::checkpoint::{
    pair_from_json, pair_to_json, params_from_json, params_to_json, tensor_from_json,
    tensor_to_json, SessionSnapshot,
};
use super::placement::ReadyKey;
use super::streams::{JobDone, StreamPool, WorkerPool};
use super::transport::{decode_tensor, encode_tensor};
use crate::util::json::{self, Json};
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph::{op_param_slots, GradSrc, Sys, Task, TaskGraph, TaskKind, TaskOp};
use crate::model::params::{pair_scale, pair_sum, TrunkGradSlots};
use crate::model::spec::LayerKind;
use crate::model::{NetParams, NetSpec};
use crate::solver::{BlockSolver, NetExecutor, SolverFactory};
use crate::tensor::{ops, vjp, Tensor};
use crate::Result;

/// The state slots of one MGRIT system (primal or adjoint): per level, the
/// point states `u`, the FAS right-hand sides `g`, the C-point residuals `r`
/// and the injection snapshots the correction consumes. Slots hold
/// `Arc<Tensor>` — tasks read them by refcount bump and every write replaces
/// the whole slot, so the ~40 defensive deep copies the dispatch path used
/// to make are gone from the scheduler hot path.
#[derive(Debug)]
pub struct SysState {
    /// Per level, the point states `u[level][j]`.
    pub u: Vec<Vec<Arc<Tensor>>>,
    g: Vec<Option<Vec<Arc<Tensor>>>>,
    r: Vec<Vec<Option<Arc<Tensor>>>>,
    inj: Vec<Vec<Option<Arc<Tensor>>>>,
}

impl SysState {
    /// Every point of every level seeded with `seed` (the constant-in-depth
    /// initial guess of `LevelState::initial`); coarse right-hand sides zero.
    /// All points share the seed allocation until first written.
    fn seeded(hier: &Hierarchy, seed: &Tensor) -> SysState {
        let s = Arc::new(seed.clone());
        let u: Vec<Vec<Arc<Tensor>>> =
            hier.levels.iter().map(|l| vec![s.clone(); l.n_points]).collect();
        let z = Arc::new(Tensor::zeros(seed.dims()));
        let g = hier
            .levels
            .iter()
            .enumerate()
            .map(|(i, l)| if i == 0 { None } else { Some(vec![z.clone(); l.n_points]) })
            .collect();
        let r = hier.levels.iter().map(|l| vec![None; l.n_points]).collect();
        let inj = hier.levels.iter().map(|l| vec![None; l.n_points]).collect();
        SysState { u, g, r, inj }
    }
}

/// Per-instance training state: the micro-batch labels, the head output, and
/// the sharded per-layer gradient slots this instance's fan-out tasks fill.
#[derive(Debug)]
struct TrainState {
    labels: Vec<i32>,
    grads: TrunkGradSlots,
    head: Option<HeadOut>,
}

/// What one instance's head task leaves behind on the scheduler side.
#[derive(Debug)]
struct HeadOut {
    loss: f64,
    dw_fc: Tensor,
    db_fc: Tensor,
}

/// The live state of ONE graph instance: the primal system, the adjoint
/// system (seeded by the instance's `Head` task mid-graph), and the
/// per-instance training bookkeeping.
#[derive(Debug)]
pub struct ExecState {
    pri: SysState,
    adj: Option<SysState>,
    train: Option<TrainState>,
}

impl ExecState {
    fn new(hier: &Hierarchy, u0: &Tensor, train: Option<TrainState>) -> ExecState {
        ExecState { pri: SysState::seeded(hier, u0), adj: None, train }
    }

    fn sys(&self, s: Sys) -> Result<&SysState> {
        match s {
            Sys::Primal => Ok(&self.pri),
            Sys::Adjoint => self
                .adj
                .as_ref()
                .ok_or_else(|| anyhow!("adjoint state missing (Head task has not retired)")),
        }
    }

    fn sys_mut(&mut self, s: Sys) -> Result<&mut SysState> {
        match s {
            Sys::Primal => Ok(&mut self.pri),
            Sys::Adjoint => self
                .adj
                .as_mut()
                .ok_or_else(|| anyhow!("adjoint state missing (Head task has not retired)")),
        }
    }

    fn train(&self) -> Result<&TrainState> {
        self.train.as_ref().ok_or_else(|| {
            anyhow!("training op in a non-training run (use MultiExecState::initial_train)")
        })
    }

    fn train_mut(&mut self) -> Result<&mut TrainState> {
        self.train.as_mut().ok_or_else(|| {
            anyhow!("training op in a non-training run (use MultiExecState::initial_train)")
        })
    }
}

/// Training state shared across instances: the parameter snapshot, the
/// per-layer micro-batch gradient reduction-tree slots, the reduced (mean)
/// gradients, and the post-SGD parameter slots — filled exactly once each by
/// the joint `ReduceGrad` / `ParamUpdate` tasks.
#[derive(Debug)]
struct SharedTrain {
    params: Arc<NetParams>,
    lr: f32,
    /// `nodes[layer][node]` — internal reduction-tree partial sums.
    nodes: Vec<Vec<Option<(Tensor, Tensor)>>>,
    /// Per-layer reduced (mean) gradients: the `ReduceGrad` roots.
    reduced: TrunkGradSlots,
    /// Per-layer post-SGD trunk parameters.
    new_trunk: TrunkGradSlots,
}

/// Versioned parameter storage for **cross-step pipelined** training: a
/// bounded ring of parameter *versions*, each one `(w, b)` pair per slot
/// (trunk layers `0..n_layers`, the opening pair at `n_layers`, the head
/// pair at `n_layers + 1`). Version 0 is the admitted snapshot; step t's
/// `ParamUpdate`s write version t+1; step t's tasks read version
/// `max(0, t − S)`. Per-version outstanding-read counts are fixed at
/// admission from the graph, so a version retires (frees its tensors) the
/// moment its last reader completes — the ring's live depth is bounded by
/// S + 2 when the graph's staleness edges are correct, and reading a retired
/// or unwritten version is a hard error, never a silent stale read.
#[derive(Debug)]
pub struct SnapshotRing {
    /// Absolute version number of `versions[0]`.
    base: usize,
    /// Live versions, oldest first; each is one optional `(w, b)` per slot.
    versions: VecDeque<Vec<Option<(Arc<Tensor>, Arc<Tensor>)>>>,
    /// Outstanding parameter reads per absolute version.
    pending: Vec<usize>,
    n_slots: usize,
    peak: usize,
}

impl SnapshotRing {
    /// Ring seeded with `params` as version 0; `pending[v]` is the total
    /// read count the admitted graph performs against version `v`.
    pub fn new(params: &NetParams, n_layers: usize, pending: Vec<usize>) -> SnapshotRing {
        let n_slots = n_layers + 2;
        let mut v0: Vec<Option<(Arc<Tensor>, Arc<Tensor>)>> = Vec::with_capacity(n_slots);
        for (w, b) in &params.trunk {
            v0.push(Some((Arc::new(w.clone()), Arc::new(b.clone()))));
        }
        v0.push(Some((Arc::new(params.w_open.clone()), Arc::new(params.b_open.clone()))));
        v0.push(Some((Arc::new(params.w_fc.clone()), Arc::new(params.b_fc.clone()))));
        let mut versions = VecDeque::new();
        versions.push_back(v0);
        SnapshotRing { base: 0, versions, pending, n_slots, peak: 1 }
    }

    /// The `(w, b)` pair of `slot` at absolute `version`. Hard-errors on a
    /// retired version (a staleness-edge bug would otherwise read freed
    /// parameters) or an unwritten one (a missing dependency edge).
    pub fn get(&self, version: usize, slot: usize) -> Result<(Arc<Tensor>, Arc<Tensor>)> {
        if version < self.base {
            bail!(
                "snapshot ring: version {version} slot {slot} already retired (base {})",
                self.base
            );
        }
        self.versions
            .get(version - self.base)
            .and_then(|v| v.get(slot))
            .and_then(|s| s.clone())
            .ok_or_else(|| anyhow!("snapshot ring: version {version} slot {slot} not yet written"))
    }

    /// Write `slot` of `version`, extending the ring as needed. A double
    /// write is a graph bug.
    pub fn set(&mut self, version: usize, slot: usize, w: Tensor, b: Tensor) -> Result<()> {
        anyhow::ensure!(
            version >= self.base,
            "snapshot ring: write to retired version {version} (base {})",
            self.base
        );
        anyhow::ensure!(slot < self.n_slots, "snapshot ring: slot {slot} out of range");
        while self.versions.len() <= version - self.base {
            self.versions.push_back(vec![None; self.n_slots]);
            self.peak = self.peak.max(self.versions.len());
        }
        let s = &mut self.versions[version - self.base][slot];
        anyhow::ensure!(
            s.is_none(),
            "snapshot ring: version {version} slot {slot} written twice"
        );
        *s = Some((Arc::new(w), Arc::new(b)));
        Ok(())
    }

    /// Record one completed read against `version` and retire leading
    /// versions whose reads drained. The newest version — the run's final
    /// parameters — is never retired.
    pub fn note_read(&mut self, version: usize) -> Result<()> {
        let p = self
            .pending
            .get_mut(version)
            .ok_or_else(|| anyhow!("snapshot ring: read of unknown version {version}"))?;
        anyhow::ensure!(
            *p > 0,
            "snapshot ring: version {version} read more times than admitted"
        );
        *p -= 1;
        while self.versions.len() > 1 && self.pending.get(self.base).copied() == Some(0) {
            self.versions.pop_front();
            self.base += 1;
        }
        Ok(())
    }

    /// Currently-live version count.
    pub fn depth(&self) -> usize {
        self.versions.len()
    }

    /// Maximum live version count over the run — the ring's memory
    /// high-water mark (≤ S + 2 when the staleness edges are correct).
    pub fn peak_depth(&self) -> usize {
        self.peak
    }

    /// Serialize the ring field-by-field for a checkpoint: live versions,
    /// outstanding read counts, and the high-water mark all survive, so a
    /// resumed run performs the identical retire sequence.
    fn to_json(&self) -> Json {
        let ver = |v: &Vec<Option<(Arc<Tensor>, Arc<Tensor>)>>| {
            Json::Arr(
                v.iter()
                    .map(|s| match s {
                        None => Json::Null,
                        Some((w, b)) => json::obj(vec![
                            ("w", tensor_to_json(w)),
                            ("b", tensor_to_json(b)),
                        ]),
                    })
                    .collect(),
            )
        };
        json::obj(vec![
            ("base", json::num(self.base as f64)),
            ("versions", Json::Arr(self.versions.iter().map(ver).collect())),
            ("pending", Json::Arr(self.pending.iter().map(|&p| json::num(p as f64)).collect())),
            ("n_slots", json::num(self.n_slots as f64)),
            ("peak", json::num(self.peak as f64)),
        ])
    }

    /// Rebuild a ring from [`SnapshotRing::to_json`] output.
    fn from_json(j: &Json) -> Result<SnapshotRing> {
        let versions = j
            .get("versions")?
            .as_arr()?
            .iter()
            .map(|v| -> Result<Vec<Option<(Arc<Tensor>, Arc<Tensor>)>>> {
                v.as_arr()?
                    .iter()
                    .map(|s| match s {
                        Json::Null => Ok(None),
                        p => Ok(Some((
                            Arc::new(tensor_from_json(p.get("w")?)?),
                            Arc::new(tensor_from_json(p.get("b")?)?),
                        ))),
                    })
                    .collect()
            })
            .collect::<Result<VecDeque<_>>>()?;
        let pending = j
            .get("pending")?
            .as_arr()?
            .iter()
            .map(|p| p.as_usize())
            .collect::<Result<Vec<_>>>()?;
        Ok(SnapshotRing {
            base: j.get("base")?.as_usize()?,
            versions,
            pending,
            n_slots: j.get("n_slots")?.as_usize()?,
            peak: j.get("peak")?.as_usize()?,
        })
    }
}

/// Training state of a cross-step **pipelined** run, shared across all its
/// instances: the versioned parameter ring plus *per-step* reduction
/// storage — each of the K steps joins its own M instances, so the flat
/// [`SharedTrain`] slots do not apply.
#[derive(Debug)]
struct PipeShared {
    spec: Arc<NetSpec>,
    lr: f32,
    micro: usize,
    staleness: usize,
    k_steps: usize,
    n_layers: usize,
    ring: SnapshotRing,
    /// `nodes[step][slot][node]` — internal reduction-tree partial sums.
    nodes: Vec<Vec<Vec<Option<(Tensor, Tensor)>>>>,
    /// `reduced[step][slot]` — the per-step `ReduceGrad` roots.
    reduced: Vec<Vec<Option<(Tensor, Tensor)>>>,
    /// Per global instance, the raw micro-batch input `y` (read by the
    /// in-graph `Opening` / `OpenGrad` tasks).
    inputs: Vec<Arc<Tensor>>,
}

/// Everything a completed pipelined training run produced.
#[derive(Debug)]
pub struct PipelineOutputs {
    /// Per-step mean loss over the step's M instances, in step order — each
    /// computed with the identical summation order as the sequential
    /// reference (`Σₖ lossₖ / M`, instance order).
    pub losses: Vec<f64>,
    /// Per-step global norm of the reduced (micro-batch mean) gradient over
    /// every parameter slot — trunk layers, opening, head — harvested from
    /// the step's `ReduceGrad` roots (the lone instance's gradients when
    /// M = 1). Same quantity `train_parallel` reports via
    /// `NetGrads::global_norm`, so pipelined step logs are comparable.
    pub grad_norms: Vec<f64>,
    /// The final parameters: ring version K.
    pub params: NetParams,
    /// The snapshot ring's live-depth high-water mark (≤ S + 2).
    pub peak_ring_depth: usize,
}

/// The live state the multi-instance executor reads and writes: one
/// [`ExecState`] per graph instance plus the shared training join state.
#[derive(Debug)]
pub struct MultiExecState {
    insts: Vec<ExecState>,
    shared: Option<SharedTrain>,
    pipe: Option<PipeShared>,
}

/// One instance's share of a completed training run.
#[derive(Debug)]
pub struct InstanceOutputs {
    /// This micro-batch's loss.
    pub loss: f64,
    /// Fine-level forward trajectory u^0..u^N.
    pub states: Vec<Tensor>,
    /// Adjoints λ^0..λ^N (forward layer indexing).
    pub lams: Vec<Tensor>,
    /// This instance's per-layer (dW, db) trunk gradients. For M = 1 the
    /// instance gradients ARE the reduced gradients, so they are moved into
    /// [`MultiTrainingOutputs::trunk_grads`] and this field is left empty
    /// (no per-step full-gradient copy on the default path).
    pub trunk_grads: Vec<(Tensor, Tensor)>,
    /// Head weight gradient for this micro-batch.
    pub dw_fc: Tensor,
    /// Head bias gradient for this micro-batch.
    pub db_fc: Tensor,
}

/// Everything a completed (possibly multi-instance) training graph produced.
#[derive(Debug)]
pub struct MultiTrainingOutputs {
    /// Mean loss over instances — identical to the instance loss when M = 1
    /// and to the serial reference's `Σ lossₖ / M` otherwise.
    pub loss: f64,
    /// Per-instance outputs, in instance order.
    pub instances: Vec<InstanceOutputs>,
    /// Reduced per-layer trunk gradients: the lone instance's gradients when
    /// M = 1, the `ReduceGrad` roots (micro-batch mean) otherwise.
    pub trunk_grads: Vec<(Tensor, Tensor)>,
    /// Per-layer post-SGD trunk parameters.
    pub new_trunk: Vec<(Tensor, Tensor)>,
}

fn unwrap_arcs(v: Vec<Arc<Tensor>>) -> Vec<Tensor> {
    v.into_iter()
        .map(|a| Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone()))
        .collect()
}

impl MultiExecState {
    /// Forward-solve state: one instance, primal system seeded with `u0`, no
    /// training bookkeeping (graphs with training ops will be rejected at
    /// dispatch).
    pub fn initial(hier: &Hierarchy, u0: &Tensor) -> MultiExecState {
        MultiExecState { insts: vec![ExecState::new(hier, u0, None)], shared: None, pipe: None }
    }

    /// Training-step state for M instances: `inputs[k]` is instance k's
    /// opening state u0 and micro-batch labels. The adjoint systems are
    /// seeded by each instance's `Head` task at runtime; the reduction-tree
    /// slots are sized for the `reduce_plan(M)` join.
    pub fn initial_train(
        hier: &Hierarchy,
        inputs: &[(Tensor, Vec<i32>)],
        params: Arc<NetParams>,
        lr: f32,
    ) -> Result<MultiExecState> {
        anyhow::ensure!(!inputs.is_empty(), "need at least one training instance");
        let n_layers = hier.fine().n_points - 1;
        let m = inputs.len();
        let insts = inputs
            .iter()
            .map(|(u0, labels)| {
                ExecState::new(
                    hier,
                    u0,
                    Some(TrainState {
                        labels: labels.clone(),
                        grads: TrunkGradSlots::new(n_layers),
                        head: None,
                    }),
                )
            })
            .collect();
        let nodes = vec![vec![None; m.saturating_sub(1)]; n_layers];
        Ok(MultiExecState {
            insts,
            shared: Some(SharedTrain {
                params,
                lr,
                nodes,
                reduced: TrunkGradSlots::new(n_layers),
                new_trunk: TrunkGradSlots::new(n_layers),
            }),
            pipe: None,
        })
    }

    /// Pipelined-training state for a `mg_train_pipeline` graph over
    /// `inputs.len()` instances (K steps × `micro` micro-batches, instance
    /// order step-major): `inputs[t·micro + k]` is step t's k-th raw
    /// micro-batch `y` and its labels — the in-graph `Opening` task computes
    /// u⁰ against the step's parameter *version*, so unlike
    /// [`MultiExecState::initial_train`] the caller passes raw inputs, not
    /// opened states. The snapshot ring is seeded with `params` as version 0
    /// and its per-version read counts are scanned from `graph`, so versions
    /// retire exactly when their last reader completes. `staleness` must
    /// match the graph's `PipeSync`: the version step t reads is
    /// `max(0, t − staleness)` — pass 0 for barrier-synced graphs, whose
    /// cross-step edges guarantee version t is complete before step t
    /// dispatches.
    #[allow(clippy::too_many_arguments)]
    pub fn initial_train_pipeline(
        hier: &Hierarchy,
        spec: Arc<NetSpec>,
        graph: &TaskGraph,
        inputs: &[(Tensor, Vec<i32>)],
        params: Arc<NetParams>,
        lr: f32,
        micro: usize,
        staleness: usize,
    ) -> Result<MultiExecState> {
        anyhow::ensure!(micro >= 1, "need at least one micro-batch");
        anyhow::ensure!(
            !inputs.is_empty() && inputs.len() % micro == 0,
            "instance count {} is not a multiple of micro {micro}",
            inputs.len()
        );
        let k_steps = inputs.len() / micro;
        let n_layers = hier.fine().n_points - 1;
        let n_slots = n_layers + 2;
        anyhow::ensure!(
            params.trunk.len() == n_layers,
            "params have {} trunk layers, hierarchy has {n_layers}",
            params.trunk.len()
        );
        // each instance's primal system is seeded by its in-graph Opening
        // task (the instance's sole dependency-free task — everything else
        // is ordered behind it), so the placeholder seed is never read
        let ph = Tensor::zeros(&[1]);
        let insts: Vec<ExecState> = inputs
            .iter()
            .map(|(_, labels)| {
                ExecState::new(
                    hier,
                    &ph,
                    Some(TrainState {
                        labels: labels.clone(),
                        // slots 0..n_layers: trunk GradAccum; slot n_layers:
                        // the OpenGrad pair (the head pair lives in HeadOut)
                        grads: TrunkGradSlots::new(n_layers + 1),
                        head: None,
                    }),
                )
            })
            .collect();
        // per-version outstanding read counts: every parameter-reading task
        // of step t reads version max(0, t − S) once per slot it touches;
        // every ParamUpdate of step t additionally reads version t (its base)
        let mut pending = vec![0usize; k_steps + 1];
        for t in &graph.tasks {
            let Some(op) = &t.op else { continue };
            let step = t.instance / micro;
            anyhow::ensure!(
                step < k_steps,
                "task {} instance {} exceeds the {k_steps}-step input set",
                t.id,
                t.instance
            );
            if matches!(op, TaskOp::ParamUpdate { .. }) {
                pending[step] += 1;
            } else {
                pending[step.saturating_sub(staleness)] += op_param_slots(op, hier, n_layers).len();
            }
        }
        let ring = SnapshotRing::new(&params, n_layers, pending);
        Ok(MultiExecState {
            insts,
            shared: None,
            pipe: Some(PipeShared {
                spec,
                lr,
                micro,
                staleness,
                k_steps,
                n_layers,
                ring,
                nodes: vec![vec![vec![None; micro.saturating_sub(1)]; n_slots]; k_steps],
                reduced: vec![vec![None; n_slots]; k_steps],
                inputs: inputs.iter().map(|(y, _)| Arc::new(y.clone())).collect(),
            }),
        })
    }

    /// State with no instances — the starting point of a dynamic
    /// ([`ExecSession`]) run, where forward-only instances are admitted one
    /// request at a time via [`MultiExecState::push_instance`].
    pub fn empty() -> MultiExecState {
        MultiExecState { insts: Vec::new(), shared: None, pipe: None }
    }

    /// Append a fresh forward-only instance (primal system seeded with `u0`,
    /// no training bookkeeping) and return its instance index.
    pub fn push_instance(&mut self, hier: &Hierarchy, u0: &Tensor) -> usize {
        self.insts.push(ExecState::new(hier, u0, None));
        self.insts.len() - 1
    }

    /// The final fine-level state u^N of instance `k`, cloned out of its
    /// slot. Errors if the instance was already released.
    pub fn final_state(&self, k: usize) -> Result<Tensor> {
        let inst = self.inst(k)?;
        inst.pri
            .u
            .first()
            .and_then(|fine| fine.last())
            .map(|u| (**u).clone())
            .ok_or_else(|| anyhow!("instance {k} has been released"))
    }

    /// Drop instance `k`'s state slots (the activation memory of a completed
    /// request), leaving a tombstone so instance indices of still-running
    /// requests stay valid. Reading a released instance errors.
    pub fn release_instance(&mut self, k: usize) -> Result<()> {
        let inst = self.inst_mut(k)?;
        inst.pri = SysState { u: Vec::new(), g: Vec::new(), r: Vec::new(), inj: Vec::new() };
        inst.adj = None;
        inst.train = None;
        Ok(())
    }

    /// Number of graph instances this state serves.
    pub fn n_instances(&self) -> usize {
        self.insts.len()
    }

    fn inst(&self, k: usize) -> Result<&ExecState> {
        self.insts.get(k).ok_or_else(|| anyhow!("task instance {k} out of range"))
    }

    fn inst_mut(&mut self, k: usize) -> Result<&mut ExecState> {
        let n = self.insts.len();
        self.insts
            .get_mut(k)
            .ok_or_else(|| anyhow!("task instance {k} out of range ({n} instances)"))
    }

    fn shared(&self) -> Result<&SharedTrain> {
        self.shared.as_ref().ok_or_else(|| {
            anyhow!("training op in a non-training run (use MultiExecState::initial_train)")
        })
    }

    fn shared_mut(&mut self) -> Result<&mut SharedTrain> {
        self.shared.as_mut().ok_or_else(|| {
            anyhow!("training op in a non-training run (use MultiExecState::initial_train)")
        })
    }

    /// A reduction-tree operand of one layer: an instance's gradient or an
    /// earlier internal node. Deep-clones the pair (it leaves the scheduler
    /// for a worker thread).
    fn grad_src(&self, layer: usize, src: GradSrc) -> Result<(Tensor, Tensor)> {
        match src {
            GradSrc::Inst(k) => self
                .inst(k)?
                .train()?
                .grads
                .get(layer)
                .cloned()
                .ok_or_else(|| anyhow!("reduce({layer}): instance {k} gradient slot empty")),
            GradSrc::Node(n) => self
                .shared()?
                .nodes
                .get(layer)
                .and_then(|l| l.get(n))
                .and_then(|s| s.clone())
                .ok_or_else(|| anyhow!("reduce({layer}): tree node {n} slot empty")),
        }
    }

    /// Pipelined counterpart of [`MultiExecState::grad_src`]: a *step-local*
    /// reduction operand — instance leaves index the step's own M instances
    /// and tree nodes the step's own storage. Slot `n_layers + 1` (the head
    /// pair) reads the instance's `HeadOut` gradients; slot `n_layers` the
    /// `OpenGrad` pair; trunk slots the `GradAccum` pairs.
    fn grad_src_pipe(&self, step: usize, slot: usize, src: GradSrc) -> Result<(Tensor, Tensor)> {
        let pipe = self
            .pipe
            .as_ref()
            .ok_or_else(|| anyhow!("pipelined reduce outside a pipelined run"))?;
        match src {
            GradSrc::Inst(k) => {
                let gi = step * pipe.micro + k;
                let train = self.inst(gi)?.train()?;
                if slot == pipe.n_layers + 1 {
                    let head = train.head.as_ref().ok_or_else(|| {
                        anyhow!("reduce({slot}): instance {gi} head not retired")
                    })?;
                    Ok((head.dw_fc.clone(), head.db_fc.clone()))
                } else {
                    train.grads.get(slot).cloned().ok_or_else(|| {
                        anyhow!("reduce({slot}): instance {gi} gradient slot empty")
                    })
                }
            }
            GradSrc::Node(n) => pipe
                .nodes
                .get(step)
                .and_then(|s| s.get(slot))
                .and_then(|l| l.get(n))
                .and_then(|s| s.clone())
                .ok_or_else(|| anyhow!("reduce({slot}): step {step} tree node {n} empty")),
        }
    }

    /// Residual tensor at `(level, j)` of instance 0's primal system, if
    /// computed (the forward solve's convergence check).
    pub fn residual(&self, level: usize, j: usize) -> Option<&Tensor> {
        self.insts[0].pri.r[level][j].as_deref()
    }

    /// Consume the state, returning instance 0's fine-level trajectory.
    pub fn into_fine_states(mut self) -> Vec<Tensor> {
        unwrap_arcs(self.insts.swap_remove(0).pri.u.swap_remove(0))
    }

    /// Consume a completed training run into its outputs. Errors if any
    /// head never retired or any sharded slot is unfilled.
    pub fn into_training_outputs(self) -> Result<MultiTrainingOutputs> {
        let shared = self.shared.ok_or_else(|| {
            anyhow!("not a training run (use MultiExecState::initial_train)")
        })?;
        let m = self.insts.len();
        let mut instances = Vec::with_capacity(m);
        for (k, inst) in self.insts.into_iter().enumerate() {
            let mut adj = inst
                .adj
                .ok_or_else(|| anyhow!("instance {k}: training run never seeded the adjoint"))?;
            let train =
                inst.train.ok_or_else(|| anyhow!("instance {k}: missing training state"))?;
            let head =
                train.head.ok_or_else(|| anyhow!("instance {k}: head task never retired"))?;
            let mut pri = inst.pri;
            let states = unwrap_arcs(pri.u.swap_remove(0));
            // μ^m = λ^{N−m} → reverse back to forward indexing
            let mut lams = unwrap_arcs(adj.u.swap_remove(0));
            lams.reverse();
            instances.push(InstanceOutputs {
                loss: head.loss,
                states,
                lams,
                trunk_grads: train.grads.into_pairs()?,
                dw_fc: head.dw_fc,
                db_fc: head.db_fc,
            });
        }
        // the combined loss: mean over instances, in instance order — the
        // serial reference computes the identical expression
        let loss = instances.iter().map(|i| i.loss).sum::<f64>() / m as f64;
        let trunk_grads = if m == 1 {
            // the instance gradients ARE the reduced set: move, don't copy
            std::mem::take(&mut instances[0].trunk_grads)
        } else {
            shared.reduced.into_pairs()?
        };
        Ok(MultiTrainingOutputs {
            loss,
            instances,
            trunk_grads,
            new_trunk: shared.new_trunk.into_pairs()?,
        })
    }

    /// Consume a completed pipelined run into its outputs: per-step mean
    /// losses, the final parameters (ring version K), and the ring's peak
    /// depth. Errors if any head never retired or a final slot is unwritten.
    pub fn into_pipeline_outputs(self) -> Result<PipelineOutputs> {
        let pipe = self.pipe.ok_or_else(|| {
            anyhow!("not a pipelined run (use MultiExecState::initial_train_pipeline)")
        })?;
        let (k, m, n_layers) = (pipe.k_steps, pipe.micro, pipe.n_layers);
        let mut losses = vec![0.0f64; k];
        let mut grad_sq = vec![0.0f64; k];
        let sq = |t: &Tensor| {
            let n = t.l2_norm();
            n * n
        };
        for (gi, inst) in self.insts.into_iter().enumerate() {
            let train =
                inst.train.ok_or_else(|| anyhow!("instance {gi}: missing training state"))?;
            let head = train
                .head
                .as_ref()
                .ok_or_else(|| anyhow!("instance {gi}: head task never retired"))?;
            losses[gi / m] += head.loss;
            if m == 1 {
                // no ReduceGrad tasks: the lone instance's gradients ARE the
                // reduced set (trunk + opening slots here, head in HeadOut)
                let acc = &mut grad_sq[gi];
                for slot in 0..=n_layers {
                    let (dw, db) = train.grads.get(slot).ok_or_else(|| {
                        anyhow!("instance {gi}: gradient slot {slot} never filled")
                    })?;
                    *acc += sq(dw) + sq(db);
                }
                *acc += sq(&head.dw_fc) + sq(&head.db_fc);
            }
        }
        for l in &mut losses {
            *l /= m as f64;
        }
        if m > 1 {
            for (step, slots) in pipe.reduced.iter().enumerate() {
                let acc = &mut grad_sq[step];
                for (slot, pair) in slots.iter().enumerate() {
                    let (dw, db) = pair.as_ref().ok_or_else(|| {
                        anyhow!("step {step}: reduced gradient slot {slot} never filled")
                    })?;
                    *acc += sq(dw) + sq(db);
                }
            }
        }
        let grad_norms: Vec<f64> = grad_sq.iter().map(|s| s.sqrt()).collect();
        let mut trunk = Vec::with_capacity(n_layers);
        for slot in 0..n_layers {
            let (w, b) = pipe.ring.get(k, slot)?;
            trunk.push(((*w).clone(), (*b).clone()));
        }
        let (w_open, b_open) = pipe.ring.get(k, n_layers)?;
        let (w_fc, b_fc) = pipe.ring.get(k, n_layers + 1)?;
        Ok(PipelineOutputs {
            losses,
            grad_norms,
            params: NetParams {
                w_open: (*w_open).clone(),
                b_open: (*b_open).clone(),
                trunk,
                w_fc: (*w_fc).clone(),
                b_fc: (*b_fc).clone(),
            },
            peak_ring_depth: pipe.ring.peak_depth(),
        })
    }

    /// Serialize the complete live state for a checkpoint
    /// ([`crate::coordinator::checkpoint::SessionSnapshot`]). Every tensor is
    /// written value-complete through the exact-roundtrip f32 path, so a
    /// resumed run computes on bit-identical inputs; `Arc` sharing between
    /// slots is not preserved (resume re-allocates each slot independently),
    /// which changes memory footprint but never values.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("insts", Json::Arr(self.insts.iter().map(inst_to_json).collect())),
            (
                "shared",
                match &self.shared {
                    None => Json::Null,
                    Some(s) => shared_to_json(s),
                },
            ),
            (
                "pipe",
                match &self.pipe {
                    None => Json::Null,
                    Some(p) => pipe_to_json(p),
                },
            ),
        ])
    }

    /// Rebuild live state from [`MultiExecState::to_json`] output. `spec` is
    /// required for pipelined snapshots (the net spec is code configuration,
    /// not state, so the resuming caller re-supplies it) and ignored
    /// otherwise.
    pub fn from_json(j: &Json, spec: Option<Arc<NetSpec>>) -> Result<MultiExecState> {
        let insts = j
            .get("insts")?
            .as_arr()?
            .iter()
            .map(inst_from_json)
            .collect::<Result<Vec<_>>>()?;
        let shared = match j.get("shared")? {
            Json::Null => None,
            s => Some(shared_from_json(s)?),
        };
        let pipe = match j.get("pipe")? {
            Json::Null => None,
            p => {
                let spec = spec
                    .ok_or_else(|| anyhow!("pipelined snapshot needs the net spec to resume"))?;
                Some(pipe_from_json(p, spec)?)
            }
        };
        Ok(MultiExecState { insts, shared, pipe })
    }
}

// ---------------------------------------------------------------------------
// checkpoint serialization of the live state (executor-private structure;
// tensor/params primitives live in `coordinator::checkpoint`)
// ---------------------------------------------------------------------------

fn opt_tensor_json(t: &Option<Arc<Tensor>>) -> Json {
    match t {
        None => Json::Null,
        Some(t) => tensor_to_json(t),
    }
}

fn opt_tensor_from(j: &Json) -> Result<Option<Arc<Tensor>>> {
    match j {
        Json::Null => Ok(None),
        t => Ok(Some(Arc::new(tensor_from_json(t)?))),
    }
}

fn opt_pair_json(p: &Option<(Tensor, Tensor)>) -> Json {
    match p {
        None => Json::Null,
        Some(p) => pair_to_json(p),
    }
}

fn opt_pair_from(j: &Json) -> Result<Option<(Tensor, Tensor)>> {
    match j {
        Json::Null => Ok(None),
        p => pair_from_json(p).map(Some),
    }
}

fn slots_to_json(s: &TrunkGradSlots) -> Json {
    Json::Arr(
        (0..s.len())
            .map(|i| match s.get(i) {
                None => Json::Null,
                Some(p) => pair_to_json(p),
            })
            .collect(),
    )
}

fn slots_from_json(j: &Json) -> Result<TrunkGradSlots> {
    let a = j.as_arr()?;
    let mut s = TrunkGradSlots::new(a.len());
    for (i, e) in a.iter().enumerate() {
        if !matches!(e, Json::Null) {
            let (w, b) = pair_from_json(e)?;
            s.set(i, w, b)?;
        }
    }
    Ok(s)
}

fn sys_to_json(s: &SysState) -> Json {
    let lvl_opt = |lvl: &Vec<Option<Arc<Tensor>>>| Json::Arr(lvl.iter().map(opt_tensor_json).collect());
    json::obj(vec![
        (
            "u",
            Json::Arr(
                s.u.iter()
                    .map(|lvl| Json::Arr(lvl.iter().map(|t| tensor_to_json(t)).collect()))
                    .collect(),
            ),
        ),
        (
            "g",
            Json::Arr(
                s.g.iter()
                    .map(|lvl| match lvl {
                        None => Json::Null,
                        Some(v) => Json::Arr(v.iter().map(|t| tensor_to_json(t)).collect()),
                    })
                    .collect(),
            ),
        ),
        ("r", Json::Arr(s.r.iter().map(lvl_opt).collect())),
        ("inj", Json::Arr(s.inj.iter().map(lvl_opt).collect())),
    ])
}

fn sys_from_json(j: &Json) -> Result<SysState> {
    let u = j
        .get("u")?
        .as_arr()?
        .iter()
        .map(|lvl| -> Result<Vec<Arc<Tensor>>> {
            lvl.as_arr()?.iter().map(|t| tensor_from_json(t).map(Arc::new)).collect()
        })
        .collect::<Result<Vec<_>>>()?;
    let g = j
        .get("g")?
        .as_arr()?
        .iter()
        .map(|lvl| -> Result<Option<Vec<Arc<Tensor>>>> {
            match lvl {
                Json::Null => Ok(None),
                v => Ok(Some(
                    v.as_arr()?
                        .iter()
                        .map(|t| tensor_from_json(t).map(Arc::new))
                        .collect::<Result<Vec<_>>>()?,
                )),
            }
        })
        .collect::<Result<Vec<_>>>()?;
    let opt_lvl = |lvl: &Json| -> Result<Vec<Option<Arc<Tensor>>>> {
        lvl.as_arr()?.iter().map(opt_tensor_from).collect()
    };
    let r = j.get("r")?.as_arr()?.iter().map(&opt_lvl).collect::<Result<Vec<_>>>()?;
    let inj = j.get("inj")?.as_arr()?.iter().map(&opt_lvl).collect::<Result<Vec<_>>>()?;
    Ok(SysState { u, g, r, inj })
}

fn train_to_json(t: &TrainState) -> Json {
    json::obj(vec![
        ("labels", Json::Arr(t.labels.iter().map(|&l| json::num(l as f64)).collect())),
        ("grads", slots_to_json(&t.grads)),
        (
            "head",
            match &t.head {
                None => Json::Null,
                Some(h) => json::obj(vec![
                    ("loss", json::num(h.loss)),
                    ("dw_fc", tensor_to_json(&h.dw_fc)),
                    ("db_fc", tensor_to_json(&h.db_fc)),
                ]),
            },
        ),
    ])
}

fn train_from_json(j: &Json) -> Result<TrainState> {
    let labels = j
        .get("labels")?
        .as_arr()?
        .iter()
        .map(|l| -> Result<i32> {
            let f = l.as_f64()?;
            anyhow::ensure!(f.fract() == 0.0, "label {f} is not an integer");
            Ok(f as i32)
        })
        .collect::<Result<Vec<_>>>()?;
    let head = match j.get("head")? {
        Json::Null => None,
        h => Some(HeadOut {
            loss: h.get("loss")?.as_f64()?,
            dw_fc: tensor_from_json(h.get("dw_fc")?)?,
            db_fc: tensor_from_json(h.get("db_fc")?)?,
        }),
    };
    Ok(TrainState { labels, grads: slots_from_json(j.get("grads")?)?, head })
}

fn inst_to_json(i: &ExecState) -> Json {
    json::obj(vec![
        ("pri", sys_to_json(&i.pri)),
        (
            "adj",
            match &i.adj {
                None => Json::Null,
                Some(s) => sys_to_json(s),
            },
        ),
        (
            "train",
            match &i.train {
                None => Json::Null,
                Some(t) => train_to_json(t),
            },
        ),
    ])
}

fn inst_from_json(j: &Json) -> Result<ExecState> {
    Ok(ExecState {
        pri: sys_from_json(j.get("pri")?)?,
        adj: match j.get("adj")? {
            Json::Null => None,
            s => Some(sys_from_json(s)?),
        },
        train: match j.get("train")? {
            Json::Null => None,
            t => Some(train_from_json(t)?),
        },
    })
}

fn shared_to_json(s: &SharedTrain) -> Json {
    json::obj(vec![
        ("params", params_to_json(&s.params)),
        ("lr", json::num(s.lr as f64)),
        (
            "nodes",
            Json::Arr(
                s.nodes
                    .iter()
                    .map(|l| Json::Arr(l.iter().map(opt_pair_json).collect()))
                    .collect(),
            ),
        ),
        ("reduced", slots_to_json(&s.reduced)),
        ("new_trunk", slots_to_json(&s.new_trunk)),
    ])
}

fn shared_from_json(j: &Json) -> Result<SharedTrain> {
    Ok(SharedTrain {
        params: Arc::new(params_from_json(j.get("params")?)?),
        lr: j.get("lr")?.as_f64()? as f32,
        nodes: j
            .get("nodes")?
            .as_arr()?
            .iter()
            .map(|l| -> Result<Vec<Option<(Tensor, Tensor)>>> {
                l.as_arr()?.iter().map(opt_pair_from).collect()
            })
            .collect::<Result<Vec<_>>>()?,
        reduced: slots_from_json(j.get("reduced")?)?,
        new_trunk: slots_from_json(j.get("new_trunk")?)?,
    })
}

fn pipe_to_json(p: &PipeShared) -> Json {
    json::obj(vec![
        ("lr", json::num(p.lr as f64)),
        ("micro", json::num(p.micro as f64)),
        ("staleness", json::num(p.staleness as f64)),
        ("k_steps", json::num(p.k_steps as f64)),
        ("n_layers", json::num(p.n_layers as f64)),
        ("ring", p.ring.to_json()),
        (
            "nodes",
            Json::Arr(
                p.nodes
                    .iter()
                    .map(|step| {
                        Json::Arr(
                            step.iter()
                                .map(|slot| Json::Arr(slot.iter().map(opt_pair_json).collect()))
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        ),
        (
            "reduced",
            Json::Arr(
                p.reduced
                    .iter()
                    .map(|step| Json::Arr(step.iter().map(opt_pair_json).collect()))
                    .collect(),
            ),
        ),
        ("inputs", Json::Arr(p.inputs.iter().map(|t| tensor_to_json(t)).collect())),
    ])
}

fn pipe_from_json(j: &Json, spec: Arc<NetSpec>) -> Result<PipeShared> {
    Ok(PipeShared {
        spec,
        lr: j.get("lr")?.as_f64()? as f32,
        micro: j.get("micro")?.as_usize()?,
        staleness: j.get("staleness")?.as_usize()?,
        k_steps: j.get("k_steps")?.as_usize()?,
        n_layers: j.get("n_layers")?.as_usize()?,
        ring: SnapshotRing::from_json(j.get("ring")?)?,
        nodes: j
            .get("nodes")?
            .as_arr()?
            .iter()
            .map(|step| -> Result<Vec<Vec<Option<(Tensor, Tensor)>>>> {
                step.as_arr()?
                    .iter()
                    .map(|slot| -> Result<Vec<Option<(Tensor, Tensor)>>> {
                        slot.as_arr()?.iter().map(opt_pair_from).collect()
                    })
                    .collect()
            })
            .collect::<Result<Vec<_>>>()?,
        reduced: j
            .get("reduced")?
            .as_arr()?
            .iter()
            .map(|step| -> Result<Vec<Option<(Tensor, Tensor)>>> {
                step.as_arr()?.iter().map(opt_pair_from).collect()
            })
            .collect::<Result<Vec<_>>>()?,
        inputs: j
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(|t| tensor_from_json(t).map(Arc::new))
            .collect::<Result<Vec<_>>>()?,
    })
}

/// Typed result of one kernel task (the payload of [`JobDone`]).
#[derive(Debug)]
pub enum TaskOut {
    /// A single state/residual/rhs tensor.
    State(Tensor),
    /// The states of a fused F-span (`BlockRun`), in point order.
    States(Vec<Tensor>),
    /// A (weight, bias)-shaped pair: a layer gradient, a reduction-tree
    /// partial sum, or updated parameters.
    Pair(Tensor, Tensor),
    /// Head forward + VJP output.
    Head {
        /// Micro-batch loss.
        loss: f64,
        /// ∂loss/∂u^N (seeds the adjoint system).
        du: Tensor,
        /// Head weight gradient.
        dw_fc: Tensor,
        /// Head bias gradient.
        db_fc: Tensor,
    },
}

/// One retired kernel task on the live executor, tagged with its graph
/// instance — the record behind the cross-instance overlap assertions
/// (pool-clock timestamps, same clock as the stream trace).
#[derive(Debug, Clone)]
pub struct ExecEvent {
    /// Graph task id.
    pub task: usize,
    /// Graph instance the task belonged to.
    pub instance: usize,
    /// Device (worker) that executed it.
    pub device: usize,
    /// Phase label.
    pub label: &'static str,
    /// Start timestamp (pool clock, seconds).
    pub t_start: f64,
    /// End timestamp (pool clock, seconds).
    pub t_end: f64,
}

/// A typed executor failure the recovery layer could not absorb: surfaced
/// through `anyhow` so callers can `downcast_ref::<ExecError>()` for the
/// structured payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecError {
    /// A worker thread died with a task on it and no surviving worker could
    /// take the re-execution (single-device pool, or retry budget spent).
    /// Before the recovery layer existed this scenario *hung* the scheduler
    /// forever: the dead worker never sent a completion and the executor's
    /// own `Sender` clone kept the channel open, so the blocking `recv`
    /// never saw a disconnect.
    WorkerLost {
        /// Graph task id that was in flight on the dead worker.
        task: usize,
        /// Worker (device) index that died.
        worker: usize,
    },
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::WorkerLost { task, worker } => {
                write!(f, "worker {worker} lost with task {task} in flight and no recovery path")
            }
        }
    }
}

impl std::error::Error for ExecError {}

/// One recovery re-dispatch: task `task` moved from `from_device` to
/// `to_device` on attempt `attempt` after the executor slept `backoff_s`.
/// Accumulated in [`ExecReport::retries`] — the audit trail every
/// fault-injection test asserts on.
#[derive(Debug, Clone)]
pub struct RetryEvent {
    /// Graph task id that was re-dispatched.
    pub task: usize,
    /// Phase label of the task.
    pub label: &'static str,
    /// Retry attempt number (1-based; 0 marks a dead-device reroute at
    /// *first* dispatch, which spends no retry budget).
    pub attempt: usize,
    /// Device the failed/unroutable dispatch targeted.
    pub from_device: usize,
    /// Surviving device the task was re-dispatched to.
    pub to_device: usize,
    /// Backoff slept before the re-dispatch, seconds.
    pub backoff_s: f64,
}

/// Re-execution budget per task: first dispatch + `MAX_RETRIES` retries.
const MAX_RETRIES: usize = 2;
/// Base of the exponential retry backoff (`BACKOFF_BASE_S · 2^(attempt−1)`).
const BACKOFF_BASE_S: f64 = 0.0005;
/// Poll granularity of the completion wait: every expiry runs a worker
/// liveness sweep so a silently-dead worker surfaces in bounded time.
const LIVENESS_POLL: Duration = Duration::from_millis(20);

fn backoff_s(attempt: usize) -> f64 {
    BACKOFF_BASE_S * (1u64 << (attempt.saturating_sub(1)).min(20)) as f64
}

/// In-flight bookkeeping behind worker recovery. Keyed on task ids in a
/// `BTreeMap` so liveness sweeps visit lost tasks in deterministic (id)
/// order — recovery re-dispatch order is then a pure function of the fault,
/// never of map iteration order.
#[derive(Debug, Default)]
struct Recovery {
    /// Retries consumed per task id.
    attempts: BTreeMap<usize, usize>,
    /// Device each in-flight task was dispatched to.
    inflight_dev: BTreeMap<usize, usize>,
}

impl Recovery {
    fn dispatched(&mut self, id: usize, dev: usize) {
        self.inflight_dev.insert(id, dev);
    }

    /// Mark a completion and return the device the task actually ran on.
    fn completed(&mut self, id: usize) -> Option<usize> {
        self.inflight_dev.remove(&id)
    }

    /// In-flight tasks stranded on dead workers, in task-id order. Sound
    /// only when the completion channel is empty: a worker sends every
    /// completion before it can die on a later message, so dead worker +
    /// empty channel ⇒ its remaining in-flight tasks will never complete.
    fn lost_tasks<F: SolverFactory, P: WorkerPool<F>>(&self, pool: &P) -> Vec<(usize, usize)> {
        self.inflight_dev
            .iter()
            .filter(|(_, &dev)| !pool.worker_alive(dev))
            .map(|(&id, &dev)| (id, dev))
            .collect()
    }

    /// Consume one unit of task `id`'s retry budget; `None` when spent.
    fn next_attempt(&mut self, id: usize) -> Option<usize> {
        let a = self.attempts.entry(id).or_insert(0);
        if *a >= MAX_RETRIES {
            None
        } else {
            *a += 1;
            Some(*a)
        }
    }
}

/// First alive device scanning cyclically from `from` (inclusive), so a
/// task whose planned worker survives stays put and a dead worker's load
/// spills deterministically onto its successor.
fn pick_alive_device<F: SolverFactory, P: WorkerPool<F>>(pool: &P, from: usize) -> Option<usize> {
    let n = pool.n_workers();
    (0..n).map(|k| (from + k) % n).find(|&d| pool.worker_alive(d))
}

/// Aggregate record of one graph execution.
#[derive(Debug, Default, Clone)]
pub struct ExecReport {
    /// Transfers retired (state boundary crossings + gradient hops).
    pub comm_events: usize,
    /// How many of those carried a layer *state* (their real size is the
    /// live activation tensor; the driver prices them from `u0`).
    pub comm_state_events: usize,
    /// Bytes of *gradient* transfers (reduction-tree hops). Gradients are
    /// parameter-shaped — batch-independent — so the graph annotation is
    /// exact and summed here directly.
    pub comm_grad_bytes: f64,
    /// Kernel tasks executed.
    pub kernels: usize,
    /// Φ/Ψ applications performed (the solve's work measure).
    pub phi_evals: usize,
    /// Per-label worker-busy seconds, in first-seen order.
    pub phase_s: Vec<(&'static str, f64)>,
    /// Instance-tagged kernel completions, in retirement order.
    pub events: Vec<ExecEvent>,
    /// Recovery re-dispatches (failed task retried, dead worker rerouted),
    /// in occurrence order — empty on a fault-free run.
    pub retries: Vec<RetryEvent>,
    /// Cross-node messages actually shipped through the live
    /// [`super::transport::Transport`] (sharded [`NodePools`] substrate
    /// only; always 0 on the shared single-pool path, where every device
    /// maps to node 0).
    pub transport_msgs: usize,
    /// Wire bytes of those messages (encoded tensor payloads, header
    /// included).
    pub transport_bytes: f64,
}

impl ExecReport {
    fn add_phase(&mut self, label: &'static str, secs: f64) {
        merge_phases(&mut self.phase_s, &[(label, secs)]);
    }
}

/// Phase label of a kernel task (`"comm"` for transfers — only reachable on
/// malformed recovery paths, never on a validated graph).
fn kernel_label(graph: &TaskGraph, id: usize) -> &'static str {
    match &graph.tasks[id].kind {
        TaskKind::Kernel { label, .. } => label,
        TaskKind::Comm { .. } => "comm",
    }
}

/// Spend one retry and pick the surviving target for a failed task:
/// `(to_device, attempt, backoff_s)`. [`ExecError::WorkerLost`] when the
/// budget is spent or no worker survives.
fn plan_retry<F: SolverFactory, P: WorkerPool<F>>(
    pool: &P,
    rec: &mut Recovery,
    id: usize,
    from: usize,
) -> Result<(usize, usize, f64)> {
    let attempt =
        rec.next_attempt(id).ok_or(ExecError::WorkerLost { task: id, worker: from })?;
    let to = pick_alive_device::<F, P>(pool, from)
        .ok_or(ExecError::WorkerLost { task: id, worker: from })?;
    Ok((to, attempt, backoff_s(attempt)))
}

/// Resolve a task's dispatch device: its planned device if that worker is
/// alive, else the deterministic reroute target (recorded as an attempt-0
/// [`RetryEvent`] — no retry budget spent, the task never ran).
fn route_dispatch<F: SolverFactory, P: WorkerPool<F>>(
    pool: &P,
    report: &mut ExecReport,
    id: usize,
    label: &'static str,
    want: usize,
) -> Result<usize> {
    if pool.worker_alive(want) {
        return Ok(want);
    }
    let to = pick_alive_device::<F, P>(pool, want)
        .ok_or(ExecError::WorkerLost { task: id, worker: want })?;
    report.retries.push(RetryEvent {
        task: id,
        label,
        attempt: 0,
        from_device: want,
        to_device: to,
        backoff_s: 0.0,
    });
    Ok(to)
}

/// Account one ready Comm task's inline retirement: a transfer feeding a
/// `ReduceGrad` carries a gradient (parameter-shaped — the graph bytes are
/// exact); everything else is a layer-state crossing priced by the driver.
/// Shared by [`execute`] and [`ExecSession`] so the two schedulers can never
/// drift in their traffic ledgers.
fn account_comm(
    report: &mut ExecReport,
    graph: &TaskGraph,
    dependents: &[Vec<usize>],
    id: usize,
) {
    // a placement policy may co-locate a transfer's endpoints — the hop
    // degenerates to a local slot handoff and leaves the traffic ledger
    // (graphs built against the static Partition map never carry src == dst)
    if let TaskKind::Comm { src, dst, .. } = &graph.tasks[id].kind {
        if src == dst {
            return;
        }
    }
    report.comm_events += 1;
    let feeds_reduce = dependents[id]
        .iter()
        .any(|&d| matches!(graph.tasks[d].op, Some(TaskOp::ReduceGrad { .. })));
    if feeds_reduce {
        if let TaskKind::Comm { bytes, .. } = &graph.tasks[id].kind {
            report.comm_grad_bytes += *bytes;
        }
    } else {
        report.comm_state_events += 1;
    }
}

/// Account one completed kernel: Φ-evaluation count per op, the per-label
/// phase ledger, and the instance-tagged event record. Shared by
/// [`execute`] and [`ExecSession`].
#[allow(clippy::too_many_arguments)]
fn account_kernel(
    report: &mut ExecReport,
    op: TaskOp,
    task: usize,
    instance: usize,
    device: usize,
    label: &'static str,
    t_start: f64,
    t_end: f64,
) {
    match op {
        TaskOp::PointUpdate { .. } | TaskOp::Residual { .. } | TaskOp::Restrict { .. } => {
            report.phi_evals += 1;
        }
        TaskOp::BlockRun { j_first, j_last, .. } => {
            report.phi_evals += j_last - j_first + 1;
        }
        _ => {}
    }
    report.kernels += 1;
    report.add_phase(label, t_end - t_start);
    report.events.push(ExecEvent { task, instance, device, label, t_start, t_end });
}

/// Per-node ready heaps — the sharded counterpart of the single global
/// ready heap. `push` routes a task by the node of its planned device, so
/// building node A's frontier never touches node B's heap (the per-pool
/// dispatch queues of the `NodePools` substrate); `pop` returns the
/// globally best key (max priority, min-id ties) by comparing heap heads.
/// [`ReadyKey`]s are unique per task, so the pop sequence is exactly the
/// single-heap sequence and the executor's dispatch order — hence its
/// output — is unchanged by sharding. With one node this degenerates to
/// the legacy single heap.
struct ReadyQueues {
    heaps: Vec<BinaryHeap<ReadyKey>>,
}

impl ReadyQueues {
    fn new(n_nodes: usize) -> ReadyQueues {
        ReadyQueues { heaps: (0..n_nodes.max(1)).map(|_| BinaryHeap::new()).collect() }
    }

    fn push(&mut self, node: usize, key: ReadyKey) {
        let last = self.heaps.len() - 1;
        self.heaps[node.min(last)].push(key);
    }

    fn pop(&mut self) -> Option<ReadyKey> {
        let best = self
            .heaps
            .iter()
            .enumerate()
            .filter_map(|(i, h)| h.peek().map(|k| (i, *k)))
            .max_by(|(_, a), (_, b)| a.cmp(b))
            .map(|(i, _)| i)?;
        self.heaps[best].pop()
    }
}

/// Ship one tensor across the live transport (encode → send → recv →
/// decode), verifying the decoded copy bitwise against the original —
/// corruption is a typed error, never a silent numeric drift. Returns the
/// decoded tensor so cross-node state slots can be re-bound to the copy
/// that actually crossed the wire.
fn ship_slot<F: SolverFactory, P: WorkerPool<F>>(
    pool: &P,
    report: &mut ExecReport,
    src_node: usize,
    dst_node: usize,
    t: &Tensor,
) -> Result<Tensor> {
    let wire = encode_tensor(t);
    report.transport_msgs += 1;
    report.transport_bytes += wire.len() as f64;
    let back = pool.ship(src_node, dst_node, wire)?;
    let got = decode_tensor(&back)?;
    anyhow::ensure!(
        got.dims() == t.dims()
            && got.data().len() == t.data().len()
            && got.data().iter().zip(t.data()).all(|(a, b)| a.to_bits() == b.to_bits()),
        "transport corrupted a tensor shipped node {src_node} -> node {dst_node}"
    );
    Ok(got)
}

/// Materialize one retiring cross-node `Comm` edge as real transport
/// messages. Intra-node edges (and co-located `src == dst` hops) stay
/// `Arc` refcount bumps, exactly as before; a cross-node edge serializes
/// the producer's output slot(s), ships the bytes through the pool's
/// [`super::transport::Transport`], and re-binds the slot to the decoded
/// copy — the explicit serialize → send → deserialize path the simulator
/// prices as `message_time` per tier. Gradient edges (a `ReduceGrad`
/// consumer) and seed outputs (`Head`/`Opening`, whose single output `Arc`
/// aliases every adjoint/primal slot) ship verify-only: the bytes cross
/// the wire and are checked bitwise, but the aliased slots keep their
/// `Arc`s. On a shared single-pool substrate every device maps to node 0,
/// so this is a no-op and the run is bit-identical by construction.
#[allow(clippy::too_many_arguments)]
fn ship_comm<F: SolverFactory, P: WorkerPool<F>>(
    pool: &P,
    report: &mut ExecReport,
    hier: &Hierarchy,
    st: &mut MultiExecState,
    graph: &TaskGraph,
    dependents: &[Vec<usize>],
    producers: &[usize],
    id: usize,
) -> Result<()> {
    let TaskKind::Comm { src, dst, .. } = &graph.tasks[id].kind else {
        return Ok(());
    };
    let (sn, dn) = (pool.node_of(*src), pool.node_of(*dst));
    if *src == *dst || sn == dn {
        return Ok(()); // loopback / intra-node: the slot handoff stays local
    }
    let feeds_reduce = dependents[id]
        .iter()
        .any(|&d| matches!(graph.tasks[d].op, Some(TaskOp::ReduceGrad { .. })));
    if feeds_reduce {
        // gradient hop: ship the (w, b) pair the consumer will read — the
        // exact operand `dispatch_kernel` resolves via `grad_src[_pipe]`.
        // Gradient slots live in shared reduction-tree state, so the ship
        // is verify-only (the consumer re-reads the same slot).
        for &d in &dependents[id] {
            let Some(TaskOp::ReduceGrad { layer, rhs, .. }) = graph.tasks[d].op else {
                continue;
            };
            let (gw, gb) = if let Some(pipe) = &st.pipe {
                let step = graph.tasks[d].instance / pipe.micro;
                st.grad_src_pipe(step, layer, rhs)?
            } else {
                st.grad_src(layer, rhs)?
            };
            ship_slot::<F, P>(pool, report, sn, dn, &gw)?;
            ship_slot::<F, P>(pool, report, sn, dn, &gb)?;
        }
        return Ok(());
    }
    // state hop: locate the producer's output slot(s) — the same slots
    // `apply_output` wrote, which the WAR edges behind this Comm's
    // consumers guarantee still hold exactly the producer's output — ship
    // each, and re-bind the slot to the decoded copy.
    let c = hier.coarsen;
    for &p in producers {
        let ki = graph.tasks[p].instance;
        match graph.tasks[p].op {
            Some(TaskOp::PointUpdate { sys, level, j }) => {
                let t = st.inst(ki)?.sys(sys)?.u[level][j].clone();
                let got = ship_slot::<F, P>(pool, report, sn, dn, &t)?;
                st.inst_mut(ki)?.sys_mut(sys)?.u[level][j] = Arc::new(got);
            }
            Some(TaskOp::BlockRun { sys, level, j_first, j_last }) => {
                for j in j_first..=j_last {
                    let t = st.inst(ki)?.sys(sys)?.u[level][j].clone();
                    let got = ship_slot::<F, P>(pool, report, sn, dn, &t)?;
                    st.inst_mut(ki)?.sys_mut(sys)?.u[level][j] = Arc::new(got);
                }
            }
            Some(TaskOp::Residual { sys, level, j }) => {
                if let Some(t) = st.inst(ki)?.sys(sys)?.r[level][j].clone() {
                    let got = ship_slot::<F, P>(pool, report, sn, dn, &t)?;
                    st.inst_mut(ki)?.sys_mut(sys)?.r[level][j] = Some(Arc::new(got));
                }
            }
            Some(TaskOp::Restrict { sys, level, j }) => {
                let t = st.inst(ki)?.sys(sys)?.g[level + 1].as_ref().map(|g| g[j].clone());
                if let Some(t) = t {
                    let got = ship_slot::<F, P>(pool, report, sn, dn, &t)?;
                    if let Some(g) = st.inst_mut(ki)?.sys_mut(sys)?.g[level + 1].as_mut() {
                        g[j] = Arc::new(got);
                    }
                }
            }
            Some(TaskOp::Correct { sys, level, j }) => {
                let t = st.inst(ki)?.sys(sys)?.u[level][j * c].clone();
                let got = ship_slot::<F, P>(pool, report, sn, dn, &t)?;
                st.inst_mut(ki)?.sys_mut(sys)?.u[level][j * c] = Arc::new(got);
            }
            Some(TaskOp::Head) => {
                // the head's ∂loss/∂u^N seed aliases every adjoint slot —
                // ship verify-only to keep the aliasing intact
                let t = st.inst(ki)?.sys(Sys::Adjoint)?.u[0][0].clone();
                ship_slot::<F, P>(pool, report, sn, dn, &t)?;
            }
            Some(TaskOp::Opening) => {
                let t = st.inst(ki)?.sys(Sys::Primal)?.u[0][0].clone();
                ship_slot::<F, P>(pool, report, sn, dn, &t)?;
            }
            _ => {
                // gradient/parameter producers (shared slots, re-read by
                // their consumers) and admission-seeded inputs (no producer
                // task) are staged host-side — nothing to ship
            }
        }
    }
    Ok(())
}

/// Execute `graph` on `pool`, mutating `st` in place. `st` must carry at
/// least as many instances as the graph references. Dispatches in the
/// legacy min-id order (equivalent to all-zero priorities).
pub fn execute<F: SolverFactory, P: WorkerPool<F>>(
    pool: &P,
    hier: &Hierarchy,
    graph: &TaskGraph,
    st: &mut MultiExecState,
) -> Result<ExecReport>
where
    F::Solver: NetExecutor,
{
    execute_prioritized::<F, P>(pool, hier, graph, st, None)
}

/// [`execute`] under a placement policy's dispatch priorities (indexed by
/// task id; higher dispatches first, ties by lowest id — the vector a
/// `coordinator::placement::Placement` carries alongside its rewritten
/// graph). `None` means all-zero: the legacy min-id order, bit-for-bit.
pub fn execute_prioritized<F: SolverFactory, P: WorkerPool<F>>(
    pool: &P,
    hier: &Hierarchy,
    graph: &TaskGraph,
    st: &mut MultiExecState,
    priority: Option<&[f64]>,
) -> Result<ExecReport>
where
    F::Solver: NetExecutor,
{
    let n = graph.tasks.len();
    let mut report = ExecReport::default();
    if n == 0 {
        return Ok(report);
    }
    let mut indeg = vec![0usize; n];
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for t in &graph.tasks {
        if t.instance >= st.insts.len() {
            bail!(
                "task {} targets instance {} but the state has {} instance(s)",
                t.id,
                t.instance,
                st.insts.len()
            );
        }
        indeg[t.id] = t.deps.len();
        for &d in &t.deps {
            dependents[d].push(t.id);
        }
    }
    if let Some(p) = priority {
        anyhow::ensure!(
            p.len() == n,
            "priority vector length {} != task count {n}",
            p.len()
        );
    }
    let pri = |id: usize| priority.map_or(0.0, |p| p[id]);
    let (tx, rx) = channel::<JobDone<TaskOut>>();
    // per-node priority max-heaps with min-id ties: without a placement
    // pass the global pop order is the legacy min-id order — ready tasks of
    // earlier instances enter worker queues first, giving the micro-batch
    // pipeline its forward skew. With one node (the shared pool) this IS
    // the legacy single heap.
    let mut ready = ReadyQueues::new(pool.n_nodes());
    for t in graph.tasks.iter().filter(|t| t.deps.is_empty()) {
        ready.push(pool.node_of(t.device), ReadyKey { pri: pri(t.id), id: t.id });
    }
    let mut in_flight = 0usize;
    let mut retired = 0usize;
    let mut recovery = Recovery::default();

    while retired < n {
        // dispatch everything currently ready; Comm tasks retire inline
        while let Some(ReadyKey { id, .. }) = ready.pop() {
            let task = &graph.tasks[id];
            match &task.kind {
                TaskKind::Comm { .. } => {
                    account_comm(&mut report, graph, &dependents, id);
                    ship_comm::<F, P>(
                        pool,
                        &mut report,
                        hier,
                        st,
                        graph,
                        &dependents,
                        &graph.tasks[id].deps,
                        id,
                    )?;
                    retired += 1;
                    for &d in &dependents[id] {
                        indeg[d] -= 1;
                        if indeg[d] == 0 {
                            ready.push(
                                pool.node_of(graph.tasks[d].device),
                                ReadyKey { pri: pri(d), id: d },
                            );
                        }
                    }
                }
                TaskKind::Kernel { label, .. } => {
                    let dev = route_dispatch::<F, P>(pool, &mut report, id, *label, task.device)?;
                    dispatch_kernel::<F, P>(pool, hier, st, task, *label, dev, &tx)?;
                    recovery.dispatched(id, dev);
                    in_flight += 1;
                }
            }
        }
        if retired == n {
            break;
        }
        if in_flight == 0 {
            bail!("executor stalled with {retired}/{n} tasks retired (cyclic dependencies?)");
        }
        // bounded-poll receive: every expiry sweeps worker liveness so a
        // silently-dead worker surfaces as recovery (or WorkerLost) in
        // bounded time instead of blocking forever
        let done = loop {
            match rx.recv_timeout(LIVENESS_POLL) {
                Ok(d) => break d,
                Err(RecvTimeoutError::Timeout) => {
                    let lost = recovery.lost_tasks::<F, P>(pool);
                    if lost.is_empty() {
                        continue;
                    }
                    // a worker sends every completion before it can die on a
                    // later message — confirm the channel is empty before
                    // declaring its in-flight tasks lost
                    match rx.try_recv() {
                        Ok(d) => break d,
                        Err(TryRecvError::Empty) => {
                            for (id, dev) in lost {
                                in_flight -= 1;
                                recovery.completed(id);
                                let label = kernel_label(graph, id);
                                let (to, attempt, backoff) =
                                    plan_retry::<F, P>(pool, &mut recovery, id, dev)?;
                                std::thread::sleep(Duration::from_secs_f64(backoff));
                                report.retries.push(RetryEvent {
                                    task: id,
                                    label,
                                    attempt,
                                    from_device: dev,
                                    to_device: to,
                                    backoff_s: backoff,
                                });
                                dispatch_kernel::<F, P>(
                                    pool, hier, st, &graph.tasks[id], label, to, &tx,
                                )?;
                                recovery.dispatched(id, to);
                                in_flight += 1;
                            }
                        }
                        Err(TryRecvError::Disconnected) => {
                            bail!("stream pool shut down with tasks in flight")
                        }
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    bail!("stream pool shut down with tasks in flight")
                }
            }
        };
        in_flight -= 1;
        let from = recovery.completed(done.id).unwrap_or(graph.tasks[done.id].device);
        let out = match done.result {
            Ok(o) => o,
            Err(e) => {
                // failed jobs write no outputs and hazard edges admit any
                // topological order, so a re-execution is bit-identical —
                // retry on a surviving worker with exponential backoff
                let (to, attempt, backoff) = plan_retry::<F, P>(pool, &mut recovery, done.id, from)
                    .map_err(|lost| lost.context(format!("task {} ({}): {e:#}", done.id, done.label)))?;
                std::thread::sleep(Duration::from_secs_f64(backoff));
                report.retries.push(RetryEvent {
                    task: done.id,
                    label: done.label,
                    attempt,
                    from_device: from,
                    to_device: to,
                    backoff_s: backoff,
                });
                dispatch_kernel::<F, P>(pool, hier, st, &graph.tasks[done.id], done.label, to, &tx)?;
                recovery.dispatched(done.id, to);
                in_flight += 1;
                continue;
            }
        };
        let task = &graph.tasks[done.id];
        let op = task
            .op
            .ok_or_else(|| anyhow!("completed task {} has no payload", done.id))?;
        apply_output(hier, st, task.instance, op, out)?;
        account_kernel(
            &mut report,
            op,
            done.id,
            task.instance,
            task.device,
            done.label,
            done.t_start,
            done.t_end,
        );
        retired += 1;
        for &d in &dependents[done.id] {
            indeg[d] -= 1;
            if indeg[d] == 0 {
                ready.push(pool.node_of(graph.tasks[d].device), ReadyKey { pri: pri(d), id: d });
            }
        }
    }
    Ok(report)
}

/// An **incremental** executor session: the dynamic-admission counterpart of
/// [`execute`], built for serving workloads where the instance set is not
/// known up front.
///
/// Where [`execute`] runs one fixed graph to completion, a session holds a
/// *growing* union graph plus its scheduler state (in-degrees, ready heap,
/// in-flight jobs) across calls:
///
/// - [`ExecSession::admit`] splices a fresh single-instance graph (e.g. a
///   forward-only `mgrit::taskgraph::mg_forward_with` request) into the union
///   frontier *while earlier instances are still in flight* — continuous
///   batching, no generation barrier;
/// - [`ExecSession::wait`] blocks (optionally bounded) for one kernel
///   completion, writes it back, and dispatches newly-ready work;
/// - [`ExecSession::poll_finished`] yields instances whose every task has
///   retired, in completion order, so the caller can harvest the output
///   ([`ExecSession::final_state`]) and free the slots
///   ([`ExecSession::release_instance`]) — making instance lifetime fully
///   dynamic.
///
/// Admitted graphs must be self-contained (no cross-instance dependencies):
/// ordering *between* requests is the scheduler's job, expressed by when the
/// caller admits, never by graph edges. The dispatch/retire semantics are
/// shared with [`execute`] (same `dispatch_kernel` / `apply_output`), so a
/// session run is bit-identical to running each instance's graph alone.
///
/// An instance is not necessarily one request: the serving scheduler's
/// shape-batching policy coalesces several same-shape requests into ONE
/// admitted instance whose `u0` carries the summed leading dimension
/// (`Tensor::concat_batch` before [`ExecSession::admit`]). The session is
/// agnostic — every op is elementwise in the batch dimension — and the
/// caller fans [`ExecSession::final_state`] back out to per-request outputs
/// with `Tensor::slice_batch` at retire time (`serving::runtime`).
pub struct ExecSession<'a, F: SolverFactory, P: WorkerPool<F> = StreamPool<F>>
where
    F::Solver: NetExecutor,
{
    pool: &'a P,
    hier: &'a Hierarchy,
    st: MultiExecState,
    graph: TaskGraph,
    indeg: Vec<usize>,
    dependents: Vec<Vec<usize>>,
    /// Per-task dispatch priority over the union graph (zero unless the
    /// instance was admitted via [`ExecSession::admit_prioritized`]).
    priority: Vec<f64>,
    ready: ReadyQueues,
    /// Producer lists of unretired `Comm` tasks, captured before dependency
    /// edges are moved into `indeg`/`dependents` at admission — the ship
    /// path (`ship_comm`) needs them to locate the slots a cross-node edge
    /// carries. Entries are removed as their Comm retires.
    comm_deps: BTreeMap<usize, Vec<usize>>,
    in_flight: usize,
    /// Unretired task count per instance; 0 ⇒ the instance is finished.
    remaining: Vec<usize>,
    /// Per-instance running max of its kernel completions' `t_end`
    /// (initialized to the admission clock): once the instance finishes,
    /// this IS the time its last task retired on a worker — the honest
    /// per-request completion timestamp, free of both the harvest-side work
    /// the caller does after polling and of cross-worker completion
    /// reordering on the channel.
    last_end: Vec<f64>,
    finished: VecDeque<usize>,
    tx: Sender<JobDone<TaskOut>>,
    rx: Receiver<JobDone<TaskOut>>,
    report: ExecReport,
    /// Kernel tasks currently executing per device (grown on demand).
    dev_inflight: Vec<usize>,
    /// EWMA of completed kernel durations (`t_end − t_start`, seconds) per
    /// device — the service-time half of [`ExecSession::device_occupancy`].
    dev_ewma_s: Vec<f64>,
    /// Worker-recovery bookkeeping (in-flight devices, retry budgets).
    recovery: Recovery,
    /// Retired-task mask over the union graph — the checkpoint frontier.
    done: Vec<bool>,
    /// Retired task count (`done.iter().filter(|d| **d).count()`).
    done_count: usize,
    /// While `true`, [`ExecSession::pump`] dispatches nothing: ready tasks
    /// stay queued so in-flight work can drain to a checkpointable quiescent
    /// state (`in_flight == 0` with a well-defined retired frontier).
    dispatch_paused: bool,
    // F appears only through the `P: WorkerPool<F>` bound, not in any field
    _factory: std::marker::PhantomData<fn() -> F>,
}

impl<'a, F: SolverFactory, P: WorkerPool<F>> ExecSession<'a, F, P>
where
    F::Solver: NetExecutor,
{
    /// An idle session over `pool`: no instances, no tasks.
    pub fn new(pool: &'a P, hier: &'a Hierarchy) -> ExecSession<'a, F, P> {
        let (tx, rx) = channel::<JobDone<TaskOut>>();
        ExecSession {
            pool,
            hier,
            st: MultiExecState::empty(),
            graph: TaskGraph::default(),
            indeg: Vec::new(),
            dependents: Vec::new(),
            priority: Vec::new(),
            ready: ReadyQueues::new(pool.n_nodes()),
            comm_deps: BTreeMap::new(),
            in_flight: 0,
            remaining: Vec::new(),
            last_end: Vec::new(),
            finished: VecDeque::new(),
            tx,
            rx,
            report: ExecReport::default(),
            dev_inflight: Vec::new(),
            dev_ewma_s: Vec::new(),
            recovery: Recovery::default(),
            done: Vec::new(),
            done_count: 0,
            dispatch_paused: false,
            _factory: std::marker::PhantomData,
        }
    }

    /// Estimated busy horizon per device, in seconds: in-flight kernel count
    /// × the device's EWMA kernel duration. A deliberately coarse heuristic —
    /// its only job is to be monotone in device load so that
    /// [`crate::coordinator::placement::plan_with_occupancy`] steers a
    /// concurrent admission away from devices that are already saturated,
    /// instead of planning every instance against an empty cluster.
    pub fn device_occupancy(&self, n_devices: usize) -> Vec<f64> {
        (0..n_devices)
            .map(|d| {
                let inflight = self.dev_inflight.get(d).copied().unwrap_or(0);
                let ewma = self.dev_ewma_s.get(d).copied().unwrap_or(0.0);
                inflight as f64 * ewma
            })
            .collect()
    }

    /// Admit one request: a fresh instance seeded with `u0`, running the
    /// self-contained executable graph `sub`. Its ready tasks dispatch
    /// immediately, interleaving with whatever is already in flight. Returns
    /// the instance index. Dispatches in the legacy min-id order (all-zero
    /// priorities).
    pub fn admit(&mut self, sub: TaskGraph, u0: &Tensor) -> Result<usize> {
        self.admit_inner(sub, u0, None)
    }

    /// [`ExecSession::admit`] under a placement policy's dispatch
    /// priorities (indexed by `sub`'s task ids — the vector a
    /// `coordinator::placement::Placement` carries alongside its rewritten
    /// graph, which should be the `sub` admitted here so the planned
    /// devices and the planned order travel together).
    pub fn admit_prioritized(
        &mut self,
        sub: TaskGraph,
        u0: &Tensor,
        priority: &[f64],
    ) -> Result<usize> {
        anyhow::ensure!(
            priority.len() == sub.tasks.len(),
            "priority vector length {} != task count {}",
            priority.len(),
            sub.tasks.len()
        );
        self.admit_inner(sub, u0, Some(priority))
    }

    fn admit_inner(
        &mut self,
        sub: TaskGraph,
        u0: &Tensor,
        priority: Option<&[f64]>,
    ) -> Result<usize> {
        anyhow::ensure!(
            sub.tasks.iter().all(|t| t.op.is_some()),
            "admitted graph must be fully executable (op on every task)"
        );
        sub.validate()?;
        let inst = self.st.push_instance(self.hier, u0);
        let n_sub = sub.tasks.len();
        let off = self.graph.append_instance(sub, inst, 0);
        self.indeg.resize(off + n_sub, 0);
        self.dependents.resize(off + n_sub, Vec::new());
        self.priority.resize(off + n_sub, 0.0);
        self.done.resize(off + n_sub, false);
        if let Some(p) = priority {
            self.priority[off..off + n_sub].copy_from_slice(p);
        }
        self.remaining.push(n_sub);
        self.last_end.push(self.pool.now());
        for id in off..off + n_sub {
            // the deps move into indeg/dependents; the session never reads
            // them again, so retired requests hold no dependency heap memory
            // (Comm producer lists alone are kept — the ship path reads
            // them once, at the Comm's retirement)
            let deps = std::mem::take(&mut self.graph.tasks[id].deps);
            self.indeg[id] = deps.len();
            if matches!(self.graph.tasks[id].kind, TaskKind::Comm { .. }) {
                self.comm_deps.insert(id, deps.clone());
            }
            for d in deps {
                self.dependents[d].push(id);
            }
        }
        if n_sub == 0 {
            self.finished.push_back(inst);
            return Ok(inst);
        }
        for id in off..off + n_sub {
            if self.indeg[id] == 0 {
                let node = self.pool.node_of(self.graph.tasks[id].device);
                self.ready.push(node, ReadyKey { pri: self.priority[id], id });
            }
        }
        self.pump()?;
        Ok(inst)
    }

    /// Dispatch everything currently ready; Comm tasks retire inline (local
    /// execution only accounts the transfer — same rule as [`execute`],
    /// through the shared `account_comm`). While dispatch is paused
    /// (checkpoint drain), ready tasks stay queued untouched.
    fn pump(&mut self) -> Result<()> {
        while !self.dispatch_paused {
            let Some(ReadyKey { id, .. }) = self.ready.pop() else { break };
            let is_comm = matches!(self.graph.tasks[id].kind, TaskKind::Comm { .. });
            if is_comm {
                account_comm(&mut self.report, &self.graph, &self.dependents, id);
                let producers = self.comm_deps.remove(&id).unwrap_or_default();
                ship_comm::<F, P>(
                    self.pool,
                    &mut self.report,
                    self.hier,
                    &mut self.st,
                    &self.graph,
                    &self.dependents,
                    &producers,
                    id,
                )?;
                self.retire(id);
            } else {
                let label = match &self.graph.tasks[id].kind {
                    TaskKind::Kernel { label, .. } => *label,
                    TaskKind::Comm { .. } => unreachable!("checked above"),
                };
                let dev = route_dispatch::<F, P>(
                    self.pool,
                    &mut self.report,
                    id,
                    label,
                    self.graph.tasks[id].device,
                )?;
                dispatch_kernel::<F, P>(
                    self.pool,
                    self.hier,
                    &mut self.st,
                    &self.graph.tasks[id],
                    label,
                    dev,
                    &self.tx,
                )?;
                self.recovery.dispatched(id, dev);
                self.in_flight += 1;
                if dev >= self.dev_inflight.len() {
                    self.dev_inflight.resize(dev + 1, 0);
                }
                self.dev_inflight[dev] += 1;
            }
        }
        Ok(())
    }

    /// Retire one task: per-instance completion bookkeeping plus dependent
    /// release. Admitted graphs are self-contained, so a task's dependent
    /// set is final by the time it retires. Dependency lists (the dominant
    /// per-task heap allocation) were already moved out at admission, and a
    /// released instance's tensors are freed by the caller — but the
    /// fixed-size `Task` records, per-instance bookkeeping entries, and the
    /// per-kernel `ExecReport::events` trace still grow with every request
    /// ever admitted, like any tracing executor. A session is sized for one
    /// serving drain; an indefinitely-lived server should start a fresh
    /// session per drain (what `serving::ServingRuntime::run` does).
    fn retire(&mut self, id: usize) {
        self.done[id] = true;
        self.done_count += 1;
        let inst = self.graph.tasks[id].instance;
        self.remaining[inst] -= 1;
        if self.remaining[inst] == 0 {
            self.finished.push_back(inst);
        }
        let deps = std::mem::take(&mut self.dependents[id]);
        for d in deps {
            self.indeg[d] -= 1;
            if self.indeg[d] == 0 {
                let node = self.pool.node_of(self.graph.tasks[d].device);
                self.ready.push(node, ReadyKey { pri: self.priority[d], id: d });
            }
        }
    }

    /// Block for one kernel completion (bounded by `timeout` if given),
    /// write its output back, and dispatch newly-ready work. `Ok(true)` if a
    /// completion was processed; `Ok(false)` on timeout or when nothing is
    /// in flight. A non-empty frontier with nothing in flight is a stall
    /// error, not a hang.
    pub fn wait(&mut self, timeout: Option<Duration>) -> Result<bool> {
        if self.in_flight == 0 {
            let outstanding: usize = self.remaining.iter().sum();
            if outstanding > 0 && !self.dispatch_paused {
                bail!("session stalled with {outstanding} tasks unretired (cyclic dependencies?)");
            }
            return Ok(false);
        }
        let deadline = timeout.map(|d| Instant::now() + d);
        // bounded-poll receive: every expiry runs a worker liveness sweep so
        // a silently-dead worker surfaces as recovery (or a typed
        // WorkerLost error) in bounded time instead of blocking forever
        let done = loop {
            let poll = match deadline {
                None => LIVENESS_POLL,
                Some(dl) => {
                    let rem = dl.saturating_duration_since(Instant::now());
                    if rem.is_zero() {
                        return Ok(false);
                    }
                    rem.min(LIVENESS_POLL)
                }
            };
            match self.rx.recv_timeout(poll) {
                Ok(d) => break d,
                Err(RecvTimeoutError::Timeout) => {
                    if let Some(d) = self.sweep_lost()? {
                        break d;
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    bail!("stream pool shut down with tasks in flight")
                }
            }
        };
        self.in_flight -= 1;
        // the device the job actually ran on (recovery may have rerouted it)
        let device = self
            .recovery
            .completed(done.id)
            .unwrap_or(self.graph.tasks[done.id].device);
        if let Some(c) = self.dev_inflight.get_mut(device) {
            *c = c.saturating_sub(1);
        }
        let out = match done.result {
            Ok(o) => o,
            Err(e) => {
                // failed jobs write no outputs and hazard edges admit any
                // topological order, so re-execution is bit-identical —
                // retry on a surviving worker with exponential backoff
                let (to, attempt, backoff) =
                    plan_retry::<F, P>(self.pool, &mut self.recovery, done.id, device).map_err(
                        |lost| lost.context(format!("task {} ({}): {e:#}", done.id, done.label)),
                    )?;
                std::thread::sleep(Duration::from_secs_f64(backoff));
                self.report.retries.push(RetryEvent {
                    task: done.id,
                    label: done.label,
                    attempt,
                    from_device: device,
                    to_device: to,
                    backoff_s: backoff,
                });
                dispatch_kernel::<F, P>(
                    self.pool,
                    self.hier,
                    &mut self.st,
                    &self.graph.tasks[done.id],
                    done.label,
                    to,
                    &self.tx,
                )?;
                self.recovery.dispatched(done.id, to);
                self.in_flight += 1;
                if to >= self.dev_inflight.len() {
                    self.dev_inflight.resize(to + 1, 0);
                }
                self.dev_inflight[to] += 1;
                return Ok(true);
            }
        };
        let (instance, op) = {
            let task = &self.graph.tasks[done.id];
            let op = task
                .op
                .ok_or_else(|| anyhow!("completed task {} has no payload", done.id))?;
            (task.instance, op)
        };
        apply_output(self.hier, &mut self.st, instance, op, out)?;
        account_kernel(
            &mut self.report,
            op,
            done.id,
            instance,
            device,
            done.label,
            done.t_start,
            done.t_end,
        );
        self.last_end[instance] = self.last_end[instance].max(done.t_end);
        if device >= self.dev_ewma_s.len() {
            self.dev_ewma_s.resize(device + 1, 0.0);
        }
        let obs = (done.t_end - done.t_start).max(0.0);
        let e = &mut self.dev_ewma_s[device];
        *e = if *e == 0.0 { obs } else { 0.5 * *e + 0.5 * obs };
        self.retire(done.id);
        self.pump()?;
        Ok(true)
    }

    /// Detect in-flight tasks stranded on dead workers and re-dispatch them
    /// onto survivors, spending retry budget. Called on poll expiry, when
    /// the channel has been observed empty; a completion that races the
    /// observation is returned for normal processing instead of sweeping.
    fn sweep_lost(&mut self) -> Result<Option<JobDone<TaskOut>>> {
        let lost = self.recovery.lost_tasks::<F, P>(self.pool);
        if lost.is_empty() {
            return Ok(None);
        }
        // a worker sends every completion before it can die on a later
        // message — confirm the channel is still empty before declaring the
        // dead workers' in-flight tasks lost
        match self.rx.try_recv() {
            Ok(d) => return Ok(Some(d)),
            Err(TryRecvError::Disconnected) => bail!("stream pool shut down with tasks in flight"),
            Err(TryRecvError::Empty) => {}
        }
        for (id, dev) in lost {
            self.in_flight -= 1;
            self.recovery.completed(id);
            if let Some(c) = self.dev_inflight.get_mut(dev) {
                *c = c.saturating_sub(1);
            }
            let label = kernel_label(&self.graph, id);
            let (to, attempt, backoff) = plan_retry::<F, P>(self.pool, &mut self.recovery, id, dev)?;
            std::thread::sleep(Duration::from_secs_f64(backoff));
            self.report.retries.push(RetryEvent {
                task: id,
                label,
                attempt,
                from_device: dev,
                to_device: to,
                backoff_s: backoff,
            });
            dispatch_kernel::<F, P>(
                self.pool,
                self.hier,
                &mut self.st,
                &self.graph.tasks[id],
                label,
                to,
                &self.tx,
            )?;
            self.recovery.dispatched(id, to);
            self.in_flight += 1;
            if to >= self.dev_inflight.len() {
                self.dev_inflight.resize(to + 1, 0);
            }
            self.dev_inflight[to] += 1;
        }
        Ok(None)
    }

    /// Next instance whose every task has retired (completion order), if any.
    pub fn poll_finished(&mut self) -> Option<usize> {
        self.finished.pop_front()
    }

    /// Pool-clock time a finished instance's last task retired on a worker
    /// (the max `t_end` over its kernel completions) — the honest completion
    /// timestamp: harvest-side work the caller performs after polling does
    /// not inflate it. `None` while the instance is in flight.
    pub fn finished_at(&self, inst: usize) -> Option<f64> {
        if self.remaining.get(inst).copied() == Some(0) {
            self.last_end.get(inst).copied()
        } else {
            None
        }
    }

    /// Kernel tasks currently executing on workers.
    pub fn in_flight(&self) -> usize {
        self.in_flight
    }

    /// Instances admitted so far (including finished and released ones).
    pub fn n_instances(&self) -> usize {
        self.st.n_instances()
    }

    /// The final fine-level state u^N of a **finished** instance. Calling
    /// this on an instance still in flight is an error, not a silent read
    /// of a partially-computed state.
    pub fn final_state(&self, inst: usize) -> Result<Tensor> {
        anyhow::ensure!(
            self.remaining.get(inst).copied() == Some(0),
            "instance {inst} has not finished (poll_finished first)"
        );
        self.st.final_state(inst)
    }

    /// Free a harvested instance's state slots (indices of other instances
    /// stay valid).
    pub fn release_instance(&mut self, inst: usize) -> Result<()> {
        self.st.release_instance(inst)
    }

    /// The cumulative execution report (instance-tagged kernel events across
    /// every admitted request — the record the overlap assertions read).
    pub fn report(&self) -> &ExecReport {
        &self.report
    }

    /// Consume the session, returning the cumulative report.
    pub fn into_report(self) -> ExecReport {
        self.report
    }

    /// Consume the session into its live state plus the cumulative report —
    /// the harvest path of checkpoint-driven runs
    /// ([`ExecSession::admit_prebuilt`] / [`ExecSession::resume`]), where the
    /// caller owns a multi-instance state the per-instance accessors do not
    /// cover.
    pub fn into_state(self) -> (MultiExecState, ExecReport) {
        (self.st, self.report)
    }

    /// Retired task count over the union graph (the checkpoint frontier
    /// size).
    pub fn retired(&self) -> usize {
        self.done_count
    }

    /// Admit a **prebuilt multi-instance graph** with its matching live
    /// state into a fresh session — the checkpointable counterpart of
    /// [`execute_prioritized`]: same graph, same state, same dispatch rules,
    /// but the caller can pause at a frontier ([`ExecSession::run_to_frontier`]),
    /// snapshot ([`ExecSession::checkpoint`]), and later
    /// [`ExecSession::resume`]. The session must be fresh (nothing admitted);
    /// `graph` task ids must be dense `0..n` with every op present, and every
    /// task's `instance` must exist in `st`.
    pub fn admit_prebuilt(
        &mut self,
        graph: TaskGraph,
        st: MultiExecState,
        priority: Option<&[f64]>,
    ) -> Result<()> {
        anyhow::ensure!(
            self.graph.tasks.is_empty() && self.st.n_instances() == 0,
            "admit_prebuilt requires a fresh session"
        );
        anyhow::ensure!(
            graph.tasks.iter().all(|t| t.op.is_some()),
            "admitted graph must be fully executable (op on every task)"
        );
        graph.validate()?;
        let n = graph.tasks.len();
        if let Some(p) = priority {
            anyhow::ensure!(
                p.len() == n,
                "priority vector length {} != task count {n}",
                p.len()
            );
        }
        let n_inst = st.n_instances();
        for t in &graph.tasks {
            anyhow::ensure!(
                t.instance < n_inst,
                "task {} targets instance {} but the state has {n_inst} instance(s)",
                t.id,
                t.instance
            );
        }
        self.st = st;
        self.graph = graph;
        self.indeg = vec![0; n];
        self.dependents = vec![Vec::new(); n];
        self.priority = priority.map(|p| p.to_vec()).unwrap_or_else(|| vec![0.0; n]);
        self.done = vec![false; n];
        self.done_count = 0;
        self.remaining = vec![0; n_inst];
        self.last_end = vec![self.pool.now(); n_inst];
        for id in 0..n {
            let deps = std::mem::take(&mut self.graph.tasks[id].deps);
            self.indeg[id] = deps.len();
            self.remaining[self.graph.tasks[id].instance] += 1;
            if matches!(self.graph.tasks[id].kind, TaskKind::Comm { .. }) {
                self.comm_deps.insert(id, deps.clone());
            }
            for d in deps {
                self.dependents[d].push(id);
            }
        }
        for (k, &r) in self.remaining.iter().enumerate() {
            if r == 0 {
                self.finished.push_back(k);
            }
        }
        for id in 0..n {
            if self.indeg[id] == 0 {
                let node = self.pool.node_of(self.graph.tasks[id].device);
                self.ready.push(node, ReadyKey { pri: self.priority[id], id });
            }
        }
        self.pump()
    }

    /// Run until at least `min_retired` tasks have retired, then pause
    /// dispatch and drain every in-flight job. On return the session is
    /// quiescent — `in_flight == 0` with a well-defined retired frontier of
    /// at least `min_retired` tasks — and ready to [`ExecSession::checkpoint`].
    /// Returns the frontier size (which may exceed `min_retired`: the drain
    /// retires whatever was already in flight).
    pub fn run_to_frontier(&mut self, min_retired: usize) -> Result<usize> {
        anyhow::ensure!(
            min_retired <= self.graph.tasks.len(),
            "frontier target {min_retired} exceeds task count {}",
            self.graph.tasks.len()
        );
        while self.done_count < min_retired {
            if !self.wait(None)? {
                break; // everything already retired
            }
        }
        self.dispatch_paused = true;
        while self.in_flight > 0 {
            self.wait(None)?;
        }
        Ok(self.done_count)
    }

    /// Snapshot the quiescent session: the retired-task frontier plus the
    /// serialized live state. Requires `in_flight == 0` (drain via
    /// [`ExecSession::run_to_frontier`]) so no completed-but-unapplied output
    /// can be lost between the frontier and the state.
    pub fn checkpoint(&self) -> Result<SessionSnapshot> {
        anyhow::ensure!(
            self.in_flight == 0,
            "checkpoint requires a quiescent session (drain via run_to_frontier)"
        );
        let frontier =
            self.done.iter().enumerate().filter(|(_, d)| **d).map(|(i, _)| i).collect();
        Ok(SessionSnapshot {
            n_tasks: self.graph.tasks.len(),
            frontier,
            state: self.st.to_json(),
        })
    }

    /// Reconstruct a session from a [`SessionSnapshot`]: the caller
    /// re-supplies the (deterministically rebuilt) graph, the dispatch
    /// priorities, and — for pipelined runs — the net spec; the snapshot
    /// supplies the retired frontier and the live state. Only un-retired
    /// tasks are executed; dependency edges satisfied by the frontier are
    /// already released, so retired work is never re-run and un-retired work
    /// is never skipped ([`ExecSession::run_to_end`] finishes the graph).
    /// Dispatch starts paused-off: ready tasks launch immediately.
    pub fn resume(
        pool: &'a P,
        hier: &'a Hierarchy,
        graph: TaskGraph,
        priority: Option<&[f64]>,
        snap: &SessionSnapshot,
        spec: Option<Arc<NetSpec>>,
    ) -> Result<ExecSession<'a, F, P>> {
        anyhow::ensure!(
            graph.tasks.len() == snap.n_tasks,
            "snapshot covers {} tasks, resumed graph has {}",
            snap.n_tasks,
            graph.tasks.len()
        );
        anyhow::ensure!(
            graph.tasks.iter().all(|t| t.op.is_some()),
            "resumed graph must be fully executable (op on every task)"
        );
        graph.validate()?;
        let st = MultiExecState::from_json(&snap.state, spec)?;
        let n = graph.tasks.len();
        if let Some(p) = priority {
            anyhow::ensure!(
                p.len() == n,
                "priority vector length {} != task count {n}",
                p.len()
            );
        }
        let mut sess = ExecSession::new(pool, hier);
        sess.st = st;
        sess.graph = graph;
        let n_inst = sess.st.n_instances();
        sess.indeg = vec![0; n];
        sess.dependents = vec![Vec::new(); n];
        sess.priority = priority.map(|p| p.to_vec()).unwrap_or_else(|| vec![0.0; n]);
        sess.done = vec![false; n];
        for &id in &snap.frontier {
            anyhow::ensure!(id < n, "frontier task {id} out of range");
            anyhow::ensure!(!sess.done[id], "frontier lists task {id} twice");
            sess.done[id] = true;
        }
        sess.done_count = snap.frontier.len();
        sess.remaining = vec![0; n_inst];
        sess.last_end = vec![pool.now(); n_inst];
        for id in 0..n {
            let t = &sess.graph.tasks[id];
            anyhow::ensure!(
                t.instance < n_inst,
                "task {} targets instance {} but the snapshot has {n_inst} instance(s)",
                t.id,
                t.instance
            );
            if !sess.done[id] {
                sess.remaining[t.instance] += 1;
            }
        }
        for id in 0..n {
            let deps = std::mem::take(&mut sess.graph.tasks[id].deps);
            if sess.done[id] {
                continue; // retired: never re-executed, holds no edges
            }
            if matches!(sess.graph.tasks[id].kind, TaskKind::Comm { .. }) {
                // full (unfiltered) producer list: a producer retired before
                // the checkpoint still owns its slot's value in the restored
                // state (its overwriters are WAR-ordered behind this Comm's
                // consumers), so the ship path reads the right tensors
                sess.comm_deps.insert(id, deps.clone());
            }
            let live: Vec<usize> = deps.into_iter().filter(|d| !sess.done[*d]).collect();
            sess.indeg[id] = live.len();
            for d in live {
                sess.dependents[d].push(id);
            }
        }
        for (k, &r) in sess.remaining.iter().enumerate() {
            if r == 0 {
                sess.finished.push_back(k);
            }
        }
        for id in 0..n {
            if !sess.done[id] && sess.indeg[id] == 0 {
                let node = pool.node_of(sess.graph.tasks[id].device);
                sess.ready.push(node, ReadyKey { pri: sess.priority[id], id });
            }
        }
        sess.pump()?;
        Ok(sess)
    }

    /// Resume dispatch (if paused) and run the session to full completion:
    /// every task of every admitted instance retired.
    pub fn run_to_end(&mut self) -> Result<()> {
        self.dispatch_paused = false;
        self.pump()?;
        while self.wait(None)? {}
        let outstanding: usize = self.remaining.iter().sum();
        anyhow::ensure!(outstanding == 0, "session ended with {outstanding} tasks unretired");
        Ok(())
    }
}

/// Forward fine state a Ψ application at (level, j−1 → j) linearizes around
/// — the same formula the graph builder used for the matching RAW edge.
fn rev_layer(hier: &Hierarchy, level: usize, j: usize) -> usize {
    hier.adjoint_state_index(level, j)
}

/// The snapshot-ring parameters a pipelined instance's trunk op must use:
/// `(layer kind, w, b)` of `layer` at the instance's read version
/// (`max(0, step − S)`). `None` on non-pipelined runs, where the workers'
/// own solver snapshot applies. Taken at dispatch time on the scheduler
/// thread — the graph's version-gap edges guarantee the version is written,
/// and the ring's read accounting keeps it alive until this task completes.
fn pipe_trunk(
    st: &MultiExecState,
    ki: usize,
    layer: usize,
) -> Result<Option<(LayerKind, Arc<Tensor>, Arc<Tensor>)>> {
    let Some(pipe) = &st.pipe else { return Ok(None) };
    let version = (ki / pipe.micro).saturating_sub(pipe.staleness);
    let (w, b) = pipe.ring.get(version, layer)?;
    Ok(Some((pipe.spec.trunk[layer].clone(), w, b)))
}

/// Φ at one trunk layer against explicit `(w, b)` — the identical free
/// functions `HostSolver::step` wraps, so pipelined dispatch is bit-identical
/// to solver dispatch at equal parameter values.
fn phi_step(kind: &LayerKind, h: f32, w: &Tensor, b: &Tensor, u: &Tensor) -> Result<Tensor> {
    match kind {
        LayerKind::Conv { kernel, .. } => ops::residual_step(u, w, b, h, kernel / 2),
        LayerKind::Fc { .. } => ops::residual_fc_step(u, w, b, h),
    }
}

/// Ψ (adjoint step) against explicit `(w, b)` — mirrors
/// `HostSolver::adjoint_step`.
fn psi_step(
    kind: &LayerKind,
    h: f32,
    w: &Tensor,
    b: &Tensor,
    fwd: &Tensor,
    lam: &Tensor,
) -> Result<Tensor> {
    match kind {
        LayerKind::Conv { kernel, .. } => vjp::adjoint_step(fwd, w, b, h, kernel / 2, lam),
        LayerKind::Fc { .. } => Ok(vjp::residual_fc_step_vjp(fwd, w, b, h, lam)?.0),
    }
}

/// Layer parameter gradient against explicit `(w, b)` — mirrors
/// `HostSolver::param_grad`.
fn phi_param_grad(
    kind: &LayerKind,
    h: f32,
    w: &Tensor,
    b: &Tensor,
    u: &Tensor,
    lam: &Tensor,
) -> Result<(Tensor, Tensor)> {
    match kind {
        LayerKind::Conv { kernel, .. } => {
            let (_, dw, db) = vjp::residual_step_vjp(u, w, b, h, kernel / 2, lam)?;
            Ok((dw, db))
        }
        LayerKind::Fc { .. } => {
            let (_, dw, db) = vjp::residual_fc_step_vjp(u, w, b, h, lam)?;
            Ok((dw, db))
        }
    }
}

/// Take `Arc` handles on a kernel task's inputs and submit it to worker
/// `dev` (the task's planned device, or the recovery reroute target when
/// that worker died). For `Restrict`, the injection (coarse initial guess +
/// correction snapshot) is applied at dispatch time: the graph's WAR edges
/// guarantee every reader of the old coarse slots has already completed.
/// Adjoint ops additionally take the forward fine state they linearize
/// around (their RAW edges guarantee it is final).
fn dispatch_kernel<F: SolverFactory, P: WorkerPool<F>>(
    pool: &P,
    hier: &Hierarchy,
    st: &mut MultiExecState,
    task: &Task,
    label: &'static str,
    dev: usize,
    tx: &Sender<JobDone<TaskOut>>,
) -> Result<()>
where
    F::Solver: NetExecutor,
{
    let op = task.op.ok_or_else(|| {
        anyhow!("task {} is not executable (op=None); this graph is cost-model-only", task.id)
    })?;
    let ki = task.instance;
    match op {
        TaskOp::PointUpdate { sys, level, j } => {
            let lvl = &hier.levels[level];
            let theta = lvl.theta_idx(j - 1);
            let h = lvl.h;
            let inst = st.inst(ki)?;
            let ss = inst.sys(sys)?;
            let u_prev = ss.u[level][j - 1].clone();
            let gj = ss.g[level].as_ref().map(|g| g[j].clone());
            match sys {
                Sys::Primal => {
                    if let Some((kind, w, b)) = pipe_trunk(st, ki, theta)? {
                        pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                            let mut v = phi_step(&kind, h, &w, &b, &u_prev)?;
                            if let Some(g) = &gj {
                                v.axpy(1.0, g)?;
                            }
                            Ok(TaskOut::State(v))
                        })
                    } else {
                        pool.submit_job(dev, label, task.id, tx.clone(), move |s: &F::Solver| {
                            let mut v = s.step(theta, h, &u_prev)?;
                            if let Some(g) = &gj {
                                v.axpy(1.0, g)?;
                            }
                            Ok(TaskOut::State(v))
                        })
                    }
                }
                Sys::Adjoint => {
                    let rev = rev_layer(hier, level, j);
                    let fwd = inst.pri.u[0][rev].clone();
                    if let Some((kind, w, b)) = pipe_trunk(st, ki, rev)? {
                        pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                            let mut v = psi_step(&kind, h, &w, &b, &fwd, &u_prev)?;
                            if let Some(g) = &gj {
                                v.axpy(1.0, g)?;
                            }
                            Ok(TaskOut::State(v))
                        })
                    } else {
                        pool.submit_job(dev, label, task.id, tx.clone(), move |s: &F::Solver| {
                            let mut v = s.adjoint_step(rev, h, &fwd, &u_prev)?;
                            if let Some(g) = &gj {
                                v.axpy(1.0, g)?;
                            }
                            Ok(TaskOut::State(v))
                        })
                    }
                }
            }
        }
        TaskOp::BlockRun { sys, level, j_first, j_last } => {
            let lvl = &hier.levels[level];
            let h = lvl.h;
            let stride = lvl.stride;
            let start_theta = lvl.theta_idx(j_first - 1);
            let count = j_last - j_first + 1;
            let inst = st.inst(ki)?;
            let ss = inst.sys(sys)?;
            if ss.g[level].is_some() {
                bail!("BlockRun on a level with a right-hand side (graph bug)");
            }
            let u_prev = ss.u[level][j_first - 1].clone();
            match sys {
                Sys::Primal => {
                    if st.pipe.is_some() {
                        let plan: Vec<(LayerKind, Arc<Tensor>, Arc<Tensor>)> = (0..count)
                            .map(|i| {
                                pipe_trunk(st, ki, start_theta + i * stride)
                                    .map(|p| p.expect("pipelined run"))
                            })
                            .collect::<Result<_>>()?;
                        pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                            let mut out = Vec::with_capacity(plan.len());
                            let mut u = (*u_prev).clone();
                            for (kind, w, b) in &plan {
                                u = phi_step(kind, h, w, b, &u)?;
                                out.push(u.clone());
                            }
                            Ok(TaskOut::States(out))
                        })
                    } else {
                        // the solver's fused block path (one PJRT block artifact)
                        pool.submit_job(dev, label, task.id, tx.clone(), move |s: &F::Solver| {
                            Ok(TaskOut::States(s.block_fprop(start_theta, stride, count, h, &u_prev)?))
                        })
                    }
                }
                Sys::Adjoint => {
                    let steps: Vec<(usize, Arc<Tensor>)> = (j_first..=j_last)
                        .map(|j| {
                            let rev = rev_layer(hier, level, j);
                            (rev, inst.pri.u[0][rev].clone())
                        })
                        .collect();
                    if st.pipe.is_some() {
                        let plan: Vec<(LayerKind, Arc<Tensor>, Arc<Tensor>, Arc<Tensor>)> = steps
                            .iter()
                            .map(|(rev, fwd)| {
                                pipe_trunk(st, ki, *rev).map(|p| {
                                    let (kind, w, b) = p.expect("pipelined run");
                                    (kind, w, b, fwd.clone())
                                })
                            })
                            .collect::<Result<_>>()?;
                        pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                            let mut out = Vec::with_capacity(plan.len());
                            let mut mu = (*u_prev).clone();
                            for (kind, w, b, fwd) in &plan {
                                mu = psi_step(kind, h, w, b, fwd, &mu)?;
                                out.push(mu.clone());
                            }
                            Ok(TaskOut::States(out))
                        })
                    } else {
                        pool.submit_job(dev, label, task.id, tx.clone(), move |s: &F::Solver| {
                            let mut out = Vec::with_capacity(steps.len());
                            let mut mu = (*u_prev).clone();
                            for (rev, fwd) in &steps {
                                mu = s.adjoint_step(*rev, h, fwd, &mu)?;
                                out.push(mu.clone());
                            }
                            Ok(TaskOut::States(out))
                        })
                    }
                }
            }
        }
        TaskOp::Residual { sys, level, j } => {
            let lvl = &hier.levels[level];
            let theta = lvl.theta_idx(j - 1);
            let h = lvl.h;
            let inst = st.inst(ki)?;
            let ss = inst.sys(sys)?;
            let u_prev = ss.u[level][j - 1].clone();
            let u_cur = ss.u[level][j].clone();
            let gj = ss.g[level].as_ref().map(|g| g[j].clone());
            let fwd = match sys {
                Sys::Primal => None,
                Sys::Adjoint => {
                    let rev = rev_layer(hier, level, j);
                    Some((rev, inst.pri.u[0][rev].clone()))
                }
            };
            let layer = match &fwd {
                None => theta,
                Some((rev, _)) => *rev,
            };
            if let Some((kind, w, b)) = pipe_trunk(st, ki, layer)? {
                pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                    let mut r = match &fwd {
                        None => phi_step(&kind, h, &w, &b, &u_prev)?,
                        Some((_, f)) => psi_step(&kind, h, &w, &b, f, &u_prev)?,
                    };
                    if let Some(g) = &gj {
                        r.axpy(1.0, g)?;
                    }
                    r.axpy(-1.0, &u_cur)?;
                    Ok(TaskOut::State(r))
                })
            } else {
                pool.submit_job(dev, label, task.id, tx.clone(), move |s: &F::Solver| {
                    let mut r = match &fwd {
                        None => s.step(theta, h, &u_prev)?,
                        Some((rev, f)) => s.adjoint_step(*rev, h, f, &u_prev)?,
                    };
                    if let Some(g) = &gj {
                        r.axpy(1.0, g)?;
                    }
                    r.axpy(-1.0, &u_cur)?;
                    Ok(TaskOut::State(r))
                })
            }
        }
        TaskOp::Restrict { sys, level, j } => {
            let c = hier.coarsen;
            let coarse = &hier.levels[level + 1];
            let theta = coarse.theta_idx(j - 1);
            let h = coarse.h;
            let (r, inj_prev, inj_cur) = {
                let ss = st.inst(ki)?.sys(sys)?;
                (
                    ss.r[level][j * c].clone().ok_or_else(|| {
                        anyhow!("restrict({level},{j}): residual at point {} missing", j * c)
                    })?,
                    ss.u[level][(j - 1) * c].clone(),
                    ss.u[level][j * c].clone(),
                )
            };
            let fwd = match sys {
                Sys::Primal => None,
                Sys::Adjoint => {
                    let rev = rev_layer(hier, level + 1, j);
                    Some((rev, st.inst(ki)?.pri.u[0][rev].clone()))
                }
            };
            let layer = match &fwd {
                None => theta,
                Some((rev, _)) => *rev,
            };
            let pp = pipe_trunk(st, ki, layer)?;
            // inject the coarse initial guess + correction snapshot now —
            // safe because this task's WAR deps have already retired
            {
                let sm = st.inst_mut(ki)?.sys_mut(sys)?;
                sm.u[level + 1][j] = inj_cur.clone();
                sm.inj[level + 1][j] = Some(inj_cur.clone());
            }
            if let Some((kind, w, b)) = pp {
                pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                    let phi = match &fwd {
                        None => phi_step(&kind, h, &w, &b, &inj_prev)?,
                        Some((_, f)) => psi_step(&kind, h, &w, &b, f, &inj_prev)?,
                    };
                    let mut out = (*r).clone();
                    out.axpy(1.0, &inj_cur)?;
                    out.axpy(-1.0, &phi)?;
                    Ok(TaskOut::State(out))
                })
            } else {
                pool.submit_job(dev, label, task.id, tx.clone(), move |s: &F::Solver| {
                    let phi = match &fwd {
                        None => s.step(theta, h, &inj_prev)?,
                        Some((rev, f)) => s.adjoint_step(*rev, h, f, &inj_prev)?,
                    };
                    let mut out = (*r).clone();
                    out.axpy(1.0, &inj_cur)?;
                    out.axpy(-1.0, &phi)?;
                    Ok(TaskOut::State(out))
                })
            }
        }
        TaskOp::Correct { sys, level, j } => {
            let c = hier.coarsen;
            let ss = st.inst(ki)?.sys(sys)?;
            let u_fine = ss.u[level][j * c].clone();
            let u_coarse = ss.u[level + 1][j].clone();
            let inj = ss.inj[level + 1][j]
                .clone()
                .ok_or_else(|| anyhow!("correct({level},{j}): injection snapshot missing"))?;
            pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                let delta = Tensor::sub(&u_coarse, &inj)?;
                let mut out = (*u_fine).clone();
                out.axpy(1.0, &delta)?;
                Ok(TaskOut::State(out))
            })
        }
        TaskOp::Head => {
            let n_last = hier.fine().n_points - 1;
            let inst = st.inst(ki)?;
            let u = inst.pri.u[0][n_last].clone();
            let labels = inst.train()?.labels.clone();
            if let Some(pipe) = &st.pipe {
                let version = (ki / pipe.micro).saturating_sub(pipe.staleness);
                let (w_fc, b_fc) = pipe.ring.get(version, pipe.n_layers + 1)?;
                pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                    let (_logits, loss) = ops::head_fwd(&u, &w_fc, &b_fc, &labels)?;
                    let (du, dw_fc, db_fc) = vjp::head_vjp(&u, &w_fc, &b_fc, &labels)?;
                    Ok(TaskOut::Head { loss, du, dw_fc, db_fc })
                })
            } else {
                pool.submit_job(dev, label, task.id, tx.clone(), move |s: &F::Solver| {
                    let (_logits, loss) = s.head(&u, &labels)?;
                    let (du, dw_fc, db_fc) = s.head_vjp(&u, &labels)?;
                    Ok(TaskOut::Head { loss, du, dw_fc, db_fc })
                })
            }
        }
        TaskOp::GradAccum { layer } => {
            let h = hier.fine().h;
            let n_layers = hier.fine().n_points - 1;
            let inst = st.inst(ki)?;
            let u = inst.pri.u[0][layer].clone();
            // λ^{layer+1} = μ^{N−1−layer}
            let lam = inst.sys(Sys::Adjoint)?.u[0][n_layers - 1 - layer].clone();
            if let Some((kind, w, b)) = pipe_trunk(st, ki, layer)? {
                pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                    let (dw, db) = phi_param_grad(&kind, h, &w, &b, &u, &lam)?;
                    Ok(TaskOut::Pair(dw, db))
                })
            } else {
                pool.submit_job(dev, label, task.id, tx.clone(), move |s: &F::Solver| {
                    let (dw, db) = s.param_grad(layer, h, &u, &lam)?;
                    Ok(TaskOut::Pair(dw, db))
                })
            }
        }
        TaskOp::ReduceGrad { layer, lhs, rhs, root, .. } => {
            // the root applies the micro-batch mean — the SAME expression the
            // serial reference uses (train::reduce_micro_grads)
            let (l, r, scale) = if let Some(pipe) = &st.pipe {
                let step = ki / pipe.micro;
                (
                    st.grad_src_pipe(step, layer, lhs)?,
                    st.grad_src_pipe(step, layer, rhs)?,
                    if root { Some(1.0 / pipe.micro as f32) } else { None },
                )
            } else {
                (
                    st.grad_src(layer, lhs)?,
                    st.grad_src(layer, rhs)?,
                    if root { Some(1.0 / st.insts.len() as f32) } else { None },
                )
            };
            pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                let mut sum = pair_sum(&l, &r)?;
                if let Some(sc) = scale {
                    pair_scale(&mut sum, sc);
                }
                Ok(TaskOut::Pair(sum.0, sum.1))
            })
        }
        TaskOp::ParamUpdate { layer } => {
            if let Some(pipe) = &st.pipe {
                let step = ki / pipe.micro;
                // M = 1: the lone instance's gradient; M > 1: the reduced mean
                let (dw, db) = if pipe.micro == 1 {
                    st.grad_src_pipe(step, layer, GradSrc::Inst(0))?
                } else {
                    pipe.reduced
                        .get(step)
                        .and_then(|s| s.get(layer))
                        .and_then(|s| s.clone())
                        .ok_or_else(|| {
                            anyhow!("param_update(step {step}, {layer}): reduced gradient missing")
                        })?
                };
                let (w, b) = pipe.ring.get(step, layer)?;
                let lr = pipe.lr;
                pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                    let mut w2 = (*w).clone();
                    w2.axpy(-lr, &dw)?;
                    let mut b2 = (*b).clone();
                    b2.axpy(-lr, &db)?;
                    Ok(TaskOut::Pair(w2, b2))
                })
            } else {
                let sh = st.shared()?;
                // M = 1: the lone instance's gradient; M > 1: the reduced mean
                let (dw, db) = if st.insts.len() == 1 {
                    st.insts[0]
                        .train()?
                        .grads
                        .get(layer)
                        .cloned()
                        .ok_or_else(|| anyhow!("param_update({layer}): gradient slot empty"))?
                } else {
                    sh.reduced
                        .get(layer)
                        .cloned()
                        .ok_or_else(|| anyhow!("param_update({layer}): reduced gradient missing"))?
                };
                let (w, b) = sh.params.trunk[layer].clone();
                let lr = sh.lr;
                pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                    let mut w2 = w;
                    w2.axpy(-lr, &dw)?;
                    let mut b2 = b;
                    b2.axpy(-lr, &db)?;
                    Ok(TaskOut::Pair(w2, b2))
                })
            }
        }
        TaskOp::Opening => {
            let pipe = st
                .pipe
                .as_ref()
                .ok_or_else(|| anyhow!("Opening task outside a pipelined run"))?;
            let version = (ki / pipe.micro).saturating_sub(pipe.staleness);
            let (w, b) = pipe.ring.get(version, pipe.n_layers)?;
            let y = pipe
                .inputs
                .get(ki)
                .cloned()
                .ok_or_else(|| anyhow!("opening: no input for instance {ki}"))?;
            let pad = pipe.spec.opening.pad;
            pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                let mut u = ops::conv2d(&y, &w, pad)?;
                ops::add_bias(&mut u, &b)?;
                ops::relu(&mut u);
                Ok(TaskOut::State(u))
            })
        }
        TaskOp::OpenGrad => {
            let pipe = st
                .pipe
                .as_ref()
                .ok_or_else(|| anyhow!("OpenGrad task outside a pipelined run"))?;
            let version = (ki / pipe.micro).saturating_sub(pipe.staleness);
            let (w, b) = pipe.ring.get(version, pipe.n_layers)?;
            let y = pipe
                .inputs
                .get(ki)
                .cloned()
                .ok_or_else(|| anyhow!("open_grad: no input for instance {ki}"))?;
            let pad = pipe.spec.opening.pad;
            let n_last = hier.fine().n_points - 1;
            // λ⁰ = the fully-relaxed adjoint state at the first trunk layer
            let lam0 = st.inst(ki)?.sys(Sys::Adjoint)?.u[0][n_last].clone();
            pool.submit_job(dev, label, task.id, tx.clone(), move |_s: &F::Solver| {
                let (dw, db) = crate::train::opening_vjp(&y, &w, &b, pad, &lam0)?;
                Ok(TaskOut::Pair(dw, db))
            })
        }
        TaskOp::Xfer => bail!("Xfer payload on a kernel task (graph bug)"),
    }
}

impl TaskOut {
    /// Compact variant name for error messages (derived Debug would dump
    /// whole tensors).
    fn kind(&self) -> &'static str {
        match self {
            TaskOut::State(_) => "State",
            TaskOut::States(_) => "States",
            TaskOut::Pair(..) => "Pair",
            TaskOut::Head { .. } => "Head",
        }
    }
}

fn expect_state(out: TaskOut, what: &str) -> Result<Tensor> {
    match out {
        TaskOut::State(t) => Ok(t),
        other => bail!("{what}: expected a single state, got {}", other.kind()),
    }
}

/// Write one completed kernel's output into its instance's (or the shared)
/// slot(s).
fn apply_output(
    hier: &Hierarchy,
    st: &mut MultiExecState,
    ki: usize,
    op: TaskOp,
    out: TaskOut,
) -> Result<()> {
    match op {
        TaskOp::PointUpdate { sys, level, j } => {
            st.inst_mut(ki)?.sys_mut(sys)?.u[level][j] =
                Arc::new(expect_state(out, "point_update")?);
        }
        TaskOp::BlockRun { sys, level, j_first, j_last } => {
            let kind = out.kind();
            let TaskOut::States(v) = out else {
                bail!("block_run: expected a state span, got {kind}");
            };
            if v.len() != j_last - j_first + 1 {
                bail!("block_run: span length {} != {}", v.len(), j_last - j_first + 1);
            }
            let ss = st.inst_mut(ki)?.sys_mut(sys)?;
            for (k, t) in v.into_iter().enumerate() {
                ss.u[level][j_first + k] = Arc::new(t);
            }
        }
        TaskOp::Residual { sys, level, j } => {
            st.inst_mut(ki)?.sys_mut(sys)?.r[level][j] =
                Some(Arc::new(expect_state(out, "residual")?));
        }
        TaskOp::Restrict { sys, level, j } => {
            let t = expect_state(out, "restrict")?;
            match &mut st.inst_mut(ki)?.sys_mut(sys)?.g[level + 1] {
                Some(g) => g[j] = Arc::new(t),
                None => bail!("restrict into level {} with no rhs storage", level + 1),
            }
        }
        TaskOp::Correct { sys, level, j } => {
            st.inst_mut(ki)?.sys_mut(sys)?.u[level][j * hier.coarsen] =
                Arc::new(expect_state(out, "correct")?);
        }
        TaskOp::Head => {
            let TaskOut::Head { loss, du, dw_fc, db_fc } = out else {
                bail!("head: wrong output kind");
            };
            // ∂loss/∂u^N seeds every slot of THIS instance's adjoint system
            // (the constant-in-depth initial guess of the adjoint MGRIT solve)
            let inst = st.inst_mut(ki)?;
            inst.adj = Some(SysState::seeded(hier, &du));
            inst.train_mut()?.head = Some(HeadOut { loss, dw_fc, db_fc });
        }
        TaskOp::GradAccum { layer } => {
            let TaskOut::Pair(dw, db) = out else {
                bail!("param_grad: wrong output kind");
            };
            st.inst_mut(ki)?.train_mut()?.grads.set(layer, dw, db)?;
        }
        TaskOp::ReduceGrad { layer, node, root, .. } => {
            let TaskOut::Pair(w, b) = out else {
                bail!("reduce_grad: wrong output kind");
            };
            if let Some(pipe) = st.pipe.as_mut() {
                let step = ki / pipe.micro;
                let slot = if root {
                    pipe.reduced
                        .get_mut(step)
                        .and_then(|s| s.get_mut(layer))
                        .ok_or_else(|| anyhow!("reduce(step {step}, {layer}): out of range"))?
                } else {
                    pipe.nodes
                        .get_mut(step)
                        .and_then(|s| s.get_mut(layer))
                        .and_then(|l| l.get_mut(node))
                        .ok_or_else(|| {
                            anyhow!("reduce(step {step}, {layer}): node {node} out of range")
                        })?
                };
                if slot.is_some() {
                    bail!("reduce(step {step}, {layer}): slot filled twice");
                }
                *slot = Some((w, b));
            } else {
                let sh = st.shared_mut()?;
                if root {
                    sh.reduced.set(layer, w, b)?;
                } else {
                    let slot = sh
                        .nodes
                        .get_mut(layer)
                        .and_then(|l| l.get_mut(node))
                        .ok_or_else(|| anyhow!("reduce({layer}): node {node} out of range"))?;
                    if slot.is_some() {
                        bail!("reduce({layer}): node {node} filled twice");
                    }
                    *slot = Some((w, b));
                }
            }
        }
        TaskOp::ParamUpdate { layer } => {
            let TaskOut::Pair(w, b) = out else {
                bail!("param_update: wrong output kind");
            };
            if let Some(pipe) = st.pipe.as_mut() {
                let step = ki / pipe.micro;
                pipe.ring.set(step + 1, layer, w, b)?;
            } else {
                st.shared_mut()?.new_trunk.set(layer, w, b)?;
            }
        }
        TaskOp::Opening => {
            let u0 = expect_state(out, "opening")?;
            anyhow::ensure!(st.pipe.is_some(), "Opening output outside a pipelined run");
            // replace the placeholder state wholesale: the opening activation
            // seeds every fine/coarse primal slot, exactly as the host-side
            // driver prologue does for the synchronous path
            st.inst_mut(ki)?.pri = SysState::seeded(hier, &u0);
        }
        TaskOp::OpenGrad => {
            let TaskOut::Pair(dw, db) = out else {
                bail!("open_grad: wrong output kind");
            };
            let n_layers = hier.fine().n_points - 1;
            st.inst_mut(ki)?.train_mut()?.grads.set(n_layers, dw, db)?;
        }
        TaskOp::Xfer => bail!("Xfer payload completed as a kernel (graph bug)"),
    }
    // Snapshot-ring read accounting: every parameter read this op performed at
    // dispatch time is released now, AFTER the write-back above, so a failed
    // write can never unpin a version that later diagnostics still need.
    if let Some(pipe) = st.pipe.as_mut() {
        let n_layers = hier.fine().n_points - 1;
        let step = ki / pipe.micro;
        if matches!(op, TaskOp::ParamUpdate { .. }) {
            pipe.ring.note_read(step)?;
        } else {
            for _ in 0..op_param_slots(&op, hier, n_layers).len() {
                pipe.ring.note_read(step.saturating_sub(pipe.staleness))?;
            }
        }
    }
    Ok(())
}

/// Merge a per-label phase ledger into a cumulative one (driver helper);
/// same accumulate-by-label rule as [`ExecReport::add_phase`].
pub(crate) fn merge_phases(
    into: &mut Vec<(&'static str, f64)>,
    phases: &[(&'static str, f64)],
) {
    for &(label, secs) in phases {
        if let Some(e) = into.iter_mut().find(|(l, _)| *l == label) {
            e.1 += secs;
        } else {
            into.push((label, secs));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{InstanceGroups, Partition};
    use crate::mgrit::fas::RelaxKind;
    use crate::mgrit::taskgraph::{self, Granularity};
    use crate::model::{NetParams, NetSpec};
    use crate::solver::host::HostSolver;
    use std::sync::Arc;

    fn setup() -> (Arc<NetSpec>, Hierarchy, Partition, StreamPool<impl SolverFactory<Solver = HostSolver>>, Tensor)
    {
        let spec = Arc::new(NetSpec::micro());
        let params = Arc::new(NetParams::init(&spec, 30).unwrap());
        let spec2 = spec.clone();
        let factory = move |_w: usize| HostSolver::new(spec2.clone(), params.clone());
        let hier = Hierarchy::two_level(4, spec.h(), 2).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let partition = Partition::contiguous(n_blocks, 2).unwrap();
        let pool = StreamPool::new(partition.n_devices(), factory).unwrap();
        let mut rng = crate::util::prng::Rng::new(31);
        let u0 = Tensor::randn(&[1, 2, 6, 6], 0.8, &mut rng);
        (spec, hier, partition, pool, u0)
    }

    #[test]
    fn vcycle_graph_executes_and_counts_work() {
        let (spec, hier, partition, pool, u0) = setup();
        let g = taskgraph::mg_vcycle(&spec, &hier, &partition, 1, RelaxKind::FCF);
        let mut st = MultiExecState::initial(&hier, &u0);
        let rep = execute(&pool, &hier, &g, &mut st).unwrap();
        assert!(rep.kernels > 0);
        assert!(rep.phi_evals > 0);
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "f_relax"));
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "coarse_solve"));
        // events are instance-tagged (single-instance graph → all zero)
        assert_eq!(rep.events.len(), rep.kernels);
        assert!(rep.events.iter().all(|e| e.instance == 0));
        // states moved away from the constant initial guess
        let moved = st.insts[0].pri.u[0][1..]
            .iter()
            .any(|u| crate::util::stats::rel_l2_err(u.data(), u0.data()) > 1e-6);
        assert!(moved, "executor did not update any state");
    }

    #[test]
    fn per_block_vcycle_bit_matches_per_step() {
        let (spec, hier, partition, pool, u0) = setup();
        let gs = taskgraph::mg_vcycle_with(&spec, &hier, &partition, 1, RelaxKind::FCF, Granularity::PerStep);
        let gb = taskgraph::mg_vcycle_with(&spec, &hier, &partition, 1, RelaxKind::FCF, Granularity::PerBlock);
        let mut st_s = MultiExecState::initial(&hier, &u0);
        let mut st_b = MultiExecState::initial(&hier, &u0);
        let rep_s = execute(&pool, &hier, &gs, &mut st_s).unwrap();
        let rep_b = execute(&pool, &hier, &gb, &mut st_b).unwrap();
        // fused F-spans perform the identical arithmetic in the same order
        assert_eq!(rep_s.phi_evals, rep_b.phi_evals);
        let a = st_s.into_fine_states();
        let b = st_b.into_fine_states();
        for (x, y) in a.iter().zip(&b) {
            assert!(x.data() == y.data(), "per-block state differs bitwise");
        }
    }

    #[test]
    fn residual_check_fills_residual_slots() {
        let (spec, hier, partition, pool, u0) = setup();
        let g = taskgraph::residual_check(&spec, &hier, &partition, 1);
        let mut st = MultiExecState::initial(&hier, &u0);
        execute(&pool, &hier, &g, &mut st).unwrap();
        for cp in hier.fine().cpoints(hier.coarsen) {
            if cp > 0 {
                assert!(st.residual(0, cp).is_some(), "residual at {cp} missing");
            }
        }
    }

    #[test]
    fn non_executable_graph_is_rejected() {
        let (spec, hier, _partition, pool, u0) = setup();
        // serial_forward carries no payloads
        let g = taskgraph::serial_forward(&spec, 1, 1);
        let mut st = MultiExecState::initial(&hier, &u0);
        assert!(execute(&pool, &hier, &g, &mut st).is_err());
    }

    #[test]
    fn training_graph_without_train_state_is_rejected() {
        let (spec, hier, partition, pool, u0) = setup();
        let g = taskgraph::mg_train_step(
            &spec, &hier, &partition, 1, 1, RelaxKind::FCF, Granularity::PerStep,
        );
        let mut st = MultiExecState::initial(&hier, &u0);
        let err = execute(&pool, &hier, &g, &mut st).unwrap_err().to_string();
        assert!(err.contains("training"), "{err}");
    }

    #[test]
    fn multi_instance_graph_needs_enough_instances() {
        let (spec, hier, partition, pool, u0) = setup();
        let params = Arc::new(NetParams::init(&spec, 30).unwrap());
        let groups = InstanceGroups::new(1, partition.n_devices()).unwrap();
        let g = taskgraph::mg_train_step_multi(
            &spec, &hier, &partition, &groups, 1, 1, RelaxKind::FCF, Granularity::PerStep, 2,
        )
        .unwrap();
        // only one instance in the state → rejected up front
        let mut st = MultiExecState::initial_train(
            &hier,
            &[(u0.clone(), vec![3i32])],
            params,
            0.05,
        )
        .unwrap();
        let err = execute(&pool, &hier, &g, &mut st).unwrap_err().to_string();
        assert!(err.contains("instance"), "{err}");
    }

    #[test]
    fn training_graph_fills_all_sharded_slots() {
        let (spec, hier, partition, pool, u0) = setup();
        let params = Arc::new(NetParams::init(&spec, 30).unwrap());
        let g = taskgraph::mg_train_step(
            &spec, &hier, &partition, 1, 2, RelaxKind::FCF, Granularity::PerStep,
        );
        let mut st = MultiExecState::initial_train(
            &hier,
            &[(u0.clone(), vec![3i32])],
            params.clone(),
            0.05,
        )
        .unwrap();
        let rep = execute(&pool, &hier, &g, &mut st).unwrap();
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "adj_f_relax"));
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "param_grad"));
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "param_update"));
        let out = st.into_training_outputs().unwrap();
        assert!(out.loss.is_finite());
        assert_eq!(out.instances.len(), 1);
        let inst = &out.instances[0];
        assert_eq!(inst.loss, out.loss);
        assert_eq!(inst.states.len(), hier.fine().n_points);
        assert_eq!(inst.lams.len(), hier.fine().n_points);
        assert_eq!(out.trunk_grads.len(), spec.n_res());
        assert_eq!(out.new_trunk.len(), spec.n_res());
        // updated params moved against the gradient direction
        for ((w_new, _), ((w_old, _), (dw, _))) in
            out.new_trunk.iter().zip(params.trunk.iter().zip(&out.trunk_grads))
        {
            let mut want = w_old.clone();
            want.axpy(-0.05, dw).unwrap();
            assert!(w_new.data() == want.data(), "param update is not θ − lr·g");
        }
    }

    #[test]
    fn two_instance_graph_reduces_and_updates_once() {
        // two micro-batch instances through one graph: per-instance grads,
        // one reduced (mean) gradient set, one post-SGD trunk
        let (spec, hier, partition, pool, u0) = setup();
        let params = Arc::new(NetParams::init(&spec, 30).unwrap());
        let groups = InstanceGroups::new(1, partition.n_devices()).unwrap();
        let g = taskgraph::mg_train_step_multi(
            &spec, &hier, &partition, &groups, 1, 2, RelaxKind::FCF, Granularity::PerStep, 2,
        )
        .unwrap();
        let mut rng = crate::util::prng::Rng::new(32);
        let u1 = Tensor::randn(&[1, 2, 6, 6], 0.8, &mut rng);
        let mut st = MultiExecState::initial_train(
            &hier,
            &[(u0.clone(), vec![3i32]), (u1, vec![5i32])],
            params.clone(),
            0.05,
        )
        .unwrap();
        let rep = execute(&pool, &hier, &g, &mut st).unwrap();
        assert!(rep.phase_s.iter().any(|(l, _)| *l == "reduce_grad"));
        // both instances appear in the event stream
        let insts: std::collections::BTreeSet<usize> =
            rep.events.iter().map(|e| e.instance).collect();
        assert_eq!(insts.len(), 2);
        let out = st.into_training_outputs().unwrap();
        assert_eq!(out.instances.len(), 2);
        // combined loss is the instance mean
        let want = (out.instances[0].loss + out.instances[1].loss) / 2.0;
        assert_eq!(out.loss, want);
        // the reduced gradient is the pairwise mean, bit-exactly
        for (i, (rw, _rb)) in out.trunk_grads.iter().enumerate() {
            let mut sum = pair_sum(
                &out.instances[0].trunk_grads[i],
                &out.instances[1].trunk_grads[i],
            )
            .unwrap();
            pair_scale(&mut sum, 1.0 / 2.0f32);
            assert!(rw.data() == sum.0.data(), "layer {i} reduced grad differs");
        }
        // post-SGD trunk uses the reduced gradient
        for ((w_new, _), ((w_old, _), (dw, _))) in
            out.new_trunk.iter().zip(params.trunk.iter().zip(&out.trunk_grads))
        {
            let mut want = w_old.clone();
            want.axpy(-0.05, dw).unwrap();
            assert!(w_new.data() == want.data(), "param update is not θ − lr·ĝ");
        }
    }

    #[test]
    fn session_matches_static_execution_bitwise() {
        // two requests streamed through one ExecSession produce the same
        // final states as running each one's graph through the fixed
        // executor — the dynamic-admission path adds scheduling, not math
        let (spec, hier, partition, pool, u0) = setup();
        let mut rng = crate::util::prng::Rng::new(33);
        let u1 = Tensor::randn(&[1, 2, 6, 6], 0.8, &mut rng);
        let g = || {
            taskgraph::mg_forward_with(
                &spec, &hier, &partition, 1, 2, RelaxKind::FCF, Granularity::PerStep,
            )
        };
        let mut want = Vec::new();
        for u in [&u0, &u1] {
            let mut st = MultiExecState::initial(&hier, u);
            execute(&pool, &hier, &g(), &mut st).unwrap();
            want.push(st.into_fine_states());
        }
        let mut session = ExecSession::new(&pool, &hier);
        let i0 = session.admit(g(), &u0).unwrap();
        let i1 = session.admit(g(), &u1).unwrap();
        assert_eq!((i0, i1), (0, 1));
        while session.wait(None).unwrap() {}
        let mut done: Vec<usize> = Vec::new();
        while let Some(k) = session.poll_finished() {
            done.push(k);
        }
        done.sort_unstable();
        assert_eq!(done, vec![0, 1]);
        for (k, w) in want.iter().enumerate() {
            let got = session.final_state(k).unwrap();
            assert!(
                got.data() == w.last().unwrap().data(),
                "instance {k} final state differs from static execution"
            );
            // completion timestamps: stamped, and consistent with the
            // instance's own kernel events
            let t = session.finished_at(k).expect("finished instance must be stamped");
            let last_end = session
                .report()
                .events
                .iter()
                .filter(|e| e.instance == k)
                .map(|e| e.t_end)
                .fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(t, last_end, "instance {k} finish time != last kernel retirement");
        }
        // events carry both instances
        let insts: std::collections::BTreeSet<usize> =
            session.report().events.iter().map(|e| e.instance).collect();
        assert_eq!(insts.len(), 2);
    }

    #[test]
    fn session_admits_while_in_flight_and_releases_instances() {
        let (spec, hier, partition, pool, u0) = setup();
        let g = || {
            taskgraph::mg_forward_with(
                &spec, &hier, &partition, 1, 1, RelaxKind::FCF, Granularity::PerStep,
            )
        };
        let mut session = ExecSession::new(&pool, &hier);
        session.admit(g(), &u0).unwrap();
        // pull one completion, then admit the second request mid-flight —
        // the continuous-batching move the fixed executor cannot make
        assert!(session.wait(None).unwrap());
        session.admit(g(), &u0).unwrap();
        // an in-flight instance is neither readable nor stamped
        assert!(session.final_state(1).is_err(), "in-flight instance must not be readable");
        assert!(session.finished_at(1).is_none());
        while session.wait(None).unwrap() {}
        let finished: Vec<usize> = std::iter::from_fn(|| session.poll_finished()).collect();
        assert_eq!(finished.len(), 2);
        // harvest + release instance 0; instance 1 stays readable
        let a = session.final_state(0).unwrap();
        session.release_instance(0).unwrap();
        assert!(session.final_state(0).is_err(), "released instance still readable");
        let b = session.final_state(1).unwrap();
        // same input + same graph ⇒ same output, bitwise
        assert!(a.data() == b.data());
        // a wait on an idle session reports no work rather than hanging
        assert!(!session.wait(Some(std::time::Duration::from_millis(1))).unwrap());
    }

    #[test]
    fn adversarial_priorities_do_not_change_results() {
        // the graph carries every RAW/WAR/WAW hazard, so ANY dispatch order
        // a priority vector induces stays bit-identical to min-id order
        let (spec, hier, partition, pool, u0) = setup();
        let g = taskgraph::mg_forward_with(
            &spec, &hier, &partition, 1, 2, RelaxKind::FCF, Granularity::PerStep,
        );
        let mut st_a = MultiExecState::initial(&hier, &u0);
        execute(&pool, &hier, &g, &mut st_a).unwrap();
        // highest-id-first: the exact reverse of the legacy tie-break
        let pri: Vec<f64> = g.tasks.iter().map(|t| t.id as f64).collect();
        let mut st_b = MultiExecState::initial(&hier, &u0);
        execute_prioritized(&pool, &hier, &g, &mut st_b, Some(&pri)).unwrap();
        let a = st_a.into_fine_states();
        let b = st_b.into_fine_states();
        for (k, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(x.data() == y.data(), "state {k} differs under reversed priorities");
        }
        // a mis-sized priority vector is an error, not a silent truncation
        let mut st_c = MultiExecState::initial(&hier, &u0);
        assert!(execute_prioritized(&pool, &hier, &g, &mut st_c, Some(&[0.0])).is_err());
    }

    #[test]
    fn session_rejects_non_executable_graphs() {
        let (spec, hier, _partition, pool, u0) = setup();
        let mut session = ExecSession::new(&pool, &hier);
        let g = taskgraph::serial_forward(&spec, 1, 1); // no payloads
        assert!(session.admit(g, &u0).is_err());
    }

    #[test]
    fn merge_phases_accumulates_by_label() {
        let mut acc: Vec<(&'static str, f64)> = vec![("a", 1.0)];
        merge_phases(&mut acc, &[("a", 2.0), ("b", 3.0)]);
        merge_phases(&mut acc, &[("b", 1.0)]);
        assert_eq!(acc, vec![("a", 3.0), ("b", 4.0)]);
    }

    /// Raw (pre-opening) micro-batch inputs for a `steps × micro` pipelined
    /// run — one `[1, C_in, H, W]` tensor + label per global instance.
    fn pipeline_inputs(
        spec: &NetSpec,
        k_steps: usize,
        micro: usize,
        seed: u64,
    ) -> Vec<(Tensor, Vec<i32>)> {
        let mut rng = crate::util::prng::Rng::new(seed);
        (0..k_steps * micro)
            .map(|gi| {
                let y = Tensor::randn(
                    &[1, spec.opening.in_channels, spec.opening.in_h, spec.opening.in_w],
                    0.8,
                    &mut rng,
                );
                (y, vec![(gi % 10) as i32])
            })
            .collect()
    }

    #[test]
    fn snapshot_ring_retires_versions_and_rejects_misuse() {
        let spec = Arc::new(NetSpec::micro());
        let params = NetParams::init(&spec, 40).unwrap();
        let n_layers = params.trunk.len();
        // admitted read counts: two against v0, one against v1
        let mut ring = SnapshotRing::new(&params, n_layers, vec![2, 1]);
        assert_eq!(ring.depth(), 1);
        // version 0 serves every slot: trunk, opening, fc
        ring.get(0, 0).unwrap();
        ring.get(0, n_layers).unwrap();
        ring.get(0, n_layers + 1).unwrap();
        // unwritten slots and double writes are hard errors
        let err = ring.get(1, 0).unwrap_err().to_string();
        assert!(err.contains("not yet written"), "{err}");
        let (w, b) = params.trunk[0].clone();
        ring.set(1, 0, w.clone(), b.clone()).unwrap();
        assert_eq!(ring.depth(), 2);
        let err = ring.set(1, 0, w, b).unwrap_err().to_string();
        assert!(err.contains("twice"), "{err}");
        // draining v0's admitted reads retires it the moment the last lands
        ring.note_read(0).unwrap();
        assert_eq!(ring.depth(), 2);
        ring.note_read(0).unwrap();
        assert_eq!(ring.depth(), 1);
        let err = ring.get(0, 0).unwrap_err().to_string();
        assert!(err.contains("retired"), "{err}");
        // a read beyond the admitted count is an accounting bug, not a no-op
        assert!(ring.note_read(0).is_err());
        // the newest version survives its own read drain (final parameters)
        ring.note_read(1).unwrap();
        assert_eq!(ring.depth(), 1);
        ring.get(1, 0).unwrap();
        assert_eq!(ring.peak_depth(), 2);
    }

    #[test]
    fn pipelined_barrier_and_staleness0_agree_bitwise() {
        // the two S = 0 composition modes differ only in WHERE the
        // cross-step edges sit; the executed arithmetic must be identical
        let (spec, hier, partition, pool, _u0) = setup();
        let params = Arc::new(NetParams::init(&spec, 30).unwrap());
        let groups = InstanceGroups::new(1, partition.n_devices()).unwrap();
        let inputs = pipeline_inputs(&spec, 2, 1, 41);
        let run = |sync| {
            let g = taskgraph::mg_train_pipeline(
                &spec, &hier, &partition, &groups, 1, 1, RelaxKind::FCF,
                Granularity::PerStep, 1, 2, sync,
            )
            .unwrap();
            let mut st = MultiExecState::initial_train_pipeline(
                &hier, spec.clone(), &g, &inputs, params.clone(), 0.05, 1, 0,
            )
            .unwrap();
            let rep = execute(&pool, &hier, &g, &mut st).unwrap();
            assert!(rep.kernels > 0);
            st.into_pipeline_outputs().unwrap()
        };
        let a = run(taskgraph::PipeSync::Barrier);
        let b = run(taskgraph::PipeSync::Staleness(0));
        assert_eq!(a.losses.len(), 2);
        assert!(a.losses.iter().all(|l| l.is_finite()));
        assert_eq!(a.losses, b.losses);
        for (x, y) in a.params.trunk.iter().zip(&b.params.trunk) {
            assert!(x.0.data() == y.0.data() && x.1.data() == y.1.data());
        }
        assert!(a.params.w_open.data() == b.params.w_open.data());
        assert!(a.params.b_open.data() == b.params.b_open.data());
        assert!(a.params.w_fc.data() == b.params.w_fc.data());
        assert!(a.params.b_fc.data() == b.params.b_fc.data());
        assert!(a.peak_ring_depth <= 2 && b.peak_ring_depth <= 2);
    }

    #[test]
    fn pipelined_staleness_run_bounds_ring_depth() {
        // K = 3 steps × M = 2 micro-batches at S = 1: reduce trees join each
        // step's pair, and the ring never holds more than S + 2 versions
        let (spec, hier, partition, pool, _u0) = setup();
        let params = Arc::new(NetParams::init(&spec, 30).unwrap());
        let groups = InstanceGroups::new(1, partition.n_devices()).unwrap();
        let inputs = pipeline_inputs(&spec, 3, 2, 42);
        let g = taskgraph::mg_train_pipeline(
            &spec, &hier, &partition, &groups, 1, 1, RelaxKind::FCF,
            Granularity::PerStep, 2, 3, taskgraph::PipeSync::Staleness(1),
        )
        .unwrap();
        let mut st = MultiExecState::initial_train_pipeline(
            &hier, spec.clone(), &g, &inputs, params.clone(), 0.05, 2, 1,
        )
        .unwrap();
        execute(&pool, &hier, &g, &mut st).unwrap();
        let out = st.into_pipeline_outputs().unwrap();
        assert_eq!(out.losses.len(), 3);
        assert!(out.losses.iter().all(|l| l.is_finite()));
        assert!(out.peak_ring_depth <= 3, "ring depth {} > S + 2", out.peak_ring_depth);
        // three updates landed: the final parameters moved off version 0
        assert!(out.params.w_fc.data() != params.w_fc.data());
    }

    #[test]
    fn live_trace_respects_staleness_bound() {
        // regression guard on the staleness edges: in the LIVE event trace,
        // no parameter-reading kernel of step t starts before the
        // ParamUpdate that produced version t − S retired — i.e. no reader
        // ever observes parameters more than S versions old
        let (spec, hier, partition, pool, _u0) = setup();
        let params = Arc::new(NetParams::init(&spec, 30).unwrap());
        let groups = InstanceGroups::new(1, partition.n_devices()).unwrap();
        let n_layers = hier.fine().n_points - 1;
        for s in [0usize, 1] {
            let inputs = pipeline_inputs(&spec, 3, 1, 43 + s as u64);
            let g = taskgraph::mg_train_pipeline(
                &spec, &hier, &partition, &groups, 1, 1, RelaxKind::FCF,
                Granularity::PerStep, 1, 3, taskgraph::PipeSync::Staleness(s),
            )
            .unwrap();
            let mut st = MultiExecState::initial_train_pipeline(
                &hier, spec.clone(), &g, &inputs, params.clone(), 0.05, 1, s,
            )
            .unwrap();
            let rep = execute(&pool, &hier, &g, &mut st).unwrap();
            // retirement time of each step's per-slot ParamUpdate (M = 1,
            // so a join task's instance tag IS its step)
            let mut pu_end = std::collections::HashMap::new();
            for e in &rep.events {
                let t = &g.tasks[e.task];
                if let Some(TaskOp::ParamUpdate { layer }) = t.op {
                    pu_end.insert((t.instance, layer), e.t_end);
                }
            }
            for e in &rep.events {
                let t = &g.tasks[e.task];
                let Some(op) = &t.op else { continue };
                if matches!(op, TaskOp::ParamUpdate { .. }) {
                    continue;
                }
                let step = t.instance;
                let need = step.saturating_sub(s);
                if need == 0 {
                    continue; // version 0 pre-exists the run
                }
                for slot in op_param_slots(op, &hier, n_layers) {
                    let end = *pu_end
                        .get(&(need - 1, slot))
                        .expect("every ParamUpdate must appear in the trace");
                    assert!(
                        e.t_start >= end,
                        "S={s}: step {step} task {} read slot {slot} (needs v{need}) \
                         at {:.9}, before its producing update retired at {end:.9}",
                        e.task,
                        e.t_start
                    );
                }
            }
        }
    }
}
