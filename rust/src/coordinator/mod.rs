//! The layer-parallel coordinator — the paper's systems contribution.
//!
//! The MGRIT engine exposes its work as a dependency DAG of per-point
//! primitives (F-relaxation updates, C-relaxation updates, residuals,
//! restriction, coarse substitution, correction — see `mgrit::taskgraph`).
//! This module executes that DAG concurrently:
//!
//! - [`streams::StreamPool`] — long-lived worker threads, one per *stream*
//!   (the CUDA-stream analogue). Each worker owns a private `BlockSolver`
//!   built by a [`crate::solver::SolverFactory`] (PJRT contexts are not
//!   `Send`, same as per-rank CuDNN handles). `submit_job` delivers typed
//!   completions — the event/callback primitive the executor retires on.
//!   [`streams::NodePools`] shards the substrate into one pool per modeled
//!   cluster node behind [`streams::RuntimePool`] (`--transport`).
//! - [`transport`] — the pluggable inter-node fabric of the sharded
//!   substrate: every cross-node `Comm` edge becomes a serialized message
//!   over a [`transport::Transport`] (`InProc` ships in-tree), paying live
//!   the per-tier byte path `perfmodel::Topology` prices in the simulator.
//! - [`partition::Partition`] — contiguous layer-block → device assignment
//!   (the paper's MPI model partitioning); [`partition::InstanceGroups`]
//!   maps micro-batch instances onto device groups.
//! - [`placement`] — the scheduling & placement layer: a
//!   [`placement::PlacementPolicy`] (`rank` → dispatch priority, `place` →
//!   device) planned once per graph against the `perfmodel` costs and then
//!   consumed identically by the live executor and the virtual-time sim.
//!   `Partition`'s static map is the `MinId` identity policy's answer; HEFT
//!   and lookahead re-place cost-aware.
//! - [`executor`] — the dependency-counting event-driven **multi-instance**
//!   executor: takes `Arc` handles on a task's input slots, ships it to its
//!   device's worker, and retires it on completion, releasing dependents
//!   immediately. One scheduler drains the union frontier of N concurrent
//!   graph instances — no per-phase and no inter-instance barriers.
//!   [`executor::ExecSession`] is its incremental form: instances are
//!   admitted and retired dynamically (the serving runtime's substrate).
//! - [`checkpoint`] — frontier snapshots of a running session plus training
//!   step checkpoints, both exact-roundtrip serialized so
//!   checkpoint → resume → finish is bit-identical to the uninterrupted run;
//!   `executor::ExecSession::{checkpoint, resume}` and the `train::*_ckpt`
//!   loops build on it, and worker recovery (retry + re-enqueue on surviving
//!   workers) keeps a session alive without one.
//! - [`driver::ParallelMgrit`] — builds the executable V-cycle graph (the
//!   same graph the simulator scores), runs it per MG iteration, keeps the
//!   boundary-traffic ledger, and exposes the kernel-event trace (the
//!   real-run analogue of the paper's nvprof Fig 5). `train_step_micro`
//!   pipelines M micro-batches through one composed training graph (hybrid
//!   data×layer parallelism).
//!
//! A complete parallel forward solve over two worker streams:
//!
//! ```
//! use std::sync::Arc;
//! use resnet_mgrit::coordinator::ParallelMgrit;
//! use resnet_mgrit::mgrit::{hierarchy::Hierarchy, MgritOptions};
//! use resnet_mgrit::model::{NetParams, NetSpec};
//! use resnet_mgrit::solver::host::HostSolver;
//! use resnet_mgrit::tensor::Tensor;
//! use resnet_mgrit::util::prng::Rng;
//!
//! let spec = Arc::new(NetSpec::micro());
//! let params = Arc::new(NetParams::init(&spec, 1).unwrap());
//! let (s2, p2) = (spec.clone(), params.clone());
//! let factory = move |_worker: usize| HostSolver::new(s2.clone(), p2.clone());
//! let hier = Hierarchy::two_level(spec.n_res(), spec.h(), 2).unwrap();
//! let driver = ParallelMgrit::new(factory, spec.clone(), hier, 2, 1).unwrap();
//!
//! let mut rng = Rng::new(2);
//! let u0 = Tensor::randn(&[1, 2, 6, 6], 0.5, &mut rng);
//! let (states, stats, metrics) = driver.solve(&u0, &MgritOptions::early_stopping(2)).unwrap();
//! assert_eq!(states.len(), spec.n_res() + 1);
//! assert_eq!(metrics.cycles, 2);
//! assert_eq!(stats.residual_norms.len(), 2);
//! ```

pub mod checkpoint;
pub mod driver;
pub mod executor;
pub mod partition;
pub mod placement;
pub mod streams;
pub mod transport;

pub use checkpoint::{SessionSnapshot, TrainCheckpoint};
pub use driver::{
    drive, DriveBackend, InstanceStep, MicroStepOutput, ParallelMgrit, PipelineRunOutput,
    RunMetrics, TrainStepOutput,
};
pub use executor::{
    ExecError, ExecEvent, ExecReport, ExecSession, InstanceOutputs, MultiExecState,
    MultiTrainingOutputs, RetryEvent, SnapshotRing, TaskOut,
};
pub use partition::{InstanceGroups, Partition};
pub use placement::{GraphCosts, PlaceCtx, Placement, PlacementKind, PlacementPolicy};
pub use streams::{JobDone, NodePools, RuntimePool, StreamPool, TraceEvent, WorkerPool};
pub use transport::{InProc, Transport, TransportMode, TransportStats};
