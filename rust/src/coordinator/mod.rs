//! The layer-parallel coordinator — the paper's systems contribution.
//!
//! The MGRIT engine exposes its work as independent per-block primitives
//! (F-relaxation per block, C-relaxation per C-point, residual/restriction
//! per C-point, layer-local parameter gradients). This module executes them
//! concurrently:
//!
//! - [`streams::StreamPool`] — long-lived worker threads, one per *stream*
//!   (the CUDA-stream analogue). Each worker owns a private `BlockSolver`
//!   built by a [`crate::solver::SolverFactory`] (PJRT contexts are not
//!   `Send`, same as per-rank CuDNN handles).
//! - [`partition::Partition`] — contiguous layer-block → device assignment
//!   (the paper's MPI model partitioning).
//! - [`driver::ParallelMgrit`] — the phase-parallel FCF/FAS cycle, with
//!   per-phase barriers, boundary-state "communication" accounting, and a
//!   kernel-event trace (the real-run analogue of the paper's nvprof Fig 5).

pub mod driver;
pub mod partition;
pub mod streams;

pub use driver::{ParallelMgrit, RunMetrics};
pub use partition::Partition;
pub use streams::{StreamPool, TraceEvent};
