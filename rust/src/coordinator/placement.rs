//! Cost-aware scheduling & placement policies shared by the live executor
//! and the virtual-time simulator.
//!
//! Historically dispatch order was baked into `coordinator::executor` (a
//! min-id ready heap) and placement into the graph builders (`Partition`'s
//! static block → device map) — placement never saw the cost model, so
//! reductions parked on the left operand's device and cheap tasks could
//! block the critical path. This module extracts both decisions behind one
//! [`PlacementPolicy`] trait:
//!
//! - [`PlacementPolicy::rank`] assigns every task a dispatch **priority**
//!   (higher dispatches first; ties break by lowest task id, so an all-equal
//!   priority vector reproduces the legacy min-id order bit-for-bit);
//! - [`PlacementPolicy::place`] picks a kernel's **device** given the
//!   planner's device states ([`PlaceCtx`]) — `Partition`'s static map is
//!   one *input* (the task's baked `device` field), not the decision.
//!
//! [`plan`] consults the policy once, ahead of execution, over the same
//! `perfmodel` costs the simulator prices — a deterministic Kahn list
//! schedule (pop the highest-priority ready task, place kernels at their
//! earliest-finish-time device) — and returns a [`Placement`]: the rewritten
//! graph (kernel devices remapped, Comm endpoints re-derived from their
//! producer/consumer placements, co-located transfers degenerating to
//! zero-cost) plus the per-task priority vector. Both the live executor
//! (`execute_prioritized` / `ExecSession::admit_prioritized`) and the sim
//! (`sim::simulate_prioritized` / `SimSession::admit_prioritized`) consume
//! that one `Placement`, so the virtual-time engine and the real run can
//! never drift on a scheduling decision.
//!
//! Three policies ship:
//!
//! | policy        | rank                  | place                          |
//! |---------------|-----------------------|--------------------------------|
//! | [`MinId`]     | constant (id order)   | the graph's baked device       |
//! | [`Heft`]      | HEFT upward rank      | min earliest-finish-time (EFT) |
//! | [`Lookahead`] | HEFT upward rank      | min EFT of the most critical child after a one-step lookahead |
//!
//! Placement may only change *when/where* a task runs, never *what* it
//! computes: workers are homogeneous (every `StreamPool` worker holds the
//! same solver + parameters) and the graph carries every RAW/WAR/WAW hazard,
//! so any topological execution on any device map stays bit-identical to the
//! serial references — asserted against `train::mg_step_serial_micro` and
//! `serving::serial_reference` in the integration tests.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind};
use crate::perfmodel::ClusterModel;
use crate::Result;

/// Per-task cost annotations a policy ranks against, computed once per graph
/// from the same [`ClusterModel`] the simulator prices.
#[derive(Debug, Clone)]
pub struct GraphCosts {
    /// Exclusive service time of each task: `DeviceModel::kernel_time` for
    /// kernels, `NetworkModel::message_time` for transfers.
    pub exec_s: Vec<f64>,
    /// HEFT upward rank: `exec_s[i] + max over dependents of rank_up` — the
    /// critical-path cost from task i to the graph sink (transfers
    /// contribute their message time as chain links).
    pub rank_up: Vec<f64>,
    /// Dependents adjacency (the reverse of `Task::deps`).
    pub dependents: Vec<Vec<usize>>,
}

impl GraphCosts {
    /// Price every task of `graph` under `cluster` and compute upward ranks.
    /// One reverse-id pass suffices: `TaskGraph::validate` guarantees deps
    /// point backwards, so ids are a topological order.
    pub fn new(graph: &TaskGraph, cluster: &ClusterModel) -> GraphCosts {
        let n = graph.tasks.len();
        let mut exec_s = vec![0.0f64; n];
        let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
        for t in &graph.tasks {
            exec_s[t.id] = match &t.kind {
                TaskKind::Kernel { class, flops, .. } => {
                    cluster.device.kernel_time(*class, *flops)
                }
                // tier-aware pricing on the graph's own endpoints: an
                // intra-node hop is cheaper than a fabric hop, so rank_up
                // and EFT see the topology the simulator will charge
                TaskKind::Comm { src, dst, bytes } => cluster.message_time(*src, *dst, *bytes),
            };
            for &d in &t.deps {
                dependents[d].push(t.id);
            }
        }
        let mut rank_up = vec![0.0f64; n];
        for id in (0..n).rev() {
            let tail = dependents[id].iter().map(|&d| rank_up[d]).fold(0.0f64, f64::max);
            rank_up[id] = exec_s[id] + tail;
        }
        GraphCosts { exec_s, rank_up, dependents }
    }
}

/// The planner's device states at one placement decision — the
/// `device_states` argument of [`PlacementPolicy::place`].
///
/// Times follow a serial-device model (each device drains its kernels one
/// at a time): a deliberate, deterministic *estimate* of the processor-
/// shared timeline the simulator replays — co-resident kernels share a
/// device's throughput there, so per-device total work (what EFT balances)
/// is conserved between the two models.
#[derive(Debug)]
pub struct PlaceCtx<'a> {
    /// The graph being planned (original devices, original Comm endpoints).
    pub graph: &'a TaskGraph,
    /// Per-task costs and upward ranks.
    pub costs: &'a GraphCosts,
    /// The cluster the costs were priced under.
    pub cluster: &'a ClusterModel,
    /// Per-device earliest idle time under the serial-device model.
    pub free_at: &'a [f64],
    /// Per-task planned finish time (valid where `placed`).
    pub finish: &'a [f64],
    /// Per-task planned device (valid where `placed`; the baked device
    /// otherwise).
    pub device: &'a [usize],
    /// Whether a task has been scheduled yet.
    pub placed: &'a [bool],
}

impl PlaceCtx<'_> {
    /// Devices available for placement.
    pub fn n_devices(&self) -> usize {
        self.free_at.len()
    }

    /// Earliest time `task`'s inputs are available on device `d`: the max of
    /// its dependencies' finish times, where a Comm dependency additionally
    /// pays its message time iff its producer was placed on a different
    /// device than `d` (co-located transfers are free — the same rule the
    /// executor and the sim apply to `src == dst` Comm tasks).
    pub fn ready_at(&self, task: &Task, d: usize) -> f64 {
        let mut t = 0.0f64;
        for &dep in &task.deps {
            let mut f = self.finish[dep];
            if let TaskKind::Comm { bytes, .. } = &self.graph.tasks[dep].kind {
                if let Some(p) = comm_producer(self.graph, dep) {
                    if self.placed[p] && self.device[p] != d {
                        f += self.cluster.message_time(self.device[p], d, *bytes);
                    }
                }
            }
            t = t.max(f);
        }
        t
    }

    /// Earliest start time of `task` on device `d` (input availability and
    /// device idleness).
    pub fn est(&self, task: &Task, d: usize) -> f64 {
        self.free_at[d].max(self.ready_at(task, d))
    }

    /// Earliest finish time of `task` on device `d`.
    pub fn eft(&self, task: &Task, d: usize) -> f64 {
        self.est(task, d) + self.costs.exec_s[task.id]
    }
}

/// A scheduling & placement policy: ranks tasks into dispatch priorities and
/// places kernels onto devices. Consulted once per graph by [`plan`]; the
/// resulting [`Placement`] drives both the live executor and the simulator.
pub trait PlacementPolicy {
    /// Short CLI/report name of this policy.
    fn name(&self) -> &'static str;

    /// Dispatch priority of `task` (higher dispatches first; ties break by
    /// lowest task id).
    fn rank(&self, task: &Task, graph: &TaskGraph, costs: &GraphCosts) -> f64;

    /// Execution device of kernel `task` given the planner's device states.
    fn place(&self, task: &Task, ctx: &PlaceCtx<'_>) -> usize;

    /// Whether this policy is the identity (keep the graph's baked devices
    /// and the legacy min-id dispatch order bit-for-bit). [`plan`] skips the
    /// graph rewrite for identity policies.
    fn is_identity(&self) -> bool {
        false
    }
}

/// Today's behavior, bit-for-bit: constant priority (so dispatch order
/// degenerates to min-id) and the graph's baked `Partition` device map.
#[derive(Debug, Clone, Copy, Default)]
pub struct MinId;

impl PlacementPolicy for MinId {
    fn name(&self) -> &'static str {
        "min-id"
    }

    fn rank(&self, _task: &Task, _graph: &TaskGraph, _costs: &GraphCosts) -> f64 {
        0.0
    }

    fn place(&self, task: &Task, _ctx: &PlaceCtx<'_>) -> usize {
        task.device
    }

    fn is_identity(&self) -> bool {
        true
    }
}

/// HEFT (heterogeneous-earliest-finish-time) list scheduling: rank by
/// upward critical-path cost, place each kernel on the device minimizing
/// its earliest finish time including transfer cost (ties break by lowest
/// device id).
#[derive(Debug, Clone, Copy, Default)]
pub struct Heft;

impl PlacementPolicy for Heft {
    fn name(&self) -> &'static str {
        "heft"
    }

    fn rank(&self, task: &Task, _graph: &TaskGraph, costs: &GraphCosts) -> f64 {
        costs.rank_up[task.id]
    }

    fn place(&self, task: &Task, ctx: &PlaceCtx<'_>) -> usize {
        let mut best = 0usize;
        let mut best_eft = f64::INFINITY;
        for d in 0..ctx.n_devices() {
            let e = ctx.eft(task, d);
            if e < best_eft {
                best = d;
                best_eft = e;
            }
        }
        best
    }
}

/// One-step EFT refinement of [`Heft`]: a kernel is placed to minimize the
/// earliest finish time of its most *critical* dependent (highest upward
/// rank, looking through Comm tasks to the consuming kernel), optimistically
/// assuming that child's other inputs are already available. Falls back to
/// plain EFT for sink tasks; ties break by the task's own EFT, then lowest
/// device id.
#[derive(Debug, Clone, Copy, Default)]
pub struct Lookahead;

impl Lookahead {
    /// The dependent kernel with the highest upward rank (Comm dependents
    /// resolve to their consuming kernel), if any.
    fn critical_child(task: &Task, ctx: &PlaceCtx<'_>) -> Option<usize> {
        let mut best: Option<usize> = None;
        for &dep in &ctx.costs.dependents[task.id] {
            let k = match ctx.graph.tasks[dep].kind {
                TaskKind::Kernel { .. } => dep,
                TaskKind::Comm { .. } => match comm_consumer(ctx.costs, dep) {
                    Some(c) if matches!(ctx.graph.tasks[c].kind, TaskKind::Kernel { .. }) => c,
                    _ => continue,
                },
            };
            if best.is_none_or(|b| ctx.costs.rank_up[k] > ctx.costs.rank_up[b]) {
                best = Some(k);
            }
        }
        best
    }

    /// Optimistic EFT of `child` over all devices, given `task` finishing at
    /// `task_eft` on device `d`: the edge from `task` (direct or through a
    /// Comm) pays its message time when the child lands elsewhere; other
    /// already-placed inputs contribute their planned finish; unplaced
    /// inputs contribute nothing.
    fn child_eft_after(
        task: &Task,
        d: usize,
        task_eft: f64,
        child: usize,
        ctx: &PlaceCtx<'_>,
    ) -> f64 {
        let c = &ctx.graph.tasks[child];
        let mut best = f64::INFINITY;
        for e in 0..ctx.n_devices() {
            let mut ready = 0.0f64;
            for &dep in &c.deps {
                let via_task = dep == task.id
                    || (matches!(ctx.graph.tasks[dep].kind, TaskKind::Comm { .. })
                        && ctx.graph.tasks[dep].deps.contains(&task.id));
                let f = if via_task {
                    let xfer = match &ctx.graph.tasks[dep].kind {
                        TaskKind::Comm { bytes, .. } if e != d => {
                            ctx.cluster.message_time(d, e, *bytes)
                        }
                        _ => 0.0,
                    };
                    task_eft + xfer
                } else if ctx.placed[dep] {
                    ctx.finish[dep]
                } else {
                    0.0
                };
                ready = ready.max(f);
            }
            let idle = if e == d { ctx.free_at[e].max(task_eft) } else { ctx.free_at[e] };
            best = best.min(ready.max(idle) + ctx.costs.exec_s[child]);
        }
        best
    }
}

impl PlacementPolicy for Lookahead {
    fn name(&self) -> &'static str {
        "lookahead"
    }

    fn rank(&self, task: &Task, _graph: &TaskGraph, costs: &GraphCosts) -> f64 {
        costs.rank_up[task.id]
    }

    fn place(&self, task: &Task, ctx: &PlaceCtx<'_>) -> usize {
        let child = Self::critical_child(task, ctx);
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        let mut best_eft = f64::INFINITY;
        for d in 0..ctx.n_devices() {
            let eft = ctx.eft(task, d);
            let score = match child {
                None => eft,
                Some(c) => Self::child_eft_after(task, d, eft, c, ctx),
            };
            if score < best_score || (score == best_score && eft < best_eft) {
                best = d;
                best_score = score;
                best_eft = eft;
            }
        }
        best
    }
}

/// Producer of a Comm task's payload: its highest-id dependency living on
/// the transfer's source device (hazard edges may add other deps), falling
/// back to the highest-id dependency.
fn comm_producer(graph: &TaskGraph, comm: usize) -> Option<usize> {
    let t = &graph.tasks[comm];
    let TaskKind::Comm { src, .. } = t.kind else { return None };
    t.deps
        .iter()
        .copied()
        .filter(|&d| graph.tasks[d].device == src)
        .max()
        .or_else(|| t.deps.iter().copied().max())
}

/// Consumer of a Comm task's payload: its lowest-id dependent.
fn comm_consumer(costs: &GraphCosts, comm: usize) -> Option<usize> {
    costs.dependents[comm].iter().copied().min()
}

/// The output of [`plan`]: everything the live executor and the simulator
/// need to execute one policy's scheduling decisions.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Name of the policy that produced this placement.
    pub policy: &'static str,
    /// Per-task dispatch priority (indexed by task id; higher first).
    pub priority: Vec<f64>,
    /// Per-task planned device (Comm tasks carry their destination).
    pub device: Vec<usize>,
    /// The graph with kernel devices remapped and Comm endpoints re-derived
    /// from their producer/consumer placements (co-located transfers keep
    /// `src == dst` and execute at zero cost). For an identity policy this
    /// is a verbatim clone of the input.
    pub graph: TaskGraph,
    /// The planner's serial-device makespan estimate (seconds) — an
    /// *estimate*; the simulator's processor-shared timeline is the score
    /// of record.
    pub est_makespan_s: f64,
}

/// Max-heap key for a priority-dispatched ready queue: higher priority pops
/// first, ties pop the **lowest** task id — so an all-equal priority vector
/// reproduces the legacy min-id dispatch order bit-for-bit. Shared by the
/// planner and the live executor.
#[derive(Debug, Clone, Copy)]
pub struct ReadyKey {
    /// Dispatch priority (higher pops first).
    pub pri: f64,
    /// Graph task id (ties pop lowest first).
    pub id: usize,
}

impl PartialEq for ReadyKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for ReadyKey {}

impl PartialOrd for ReadyKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ReadyKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.pri.total_cmp(&other.pri).then_with(|| other.id.cmp(&self.id))
    }
}

/// Consult `policy` over `graph` under `cluster`: a deterministic Kahn list
/// schedule popping the highest-priority ready task (ties by lowest id),
/// placing each kernel via [`PlacementPolicy::place`] at its policy-chosen
/// device under a serial-device EFT model. Comm tasks are transparent to
/// the planner's clock (their transfer cost is priced at the consumer, and
/// only when the endpoints differ — exactly when the executed graph pays
/// it). Returns the rewritten graph + priorities as a [`Placement`].
pub fn plan<P: PlacementPolicy + ?Sized>(
    policy: &P,
    graph: &TaskGraph,
    cluster: &ClusterModel,
) -> Result<Placement> {
    plan_with_occupancy(policy, graph, cluster, &[])
}

/// As [`plan`], seeding each device's earliest-free time from `busy` — the
/// live occupancy horizon (`ExecSession::device_occupancy` on the executor
/// side) at admission time, so a plan made while earlier admissions are
/// still in flight stops pricing against an empty cluster. Devices beyond
/// `busy.len()` start free. Occupancy shifts only the planner's EFT model —
/// where load-aware policies place work and what the makespan estimate
/// reads — never the graph's semantics.
pub fn plan_with_occupancy<P: PlacementPolicy + ?Sized>(
    policy: &P,
    graph: &TaskGraph,
    cluster: &ClusterModel,
    busy: &[f64],
) -> Result<Placement> {
    graph.validate()?;
    let n = graph.tasks.len();
    let n_dev = cluster.n_devices.max(1);
    let costs = GraphCosts::new(graph, cluster);
    let priority: Vec<f64> =
        graph.tasks.iter().map(|t| policy.rank(t, graph, &costs)).collect();

    let mut indeg = vec![0usize; n];
    for t in &graph.tasks {
        indeg[t.id] = t.deps.len();
    }
    let mut heap: BinaryHeap<ReadyKey> = graph
        .tasks
        .iter()
        .filter(|t| t.deps.is_empty())
        .map(|t| ReadyKey { pri: priority[t.id], id: t.id })
        .collect();
    let mut free_at: Vec<f64> =
        (0..n_dev).map(|d| busy.get(d).copied().unwrap_or(0.0).max(0.0)).collect();
    let mut finish = vec![0.0f64; n];
    let mut device: Vec<usize> = graph.tasks.iter().map(|t| t.device).collect();
    let mut placed = vec![false; n];
    let mut scheduled = 0usize;
    while let Some(ReadyKey { id, .. }) = heap.pop() {
        let task = &graph.tasks[id];
        match &task.kind {
            TaskKind::Comm { .. } => {
                // transparent: the transfer is priced at the consumer, and
                // only if the endpoints end up on different devices
                finish[id] = task.deps.iter().map(|&d| finish[d]).fold(0.0f64, f64::max);
            }
            TaskKind::Kernel { .. } => {
                let (d, eft) = {
                    let ctx = PlaceCtx {
                        graph,
                        costs: &costs,
                        cluster,
                        free_at: &free_at,
                        finish: &finish,
                        device: &device,
                        placed: &placed,
                    };
                    let d =
                        if policy.is_identity() { task.device } else { policy.place(task, &ctx) };
                    anyhow::ensure!(
                        d < n_dev,
                        "policy {} placed task {} on device {d} but the cluster has {n_dev}",
                        policy.name(),
                        id
                    );
                    (d, ctx.eft(task, d))
                };
                device[id] = d;
                finish[id] = eft;
                free_at[d] = eft;
            }
        }
        placed[id] = true;
        scheduled += 1;
        for &dep in &costs.dependents[id] {
            indeg[dep] -= 1;
            if indeg[dep] == 0 {
                heap.push(ReadyKey { pri: priority[dep], id: dep });
            }
        }
    }
    anyhow::ensure!(
        scheduled == n,
        "placement planner stalled at {scheduled}/{n} tasks (cyclic dependencies?)"
    );
    let est_makespan_s = finish.iter().fold(0.0f64, |a, &b| a.max(b));

    let mut tasks: Vec<Task> = graph.tasks.clone();
    if !policy.is_identity() {
        for t in &mut tasks {
            match &mut t.kind {
                TaskKind::Kernel { .. } => t.device = device[t.id],
                TaskKind::Comm { src, dst, .. } => {
                    if let Some(p) = comm_producer(graph, t.id) {
                        *src = device[p];
                    }
                    if let Some(c) = comm_consumer(&costs, t.id) {
                        *dst = device[c];
                    }
                    t.device = *dst;
                    device[t.id] = *dst;
                }
            }
        }
    }
    Ok(Placement {
        policy: policy.name(),
        priority,
        device,
        graph: TaskGraph { tasks },
        est_makespan_s,
    })
}

/// The shipped policy inventory, CLI-selectable via `--placement`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementKind {
    /// [`MinId`]: the graph's baked devices, min-id dispatch (the library
    /// default — no planning pass, bit-for-bit today's behavior).
    #[default]
    MinId,
    /// [`Heft`]: upward-rank priorities, min-EFT placement.
    Heft,
    /// [`Lookahead`]: upward-rank priorities, one-step EFT refinement.
    Lookahead,
}

impl PlacementKind {
    /// Parse a CLI spelling (`min-id` | `heft` | `lookahead`).
    pub fn parse(s: &str) -> Result<PlacementKind> {
        match s {
            "min-id" | "min_id" | "minid" => Ok(PlacementKind::MinId),
            "heft" => Ok(PlacementKind::Heft),
            "lookahead" | "heft-la" => Ok(PlacementKind::Lookahead),
            other => anyhow::bail!("unknown placement policy {other:?} (min-id|heft|lookahead)"),
        }
    }

    /// The policy's report/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            PlacementKind::MinId => "min-id",
            PlacementKind::Heft => "heft",
            PlacementKind::Lookahead => "lookahead",
        }
    }

    /// Instantiate the policy.
    pub fn build(&self) -> Box<dyn PlacementPolicy> {
        match self {
            PlacementKind::MinId => Box::new(MinId),
            PlacementKind::Heft => Box::new(Heft),
            PlacementKind::Lookahead => Box::new(Lookahead),
        }
    }

    /// Every shipped policy, in inventory order.
    pub fn all() -> [PlacementKind; 3] {
        [PlacementKind::MinId, PlacementKind::Heft, PlacementKind::Lookahead]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{InstanceGroups, Partition};
    use crate::mgrit::fas::RelaxKind;
    use crate::mgrit::hierarchy::Hierarchy;
    use crate::mgrit::taskgraph::{self, Granularity, KernelClass};
    use crate::model::NetSpec;

    fn forward_graph(devices: usize) -> (TaskGraph, ClusterModel) {
        let spec = NetSpec::fig6_depth(32);
        let hier = Hierarchy::two_level(32, spec.h(), 4).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let part = Partition::contiguous(n_blocks, devices).unwrap();
        let g = taskgraph::mg_forward_with(
            &spec,
            &hier,
            &part,
            1,
            1,
            RelaxKind::FCF,
            Granularity::PerStep,
        );
        (g, ClusterModel::tx_gaia(part.n_devices()))
    }

    #[test]
    fn ready_key_orders_by_priority_then_min_id() {
        let mut h = BinaryHeap::new();
        h.push(ReadyKey { pri: 0.0, id: 7 });
        h.push(ReadyKey { pri: 0.0, id: 3 });
        h.push(ReadyKey { pri: 1.0, id: 9 });
        h.push(ReadyKey { pri: 0.0, id: 5 });
        let order: Vec<usize> = std::iter::from_fn(|| h.pop().map(|k| k.id)).collect();
        // highest priority first; equal priorities pop in min-id order
        assert_eq!(order, vec![9, 3, 5, 7]);
    }

    #[test]
    fn upward_rank_grows_toward_sources() {
        let (g, cluster) = forward_graph(2);
        let costs = GraphCosts::new(&g, &cluster);
        // rank(dep) ≥ rank(dependent) + exec(dep) − ε for every edge
        for t in &g.tasks {
            for &d in &t.deps {
                assert!(
                    costs.rank_up[d] >= costs.rank_up[t.id] + costs.exec_s[d] - 1e-15,
                    "rank_up not monotone along edge {d} -> {}",
                    t.id
                );
            }
        }
        // a source's rank bounds the whole downstream chain
        let max_rank = costs.rank_up.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(max_rank > 0.0);
    }

    #[test]
    fn min_id_plan_is_identity() {
        let (g, cluster) = forward_graph(2);
        let p = plan(&MinId, &g, &cluster).unwrap();
        assert_eq!(p.policy, "min-id");
        assert!(p.priority.iter().all(|&x| x == 0.0));
        assert!(p.est_makespan_s > 0.0);
        assert_eq!(p.graph.tasks.len(), g.tasks.len());
        for (a, b) in p.graph.tasks.iter().zip(&g.tasks) {
            assert_eq!(a.device, b.device, "task {} device changed", b.id);
            assert_eq!(a.kind, b.kind, "task {} kind changed", b.id);
            assert_eq!(a.deps, b.deps, "task {} deps changed", b.id);
        }
        // zero-priority dispatch over the unchanged graph replays the legacy
        // timeline exactly
        let base = crate::sim::simulate(&g, &cluster, false).unwrap();
        let planned =
            crate::sim::simulate_prioritized(&p.graph, &cluster, false, Some(&p.priority))
                .unwrap();
        assert_eq!(base.makespan_s, planned.makespan_s);
        assert_eq!(base.n_comms, planned.n_comms);
    }

    #[test]
    fn planned_graphs_stay_valid_and_in_device_range() {
        let (g, cluster) = forward_graph(4);
        for kind in PlacementKind::all() {
            let p = plan(kind.build().as_ref(), &g, &cluster).unwrap();
            p.graph.validate().unwrap();
            assert_eq!(p.priority.len(), g.tasks.len());
            for t in &p.graph.tasks {
                assert!(t.device < cluster.n_devices, "{}: task {} device", kind.name(), t.id);
                if let TaskKind::Comm { src, dst, .. } = t.kind {
                    assert!(src < cluster.n_devices && dst < cluster.n_devices);
                    assert_eq!(t.device, dst);
                }
            }
            // the planner only remaps placement — never the work itself
            assert_eq!(p.graph.total_flops(), g.total_flops());
            assert_eq!(p.graph.n_comms(), g.n_comms());
        }
    }

    #[test]
    fn occupancy_seeding_shifts_work_off_busy_devices() {
        let (g, cluster) = forward_graph(2);
        // an empty busy vector reproduces plan() exactly
        let base = plan(&Heft, &g, &cluster).unwrap();
        let zero = plan_with_occupancy(&Heft, &g, &cluster, &[]).unwrap();
        assert_eq!(base.priority, zero.priority);
        assert_eq!(base.device, zero.device);
        assert_eq!(base.est_makespan_s, zero.est_makespan_s);
        // device 0 busy far beyond this graph's span: min-EFT placement must
        // route every kernel to device 1 instead of the empty-cluster split
        let busy = [1e3, 0.0];
        let shifted = plan_with_occupancy(&Heft, &g, &cluster, &busy).unwrap();
        shifted.graph.validate().unwrap();
        for t in &shifted.graph.tasks {
            if matches!(t.kind, TaskKind::Kernel { .. }) {
                assert_eq!(t.device, 1, "task {} planned onto the busy device", t.id);
            }
        }
        assert!(shifted.est_makespan_s >= base.est_makespan_s);
        // identity policies keep their baked devices regardless of occupancy
        let ident = plan_with_occupancy(&MinId, &g, &cluster, &busy).unwrap();
        for (a, b) in ident.graph.tasks.iter().zip(&g.tasks) {
            assert_eq!(a.device, b.device);
        }
    }

    #[test]
    fn comm_endpoints_follow_their_producer_and_consumer() {
        let (g, cluster) = forward_graph(4);
        let p = plan(&Heft, &g, &cluster).unwrap();
        for t in &p.graph.tasks {
            if let TaskKind::Comm { src, dst, .. } = t.kind {
                if let Some(prod) = comm_producer(&g, t.id) {
                    assert_eq!(src, p.device[prod], "comm {} src != producer device", t.id);
                }
                let costs = GraphCosts::new(&g, &cluster);
                if let Some(cons) = comm_consumer(&costs, t.id) {
                    assert_eq!(dst, p.device[cons], "comm {} dst != consumer device", t.id);
                }
            }
        }
    }

    #[test]
    fn single_device_cluster_pins_everything_to_device_zero() {
        let (g, cluster) = forward_graph(1);
        for kind in [PlacementKind::Heft, PlacementKind::Lookahead] {
            let p = plan(kind.build().as_ref(), &g, &cluster).unwrap();
            assert!(p.graph.tasks.iter().all(|t| t.device == 0));
        }
    }

    #[test]
    fn heft_ranks_critical_chain_above_leaves() {
        // hand-built diamond: a long chain and a cheap leaf from one source
        let k = |flops: f64| TaskKind::Kernel { label: "x", class: KernelClass::Conv, flops };
        let tasks = vec![
            Task { id: 0, instance: 0, device: 0, kind: k(1e8), deps: vec![], op: None },
            Task { id: 1, instance: 0, device: 0, kind: k(1e9), deps: vec![0], op: None },
            Task { id: 2, instance: 0, device: 1, kind: k(1e6), deps: vec![0], op: None },
            Task { id: 3, instance: 0, device: 0, kind: k(1e9), deps: vec![1], op: None },
        ];
        let g = TaskGraph { tasks };
        let cluster = ClusterModel::tx_gaia(2);
        let costs = GraphCosts::new(&g, &cluster);
        let heft = Heft;
        let chain = heft.rank(&g.tasks[1], &g, &costs);
        let leaf = heft.rank(&g.tasks[2], &g, &costs);
        assert!(chain > leaf, "critical chain must outrank the cheap leaf");
        // and the source outranks everything downstream
        assert!(heft.rank(&g.tasks[0], &g, &costs) > chain);
    }

    #[test]
    fn heft_strictly_beats_min_id_on_multi_instance_training_graph() {
        // the acceptance gate: on the M = 2 multi-instance training graph at
        // ≥ 2 devices, cost-aware ranks + EFT placement strictly reduce the
        // simulated makespan vs the static min-id schedule
        let spec = NetSpec::fig6_depth(64);
        let hier = Hierarchy::two_level(64, spec.h(), 4).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        for devices in [2usize, 4] {
            let part = Partition::contiguous(n_blocks, devices).unwrap();
            let groups = InstanceGroups::new(1, part.n_devices()).unwrap();
            let g = taskgraph::mg_train_step_multi(
                &spec,
                &hier,
                &part,
                &groups,
                1,
                2,
                RelaxKind::FCF,
                Granularity::PerStep,
                2,
            )
            .unwrap();
            let cluster = ClusterModel::tx_gaia(part.n_devices());
            let minid = plan(&MinId, &g, &cluster).unwrap();
            let heft = plan(&Heft, &g, &cluster).unwrap();
            let base = crate::sim::simulate_prioritized(
                &minid.graph,
                &cluster,
                false,
                Some(&minid.priority),
            )
            .unwrap();
            let tuned = crate::sim::simulate_prioritized(
                &heft.graph,
                &cluster,
                false,
                Some(&heft.priority),
            )
            .unwrap();
            assert!(
                tuned.makespan_s < base.makespan_s,
                "devices={devices}: heft {:.6e} !< min-id {:.6e}",
                tuned.makespan_s,
                base.makespan_s
            );
        }
    }

    #[test]
    fn placement_kind_round_trips() {
        for kind in PlacementKind::all() {
            assert_eq!(PlacementKind::parse(kind.name()).unwrap(), kind);
            assert_eq!(kind.build().name(), kind.name());
        }
        assert!(PlacementKind::parse("random").is_err());
        assert_eq!(PlacementKind::default(), PlacementKind::MinId);
    }
}
