//! Checkpoint primitives: exact-roundtrip serialization of tensors and
//! parameters, plus the two on-disk checkpoint records — a
//! [`SessionSnapshot`] (one executor session frozen at a retired-task
//! frontier) and a [`TrainCheckpoint`] (training-loop progress between
//! steps).
//!
//! Everything goes through `util::json`, whose number writer emits the
//! shortest f64 decimal that round-trips; every f32 is exactly representable
//! as f64 and the shortest-roundtrip property composes, so
//! `f32 → Json → text → Json → f32` is the identity. That is the whole
//! fault-tolerance story: a resumed run computes on bit-identical inputs, so
//! checkpoint → resume → finish equals the uninterrupted run bit-for-bit
//! (asserted by `tests/fault_integration.rs`).
//!
//! The *structure* of a session (its task graph) is deliberately NOT part of
//! a snapshot: graphs are pure functions of the run configuration, so the
//! resuming caller rebuilds the graph and the snapshot contributes only the
//! frontier (which task ids have retired) and the live state slots. This
//! keeps snapshots small and immune to graph-encoding drift.

use std::path::Path;

use anyhow::{anyhow, Context};

use crate::model::NetParams;
use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use crate::Result;

/// `{"dims": [...], "data": [...]}` — value-complete, exact for f32.
pub(crate) fn tensor_to_json(t: &Tensor) -> Json {
    json::obj(vec![
        ("dims", Json::Arr(t.dims().iter().map(|&d| json::num(d as f64)).collect())),
        ("data", Json::Arr(t.data().iter().map(|&v| json::num(v as f64)).collect())),
    ])
}

/// Inverse of [`tensor_to_json`].
pub(crate) fn tensor_from_json(j: &Json) -> Result<Tensor> {
    let dims = j
        .get("dims")?
        .as_arr()?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<Vec<_>>>()?;
    let data = j
        .get("data")?
        .as_arr()?
        .iter()
        .map(|v| v.as_f64().map(|f| f as f32))
        .collect::<Result<Vec<_>>>()?;
    Tensor::new(dims, data)
}

/// `{"w": tensor, "b": tensor}` for one (weight, bias) pair.
pub(crate) fn pair_to_json(p: &(Tensor, Tensor)) -> Json {
    json::obj(vec![("w", tensor_to_json(&p.0)), ("b", tensor_to_json(&p.1))])
}

/// Inverse of [`pair_to_json`].
pub(crate) fn pair_from_json(j: &Json) -> Result<(Tensor, Tensor)> {
    Ok((tensor_from_json(j.get("w")?)?, tensor_from_json(j.get("b")?)?))
}

/// Full network parameters: opening pair, trunk pairs, head pair.
pub(crate) fn params_to_json(p: &NetParams) -> Json {
    json::obj(vec![
        ("w_open", tensor_to_json(&p.w_open)),
        ("b_open", tensor_to_json(&p.b_open)),
        ("trunk", Json::Arr(p.trunk.iter().map(pair_to_json).collect())),
        ("w_fc", tensor_to_json(&p.w_fc)),
        ("b_fc", tensor_to_json(&p.b_fc)),
    ])
}

/// Inverse of [`params_to_json`].
pub(crate) fn params_from_json(j: &Json) -> Result<NetParams> {
    Ok(NetParams {
        w_open: tensor_from_json(j.get("w_open")?)?,
        b_open: tensor_from_json(j.get("b_open")?)?,
        trunk: j
            .get("trunk")?
            .as_arr()?
            .iter()
            .map(pair_from_json)
            .collect::<Result<Vec<_>>>()?,
        w_fc: tensor_from_json(j.get("w_fc")?)?,
        b_fc: tensor_from_json(j.get("b_fc")?)?,
    })
}

fn save_json(j: &Json, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        }
    }
    std::fs::write(path, j.to_string())
        .with_context(|| format!("writing checkpoint {}", path.display()))
}

fn load_json(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading checkpoint {}", path.display()))?;
    Json::parse(&text).with_context(|| format!("parsing checkpoint {}", path.display()))
}

/// One executor session frozen at a quiescent retired-task frontier
/// (`coordinator::executor::ExecSession::checkpoint`): which tasks of the
/// deterministically-rebuildable graph have retired, plus the serialized
/// live state (`MultiExecState::to_json`). `ExecSession::resume` turns it
/// back into a running session that executes exactly the un-retired tasks.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionSnapshot {
    /// Task count of the graph this snapshot covers — resume refuses a graph
    /// of any other size (the cheap guard against resuming a snapshot
    /// against the wrong run configuration).
    pub n_tasks: usize,
    /// Retired task ids, ascending.
    pub frontier: Vec<usize>,
    /// `MultiExecState::to_json` output: every live state slot.
    pub state: Json,
}

impl SessionSnapshot {
    /// Serialize, including a format version tag.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("version", json::num(1.0)),
            ("n_tasks", json::num(self.n_tasks as f64)),
            ("frontier", Json::Arr(self.frontier.iter().map(|&i| json::num(i as f64)).collect())),
            ("state", self.state.clone()),
        ])
    }

    /// Inverse of [`SessionSnapshot::to_json`].
    pub fn from_json(j: &Json) -> Result<SessionSnapshot> {
        let version = j.get("version")?.as_usize()?;
        if version != 1 {
            return Err(anyhow!("unsupported session snapshot version {version}"));
        }
        Ok(SessionSnapshot {
            n_tasks: j.get("n_tasks")?.as_usize()?,
            frontier: j
                .get("frontier")?
                .as_arr()?
                .iter()
                .map(|i| i.as_usize())
                .collect::<Result<Vec<_>>>()?,
            state: j.get("state")?.clone(),
        })
    }

    /// Write to `path` (parent directories are created).
    pub fn save(&self, path: &Path) -> Result<()> {
        save_json(&self.to_json(), path)
    }

    /// Read back what [`SessionSnapshot::save`] wrote.
    pub fn load(path: &Path) -> Result<SessionSnapshot> {
        SessionSnapshot::from_json(&load_json(path)?)
    }
}

/// Training-loop progress at a step boundary: the next step to run and the
/// exact parameters entering it. Everything else a resumed run needs (batch
/// schedule, learning rate, hierarchy) is a pure function of the training
/// config and the step index, so `train::*_ckpt` resumes bit-identically
/// from just this record.
#[derive(Debug, Clone)]
pub struct TrainCheckpoint {
    /// Index of the next training step to execute (steps `0..step` are done).
    pub step: usize,
    /// Parameters entering step `step`, bit-exact.
    pub params: NetParams,
}

impl TrainCheckpoint {
    /// Serialize, including a format version tag.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("version", json::num(1.0)),
            ("step", json::num(self.step as f64)),
            ("params", params_to_json(&self.params)),
        ])
    }

    /// Inverse of [`TrainCheckpoint::to_json`].
    pub fn from_json(j: &Json) -> Result<TrainCheckpoint> {
        let version = j.get("version")?.as_usize()?;
        if version != 1 {
            return Err(anyhow!("unsupported train checkpoint version {version}"));
        }
        Ok(TrainCheckpoint {
            step: j.get("step")?.as_usize()?,
            params: params_from_json(j.get("params")?)?,
        })
    }

    /// Write to `path` (parent directories are created).
    pub fn save(&self, path: &Path) -> Result<()> {
        save_json(&self.to_json(), path)
    }

    /// Read back what [`TrainCheckpoint::save`] wrote.
    pub fn load(path: &Path) -> Result<TrainCheckpoint> {
        TrainCheckpoint::from_json(&load_json(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::NetSpec;
    use crate::util::prng::Rng;

    #[test]
    fn tensor_roundtrip_is_bit_exact() {
        let vals = vec![
            0.0f32,
            -0.0,
            1.0,
            -1.5,
            1.0 / 3.0,
            core::f32::consts::PI,
            1e-30,
            -3.4e38,
            f32::MIN_POSITIVE,
        ];
        let t = Tensor::new(vec![3, 3], vals.clone()).unwrap();
        let back = tensor_from_json(&Json::parse(&tensor_to_json(&t).to_string()).unwrap()).unwrap();
        assert_eq!(back.dims(), t.dims());
        for (a, b) in t.data().iter().zip(back.data()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} round-tripped to {b}");
        }
    }

    #[test]
    fn params_roundtrip_is_bit_exact() {
        let spec = NetSpec::micro();
        let p = NetParams::init(&spec, 17).unwrap();
        let back =
            params_from_json(&Json::parse(&params_to_json(&p).to_string()).unwrap()).unwrap();
        let eq = |a: &Tensor, b: &Tensor| {
            assert_eq!(a.dims(), b.dims());
            for (x, y) in a.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        };
        eq(&p.w_open, &back.w_open);
        eq(&p.b_open, &back.b_open);
        assert_eq!(p.trunk.len(), back.trunk.len());
        for ((w, b), (w2, b2)) in p.trunk.iter().zip(&back.trunk) {
            eq(w, w2);
            eq(b, b2);
        }
        eq(&p.w_fc, &back.w_fc);
        eq(&p.b_fc, &back.b_fc);
    }

    #[test]
    fn session_snapshot_file_roundtrip() {
        let snap = SessionSnapshot {
            n_tasks: 42,
            frontier: vec![0, 1, 5, 7],
            state: json::obj(vec![("insts", Json::Arr(vec![]))]),
        };
        let dir = std::path::Path::new("target/checkpoint-selftest");
        let path = dir.join("snap.json");
        snap.save(&path).unwrap();
        let back = SessionSnapshot::load(&path).unwrap();
        assert_eq!(back, snap);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn train_checkpoint_file_roundtrip() {
        let spec = NetSpec::micro();
        let mut params = NetParams::init(&spec, 3).unwrap();
        // perturb so the record is not the seed initialization
        let mut rng = Rng::new(9);
        let w = params.trunk[0].0.data_mut();
        for v in w.iter_mut() {
            *v += rng.normal() * 0.1;
        }
        let ck = TrainCheckpoint { step: 5, params: params.clone() };
        let dir = std::path::Path::new("target/checkpoint-selftest-train");
        let path = dir.join("ck.json");
        ck.save(&path).unwrap();
        let back = TrainCheckpoint::load(&path).unwrap();
        assert_eq!(back.step, 5);
        assert_eq!(back.params.trunk[0].0.data(), params.trunk[0].0.data());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn wrong_version_is_rejected() {
        let j = json::obj(vec![
            ("version", json::num(2.0)),
            ("step", json::num(0.0)),
            ("params", Json::Null),
        ]);
        assert!(TrainCheckpoint::from_json(&j).is_err());
    }
}
