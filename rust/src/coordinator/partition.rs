//! Layer-block → device partitioning (the paper's contiguous MPI model
//! partitions: "layer blocks are distributed into contiguous model
//! partitions across GPUs").

use anyhow::{bail, Result};

/// A contiguous assignment of `n_blocks` layer blocks to `n_devices`
/// devices: device d owns blocks `bounds[d]..bounds[d+1]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    bounds: Vec<usize>,
}

impl Partition {
    /// Balanced contiguous partition: every device gets ⌊n/p⌋ or ⌈n/p⌉
    /// blocks, the larger shares first.
    pub fn contiguous(n_blocks: usize, n_devices: usize) -> Result<Partition> {
        if n_devices == 0 {
            bail!("need at least one device");
        }
        if n_blocks == 0 {
            bail!("need at least one block");
        }
        let p = n_devices.min(n_blocks);
        let base = n_blocks / p;
        let extra = n_blocks % p;
        let mut bounds = Vec::with_capacity(p + 1);
        bounds.push(0);
        for d in 0..p {
            let take = base + usize::from(d < extra);
            bounds.push(bounds[d] + take);
        }
        Ok(Partition { bounds })
    }

    /// Number of devices actually used (≤ requested when blocks < devices).
    pub fn n_devices(&self) -> usize {
        self.bounds.len() - 1
    }

    /// Total layer blocks covered.
    pub fn n_blocks(&self) -> usize {
        *self.bounds.last().unwrap()
    }

    /// Owning device of a block.
    pub fn device_of(&self, block: usize) -> usize {
        debug_assert!(block < self.n_blocks());
        // bounds is sorted; partition_point returns the first d with
        // bounds[d] > block, so the owner is d - 1
        self.bounds.partition_point(|&b| b <= block) - 1
    }

    /// Blocks owned by device d.
    pub fn blocks_of(&self, d: usize) -> std::ops::Range<usize> {
        self.bounds[d]..self.bounds[d + 1]
    }

    /// The contiguous block span of every device, in device order — what
    /// graph builders expand into a block → device map once, instead of
    /// re-deriving partition bounds point by point.
    pub fn spans(&self) -> Vec<std::ops::Range<usize>> {
        (0..self.n_devices()).map(|d| self.blocks_of(d)).collect()
    }

    /// Number of device-boundary crossings between consecutive blocks —
    /// each is one activation transfer during C-relaxation.
    pub fn n_boundaries(&self) -> usize {
        self.n_devices() - 1
    }
}

/// Instance → device-group mapping for the multi-instance graph runtime:
/// `n_groups` groups of `devices_per_group` devices each. Micro-batch
/// instance `k` runs its layer-block partition inside group `k mod n_groups`
/// (every task device id offset by `group · devices_per_group`).
///
/// One group — the default — means every instance shares all devices, which
/// maximizes cross-instance overlap (micro-batch k+1's forward V-cycles fill
/// the gaps of micro-batch k's adjoint wave). More groups give instances
/// disjoint device sets: classic data parallelism across groups with
/// layer parallelism inside each, joined only by the per-layer `ReduceGrad`
/// tree (whose cross-group hops become explicit Comm tasks).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InstanceGroups {
    n_groups: usize,
    devices_per_group: usize,
}

impl InstanceGroups {
    /// `n_groups` groups of `devices_per_group` devices each.
    pub fn new(n_groups: usize, devices_per_group: usize) -> Result<InstanceGroups> {
        if n_groups == 0 {
            bail!("need at least one device group");
        }
        if devices_per_group == 0 {
            bail!("need at least one device per group");
        }
        Ok(InstanceGroups { n_groups, devices_per_group })
    }

    /// Number of device groups.
    pub fn n_groups(&self) -> usize {
        self.n_groups
    }

    /// Devices inside each group.
    pub fn devices_per_group(&self) -> usize {
        self.devices_per_group
    }

    /// Total devices across all groups (the stream-pool size).
    pub fn n_devices(&self) -> usize {
        self.n_groups * self.devices_per_group
    }

    /// Group an instance's tasks run in.
    pub fn group_of(&self, instance: usize) -> usize {
        instance % self.n_groups
    }

    /// Device-id offset of an instance's tasks.
    pub fn device_offset(&self, instance: usize) -> usize {
        self.group_of(instance) * self.devices_per_group
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite as pt;

    #[test]
    fn balanced_exact_division() {
        let p = Partition::contiguous(8, 4).unwrap();
        assert_eq!(p.n_devices(), 4);
        for d in 0..4 {
            assert_eq!(p.blocks_of(d).len(), 2);
        }
    }

    #[test]
    fn balanced_with_remainder() {
        let p = Partition::contiguous(10, 4).unwrap();
        let sizes: Vec<usize> = (0..4).map(|d| p.blocks_of(d).len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
    }

    #[test]
    fn more_devices_than_blocks() {
        let p = Partition::contiguous(3, 8).unwrap();
        assert_eq!(p.n_devices(), 3);
        assert_eq!(p.n_blocks(), 3);
    }

    #[test]
    fn device_of_consistent_with_blocks_of() {
        let p = Partition::contiguous(11, 3).unwrap();
        for d in 0..p.n_devices() {
            for b in p.blocks_of(d) {
                assert_eq!(p.device_of(b), d);
            }
        }
    }

    #[test]
    fn single_device() {
        let p = Partition::contiguous(5, 1).unwrap();
        assert_eq!(p.n_devices(), 1);
        assert_eq!(p.n_boundaries(), 0);
        assert_eq!(p.device_of(4), 0);
    }

    #[test]
    fn single_block_many_devices() {
        // one block: exactly one device used, owning everything
        let p = Partition::contiguous(1, 16).unwrap();
        assert_eq!(p.n_devices(), 1);
        assert_eq!(p.n_blocks(), 1);
        assert_eq!(p.n_boundaries(), 0);
        assert_eq!(p.device_of(0), 0);
        assert_eq!(p.blocks_of(0), 0..1);
    }

    #[test]
    fn more_devices_than_blocks_each_device_owns_one() {
        // requested devices clamp to the block count; every used device owns
        // exactly one block and ownership stays contiguous
        for (n_blocks, n_req) in [(3usize, 8usize), (5, 64), (2, 3)] {
            let p = Partition::contiguous(n_blocks, n_req).unwrap();
            assert_eq!(p.n_devices(), n_blocks, "{n_blocks} blocks / {n_req} devices");
            assert_eq!(p.n_boundaries(), n_blocks - 1);
            for b in 0..n_blocks {
                assert_eq!(p.device_of(b), b);
                assert_eq!(p.blocks_of(b), b..b + 1);
            }
        }
    }

    #[test]
    fn non_divisible_split_is_contiguous_and_covers() {
        // 7 blocks over 3 devices: 3/2/2, larger shares first
        let p = Partition::contiguous(7, 3).unwrap();
        let sizes: Vec<usize> = (0..3).map(|d| p.blocks_of(d).len()).collect();
        assert_eq!(sizes, vec![3, 2, 2]);
        // coverage without gaps or overlap
        let mut covered = vec![0usize; 7];
        for d in 0..p.n_devices() {
            for b in p.blocks_of(d) {
                covered[b] += 1;
            }
        }
        assert!(covered.iter().all(|&c| c == 1), "{covered:?}");
    }

    #[test]
    fn spans_cover_all_blocks_in_device_order() {
        let p = Partition::contiguous(11, 3).unwrap();
        let spans = p.spans();
        assert_eq!(spans.len(), p.n_devices());
        let mut next = 0usize;
        for (d, span) in spans.iter().enumerate() {
            assert_eq!(span.start, next, "device {d} span not contiguous");
            for b in span.clone() {
                assert_eq!(p.device_of(b), d);
            }
            next = span.end;
        }
        assert_eq!(next, p.n_blocks());
    }

    #[test]
    fn rejects_degenerate() {
        assert!(Partition::contiguous(0, 2).is_err());
        assert!(Partition::contiguous(2, 0).is_err());
    }

    #[test]
    fn instance_groups_round_robin_offsets() {
        let g = InstanceGroups::new(2, 3).unwrap();
        assert_eq!(g.n_devices(), 6);
        assert_eq!(g.devices_per_group(), 3);
        // instances alternate groups; offsets step by devices_per_group
        assert_eq!(g.group_of(0), 0);
        assert_eq!(g.group_of(1), 1);
        assert_eq!(g.group_of(2), 0);
        assert_eq!(g.device_offset(0), 0);
        assert_eq!(g.device_offset(1), 3);
        assert_eq!(g.device_offset(5), 3);
    }

    #[test]
    fn single_group_shares_all_devices() {
        let g = InstanceGroups::new(1, 4).unwrap();
        for k in 0..8 {
            assert_eq!(g.group_of(k), 0);
            assert_eq!(g.device_offset(k), 0);
        }
        assert_eq!(g.n_devices(), 4);
    }

    #[test]
    fn instance_groups_reject_degenerate() {
        assert!(InstanceGroups::new(0, 2).is_err());
        assert!(InstanceGroups::new(2, 0).is_err());
    }

    #[test]
    fn prop_partition_invariants() {
        pt::check("partition-invariants", |rng| {
            let n = pt::gen_usize(rng, 1, 500);
            let p_req = pt::gen_usize(rng, 1, 64);
            let p = Partition::contiguous(n, p_req).unwrap();
            // full coverage, contiguous, balanced within 1
            assert_eq!(p.n_blocks(), n);
            let sizes: Vec<usize> = (0..p.n_devices()).map(|d| p.blocks_of(d).len()).collect();
            let mn = *sizes.iter().min().unwrap();
            let mx = *sizes.iter().max().unwrap();
            assert!(mx - mn <= 1, "unbalanced: {sizes:?}");
            assert!(sizes.iter().all(|&s| s >= 1));
            let total: usize = sizes.iter().sum();
            assert_eq!(total, n);
            // ownership is monotone non-decreasing over blocks
            let owners: Vec<usize> = (0..n).map(|b| p.device_of(b)).collect();
            for w in owners.windows(2) {
                assert!(w[1] == w[0] || w[1] == w[0] + 1);
            }
        });
    }
}
