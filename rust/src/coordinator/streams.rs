//! The stream pool: long-lived worker threads, one per layer-block stream —
//! the CPU analogue of the paper's "one CUDA stream + one OpenMP thread per
//! layer block". Each worker builds its own `BlockSolver` (PJRT contexts are
//! single-threaded) and records begin/end timestamps per job so a real run
//! can be rendered as a Fig 5-style concurrency timeline.
//!
//! The substrate comes in two shapes behind the [`WorkerPool`] trait: a flat
//! [`StreamPool`] (one shared address space — the legacy substrate) and the
//! sharded [`NodePools`] (one pool per modeled cluster node, cross-node
//! edges carried by a pluggable [`super::transport::Transport`]);
//! [`RuntimePool`] is the runtime's switch between them (`--transport`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::anyhow;

use super::transport::{Transport, TransportStats};
use crate::solver::SolverFactory;
use crate::util::faultpoint::{FaultAction, FaultPlan, FaultState};
use crate::Result;

/// One recorded job execution (for the concurrency timeline).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Worker (stream) id that ran the job.
    pub worker: usize,
    /// Job label.
    pub label: &'static str,
    /// Seconds since pool creation.
    pub t_start: f64,
    /// End timestamp (same clock).
    pub t_end: f64,
}

/// Completion record of one [`StreamPool::submit_job`] call: the job's id,
/// label, begin/end timestamps (pool clock) and its result — the signaling
/// primitive the dependency-driven executor retires tasks on.
#[derive(Debug)]
pub struct JobDone<T> {
    /// Caller-assigned job id (the executor uses the task id).
    pub id: usize,
    /// Job label.
    pub label: &'static str,
    /// Seconds since pool creation (same clock as the trace).
    pub t_start: f64,
    /// End timestamp (same clock).
    pub t_end: f64,
    /// What the job returned (or the error/panic it raised).
    pub result: Result<T>,
}

type Job<S> = Box<dyn FnOnce(&S) + Send>;

enum Msg<S> {
    Run { label: &'static str, job: Job<S> },
    Shutdown,
}

/// A pool of worker threads with per-worker job queues.
pub struct StreamPool<F: SolverFactory> {
    senders: Vec<Sender<Msg<F::Solver>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    trace: Arc<Mutex<Vec<TraceEvent>>>,
    /// Whether workers record [`TraceEvent`]s (on by default). Consumers
    /// with their own event ledger — the serving runtime keeps
    /// instance-tagged `ExecEvent`s — turn it off to skip the per-job mutex
    /// append on the completion path.
    trace_on: Arc<AtomicBool>,
    /// Deterministic fault-injection hooks (unarmed by default); see
    /// [`crate::util::faultpoint`].
    faults: Arc<FaultState>,
    epoch: Instant,
}

impl<F: SolverFactory> StreamPool<F> {
    /// Spawn `n` workers; each constructs its solver via `factory(worker_id)`
    /// inside its own thread.
    pub fn new(n: usize, factory: F) -> Result<StreamPool<F>> {
        StreamPool::with_epoch(n, factory, Instant::now())
    }

    /// Like [`StreamPool::new`] but with a caller-supplied clock epoch, so
    /// several pools — one per modeled node in a [`NodePools`] — share ONE
    /// comparable timeline for traces and `now()`.
    pub fn with_epoch(n: usize, factory: F, epoch: Instant) -> Result<StreamPool<F>> {
        let trace = Arc::new(Mutex::new(Vec::new()));
        let trace_on = Arc::new(AtomicBool::new(true));
        let faults = Arc::new(FaultState::new(n));
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        // collect construction errors through a channel so a failing factory
        // surfaces as Err instead of a wedged pool
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        for w in 0..n {
            let (tx, rx): (Sender<Msg<F::Solver>>, Receiver<Msg<F::Solver>>) = channel();
            let f = factory.clone();
            let tr = trace.clone();
            let tr_on = trace_on.clone();
            let flt = faults.clone();
            let rtx = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("stream-{w}"))
                .spawn(move || {
                    let solver = match f.build(w) {
                        Ok(s) => {
                            let _ = rtx.send(Ok(()));
                            s
                        }
                        Err(e) => {
                            let _ = rtx.send(Err(format!("worker {w}: {e}")));
                            return;
                        }
                    };
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            Msg::Run { label, job } => {
                                // an armed kill_worker_at point: the thread
                                // exits mid-queue, dropping this job without
                                // a completion — the silent-death failure
                                // mode the executor's liveness sweep detects
                                if flt.on_worker_msg(w) {
                                    break;
                                }
                                let t0 = epoch.elapsed().as_secs_f64();
                                // a plain-`submit` job that panics must not
                                // take the worker thread down with it (the
                                // old hang: dead worker, live sender, blocked
                                // scheduler); submit_job additionally wraps
                                // the body so the panic surfaces as an Err
                                // completion
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| job(&solver)),
                                );
                                let t1 = epoch.elapsed().as_secs_f64();
                                if tr_on.load(Ordering::Relaxed) {
                                    // tolerate poisoning: a panicked trace
                                    // reader must not wedge every worker
                                    tr.lock().unwrap_or_else(|p| p.into_inner()).push(
                                        TraceEvent { worker: w, label, t_start: t0, t_end: t1 },
                                    );
                                }
                            }
                            Msg::Shutdown => break,
                        }
                    }
                })
                .map_err(|e| anyhow!("spawning stream-{w}: {e}"))?;
            senders.push(tx);
            handles.push(handle);
        }
        drop(ready_tx);
        for r in ready_rx.iter().take(n) {
            if let Err(e) = r {
                return Err(anyhow!("solver construction failed: {e}"));
            }
        }
        Ok(StreamPool { senders, handles, trace, trace_on, faults, epoch })
    }

    /// Arm a deterministic [`FaultPlan`] (chaos testing): the next matching
    /// dispatch / worker message fires the plan's fault points. Arming
    /// [`FaultPlan::none`] disarms injection.
    pub fn arm_faults(&self, plan: FaultPlan) {
        self.faults.arm(plan);
    }

    /// Whether `worker`'s thread is still running. `false` for an
    /// out-of-range index or a worker that died (injected kill or crash) —
    /// the executor's recovery path reroutes work accordingly.
    pub fn worker_alive(&self, worker: usize) -> bool {
        self.handles.get(worker).map(|h| !h.is_finished()).unwrap_or(false)
    }

    /// Enable or disable [`TraceEvent`] recording (enabled by default).
    /// Disabling skips the per-job mutex append on every worker's
    /// completion path — for consumers that keep their own event ledger.
    pub fn set_trace_enabled(&self, on: bool) {
        self.trace_on.store(on, Ordering::Relaxed);
    }

    /// Number of worker threads (streams) in the pool.
    pub fn n_workers(&self) -> usize {
        self.senders.len()
    }

    /// Submit a job to a worker's queue (returns immediately).
    pub fn submit(
        &self,
        worker: usize,
        label: &'static str,
        job: impl FnOnce(&F::Solver) + Send + 'static,
    ) -> Result<()> {
        self.senders
            .get(worker)
            .ok_or_else(|| anyhow!("worker {worker} out of range"))?
            .send(Msg::Run { label, job: Box::new(job) })
            .map_err(|_| anyhow!("worker {worker} has shut down"))
    }

    /// Submit a value-returning job whose completion (result + timestamps)
    /// is delivered on `tx` tagged with `id`. This is the primitive the DAG
    /// executor uses to retire tasks as they finish, in completion order —
    /// the CPU analogue of a CUDA stream callback / event.
    ///
    /// A panicking job is caught and delivered as an `Err` completion, so a
    /// scheduler blocked on the channel always wakes up instead of hanging.
    pub fn submit_job<T: Send + 'static>(
        &self,
        worker: usize,
        label: &'static str,
        id: usize,
        tx: Sender<JobDone<T>>,
        job: impl FnOnce(&F::Solver) -> Result<T> + Send + 'static,
    ) -> Result<()> {
        let epoch = self.epoch;
        // fault injection keys on the dispatch, not the execution: the
        // decision is taken here on the (single) scheduler thread, so the
        // n-th dispatch is the same job on every run of the same graph
        let fault = self.faults.on_dispatch(id);
        self.submit(worker, label, move |solver| {
            let t_start = epoch.elapsed().as_secs_f64();
            let result =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match fault {
                    FaultAction::PanicJob => panic!("injected fault: kill task {id}"),
                    FaultAction::FailJob => {
                        Err(anyhow!("job {id} ({label}): injected dispatch fault"))
                    }
                    FaultAction::None => job(solver),
                }))
                    .unwrap_or_else(|payload| {
                        let msg = payload
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "<non-string panic>".into());
                        Err(anyhow!("job {id} ({label}) panicked: {msg}"))
                    });
            let t_end = epoch.elapsed().as_secs_f64();
            let _ = tx.send(JobDone { id, label, t_start, t_end, result });
        })
    }

    /// Snapshot of the trace so far.
    pub fn trace(&self) -> Vec<TraceEvent> {
        self.trace.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Discard the trace recorded so far.
    pub fn clear_trace(&self) {
        self.trace.lock().unwrap_or_else(|p| p.into_inner()).clear();
    }

    /// Seconds since pool creation (same clock as the trace).
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }
}

impl<F: SolverFactory> Drop for StreamPool<F> {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The executor-facing surface of an execution substrate. The DAG executor
/// and `ExecSession` are generic over this trait, so the same scheduler
/// drives a flat [`StreamPool`] (one shared address space), a sharded
/// [`NodePools`] (one pool per modeled node behind a
/// [`Transport`]), or the [`RuntimePool`] switch between them.
///
/// Workers are addressed by **global** index; `node_of` maps a worker to
/// its owning node, matching `perfmodel::Topology::nodes` (contiguous
/// ranges of `devices_per_node` workers). Single-node substrates keep the
/// defaults: every worker on node 0 and `ship` a loopback no-op.
pub trait WorkerPool<F: SolverFactory> {
    /// Number of workers (devices) addressable by this pool.
    fn n_workers(&self) -> usize;

    /// Whether `worker`'s thread is still running (`false` out of range).
    fn worker_alive(&self, worker: usize) -> bool;

    /// Seconds since pool creation (the trace clock).
    fn now(&self) -> f64;

    /// The modeled node owning global `worker` (0 on single-node pools).
    fn node_of(&self, _worker: usize) -> usize {
        0
    }

    /// Number of modeled nodes behind this pool.
    fn n_nodes(&self) -> usize {
        1
    }

    /// Submit a value-returning job to a worker's queue; semantics of
    /// [`StreamPool::submit_job`].
    fn submit_job<T: Send + 'static>(
        &self,
        worker: usize,
        label: &'static str,
        id: usize,
        tx: Sender<JobDone<T>>,
        job: impl FnOnce(&F::Solver) -> Result<T> + Send + 'static,
    ) -> Result<()>;

    /// Carry one serialized inter-node message from `src_node` to
    /// `dst_node`, returning the bytes as delivered. Single-node pools are
    /// loopback-only: the payload comes back untouched without crossing any
    /// fabric (the executor only ships when the nodes differ).
    fn ship(&self, _src_node: usize, _dst_node: usize, payload: Vec<u8>) -> Result<Vec<u8>> {
        Ok(payload)
    }
}

impl<F: SolverFactory> WorkerPool<F> for StreamPool<F> {
    fn n_workers(&self) -> usize {
        StreamPool::n_workers(self)
    }

    fn worker_alive(&self, worker: usize) -> bool {
        StreamPool::worker_alive(self, worker)
    }

    fn now(&self) -> f64 {
        StreamPool::now(self)
    }

    fn submit_job<T: Send + 'static>(
        &self,
        worker: usize,
        label: &'static str,
        id: usize,
        tx: Sender<JobDone<T>>,
        job: impl FnOnce(&F::Solver) -> Result<T> + Send + 'static,
    ) -> Result<()> {
        StreamPool::submit_job(self, worker, label, id, tx, job)
    }
}

/// The sharded execution substrate: one [`StreamPool`] per modeled cluster
/// node, all sharing one clock epoch, joined by a pluggable
/// [`Transport`]. Global worker `w` lives on node
/// `w / devices_per_node` at local index `w % devices_per_node` — the same
/// contiguous mapping `perfmodel::Topology::nodes` prices — so dispatch on
/// one node's pool never touches another node's queues, and every
/// cross-node `Comm` edge the executor retires pays an explicit
/// serialize→send→deserialize hop over the transport.
pub struct NodePools<F: SolverFactory> {
    pools: Vec<StreamPool<F>>,
    devices_per_node: usize,
    transport: Box<dyn Transport>,
}

impl<F: SolverFactory> NodePools<F> {
    /// Build `n_nodes` pools of `devices_per_node` workers each over
    /// `transport` (which must span at least `n_nodes` endpoints).
    pub fn new(
        n_nodes: usize,
        devices_per_node: usize,
        factory: F,
        transport: Box<dyn Transport>,
    ) -> Result<NodePools<F>> {
        anyhow::ensure!(n_nodes >= 1, "NodePools needs at least one node");
        anyhow::ensure!(devices_per_node >= 1, "NodePools needs at least one device per node");
        anyhow::ensure!(
            transport.n_nodes() >= n_nodes,
            "transport spans {} nodes, pool needs {n_nodes}",
            transport.n_nodes()
        );
        let epoch = Instant::now();
        let pools = (0..n_nodes)
            .map(|_| StreamPool::with_epoch(devices_per_node, factory.clone(), epoch))
            .collect::<Result<Vec<_>>>()?;
        Ok(NodePools { pools, devices_per_node, transport })
    }

    fn split(&self, worker: usize) -> (usize, usize) {
        (worker / self.devices_per_node, worker % self.devices_per_node)
    }

    /// Number of modeled nodes (member pools).
    pub fn n_nodes(&self) -> usize {
        self.pools.len()
    }

    /// Total workers across all node pools.
    pub fn n_workers(&self) -> usize {
        self.pools.len() * self.devices_per_node
    }

    /// The node owning global `worker`.
    pub fn node_of(&self, worker: usize) -> usize {
        worker / self.devices_per_node
    }

    /// Liveness of global `worker` (`false` out of range).
    pub fn worker_alive(&self, worker: usize) -> bool {
        let (node, local) = self.split(worker);
        self.pools.get(node).map(|p| p.worker_alive(local)).unwrap_or(false)
    }

    /// Seconds since pool creation — every member pool shares one epoch.
    pub fn now(&self) -> f64 {
        self.pools[0].now()
    }

    /// Enable or disable trace recording on every member pool.
    pub fn set_trace_enabled(&self, on: bool) {
        for p in &self.pools {
            p.set_trace_enabled(on);
        }
    }

    /// Merged trace of all member pools, worker ids translated to global
    /// indices and events ordered by start time (the per-pool clocks share
    /// one epoch, so timestamps are directly comparable).
    pub fn trace(&self) -> Vec<TraceEvent> {
        let mut all: Vec<TraceEvent> = Vec::new();
        for (node, p) in self.pools.iter().enumerate() {
            all.extend(p.trace().into_iter().map(|mut e| {
                e.worker += node * self.devices_per_node;
                e
            }));
        }
        all.sort_by(|a, b| a.t_start.total_cmp(&b.t_start));
        all
    }

    /// Discard every member pool's trace.
    pub fn clear_trace(&self) {
        for p in &self.pools {
            p.clear_trace();
        }
    }

    /// Arm a deterministic [`FaultPlan`] across the shard: a
    /// `kill_worker_at` global index is translated to the owning pool's
    /// local index (other pools get no kill); `kill_task` arms everywhere
    /// (a task id dispatches on exactly one pool, and the retry of a caught
    /// panic redispatches to the same still-alive worker, so the one-shot
    /// latch fires once); `fail_nth_dispatch` counts per member pool.
    pub fn arm_faults(&self, plan: FaultPlan) {
        for (node, pool) in self.pools.iter().enumerate() {
            let mut local = plan.clone();
            local.kill_worker_at = match plan.kill_worker_at {
                Some((w, nth)) if w / self.devices_per_node == node => {
                    Some((w % self.devices_per_node, nth))
                }
                _ => None,
            };
            pool.arm_faults(local);
        }
    }

    /// Traffic counters of the inter-node transport.
    pub fn transport_stats(&self) -> TransportStats {
        self.transport.stats()
    }

    /// Submit a value-returning job to global `worker`'s node pool.
    pub fn submit_job<T: Send + 'static>(
        &self,
        worker: usize,
        label: &'static str,
        id: usize,
        tx: Sender<JobDone<T>>,
        job: impl FnOnce(&F::Solver) -> Result<T> + Send + 'static,
    ) -> Result<()> {
        let (node, local) = self.split(worker);
        self.pools
            .get(node)
            .ok_or_else(|| anyhow!("worker {worker} out of range ({} workers)", self.n_workers()))?
            .submit_job(local, label, id, tx, job)
    }

    /// Carry one serialized message across the transport: enqueue on
    /// `src_node`'s NIC, deliver from `dst_node`'s inbox.
    pub fn ship(&self, src_node: usize, dst_node: usize, payload: Vec<u8>) -> Result<Vec<u8>> {
        self.transport.send(src_node, dst_node, payload)?;
        self.transport.recv(dst_node)
    }
}

impl<F: SolverFactory> WorkerPool<F> for NodePools<F> {
    fn n_workers(&self) -> usize {
        NodePools::n_workers(self)
    }

    fn worker_alive(&self, worker: usize) -> bool {
        NodePools::worker_alive(self, worker)
    }

    fn now(&self) -> f64 {
        NodePools::now(self)
    }

    fn node_of(&self, worker: usize) -> usize {
        NodePools::node_of(self, worker)
    }

    fn n_nodes(&self) -> usize {
        NodePools::n_nodes(self)
    }

    fn submit_job<T: Send + 'static>(
        &self,
        worker: usize,
        label: &'static str,
        id: usize,
        tx: Sender<JobDone<T>>,
        job: impl FnOnce(&F::Solver) -> Result<T> + Send + 'static,
    ) -> Result<()> {
        NodePools::submit_job(self, worker, label, id, tx, job)
    }

    fn ship(&self, src_node: usize, dst_node: usize, payload: Vec<u8>) -> Result<Vec<u8>> {
        NodePools::ship(self, src_node, dst_node, payload)
    }
}

/// The runtime's execution substrate: either the legacy shared pool or the
/// sharded per-node pools (the CLI `--transport` switch). Exposes the full
/// pool admin surface by delegation so driver/serving call sites are
/// substrate-agnostic.
pub enum RuntimePool<F: SolverFactory> {
    /// One shared [`StreamPool`], one address space.
    Shared(StreamPool<F>),
    /// One pool per modeled node behind a [`Transport`].
    Sharded(NodePools<F>),
}

impl<F: SolverFactory> RuntimePool<F> {
    /// Number of workers (devices).
    pub fn n_workers(&self) -> usize {
        match self {
            RuntimePool::Shared(p) => p.n_workers(),
            RuntimePool::Sharded(p) => p.n_workers(),
        }
    }

    /// Liveness of global `worker`.
    pub fn worker_alive(&self, worker: usize) -> bool {
        match self {
            RuntimePool::Shared(p) => p.worker_alive(worker),
            RuntimePool::Sharded(p) => p.worker_alive(worker),
        }
    }

    /// Seconds since pool creation.
    pub fn now(&self) -> f64 {
        match self {
            RuntimePool::Shared(p) => p.now(),
            RuntimePool::Sharded(p) => p.now(),
        }
    }

    /// The modeled node owning `worker` (always 0 when shared).
    pub fn node_of(&self, worker: usize) -> usize {
        match self {
            RuntimePool::Shared(_) => 0,
            RuntimePool::Sharded(p) => p.node_of(worker),
        }
    }

    /// Number of modeled nodes (1 when shared).
    pub fn n_nodes(&self) -> usize {
        match self {
            RuntimePool::Shared(_) => 1,
            RuntimePool::Sharded(p) => p.n_nodes(),
        }
    }

    /// Arm a deterministic [`FaultPlan`] (see [`NodePools::arm_faults`] for
    /// the sharded translation rules).
    pub fn arm_faults(&self, plan: FaultPlan) {
        match self {
            RuntimePool::Shared(p) => p.arm_faults(plan),
            RuntimePool::Sharded(p) => p.arm_faults(plan),
        }
    }

    /// Enable or disable [`TraceEvent`] recording.
    pub fn set_trace_enabled(&self, on: bool) {
        match self {
            RuntimePool::Shared(p) => p.set_trace_enabled(on),
            RuntimePool::Sharded(p) => p.set_trace_enabled(on),
        }
    }

    /// Snapshot of the trace so far (global worker indices).
    pub fn trace(&self) -> Vec<TraceEvent> {
        match self {
            RuntimePool::Shared(p) => p.trace(),
            RuntimePool::Sharded(p) => p.trace(),
        }
    }

    /// Discard the trace recorded so far.
    pub fn clear_trace(&self) {
        match self {
            RuntimePool::Shared(p) => p.clear_trace(),
            RuntimePool::Sharded(p) => p.clear_trace(),
        }
    }

    /// Inter-node traffic counters (`None` for the shared substrate, which
    /// has no transport).
    pub fn transport_stats(&self) -> Option<TransportStats> {
        match self {
            RuntimePool::Shared(_) => None,
            RuntimePool::Sharded(p) => Some(p.transport_stats()),
        }
    }

    /// Submit a value-returning job to global `worker`.
    pub fn submit_job<T: Send + 'static>(
        &self,
        worker: usize,
        label: &'static str,
        id: usize,
        tx: Sender<JobDone<T>>,
        job: impl FnOnce(&F::Solver) -> Result<T> + Send + 'static,
    ) -> Result<()> {
        match self {
            RuntimePool::Shared(p) => p.submit_job(worker, label, id, tx, job),
            RuntimePool::Sharded(p) => NodePools::submit_job(p, worker, label, id, tx, job),
        }
    }

    /// Carry one serialized inter-node message (loopback when shared).
    pub fn ship(&self, src_node: usize, dst_node: usize, payload: Vec<u8>) -> Result<Vec<u8>> {
        match self {
            RuntimePool::Shared(_) => Ok(payload),
            RuntimePool::Sharded(p) => NodePools::ship(p, src_node, dst_node, payload),
        }
    }
}

impl<F: SolverFactory> WorkerPool<F> for RuntimePool<F> {
    fn n_workers(&self) -> usize {
        RuntimePool::n_workers(self)
    }

    fn worker_alive(&self, worker: usize) -> bool {
        RuntimePool::worker_alive(self, worker)
    }

    fn now(&self) -> f64 {
        RuntimePool::now(self)
    }

    fn node_of(&self, worker: usize) -> usize {
        RuntimePool::node_of(self, worker)
    }

    fn n_nodes(&self) -> usize {
        RuntimePool::n_nodes(self)
    }

    fn submit_job<T: Send + 'static>(
        &self,
        worker: usize,
        label: &'static str,
        id: usize,
        tx: Sender<JobDone<T>>,
        job: impl FnOnce(&F::Solver) -> Result<T> + Send + 'static,
    ) -> Result<()> {
        RuntimePool::submit_job(self, worker, label, id, tx, job)
    }

    fn ship(&self, src_node: usize, dst_node: usize, payload: Vec<u8>) -> Result<Vec<u8>> {
        RuntimePool::ship(self, src_node, dst_node, payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NetParams, NetSpec};
    use crate::solver::host::HostSolver;
    use crate::solver::BlockSolver;
    use crate::tensor::Tensor;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn host_factory() -> impl SolverFactory<Solver = HostSolver> {
        let spec = Arc::new(NetSpec::micro());
        let params = Arc::new(NetParams::init(&spec, 1).unwrap());
        move |_w: usize| HostSolver::new(spec.clone(), params.clone())
    }

    #[test]
    fn jobs_run_on_their_workers_with_solver() {
        let pool = StreamPool::new(3, host_factory()).unwrap();
        let (tx, rx) = channel();
        for w in 0..3 {
            let tx = tx.clone();
            pool.submit(w, "probe", move |s: &HostSolver| {
                let u = Tensor::zeros(&[1, 2, 6, 6]);
                let v = s.step(0, 0.1, &u).unwrap();
                tx.send((w, v.len())).unwrap();
            })
            .unwrap();
        }
        let mut got: Vec<(usize, usize)> = rx.iter().take(3).collect();
        got.sort();
        assert_eq!(got, vec![(0, 72), (1, 72), (2, 72)]);
    }

    #[test]
    fn per_worker_queues_are_fifo() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        let (tx, rx) = channel();
        for i in 0..10 {
            let tx = tx.clone();
            pool.submit(0, "seq", move |_s| {
                tx.send(i).unwrap();
            })
            .unwrap();
        }
        let got: Vec<i32> = rx.iter().take(10).collect();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn trace_records_events() {
        let pool = StreamPool::new(2, host_factory()).unwrap();
        let (tx, rx) = channel();
        for w in 0..2 {
            let tx = tx.clone();
            pool.submit(w, "traced", move |_s| {
                std::thread::sleep(std::time::Duration::from_millis(2));
                tx.send(()).unwrap();
            })
            .unwrap();
        }
        let _: Vec<()> = rx.iter().take(2).collect();
        // events are pushed after the job body runs; wait for both
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(1);
        loop {
            let tr = pool.trace();
            if tr.len() == 2 || std::time::Instant::now() > deadline {
                assert_eq!(tr.len(), 2);
                for e in &tr {
                    assert!(e.t_end >= e.t_start);
                    assert_eq!(e.label, "traced");
                }
                break;
            }
            std::thread::yield_now();
        }
    }

    #[test]
    fn trace_can_be_disabled_and_reenabled() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        pool.set_trace_enabled(false);
        let (tx, rx) = channel();
        pool.submit(0, "silent", move |_s| {
            tx.send(()).unwrap();
        })
        .unwrap();
        rx.iter().next().unwrap();
        // the push is skipped entirely, not deferred
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(pool.trace().is_empty());
        pool.set_trace_enabled(true);
        let (tx, rx) = channel();
        pool.submit(0, "traced", move |_s| {
            tx.send(()).unwrap();
        })
        .unwrap();
        rx.iter().next().unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(1);
        while pool.trace().is_empty() && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(pool.trace().len(), 1);
        assert_eq!(pool.trace()[0].label, "traced");
    }

    #[test]
    fn submit_job_delivers_results_and_timestamps() {
        let pool = StreamPool::new(2, host_factory()).unwrap();
        let (tx, rx) = channel::<JobDone<usize>>();
        for (id, w) in [(10usize, 0usize), (11, 1)] {
            pool.submit_job(w, "job", id, tx.clone(), move |s: &HostSolver| {
                let u = Tensor::zeros(&[1, 2, 6, 6]);
                let v = s.step(0, 0.1, &u)?;
                Ok(v.len())
            })
            .unwrap();
        }
        let mut got: Vec<JobDone<usize>> = rx.iter().take(2).collect();
        got.sort_by_key(|d| d.id);
        assert_eq!(got.len(), 2);
        for (d, want_id) in got.iter().zip([10usize, 11]) {
            assert_eq!(d.id, want_id);
            assert_eq!(d.label, "job");
            assert_eq!(*d.result.as_ref().unwrap(), 72);
            assert!(d.t_end >= d.t_start);
        }
    }

    #[test]
    fn submit_job_converts_panics_to_errors() {
        // a panicking job must still deliver a completion (Err), not hang
        // the scheduler waiting on the channel
        let pool = StreamPool::new(1, host_factory()).unwrap();
        let (tx, rx) = channel::<JobDone<usize>>();
        pool.submit_job(0, "boom", 3, tx.clone(), move |_s: &HostSolver| {
            panic!("intentional panic");
        })
        .unwrap();
        let done = rx.iter().next().unwrap();
        assert_eq!(done.id, 3);
        let err = done.result.unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        // the worker survives and keeps serving jobs
        pool.submit_job(0, "after", 4, tx, move |_s: &HostSolver| Ok(7usize)).unwrap();
        let done = rx.iter().next().unwrap();
        assert_eq!(done.id, 4);
        assert_eq!(*done.result.as_ref().unwrap(), 7);
    }

    #[test]
    fn submit_job_propagates_errors() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        let (tx, rx) = channel::<JobDone<usize>>();
        pool.submit_job(0, "fail", 7, tx, move |_s: &HostSolver| {
            Err(anyhow!("intentional failure"))
        })
        .unwrap();
        let done = rx.iter().next().unwrap();
        assert_eq!(done.id, 7);
        assert!(done.result.is_err());
    }

    #[test]
    fn failing_factory_reports_error() {
        let factory = move |w: usize| -> Result<HostSolver> {
            Err(anyhow!("no solver for worker {w}"))
        };
        assert!(StreamPool::new(2, factory).is_err());
    }

    #[test]
    fn out_of_range_worker_rejected() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        assert!(pool.submit(5, "x", |_s| {}).is_err());
    }

    #[test]
    fn plain_submit_panic_does_not_kill_worker() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        pool.submit(0, "boom", |_s| panic!("intentional")).unwrap();
        let (tx, rx) = channel();
        pool.submit(0, "after", move |_s| tx.send(42).unwrap()).unwrap();
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap(), 42);
        assert!(pool.worker_alive(0));
    }

    #[test]
    fn injected_task_kill_surfaces_as_err_completion() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        pool.arm_faults(crate::util::faultpoint::FaultPlan {
            kill_task: Some(5),
            ..Default::default()
        });
        let (tx, rx) = channel::<JobDone<usize>>();
        pool.submit_job(0, "job", 5, tx.clone(), move |_s: &HostSolver| Ok(1usize)).unwrap();
        let done = rx.iter().next().unwrap();
        let err = done.result.unwrap_err().to_string();
        assert!(err.contains("injected fault"), "{err}");
        // one-shot: the same id re-dispatched runs clean (the retry path)
        pool.submit_job(0, "job", 5, tx, move |_s: &HostSolver| Ok(1usize)).unwrap();
        assert_eq!(*rx.iter().next().unwrap().result.as_ref().unwrap(), 1);
    }

    fn node_pools(n_nodes: usize, dpn: usize) -> NodePools<impl SolverFactory<Solver = HostSolver>> {
        NodePools::new(
            n_nodes,
            dpn,
            host_factory(),
            Box::new(crate::coordinator::transport::InProc::new(n_nodes)),
        )
        .unwrap()
    }

    #[test]
    fn node_pools_route_global_workers_to_member_pools() {
        let pools = node_pools(2, 2);
        assert_eq!(pools.n_workers(), 4);
        assert_eq!(pools.n_nodes(), 2);
        assert_eq!((pools.node_of(0), pools.node_of(1)), (0, 0));
        assert_eq!((pools.node_of(2), pools.node_of(3)), (1, 1));
        let (tx, rx) = channel::<JobDone<usize>>();
        for w in 0..4 {
            pools
                .submit_job(w, "probe", w, tx.clone(), move |s: &HostSolver| {
                    let u = Tensor::zeros(&[1, 2, 6, 6]);
                    Ok(s.step(0, 0.1, &u)?.len() + w)
                })
                .unwrap();
        }
        let mut got: Vec<usize> = rx.iter().take(4).map(|d| *d.result.as_ref().unwrap()).collect();
        got.sort();
        assert_eq!(got, vec![72, 73, 74, 75]);
        assert!(pools.submit_job(4, "oob", 9, tx, |_s| Ok(0usize)).is_err());
    }

    #[test]
    fn node_pools_trace_uses_global_worker_indices() {
        let pools = node_pools(2, 2);
        let (tx, rx) = channel::<JobDone<usize>>();
        for w in 0..4 {
            pools.submit_job(w, "traced", w, tx.clone(), move |_s| Ok(w)).unwrap();
        }
        let _: Vec<_> = rx.iter().take(4).collect();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while pools.trace().len() < 4 && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        let mut workers: Vec<usize> = pools.trace().iter().map(|e| e.worker).collect();
        workers.sort();
        assert_eq!(workers, vec![0, 1, 2, 3], "trace must report GLOBAL worker ids");
        // shared epoch: the merged trace is start-ordered
        let tr = pools.trace();
        assert!(tr.windows(2).all(|w| w[0].t_start <= w[1].t_start));
        pools.clear_trace();
        assert!(pools.trace().is_empty());
    }

    #[test]
    fn node_pools_kill_worker_translates_to_owning_pool() {
        let pools = node_pools(2, 2);
        // global worker 2 = node 1, local 0
        pools.arm_faults(crate::util::faultpoint::FaultPlan {
            kill_worker_at: Some((2, 1)),
            ..Default::default()
        });
        let (tx, _rx) = channel::<JobDone<usize>>();
        pools.submit_job(2, "dropped", 0, tx, |_s| Ok(0usize)).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while pools.worker_alive(2) && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert!(!pools.worker_alive(2), "global worker 2 must die");
        for w in [0usize, 1, 3] {
            assert!(pools.worker_alive(w), "worker {w} must survive");
        }
        assert!(!pools.worker_alive(9), "out of range reads as dead");
    }

    #[test]
    fn node_pools_ship_crosses_the_transport() {
        let pools = node_pools(2, 1);
        let back = pools.ship(0, 1, vec![1, 2, 3]).unwrap();
        assert_eq!(back, vec![1, 2, 3]);
        let st = pools.transport_stats();
        assert_eq!((st.messages, st.bytes, st.loopback), (1, 3, 0));
    }

    #[test]
    fn runtime_pool_delegates_both_substrates() {
        let shared: RuntimePool<_> = RuntimePool::Shared(StreamPool::new(2, host_factory()).unwrap());
        assert_eq!((shared.n_workers(), shared.n_nodes()), (2, 1));
        assert_eq!(shared.node_of(1), 0);
        assert!(shared.transport_stats().is_none());
        assert_eq!(shared.ship(0, 0, vec![7]).unwrap(), vec![7]);
        let sharded: RuntimePool<_> = RuntimePool::Sharded(node_pools(2, 1));
        assert_eq!((sharded.n_workers(), sharded.n_nodes()), (2, 2));
        assert_eq!(sharded.node_of(1), 1);
        assert_eq!(sharded.ship(1, 0, vec![9]).unwrap(), vec![9]);
        assert_eq!(sharded.transport_stats().unwrap().messages, 1);
    }

    #[test]
    fn injected_worker_kill_flips_liveness() {
        let pool = StreamPool::new(2, host_factory()).unwrap();
        pool.arm_faults(crate::util::faultpoint::FaultPlan {
            kill_worker_at: Some((0, 1)),
            ..Default::default()
        });
        // the doomed worker receives its first message and exits silently —
        // the job is dropped without any completion
        pool.submit(0, "dropped", |_s| {}).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while pool.worker_alive(0) && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert!(!pool.worker_alive(0), "killed worker must read as dead");
        assert!(pool.worker_alive(1), "survivor must read as alive");
        assert!(!pool.worker_alive(7), "out of range reads as dead");
    }
}
