//! The stream pool: long-lived worker threads, one per layer-block stream —
//! the CPU analogue of the paper's "one CUDA stream + one OpenMP thread per
//! layer block". Each worker builds its own `BlockSolver` (PJRT contexts are
//! single-threaded) and records begin/end timestamps per job so a real run
//! can be rendered as a Fig 5-style concurrency timeline.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::anyhow;

use crate::solver::SolverFactory;
use crate::Result;

/// One recorded job execution (for the concurrency timeline).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub worker: usize,
    pub label: &'static str,
    /// Seconds since pool creation.
    pub t_start: f64,
    pub t_end: f64,
}

type Job<S> = Box<dyn FnOnce(&S) + Send>;

enum Msg<S> {
    Run { label: &'static str, job: Job<S> },
    Shutdown,
}

/// A pool of worker threads with per-worker job queues.
pub struct StreamPool<F: SolverFactory> {
    senders: Vec<Sender<Msg<F::Solver>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    trace: Arc<Mutex<Vec<TraceEvent>>>,
    epoch: Instant,
}

impl<F: SolverFactory> StreamPool<F> {
    /// Spawn `n` workers; each constructs its solver via `factory(worker_id)`
    /// inside its own thread.
    pub fn new(n: usize, factory: F) -> Result<StreamPool<F>> {
        let epoch = Instant::now();
        let trace = Arc::new(Mutex::new(Vec::new()));
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        // collect construction errors through a channel so a failing factory
        // surfaces as Err instead of a wedged pool
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        for w in 0..n {
            let (tx, rx): (Sender<Msg<F::Solver>>, Receiver<Msg<F::Solver>>) = channel();
            let f = factory.clone();
            let tr = trace.clone();
            let rtx = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("stream-{w}"))
                .spawn(move || {
                    let solver = match f.build(w) {
                        Ok(s) => {
                            let _ = rtx.send(Ok(()));
                            s
                        }
                        Err(e) => {
                            let _ = rtx.send(Err(format!("worker {w}: {e}")));
                            return;
                        }
                    };
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            Msg::Run { label, job } => {
                                let t0 = epoch.elapsed().as_secs_f64();
                                job(&solver);
                                let t1 = epoch.elapsed().as_secs_f64();
                                tr.lock().unwrap().push(TraceEvent {
                                    worker: w,
                                    label,
                                    t_start: t0,
                                    t_end: t1,
                                });
                            }
                            Msg::Shutdown => break,
                        }
                    }
                })
                .map_err(|e| anyhow!("spawning stream-{w}: {e}"))?;
            senders.push(tx);
            handles.push(handle);
        }
        drop(ready_tx);
        for r in ready_rx.iter().take(n) {
            if let Err(e) = r {
                return Err(anyhow!("solver construction failed: {e}"));
            }
        }
        Ok(StreamPool { senders, handles, trace, epoch })
    }

    pub fn n_workers(&self) -> usize {
        self.senders.len()
    }

    /// Submit a job to a worker's queue (returns immediately).
    pub fn submit(
        &self,
        worker: usize,
        label: &'static str,
        job: impl FnOnce(&F::Solver) + Send + 'static,
    ) -> Result<()> {
        self.senders
            .get(worker)
            .ok_or_else(|| anyhow!("worker {worker} out of range"))?
            .send(Msg::Run { label, job: Box::new(job) })
            .map_err(|_| anyhow!("worker {worker} has shut down"))
    }

    /// Snapshot of the trace so far.
    pub fn trace(&self) -> Vec<TraceEvent> {
        self.trace.lock().unwrap().clone()
    }

    pub fn clear_trace(&self) {
        self.trace.lock().unwrap().clear();
    }

    /// Seconds since pool creation (same clock as the trace).
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }
}

impl<F: SolverFactory> Drop for StreamPool<F> {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NetParams, NetSpec};
    use crate::solver::host::HostSolver;
    use crate::solver::BlockSolver;
    use crate::tensor::Tensor;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn host_factory() -> impl SolverFactory<Solver = HostSolver> {
        let spec = Arc::new(NetSpec::micro());
        let params = Arc::new(NetParams::init(&spec, 1).unwrap());
        move |_w: usize| HostSolver::new(spec.clone(), params.clone())
    }

    #[test]
    fn jobs_run_on_their_workers_with_solver() {
        let pool = StreamPool::new(3, host_factory()).unwrap();
        let (tx, rx) = channel();
        for w in 0..3 {
            let tx = tx.clone();
            pool.submit(w, "probe", move |s: &HostSolver| {
                let u = Tensor::zeros(&[1, 2, 6, 6]);
                let v = s.step(0, 0.1, &u).unwrap();
                tx.send((w, v.len())).unwrap();
            })
            .unwrap();
        }
        let mut got: Vec<(usize, usize)> = rx.iter().take(3).collect();
        got.sort();
        assert_eq!(got, vec![(0, 72), (1, 72), (2, 72)]);
    }

    #[test]
    fn per_worker_queues_are_fifo() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        let (tx, rx) = channel();
        for i in 0..10 {
            let tx = tx.clone();
            pool.submit(0, "seq", move |_s| {
                tx.send(i).unwrap();
            })
            .unwrap();
        }
        let got: Vec<i32> = rx.iter().take(10).collect();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn trace_records_events() {
        let pool = StreamPool::new(2, host_factory()).unwrap();
        let (tx, rx) = channel();
        for w in 0..2 {
            let tx = tx.clone();
            pool.submit(w, "traced", move |_s| {
                std::thread::sleep(std::time::Duration::from_millis(2));
                tx.send(()).unwrap();
            })
            .unwrap();
        }
        let _: Vec<()> = rx.iter().take(2).collect();
        // events are pushed after the job body runs; wait for both
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(1);
        loop {
            let tr = pool.trace();
            if tr.len() == 2 || std::time::Instant::now() > deadline {
                assert_eq!(tr.len(), 2);
                for e in &tr {
                    assert!(e.t_end >= e.t_start);
                    assert_eq!(e.label, "traced");
                }
                break;
            }
            std::thread::yield_now();
        }
    }

    #[test]
    fn failing_factory_reports_error() {
        let factory = move |w: usize| -> Result<HostSolver> {
            Err(anyhow!("no solver for worker {w}"))
        };
        assert!(StreamPool::new(2, factory).is_err());
    }

    #[test]
    fn out_of_range_worker_rejected() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        assert!(pool.submit(5, "x", |_s| {}).is_err());
    }
}
