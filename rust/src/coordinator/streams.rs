//! The stream pool: long-lived worker threads, one per layer-block stream —
//! the CPU analogue of the paper's "one CUDA stream + one OpenMP thread per
//! layer block". Each worker builds its own `BlockSolver` (PJRT contexts are
//! single-threaded) and records begin/end timestamps per job so a real run
//! can be rendered as a Fig 5-style concurrency timeline.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::anyhow;

use crate::solver::SolverFactory;
use crate::util::faultpoint::{FaultAction, FaultPlan, FaultState};
use crate::Result;

/// One recorded job execution (for the concurrency timeline).
#[derive(Debug, Clone)]
pub struct TraceEvent {
    /// Worker (stream) id that ran the job.
    pub worker: usize,
    /// Job label.
    pub label: &'static str,
    /// Seconds since pool creation.
    pub t_start: f64,
    /// End timestamp (same clock).
    pub t_end: f64,
}

/// Completion record of one [`StreamPool::submit_job`] call: the job's id,
/// label, begin/end timestamps (pool clock) and its result — the signaling
/// primitive the dependency-driven executor retires tasks on.
#[derive(Debug)]
pub struct JobDone<T> {
    /// Caller-assigned job id (the executor uses the task id).
    pub id: usize,
    /// Job label.
    pub label: &'static str,
    /// Seconds since pool creation (same clock as the trace).
    pub t_start: f64,
    /// End timestamp (same clock).
    pub t_end: f64,
    /// What the job returned (or the error/panic it raised).
    pub result: Result<T>,
}

type Job<S> = Box<dyn FnOnce(&S) + Send>;

enum Msg<S> {
    Run { label: &'static str, job: Job<S> },
    Shutdown,
}

/// A pool of worker threads with per-worker job queues.
pub struct StreamPool<F: SolverFactory> {
    senders: Vec<Sender<Msg<F::Solver>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    trace: Arc<Mutex<Vec<TraceEvent>>>,
    /// Whether workers record [`TraceEvent`]s (on by default). Consumers
    /// with their own event ledger — the serving runtime keeps
    /// instance-tagged `ExecEvent`s — turn it off to skip the per-job mutex
    /// append on the completion path.
    trace_on: Arc<AtomicBool>,
    /// Deterministic fault-injection hooks (unarmed by default); see
    /// [`crate::util::faultpoint`].
    faults: Arc<FaultState>,
    epoch: Instant,
}

impl<F: SolverFactory> StreamPool<F> {
    /// Spawn `n` workers; each constructs its solver via `factory(worker_id)`
    /// inside its own thread.
    pub fn new(n: usize, factory: F) -> Result<StreamPool<F>> {
        let epoch = Instant::now();
        let trace = Arc::new(Mutex::new(Vec::new()));
        let trace_on = Arc::new(AtomicBool::new(true));
        let faults = Arc::new(FaultState::new(n));
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        // collect construction errors through a channel so a failing factory
        // surfaces as Err instead of a wedged pool
        let (ready_tx, ready_rx) = channel::<std::result::Result<(), String>>();
        for w in 0..n {
            let (tx, rx): (Sender<Msg<F::Solver>>, Receiver<Msg<F::Solver>>) = channel();
            let f = factory.clone();
            let tr = trace.clone();
            let tr_on = trace_on.clone();
            let flt = faults.clone();
            let rtx = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("stream-{w}"))
                .spawn(move || {
                    let solver = match f.build(w) {
                        Ok(s) => {
                            let _ = rtx.send(Ok(()));
                            s
                        }
                        Err(e) => {
                            let _ = rtx.send(Err(format!("worker {w}: {e}")));
                            return;
                        }
                    };
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            Msg::Run { label, job } => {
                                // an armed kill_worker_at point: the thread
                                // exits mid-queue, dropping this job without
                                // a completion — the silent-death failure
                                // mode the executor's liveness sweep detects
                                if flt.on_worker_msg(w) {
                                    break;
                                }
                                let t0 = epoch.elapsed().as_secs_f64();
                                // a plain-`submit` job that panics must not
                                // take the worker thread down with it (the
                                // old hang: dead worker, live sender, blocked
                                // scheduler); submit_job additionally wraps
                                // the body so the panic surfaces as an Err
                                // completion
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(|| job(&solver)),
                                );
                                let t1 = epoch.elapsed().as_secs_f64();
                                if tr_on.load(Ordering::Relaxed) {
                                    // tolerate poisoning: a panicked trace
                                    // reader must not wedge every worker
                                    tr.lock().unwrap_or_else(|p| p.into_inner()).push(
                                        TraceEvent { worker: w, label, t_start: t0, t_end: t1 },
                                    );
                                }
                            }
                            Msg::Shutdown => break,
                        }
                    }
                })
                .map_err(|e| anyhow!("spawning stream-{w}: {e}"))?;
            senders.push(tx);
            handles.push(handle);
        }
        drop(ready_tx);
        for r in ready_rx.iter().take(n) {
            if let Err(e) = r {
                return Err(anyhow!("solver construction failed: {e}"));
            }
        }
        Ok(StreamPool { senders, handles, trace, trace_on, faults, epoch })
    }

    /// Arm a deterministic [`FaultPlan`] (chaos testing): the next matching
    /// dispatch / worker message fires the plan's fault points. Arming
    /// [`FaultPlan::none`] disarms injection.
    pub fn arm_faults(&self, plan: FaultPlan) {
        self.faults.arm(plan);
    }

    /// Whether `worker`'s thread is still running. `false` for an
    /// out-of-range index or a worker that died (injected kill or crash) —
    /// the executor's recovery path reroutes work accordingly.
    pub fn worker_alive(&self, worker: usize) -> bool {
        self.handles.get(worker).map(|h| !h.is_finished()).unwrap_or(false)
    }

    /// Enable or disable [`TraceEvent`] recording (enabled by default).
    /// Disabling skips the per-job mutex append on every worker's
    /// completion path — for consumers that keep their own event ledger.
    pub fn set_trace_enabled(&self, on: bool) {
        self.trace_on.store(on, Ordering::Relaxed);
    }

    /// Number of worker threads (streams) in the pool.
    pub fn n_workers(&self) -> usize {
        self.senders.len()
    }

    /// Submit a job to a worker's queue (returns immediately).
    pub fn submit(
        &self,
        worker: usize,
        label: &'static str,
        job: impl FnOnce(&F::Solver) + Send + 'static,
    ) -> Result<()> {
        self.senders
            .get(worker)
            .ok_or_else(|| anyhow!("worker {worker} out of range"))?
            .send(Msg::Run { label, job: Box::new(job) })
            .map_err(|_| anyhow!("worker {worker} has shut down"))
    }

    /// Submit a value-returning job whose completion (result + timestamps)
    /// is delivered on `tx` tagged with `id`. This is the primitive the DAG
    /// executor uses to retire tasks as they finish, in completion order —
    /// the CPU analogue of a CUDA stream callback / event.
    ///
    /// A panicking job is caught and delivered as an `Err` completion, so a
    /// scheduler blocked on the channel always wakes up instead of hanging.
    pub fn submit_job<T: Send + 'static>(
        &self,
        worker: usize,
        label: &'static str,
        id: usize,
        tx: Sender<JobDone<T>>,
        job: impl FnOnce(&F::Solver) -> Result<T> + Send + 'static,
    ) -> Result<()> {
        let epoch = self.epoch;
        // fault injection keys on the dispatch, not the execution: the
        // decision is taken here on the (single) scheduler thread, so the
        // n-th dispatch is the same job on every run of the same graph
        let fault = self.faults.on_dispatch(id);
        self.submit(worker, label, move |solver| {
            let t_start = epoch.elapsed().as_secs_f64();
            let result =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match fault {
                    FaultAction::PanicJob => panic!("injected fault: kill task {id}"),
                    FaultAction::FailJob => {
                        Err(anyhow!("job {id} ({label}): injected dispatch fault"))
                    }
                    FaultAction::None => job(solver),
                }))
                    .unwrap_or_else(|payload| {
                        let msg = payload
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "<non-string panic>".into());
                        Err(anyhow!("job {id} ({label}) panicked: {msg}"))
                    });
            let t_end = epoch.elapsed().as_secs_f64();
            let _ = tx.send(JobDone { id, label, t_start, t_end, result });
        })
    }

    /// Snapshot of the trace so far.
    pub fn trace(&self) -> Vec<TraceEvent> {
        self.trace.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }

    /// Discard the trace recorded so far.
    pub fn clear_trace(&self) {
        self.trace.lock().unwrap_or_else(|p| p.into_inner()).clear();
    }

    /// Seconds since pool creation (same clock as the trace).
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }
}

impl<F: SolverFactory> Drop for StreamPool<F> {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NetParams, NetSpec};
    use crate::solver::host::HostSolver;
    use crate::solver::BlockSolver;
    use crate::tensor::Tensor;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn host_factory() -> impl SolverFactory<Solver = HostSolver> {
        let spec = Arc::new(NetSpec::micro());
        let params = Arc::new(NetParams::init(&spec, 1).unwrap());
        move |_w: usize| HostSolver::new(spec.clone(), params.clone())
    }

    #[test]
    fn jobs_run_on_their_workers_with_solver() {
        let pool = StreamPool::new(3, host_factory()).unwrap();
        let (tx, rx) = channel();
        for w in 0..3 {
            let tx = tx.clone();
            pool.submit(w, "probe", move |s: &HostSolver| {
                let u = Tensor::zeros(&[1, 2, 6, 6]);
                let v = s.step(0, 0.1, &u).unwrap();
                tx.send((w, v.len())).unwrap();
            })
            .unwrap();
        }
        let mut got: Vec<(usize, usize)> = rx.iter().take(3).collect();
        got.sort();
        assert_eq!(got, vec![(0, 72), (1, 72), (2, 72)]);
    }

    #[test]
    fn per_worker_queues_are_fifo() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        let (tx, rx) = channel();
        for i in 0..10 {
            let tx = tx.clone();
            pool.submit(0, "seq", move |_s| {
                tx.send(i).unwrap();
            })
            .unwrap();
        }
        let got: Vec<i32> = rx.iter().take(10).collect();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn trace_records_events() {
        let pool = StreamPool::new(2, host_factory()).unwrap();
        let (tx, rx) = channel();
        for w in 0..2 {
            let tx = tx.clone();
            pool.submit(w, "traced", move |_s| {
                std::thread::sleep(std::time::Duration::from_millis(2));
                tx.send(()).unwrap();
            })
            .unwrap();
        }
        let _: Vec<()> = rx.iter().take(2).collect();
        // events are pushed after the job body runs; wait for both
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(1);
        loop {
            let tr = pool.trace();
            if tr.len() == 2 || std::time::Instant::now() > deadline {
                assert_eq!(tr.len(), 2);
                for e in &tr {
                    assert!(e.t_end >= e.t_start);
                    assert_eq!(e.label, "traced");
                }
                break;
            }
            std::thread::yield_now();
        }
    }

    #[test]
    fn trace_can_be_disabled_and_reenabled() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        pool.set_trace_enabled(false);
        let (tx, rx) = channel();
        pool.submit(0, "silent", move |_s| {
            tx.send(()).unwrap();
        })
        .unwrap();
        rx.iter().next().unwrap();
        // the push is skipped entirely, not deferred
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(pool.trace().is_empty());
        pool.set_trace_enabled(true);
        let (tx, rx) = channel();
        pool.submit(0, "traced", move |_s| {
            tx.send(()).unwrap();
        })
        .unwrap();
        rx.iter().next().unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(1);
        while pool.trace().is_empty() && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert_eq!(pool.trace().len(), 1);
        assert_eq!(pool.trace()[0].label, "traced");
    }

    #[test]
    fn submit_job_delivers_results_and_timestamps() {
        let pool = StreamPool::new(2, host_factory()).unwrap();
        let (tx, rx) = channel::<JobDone<usize>>();
        for (id, w) in [(10usize, 0usize), (11, 1)] {
            pool.submit_job(w, "job", id, tx.clone(), move |s: &HostSolver| {
                let u = Tensor::zeros(&[1, 2, 6, 6]);
                let v = s.step(0, 0.1, &u)?;
                Ok(v.len())
            })
            .unwrap();
        }
        let mut got: Vec<JobDone<usize>> = rx.iter().take(2).collect();
        got.sort_by_key(|d| d.id);
        assert_eq!(got.len(), 2);
        for (d, want_id) in got.iter().zip([10usize, 11]) {
            assert_eq!(d.id, want_id);
            assert_eq!(d.label, "job");
            assert_eq!(*d.result.as_ref().unwrap(), 72);
            assert!(d.t_end >= d.t_start);
        }
    }

    #[test]
    fn submit_job_converts_panics_to_errors() {
        // a panicking job must still deliver a completion (Err), not hang
        // the scheduler waiting on the channel
        let pool = StreamPool::new(1, host_factory()).unwrap();
        let (tx, rx) = channel::<JobDone<usize>>();
        pool.submit_job(0, "boom", 3, tx.clone(), move |_s: &HostSolver| {
            panic!("intentional panic");
        })
        .unwrap();
        let done = rx.iter().next().unwrap();
        assert_eq!(done.id, 3);
        let err = done.result.unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
        // the worker survives and keeps serving jobs
        pool.submit_job(0, "after", 4, tx, move |_s: &HostSolver| Ok(7usize)).unwrap();
        let done = rx.iter().next().unwrap();
        assert_eq!(done.id, 4);
        assert_eq!(*done.result.as_ref().unwrap(), 7);
    }

    #[test]
    fn submit_job_propagates_errors() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        let (tx, rx) = channel::<JobDone<usize>>();
        pool.submit_job(0, "fail", 7, tx, move |_s: &HostSolver| {
            Err(anyhow!("intentional failure"))
        })
        .unwrap();
        let done = rx.iter().next().unwrap();
        assert_eq!(done.id, 7);
        assert!(done.result.is_err());
    }

    #[test]
    fn failing_factory_reports_error() {
        let factory = move |w: usize| -> Result<HostSolver> {
            Err(anyhow!("no solver for worker {w}"))
        };
        assert!(StreamPool::new(2, factory).is_err());
    }

    #[test]
    fn out_of_range_worker_rejected() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        assert!(pool.submit(5, "x", |_s| {}).is_err());
    }

    #[test]
    fn plain_submit_panic_does_not_kill_worker() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        pool.submit(0, "boom", |_s| panic!("intentional")).unwrap();
        let (tx, rx) = channel();
        pool.submit(0, "after", move |_s| tx.send(42).unwrap()).unwrap();
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap(), 42);
        assert!(pool.worker_alive(0));
    }

    #[test]
    fn injected_task_kill_surfaces_as_err_completion() {
        let pool = StreamPool::new(1, host_factory()).unwrap();
        pool.arm_faults(crate::util::faultpoint::FaultPlan {
            kill_task: Some(5),
            ..Default::default()
        });
        let (tx, rx) = channel::<JobDone<usize>>();
        pool.submit_job(0, "job", 5, tx.clone(), move |_s: &HostSolver| Ok(1usize)).unwrap();
        let done = rx.iter().next().unwrap();
        let err = done.result.unwrap_err().to_string();
        assert!(err.contains("injected fault"), "{err}");
        // one-shot: the same id re-dispatched runs clean (the retry path)
        pool.submit_job(0, "job", 5, tx, move |_s: &HostSolver| Ok(1usize)).unwrap();
        assert_eq!(*rx.iter().next().unwrap().result.as_ref().unwrap(), 1);
    }

    #[test]
    fn injected_worker_kill_flips_liveness() {
        let pool = StreamPool::new(2, host_factory()).unwrap();
        pool.arm_faults(crate::util::faultpoint::FaultPlan {
            kill_worker_at: Some((0, 1)),
            ..Default::default()
        });
        // the doomed worker receives its first message and exits silently —
        // the job is dropped without any completion
        pool.submit(0, "dropped", |_s| {}).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while pool.worker_alive(0) && std::time::Instant::now() < deadline {
            std::thread::yield_now();
        }
        assert!(!pool.worker_alive(0), "killed worker must read as dead");
        assert!(pool.worker_alive(1), "survivor must read as alive");
        assert!(!pool.worker_alive(7), "out of range reads as dead");
    }
}
