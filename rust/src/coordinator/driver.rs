//! The phase-parallel MGRIT driver: executes the FAS cycle with every
//! per-block primitive fanned out to the stream pool, per-phase barriers
//! (the CUDA-stream-sync analogue), and explicit accounting of the
//! activation traffic that crosses device partitions (the paper's MPI
//! communication during C-relaxation).
//!
//! The driver produces *numerically identical* results to the serial engine
//! in `mgrit::fas` — asserted by `tests/mgrit_integration.rs` — because each
//! point update performs the same operations on the same inputs; only the
//! execution order across independent blocks differs.

use std::sync::mpsc::channel;

use anyhow::anyhow;

use super::partition::Partition;
use super::streams::StreamPool;
use crate::mgrit::fas::{CycleStats, LevelState, MgritOptions, RelaxKind};
use crate::mgrit::hierarchy::Hierarchy;
use crate::solver::{BlockSolver, SolverFactory};
use crate::tensor::Tensor;
use crate::Result;

/// Metrics of one parallel solve (feeds Fig 5/6-style reporting for real runs).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// (phase label, wall seconds) in execution order.
    pub phases: Vec<(&'static str, f64)>,
    /// Activation bytes that crossed a device boundary.
    pub comm_bytes: u64,
    /// Number of boundary transfers.
    pub comm_events: usize,
    /// Completed cycles.
    pub cycles: usize,
    /// ‖R_h‖ after each cycle.
    pub residual_norms: Vec<f64>,
}

impl RunMetrics {
    /// Total seconds across phases.
    pub fn total_s(&self) -> f64 {
        self.phases.iter().map(|(_, s)| s).sum()
    }

    /// Seconds spent in a given phase label.
    pub fn phase_s(&self, label: &str) -> f64 {
        self.phases.iter().filter(|(l, _)| *l == label).map(|(_, s)| s).sum()
    }
}

/// Phase-parallel MGRIT over a stream pool.
pub struct ParallelMgrit<F: SolverFactory> {
    pool: StreamPool<F>,
    hier: Hierarchy,
    partition: Partition,
    /// Bytes of one layer state (for comm accounting).
    state_bytes: u64,
}

impl<F: SolverFactory> ParallelMgrit<F> {
    /// `n_devices` workers over the hierarchy's fine-level blocks.
    pub fn new(
        factory: F,
        hier: Hierarchy,
        n_devices: usize,
        state_bytes: u64,
    ) -> Result<ParallelMgrit<F>> {
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let partition = Partition::contiguous(n_blocks, n_devices)?;
        let pool = StreamPool::new(partition.n_devices(), factory)?;
        Ok(ParallelMgrit { pool, hier, partition, state_bytes })
    }

    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    pub fn pool(&self) -> &StreamPool<F> {
        &self.pool
    }

    pub fn hierarchy(&self) -> &Hierarchy {
        &self.hier
    }

    /// Device owning point `j` of level `level` (via its fine-level block).
    fn device_of_point(&self, level: usize, j: usize) -> usize {
        let fine_idx = j * self.hier.levels[level].stride;
        let block = (fine_idx / self.hier.coarsen).min(self.partition.n_blocks() - 1);
        self.partition.device_of(block)
    }

    /// Record a transfer if `src` and `dst` devices differ.
    fn account_comm(&self, m: &mut RunMetrics, src: usize, dst: usize) {
        if src != dst {
            m.comm_bytes += self.state_bytes;
            m.comm_events += 1;
        }
    }

    /// Fan a set of jobs out to the pool and gather results in input order.
    /// Each job is (worker, closure). A barrier: returns when all complete.
    fn run_jobs<T: Send + 'static>(
        &self,
        label: &'static str,
        jobs: Vec<(usize, Box<dyn FnOnce(&F::Solver) -> Result<T> + Send>)>,
    ) -> Result<Vec<T>> {
        let n = jobs.len();
        let (tx, rx) = channel::<(usize, Result<T>)>();
        for (idx, (worker, job)) in jobs.into_iter().enumerate() {
            let tx = tx.clone();
            self.pool.submit(worker, label, move |solver| {
                let _ = tx.send((idx, job(solver)));
            })?;
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (idx, res) in rx.iter().take(n) {
            out[idx] = Some(res?);
        }
        out.into_iter()
            .enumerate()
            .map(|(i, v)| v.ok_or_else(|| anyhow!("job {i} of phase {label} never reported")))
            .collect()
    }

    /// Parallel F-relaxation on one level: every block's F-point run is one
    /// job on the block's device.
    fn f_relax_phase(
        &self,
        level: usize,
        st: &mut LevelState,
        m: &mut RunMetrics,
    ) -> Result<()> {
        let t0 = std::time::Instant::now();
        let lvl = self.hier.levels[level].clone();
        let c = self.hier.coarsen;
        let mut jobs: Vec<(usize, Box<dyn FnOnce(&F::Solver) -> Result<Vec<Tensor>> + Send>)> =
            Vec::new();
        let mut spans = Vec::new();
        for b in lvl.blocks(c) {
            if b.n_fpoints() == 0 {
                continue;
            }
            let worker = self.device_of_point(level, b.cpoint);
            let u0 = st.u[b.cpoint].clone();
            let g: Option<Vec<Tensor>> =
                st.g.as_ref().map(|g| g[b.cpoint + 1..=b.f_end].to_vec());
            let lvl2 = lvl.clone();
            let count = b.n_fpoints();
            let start_theta = lvl.theta_idx(b.cpoint);
            let stride = lvl.stride;
            spans.push(b);
            jobs.push((
                worker,
                Box::new(move |solver: &F::Solver| {
                    match g {
                        // fine level (g ≡ 0): the block artifact fast-path
                        None => solver.block_fprop(start_theta, stride, count, lvl2.h, &u0),
                        // FAS levels: per-point update u = Φ(u_prev) + g
                        Some(g) => {
                            let mut states = Vec::with_capacity(count);
                            let mut u = u0;
                            for (j, gj) in g.iter().enumerate() {
                                let mut v =
                                    solver.step(start_theta + j * stride, lvl2.h, &u)?;
                                v.axpy(1.0, gj)?;
                                states.push(v.clone());
                                u = v;
                            }
                            Ok(states)
                        }
                    }
                }),
            ));
        }
        let results = self.run_jobs("f_relax", jobs)?;
        for (b, states) in spans.into_iter().zip(results) {
            for (off, v) in states.into_iter().enumerate() {
                st.u[b.cpoint + 1 + off] = v;
            }
        }
        m.phases.push(("f_relax", t0.elapsed().as_secs_f64()));
        Ok(())
    }

    /// Parallel C-relaxation: each C-point updates from the preceding
    /// F-point, which lives on the *previous* block — the phase that incurs
    /// boundary communication in the paper's MPI implementation.
    fn c_relax_phase(
        &self,
        level: usize,
        st: &mut LevelState,
        m: &mut RunMetrics,
    ) -> Result<()> {
        let t0 = std::time::Instant::now();
        let lvl = self.hier.levels[level].clone();
        let c = self.hier.coarsen;
        let mut jobs: Vec<(usize, Box<dyn FnOnce(&F::Solver) -> Result<Tensor> + Send>)> =
            Vec::new();
        let mut points = Vec::new();
        for cp in lvl.cpoints(c) {
            if cp == 0 {
                continue;
            }
            let dst = self.device_of_point(level, cp);
            let src = self.device_of_point(level, cp - 1);
            self.account_comm(m, src, dst);
            let u_prev = st.u[cp - 1].clone();
            let g = st.g.as_ref().map(|g| g[cp].clone());
            let theta = lvl.theta_idx(cp - 1);
            let h = lvl.h;
            points.push(cp);
            jobs.push((
                dst,
                Box::new(move |solver: &F::Solver| {
                    let mut v = solver.step(theta, h, &u_prev)?;
                    if let Some(gj) = g {
                        v.axpy(1.0, &gj)?;
                    }
                    Ok(v)
                }),
            ));
        }
        let results = self.run_jobs("c_relax", jobs)?;
        for (cp, v) in points.into_iter().zip(results) {
            st.u[cp] = v;
        }
        m.phases.push(("c_relax", t0.elapsed().as_secs_f64()));
        Ok(())
    }

    /// Parallel residual computation at all C-points > 0.
    fn residual_phase(
        &self,
        level: usize,
        st: &LevelState,
        m: &mut RunMetrics,
    ) -> Result<Vec<Tensor>> {
        let t0 = std::time::Instant::now();
        let lvl = self.hier.levels[level].clone();
        let c = self.hier.coarsen;
        let mut jobs: Vec<(usize, Box<dyn FnOnce(&F::Solver) -> Result<Tensor> + Send>)> =
            Vec::new();
        for cp in lvl.cpoints(c) {
            if cp == 0 {
                continue;
            }
            let dst = self.device_of_point(level, cp);
            let src = self.device_of_point(level, cp - 1);
            self.account_comm(m, src, dst);
            let u_prev = st.u[cp - 1].clone();
            let u_cur = st.u[cp].clone();
            let g = st.g.as_ref().map(|g| g[cp].clone());
            let theta = lvl.theta_idx(cp - 1);
            let h = lvl.h;
            jobs.push((
                dst,
                Box::new(move |solver: &F::Solver| {
                    let mut r = solver.step(theta, h, &u_prev)?;
                    if let Some(gj) = g {
                        r.axpy(1.0, &gj)?;
                    }
                    r.axpy(-1.0, &u_cur)?;
                    Ok(r)
                }),
            ));
        }
        let res = self.run_jobs("residual", jobs)?;
        m.phases.push(("residual", t0.elapsed().as_secs_f64()));
        Ok(res)
    }

    /// Parallel restriction: build the coarse FAS right-hand side from the
    /// residuals (already computed) and the injected C-point states.
    fn restrict_phase(
        &self,
        level: usize,
        st: &LevelState,
        residuals: Vec<Tensor>,
        m: &mut RunMetrics,
    ) -> Result<(LevelState, Vec<Tensor>)> {
        let t0 = std::time::Instant::now();
        let c = self.hier.coarsen;
        let coarse = self.hier.levels[level + 1].clone();
        let injected: Vec<Tensor> =
            (0..coarse.n_points).map(|j| st.u[j * c].clone()).collect();
        let mut jobs: Vec<(usize, Box<dyn FnOnce(&F::Solver) -> Result<Tensor> + Send>)> =
            Vec::new();
        for j in 1..coarse.n_points {
            let dst = self.device_of_point(level + 1, j);
            let src = self.device_of_point(level + 1, j - 1);
            self.account_comm(m, src, dst);
            let inj_prev = injected[j - 1].clone();
            let inj_cur = injected[j].clone();
            let mut r = residuals[j - 1].clone(); // residual at fine point j·c
            let theta = coarse.theta_idx(j - 1);
            let h = coarse.h;
            jobs.push((
                dst,
                Box::new(move |solver: &F::Solver| {
                    let phi = solver.step(theta, h, &inj_prev)?;
                    r.axpy(1.0, &inj_cur)?;
                    r.axpy(-1.0, &phi)?;
                    Ok(r)
                }),
            ));
        }
        let mut g = vec![Tensor::zeros(injected[0].dims())];
        g.extend(self.run_jobs("restrict", jobs)?);
        m.phases.push(("restrict", t0.elapsed().as_secs_f64()));
        Ok((LevelState { u: injected.clone(), g: Some(g) }, injected))
    }

    /// Exact coarsest-level solve: sequential forward substitution. In the
    /// distributed schedule this pipelines device-to-device in place (one
    /// boundary transfer per partition crossing); the local execution runs
    /// it on worker 0, and the comm ledger records the pipeline crossings.
    fn coarse_solve_phase(
        &self,
        level: usize,
        st: &mut LevelState,
        m: &mut RunMetrics,
    ) -> Result<()> {
        let t0 = std::time::Instant::now();
        let lvl = self.hier.levels[level].clone();
        // pipeline crossings: one transfer per device boundary in the chain
        for j in 1..lvl.n_points {
            let src = self.device_of_point(level, j - 1);
            let dst = self.device_of_point(level, j);
            self.account_comm(m, src, dst);
        }
        let u0 = st.u[0].clone();
        let g = st.g.clone();
        let n = lvl.n_points;
        let mut results = self.run_jobs(
            "coarse_solve",
            vec![(
                0usize,
                Box::new(move |solver: &F::Solver| {
                    let mut u = vec![u0];
                    for j in 1..n {
                        let mut v = solver.step(lvl.theta_idx(j - 1), lvl.h, &u[j - 1])?;
                        if let Some(g) = &g {
                            v.axpy(1.0, &g[j])?;
                        }
                        u.push(v);
                    }
                    Ok(u)
                }) as Box<dyn FnOnce(&F::Solver) -> Result<Vec<Tensor>> + Send>,
            )],
        )?;
        st.u = results.pop().unwrap();
        m.phases.push(("coarse_solve", t0.elapsed().as_secs_f64()));
        Ok(())
    }

    /// One parallel V-cycle on `level` (recursive).
    fn vcycle(
        &self,
        level: usize,
        st: &mut LevelState,
        opts: &MgritOptions,
        m: &mut RunMetrics,
    ) -> Result<()> {
        if level == self.hier.n_levels() - 1 {
            return self.coarse_solve_phase(level, st, m);
        }
        match opts.relax {
            RelaxKind::F => self.f_relax_phase(level, st, m)?,
            RelaxKind::FC => {
                self.f_relax_phase(level, st, m)?;
                self.c_relax_phase(level, st, m)?;
            }
            RelaxKind::FCF => {
                self.f_relax_phase(level, st, m)?;
                self.c_relax_phase(level, st, m)?;
                self.f_relax_phase(level, st, m)?;
            }
        }
        let residuals = self.residual_phase(level, st, m)?;
        let (mut coarse_st, injected) = self.restrict_phase(level, st, residuals, m)?;
        self.vcycle(level + 1, &mut coarse_st, opts, m)?;
        // correction is element-wise on C-points — negligible, done inline
        crate::mgrit::fas::correct(st, &coarse_st, &injected, self.hier.coarsen)?;
        self.f_relax_phase(level, st, m)?;
        Ok(())
    }

    /// Full parallel MGRIT solve (same contract as `mgrit::solve_forward`).
    pub fn solve(
        &self,
        u0: &Tensor,
        opts: &MgritOptions,
    ) -> Result<(Vec<Tensor>, CycleStats, RunMetrics)> {
        let fine_points = self.hier.fine().n_points;
        let mut st = LevelState::initial(u0, fine_points);
        let mut metrics = RunMetrics::default();
        let mut stats = CycleStats { residual_norms: Vec::new(), converged: false, phi_evals: 0 };
        for _ in 0..opts.max_cycles {
            self.vcycle(0, &mut st, opts, &mut metrics)?;
            metrics.cycles += 1;
            let rs = self.residual_phase(0, &st, &mut metrics)?;
            let norm = {
                let mut acc = 0.0;
                for r in &rs {
                    let n = r.l2_norm();
                    acc += n * n;
                }
                acc.sqrt()
            };
            stats.residual_norms.push(norm);
            metrics.residual_norms.push(norm);
            if norm <= opts.tol {
                stats.converged = true;
                break;
            }
        }
        Ok((st.u, stats, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NetParams, NetSpec};
    use crate::solver::host::HostSolver;
    use std::sync::Arc;

    fn factory(spec: NetSpec, seed: u64) -> impl SolverFactory<Solver = HostSolver> {
        let spec = Arc::new(spec);
        let params = Arc::new(NetParams::init(&spec, seed).unwrap());
        move |_w: usize| HostSolver::new(spec.clone(), params.clone())
    }

    #[test]
    fn parallel_equals_serial_engine() {
        let spec = NetSpec::mnist();
        let h = spec.h();
        let f = factory(spec.clone(), 50);
        let solver = f.build(0).unwrap();
        let mut rng = crate::util::prng::Rng::new(51);
        let u0 = Tensor::randn(&[1, 8, 28, 28], 0.5, &mut rng);
        let opts = MgritOptions { tol: 0.0, max_cycles: 3, ..Default::default() };

        let hier = Hierarchy::two_level(32, h, 4).unwrap();
        let (serial, _) =
            crate::mgrit::fas::solve_forward_with(&solver, &hier, &u0, &opts).unwrap();

        for n_dev in [1usize, 2, 4] {
            let drv = ParallelMgrit::new(f.clone(), hier.clone(), n_dev, 4 * 6272).unwrap();
            let (par, _, metrics) = drv.solve(&u0, &opts).unwrap();
            assert_eq!(par.len(), serial.len());
            for (a, b) in par.iter().zip(&serial) {
                let err = crate::util::stats::rel_l2_err(a.data(), b.data());
                assert!(err < 1e-6, "n_dev={n_dev}: {err}");
            }
            if n_dev == 1 {
                assert_eq!(metrics.comm_events, 0, "single device must not communicate");
            } else {
                assert!(metrics.comm_events > 0);
            }
        }
    }

    #[test]
    fn comm_scales_with_devices() {
        let spec = NetSpec::mnist();
        let h = spec.h();
        let f = factory(spec, 52);
        let mut rng = crate::util::prng::Rng::new(53);
        let u0 = Tensor::randn(&[1, 8, 28, 28], 0.5, &mut rng);
        let opts = MgritOptions { tol: 0.0, max_cycles: 1, ..Default::default() };
        let hier = Hierarchy::two_level(32, h, 4).unwrap();
        let mut prev = 0u64;
        for n_dev in [2usize, 4, 8] {
            let drv = ParallelMgrit::new(f.clone(), hier.clone(), n_dev, 100).unwrap();
            let (_, _, m) = drv.solve(&u0, &opts).unwrap();
            assert!(m.comm_bytes >= prev, "comm should grow with devices");
            prev = m.comm_bytes;
        }
    }

    #[test]
    fn metrics_record_phases() {
        let spec = NetSpec::micro();
        let h = spec.h();
        let f = factory(spec, 54);
        let mut rng = crate::util::prng::Rng::new(55);
        let u0 = Tensor::randn(&[1, 2, 6, 6], 0.5, &mut rng);
        let hier = Hierarchy::two_level(4, h, 2).unwrap();
        let drv = ParallelMgrit::new(f, hier, 2, 10).unwrap();
        let opts = MgritOptions { tol: 0.0, max_cycles: 2, ..Default::default() };
        let (_, _, m) = drv.solve(&u0, &opts).unwrap();
        assert_eq!(m.cycles, 2);
        assert!(m.phase_s("f_relax") > 0.0);
        assert!(m.phase_s("c_relax") > 0.0);
        assert!(m.phase_s("coarse_solve") > 0.0);
        assert!(m.total_s() > 0.0);
        assert_eq!(m.residual_norms.len(), 2);
    }

    #[test]
    fn trace_shows_concurrent_blocks() {
        // with ≥2 devices the pool trace must contain f_relax events from
        // different workers (the Fig 5 concurrency property on a real run)
        let spec = NetSpec::mnist();
        let h = spec.h();
        let f = factory(spec, 56);
        let mut rng = crate::util::prng::Rng::new(57);
        let u0 = Tensor::randn(&[1, 8, 28, 28], 0.5, &mut rng);
        let hier = Hierarchy::two_level(32, h, 4).unwrap();
        let drv = ParallelMgrit::new(f, hier, 4, 10).unwrap();
        let opts = MgritOptions { tol: 0.0, max_cycles: 1, ..Default::default() };
        drv.solve(&u0, &opts).unwrap();
        let trace = drv.pool().trace();
        let workers: std::collections::BTreeSet<usize> = trace
            .iter()
            .filter(|e| e.label == "f_relax")
            .map(|e| e.worker)
            .collect();
        assert!(workers.len() >= 2, "expected multi-worker f_relax, got {workers:?}");
    }
}
