//! The dependency-driven MGRIT driver: builds the executable schedule DAG
//! (`mgrit::taskgraph::mg_vcycle`) once per solve and runs it per cycle on
//! the [`executor`](super::executor) — tasks dispatch to `StreamPool` workers
//! the moment their dependencies retire, with **no per-phase barriers**:
//! C-relaxation and residual work of one partition overlap F-relaxation of
//! another (the paper's kernel-concurrency property, Fig 5), and the
//! simulator (`sim::engine`) consumes the *identical* graph, so simulated
//! and real schedules cannot drift.
//!
//! The driver produces *bit-identical* results to the serial engine in
//! `mgrit::fas` — asserted by `tests/mgrit_integration.rs` — because each
//! task performs the same f32 operations in the same order on the same
//! inputs, and the graph encodes every read/write hazard; only the execution
//! order across independent tasks differs. Activation traffic that crosses
//! device partitions (the paper's MPI communication during C-relaxation) is
//! accounted through the graph's Comm tasks.

use std::sync::Arc;

use super::executor::{self, ExecEvent, MultiExecState};
use super::partition::{InstanceGroups, Partition};
use super::placement::{self, PlacementKind};
use super::streams::{NodePools, RuntimePool, StreamPool};
use super::transport::{InProc, TransportMode};
use crate::mgrit::fas::{CycleStats, MgritOptions};
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph::{self, Collective, Granularity, PipeSync, ReduceStep, TaskGraph};
use crate::model::params::NetGrads;
use crate::model::{NetParams, NetSpec};
use crate::perfmodel::ClusterModel;
use crate::serving::policy::{PolicyCtx, QueuedRequest, SchedulerPolicy};
use crate::serving::request::ShedReason;
use crate::solver::{NetExecutor, SolverFactory};
use crate::tensor::Tensor;
use crate::Result;

/// Metrics of one parallel solve (feeds Fig 5/6-style reporting for real runs).
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// (task label, accumulated worker-busy seconds).
    pub phases: Vec<(&'static str, f64)>,
    /// Activation bytes that crossed a device boundary.
    pub comm_bytes: u64,
    /// Number of boundary transfers.
    pub comm_events: usize,
    /// Completed cycles.
    pub cycles: usize,
    /// ‖R_h‖ after each cycle.
    pub residual_norms: Vec<f64>,
    /// Instance-tagged kernel completions (pool-clock timestamps) — the
    /// record the cross-instance pipelining assertions read.
    pub events: Vec<ExecEvent>,
    /// Recovery re-dispatches absorbed over the run: failed or lost tasks
    /// re-enqueued onto surviving workers (0 on a fault-free run).
    pub retries: usize,
    /// Messages that crossed the inter-node [`crate::coordinator::Transport`]
    /// (0 on the shared single-pool substrate).
    pub transport_msgs: usize,
    /// Serialized wire bytes shipped over the transport.
    pub transport_bytes: u64,
}

impl RunMetrics {
    /// Total busy seconds across phases.
    pub fn total_s(&self) -> f64 {
        self.phases.iter().map(|(_, s)| s).sum()
    }

    /// Seconds spent in a given phase label.
    pub fn phase_s(&self, label: &str) -> f64 {
        self.phases.iter().filter(|(l, _)| *l == label).map(|(_, s)| s).sum()
    }
}

/// Output of one whole-training-step graph execution (see
/// [`ParallelMgrit::train_step`]): bit-identical to the serial reference
/// `train::mg_step_serial` on the same hierarchy.
#[derive(Debug)]
pub struct TrainStepOutput {
    /// Minibatch loss.
    pub loss: f64,
    /// Full gradient set (trunk from the graph's `GradAccum` tasks; opening
    /// and head computed host-side exactly as in the serial step).
    pub grads: NetGrads,
    /// Post-SGD parameters (trunk from the graph's `ParamUpdate` tasks).
    pub params: NetParams,
    /// Fine-level forward trajectory u^0..u^N.
    pub states: Vec<Tensor>,
    /// Adjoints λ^0..λ^N.
    pub lams: Vec<Tensor>,
    /// Execution metrics (phases, traffic, events).
    pub metrics: RunMetrics,
}

/// One micro-batch instance's trajectory out of a hybrid training step.
#[derive(Debug)]
pub struct InstanceStep {
    /// This micro-batch's loss.
    pub loss: f64,
    /// Fine-level forward trajectory u^0..u^N.
    pub states: Vec<Tensor>,
    /// Adjoints λ^0..λ^N.
    pub lams: Vec<Tensor>,
}

/// Output of one hybrid (M micro-batch) training-step graph execution (see
/// [`ParallelMgrit::train_step_micro`]): bit-identical to the serial
/// sum-over-micro-batches reference `train::mg_step_serial_micro`.
#[derive(Debug)]
pub struct MicroStepOutput {
    /// Mean loss over micro-batches.
    pub loss: f64,
    /// Reduced (micro-batch mean) gradient set — trunk from the graph's
    /// `ReduceGrad` roots; opening and head reduced host-side with the same
    /// plan and primitives.
    pub grads: NetGrads,
    /// Post-SGD parameters (trunk from the graph's `ParamUpdate` tasks).
    pub params: NetParams,
    /// Per-micro-batch trajectories, in instance order.
    pub per_instance: Vec<InstanceStep>,
    /// Execution metrics (phases, traffic, events).
    pub metrics: RunMetrics,
}

/// Output of one **cross-step pipelined** training run (see
/// [`ParallelMgrit::train_pipeline`]): K steps executed as ONE graph.
#[derive(Debug)]
pub struct PipelineRunOutput {
    /// Per-step mean loss, in step order — with `PipeSync::Staleness(0)`
    /// bit-identical to K sequential [`ParallelMgrit::train_step_micro`]
    /// losses.
    pub losses: Vec<f64>,
    /// Per-step global norm of the reduced (micro-batch mean) gradient over
    /// every parameter slot, in step order — the same quantity the
    /// sequential paths report via `NetGrads::global_norm`, so pipelined
    /// step logs are comparable.
    pub grad_norms: Vec<f64>,
    /// The final parameters after all K updates (snapshot-ring version K).
    pub params: NetParams,
    /// The snapshot ring's live-depth high-water mark (≤ S + 2).
    pub peak_ring_depth: usize,
    /// Execution metrics (phases, traffic, events) over the whole run.
    pub metrics: RunMetrics,
}

/// Dependency-driven parallel MGRIT over a stream pool.
pub struct ParallelMgrit<F: SolverFactory> {
    pool: RuntimePool<F>,
    factory: F,
    spec: Arc<NetSpec>,
    batch: usize,
    hier: Hierarchy,
    partition: Partition,
    granularity: Granularity,
    /// Device groups for multi-instance runs: instance k's tasks run on
    /// device group k mod n_groups (group 0 is the partition itself).
    n_groups: usize,
    /// Scheduling & placement policy. `MinId` (the default) executes the
    /// graph as built — static `Partition` devices, min-id dispatch — with
    /// no planning pass; `Heft`/`Lookahead` plan each graph once against
    /// the `perfmodel` cluster costs and execute the rewritten graph under
    /// its dispatch priorities. Bit-identical outputs either way.
    placement: PlacementKind,
    /// The micro-batch gradient collective (see
    /// [`taskgraph::Collective`]). `Tree` — the default — is the balanced
    /// pairwise plan, bit-for-bit the pre-topology behavior; `Ring` and
    /// `TwoPhase` change the `(src, dst)` endpoints of the reduction's
    /// transfers (two-phase reduces inside each node first, crossing the
    /// inter-node fabric once per remote node).
    collective: Collective,
}

impl<F: SolverFactory> ParallelMgrit<F> {
    /// `n_devices` workers over the hierarchy's fine-level blocks. `spec`
    /// provides the cost/traffic annotations of the schedule DAG (shared
    /// with the simulator); `batch` is the leading dimension of the states
    /// this driver will solve for.
    pub fn new(
        factory: F,
        spec: Arc<NetSpec>,
        hier: Hierarchy,
        n_devices: usize,
        batch: usize,
    ) -> Result<ParallelMgrit<F>> {
        Self::new_grouped(factory, spec, hier, n_devices, 1, batch)
    }

    /// As [`ParallelMgrit::new`] with `n_groups` device groups of
    /// `devices_per_group` workers each: the layer-block partition lives
    /// inside one group, and micro-batch instances are spread round-robin
    /// across groups (`n_groups == 1` — the default — shares every device
    /// between all instances for maximal cross-instance overlap).
    pub fn new_grouped(
        factory: F,
        spec: Arc<NetSpec>,
        hier: Hierarchy,
        devices_per_group: usize,
        n_groups: usize,
        batch: usize,
    ) -> Result<ParallelMgrit<F>> {
        anyhow::ensure!(n_groups >= 1, "need at least one device group");
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let partition = Partition::contiguous(n_blocks, devices_per_group)?;
        let pool =
            RuntimePool::Shared(StreamPool::new(partition.n_devices() * n_groups, factory.clone())?);
        Ok(ParallelMgrit {
            pool,
            factory,
            spec,
            batch,
            hier,
            partition,
            granularity: Granularity::PerStep,
            n_groups,
            placement: PlacementKind::MinId,
            collective: Collective::Tree,
        })
    }

    /// The layer-block → device partition in use.
    pub fn partition(&self) -> &Partition {
        &self.partition
    }

    /// The worker pool (its clock is the trace clock).
    pub fn pool(&self) -> &RuntimePool<F> {
        &self.pool
    }

    /// Switch the execution substrate (see [`TransportMode`]). `Shared` —
    /// the default — keeps one pool over all `groups × devices` workers;
    /// `InProc` shards it into one [`NodePools`] member pool per device
    /// group, with every cross-group `Comm` edge shipped as serialized
    /// bytes over the in-process [`super::transport::Transport`]. The
    /// substrate only changes *where* dispatch queues live and *how*
    /// cross-node edges move — outputs are bit-identical either way.
    /// Rebuilds the pool, so any armed faults or recorded trace are reset.
    pub fn set_transport(&mut self, mode: TransportMode) -> Result<()> {
        self.pool = match mode {
            TransportMode::Shared => RuntimePool::Shared(StreamPool::new(
                self.partition.n_devices() * self.n_groups,
                self.factory.clone(),
            )?),
            TransportMode::InProc => RuntimePool::Sharded(NodePools::new(
                self.n_groups,
                self.partition.n_devices(),
                self.factory.clone(),
                Box::new(InProc::new(self.n_groups)),
            )?),
        };
        Ok(())
    }

    /// The active transport mode (derived from the substrate in use).
    pub fn transport(&self) -> TransportMode {
        match &self.pool {
            RuntimePool::Shared(_) => TransportMode::Shared,
            RuntimePool::Sharded(_) => TransportMode::InProc,
        }
    }

    /// The MGRIT hierarchy this driver solves on.
    pub fn hierarchy(&self) -> &Hierarchy {
        &self.hier
    }

    /// F-relaxation task granularity (`PerStep` default; `PerBlock` fuses
    /// each block's F-span into one task, reaching the solver's fused
    /// `block_fprop` fast path). Bit-identical either way.
    pub fn set_granularity(&mut self, g: Granularity) {
        self.granularity = g;
    }

    /// The configured F-relaxation granularity.
    pub fn granularity(&self) -> Granularity {
        self.granularity
    }

    /// Select the scheduling & placement policy (see
    /// [`super::placement`]). The library default is `MinId` — the graphs
    /// run exactly as built; the CLI defaults to the policy-comparison
    /// winner instead.
    pub fn set_placement(&mut self, kind: PlacementKind) {
        self.placement = kind;
    }

    /// The configured placement policy.
    pub fn placement(&self) -> PlacementKind {
        self.placement
    }

    /// Select the micro-batch gradient collective. `Tree` (the default)
    /// keeps the balanced pairwise plan; every choice stays bit-identical to
    /// the serial reference executing the same plan — only the transfer
    /// endpoints and the sum's association order move.
    pub fn set_collective(&mut self, c: Collective) {
        self.collective = c;
    }

    /// The configured gradient collective.
    pub fn collective(&self) -> Collective {
        self.collective
    }

    /// Device groups (each one modeled cluster node when > 1).
    pub fn n_groups(&self) -> usize {
        self.n_groups
    }

    /// The reduction plan the configured collective emits for `m` instances,
    /// with instance k hosted on node `k mod n_groups` (the round-robin
    /// [`InstanceGroups`] spread). Shared by the graph builders and the
    /// host-side epilogue so both reduce with the identical plan.
    fn reduce_plan_for(&self, m: usize) -> Vec<ReduceStep> {
        let node_of: Vec<usize> = (0..m).map(|k| k % self.n_groups).collect();
        taskgraph::collective_plan(self.collective, m, &node_of)
    }

    /// The cluster cost model the planning pass prices against — one
    /// modeled device per pool worker. With more than one device group the
    /// groups are promoted to **nodes**: PCIe inside a group, the 25G
    /// fabric between groups; a single group keeps the legacy flat pricing
    /// bit-for-bit.
    fn cluster(&self) -> ClusterModel {
        if self.n_groups > 1 {
            ClusterModel::tx_gaia_nodes(self.n_groups, self.partition.n_devices())
        } else {
            ClusterModel::tx_gaia(self.partition.n_devices())
        }
    }

    /// Run `graph` through the configured placement policy: `MinId` is the
    /// no-plan fast path (graph unchanged, min-id dispatch); other policies
    /// return the rewritten graph plus its dispatch priorities.
    fn planned(&self, graph: TaskGraph) -> Result<(TaskGraph, Option<Vec<f64>>)> {
        match self.placement {
            PlacementKind::MinId => Ok((graph, None)),
            kind => {
                let p = placement::plan(kind.build().as_ref(), &graph, &self.cluster())?;
                Ok((p.graph, Some(p.priority)))
            }
        }
    }

    /// The executable V-cycle schedule this driver runs each MG iteration —
    /// the same graph `sim::simulate` scores (Fig 5/6 consistency).
    pub fn cycle_graph(&self, opts: &MgritOptions) -> taskgraph::TaskGraph {
        taskgraph::mg_vcycle_with(
            &self.spec,
            &self.hier,
            &self.partition,
            self.batch,
            opts.relax,
            self.granularity,
        )
    }

    /// The whole-training-step schedule (forward cycles → head → adjoint
    /// cycles → per-layer gradients → per-layer SGD updates) — one graph,
    /// no inter-phase barriers; identical for the simulator and the live
    /// executor.
    pub fn train_graph(&self, opts: &MgritOptions) -> taskgraph::TaskGraph {
        taskgraph::mg_train_step(
            &self.spec,
            &self.hier,
            &self.partition,
            self.batch,
            opts.max_cycles,
            opts.relax,
            self.granularity,
        )
    }

    /// The hybrid data×layer training schedule: `micro_batches` full
    /// primal+adjoint instances joined by per-layer `ReduceGrad` trees and a
    /// single `ParamUpdate` per layer — one composed graph, no inter-instance
    /// barrier; identical for the simulator and the live executor.
    pub fn train_graph_micro(
        &self,
        opts: &MgritOptions,
        micro_batches: usize,
    ) -> Result<taskgraph::TaskGraph> {
        let groups = InstanceGroups::new(self.n_groups, self.partition.n_devices())?;
        taskgraph::mg_train_step_multi_plan(
            &self.spec,
            &self.hier,
            &self.partition,
            &groups,
            (self.batch / micro_batches.max(1)).max(1),
            opts.max_cycles,
            opts.relax,
            self.granularity,
            micro_batches,
            &self.reduce_plan_for(micro_batches),
        )
    }

    /// The cross-step pipelined training schedule: `k_steps` consecutive
    /// training steps of `micro_batches` instances each, composed into ONE
    /// graph whose only cross-step edges are the `sync` policy's
    /// version-gap bounds — one plan, one execution, no inter-step barrier.
    pub fn train_pipeline_graph(
        &self,
        opts: &MgritOptions,
        micro_batches: usize,
        k_steps: usize,
        sync: PipeSync,
    ) -> Result<taskgraph::TaskGraph> {
        let groups = InstanceGroups::new(self.n_groups, self.partition.n_devices())?;
        taskgraph::mg_train_pipeline_plan(
            &self.spec,
            &self.hier,
            &self.partition,
            &groups,
            (self.batch / (k_steps * micro_batches).max(1)).max(1),
            opts.max_cycles,
            opts.relax,
            self.granularity,
            micro_batches,
            k_steps,
            sync,
            &self.reduce_plan_for(micro_batches),
        )
    }
}

impl<F: SolverFactory> ParallelMgrit<F>
where
    F::Solver: NetExecutor,
{
    /// Fold one execution report into the run metrics. `state_bytes` is the
    /// size of one layer state actually being solved for (from `u0`), so the
    /// state-transfer ledger reflects the real tensors, not the
    /// construction-time batch hint; gradient transfers (reduction-tree
    /// hops) are parameter-shaped and come pre-priced from the graph.
    fn absorb(
        m: &mut RunMetrics,
        rep: &executor::ExecReport,
        stats: &mut CycleStats,
        state_bytes: u64,
    ) {
        m.comm_events += rep.comm_events;
        m.comm_bytes +=
            rep.comm_state_events as u64 * state_bytes + rep.comm_grad_bytes as u64;
        stats.phi_evals += rep.phi_evals;
        executor::merge_phases(&mut m.phases, &rep.phase_s);
        m.events.extend(rep.events.iter().cloned());
        m.retries += rep.retries.len();
        m.transport_msgs += rep.transport_msgs;
        m.transport_bytes += rep.transport_bytes as u64;
    }

    /// Full parallel MGRIT solve (same contract as `mgrit::solve_forward`):
    /// V-cycles until `opts.tol` or `opts.max_cycles`, convergence measured
    /// as ‖R_h‖ over the fine C-points.
    pub fn solve(
        &self,
        u0: &Tensor,
        opts: &MgritOptions,
    ) -> Result<(Vec<Tensor>, CycleStats, RunMetrics)> {
        let (cycle, cycle_pri) = self.planned(self.cycle_graph(opts))?;
        let (check, check_pri) = self.planned(taskgraph::residual_check(
            &self.spec,
            &self.hier,
            &self.partition,
            self.batch,
        ))?;
        let state_bytes = 4 * u0.len() as u64;
        let mut st = MultiExecState::initial(&self.hier, u0);
        let mut metrics = RunMetrics::default();
        let mut stats =
            CycleStats { residual_norms: Vec::new(), converged: false, phi_evals: 0 };
        for _ in 0..opts.max_cycles {
            let rep = executor::execute_prioritized(
                &self.pool,
                &self.hier,
                &cycle,
                &mut st,
                cycle_pri.as_deref(),
            )?;
            Self::absorb(&mut metrics, &rep, &mut stats, state_bytes);
            metrics.cycles += 1;
            // convergence check: residual at every fine C-point (same
            // arithmetic + accumulation order as the serial engine)
            let rep = executor::execute_prioritized(
                &self.pool,
                &self.hier,
                &check,
                &mut st,
                check_pri.as_deref(),
            )?;
            Self::absorb(&mut metrics, &rep, &mut stats, state_bytes);
            let mut acc = 0.0f64;
            for cp in self.hier.fine().cpoints(self.hier.coarsen) {
                if cp == 0 {
                    continue;
                }
                let r = st
                    .residual(0, cp)
                    .ok_or_else(|| anyhow::anyhow!("residual at C-point {cp} missing"))?;
                let n = r.l2_norm();
                acc += n * n;
            }
            let norm = acc.sqrt();
            stats.residual_norms.push(norm);
            metrics.residual_norms.push(norm);
            if norm <= opts.tol {
                stats.converged = true;
                break;
            }
        }
        Ok((st.into_fine_states(), stats, metrics))
    }

    /// One whole training step executed as a single task graph: forward
    /// MGRIT (fixed `opts.max_cycles` early-stopped cycles — the paper's
    /// training mode, so no mid-graph convergence exit), head, adjoint
    /// MGRIT, per-layer gradients, per-layer SGD — with no inter-phase
    /// barriers. The opening layer and its VJP, and the head/opening SGD
    /// updates, run host-side exactly as in the serial step (parameters
    /// live on the host in both execution paths).
    ///
    /// Bit-identical to `train::mg_step_serial` on the same hierarchy —
    /// asserted by `tests/mgrit_integration.rs`. This is
    /// [`ParallelMgrit::train_step_micro`] with one micro-batch.
    pub fn train_step(
        &self,
        y: &Tensor,
        labels: &[i32],
        opts: &MgritOptions,
        lr: f32,
    ) -> Result<TrainStepOutput> {
        let mut out = self.train_step_micro(y, labels, opts, lr, 1)?;
        let inst = out.per_instance.pop().expect("one instance");
        Ok(TrainStepOutput {
            loss: out.loss,
            grads: out.grads,
            params: out.params,
            states: inst.states,
            lams: inst.lams,
            metrics: out.metrics,
        })
    }

    /// One **hybrid data×layer** training step: the minibatch is split into
    /// `micro_batches` equal micro-batches, each becomes one primal+adjoint
    /// graph instance, and all instances execute through the multi-instance
    /// runtime as ONE composed graph — micro-batch k+1's forward V-cycles
    /// overlap micro-batch k's adjoint/gradient wave, joined only by the
    /// per-layer `ReduceGrad` mean and a single SGD update.
    ///
    /// The batch must divide evenly by `micro_batches` (a mean of unequal
    /// micro-batch means would not be the batch mean). Opening layers and
    /// their VJPs, and the head/opening SGD updates, run host-side per
    /// micro-batch, reduced with the same plan and primitives as the graph.
    ///
    /// Bit-identical (states, λ, gradients, loss, post-SGD parameters) to
    /// the serial reference `train::mg_step_serial_micro` on the same
    /// hierarchy — asserted by `tests/hybrid_integration.rs`.
    pub fn train_step_micro(
        &self,
        y: &Tensor,
        labels: &[i32],
        opts: &MgritOptions,
        lr: f32,
        micro_batches: usize,
    ) -> Result<MicroStepOutput> {
        let m = micro_batches;
        anyhow::ensure!(m >= 1, "need at least one micro-batch");
        let b = *y
            .dims()
            .first()
            .ok_or_else(|| anyhow::anyhow!("batch tensor has no leading dimension"))?;
        anyhow::ensure!(labels.len() == b, "labels len {} != batch {b}", labels.len());
        anyhow::ensure!(
            b % m == 0,
            "batch {b} does not divide into {m} micro-batches"
        );
        let per = b / m;
        // a scheduler-side executor for the host-side stages; its parameter
        // snapshot is the one the workers share (same factory, worker 0's
        // view — factories may key device selection off the index)
        let exec = self.factory.build(0)?;
        let params = Arc::new(exec.net_params().clone());
        // split + opening per micro-batch, in instance order (the serial
        // reference does the same, so the inputs are bit-identical)
        let mut ys = Vec::with_capacity(m);
        let mut inputs = Vec::with_capacity(m);
        for k in 0..m {
            let yk = y.slice_batch(k * per, per)?;
            let u0 = exec.opening(&yk)?;
            inputs.push((u0, labels[k * per..(k + 1) * per].to_vec()));
            ys.push(yk);
        }
        let (graph, pri) = self.planned(self.train_graph_micro(opts, m)?)?;
        let state_bytes = 4 * inputs[0].0.len() as u64;
        let mut st =
            MultiExecState::initial_train(&self.hier, &inputs, params.clone(), lr)?;
        let mut metrics = RunMetrics::default();
        let mut stats =
            CycleStats { residual_norms: Vec::new(), converged: false, phi_evals: 0 };
        let rep = executor::execute_prioritized(
            &self.pool,
            &self.hier,
            &graph,
            &mut st,
            pri.as_deref(),
        )?;
        Self::absorb(&mut metrics, &rep, &mut stats, state_bytes);
        metrics.cycles = opts.max_cycles;
        let out = st.into_training_outputs()?;
        // host-side epilogue — per-micro-batch opening VJPs and head grads,
        // reduced with the SAME plan/primitives as the graph's ReduceGrad
        let mut open_leaves = Vec::with_capacity(m);
        let mut fc_leaves = Vec::with_capacity(m);
        for (k, inst) in out.instances.iter().enumerate() {
            let (dw, db) = crate::train::opening_vjp(
                &ys[k],
                &params.w_open,
                &params.b_open,
                self.spec.opening.pad,
                &inst.lams[0],
            )?;
            open_leaves.push((dw, db));
            fc_leaves.push((inst.dw_fc.clone(), inst.db_fc.clone()));
        }
        let plan = self.reduce_plan_for(m);
        let (w_open_g, b_open_g) = crate::train::reduce_micro_grads_plan(&plan, &open_leaves)?;
        let (w_fc_g, b_fc_g) = crate::train::reduce_micro_grads_plan(&plan, &fc_leaves)?;
        let grads = NetGrads {
            w_open: w_open_g,
            b_open: b_open_g,
            trunk: out.trunk_grads,
            w_fc: w_fc_g,
            b_fc: b_fc_g,
        };
        let mut new_params = NetParams {
            w_open: params.w_open.clone(),
            b_open: params.b_open.clone(),
            trunk: out.new_trunk,
            w_fc: params.w_fc.clone(),
            b_fc: params.b_fc.clone(),
        };
        new_params.w_open.axpy(-lr, &grads.w_open)?;
        new_params.b_open.axpy(-lr, &grads.b_open)?;
        new_params.w_fc.axpy(-lr, &grads.w_fc)?;
        new_params.b_fc.axpy(-lr, &grads.b_fc)?;
        let per_instance = out
            .instances
            .into_iter()
            .map(|i| InstanceStep { loss: i.loss, states: i.states, lams: i.lams })
            .collect();
        Ok(MicroStepOutput {
            loss: out.loss,
            grads,
            params: new_params,
            per_instance,
            metrics,
        })
    }

    /// **Cross-step pipelined training**: run `k_steps` consecutive training
    /// steps as ONE executable graph. The superbatch `y` (leading dimension
    /// K·M·per) is sliced step-major — step t's micro-batch k is rows
    /// `[(t·M + k)·per, (t·M + k + 1)·per)` — so each step sees exactly the
    /// rows the sequential reference would.
    ///
    /// Under `PipeSync::Staleness(S)`, step t's tasks read the snapshot-ring
    /// parameter version `max(0, t − S)`: step t+1's forward V-cycles launch
    /// against the step-t snapshot while step t's adjoint/gradient tail is
    /// still draining, and the only cross-step edges are the version-gap
    /// bounds (`ParamUpdate(t−S−1, slot)` → step t's first reader of the
    /// slot). `S = 0` is **bit-identical** to `k_steps` sequential
    /// [`ParallelMgrit::train_step_micro`] calls — same arithmetic, same
    /// order, only the schedule overlaps. `PipeSync::Barrier` is the fully
    /// synchronous reference composition (every step-t+1 root waits for all
    /// of step t's updates).
    ///
    /// Unlike the single-step paths, the opening layer and its VJP, and ALL
    /// parameter updates, run **in-graph** against the versioned snapshot
    /// ring — host-side staging would serialize the steps this exists to
    /// overlap.
    #[allow(clippy::too_many_arguments)]
    pub fn train_pipeline(
        &self,
        y: &Tensor,
        labels: &[i32],
        opts: &MgritOptions,
        lr: f32,
        micro_batches: usize,
        k_steps: usize,
        sync: PipeSync,
    ) -> Result<PipelineRunOutput> {
        let m = micro_batches;
        anyhow::ensure!(m >= 1, "need at least one micro-batch");
        anyhow::ensure!(k_steps >= 1, "need at least one pipeline step");
        let b = *y
            .dims()
            .first()
            .ok_or_else(|| anyhow::anyhow!("batch tensor has no leading dimension"))?;
        anyhow::ensure!(labels.len() == b, "labels len {} != batch {b}", labels.len());
        anyhow::ensure!(
            b % (k_steps * m) == 0,
            "superbatch {b} does not divide into {k_steps} steps × {m} micro-batches"
        );
        let per = b / (k_steps * m);
        let exec = self.factory.build(0)?;
        let params = Arc::new(exec.net_params().clone());
        let mut inputs = Vec::with_capacity(k_steps * m);
        for gi in 0..k_steps * m {
            let yk = y.slice_batch(gi * per, per)?;
            inputs.push((yk, labels[gi * per..(gi + 1) * per].to_vec()));
        }
        let (graph, pri) =
            self.planned(self.train_pipeline_graph(opts, m, k_steps, sync)?)?;
        // a barrier-synced graph's cross-step edges already guarantee version
        // t is complete before step t dispatches — its executor staleness is 0
        let staleness = match sync {
            PipeSync::Barrier => 0,
            PipeSync::Staleness(s) => s,
        };
        let mut st = MultiExecState::initial_train_pipeline(
            &self.hier,
            self.spec.clone(),
            &graph,
            &inputs,
            params,
            lr,
            m,
            staleness,
        )?;
        let state_bytes = 4 * (per * self.spec.state_elems()) as u64;
        let mut metrics = RunMetrics::default();
        let mut stats =
            CycleStats { residual_norms: Vec::new(), converged: false, phi_evals: 0 };
        let rep = executor::execute_prioritized(
            &self.pool,
            &self.hier,
            &graph,
            &mut st,
            pri.as_deref(),
        )?;
        Self::absorb(&mut metrics, &rep, &mut stats, state_bytes);
        metrics.cycles = opts.max_cycles * k_steps;
        let out = st.into_pipeline_outputs()?;
        Ok(PipelineRunOutput {
            losses: out.losses,
            grad_norms: out.grad_norms,
            params: out.params,
            peak_ring_depth: out.peak_ring_depth,
            metrics,
        })
    }
}

/// Executor-and-clock abstraction behind the serving drain loop. The live
/// `serving::runtime::ServingRuntime::run` (wall clock + `ExecSession`) and
/// the virtual-time `serving::sim::simulate_serving_policy` (event clock +
/// `SimSession`) used to carry two hand-synchronized copies of the same
/// intake → decide → retire → wait protocol; both now implement this trait
/// and share the single [`drive`] loop, so the two timelines cannot drift —
/// a policy bug or a protocol change lands in exactly one place.
///
/// The split: [`drive`] owns everything *protocol* — the waiting room, the
/// bounded-queue door shed, the decide loop with its
/// [`Decision::apply`](crate::serving::policy::Decision::apply) call, the
/// harvest-before-wait ordering, and termination. The backend owns
/// everything *mechanism* — where requests come from, what a clock read
/// means, how a group becomes a running graph instance, and how to block
/// until the next event.
pub trait DriveBackend {
    /// The request type held in the waiting room (live: `InferRequest`
    /// carrying a real tensor; sim: `SimRequest` carrying just a row count).
    type Req;

    /// Current time on this backend's clock (wall seconds on the pool clock,
    /// or virtual seconds).
    fn now(&self) -> f64;

    /// Arrival time of the earliest not-yet-arrived request, `None` when the
    /// submission queue is drained. `drive` uses it both to bound waits and
    /// (with an empty waiting room and nothing in flight) to terminate.
    fn next_arrival_s(&self) -> Option<f64>;

    /// Pop the next request whose arrival is `<= now`, in submission order;
    /// `None` when nothing (more) has arrived yet.
    fn pop_arrived(&mut self, now: f64) -> Option<Self::Req>;

    /// The policy-facing view of a waiting request.
    fn view(&self, req: &Self::Req) -> QueuedRequest;

    /// **Per-row** service-time estimate handed to the policy for shedding
    /// decisions (live: completion-fed EWMA; sim: the makespan of one
    /// batch-1 instance). `drive` scales it by the policy's coalesce width.
    fn service_estimate_s(&self) -> f64;

    /// Record a dropped request. `at_s` is the backend clock at the drop.
    fn shed(&mut self, req: Self::Req, at_s: f64, reason: ShedReason);

    /// Coalesce an admitted group (non-empty, decision order) into ONE graph
    /// instance and start it on the executor. The backend samples its own
    /// admission timestamp first, so queue-wait accounting stays pure.
    fn admit(&mut self, group: Vec<Self::Req>) -> Result<()>;

    /// Harvest at most one finished instance (record outcomes, release the
    /// slot, feed the service estimate). `Ok(false)` when none is finished —
    /// `drive` calls this in a loop, then re-enters the decide phase
    /// immediately if anything was harvested.
    fn poll_retire(&mut self) -> Result<bool>;

    /// Number of admitted-but-unfinished instances (occupied window slots).
    fn n_active(&self) -> usize;

    /// Block (live) or advance virtual time (sim) until the next event, but
    /// never past `bound` — the earlier of the next arrival and the policy's
    /// timer, `+∞` when neither exists. Must error out (not spin) when no
    /// event can ever come: `n_waiting` and `policy_name` feed that
    /// diagnostic.
    fn advance(&mut self, bound: f64, n_waiting: usize, policy_name: &'static str)
        -> Result<()>;
}

/// The single serving drain protocol over any [`DriveBackend`]: intake
/// (bounded-queue door shed) → decide until the policy rests (admissions
/// and sheds via `Decision::apply`) → harvest every finished instance →
/// terminate when nothing is waiting, in flight, or still to arrive —
/// otherwise wait for the next completion, arrival, or policy timer and go
/// around. Freed slots are re-offered to the policy before any wait.
pub fn drive<B: DriveBackend>(
    backend: &mut B,
    policy: &mut dyn SchedulerPolicy,
    max_inflight: usize,
    max_queue: Option<usize>,
) -> Result<()> {
    let mut waiting: Vec<B::Req> = Vec::new();
    loop {
        // 1. intake: arrived requests enter the waiting room; a full bounded
        //    queue sheds at the door. Same-instant arrivals are enqueued in
        //    submission order before any admission decision at that instant.
        let now = backend.now();
        while let Some(req) = backend.pop_arrived(now) {
            if max_queue.map(|cap| waiting.len() >= cap).unwrap_or(false) {
                backend.shed(req, now, ShedReason::QueueFull);
                continue;
            }
            waiting.push(req);
        }
        // 2. decide: admissions and sheds until the policy rests (the
        //    resting decision's timer bounds the wait below)
        let wait_hint: Option<f64> = loop {
            let view: Vec<QueuedRequest> = waiting.iter().map(|r| backend.view(r)).collect();
            let ctx = PolicyCtx {
                now: backend.now(),
                free_slots: max_inflight.saturating_sub(backend.n_active()),
                service_estimate_s: backend.service_estimate_s()
                    * policy.coalesce_width().max(1) as f64,
            };
            let d = policy.decide(&view, &ctx);
            if !d.acted() {
                break d.wait_until;
            }
            // the one shared protocol implementation: validate the decision
            // and pull its subjects out of the waiting room
            let shed_now = backend.now();
            let (group, shed) = d.apply(&mut waiting, policy.name(), ctx.free_slots)?;
            for req in shed {
                backend.shed(req, shed_now, ShedReason::DeadlineHopeless);
            }
            if group.is_empty() {
                continue;
            }
            backend.admit(group)?;
        };
        // 3. retire: harvest every finished instance
        let mut harvested = false;
        while backend.poll_retire()? {
            harvested = true;
        }
        if backend.n_active() == 0 && waiting.is_empty() && backend.next_arrival_s().is_none() {
            break;
        }
        // a retirement freed window slots: admit into them immediately
        // instead of waiting for an unrelated event first
        if harvested {
            continue;
        }
        // 4. wait: for a completion, but never past the next arrival or the
        //    policy's timer (a batch window expiring)
        let bound = [backend.next_arrival_s(), wait_hint]
            .into_iter()
            .flatten()
            .fold(f64::INFINITY, f64::min);
        backend.advance(bound, waiting.len(), policy.name())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NetParams, NetSpec};
    use crate::solver::host::HostSolver;
    use std::sync::Arc;

    fn factory(spec: Arc<NetSpec>, seed: u64) -> impl SolverFactory<Solver = HostSolver> {
        let params = Arc::new(NetParams::init(&spec, seed).unwrap());
        move |_w: usize| HostSolver::new(spec.clone(), params.clone())
    }

    /// Scripted executor for [`drive`]: constant service time, completions
    /// retire when the clock passes them, the clock jumps to the next event.
    struct MockBackend {
        now: f64,
        future: std::collections::VecDeque<(u64, f64)>,
        active: Vec<(u64, f64)>,
        served: Vec<(u64, f64)>,
        sheds: Vec<(u64, ShedReason)>,
        svc: f64,
    }

    impl DriveBackend for MockBackend {
        type Req = (u64, f64);

        fn now(&self) -> f64 {
            self.now
        }

        fn next_arrival_s(&self) -> Option<f64> {
            self.future.front().map(|r| r.1)
        }

        fn pop_arrived(&mut self, now: f64) -> Option<(u64, f64)> {
            if self.future.front().map(|r| r.1 <= now).unwrap_or(false) {
                self.future.pop_front()
            } else {
                None
            }
        }

        fn view(&self, r: &(u64, f64)) -> QueuedRequest {
            QueuedRequest { id: r.0, arrival_s: r.1, deadline_ms: None, dims: vec![1, 4] }
        }

        fn service_estimate_s(&self) -> f64 {
            self.svc
        }

        fn shed(&mut self, req: (u64, f64), _at_s: f64, reason: ShedReason) {
            self.sheds.push((req.0, reason));
        }

        fn admit(&mut self, group: Vec<(u64, f64)>) -> Result<()> {
            let done = self.now + self.svc;
            for r in group {
                self.active.push((r.0, done));
            }
            Ok(())
        }

        fn poll_retire(&mut self) -> Result<bool> {
            let now = self.now;
            if let Some(pos) = self.active.iter().position(|&(_, t)| t <= now) {
                let entry = self.active.remove(pos);
                self.served.push(entry);
                return Ok(true);
            }
            Ok(false)
        }

        fn n_active(&self) -> usize {
            self.active.len()
        }

        fn advance(
            &mut self,
            bound: f64,
            n_waiting: usize,
            policy_name: &'static str,
        ) -> Result<()> {
            let next_done =
                self.active.iter().map(|&(_, t)| t).fold(f64::INFINITY, f64::min);
            let target = bound.min(next_done);
            anyhow::ensure!(
                target.is_finite() && target > self.now,
                "policy {policy_name} deadlocked with {n_waiting} waiting request(s)"
            );
            self.now = target;
            Ok(())
        }
    }

    #[test]
    fn drive_protocol_on_mock_backend() {
        use crate::serving::policy::Fifo;
        // three requests: two at t=0 into a 1-slot waiting room (second
        // sheds at the door), a third at t=0.5 that must wait for the
        // single in-flight slot to free at t=1
        let mut b = MockBackend {
            now: 0.0,
            future: vec![(1, 0.0), (2, 0.0), (3, 0.5)].into(),
            active: Vec::new(),
            served: Vec::new(),
            sheds: Vec::new(),
            svc: 1.0,
        };
        drive(&mut b, &mut Fifo, 1, Some(1)).unwrap();
        assert_eq!(b.sheds, vec![(2, ShedReason::QueueFull)]);
        assert_eq!(b.served, vec![(1, 1.0), (3, 2.0)]);
        assert_eq!(b.n_active(), 0);
        assert_eq!(b.now, 2.0);
    }

    #[test]
    fn drive_bails_instead_of_spinning_when_idle_with_no_timer() {
        // a policy that never admits: one waiting request, nothing in
        // flight, no timer — the backend's advance must surface a deadlock
        // error rather than loop forever
        struct Never;
        impl SchedulerPolicy for Never {
            fn name(&self) -> &'static str {
                "never"
            }
            fn decide(
                &mut self,
                _q: &[QueuedRequest],
                _ctx: &PolicyCtx,
            ) -> crate::serving::policy::Decision {
                crate::serving::policy::Decision::rest()
            }
        }
        let mut b = MockBackend {
            now: 0.0,
            future: vec![(1, 0.0)].into(),
            active: Vec::new(),
            served: Vec::new(),
            sheds: Vec::new(),
            svc: 1.0,
        };
        let err = drive(&mut b, &mut Never, 1, None).unwrap_err();
        assert!(err.to_string().contains("deadlocked"), "got: {err}");
    }

    #[test]
    fn parallel_equals_serial_engine() {
        let spec = Arc::new(NetSpec::mnist());
        let h = spec.h();
        let f = factory(spec.clone(), 50);
        let solver = f.build(0).unwrap();
        let mut rng = crate::util::prng::Rng::new(51);
        let u0 = Tensor::randn(&[1, 8, 28, 28], 0.5, &mut rng);
        let opts = MgritOptions { tol: 0.0, max_cycles: 3, ..Default::default() };

        let hier = Hierarchy::two_level(32, h, 4).unwrap();
        let (serial, _) =
            crate::mgrit::fas::solve_forward_with(&solver, &hier, &u0, &opts).unwrap();

        for n_dev in [1usize, 2, 4] {
            let drv =
                ParallelMgrit::new(f.clone(), spec.clone(), hier.clone(), n_dev, 1).unwrap();
            let (par, _, metrics) = drv.solve(&u0, &opts).unwrap();
            assert_eq!(par.len(), serial.len());
            for (a, b) in par.iter().zip(&serial) {
                let err = crate::util::stats::rel_l2_err(a.data(), b.data());
                assert!(err < 1e-6, "n_dev={n_dev}: {err}");
            }
            if n_dev == 1 {
                assert_eq!(metrics.comm_events, 0, "single device must not communicate");
            } else {
                assert!(metrics.comm_events > 0);
            }
        }
    }

    #[test]
    fn comm_scales_with_devices() {
        let spec = Arc::new(NetSpec::mnist());
        let h = spec.h();
        let f = factory(spec.clone(), 52);
        let mut rng = crate::util::prng::Rng::new(53);
        let u0 = Tensor::randn(&[1, 8, 28, 28], 0.5, &mut rng);
        let opts = MgritOptions { tol: 0.0, max_cycles: 1, ..Default::default() };
        let hier = Hierarchy::two_level(32, h, 4).unwrap();
        let mut prev = 0u64;
        for n_dev in [2usize, 4, 8] {
            let drv =
                ParallelMgrit::new(f.clone(), spec.clone(), hier.clone(), n_dev, 1).unwrap();
            let (_, _, m) = drv.solve(&u0, &opts).unwrap();
            assert!(m.comm_bytes >= prev, "comm should grow with devices");
            prev = m.comm_bytes;
        }
    }

    #[test]
    fn metrics_record_phases() {
        let spec = Arc::new(NetSpec::micro());
        let h = spec.h();
        let f = factory(spec.clone(), 54);
        let mut rng = crate::util::prng::Rng::new(55);
        let u0 = Tensor::randn(&[1, 2, 6, 6], 0.5, &mut rng);
        let hier = Hierarchy::two_level(4, h, 2).unwrap();
        let drv = ParallelMgrit::new(f, spec, hier, 2, 1).unwrap();
        let opts = MgritOptions { tol: 0.0, max_cycles: 2, ..Default::default() };
        let (_, _, m) = drv.solve(&u0, &opts).unwrap();
        assert_eq!(m.cycles, 2);
        assert!(m.phase_s("f_relax") > 0.0);
        assert!(m.phase_s("c_relax") > 0.0);
        assert!(m.phase_s("coarse_solve") > 0.0);
        assert!(m.phase_s("residual") > 0.0);
        assert!(m.total_s() > 0.0);
        assert_eq!(m.residual_norms.len(), 2);
    }

    #[test]
    fn trace_shows_concurrent_blocks() {
        // with ≥2 devices the pool trace must contain f_relax events from
        // different workers (the Fig 5 concurrency property on a real run)
        let spec = Arc::new(NetSpec::mnist());
        let h = spec.h();
        let f = factory(spec.clone(), 56);
        let mut rng = crate::util::prng::Rng::new(57);
        let u0 = Tensor::randn(&[1, 8, 28, 28], 0.5, &mut rng);
        let hier = Hierarchy::two_level(32, h, 4).unwrap();
        let drv = ParallelMgrit::new(f, spec, hier, 4, 1).unwrap();
        let opts = MgritOptions { tol: 0.0, max_cycles: 1, ..Default::default() };
        drv.solve(&u0, &opts).unwrap();
        let trace = drv.pool().trace();
        let workers: std::collections::BTreeSet<usize> = trace
            .iter()
            .filter(|e| e.label == "f_relax")
            .map(|e| e.worker)
            .collect();
        assert!(workers.len() >= 2, "expected multi-worker f_relax, got {workers:?}");
    }

    #[test]
    fn dag_executor_overlaps_phases() {
        // the tentpole property: no per-phase barrier — some C-relax or
        // residual task must START before the last F-relax task of another
        // partition ENDS (cross-phase, cross-device overlap)
        let spec = Arc::new(NetSpec::fig6_depth(64));
        let h = spec.h();
        let f = factory(spec.clone(), 58);
        let mut rng = crate::util::prng::Rng::new(59);
        let u0 = Tensor::randn(&[1, 4, 24, 24], 0.5, &mut rng);
        let hier = Hierarchy::two_level(64, h, 4).unwrap();
        let drv = ParallelMgrit::new(f, spec, hier, 4, 1).unwrap();
        let opts = MgritOptions { tol: 0.0, max_cycles: 1, ..Default::default() };
        drv.solve(&u0, &opts).unwrap();
        let trace = drv.pool().trace();
        // an f_relax task must be IN FLIGHT (started before, ended after) on
        // another worker at the moment a c_relax/residual task starts — a
        // barriered executor can never produce this pair, because barriers
        // force every f_relax of a sweep to finish before c_relax begins and
        // the cycle-final f_relax to start only after the residuals end
        let overlap = trace
            .iter()
            .filter(|c| c.label == "c_relax" || c.label == "residual")
            .any(|c| {
                trace.iter().any(|fr| {
                    fr.label == "f_relax"
                        && fr.worker != c.worker
                        && fr.t_start < c.t_start
                        && fr.t_end > c.t_start
                })
            });
        assert!(overlap, "no cross-phase overlap observed in the stream trace");
    }

    /// Run the sequential K-step reference (one driver per step, each over
    /// the step's slice of `y`) and return (per-step losses, final params).
    fn sequential_steps(
        spec: &Arc<NetSpec>,
        hier: &Hierarchy,
        y: &Tensor,
        labels: &[i32],
        opts: &MgritOptions,
        seed: u64,
        n_dev: usize,
        micro: usize,
        k: usize,
        batch: usize,
    ) -> (Vec<f64>, NetParams) {
        let mut p_seq = NetParams::init(spec, seed).unwrap();
        let mut losses = Vec::new();
        for t in 0..k {
            let ys = y.slice_batch(t * batch, batch).unwrap();
            let ls = labels[t * batch..(t + 1) * batch].to_vec();
            let sp = spec.clone();
            let snap = Arc::new(p_seq.clone());
            let f = move |_w: usize| HostSolver::new(sp.clone(), snap.clone());
            let drv =
                ParallelMgrit::new(f, spec.clone(), hier.clone(), n_dev, batch).unwrap();
            let out = drv.train_step_micro(&ys, &ls, opts, 0.05, micro).unwrap();
            p_seq = out.params;
            losses.push(out.loss);
        }
        (losses, p_seq)
    }

    /// One pipelined window over the full superbatch, then bitwise-compare
    /// against the sequential reference.
    fn assert_pipeline_s0_parity(
        spec: &Arc<NetSpec>,
        hier: &Hierarchy,
        seed: u64,
        n_dev: usize,
        micro: usize,
        k: usize,
        batch: usize,
    ) {
        let mut rng = crate::util::prng::Rng::new(seed + 1);
        let y = Tensor::randn(
            &[k * batch, spec.opening.in_channels, spec.opening.in_h, spec.opening.in_w],
            0.8,
            &mut rng,
        );
        let labels: Vec<i32> = (0..k * batch).map(|i| (i % 10) as i32).collect();
        let opts = MgritOptions::early_stopping(1);
        let (losses, p_seq) =
            sequential_steps(spec, hier, &y, &labels, &opts, seed, n_dev, micro, k, batch);
        let sp = spec.clone();
        let snap = Arc::new(NetParams::init(spec, seed).unwrap());
        let f = move |_w: usize| HostSolver::new(sp.clone(), snap.clone());
        let drv =
            ParallelMgrit::new(f, spec.clone(), hier.clone(), n_dev, k * batch).unwrap();
        let out = drv
            .train_pipeline(&y, &labels, &opts, 0.05, micro, k, PipeSync::Staleness(0))
            .unwrap();
        let tag = format!("dev {n_dev} micro {micro}");
        assert_eq!(out.losses, losses, "{tag}: losses differ");
        assert!(out.peak_ring_depth <= 2, "{tag}: ring depth {}", out.peak_ring_depth);
        for (i, ((w, b), (w2, b2))) in
            out.params.trunk.iter().zip(&p_seq.trunk).enumerate()
        {
            assert!(
                w.data() == w2.data() && b.data() == b2.data(),
                "{tag}: trunk layer {i} differs"
            );
        }
        assert!(out.params.w_open.data() == p_seq.w_open.data(), "{tag}: w_open differs");
        assert!(out.params.b_open.data() == p_seq.b_open.data(), "{tag}: b_open differs");
        assert!(out.params.w_fc.data() == p_seq.w_fc.data(), "{tag}: w_fc differs");
        assert!(out.params.b_fc.data() == p_seq.b_fc.data(), "{tag}: b_fc differs");
    }

    #[test]
    fn pipeline_s0_bitwise_matches_sequential_steps() {
        // tentpole acceptance gate: one composed K-step graph at staleness 0
        // is bit-identical to K sequential micro-batched steps, across
        // device counts and micro splits on a two-level hierarchy
        let spec = Arc::new(NetSpec::micro());
        let hier = Hierarchy::two_level(4, spec.h(), 2).unwrap();
        for (n_dev, micro) in [(1usize, 1usize), (1, 2), (2, 1), (2, 2)] {
            assert_pipeline_s0_parity(&spec, &hier, 91, n_dev, micro, 3, 2);
        }
    }

    #[test]
    fn pipeline_s0_parity_four_devices() {
        // the 4-device column of the parity matrix needs ≥ 4 layer blocks:
        // an 8-layer trunk on a two-level hierarchy with coarsening 2
        let mut s = NetSpec::mnist();
        s.trunk.truncate(8);
        s.t_final = 0.5;
        let spec = Arc::new(s);
        let hier = Hierarchy::two_level(8, spec.h(), 2).unwrap();
        for micro in [1usize, 2] {
            assert_pipeline_s0_parity(&spec, &hier, 93, 4, micro, 2, 2);
        }
    }

    fn assert_params_bitwise(tag: &str, a: &NetParams, e: &NetParams) {
        for (i, ((w, b), (w2, b2))) in a.trunk.iter().zip(&e.trunk).enumerate() {
            assert!(
                w.data() == w2.data() && b.data() == b2.data(),
                "{tag}: trunk layer {i} differs"
            );
        }
        assert!(a.w_open.data() == e.w_open.data(), "{tag}: w_open differs");
        assert!(a.b_open.data() == e.b_open.data(), "{tag}: b_open differs");
        assert!(a.w_fc.data() == e.w_fc.data(), "{tag}: w_fc differs");
        assert!(a.b_fc.data() == e.b_fc.data(), "{tag}: b_fc differs");
    }

    fn assert_grads_bitwise(tag: &str, a: &crate::model::NetGrads, e: &crate::model::NetGrads) {
        for (i, ((w, b), (w2, b2))) in a.trunk.iter().zip(&e.trunk).enumerate() {
            assert!(
                w.data() == w2.data() && b.data() == b2.data(),
                "{tag}: trunk grad {i} differs"
            );
        }
        assert!(a.w_open.data() == e.w_open.data(), "{tag}: opening grad differs");
        assert!(a.b_open.data() == e.b_open.data(), "{tag}: opening bias grad differs");
        assert!(a.w_fc.data() == e.w_fc.data(), "{tag}: head grad differs");
        assert!(a.b_fc.data() == e.b_fc.data(), "{tag}: head bias grad differs");
    }

    #[test]
    fn sharded_transport_training_is_bit_identical() {
        // tentpole acceptance gate: the sharded NodePools substrate — one
        // StreamPool per device group, every cross-node Comm serialized
        // through the InProc transport — produces bit-identical hybrid
        // training output to the shared single-pool executor at 1/2/4 nodes
        let spec = Arc::new(NetSpec::micro());
        let hier = Hierarchy::two_level(4, spec.h(), 2).unwrap();
        let (batch, micro) = (4usize, 4usize);
        let mut rng = crate::util::prng::Rng::new(95);
        let y = Tensor::randn(
            &[batch, spec.opening.in_channels, spec.opening.in_h, spec.opening.in_w],
            0.8,
            &mut rng,
        );
        let labels: Vec<i32> = (0..batch).map(|i| (i % 10) as i32).collect();
        let opts = MgritOptions::early_stopping(1);
        for groups in [1usize, 2, 4] {
            let tag = format!("groups {groups}");
            let shared = ParallelMgrit::new_grouped(
                factory(spec.clone(), 94),
                spec.clone(),
                hier.clone(),
                2,
                groups,
                batch,
            )
            .unwrap();
            assert_eq!(shared.transport(), TransportMode::Shared);
            let a = shared.train_step_micro(&y, &labels, &opts, 0.05, micro).unwrap();
            let mut drv = ParallelMgrit::new_grouped(
                factory(spec.clone(), 94),
                spec.clone(),
                hier.clone(),
                2,
                groups,
                batch,
            )
            .unwrap();
            drv.set_transport(TransportMode::InProc).unwrap();
            assert_eq!(drv.transport(), TransportMode::InProc);
            let e = drv.train_step_micro(&y, &labels, &opts, 0.05, micro).unwrap();
            assert!(a.loss.to_bits() == e.loss.to_bits(), "{tag}: loss differs");
            for (k, (ia, ie)) in a.per_instance.iter().zip(&e.per_instance).enumerate() {
                assert!(
                    ia.loss.to_bits() == ie.loss.to_bits(),
                    "{tag}: instance {k} loss differs"
                );
                for (j, (ua, ue)) in ia.states.iter().zip(&ie.states).enumerate() {
                    assert!(ua.data() == ue.data(), "{tag}: instance {k} state {j} differs");
                }
            }
            assert_grads_bitwise(&tag, &a.grads, &e.grads);
            assert_params_bitwise(&tag, &a.params, &e.params);
            // the shared pool never ships; the sharded pool must ship real
            // serialized traffic exactly when instances span >1 node
            assert_eq!(a.metrics.transport_msgs, 0, "{tag}: shared pool shipped");
            if groups > 1 {
                assert!(
                    e.metrics.transport_msgs > 0 && e.metrics.transport_bytes > 0,
                    "{tag}: no traffic crossed the transport"
                );
            } else {
                assert_eq!(e.metrics.transport_msgs, 0, "{tag}: loopback not elided");
            }
        }
    }

    #[test]
    fn sharded_transport_pipeline_is_bit_identical() {
        // cross-step pipelined parity on the sharded substrate, both at the
        // sequential-equivalent staleness 0 and the genuinely-stale S = 1
        let spec = Arc::new(NetSpec::micro());
        let hier = Hierarchy::two_level(4, spec.h(), 2).unwrap();
        let (k, batch, micro, groups) = (2usize, 2usize, 2usize, 2usize);
        let mut rng = crate::util::prng::Rng::new(97);
        let y = Tensor::randn(
            &[k * batch, spec.opening.in_channels, spec.opening.in_h, spec.opening.in_w],
            0.8,
            &mut rng,
        );
        let labels: Vec<i32> = (0..k * batch).map(|i| (i % 10) as i32).collect();
        let opts = MgritOptions::early_stopping(1);
        for s in [0usize, 1] {
            let tag = format!("staleness {s}");
            let shared = ParallelMgrit::new_grouped(
                factory(spec.clone(), 96),
                spec.clone(),
                hier.clone(),
                2,
                groups,
                k * batch,
            )
            .unwrap();
            let a = shared
                .train_pipeline(&y, &labels, &opts, 0.05, micro, k, PipeSync::Staleness(s))
                .unwrap();
            let mut drv = ParallelMgrit::new_grouped(
                factory(spec.clone(), 96),
                spec.clone(),
                hier.clone(),
                2,
                groups,
                k * batch,
            )
            .unwrap();
            drv.set_transport(TransportMode::InProc).unwrap();
            let e = drv
                .train_pipeline(&y, &labels, &opts, 0.05, micro, k, PipeSync::Staleness(s))
                .unwrap();
            assert_eq!(a.losses, e.losses, "{tag}: losses differ");
            assert_eq!(a.grad_norms, e.grad_norms, "{tag}: grad norms differ");
            assert_params_bitwise(&tag, &e.params, &a.params);
            assert_eq!(a.metrics.transport_msgs, 0, "{tag}: shared pool shipped");
            assert!(
                e.metrics.transport_msgs > 0,
                "{tag}: no traffic crossed the transport"
            );
        }
    }
}
