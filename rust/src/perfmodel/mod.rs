//! Analytic device + interconnect cost model for the cluster simulator —
//! the substitute for the paper's TX-GAIA testbed (V100 GPUs, 25 Gb/s
//! Ethernet through one non-blocking switch, no NVLink).
//!
//! Absolute constants are published device specs plus standard effective-
//! efficiency factors; the experiments only claim the paper's *shape*
//! (crossovers, who wins, comm-bound collapse), which is set by the ratios
//! compute-time : launch-overhead : message-time rather than by any single
//! constant.

use crate::mgrit::taskgraph::KernelClass;

/// One accelerator (V100-class by default).
#[derive(Debug, Clone)]
pub struct DeviceModel {
    /// Peak fp32 throughput (FLOP/s).
    pub peak_flops: f64,
    /// Achieved fraction of peak for convolution kernels (small-channel
    /// convs are heavily launch/memory-bound on CuDNN).
    pub eff_conv: f64,
    /// Achieved fraction of peak for dense GEMM.
    pub eff_gemm: f64,
    /// Elementwise kernels (bandwidth-bound; expressed as a FLOPs fraction).
    pub eff_light: f64,
    /// Fixed kernel launch + driver overhead per kernel (seconds).
    pub launch_s: f64,
    /// Maximum concurrently-resident kernels per device (the paper observes
    /// 5-way concurrency before register pressure serializes convolutions).
    pub max_concurrency: usize,
}

impl DeviceModel {
    /// NVIDIA Tesla V100 (fp32): 15.7 TFLOP/s peak.
    pub fn v100() -> DeviceModel {
        DeviceModel {
            peak_flops: 15.7e12,
            eff_conv: 0.25,
            eff_gemm: 0.70,
            eff_light: 0.02,
            launch_s: 8e-6,
            max_concurrency: 5,
        }
    }

    /// Exclusive-execution service time of one kernel.
    pub fn kernel_time(&self, class: KernelClass, flops: f64) -> f64 {
        let (l, c) = self.kernel_phases(class, flops);
        l + c
    }

    /// (launch overhead, compute time): launches on different streams
    /// overlap; compute is shared across co-resident kernels.
    ///
    /// Convolution kernels are special-cased per the paper's observation
    /// that "the number of registers within the GPU prevents multiple
    /// convolution kernels from executing simultaneously": their launch
    /// does NOT overlap with other kernels (it is folded into the shared
    /// phase), so conv-dominated schedules gain no intra-device concurrency
    /// benefit — exactly the paper's Fig 5 discussion.
    pub fn kernel_phases(&self, class: KernelClass, flops: f64) -> (f64, f64) {
        let eff = match class {
            KernelClass::Conv => self.eff_conv,
            KernelClass::Gemm => self.eff_gemm,
            KernelClass::Light => self.eff_light,
        };
        let compute = flops / (self.peak_flops * eff);
        match class {
            KernelClass::Conv => (0.0, self.launch_s + compute),
            _ => (self.launch_s, compute),
        }
    }
}

/// The inter-device fabric (per-device NIC through one non-blocking switch).
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// One-way small-message latency (seconds). TX-GAIA's 25 GbE path
    /// traverses host staging on the first CPU (no NVLink, no GPUDirect),
    /// so this includes PCIe + MPI + TCP overheads.
    pub latency_s: f64,
    /// Per-NIC bandwidth (bytes/second).
    pub bandwidth_bps: f64,
}

impl NetworkModel {
    /// 25 Gb/s Ethernet, host-staged MPI (the paper's interconnect).
    pub fn ethernet_25g() -> NetworkModel {
        NetworkModel { latency_s: 25e-6, bandwidth_bps: 25e9 / 8.0 }
    }

    /// Message service time.
    pub fn message_time(&self, bytes: f64) -> f64 {
        self.latency_s + bytes / self.bandwidth_bps
    }
}

/// Full cluster description for the simulator.
#[derive(Debug, Clone)]
pub struct ClusterModel {
    /// Devices in the cluster.
    pub n_devices: usize,
    /// Per-device compute model.
    pub device: DeviceModel,
    /// Interconnect model.
    pub net: NetworkModel,
}

impl ClusterModel {
    /// The paper's testbed at a given GPU count.
    pub fn tx_gaia(n_devices: usize) -> ClusterModel {
        ClusterModel { n_devices, device: DeviceModel::v100(), net: NetworkModel::ethernet_25g() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_time_includes_launch_floor() {
        let d = DeviceModel::v100();
        // a tiny kernel is launch-bound
        let t = d.kernel_time(KernelClass::Conv, 1e3);
        assert!(t >= d.launch_s);
        assert!(t < d.launch_s * 1.1);
    }

    #[test]
    fn kernel_time_scales_with_flops() {
        let d = DeviceModel::v100();
        let t1 = d.kernel_time(KernelClass::Gemm, 1e9);
        let t2 = d.kernel_time(KernelClass::Gemm, 2e9);
        assert!(t2 > t1);
        assert!((t2 - d.launch_s) / (t1 - d.launch_s) > 1.99);
    }

    #[test]
    fn conv_slower_than_gemm_per_flop() {
        let d = DeviceModel::v100();
        assert!(
            d.kernel_time(KernelClass::Conv, 1e9) > d.kernel_time(KernelClass::Gemm, 1e9)
        );
    }

    #[test]
    fn message_time_latency_plus_bw() {
        let n = NetworkModel::ethernet_25g();
        let t = n.message_time(3.125e9); // 1 second of wire time
        assert!((t - (1.0 + n.latency_s)).abs() < 1e-9);
        // small messages are latency-bound
        assert!(n.message_time(100.0) < 2.0 * n.latency_s);
    }

    #[test]
    fn tx_gaia_defaults() {
        let c = ClusterModel::tx_gaia(64);
        assert_eq!(c.n_devices, 64);
        assert_eq!(c.device.max_concurrency, 5);
    }

    #[test]
    fn phases_decompose_kernel_time_exactly() {
        // kernel_time is definitionally the sum of the two kernel_phases
        // components, and Conv folds its launch into the serialized phase —
        // the invariants the simulator's phase bookkeeping relies on
        let d = DeviceModel::v100();
        for class in [KernelClass::Conv, KernelClass::Gemm, KernelClass::Light] {
            let (l, c) = d.kernel_phases(class, 2.5e9);
            assert_eq!(l + c, d.kernel_time(class, 2.5e9));
        }
        let (l_conv, _) = d.kernel_phases(KernelClass::Conv, 2.5e9);
        assert_eq!(l_conv, 0.0, "conv launch must fold into the shared phase");
        let (l_gemm, _) = d.kernel_phases(KernelClass::Gemm, 2.5e9);
        assert_eq!(l_gemm, d.launch_s);
    }

    #[test]
    fn model_arithmetic_matches_sim_per_event_accounting() {
        // the contract between this module and the simulator, checked on a
        // known two-kernel chain (conv on device 0 → transfer → gemm on
        // device 1): every simulated interval must be priced by exactly the
        // published formulas — kernel_time for solo kernels, message_time
        // for the transfer — and the serial chain's makespan is their sum
        use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind};
        use crate::sim;

        let c = ClusterModel::tx_gaia(2);
        let (flops0, flops1, bytes) = (3.0e9, 1.5e9, 4.0e6);
        let g = TaskGraph {
            tasks: vec![
                Task {
                    id: 0,
                    instance: 0,
                    device: 0,
                    kind: TaskKind::Kernel { label: "k0", class: KernelClass::Conv, flops: flops0 },
                    deps: vec![],
                    op: None,
                },
                Task {
                    id: 1,
                    instance: 0,
                    device: 1,
                    kind: TaskKind::Comm { src: 0, dst: 1, bytes },
                    deps: vec![0],
                    op: None,
                },
                Task {
                    id: 2,
                    instance: 0,
                    device: 1,
                    kind: TaskKind::Kernel { label: "k1", class: KernelClass::Gemm, flops: flops1 },
                    deps: vec![1],
                    op: None,
                },
            ],
        };
        let rep = sim::simulate(&g, &c, true).unwrap();

        let kt0 = c.device.kernel_time(KernelClass::Conv, flops0);
        let kt1 = c.device.kernel_time(KernelClass::Gemm, flops1);
        let mt = c.net.message_time(bytes);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * b.abs();

        assert_eq!((rep.n_kernels, rep.n_comms), (2, 1));
        assert!(
            close(rep.makespan_s, kt0 + mt + kt1),
            "makespan {} vs model sum {}",
            rep.makespan_s,
            kt0 + mt + kt1
        );
        // the comm ledger is the one-sided NIC occupancy: one transfer,
        // exactly message_time long
        assert_eq!(rep.comm_total_s, mt);
        // device busy time = that device's solo kernel interval
        assert!(close(rep.device_busy_s[0], kt0), "{} vs {kt0}", rep.device_busy_s[0]);
        assert!(close(rep.device_busy_s[1], kt1), "{} vs {kt1}", rep.device_busy_s[1]);

        // per-event accounting on the trace
        assert_eq!(rep.trace.len(), 3);
        let ev = |id: usize| rep.trace.iter().find(|e| e.task == id).unwrap();
        assert!(!ev(0).is_comm && ev(0).device == 0);
        assert!(close(ev(0).t_end - ev(0).t_start, kt0));
        let comm = ev(1);
        assert!(comm.is_comm && comm.device == 1, "comm events land on the destination");
        assert!(close(comm.t_end - comm.t_start, mt));
        assert!(!ev(2).is_comm && ev(2).device == 1);
        assert!(close(ev(2).t_end - ev(2).t_start, kt1));
        // the chain hands off with no idle gap: each stage starts the
        // instant its predecessor retires
        assert_eq!(comm.t_start, ev(0).t_end);
        assert_eq!(ev(2).t_start, comm.t_end);
    }
}
