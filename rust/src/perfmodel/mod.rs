//! Analytic device + interconnect cost model for the cluster simulator —
//! the substitute for the paper's TX-GAIA testbed (V100 GPUs, 25 Gb/s
//! Ethernet through one non-blocking switch, no NVLink).
//!
//! Absolute constants are published device specs plus standard effective-
//! efficiency factors; the experiments only claim the paper's *shape*
//! (crossovers, who wins, comm-bound collapse), which is set by the ratios
//! compute-time : launch-overhead : message-time rather than by any single
//! constant.

use crate::mgrit::taskgraph::KernelClass;

/// One accelerator (V100-class by default).
#[derive(Debug, Clone)]
pub struct DeviceModel {
    /// Peak fp32 throughput (FLOP/s).
    pub peak_flops: f64,
    /// Achieved fraction of peak for convolution kernels (small-channel
    /// convs are heavily launch/memory-bound on CuDNN).
    pub eff_conv: f64,
    /// Achieved fraction of peak for dense GEMM.
    pub eff_gemm: f64,
    /// Elementwise kernels (bandwidth-bound; expressed as a FLOPs fraction).
    pub eff_light: f64,
    /// Fixed kernel launch + driver overhead per kernel (seconds).
    pub launch_s: f64,
    /// Maximum concurrently-resident kernels per device (the paper observes
    /// 5-way concurrency before register pressure serializes convolutions).
    pub max_concurrency: usize,
}

impl DeviceModel {
    /// NVIDIA Tesla V100 (fp32): 15.7 TFLOP/s peak.
    pub fn v100() -> DeviceModel {
        DeviceModel {
            peak_flops: 15.7e12,
            eff_conv: 0.25,
            eff_gemm: 0.70,
            eff_light: 0.02,
            launch_s: 8e-6,
            max_concurrency: 5,
        }
    }

    /// Exclusive-execution service time of one kernel.
    pub fn kernel_time(&self, class: KernelClass, flops: f64) -> f64 {
        let (l, c) = self.kernel_phases(class, flops);
        l + c
    }

    /// (launch overhead, compute time): launches on different streams
    /// overlap; compute is shared across co-resident kernels.
    ///
    /// Convolution kernels are special-cased per the paper's observation
    /// that "the number of registers within the GPU prevents multiple
    /// convolution kernels from executing simultaneously": their launch
    /// does NOT overlap with other kernels (it is folded into the shared
    /// phase), so conv-dominated schedules gain no intra-device concurrency
    /// benefit — exactly the paper's Fig 5 discussion.
    pub fn kernel_phases(&self, class: KernelClass, flops: f64) -> (f64, f64) {
        let eff = match class {
            KernelClass::Conv => self.eff_conv,
            KernelClass::Gemm => self.eff_gemm,
            KernelClass::Light => self.eff_light,
        };
        let compute = flops / (self.peak_flops * eff);
        match class {
            KernelClass::Conv => (0.0, self.launch_s + compute),
            _ => (self.launch_s, compute),
        }
    }
}

/// The inter-device fabric (per-device NIC through one non-blocking switch).
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// One-way small-message latency (seconds). TX-GAIA's 25 GbE path
    /// traverses host staging on the first CPU (no NVLink, no GPUDirect),
    /// so this includes PCIe + MPI + TCP overheads.
    pub latency_s: f64,
    /// Per-NIC bandwidth (bytes/second).
    pub bandwidth_bps: f64,
}

impl NetworkModel {
    /// 25 Gb/s Ethernet, host-staged MPI (the paper's interconnect).
    pub fn ethernet_25g() -> NetworkModel {
        NetworkModel { latency_s: 25e-6, bandwidth_bps: 25e9 / 8.0 }
    }

    /// Intra-node device-to-device staging over PCIe gen3 ×16 (the paper's
    /// nodes have no NVLink): far lower latency than the host-staged MPI
    /// fabric and ~4× its per-link bandwidth.
    pub fn pcie_gen3() -> NetworkModel {
        NetworkModel { latency_s: 5e-6, bandwidth_bps: 12e9 }
    }

    /// Message service time.
    pub fn message_time(&self, bytes: f64) -> f64 {
        self.latency_s + bytes / self.bandwidth_bps
    }
}

/// Which network tier one (src, dst) hop traverses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkTier {
    /// Both endpoints on the same node (PCIe / shared-memory staging).
    Intra,
    /// Endpoints on different nodes (the inter-node fabric).
    Inter,
}

/// Devices grouped into nodes, with one [`NetworkModel`] per tier. A hop is
/// priced by the tier it traverses: the intra-node link when both endpoints
/// share a node, the inter-node fabric otherwise. The flat (one device per
/// node) topology reproduces the legacy uniform pricing exactly — every
/// cross-device hop is an inter-node hop.
#[derive(Debug, Clone)]
pub struct Topology {
    /// `node_of[d]` = node hosting device d.
    node_of: Vec<usize>,
    /// Intra-node link (same-node, cross-device hops).
    pub intra: NetworkModel,
    /// Inter-node fabric (cross-node hops).
    pub inter: NetworkModel,
}

impl Topology {
    /// One device per node: every cross-device hop rides `fabric`, so this
    /// is bit-for-bit the pre-topology flat network (the intra tier is
    /// present but unreachable).
    pub fn flat(n_devices: usize, fabric: NetworkModel) -> Topology {
        Topology { node_of: (0..n_devices).collect(), intra: fabric.clone(), inter: fabric }
    }

    /// `n_nodes` nodes of `devices_per_node` consecutive devices each:
    /// device d lives on node `d / devices_per_node`.
    pub fn nodes(
        n_nodes: usize,
        devices_per_node: usize,
        intra: NetworkModel,
        inter: NetworkModel,
    ) -> Topology {
        let node_of = (0..n_nodes * devices_per_node).map(|d| d / devices_per_node).collect();
        Topology { node_of, intra, inter }
    }

    /// Devices in the topology.
    pub fn n_devices(&self) -> usize {
        self.node_of.len()
    }

    /// Nodes in the topology (1 + the highest node id).
    pub fn n_nodes(&self) -> usize {
        self.node_of.iter().copied().max().map_or(0, |m| m + 1)
    }

    /// Node hosting device `d`.
    pub fn node_of(&self, d: usize) -> usize {
        self.node_of[d]
    }

    /// Whether two devices share a node.
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of[a] == self.node_of[b]
    }

    /// The tier a src → dst hop traverses (src == dst is intra by
    /// convention, but such hops are free — see [`Topology::message_time`]).
    pub fn tier(&self, src: usize, dst: usize) -> LinkTier {
        if self.same_node(src, dst) {
            LinkTier::Intra
        } else {
            LinkTier::Inter
        }
    }

    /// Per-hop message service time: 0 for co-located endpoints (a local
    /// handoff — the simulator and live executor both treat src == dst
    /// transfers as free), the owning tier's `message_time` otherwise.
    pub fn message_time(&self, src: usize, dst: usize, bytes: f64) -> f64 {
        if src == dst {
            return 0.0;
        }
        match self.tier(src, dst) {
            LinkTier::Intra => self.intra.message_time(bytes),
            LinkTier::Inter => self.inter.message_time(bytes),
        }
    }
}

/// Full cluster description for the simulator. `topo.n_devices()` always
/// equals `n_devices` (both constructors guarantee it).
#[derive(Debug, Clone)]
pub struct ClusterModel {
    /// Devices in the cluster.
    pub n_devices: usize,
    /// Per-device compute model.
    pub device: DeviceModel,
    /// Node grouping + per-tier interconnect models.
    pub topo: Topology,
}

impl ClusterModel {
    /// The paper's testbed at a given GPU count: flat topology (one device
    /// per node — TX-GAIA's GPUs talk through host-staged MPI even within a
    /// node), so every hop is priced on the 25 GbE fabric.
    pub fn tx_gaia(n_devices: usize) -> ClusterModel {
        ClusterModel {
            n_devices,
            device: DeviceModel::v100(),
            topo: Topology::flat(n_devices, NetworkModel::ethernet_25g()),
        }
    }

    /// A multi-node variant of the testbed: `n_nodes` nodes of
    /// `devices_per_node` GPUs, PCIe-staged intra-node transfers, the same
    /// 25 GbE fabric between nodes.
    pub fn tx_gaia_nodes(n_nodes: usize, devices_per_node: usize) -> ClusterModel {
        ClusterModel {
            n_devices: n_nodes * devices_per_node,
            device: DeviceModel::v100(),
            topo: Topology::nodes(
                n_nodes,
                devices_per_node,
                NetworkModel::pcie_gen3(),
                NetworkModel::ethernet_25g(),
            ),
        }
    }

    /// Tier-aware per-hop pricing (see [`Topology::message_time`]).
    pub fn message_time(&self, src: usize, dst: usize, bytes: f64) -> f64 {
        self.topo.message_time(src, dst, bytes)
    }

    /// The inter-node fabric — the flat-rate model analytic expressions
    /// (e.g. the data-parallel allreduce closed form) price against.
    pub fn fabric(&self) -> &NetworkModel {
        &self.topo.inter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_time_includes_launch_floor() {
        let d = DeviceModel::v100();
        // a tiny kernel is launch-bound
        let t = d.kernel_time(KernelClass::Conv, 1e3);
        assert!(t >= d.launch_s);
        assert!(t < d.launch_s * 1.1);
    }

    #[test]
    fn kernel_time_scales_with_flops() {
        let d = DeviceModel::v100();
        let t1 = d.kernel_time(KernelClass::Gemm, 1e9);
        let t2 = d.kernel_time(KernelClass::Gemm, 2e9);
        assert!(t2 > t1);
        assert!((t2 - d.launch_s) / (t1 - d.launch_s) > 1.99);
    }

    #[test]
    fn conv_slower_than_gemm_per_flop() {
        let d = DeviceModel::v100();
        assert!(
            d.kernel_time(KernelClass::Conv, 1e9) > d.kernel_time(KernelClass::Gemm, 1e9)
        );
    }

    #[test]
    fn message_time_latency_plus_bw() {
        let n = NetworkModel::ethernet_25g();
        let t = n.message_time(3.125e9); // 1 second of wire time
        assert!((t - (1.0 + n.latency_s)).abs() < 1e-9);
        // small messages are latency-bound
        assert!(n.message_time(100.0) < 2.0 * n.latency_s);
    }

    #[test]
    fn tx_gaia_defaults() {
        let c = ClusterModel::tx_gaia(64);
        assert_eq!(c.n_devices, 64);
        assert_eq!(c.device.max_concurrency, 5);
        // flat topology: one device per node, every hop on the fabric
        assert_eq!(c.topo.n_devices(), 64);
        assert_eq!(c.topo.n_nodes(), 64);
        assert_eq!(c.message_time(0, 1, 1e6), c.fabric().message_time(1e6));
    }

    #[test]
    fn topology_tiers_price_per_hop() {
        let c = ClusterModel::tx_gaia_nodes(2, 4);
        assert_eq!(c.n_devices, 8);
        assert_eq!(c.topo.n_devices(), 8);
        assert_eq!(c.topo.n_nodes(), 2);
        // consecutive grouping: devices 0..4 on node 0, 4..8 on node 1
        assert_eq!(c.topo.node_of(3), 0);
        assert_eq!(c.topo.node_of(4), 1);
        assert!(c.topo.same_node(1, 3) && !c.topo.same_node(3, 4));
        assert_eq!(c.topo.tier(0, 2), LinkTier::Intra);
        assert_eq!(c.topo.tier(2, 6), LinkTier::Inter);
        // pricing: intra hops ride PCIe, inter hops ride the fabric,
        // co-located hops are free
        let bytes = 4.0e6;
        assert_eq!(c.message_time(0, 2, bytes), NetworkModel::pcie_gen3().message_time(bytes));
        assert_eq!(c.message_time(2, 6, bytes), NetworkModel::ethernet_25g().message_time(bytes));
        assert_eq!(c.message_time(5, 5, bytes), 0.0);
        // the intra link must actually be faster, or the two-phase
        // collective's phase split buys nothing
        assert!(c.message_time(0, 2, bytes) < c.message_time(2, 6, bytes));
    }

    #[test]
    fn phases_decompose_kernel_time_exactly() {
        // kernel_time is definitionally the sum of the two kernel_phases
        // components, and Conv folds its launch into the serialized phase —
        // the invariants the simulator's phase bookkeeping relies on
        let d = DeviceModel::v100();
        for class in [KernelClass::Conv, KernelClass::Gemm, KernelClass::Light] {
            let (l, c) = d.kernel_phases(class, 2.5e9);
            assert_eq!(l + c, d.kernel_time(class, 2.5e9));
        }
        let (l_conv, _) = d.kernel_phases(KernelClass::Conv, 2.5e9);
        assert_eq!(l_conv, 0.0, "conv launch must fold into the shared phase");
        let (l_gemm, _) = d.kernel_phases(KernelClass::Gemm, 2.5e9);
        assert_eq!(l_gemm, d.launch_s);
    }

    #[test]
    fn model_arithmetic_matches_sim_per_event_accounting() {
        // the contract between this module and the simulator, checked on a
        // known two-kernel chain (conv on device 0 → transfer → gemm on
        // device 1): every simulated interval must be priced by exactly the
        // published formulas — kernel_time for solo kernels, message_time
        // for the transfer — and the serial chain's makespan is their sum
        use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind};
        use crate::sim;

        let c = ClusterModel::tx_gaia(2);
        let (flops0, flops1, bytes) = (3.0e9, 1.5e9, 4.0e6);
        let g = TaskGraph {
            tasks: vec![
                Task {
                    id: 0,
                    instance: 0,
                    device: 0,
                    kind: TaskKind::Kernel { label: "k0", class: KernelClass::Conv, flops: flops0 },
                    deps: vec![],
                    op: None,
                },
                Task {
                    id: 1,
                    instance: 0,
                    device: 1,
                    kind: TaskKind::Comm { src: 0, dst: 1, bytes },
                    deps: vec![0],
                    op: None,
                },
                Task {
                    id: 2,
                    instance: 0,
                    device: 1,
                    kind: TaskKind::Kernel { label: "k1", class: KernelClass::Gemm, flops: flops1 },
                    deps: vec![1],
                    op: None,
                },
            ],
        };
        let rep = sim::simulate(&g, &c, true).unwrap();

        let kt0 = c.device.kernel_time(KernelClass::Conv, flops0);
        let kt1 = c.device.kernel_time(KernelClass::Gemm, flops1);
        let mt = c.message_time(0, 1, bytes);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * b.abs();

        assert_eq!((rep.n_kernels, rep.n_comms), (2, 1));
        assert!(
            close(rep.makespan_s, kt0 + mt + kt1),
            "makespan {} vs model sum {}",
            rep.makespan_s,
            kt0 + mt + kt1
        );
        // the comm ledger is the one-sided NIC occupancy: one transfer,
        // exactly message_time long
        assert_eq!(rep.comm_total_s, mt);
        // device busy time = that device's solo kernel interval
        assert!(close(rep.device_busy_s[0], kt0), "{} vs {kt0}", rep.device_busy_s[0]);
        assert!(close(rep.device_busy_s[1], kt1), "{} vs {kt1}", rep.device_busy_s[1]);

        // per-event accounting on the trace
        assert_eq!(rep.trace.len(), 3);
        let ev = |id: usize| rep.trace.iter().find(|e| e.task == id).unwrap();
        assert!(!ev(0).is_comm && ev(0).device == 0);
        assert!(close(ev(0).t_end - ev(0).t_start, kt0));
        let comm = ev(1);
        assert!(comm.is_comm && comm.device == 1, "comm events land on the destination");
        assert!(close(comm.t_end - comm.t_start, mt));
        assert!(!ev(2).is_comm && ev(2).device == 1);
        assert!(close(ev(2).t_end - ev(2).t_start, kt1));
        // the chain hands off with no idle gap: each stage starts the
        // instant its predecessor retires
        assert_eq!(comm.t_start, ev(0).t_end);
        assert_eq!(ev(2).t_start, comm.t_end);
    }

    #[test]
    fn tiered_model_arithmetic_matches_sim_on_two_node_chain() {
        // same contract as above, on a known TWO-NODE chain: an intra-node
        // hop (device 0 → 1, node 0) then an inter-node hop (device 1 → 2,
        // node 0 → 1). Each simulated transfer must be priced by ITS tier's
        // message_time, the two-level ledger must split exactly along the
        // tier boundary, and only the inter hop's bytes count as cross-node
        use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind};
        use crate::sim;

        let c = ClusterModel::tx_gaia_nodes(2, 2);
        let (flops0, bytes_intra, bytes_inter) = (2.0e9, 3.0e6, 5.0e6);
        let g = TaskGraph {
            tasks: vec![
                Task {
                    id: 0,
                    instance: 0,
                    device: 0,
                    kind: TaskKind::Kernel { label: "k0", class: KernelClass::Conv, flops: flops0 },
                    deps: vec![],
                    op: None,
                },
                Task {
                    id: 1,
                    instance: 0,
                    device: 1,
                    kind: TaskKind::Comm { src: 0, dst: 1, bytes: bytes_intra },
                    deps: vec![0],
                    op: None,
                },
                Task {
                    id: 2,
                    instance: 0,
                    device: 2,
                    kind: TaskKind::Comm { src: 1, dst: 2, bytes: bytes_inter },
                    deps: vec![1],
                    op: None,
                },
            ],
        };
        let rep = sim::simulate(&g, &c, true).unwrap();

        let kt0 = c.device.kernel_time(KernelClass::Conv, flops0);
        let mt_intra = c.topo.intra.message_time(bytes_intra);
        let mt_inter = c.topo.inter.message_time(bytes_inter);
        assert_eq!(c.message_time(0, 1, bytes_intra), mt_intra);
        assert_eq!(c.message_time(1, 2, bytes_inter), mt_inter);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-9 * b.abs();

        assert_eq!((rep.n_kernels, rep.n_comms), (1, 2));
        assert!(close(rep.makespan_s, kt0 + mt_intra + mt_inter));
        // the two-level ledger splits on the tier boundary and still sums
        // to the legacy total
        assert_eq!(rep.comm_intra_s, mt_intra);
        assert_eq!(rep.comm_inter_s, mt_inter);
        assert_eq!(rep.comm_total_s, mt_intra + mt_inter);
        assert_eq!(rep.cross_node_bytes, bytes_inter);
        // per-event agreement on the trace
        let ev = |id: usize| rep.trace.iter().find(|e| e.task == id).unwrap();
        assert!(close(ev(1).t_end - ev(1).t_start, mt_intra));
        assert!(close(ev(2).t_end - ev(2).t_start, mt_inter));
    }
}
