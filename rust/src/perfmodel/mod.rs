//! Analytic device + interconnect cost model for the cluster simulator —
//! the substitute for the paper's TX-GAIA testbed (V100 GPUs, 25 Gb/s
//! Ethernet through one non-blocking switch, no NVLink).
//!
//! Absolute constants are published device specs plus standard effective-
//! efficiency factors; the experiments only claim the paper's *shape*
//! (crossovers, who wins, comm-bound collapse), which is set by the ratios
//! compute-time : launch-overhead : message-time rather than by any single
//! constant.

use crate::mgrit::taskgraph::KernelClass;

/// One accelerator (V100-class by default).
#[derive(Debug, Clone)]
pub struct DeviceModel {
    /// Peak fp32 throughput (FLOP/s).
    pub peak_flops: f64,
    /// Achieved fraction of peak for convolution kernels (small-channel
    /// convs are heavily launch/memory-bound on CuDNN).
    pub eff_conv: f64,
    /// Achieved fraction of peak for dense GEMM.
    pub eff_gemm: f64,
    /// Elementwise kernels (bandwidth-bound; expressed as a FLOPs fraction).
    pub eff_light: f64,
    /// Fixed kernel launch + driver overhead per kernel (seconds).
    pub launch_s: f64,
    /// Maximum concurrently-resident kernels per device (the paper observes
    /// 5-way concurrency before register pressure serializes convolutions).
    pub max_concurrency: usize,
}

impl DeviceModel {
    /// NVIDIA Tesla V100 (fp32): 15.7 TFLOP/s peak.
    pub fn v100() -> DeviceModel {
        DeviceModel {
            peak_flops: 15.7e12,
            eff_conv: 0.25,
            eff_gemm: 0.70,
            eff_light: 0.02,
            launch_s: 8e-6,
            max_concurrency: 5,
        }
    }

    /// Exclusive-execution service time of one kernel.
    pub fn kernel_time(&self, class: KernelClass, flops: f64) -> f64 {
        let (l, c) = self.kernel_phases(class, flops);
        l + c
    }

    /// (launch overhead, compute time): launches on different streams
    /// overlap; compute is shared across co-resident kernels.
    ///
    /// Convolution kernels are special-cased per the paper's observation
    /// that "the number of registers within the GPU prevents multiple
    /// convolution kernels from executing simultaneously": their launch
    /// does NOT overlap with other kernels (it is folded into the shared
    /// phase), so conv-dominated schedules gain no intra-device concurrency
    /// benefit — exactly the paper's Fig 5 discussion.
    pub fn kernel_phases(&self, class: KernelClass, flops: f64) -> (f64, f64) {
        let eff = match class {
            KernelClass::Conv => self.eff_conv,
            KernelClass::Gemm => self.eff_gemm,
            KernelClass::Light => self.eff_light,
        };
        let compute = flops / (self.peak_flops * eff);
        match class {
            KernelClass::Conv => (0.0, self.launch_s + compute),
            _ => (self.launch_s, compute),
        }
    }
}

/// The inter-device fabric (per-device NIC through one non-blocking switch).
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// One-way small-message latency (seconds). TX-GAIA's 25 GbE path
    /// traverses host staging on the first CPU (no NVLink, no GPUDirect),
    /// so this includes PCIe + MPI + TCP overheads.
    pub latency_s: f64,
    /// Per-NIC bandwidth (bytes/second).
    pub bandwidth_bps: f64,
}

impl NetworkModel {
    /// 25 Gb/s Ethernet, host-staged MPI (the paper's interconnect).
    pub fn ethernet_25g() -> NetworkModel {
        NetworkModel { latency_s: 25e-6, bandwidth_bps: 25e9 / 8.0 }
    }

    /// Message service time.
    pub fn message_time(&self, bytes: f64) -> f64 {
        self.latency_s + bytes / self.bandwidth_bps
    }
}

/// Full cluster description for the simulator.
#[derive(Debug, Clone)]
pub struct ClusterModel {
    /// Devices in the cluster.
    pub n_devices: usize,
    /// Per-device compute model.
    pub device: DeviceModel,
    /// Interconnect model.
    pub net: NetworkModel,
}

impl ClusterModel {
    /// The paper's testbed at a given GPU count.
    pub fn tx_gaia(n_devices: usize) -> ClusterModel {
        ClusterModel { n_devices, device: DeviceModel::v100(), net: NetworkModel::ethernet_25g() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_time_includes_launch_floor() {
        let d = DeviceModel::v100();
        // a tiny kernel is launch-bound
        let t = d.kernel_time(KernelClass::Conv, 1e3);
        assert!(t >= d.launch_s);
        assert!(t < d.launch_s * 1.1);
    }

    #[test]
    fn kernel_time_scales_with_flops() {
        let d = DeviceModel::v100();
        let t1 = d.kernel_time(KernelClass::Gemm, 1e9);
        let t2 = d.kernel_time(KernelClass::Gemm, 2e9);
        assert!(t2 > t1);
        assert!((t2 - d.launch_s) / (t1 - d.launch_s) > 1.99);
    }

    #[test]
    fn conv_slower_than_gemm_per_flop() {
        let d = DeviceModel::v100();
        assert!(
            d.kernel_time(KernelClass::Conv, 1e9) > d.kernel_time(KernelClass::Gemm, 1e9)
        );
    }

    #[test]
    fn message_time_latency_plus_bw() {
        let n = NetworkModel::ethernet_25g();
        let t = n.message_time(3.125e9); // 1 second of wire time
        assert!((t - (1.0 + n.latency_s)).abs() < 1e-9);
        // small messages are latency-bound
        assert!(n.message_time(100.0) < 2.0 * n.latency_s);
    }

    #[test]
    fn tx_gaia_defaults() {
        let c = ClusterModel::tx_gaia(64);
        assert_eq!(c.n_devices, 64);
        assert_eq!(c.device.max_concurrency, 5);
    }
}
