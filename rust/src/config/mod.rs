//! Layered run configuration: defaults ← optional config file ← CLI flags.
//!
//! The config file format is a minimal `key = value` per line (`#` comments),
//! covering exactly the knobs the CLI exposes, so runs are reproducible from
//! a checked-in file (`mgrit train --config runs/mnist.cfg --lr 0.1`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context};

use crate::mgrit::{MgritOptions, RelaxKind};
use crate::util::args::Args;
use crate::Result;

/// Everything a run needs; sub-structs are derived views.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Network preset name (`micro`, `mnist`, `fig6`, …).
    pub preset: String,
    /// Minibatch size.
    pub batch: usize,
    /// MG cycles per solve/step.
    pub cycles: usize,
    /// Worker devices (streams).
    pub devices: usize,
    /// Training steps.
    pub steps: usize,
    /// SGD learning rate.
    pub lr: f64,
    /// PRNG seed (init + data).
    pub seed: u64,
    /// MGRIT convergence tolerance.
    pub tol: f64,
    /// Maximum MGRIT hierarchy levels.
    pub max_levels: usize,
    /// Relaxation sweep pattern.
    pub relax: RelaxKind,
    /// MNIST idx directory (synthetic fallback if absent).
    pub data_dir: String,
    /// AOT artifact directory for the pjrt backend.
    pub artifacts_dir: String,
    /// Execution backend: "host" (pure rust) or "pjrt" (AOT artifacts).
    pub backend: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            preset: "mnist".into(),
            batch: 16,
            cycles: 2,
            devices: 4,
            steps: 200,
            lr: 0.05,
            seed: 7,
            tol: 1e-9,
            max_levels: 2,
            relax: RelaxKind::FCF,
            data_dir: "data".into(),
            artifacts_dir: "artifacts".into(),
            backend: "host".into(),
        }
    }
}

fn parse_relax(s: &str) -> Result<RelaxKind> {
    Ok(match s.to_ascii_uppercase().as_str() {
        "F" => RelaxKind::F,
        "FC" => RelaxKind::FC,
        "FCF" => RelaxKind::FCF,
        _ => bail!("unknown relaxation {s:?} (F|FC|FCF)"),
    })
}

/// Parse a `key = value` config file into a map.
pub fn parse_config_file(text: &str) -> Result<BTreeMap<String, String>> {
    let mut map = BTreeMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected `key = value`, got {raw:?}", lineno + 1))?;
        map.insert(k.trim().to_string(), v.trim().to_string());
    }
    Ok(map)
}

impl RunConfig {
    /// Defaults ← config file (if `--config`) ← CLI flags.
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(Path::new(path))
                .with_context(|| format!("reading config {path}"))?;
            let map = parse_config_file(&text)?;
            cfg.apply(&map)?;
        }
        // CLI flags override
        let mut cli = BTreeMap::new();
        for key in [
            "preset", "batch", "cycles", "devices", "steps", "lr", "seed", "tol",
            "max-levels", "relax", "data-dir", "artifacts-dir", "backend",
        ] {
            if let Some(v) = args.get(key) {
                cli.insert(key.replace('-', "_"), v.to_string());
            }
        }
        cfg.apply(&cli)?;
        cfg.validate()?;
        Ok(cfg)
    }

    fn apply(&mut self, map: &BTreeMap<String, String>) -> Result<()> {
        for (k, v) in map {
            match k.as_str() {
                "preset" => self.preset = v.clone(),
                "batch" => self.batch = v.parse().with_context(|| format!("batch={v}"))?,
                "cycles" => self.cycles = v.parse().with_context(|| format!("cycles={v}"))?,
                "devices" => self.devices = v.parse().with_context(|| format!("devices={v}"))?,
                "steps" => self.steps = v.parse().with_context(|| format!("steps={v}"))?,
                "lr" => self.lr = v.parse().with_context(|| format!("lr={v}"))?,
                "seed" => self.seed = v.parse().with_context(|| format!("seed={v}"))?,
                "tol" => self.tol = v.parse().with_context(|| format!("tol={v}"))?,
                "max_levels" => {
                    self.max_levels = v.parse().with_context(|| format!("max_levels={v}"))?
                }
                "relax" => self.relax = parse_relax(v)?,
                "data_dir" => self.data_dir = v.clone(),
                "artifacts_dir" => self.artifacts_dir = v.clone(),
                "backend" => self.backend = v.clone(),
                _ => bail!("unknown config key {k:?}"),
            }
        }
        Ok(())
    }

    /// Reject configurations no run mode accepts.
    pub fn validate(&self) -> Result<()> {
        if self.batch == 0 || self.devices == 0 || self.cycles == 0 {
            bail!("batch/devices/cycles must be positive");
        }
        if !matches!(self.backend.as_str(), "host" | "pjrt") {
            bail!("backend must be host|pjrt, got {:?}", self.backend);
        }
        crate::model::NetSpec::by_name(&self.preset)?;
        Ok(())
    }

    /// MGRIT options implied by this config.
    pub fn mgrit_options(&self) -> MgritOptions {
        MgritOptions {
            max_cycles: self.cycles,
            tol: self.tol,
            relax: self.relax,
            max_levels: self.max_levels,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(parts: &[&str]) -> Args {
        Args::parse(parts.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn defaults_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn cli_overrides() {
        let a = args(&["train", "--preset", "micro", "--lr", "0.1", "--relax", "FC"]);
        let cfg = RunConfig::from_args(&a).unwrap();
        assert_eq!(cfg.preset, "micro");
        assert_eq!(cfg.lr, 0.1);
        assert_eq!(cfg.relax, RelaxKind::FC);
        assert_eq!(cfg.batch, 16); // default preserved
    }

    #[test]
    fn config_file_then_cli() {
        let dir = std::env::temp_dir().join("mgrit_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.cfg");
        std::fs::write(&path, "# a run\npreset = micro\nlr = 0.2\nbatch = 4\n").unwrap();
        let a = args(&["train", "--config", path.to_str().unwrap(), "--lr", "0.3"]);
        let cfg = RunConfig::from_args(&a).unwrap();
        assert_eq!(cfg.preset, "micro");
        assert_eq!(cfg.batch, 4); // from file
        assert_eq!(cfg.lr, 0.3); // CLI wins
    }

    #[test]
    fn bad_values_rejected() {
        assert!(RunConfig::from_args(&args(&["x", "--preset", "nope"])).is_err());
        assert!(RunConfig::from_args(&args(&["x", "--batch", "0"])).is_err());
        assert!(RunConfig::from_args(&args(&["x", "--relax", "XYZ"])).is_err());
        assert!(RunConfig::from_args(&args(&["x", "--backend", "cuda"])).is_err());
    }

    #[test]
    fn file_parser_handles_comments_and_errors() {
        let m = parse_config_file("a = 1\n# comment\n\nb = two # trailing\n").unwrap();
        assert_eq!(m["a"], "1");
        assert_eq!(m["b"], "two");
        assert!(parse_config_file("not-a-pair\n").is_err());
    }

    #[test]
    fn mgrit_options_derived() {
        let a = args(&["x", "--cycles", "3", "--tol", "1e-6", "--max-levels", "4"]);
        let cfg = RunConfig::from_args(&a).unwrap();
        let o = cfg.mgrit_options();
        assert_eq!(o.max_cycles, 3);
        assert_eq!(o.tol, 1e-6);
        assert_eq!(o.max_levels, 4);
    }
}
