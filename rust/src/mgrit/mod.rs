//! The paper's algorithmic contribution: nonlinear multigrid (FAS / MGRIT)
//! applied to the layer dimension of a residual network.
//!
//! The forward propagation u^{n+1} = u^n + h·F(u^n; θ^n) is a lower-
//! bidiagonal nonlinear system L_h(U) = f (paper eq. 18). Instead of the
//! O(N)-sequential forward substitution, MGRIT relaxes all layer blocks
//! concurrently (F-/C-relaxation), restricts the residual to a coarser layer
//! grid (every c-th layer), solves the FAS-corrected coarse system there, and
//! prolongates the correction back (Algorithm 1 of the paper).
//!
//! Submodules:
//! - [`hierarchy`] — the level structure (strides, step sizes, C/F points)
//! - [`fas`]       — relaxation, restriction, coarse solve, correction, cycles
//! - [`adjoint`]   — the backward pass as MGRIT on the adjoint ODE
//! - [`taskgraph`] — the schedule DAG consumed by the cluster simulator

pub mod adjoint;
pub mod fas;
pub mod hierarchy;
pub mod taskgraph;

pub use fas::{solve_forward, CycleStats, LevelState, MgritOptions, RelaxKind};
pub use hierarchy::{Hierarchy, Level};
pub use taskgraph::{Collective, Granularity};
