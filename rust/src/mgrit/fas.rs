//! The FAS/MGRIT cycle: relaxation, restriction, coarse solve, correction
//! (the paper's Algorithm 1, generalized to multilevel V-cycles).
//!
//! Everything here is expressed block-wise so the serial driver (this file)
//! and the parallel coordinator (`coordinator::driver`) share one
//! implementation of the algebra — the coordinator only changes *where*
//! each block primitive runs.

use anyhow::{bail, Result};

use super::hierarchy::{Hierarchy, Level};
use crate::solver::BlockSolver;
use crate::tensor::Tensor;

/// Relaxation sweep pattern. The paper uses FCF.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RelaxKind {
    /// F-relaxation only.
    F,
    /// F then C.
    FC,
    /// F, C, F — the paper's Algorithm 1 step 1.
    FCF,
}

/// Options for an MGRIT solve.
#[derive(Debug, Clone)]
pub struct MgritOptions {
    /// Maximum MG cycles; training uses the paper's early stopping (2).
    pub max_cycles: usize,
    /// Convergence tolerance on ‖R_h‖_{L2} (Fig 4 runs to 1e-9).
    pub tol: f64,
    /// Relaxation sweep pattern per cycle.
    pub relax: RelaxKind,
    /// Maximum levels in the hierarchy (2 = the paper's Algorithm 1).
    pub max_levels: usize,
    /// Stop coarsening at this many points; the coarsest level is solved
    /// exactly by forward substitution.
    pub min_coarse_points: usize,
}

impl Default for MgritOptions {
    fn default() -> Self {
        MgritOptions { max_cycles: 20, tol: 1e-9, relax: RelaxKind::FCF, max_levels: 2, min_coarse_points: 8 }
    }
}

impl MgritOptions {
    /// The paper's training configuration: 2 cycles, no tolerance exit.
    pub fn early_stopping(cycles: usize) -> Self {
        MgritOptions { max_cycles: cycles, tol: 0.0, ..Default::default() }
    }
}

/// Per-solve convergence record (Fig 4's data).
#[derive(Debug, Clone)]
pub struct CycleStats {
    /// ‖R_h‖ after each cycle.
    pub residual_norms: Vec<f64>,
    /// Whether the tolerance was reached before the cycle cap.
    pub converged: bool,
    /// Number of Φ applications performed (the solve's work measure).
    pub phi_evals: usize,
}

/// The unknowns of one level: layer states `u[0..n_points]` plus the FAS
/// right-hand side `g` (None on the finest level, where g ≡ 0 for all
/// points except the fixed input u[0]).
#[derive(Debug, Clone)]
pub struct LevelState {
    /// Point states `u[0..n_points]`.
    pub u: Vec<Tensor>,
    /// FAS right-hand side (None on the finest level, where g ≡ 0).
    pub g: Option<Vec<Tensor>>,
}

impl LevelState {
    /// Initial fine-level state: u[0] = u0, all other points seeded with u0
    /// (a constant-in-depth initial guess — any guess converges, this one
    /// makes cycle-1 residuals well-scaled).
    pub fn initial(u0: &Tensor, n_points: usize) -> LevelState {
        LevelState { u: vec![u0.clone(); n_points], g: None }
    }

    fn rhs(&self, j: usize) -> Option<&Tensor> {
        self.g.as_ref().map(|g| &g[j])
    }
}

/// u[j] = Φ(u[j−1]) + g[j] — the elementary update of every relaxation.
fn point_update<S: BlockSolver>(
    solver: &S,
    lvl: &Level,
    st: &mut LevelState,
    j: usize,
    phi_evals: &mut usize,
) -> Result<()> {
    debug_assert!(j >= 1 && j < lvl.n_points);
    let mut v = solver.step(lvl.theta_idx(j - 1), lvl.h, &st.u[j - 1])?;
    *phi_evals += 1;
    if let Some(gj) = st.rhs(j) {
        v.axpy(1.0, gj)?;
    }
    st.u[j] = v;
    Ok(())
}

/// F-relaxation of one block: from its C-point, recompute the F-points
/// sequentially (the paper's Fig 3, right). Independent across blocks —
/// the unit of layer parallelism.
pub fn f_relax_block<S: BlockSolver>(
    solver: &S,
    lvl: &Level,
    st: &mut LevelState,
    block: super::hierarchy::Block,
    phi_evals: &mut usize,
) -> Result<()> {
    for j in block.cpoint + 1..=block.f_end {
        point_update(solver, lvl, st, j, phi_evals)?;
    }
    Ok(())
}

/// F-relaxation over all blocks (serial reference; the coordinator fans the
/// per-block calls out to streams/devices).
pub fn f_relax<S: BlockSolver>(
    solver: &S,
    lvl: &Level,
    coarsen: usize,
    st: &mut LevelState,
    phi_evals: &mut usize,
) -> Result<()> {
    for b in lvl.blocks(coarsen) {
        f_relax_block(solver, lvl, st, b, phi_evals)?;
    }
    Ok(())
}

/// C-relaxation: update every C-point from the preceding F-point (the
/// paper's Fig 3, left). Independent across C-points given current states.
pub fn c_relax<S: BlockSolver>(
    solver: &S,
    lvl: &Level,
    coarsen: usize,
    st: &mut LevelState,
    phi_evals: &mut usize,
) -> Result<()> {
    for cp in lvl.cpoints(coarsen) {
        if cp > 0 {
            point_update(solver, lvl, st, cp, phi_evals)?;
        }
    }
    Ok(())
}

/// The residual r[j] = g[j] + Φ(u[j−1]) − u[j] at one point (paper eq. 19
/// with our sign convention; zero iff the step equation holds at j).
pub fn residual_at<S: BlockSolver>(
    solver: &S,
    lvl: &Level,
    st: &LevelState,
    j: usize,
    phi_evals: &mut usize,
) -> Result<Tensor> {
    debug_assert!(j >= 1);
    let mut r = solver.step(lvl.theta_idx(j - 1), lvl.h, &st.u[j - 1])?;
    *phi_evals += 1;
    if let Some(gj) = st.rhs(j) {
        r.axpy(1.0, gj)?;
    }
    r.axpy(-1.0, &st.u[j])?;
    Ok(r)
}

/// ‖R‖_{L2} over all C-points (the convergence functional of Fig 4).
/// After F-relaxation the F-point residuals vanish identically, so the
/// C-point residual is the whole residual.
pub fn residual_norm<S: BlockSolver>(
    solver: &S,
    lvl: &Level,
    coarsen: usize,
    st: &LevelState,
    phi_evals: &mut usize,
) -> Result<f64> {
    let mut acc = 0.0f64;
    for cp in lvl.cpoints(coarsen) {
        if cp > 0 {
            let r = residual_at(solver, lvl, st, cp, phi_evals)?;
            let n = r.l2_norm();
            acc += n * n;
        }
    }
    Ok(acc.sqrt())
}

/// FAS restriction (paper Algorithm 1 step 2 + eq. 24): inject the C-point
/// states to the coarse level and build the coarse right-hand side
/// S_H[j] = (ū_H[j] − Φ_H(ū_H[j−1])) + r_h[jc].
///
/// Returns the coarse state (initial guess = injection) and a copy of the
/// injected values (needed for the correction step).
pub fn restrict<S: BlockSolver>(
    solver: &S,
    fine: &Level,
    coarse: &Level,
    coarsen: usize,
    st: &LevelState,
    phi_evals: &mut usize,
) -> Result<(LevelState, Vec<Tensor>)> {
    let injected: Vec<Tensor> =
        (0..coarse.n_points).map(|j| st.u[j * coarsen].clone()).collect();
    let mut g = Vec::with_capacity(coarse.n_points);
    g.push(Tensor::zeros(injected[0].dims())); // g[0] unused (u[0] fixed)
    for j in 1..coarse.n_points {
        // fine residual at the C-point
        let mut gj = residual_at(solver, fine, st, j * coarsen, phi_evals)?;
        // + τ-term: ū_H[j] − Φ_H(ū_H[j−1])
        let phi_h = solver.step(coarse.theta_idx(j - 1), coarse.h, &injected[j - 1])?;
        *phi_evals += 1;
        gj.axpy(1.0, &injected[j])?;
        gj.axpy(-1.0, &phi_h)?;
        g.push(gj);
    }
    let coarse_st = LevelState { u: injected.clone(), g: Some(g) };
    Ok((coarse_st, injected))
}

/// Exact solve of L(V) = g on a level by forward substitution — O(n) serial,
/// used on the coarsest level where n is small.
pub fn solve_exact<S: BlockSolver>(
    solver: &S,
    lvl: &Level,
    st: &mut LevelState,
    phi_evals: &mut usize,
) -> Result<()> {
    for j in 1..lvl.n_points {
        point_update(solver, lvl, st, j, phi_evals)?;
    }
    Ok(())
}

/// FAS correction (Algorithm 1 step 5): u_h[jc] += v_H[j] − ū_H[j].
pub fn correct(
    fine_st: &mut LevelState,
    coarse_st: &LevelState,
    injected_old: &[Tensor],
    coarsen: usize,
) -> Result<()> {
    if coarse_st.u.len() != injected_old.len() {
        bail!("correction size mismatch");
    }
    for j in 1..coarse_st.u.len() {
        let mut delta = Tensor::sub(&coarse_st.u[j], &injected_old[j])?;
        std::mem::swap(&mut delta, &mut fine_st.u[j * coarsen]);
        fine_st.u[j * coarsen].axpy(1.0, &delta)?;
    }
    Ok(())
}

/// One multigrid cycle on `level` (recursive V-cycle; at the coarsest level,
/// exact forward substitution).
pub fn vcycle<S: BlockSolver>(
    solver: &S,
    hier: &Hierarchy,
    level: usize,
    st: &mut LevelState,
    opts: &MgritOptions,
    phi_evals: &mut usize,
) -> Result<()> {
    let lvl = &hier.levels[level];
    if level == hier.n_levels() - 1 {
        return solve_exact(solver, lvl, st, phi_evals);
    }
    let c = hier.coarsen;
    // step 1: relaxation
    match opts.relax {
        RelaxKind::F => f_relax(solver, lvl, c, st, phi_evals)?,
        RelaxKind::FC => {
            f_relax(solver, lvl, c, st, phi_evals)?;
            c_relax(solver, lvl, c, st, phi_evals)?;
        }
        RelaxKind::FCF => {
            f_relax(solver, lvl, c, st, phi_evals)?;
            c_relax(solver, lvl, c, st, phi_evals)?;
            f_relax(solver, lvl, c, st, phi_evals)?;
        }
    }
    // steps 2–4: restrict, coarse solve (recursively), correct
    let coarse = &hier.levels[level + 1];
    let (mut coarse_st, injected) = restrict(solver, lvl, coarse, c, st, phi_evals)?;
    vcycle(solver, hier, level + 1, &mut coarse_st, opts, phi_evals)?;
    correct(st, &coarse_st, &injected, c)?;
    // step 5 epilogue: refresh F-points from the corrected C-points
    f_relax(solver, lvl, c, st, phi_evals)?;
    Ok(())
}

/// Full MGRIT solve of the forward propagation: returns the layer states
/// `u[0..=N]` and the per-cycle residual history.
///
/// `u0` is the trunk input (the opening layer's output). The serial
/// equivalent is `solver.block_fprop(0, 1, N, h, u0)`.
pub fn solve_forward<S: BlockSolver>(
    solver: &S,
    n_layers: usize,
    h: f32,
    u0: &Tensor,
    opts: &MgritOptions,
) -> Result<(Vec<Tensor>, CycleStats)> {
    let hier = Hierarchy::build(
        n_layers,
        h,
        coarsen_for(n_layers),
        opts.max_levels,
        opts.min_coarse_points,
    )?;
    solve_forward_with(solver, &hier, u0, opts)
}

/// As [`solve_forward`] with an explicit hierarchy (choose your own c).
pub fn solve_forward_with<S: BlockSolver>(
    solver: &S,
    hier: &Hierarchy,
    u0: &Tensor,
    opts: &MgritOptions,
) -> Result<(Vec<Tensor>, CycleStats)> {
    let fine = hier.fine().clone();
    let mut st = LevelState::initial(u0, fine.n_points);
    let mut stats = CycleStats { residual_norms: Vec::new(), converged: false, phi_evals: 0 };
    for _cycle in 0..opts.max_cycles {
        vcycle(solver, hier, 0, &mut st, opts, &mut stats.phi_evals)?;
        let norm = residual_norm(solver, &fine, hier.coarsen, &st, &mut stats.phi_evals)?;
        stats.residual_norms.push(norm);
        if norm <= opts.tol {
            stats.converged = true;
            break;
        }
    }
    Ok((st.u, stats))
}

/// Default coarsening factor when the caller doesn't pin one (the paper's
/// figures use c = 4).
pub fn coarsen_for(_n_layers: usize) -> usize {
    4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NetParams, NetSpec};
    use crate::solver::host::HostSolver;
    use crate::util::prng::Rng;
    use std::sync::Arc;

    fn solver_for(spec: NetSpec, seed: u64) -> HostSolver {
        let spec = Arc::new(spec);
        let params = Arc::new(NetParams::init(&spec, seed).unwrap());
        HostSolver::new(spec, params).unwrap()
    }

    fn serial_states(s: &HostSolver, u0: &Tensor) -> Vec<Tensor> {
        let n = s.spec().n_res();
        let mut out = vec![u0.clone()];
        out.extend(s.block_fprop(0, 1, n, s.spec().h(), u0).unwrap());
        out
    }

    #[test]
    fn converged_solve_matches_serial_forward() {
        let s = solver_for(NetSpec::micro(), 5);
        let mut rng = Rng::new(6);
        let u0 = Tensor::randn(&[2, 2, 6, 6], 1.0, &mut rng);
        let opts = MgritOptions { tol: 1e-6, max_cycles: 30, ..Default::default() };
        let (mg, stats) = solve_forward(&s, 4, s.spec().h(), &u0, &opts).unwrap();
        assert!(stats.converged, "norms: {:?}", stats.residual_norms);
        let serial = serial_states(&s, &u0);
        for (a, b) in mg.iter().zip(&serial) {
            assert!(
                crate::util::stats::rel_l2_err(a.data(), b.data()) < 1e-5,
                "MG != serial"
            );
        }
    }

    #[test]
    fn residual_decreases_monotonically() {
        let spec = NetSpec::mnist();
        let s = solver_for(spec, 7);
        let mut rng = Rng::new(8);
        let u0 = Tensor::randn(&[1, 8, 28, 28], 0.5, &mut rng);
        let opts = MgritOptions { tol: 0.0, max_cycles: 6, ..Default::default() };
        let (_, stats) = solve_forward(&s, 32, s.spec().h(), &u0, &opts).unwrap();
        for w in stats.residual_norms.windows(2) {
            assert!(w[1] <= w[0] * 1.01, "residual grew: {:?}", stats.residual_norms);
        }
        // FCF + coarse correction should contract strongly on a smooth net
        assert!(
            stats.residual_norms.last().unwrap() < &(stats.residual_norms[0] * 1e-3),
            "{:?}",
            stats.residual_norms
        );
    }

    #[test]
    fn exact_trajectory_has_zero_residual() {
        let s = solver_for(NetSpec::micro(), 9);
        let mut rng = Rng::new(10);
        let u0 = Tensor::randn(&[1, 2, 6, 6], 1.0, &mut rng);
        let serial = serial_states(&s, &u0);
        let st = LevelState { u: serial, g: None };
        let lvl = Level { stride: 1, h: s.spec().h(), n_points: 5 };
        let mut evals = 0;
        let norm = residual_norm(&s, &lvl, 2, &st, &mut evals).unwrap();
        assert!(norm < 1e-5, "norm {norm}");
    }

    #[test]
    fn f_relax_zeroes_fpoint_residuals() {
        let s = solver_for(NetSpec::micro(), 11);
        let mut rng = Rng::new(12);
        let u0 = Tensor::randn(&[1, 2, 6, 6], 1.0, &mut rng);
        let lvl = Level { stride: 1, h: s.spec().h(), n_points: 5 };
        let mut st = LevelState::initial(&u0, 5);
        let mut evals = 0;
        f_relax(&s, &lvl, 2, &mut st, &mut evals).unwrap();
        // F-points are 1, 3 with c=2: their residuals must vanish
        for j in [1usize, 3] {
            let r = residual_at(&s, &lvl, &st, j, &mut evals).unwrap();
            assert!(r.l2_norm() < 1e-5, "F-point {j} residual {}", r.l2_norm());
        }
    }

    #[test]
    fn two_cycles_give_good_early_stopped_estimate() {
        // the paper's training mode: 2 cycles ≈ exact states
        let s = solver_for(NetSpec::mnist(), 13);
        let mut rng = Rng::new(14);
        let u0 = Tensor::randn(&[1, 8, 28, 28], 0.5, &mut rng);
        let opts = MgritOptions::early_stopping(2);
        let (mg, _) = solve_forward(&s, 32, s.spec().h(), &u0, &opts).unwrap();
        let serial = serial_states(&s, &u0);
        let err = crate::util::stats::rel_l2_err(
            mg.last().unwrap().data(),
            serial.last().unwrap().data(),
        );
        assert!(err < 5e-2, "final-state error after 2 cycles: {err}");
    }

    #[test]
    fn multilevel_matches_two_level_solution() {
        let spec = NetSpec::fig6_depth(32);
        let s = solver_for(spec, 15);
        let mut rng = Rng::new(16);
        let u0 = Tensor::randn(&[1, 4, 24, 24], 0.5, &mut rng);
        let two = MgritOptions { max_levels: 2, tol: 1e-5, max_cycles: 40, ..Default::default() };
        let multi = MgritOptions { max_levels: 4, tol: 1e-5, max_cycles: 40, min_coarse_points: 3, ..Default::default() };
        let (a, sa) = solve_forward(&s, 32, s.spec().h(), &u0, &two).unwrap();
        let (b, sb) = solve_forward(&s, 32, s.spec().h(), &u0, &multi).unwrap();
        // both must contract far below the initial residual (absolute tol is
        // limited by the f32 state magnitude, so assert relative drop)
        for st in [&sa, &sb] {
            let drop = st.residual_norms.last().unwrap() / st.residual_norms[0];
            assert!(st.converged || drop < 1e-4, "norms {:?}", st.residual_norms);
        }
        let err = crate::util::stats::rel_l2_err(
            a.last().unwrap().data(),
            b.last().unwrap().data(),
        );
        assert!(err < 1e-5, "two-level vs V-cycle differ: {err}");
    }

    #[test]
    fn relax_kind_f_converges_slower_than_fcf() {
        let s = solver_for(NetSpec::mnist(), 17);
        let mut rng = Rng::new(18);
        let u0 = Tensor::randn(&[1, 8, 28, 28], 0.5, &mut rng);
        let mk = |relax| MgritOptions { relax, tol: 0.0, max_cycles: 3, ..Default::default() };
        let (_, f) = solve_forward(&s, 32, s.spec().h(), &u0, &mk(RelaxKind::F)).unwrap();
        let (_, fcf) = solve_forward(&s, 32, s.spec().h(), &u0, &mk(RelaxKind::FCF)).unwrap();
        assert!(
            fcf.residual_norms.last().unwrap() <= f.residual_norms.last().unwrap(),
            "F {:?} vs FCF {:?}",
            f.residual_norms,
            fcf.residual_norms
        );
    }

    #[test]
    fn non_divisible_depth_converges() {
        // N = 7 with c = 4 exercises the trailing partial block
        let spec = NetSpec::fig6_depth(7);
        let s = solver_for(spec, 19);
        let mut rng = Rng::new(20);
        let u0 = Tensor::randn(&[1, 4, 24, 24], 0.5, &mut rng);
        let opts = MgritOptions { tol: 1e-6, max_cycles: 30, ..Default::default() };
        let (mg, stats) = solve_forward(&s, 7, s.spec().h(), &u0, &opts).unwrap();
        assert!(stats.converged);
        let serial = serial_states(&s, &u0);
        let err = crate::util::stats::rel_l2_err(
            mg.last().unwrap().data(),
            serial.last().unwrap().data(),
        );
        assert!(err < 1e-5, "{err}");
    }

    #[test]
    fn prop_converged_mg_equals_serial() {
        use crate::util::proptest_lite as pt;
        pt::check_with(
            pt::Config { cases: 6, ..Default::default() },
            "mg-equals-serial",
            |rng| {
                let n = pt::gen_usize(rng, 2, 12);
                let spec = NetSpec {
                    name: "prop".into(),
                    trunk: vec![
                        crate::model::LayerKind::Conv { channels: 2, kernel: 3 };
                        n
                    ],
                    ..NetSpec::micro()
                };
                let s = solver_for(spec, rng.next_u64());
                let mut r2 = rng.split();
                let u0 = Tensor::randn(&[1, 2, 6, 6], 0.8, &mut r2);
                let opts = MgritOptions { tol: 1e-6, max_cycles: 50, ..Default::default() };
                let (mg, stats) = solve_forward(&s, n, s.spec().h(), &u0, &opts).unwrap();
                assert!(stats.converged, "n={n} norms {:?}", stats.residual_norms);
                let serial = serial_states(&s, &u0);
                let err = crate::util::stats::rel_l2_err(
                    mg.last().unwrap().data(),
                    serial.last().unwrap().data(),
                );
                assert!(err < 1e-4, "n={n} err={err}");
            },
        );
    }
}
