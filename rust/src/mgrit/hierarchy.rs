//! MGRIT level hierarchy: which layer-grid points live on which level,
//! which are C-points (shared with the next-coarser level) and which are
//! F-points, and how point indices map back to fine-level layer indices.

use anyhow::{bail, Result};

/// One level of the layer-grid hierarchy. Points 0..n_points are layer
/// *states*; the step from point j to j+1 applies the propagator with the
/// parameters of fine layer `j·stride` and step size `h` (coarse levels use
/// the same injected θ with h scaled by the coarsening factor — paper eq. 25).
#[derive(Debug, Clone, PartialEq)]
pub struct Level {
    /// Fine layers spanned by one step on this level (c^level).
    pub stride: usize,
    /// ODE step size on this level (h_fine · stride).
    pub h: f32,
    /// Number of layer states on this level (fine level: N + 1).
    pub n_points: usize,
}

impl Level {
    /// Fine-level layer index whose parameters the step j → j+1 uses.
    pub fn theta_idx(&self, j: usize) -> usize {
        j * self.stride
    }

    /// Is point `j` a C-point (member of the next-coarser level)?
    pub fn is_cpoint(&self, j: usize, coarsen: usize) -> bool {
        j % coarsen == 0
    }

    /// C-point indices on this level.
    pub fn cpoints(&self, coarsen: usize) -> Vec<usize> {
        (0..self.n_points).step_by(coarsen).collect()
    }

    /// F-point index ranges per block: for each C-point, the run of F-points
    /// that F-relaxation updates from it, `(cp, cp+1 ..= end)` with
    /// `end = min(cp + coarsen − 1, n_points − 1)`. Blocks at the tail may be
    /// shorter (N need not divide by c — fig6's N = 4,093 doesn't).
    pub fn blocks(&self, coarsen: usize) -> Vec<Block> {
        self.cpoints(coarsen)
            .into_iter()
            .map(|cp| Block {
                cpoint: cp,
                f_end: (cp + coarsen - 1).min(self.n_points - 1),
            })
            .collect()
    }
}

/// One layer block: a C-point and the F-points that follow it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Block {
    /// The block's owning C-point.
    pub cpoint: usize,
    /// Last F-point of the block (inclusive); == cpoint when the block has
    /// no F-points (possible only for the final C-point).
    pub f_end: usize,
}

impl Block {
    /// Number of F-points this block updates.
    pub fn n_fpoints(&self) -> usize {
        self.f_end - self.cpoint
    }
}

/// The full multilevel hierarchy.
#[derive(Debug, Clone)]
pub struct Hierarchy {
    /// Coarsening factor c between consecutive levels.
    pub coarsen: usize,
    /// The levels, finest first.
    pub levels: Vec<Level>,
}

impl Hierarchy {
    /// Build a hierarchy for `n_layers` residual layers with fine step
    /// `h_fine`, coarsening by `coarsen` per level, at most `max_levels`
    /// levels, stopping once a level has ≤ `min_points` points (the coarsest
    /// level is solved exactly by forward substitution).
    pub fn build(
        n_layers: usize,
        h_fine: f32,
        coarsen: usize,
        max_levels: usize,
        min_points: usize,
    ) -> Result<Hierarchy> {
        if coarsen < 2 {
            bail!("coarsening factor must be ≥ 2, got {coarsen}");
        }
        if n_layers < 1 {
            bail!("need at least one layer");
        }
        if max_levels < 1 {
            bail!("need at least one level");
        }
        let mut levels = vec![Level { stride: 1, h: h_fine, n_points: n_layers + 1 }];
        while levels.len() < max_levels {
            let last = levels.last().unwrap();
            if last.n_points <= min_points.max(2) {
                break;
            }
            let n_coarse = (last.n_points - 1) / coarsen + 1;
            if n_coarse < 2 || n_coarse == last.n_points {
                break;
            }
            levels.push(Level {
                stride: last.stride * coarsen,
                h: last.h * coarsen as f32,
                n_points: n_coarse,
            });
        }
        Ok(Hierarchy { coarsen, levels })
    }

    /// Two-level hierarchy (the paper's Algorithm 1 configuration).
    pub fn two_level(n_layers: usize, h_fine: f32, coarsen: usize) -> Result<Hierarchy> {
        Self::build(n_layers, h_fine, coarsen, 2, 2)
    }

    /// Number of levels.
    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    /// The finest level.
    pub fn fine(&self) -> &Level {
        &self.levels[0]
    }

    /// Forward fine-state index an *adjoint* step at (level, j−1 → j)
    /// linearizes around: the μ-system step applies the VJP of fine layer
    /// N−1−θ(j−1), whose input is the forward state u[0][N−1−θ(j−1)].
    /// Shared by the graph builder (which emits the matching RAW edge) and
    /// the live executor (which reads the state at dispatch) — one formula,
    /// so edge and read cannot drift apart.
    pub fn adjoint_state_index(&self, level: usize, j: usize) -> usize {
        let n_layers = self.fine().n_points - 1;
        n_layers - 1 - self.levels[level].theta_idx(j - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite as pt;

    #[test]
    fn two_level_basic() {
        // 8 layers, c=4: fine 9 points, coarse 3 points (0,4,8)
        let h = Hierarchy::two_level(8, 0.1, 4).unwrap();
        assert_eq!(h.n_levels(), 2);
        assert_eq!(h.levels[0].n_points, 9);
        assert_eq!(h.levels[1].n_points, 3);
        assert_eq!(h.levels[1].stride, 4);
        assert!((h.levels[1].h - 0.4).abs() < 1e-7);
    }

    #[test]
    fn non_divisible_depth() {
        // 10 layers, c=4: fine 11 points; C-points 0,4,8 → coarse 3 points,
        // trailing F-points 9, 10 belong to the last block
        let h = Hierarchy::two_level(10, 0.1, 4).unwrap();
        assert_eq!(h.levels[1].n_points, 3);
        let blocks = h.levels[0].blocks(4);
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks[2], Block { cpoint: 8, f_end: 10 });
        assert_eq!(blocks[2].n_fpoints(), 2);
    }

    #[test]
    fn multilevel_build() {
        // 64 layers, c=4: 65 → 17 → 5 → 2 points
        let h = Hierarchy::build(64, 0.05, 4, 10, 2).unwrap();
        let pts: Vec<usize> = h.levels.iter().map(|l| l.n_points).collect();
        assert_eq!(pts, vec![65, 17, 5, 2]);
        assert_eq!(h.levels[3].stride, 64);
    }

    #[test]
    fn theta_idx_in_bounds_on_all_levels() {
        let n_layers = 37;
        let h = Hierarchy::build(n_layers, 0.1, 3, 8, 2).unwrap();
        for lvl in &h.levels {
            for j in 0..lvl.n_points - 1 {
                assert!(lvl.theta_idx(j) < n_layers, "level stride {}", lvl.stride);
            }
        }
    }

    #[test]
    fn cpoints_and_blocks_consistent() {
        let lvl = Level { stride: 1, h: 0.1, n_points: 11 };
        assert_eq!(lvl.cpoints(4), vec![0, 4, 8]);
        assert!(lvl.is_cpoint(8, 4));
        assert!(!lvl.is_cpoint(3, 4));
        let blocks = lvl.blocks(4);
        // every non-C point is an F-point of exactly one block
        let mut covered = vec![0usize; 11];
        for b in &blocks {
            for j in b.cpoint + 1..=b.f_end {
                covered[j] += 1;
            }
        }
        for j in 0..11 {
            let expect = if j % 4 == 0 { 0 } else { 1 };
            assert_eq!(covered[j], expect, "point {j}");
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(Hierarchy::build(8, 0.1, 1, 2, 2).is_err());
        assert!(Hierarchy::build(0, 0.1, 2, 2, 2).is_err());
        assert!(Hierarchy::build(8, 0.1, 2, 0, 2).is_err());
    }

    #[test]
    fn prop_hierarchy_invariants() {
        pt::check("hierarchy-invariants", |rng| {
            let n_layers = pt::gen_usize(rng, 1, 200);
            let c = pt::gen_usize(rng, 2, 8);
            let max_levels = pt::gen_usize(rng, 1, 6);
            let h = Hierarchy::build(n_layers, 0.1, c, max_levels, 2).unwrap();
            assert!(h.n_levels() >= 1 && h.n_levels() <= max_levels);
            assert_eq!(h.levels[0].n_points, n_layers + 1);
            for w in h.levels.windows(2) {
                // each coarse level is strictly smaller and stride-consistent
                assert!(w[1].n_points < w[0].n_points);
                assert_eq!(w[1].stride, w[0].stride * c);
                assert_eq!(w[1].n_points, (w[0].n_points - 1) / c + 1);
                // coarse points exist on the fine level
                assert!((w[1].n_points - 1) * c <= w[0].n_points - 1);
            }
            // θ indices stay in range everywhere
            for lvl in &h.levels {
                assert!(lvl.n_points >= 2);
                assert!(lvl.theta_idx(lvl.n_points - 2) < n_layers);
            }
        });
    }
}
