//! The backward pass as MGRIT on the adjoint ODE (following Günther et al.,
//! SIMODS 2020 — ref [14] of the paper, which delegates training details
//! there).
//!
//! The adjoint recurrence λ^n = λ^{n+1} + h·(∂F/∂u(u^n; θ^n))ᵀ λ^{n+1} is
//! itself a residual network running in reversed layer order, with the
//! *linear* propagator Ψ_n(λ) = λ + h·Jᵀ_n λ. Substituting μ^m := λ^{N−m}
//! turns it into a forward system over m = 0..N, so the exact same FAS/MGRIT
//! machinery applies. Once λ is known, per-layer parameter gradients
//! g^n = h·(∂F/∂θ^n)ᵀ λ^{n+1} are layer-local and embarrassingly parallel.

use anyhow::bail;

use super::fas::{self, CycleStats, MgritOptions};
use super::hierarchy::Hierarchy;
use crate::solver::BlockSolver;
use crate::tensor::Tensor;
use crate::Result;

/// Wraps a forward solver + forward trajectory as the *adjoint* system:
/// `step(m, h, μ)` applies Ψ at reversed layer index n = N−1−m, linearized
/// around the forward state u^n (the input of layer n).
pub struct AdjointSystem<'a, S: BlockSolver> {
    solver: &'a S,
    /// Forward states u^0..u^N (length N+1); u[n] is layer n's input.
    states: &'a [Tensor],
    n_layers: usize,
}

impl<'a, S: BlockSolver> AdjointSystem<'a, S> {
    /// An adjoint system linearized around the forward states u^0..u^N.
    pub fn new(solver: &'a S, states: &'a [Tensor]) -> Result<Self> {
        if states.len() < 2 {
            bail!("adjoint system needs at least 2 forward states");
        }
        Ok(AdjointSystem { solver, states, n_layers: states.len() - 1 })
    }

    /// Reversed layer index for adjoint step m.
    fn rev(&self, m: usize) -> usize {
        self.n_layers - 1 - m
    }
}

impl<'a, S: BlockSolver> BlockSolver for AdjointSystem<'a, S> {
    fn step(&self, fine_idx: usize, h: f32, lam: &Tensor) -> Result<Tensor> {
        let n = self.rev(fine_idx);
        self.solver.adjoint_step(n, h, &self.states[n], lam)
    }

    fn adjoint_step(&self, _: usize, _: f32, _: &Tensor, _: &Tensor) -> Result<Tensor> {
        bail!("second-order adjoint not supported")
    }

    fn param_grad(&self, _: usize, _: f32, _: &Tensor, _: &Tensor) -> Result<(Tensor, Tensor)> {
        bail!("adjoint system has no parameters")
    }
}

/// Solve the adjoint system with MGRIT. `lam_final` is ∂loss/∂u^N (the head
/// gradient); returns λ^0..λ^N (forward layer indexing) and cycle stats.
pub fn solve_adjoint<S: BlockSolver>(
    solver: &S,
    states: &[Tensor],
    h: f32,
    lam_final: &Tensor,
    opts: &MgritOptions,
) -> Result<(Vec<Tensor>, CycleStats)> {
    let sys = AdjointSystem::new(solver, states)?;
    let n = sys.n_layers;
    let (mu, stats) = fas::solve_forward(&sys, n, h, lam_final, opts)?;
    // μ^m = λ^{N−m} → reverse back to forward indexing
    let mut lam = mu;
    lam.reverse();
    Ok((lam, stats))
}

/// As [`solve_adjoint`] with an explicit hierarchy.
pub fn solve_adjoint_with<S: BlockSolver>(
    solver: &S,
    states: &[Tensor],
    hier: &Hierarchy,
    lam_final: &Tensor,
    opts: &MgritOptions,
) -> Result<(Vec<Tensor>, CycleStats)> {
    let sys = AdjointSystem::new(solver, states)?;
    let (mu, stats) = fas::solve_forward_with(&sys, hier, lam_final, opts)?;
    let mut lam = mu;
    lam.reverse();
    Ok((lam, stats))
}

/// Serial adjoint sweep (the exact-backprop baseline): λ^N = lam_final,
/// λ^n = Ψ_n(λ^{n+1}).
pub fn serial_adjoint<S: BlockSolver>(
    solver: &S,
    states: &[Tensor],
    h: f32,
    lam_final: &Tensor,
) -> Result<Vec<Tensor>> {
    let n = states.len() - 1;
    let mut lam = vec![lam_final.clone()];
    for i in (0..n).rev() {
        let prev = solver.adjoint_step(i, h, &states[i], lam.last().unwrap())?;
        lam.push(prev);
    }
    lam.reverse();
    Ok(lam)
}

/// Per-layer parameter gradients from forward states + adjoints:
/// (dWᵢ, dbᵢ) = param_grad(uⁱ, λ^{i+1}). Layer-local — the coordinator
/// fans this out across all devices at once.
pub fn param_grads<S: BlockSolver>(
    solver: &S,
    states: &[Tensor],
    lams: &[Tensor],
    h: f32,
) -> Result<Vec<(Tensor, Tensor)>> {
    if states.len() != lams.len() {
        bail!("states/adjoints length mismatch: {} vs {}", states.len(), lams.len());
    }
    let n = states.len() - 1;
    (0..n).map(|i| solver.param_grad(i, h, &states[i], &lams[i + 1])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{NetParams, NetSpec};
    use crate::solver::host::HostSolver;
    use crate::tensor::ops;
    use crate::util::prng::Rng;
    use std::sync::Arc;

    fn setup(seed: u64) -> (HostSolver, Vec<Tensor>, Tensor) {
        let spec = Arc::new(NetSpec::micro());
        let params = Arc::new(NetParams::init(&spec, seed).unwrap());
        let s = HostSolver::new(spec, params).unwrap();
        let mut rng = Rng::new(seed + 100);
        let u0 = Tensor::randn(&[1, 2, 6, 6], 0.8, &mut rng);
        let mut states = vec![u0.clone()];
        states.extend(s.block_fprop(0, 1, 4, s.spec().h(), &u0).unwrap());
        let lam_final = Tensor::randn(&[1, 2, 6, 6], 1.0, &mut rng);
        (s, states, lam_final)
    }

    #[test]
    fn serial_adjoint_matches_chained_vjp() {
        let (s, states, lam_final) = setup(21);
        let h = s.spec().h();
        let lams = serial_adjoint(&s, &states, h, &lam_final).unwrap();
        assert_eq!(lams.len(), states.len());
        // chain VJPs manually
        let mut lam = lam_final.clone();
        for i in (0..4).rev() {
            let (w, b) = &s.params().trunk[i];
            let (l, _, _) =
                crate::tensor::vjp::residual_step_vjp(&states[i], w, b, h, 1, &lam).unwrap();
            lam = l;
            assert_eq!(&lams[i], &lam);
        }
    }

    #[test]
    fn mgrit_adjoint_converges_to_serial_adjoint() {
        let (s, states, lam_final) = setup(22);
        let h = s.spec().h();
        let opts = MgritOptions { tol: 1e-6, max_cycles: 40, ..Default::default() };
        let (mg, stats) = solve_adjoint(&s, &states, h, &lam_final, &opts).unwrap();
        assert!(stats.converged);
        let serial = serial_adjoint(&s, &states, h, &lam_final).unwrap();
        for (a, b) in mg.iter().zip(&serial) {
            assert!(crate::util::stats::rel_l2_err(a.data(), b.data()) < 1e-4);
        }
    }

    #[test]
    fn adjoint_gradient_matches_loss_finite_difference() {
        // end-to-end: d loss / d u0 via adjoint == finite differences
        let spec = Arc::new(NetSpec::micro());
        let params = Arc::new(NetParams::init(&spec, 23).unwrap());
        let s = HostSolver::new(spec.clone(), params).unwrap();
        let mut rng = Rng::new(24);
        let u0 = Tensor::randn(&[1, 2, 6, 6], 0.8, &mut rng);
        let labels = [3i32];
        let h = spec.h();

        let fwd = |u0: &Tensor| -> f64 {
            let un = s.block_fprop(0, 1, 4, h, u0).unwrap().pop().unwrap();
            s.head(&un, &labels).unwrap().1
        };

        let mut states = vec![u0.clone()];
        states.extend(s.block_fprop(0, 1, 4, h, &u0).unwrap());
        let (du_n, _, _) = s.head_vjp(states.last().unwrap(), &labels).unwrap();
        let lams = serial_adjoint(&s, &states, h, &du_n).unwrap();

        for i in [0usize, 17, 40, 71] {
            let eps = 1e-2f32;
            let mut up = u0.clone();
            up.data_mut()[i] += eps;
            let mut um = u0.clone();
            um.data_mut()[i] -= eps;
            let fd = (fwd(&up) - fwd(&um)) / (2.0 * eps as f64);
            let got = lams[0].data()[i] as f64;
            assert!((got - fd).abs() < 2e-2, "i={i}: adjoint {got} vs fd {fd}");
        }
    }

    #[test]
    fn param_grads_match_block_vjp_composition() {
        let (s, states, lam_final) = setup(25);
        let h = s.spec().h();
        let lams = serial_adjoint(&s, &states, h, &lam_final).unwrap();
        let grads = param_grads(&s, &states, &lams, h).unwrap();
        assert_eq!(grads.len(), 4);
        // validate one layer against an independent FD of ⟨λ_final, u^N⟩
        let i = 2usize;
        let (w, b) = &s.params().trunk[i];
        let f = |ww: &Tensor| {
            // propagate 4 layers with layer i's weight replaced
            let mut u = states[0].clone();
            for j in 0..4 {
                let (wj, bj) = &s.params().trunk[j];
                let wj = if j == i { ww } else { wj };
                u = ops::residual_step(&u, wj, bj, h, 1).unwrap();
            }
            Tensor::dot(&u, &lam_final).unwrap()
        };
        let eps = 1e-2f32;
        for idx in [0usize, 9, 20] {
            let mut wp = w.clone();
            wp.data_mut()[idx] += eps;
            let mut wm = w.clone();
            wm.data_mut()[idx] -= eps;
            let fd = (f(&wp) - f(&wm)) / (2.0 * eps as f64);
            let got = grads[i].0.data()[idx] as f64;
            assert!((got - fd).abs() < 3e-2, "idx={idx}: {got} vs {fd}");
        }
        let _ = b;
    }

    #[test]
    fn early_stopped_adjoint_close_to_exact() {
        let (s, states, lam_final) = setup(26);
        let h = s.spec().h();
        let opts = MgritOptions::early_stopping(2);
        let (mg, _) = solve_adjoint(&s, &states, h, &lam_final, &opts).unwrap();
        let serial = serial_adjoint(&s, &states, h, &lam_final).unwrap();
        let err =
            crate::util::stats::rel_l2_err(mg[0].data(), serial[0].data());
        assert!(err < 5e-2, "2-cycle adjoint error {err}");
    }

    #[test]
    fn mismatched_lengths_rejected() {
        let (s, states, lam) = setup(27);
        assert!(param_grads(&s, &states[1..], &vec![lam.clone(); states.len()], 0.1).is_err());
        assert!(AdjointSystem::new(&s, &states[..1]).is_err());
    }
}
