//! Schedule DAGs: the exact task structure the coordinator executes, in a
//! form the discrete-event cluster simulator can run at paper scale
//! (fig6/fig7 presets, 1–64 devices) without touching tensors.
//!
//! One generator per algorithm under study:
//! - [`mg_forward`] / [`mg_training`] — the paper's MGRIT layer-parallelism
//! - [`serial_forward`] / [`serial_training`] — single-stream sequential
//!   baseline (distributed = the paper's "Model Partitioned" / PM method)
//!
//! The MG generators mirror `coordinator::driver` phase-for-phase (F-relax
//! blocks, C-relax points, residual, restrict, coarse substitution, correct,
//! final F-relax), so simulated scaling reflects the implemented schedule,
//! not an idealized one.

use crate::coordinator::Partition;
use crate::model::cost::{layer_bwd_cost, layer_cost, state_bytes};
use crate::model::NetSpec;
use crate::Result;

use super::hierarchy::Hierarchy;

/// What a task occupies while it runs.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskKind {
    /// GPU kernel work: `flops` of the given class on `device`.
    Kernel { label: &'static str, class: KernelClass, flops: f64 },
    /// A point-to-point activation transfer.
    Comm { src: usize, dst: usize, bytes: f64 },
}

/// Kernel efficiency class (convolutions and GEMMs achieve very different
/// fractions of peak; the perfmodel assigns rates per class).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelClass {
    Conv,
    Gemm,
    /// Elementwise / reduction epilogues.
    Light,
}

/// One node of the schedule DAG.
#[derive(Debug, Clone)]
pub struct Task {
    pub id: usize,
    /// Executing device (for Comm: the destination device).
    pub device: usize,
    pub kind: TaskKind,
    pub deps: Vec<usize>,
}

/// A schedule DAG plus bookkeeping to attach dependencies incrementally.
#[derive(Debug, Default)]
pub struct TaskGraph {
    pub tasks: Vec<Task>,
}

impl TaskGraph {
    fn push(&mut self, device: usize, kind: TaskKind, deps: Vec<usize>) -> usize {
        let id = self.tasks.len();
        self.tasks.push(Task { id, device, kind, deps });
        id
    }

    /// Kernel task helper.
    fn kernel(
        &mut self,
        device: usize,
        label: &'static str,
        class: KernelClass,
        flops: f64,
        deps: Vec<usize>,
    ) -> usize {
        self.push(device, TaskKind::Kernel { label, class, flops }, deps)
    }

    /// Transfer `bytes` from src to dst (no task if same device).
    fn comm(&mut self, src: usize, dst: usize, bytes: f64, deps: Vec<usize>) -> Option<usize> {
        if src == dst {
            None
        } else {
            Some(self.push(dst, TaskKind::Comm { src, dst, bytes }, deps))
        }
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub fn total_flops(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| match &t.kind {
                TaskKind::Kernel { flops, .. } => *flops,
                _ => 0.0,
            })
            .sum()
    }

    pub fn total_comm_bytes(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| match &t.kind {
                TaskKind::Comm { bytes, .. } => *bytes,
                _ => 0.0,
            })
            .sum()
    }

    /// Verify the graph is a DAG with in-range dependencies (deps always
    /// point backwards by construction; this asserts it).
    pub fn validate(&self) -> Result<()> {
        for t in &self.tasks {
            for &d in &t.deps {
                if d >= t.id {
                    anyhow::bail!("task {} depends on non-earlier task {}", t.id, d);
                }
            }
        }
        Ok(())
    }
}

/// Maps MGRIT points to devices (same rule as the parallel driver).
struct PointMap<'a> {
    hier: &'a Hierarchy,
    partition: &'a Partition,
}

impl<'a> PointMap<'a> {
    fn device_of_point(&self, level: usize, j: usize) -> usize {
        let fine_idx = j * self.hier.levels[level].stride;
        let block = (fine_idx / self.hier.coarsen).min(self.partition.n_blocks() - 1);
        self.partition.device_of(block)
    }
}

/// Builder state for the MG schedule: the task that last wrote each point of
/// each level (the dependency frontier).
struct MgBuilder<'a> {
    g: TaskGraph,
    spec: &'a NetSpec,
    batch: usize,
    pm: PointMap<'a>,
    /// Cost multiplier for Φ applications (1 for forward, ~2 for adjoint).
    flop_scale: f64,
    /// last_writer[level][j] — None means "initial state, no producer".
    last_writer: Vec<Vec<Option<usize>>>,
}

impl<'a> MgBuilder<'a> {
    fn new(spec: &'a NetSpec, hier: &'a Hierarchy, partition: &'a Partition, batch: usize) -> Self {
        let last_writer = hier.levels.iter().map(|l| vec![None; l.n_points]).collect();
        MgBuilder {
            g: TaskGraph::default(),
            spec,
            batch,
            pm: PointMap { hier, partition },
            flop_scale: 1.0,
            last_writer,
        }
    }

    fn class_of(&self, fine_idx: usize) -> KernelClass {
        match self.spec.trunk[fine_idx.min(self.spec.n_res() - 1)] {
            crate::model::LayerKind::Conv { .. } => KernelClass::Conv,
            crate::model::LayerKind::Fc { .. } => KernelClass::Gemm,
        }
    }

    fn step_flops(&self, fine_idx: usize) -> f64 {
        self.flop_scale * layer_cost(self.spec, fine_idx.min(self.spec.n_res() - 1), self.batch).flops
    }

    fn dep_of(&self, level: usize, j: usize) -> Vec<usize> {
        self.last_writer[level][j].into_iter().collect()
    }

    /// Φ-apply at point j−1 → j, with boundary comm if the producer of
    /// u[j−1] lives on another device. Returns the new writer of point j.
    fn point_update(&mut self, level: usize, j: usize, label: &'static str) -> usize {
        let lvl = &self.pm.hier.levels[level];
        let dst = self.pm.device_of_point(level, j);
        let src = self.pm.device_of_point(level, j - 1);
        let mut deps = self.dep_of(level, j - 1);
        if let Some(c) = self.g.comm(src, dst, state_bytes(self.spec, self.batch), deps.clone())
        {
            deps = vec![c];
        }
        let fine_idx = lvl.theta_idx(j - 1);
        let t = self.g.kernel(dst, label, self.class_of(fine_idx), self.step_flops(fine_idx), deps);
        self.last_writer[level][j] = Some(t);
        t
    }

    fn f_relax(&mut self, level: usize) {
        let lvl = self.pm.hier.levels[level].clone();
        for b in lvl.blocks(self.pm.hier.coarsen) {
            for j in b.cpoint + 1..=b.f_end {
                self.point_update(level, j, "f_relax");
            }
        }
    }

    fn c_relax(&mut self, level: usize) {
        let lvl = self.pm.hier.levels[level].clone();
        for cp in lvl.cpoints(self.pm.hier.coarsen) {
            if cp > 0 {
                self.point_update(level, cp, "c_relax");
            }
        }
    }

    /// Residual at C-points; returns the residual tasks (producers of r).
    fn residual(&mut self, level: usize) -> Vec<usize> {
        let lvl = self.pm.hier.levels[level].clone();
        let mut out = Vec::new();
        for cp in lvl.cpoints(self.pm.hier.coarsen) {
            if cp == 0 {
                continue;
            }
            let dst = self.pm.device_of_point(level, cp);
            let src = self.pm.device_of_point(level, cp - 1);
            let mut deps = self.dep_of(level, cp - 1);
            deps.extend(self.dep_of(level, cp));
            if let Some(c) =
                self.g.comm(src, dst, state_bytes(self.spec, self.batch), deps.clone())
            {
                deps = vec![c];
            }
            let fine_idx = lvl.theta_idx(cp - 1);
            let t = self.g.kernel(
                dst,
                "residual",
                self.class_of(fine_idx),
                self.step_flops(fine_idx),
                deps,
            );
            out.push(t);
        }
        out
    }

    /// Restriction to level+1: τ-term Φ_H per coarse point + residual dep.
    fn restrict(&mut self, level: usize, residual_tasks: &[usize]) {
        let coarse = self.pm.hier.levels[level + 1].clone();
        let c = self.pm.hier.coarsen;
        for j in 1..coarse.n_points {
            let dst = self.pm.device_of_point(level + 1, j);
            let src = self.pm.device_of_point(level + 1, j - 1);
            let mut deps = self.dep_of(level, (j - 1) * c);
            deps.push(residual_tasks[j - 1]);
            if let Some(cm) =
                self.g.comm(src, dst, state_bytes(self.spec, self.batch), deps.clone())
            {
                deps = vec![cm];
            }
            let fine_idx = coarse.theta_idx(j - 1);
            let t = self.g.kernel(
                dst,
                "restrict",
                self.class_of(fine_idx),
                self.step_flops(fine_idx),
                deps,
            );
            self.last_writer[level + 1][j] = Some(t);
            if self.last_writer[level + 1][j - 1].is_none() {
                self.last_writer[level + 1][j - 1] = self.last_writer[level][(j - 1) * c];
            }
        }
    }

    /// Sequential exact solve on the coarsest level, *in place*: the forward
    /// substitution pipelines across the devices that own the points, with
    /// one boundary transfer per partition crossing (the paper's MPI
    /// C-relaxation pattern) — NOT a gather to one device, which would
    /// serialize O(n_points) messages through a single NIC.
    fn coarse_solve(&mut self, level: usize) {
        let lvl = self.pm.hier.levels[level].clone();
        let bytes = state_bytes(self.spec, self.batch);
        for j in 1..lvl.n_points {
            let dst = self.pm.device_of_point(level, j);
            let src = self.pm.device_of_point(level, j - 1);
            let mut deps = self.dep_of(level, j - 1);
            deps.extend(self.dep_of(level, j));
            if let Some(c) = self.g.comm(src, dst, bytes, deps.clone()) {
                deps = vec![c];
            }
            let fine_idx = lvl.theta_idx(j - 1);
            let t = self.g.kernel(
                dst,
                "coarse_solve",
                self.class_of(fine_idx),
                self.step_flops(fine_idx),
                deps,
            );
            self.last_writer[level][j] = Some(t);
        }
    }

    /// Correction: elementwise C-point update after the coarse solve (the
    /// coarse point is co-located with its fine C-point by construction).
    fn correct(&mut self, level: usize) {
        let coarse_n = self.pm.hier.levels[level + 1].n_points;
        let act = state_bytes(self.spec, self.batch) / 4.0; // elements
        for j in 1..coarse_n {
            let fine_j = j * self.pm.hier.coarsen;
            let dev = self.pm.device_of_point(level, fine_j);
            let mut deps = self.dep_of(level + 1, j);
            deps.extend(self.dep_of(level, fine_j));
            let t = self.g.kernel(dev, "correct", KernelClass::Light, 2.0 * act, deps);
            self.last_writer[level][fine_j] = Some(t);
        }
    }

    fn vcycle(&mut self, level: usize) {
        if level == self.pm.hier.n_levels() - 1 {
            self.coarse_solve(level);
            return;
        }
        // FCF relaxation (the paper's configuration)
        self.f_relax(level);
        self.c_relax(level);
        self.f_relax(level);
        let rs = self.residual(level);
        self.restrict(level, &rs);
        self.vcycle(level + 1);
        self.correct(level);
        self.f_relax(level);
    }
}

/// MG forward propagation schedule: `cycles` V-cycles.
pub fn mg_forward(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    cycles: usize,
) -> TaskGraph {
    let mut b = MgBuilder::new(spec, hier, partition, batch);
    for _ in 0..cycles {
        b.vcycle(0);
    }
    b.g
}

/// MG training step: forward MG, head fwd+vjp, adjoint MG (same cycle count,
/// VJP steps ≈ 2× forward cost), then layer-local parameter gradients fanned
/// out across all devices.
pub fn mg_training(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    cycles: usize,
) -> TaskGraph {
    let mut b = MgBuilder::new(spec, hier, partition, batch);
    for _ in 0..cycles {
        b.vcycle(0);
    }
    // head on the device owning the last point
    let n_fine = b.pm.hier.fine().n_points;
    let last_dev = b.pm.device_of_point(0, n_fine - 1);
    let head = crate::model::cost::head_cost(spec, batch);
    let deps = b.dep_of(0, n_fine - 1);
    let h1 = b.g.kernel(last_dev, "head", KernelClass::Gemm, head.flops, deps);
    let h2 = b.g.kernel(last_dev, "head_vjp", KernelClass::Gemm, 2.0 * head.flops, vec![h1]);
    // adjoint MG: structurally identical cycles over the reversed system,
    // each Φ replaced by its VJP (≈ 2× flops)
    b.last_writer[0][n_fine - 1] = Some(h2);
    b.flop_scale = 2.0;
    for _ in 0..cycles {
        b.vcycle(0);
    }
    // layer-local parameter gradients (no communication)
    b.flop_scale = 1.0;
    for i in 0..spec.n_res() {
        let j = (i + 1).min(n_fine - 1);
        let dev = b.pm.device_of_point(0, j);
        let deps = b.dep_of(0, j);
        let c = layer_bwd_cost(spec, i, batch);
        b.g.kernel(dev, "param_grad", b.class_of(i), c.flops, deps);
    }
    b.g
}

/// Sequential forward propagation partitioned across devices — one long
/// dependency chain with a transfer at every partition boundary. With
/// n_devices == 1 this is the pure serial baseline; with > 1 it is the
/// paper's "Model Partitioned" (PM) layer-wise parallelism.
pub fn serial_forward(spec: &NetSpec, n_devices: usize, batch: usize) -> TaskGraph {
    let mut g = TaskGraph::default();
    let n = spec.n_res();
    let part = Partition::contiguous(n, n_devices).expect("partition");
    let mut prev: Option<usize> = None;
    let mut prev_dev = part.device_of(0);
    for i in 0..n {
        let dev = part.device_of(i);
        let mut deps: Vec<usize> = prev.into_iter().collect();
        if dev != prev_dev {
            if let Some(c) = g.comm(prev_dev, dev, state_bytes(spec, batch), deps.clone()) {
                deps = vec![c];
            }
        }
        let cost = layer_cost(spec, i, batch);
        let class = match spec.trunk[i] {
            crate::model::LayerKind::Conv { .. } => KernelClass::Conv,
            crate::model::LayerKind::Fc { .. } => KernelClass::Gemm,
        };
        prev = Some(g.kernel(dev, "serial_fwd", class, cost.flops, deps));
        prev_dev = dev;
    }
    g
}

/// Sequential training step (forward + backward chains) across devices —
/// the PM training baseline of Fig 6b.
pub fn serial_training(spec: &NetSpec, n_devices: usize, batch: usize) -> TaskGraph {
    let mut g = TaskGraph::default();
    let n = spec.n_res();
    let part = Partition::contiguous(n, n_devices).expect("partition");
    let bytes = state_bytes(spec, batch);
    let class_of = |i: usize| match spec.trunk[i] {
        crate::model::LayerKind::Conv { .. } => KernelClass::Conv,
        crate::model::LayerKind::Fc { .. } => KernelClass::Gemm,
    };
    // forward chain
    let mut prev: Option<usize> = None;
    let mut prev_dev = part.device_of(0);
    for i in 0..n {
        let dev = part.device_of(i);
        let mut deps: Vec<usize> = prev.into_iter().collect();
        if dev != prev_dev {
            if let Some(c) = g.comm(prev_dev, dev, bytes, deps.clone()) {
                deps = vec![c];
            }
        }
        prev = Some(g.kernel(dev, "fwd", class_of(i), layer_cost(spec, i, batch).flops, deps));
        prev_dev = dev;
    }
    // head (fwd + vjp)
    let head = crate::model::cost::head_cost(spec, batch);
    let last_dev = part.device_of(n - 1);
    let h1 =
        g.kernel(last_dev, "head", KernelClass::Gemm, 3.0 * head.flops, prev.into_iter().collect());
    // backward chain
    let mut prev = h1;
    let mut prev_dev = last_dev;
    for i in (0..n).rev() {
        let dev = part.device_of(i);
        let mut deps = vec![prev];
        if dev != prev_dev {
            if let Some(c) = g.comm(prev_dev, dev, bytes, deps.clone()) {
                deps = vec![c];
            }
        }
        prev = g.kernel(dev, "bwd", class_of(i), layer_bwd_cost(spec, i, batch).flops, deps);
        prev_dev = dev;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n_res: usize, n_dev: usize) -> (NetSpec, Hierarchy, Partition) {
        let spec = NetSpec::fig6_depth(n_res);
        let hier = Hierarchy::two_level(n_res, spec.h(), spec.coarsen).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let partition = Partition::contiguous(n_blocks, n_dev).unwrap();
        (spec, hier, partition)
    }

    #[test]
    fn mg_forward_is_valid_dag() {
        let (spec, hier, part) = setup(64, 4);
        let g = mg_forward(&spec, &hier, &part, 1, 2);
        g.validate().unwrap();
        assert!(g.n_tasks() > 0);
        assert!(g.total_flops() > 0.0);
    }

    #[test]
    fn single_device_mg_has_no_comm() {
        let (spec, hier, part) = setup(64, 1);
        let g = mg_forward(&spec, &hier, &part, 1, 2);
        assert_eq!(g.total_comm_bytes(), 0.0);
    }

    #[test]
    fn multi_device_mg_comm_grows_with_devices() {
        let (spec, hier, _) = setup(256, 1);
        let mut prev = 0.0;
        for n_dev in [2usize, 4, 8, 16] {
            let n_blocks = hier.fine().blocks(hier.coarsen).len();
            let part = Partition::contiguous(n_blocks, n_dev).unwrap();
            let g = mg_forward(&spec, &hier, &part, 1, 2);
            let bytes = g.total_comm_bytes();
            assert!(bytes > prev, "n_dev={n_dev}: {bytes} <= {prev}");
            prev = bytes;
        }
    }

    #[test]
    fn mg_work_is_cycles_times_sweep_work() {
        let (spec, hier, part) = setup(64, 2);
        let g1 = mg_forward(&spec, &hier, &part, 1, 1);
        let g2 = mg_forward(&spec, &hier, &part, 1, 2);
        assert!((g2.total_flops() / g1.total_flops() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn serial_forward_flops_match_trunk() {
        let spec = NetSpec::fig6_depth(64);
        let g = serial_forward(&spec, 1, 1);
        let want = crate::model::cost::trunk_flops(&spec, 1);
        assert!((g.total_flops() - want).abs() / want < 1e-12);
        assert_eq!(g.total_comm_bytes(), 0.0);
        g.validate().unwrap();
    }

    #[test]
    fn pm_partitioned_has_boundary_comms() {
        let spec = NetSpec::fig6_depth(64);
        let g = serial_forward(&spec, 8, 1);
        let n_comms = g.tasks.iter().filter(|t| matches!(t.kind, TaskKind::Comm { .. })).count();
        assert_eq!(n_comms, 7); // 7 partition boundaries
    }

    #[test]
    fn mg_does_more_flops_than_serial() {
        // MG is iterative: with 2 cycles it performs > 2x the serial work
        // (the paper's "4x slower on one GPU" effect)
        let (spec, hier, part) = setup(64, 1);
        let mg = mg_forward(&spec, &hier, &part, 1, 2);
        let serial = serial_forward(&spec, 1, 1);
        let ratio = mg.total_flops() / serial.total_flops();
        assert!(ratio > 2.0 && ratio < 10.0, "ratio {ratio}");
    }

    #[test]
    fn training_graph_has_param_grads_on_all_layers() {
        let (spec, hier, part) = setup(32, 2);
        let g = mg_training(&spec, &hier, &part, 1, 2);
        g.validate().unwrap();
        let n_pg = g
            .tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Kernel { label: "param_grad", .. }))
            .count();
        assert_eq!(n_pg, 32);
    }

    #[test]
    fn serial_training_fwd_bwd_chain() {
        let spec = NetSpec::fig6_depth(16);
        let g = serial_training(&spec, 2, 1);
        g.validate().unwrap();
        let fwd: f64 = g
            .tasks
            .iter()
            .filter_map(|t| match &t.kind {
                TaskKind::Kernel { label: "fwd", flops, .. } => Some(*flops),
                _ => None,
            })
            .sum();
        let bwd: f64 = g
            .tasks
            .iter()
            .filter_map(|t| match &t.kind {
                TaskKind::Kernel { label: "bwd", flops, .. } => Some(*flops),
                _ => None,
            })
            .sum();
        assert!((bwd / fwd - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fig7_schedule_scales() {
        // the 2B-param preset: schedule generation must handle 4k+ layers
        let spec = NetSpec::fig7();
        let hier = Hierarchy::two_level(spec.n_res(), spec.h(), spec.coarsen).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let part = Partition::contiguous(n_blocks, 64).unwrap();
        let g = mg_forward(&spec, &hier, &part, 1, 2);
        g.validate().unwrap();
        assert!(g.n_tasks() > 10_000);
        assert!(g.total_comm_bytes() > 0.0);
    }
}
