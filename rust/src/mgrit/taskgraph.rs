//! Schedule DAGs: the *single source of truth* for MGRIT execution order.
//!
//! One graph serves two consumers:
//! - the discrete-event cluster simulator (`sim::engine`) runs it in virtual
//!   time at paper scale (fig6/fig7 presets, 1–64 devices) using the cost
//!   annotations (`TaskKind`), and
//! - the live DAG executor (`coordinator::executor`) runs it on real tensors
//!   using the executable payloads (`TaskOp`), dispatching each task to a
//!   `StreamPool` worker the moment its dependencies retire — no per-phase
//!   barriers.
//!
//! Because both consume the *identical* graph, the simulated schedule and the
//! real schedule cannot drift.
//!
//! Dependencies encode every hazard, not just read-after-write: a task that
//! overwrites a state the previous phase still reads carries write-after-read
//! edges to those readers, so any topological execution order produces
//! bit-identical results to the serial engine in `mgrit::fas`.
//!
//! Generators:
//! - [`mg_vcycle`] — one executable V-cycle (what `ParallelMgrit` runs per
//!   MG iteration)
//! - [`residual_check`] — the fine-level residual evaluation used for the
//!   convergence test between cycles
//! - [`mg_forward`] / [`mg_training`] — multi-cycle schedules for the
//!   simulator (training adds head + adjoint + parameter-gradient stages,
//!   cost-only)
//! - [`serial_forward`] / [`serial_training`] — single-stream sequential
//!   baseline (distributed = the paper's "Model Partitioned" / PM method)

use crate::coordinator::Partition;
use crate::model::cost::{layer_bwd_cost, layer_cost, state_bytes};
use crate::model::NetSpec;
use crate::Result;

use super::fas::RelaxKind;
use super::hierarchy::Hierarchy;

/// What a task occupies while it runs.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskKind {
    /// GPU kernel work: `flops` of the given class on `device`.
    Kernel { label: &'static str, class: KernelClass, flops: f64 },
    /// A point-to-point activation transfer.
    Comm { src: usize, dst: usize, bytes: f64 },
}

/// Kernel efficiency class (convolutions and GEMMs achieve very different
/// fractions of peak; the perfmodel assigns rates per class).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelClass {
    Conv,
    Gemm,
    /// Elementwise / reduction epilogues.
    Light,
}

/// Executable payload: which state slots a task reads and writes. `level`
/// indexes the MGRIT hierarchy; `j` is a point index on that level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskOp {
    /// `u[level][j] = Φ_{θ(j−1)}(u[level][j−1]) + g[level][j]` — the
    /// elementary update of F-relaxation, C-relaxation, and the coarse
    /// forward substitution.
    PointUpdate { level: usize, j: usize },
    /// `r[level][j] = Φ_{θ(j−1)}(u[level][j−1]) + g[level][j] − u[level][j]`.
    Residual { level: usize, j: usize },
    /// FAS restriction to `level+1`:
    /// `g[level+1][j] = r[level][j·c] + ū_H[j] − Φ_H(ū_H[j−1])` with
    /// `ū_H[j] = u[level][j·c]`; also injects `u[level+1][j] = ū_H[j]` and
    /// snapshots it for the later correction.
    Restrict { level: usize, j: usize },
    /// FAS correction: `u[level][j·c] += u[level+1][j] − ū_H[j]`.
    Correct { level: usize, j: usize },
    /// Boundary transfer (accounting only in local execution).
    Xfer,
}

/// One node of the schedule DAG.
#[derive(Debug, Clone)]
pub struct Task {
    pub id: usize,
    /// Executing device (for Comm: the destination device).
    pub device: usize,
    pub kind: TaskKind,
    pub deps: Vec<usize>,
    /// Executable payload; `None` for cost-model-only tasks (training-step
    /// stages the live executor does not run).
    pub op: Option<TaskOp>,
}

/// A schedule DAG plus bookkeeping to attach dependencies incrementally.
#[derive(Debug, Default)]
pub struct TaskGraph {
    pub tasks: Vec<Task>,
}

impl TaskGraph {
    fn push(
        &mut self,
        device: usize,
        kind: TaskKind,
        deps: Vec<usize>,
        op: Option<TaskOp>,
    ) -> usize {
        let id = self.tasks.len();
        self.tasks.push(Task { id, device, kind, deps, op });
        id
    }

    /// Kernel task helper.
    fn kernel(
        &mut self,
        device: usize,
        label: &'static str,
        class: KernelClass,
        flops: f64,
        deps: Vec<usize>,
        op: Option<TaskOp>,
    ) -> usize {
        self.push(device, TaskKind::Kernel { label, class, flops }, deps, op)
    }

    /// Transfer `bytes` from src to dst (no task if same device).
    fn comm(
        &mut self,
        src: usize,
        dst: usize,
        bytes: f64,
        deps: Vec<usize>,
        op: Option<TaskOp>,
    ) -> Option<usize> {
        if src == dst {
            None
        } else {
            Some(self.push(dst, TaskKind::Comm { src, dst, bytes }, deps, op))
        }
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub fn total_flops(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| match &t.kind {
                TaskKind::Kernel { flops, .. } => *flops,
                _ => 0.0,
            })
            .sum()
    }

    pub fn total_comm_bytes(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| match &t.kind {
                TaskKind::Comm { bytes, .. } => *bytes,
                _ => 0.0,
            })
            .sum()
    }

    /// Number of Comm tasks.
    pub fn n_comms(&self) -> usize {
        self.tasks.iter().filter(|t| matches!(t.kind, TaskKind::Comm { .. })).count()
    }

    /// Verify the graph is a DAG with in-range dependencies (deps always
    /// point backwards by construction; this asserts it).
    pub fn validate(&self) -> Result<()> {
        for t in &self.tasks {
            for &d in &t.deps {
                if d >= t.id {
                    anyhow::bail!("task {} depends on non-earlier task {}", t.id, d);
                }
            }
        }
        Ok(())
    }
}

/// Maps MGRIT points to devices (same rule as the parallel driver).
struct PointMap<'a> {
    hier: &'a Hierarchy,
    partition: &'a Partition,
}

impl<'a> PointMap<'a> {
    fn device_of_point(&self, level: usize, j: usize) -> usize {
        let fine_idx = j * self.hier.levels[level].stride;
        let block = (fine_idx / self.hier.coarsen).min(self.partition.n_blocks() - 1);
        self.partition.device_of(block)
    }
}

/// The dependency frontier of one state slot: its last writer plus every
/// reader since that write. A new writer depends on all of them (RAW + WAR +
/// WAW), which is what makes any topological order bit-equivalent to serial.
#[derive(Debug, Clone, Default)]
struct Frontier {
    writer: Option<usize>,
    readers: Vec<usize>,
}

impl Frontier {
    /// Dependencies a writer of this slot must carry; resets the frontier to
    /// the new writer.
    fn begin_write(&mut self, deps: &mut Vec<usize>) {
        deps.append(&mut self.readers);
        if let Some(w) = self.writer {
            deps.push(w);
        }
    }
}

fn dedup(mut deps: Vec<usize>) -> Vec<usize> {
    deps.sort_unstable();
    deps.dedup();
    deps
}

/// Builder state for the MG schedule: per-slot dependency frontiers for the
/// layer states `u`, the FAS right-hand sides `g`, the C-point residuals `r`
/// and the injection snapshots used by the correction.
struct MgBuilder<'a> {
    g: TaskGraph,
    spec: &'a NetSpec,
    batch: usize,
    pm: PointMap<'a>,
    /// Cost multiplier for Φ applications (1 for forward, ~2 for adjoint).
    flop_scale: f64,
    /// Attach executable payloads? (false for cost-model-only stages)
    executable: bool,
    u: Vec<Vec<Frontier>>,
    rhs: Vec<Vec<Frontier>>,
    res: Vec<Vec<Frontier>>,
    inj: Vec<Vec<Frontier>>,
}

impl<'a> MgBuilder<'a> {
    fn new(spec: &'a NetSpec, hier: &'a Hierarchy, partition: &'a Partition, batch: usize) -> Self {
        let slots = |hier: &Hierarchy| -> Vec<Vec<Frontier>> {
            hier.levels.iter().map(|l| vec![Frontier::default(); l.n_points]).collect()
        };
        MgBuilder {
            g: TaskGraph::default(),
            spec,
            batch,
            pm: PointMap { hier, partition },
            flop_scale: 1.0,
            executable: true,
            u: slots(hier),
            rhs: slots(hier),
            res: slots(hier),
            inj: slots(hier),
        }
    }

    fn op(&self, op: TaskOp) -> Option<TaskOp> {
        if self.executable {
            Some(op)
        } else {
            None
        }
    }

    fn class_of(&self, fine_idx: usize) -> KernelClass {
        match self.spec.trunk[fine_idx.min(self.spec.n_res() - 1)] {
            crate::model::LayerKind::Conv { .. } => KernelClass::Conv,
            crate::model::LayerKind::Fc { .. } => KernelClass::Gemm,
        }
    }

    fn step_flops(&self, fine_idx: usize) -> f64 {
        self.flop_scale * layer_cost(self.spec, fine_idx.min(self.spec.n_res() - 1), self.batch).flops
    }

    fn bytes(&self) -> f64 {
        state_bytes(self.spec, self.batch)
    }

    /// Φ-apply at point j−1 → j, with boundary comm if the producer of
    /// u[j−1] lives on another device. Returns the new writer of point j.
    fn point_update(&mut self, level: usize, j: usize, label: &'static str) -> usize {
        let dst = self.pm.device_of_point(level, j);
        let src = self.pm.device_of_point(level, j - 1);
        // data dependencies: u[level][j−1] and (FAS levels) g[level][j]
        let mut deps: Vec<usize> = Vec::new();
        if let Some(w) = self.u[level][j - 1].writer {
            deps.push(w);
        }
        if level > 0 {
            if let Some(w) = self.rhs[level][j].writer {
                deps.push(w);
            }
        }
        let comm =
            self.g.comm(src, dst, self.bytes(), dedup(deps.clone()), self.op(TaskOp::Xfer));
        if let Some(c) = comm {
            self.u[level][j - 1].readers.push(c);
            deps = vec![c];
        }
        // write hazards on the target slot u[level][j]
        self.u[level][j].begin_write(&mut deps);
        let fine_idx = self.pm.hier.levels[level].theta_idx(j - 1);
        let t = self.g.kernel(
            dst,
            label,
            self.class_of(fine_idx),
            self.step_flops(fine_idx),
            dedup(deps),
            self.op(TaskOp::PointUpdate { level, j }),
        );
        self.u[level][j].writer = Some(t);
        self.u[level][j - 1].readers.push(t);
        if level > 0 {
            self.rhs[level][j].readers.push(t);
        }
        t
    }

    fn f_relax(&mut self, level: usize) {
        let lvl = self.pm.hier.levels[level].clone();
        for b in lvl.blocks(self.pm.hier.coarsen) {
            for j in b.cpoint + 1..=b.f_end {
                self.point_update(level, j, "f_relax");
            }
        }
    }

    fn c_relax(&mut self, level: usize) {
        let lvl = self.pm.hier.levels[level].clone();
        for cp in lvl.cpoints(self.pm.hier.coarsen) {
            if cp > 0 {
                self.point_update(level, cp, "c_relax");
            }
        }
    }

    /// Residual at C-points > 0 into the per-point residual slots.
    fn residual(&mut self, level: usize) {
        let lvl = self.pm.hier.levels[level].clone();
        for cp in lvl.cpoints(self.pm.hier.coarsen) {
            if cp == 0 {
                continue;
            }
            let dst = self.pm.device_of_point(level, cp);
            let src = self.pm.device_of_point(level, cp - 1);
            let mut deps: Vec<usize> = Vec::new();
            if let Some(w) = self.u[level][cp - 1].writer {
                deps.push(w);
            }
            if let Some(w) = self.u[level][cp].writer {
                deps.push(w);
            }
            if level > 0 {
                if let Some(w) = self.rhs[level][cp].writer {
                    deps.push(w);
                }
            }
            let comm =
                self.g.comm(src, dst, self.bytes(), dedup(deps.clone()), self.op(TaskOp::Xfer));
            if let Some(c) = comm {
                self.u[level][cp - 1].readers.push(c);
                deps = vec![c];
            }
            self.res[level][cp].begin_write(&mut deps);
            let fine_idx = lvl.theta_idx(cp - 1);
            let t = self.g.kernel(
                dst,
                "residual",
                self.class_of(fine_idx),
                self.step_flops(fine_idx),
                dedup(deps),
                self.op(TaskOp::Residual { level, j: cp }),
            );
            self.res[level][cp].writer = Some(t);
            self.u[level][cp - 1].readers.push(t);
            self.u[level][cp].readers.push(t);
            if level > 0 {
                self.rhs[level][cp].readers.push(t);
            }
        }
    }

    /// FAS restriction to level+1: builds the coarse right-hand side from the
    /// residual slots and injects the C-point states as the coarse initial
    /// guess (+ snapshot for the correction).
    fn restrict(&mut self, level: usize) {
        let c = self.pm.hier.coarsen;
        let coarse = self.pm.hier.levels[level + 1].clone();
        for j in 1..coarse.n_points {
            let fine_j = j * c;
            let prev_fine = (j - 1) * c;
            let dst = self.pm.device_of_point(level + 1, j);
            let src = self.pm.device_of_point(level + 1, j - 1);
            let mut deps: Vec<usize> = Vec::new();
            if let Some(w) = self.res[level][fine_j].writer {
                deps.push(w);
            }
            if let Some(w) = self.u[level][fine_j].writer {
                deps.push(w);
            }
            if let Some(w) = self.u[level][prev_fine].writer {
                deps.push(w);
            }
            let comm =
                self.g.comm(src, dst, self.bytes(), dedup(deps.clone()), self.op(TaskOp::Xfer));
            if let Some(cm) = comm {
                self.u[level][prev_fine].readers.push(cm);
                deps = vec![cm];
            }
            // write hazards on the three coarse slots this task produces
            self.rhs[level + 1][j].begin_write(&mut deps);
            self.u[level + 1][j].begin_write(&mut deps);
            self.inj[level + 1][j].begin_write(&mut deps);
            let fine_idx = coarse.theta_idx(j - 1);
            let t = self.g.kernel(
                dst,
                "restrict",
                self.class_of(fine_idx),
                self.step_flops(fine_idx),
                dedup(deps),
                self.op(TaskOp::Restrict { level, j }),
            );
            self.rhs[level + 1][j].writer = Some(t);
            self.u[level + 1][j].writer = Some(t);
            self.inj[level + 1][j].writer = Some(t);
            self.res[level][fine_j].readers.push(t);
            self.u[level][fine_j].readers.push(t);
            self.u[level][prev_fine].readers.push(t);
        }
    }

    /// Sequential exact solve on the coarsest level, *in place*: the forward
    /// substitution pipelines across the devices that own the points, with
    /// one boundary transfer per partition crossing (the paper's MPI
    /// C-relaxation pattern) — NOT a gather to one device, which would
    /// serialize O(n_points) messages through a single NIC.
    fn coarse_solve(&mut self, level: usize) {
        let n = self.pm.hier.levels[level].n_points;
        for j in 1..n {
            self.point_update(level, j, "coarse_solve");
        }
    }

    /// Correction: elementwise C-point update after the coarse solve (the
    /// coarse point is co-located with its fine C-point by construction).
    fn correct(&mut self, level: usize) {
        let c = self.pm.hier.coarsen;
        let coarse_n = self.pm.hier.levels[level + 1].n_points;
        let act = self.bytes() / 4.0; // elements
        for j in 1..coarse_n {
            let fine_j = j * c;
            let dev = self.pm.device_of_point(level, fine_j);
            let mut deps: Vec<usize> = Vec::new();
            if let Some(w) = self.u[level + 1][j].writer {
                deps.push(w);
            }
            if let Some(w) = self.inj[level + 1][j].writer {
                deps.push(w);
            }
            self.u[level][fine_j].begin_write(&mut deps);
            let t = self.g.kernel(
                dev,
                "correct",
                KernelClass::Light,
                2.0 * act,
                dedup(deps),
                self.op(TaskOp::Correct { level, j }),
            );
            self.u[level][fine_j].writer = Some(t);
            self.u[level + 1][j].readers.push(t);
            self.inj[level + 1][j].readers.push(t);
        }
    }

    fn vcycle(&mut self, level: usize, relax: RelaxKind) {
        if level == self.pm.hier.n_levels() - 1 {
            self.coarse_solve(level);
            return;
        }
        match relax {
            RelaxKind::F => self.f_relax(level),
            RelaxKind::FC => {
                self.f_relax(level);
                self.c_relax(level);
            }
            RelaxKind::FCF => {
                self.f_relax(level);
                self.c_relax(level);
                self.f_relax(level);
            }
        }
        self.residual(level);
        self.restrict(level);
        self.vcycle(level + 1, relax);
        self.correct(level);
        self.f_relax(level);
    }
}

/// One executable V-cycle (level 0 downwards) with the given relaxation
/// pattern — the graph `ParallelMgrit` executes per MG iteration and the
/// building block of [`mg_forward`].
pub fn mg_vcycle(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    relax: RelaxKind,
) -> TaskGraph {
    let mut b = MgBuilder::new(spec, hier, partition, batch);
    b.vcycle(0, relax);
    b.g
}

/// The fine-level residual evaluation (all C-points > 0) used for the
/// convergence check between cycles. Comm-accounting tasks are included so
/// the live driver's traffic ledger matches the paper's MPI pattern.
pub fn residual_check(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
) -> TaskGraph {
    let mut b = MgBuilder::new(spec, hier, partition, batch);
    b.residual(0);
    b.g
}

/// MG forward propagation schedule: `cycles` V-cycles (the paper's FCF
/// configuration).
pub fn mg_forward(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    cycles: usize,
) -> TaskGraph {
    let mut b = MgBuilder::new(spec, hier, partition, batch);
    for _ in 0..cycles {
        b.vcycle(0, RelaxKind::FCF);
    }
    b.g
}

/// MG training step: forward MG, head fwd+vjp, adjoint MG (same cycle count,
/// VJP steps ≈ 2× forward cost), then layer-local parameter gradients fanned
/// out across all devices. Cost-model-only (`op == None`): the live executor
/// runs forward solves; training runs through `train::` on the solver path.
pub fn mg_training(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    cycles: usize,
) -> TaskGraph {
    let mut b = MgBuilder::new(spec, hier, partition, batch);
    b.executable = false;
    for _ in 0..cycles {
        b.vcycle(0, RelaxKind::FCF);
    }
    // head on the device owning the last point
    let n_fine = b.pm.hier.fine().n_points;
    let last_dev = b.pm.device_of_point(0, n_fine - 1);
    let head = crate::model::cost::head_cost(spec, batch);
    let deps: Vec<usize> = b.u[0][n_fine - 1].writer.into_iter().collect();
    let h1 = b.g.kernel(last_dev, "head", KernelClass::Gemm, head.flops, deps, None);
    let h2 =
        b.g.kernel(last_dev, "head_vjp", KernelClass::Gemm, 2.0 * head.flops, vec![h1], None);
    // adjoint MG: structurally identical cycles over the reversed system,
    // each Φ replaced by its VJP (≈ 2× flops)
    b.u[0][n_fine - 1] = Frontier { writer: Some(h2), readers: Vec::new() };
    b.flop_scale = 2.0;
    for _ in 0..cycles {
        b.vcycle(0, RelaxKind::FCF);
    }
    // layer-local parameter gradients (no communication)
    b.flop_scale = 1.0;
    for i in 0..spec.n_res() {
        let j = (i + 1).min(n_fine - 1);
        let dev = b.pm.device_of_point(0, j);
        let deps: Vec<usize> = b.u[0][j].writer.into_iter().collect();
        let c = layer_bwd_cost(spec, i, batch);
        b.g.kernel(dev, "param_grad", b.class_of(i), c.flops, deps, None);
    }
    b.g
}

/// Sequential forward propagation partitioned across devices — one long
/// dependency chain with a transfer at every partition boundary. With
/// n_devices == 1 this is the pure serial baseline; with > 1 it is the
/// paper's "Model Partitioned" (PM) layer-wise parallelism.
pub fn serial_forward(spec: &NetSpec, n_devices: usize, batch: usize) -> TaskGraph {
    let mut g = TaskGraph::default();
    let n = spec.n_res();
    let part = Partition::contiguous(n, n_devices).expect("partition");
    let mut prev: Option<usize> = None;
    let mut prev_dev = part.device_of(0);
    for i in 0..n {
        let dev = part.device_of(i);
        let mut deps: Vec<usize> = prev.into_iter().collect();
        if dev != prev_dev {
            if let Some(c) = g.comm(prev_dev, dev, state_bytes(spec, batch), deps.clone(), None) {
                deps = vec![c];
            }
        }
        let cost = layer_cost(spec, i, batch);
        let class = match spec.trunk[i] {
            crate::model::LayerKind::Conv { .. } => KernelClass::Conv,
            crate::model::LayerKind::Fc { .. } => KernelClass::Gemm,
        };
        prev = Some(g.kernel(dev, "serial_fwd", class, cost.flops, deps, None));
        prev_dev = dev;
    }
    g
}

/// Sequential training step (forward + backward chains) across devices —
/// the PM training baseline of Fig 6b.
pub fn serial_training(spec: &NetSpec, n_devices: usize, batch: usize) -> TaskGraph {
    let mut g = TaskGraph::default();
    let n = spec.n_res();
    let part = Partition::contiguous(n, n_devices).expect("partition");
    let bytes = state_bytes(spec, batch);
    let class_of = |i: usize| match spec.trunk[i] {
        crate::model::LayerKind::Conv { .. } => KernelClass::Conv,
        crate::model::LayerKind::Fc { .. } => KernelClass::Gemm,
    };
    // forward chain
    let mut prev: Option<usize> = None;
    let mut prev_dev = part.device_of(0);
    for i in 0..n {
        let dev = part.device_of(i);
        let mut deps: Vec<usize> = prev.into_iter().collect();
        if dev != prev_dev {
            if let Some(c) = g.comm(prev_dev, dev, bytes, deps.clone(), None) {
                deps = vec![c];
            }
        }
        prev = Some(g.kernel(dev, "fwd", class_of(i), layer_cost(spec, i, batch).flops, deps, None));
        prev_dev = dev;
    }
    // head (fwd + vjp)
    let head = crate::model::cost::head_cost(spec, batch);
    let last_dev = part.device_of(n - 1);
    let h1 = g.kernel(
        last_dev,
        "head",
        KernelClass::Gemm,
        3.0 * head.flops,
        prev.into_iter().collect(),
        None,
    );
    // backward chain
    let mut prev = h1;
    let mut prev_dev = last_dev;
    for i in (0..n).rev() {
        let dev = part.device_of(i);
        let mut deps = vec![prev];
        if dev != prev_dev {
            if let Some(c) = g.comm(prev_dev, dev, bytes, deps.clone(), None) {
                deps = vec![c];
            }
        }
        prev = g.kernel(dev, "bwd", class_of(i), layer_bwd_cost(spec, i, batch).flops, deps, None);
        prev_dev = dev;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n_res: usize, n_dev: usize) -> (NetSpec, Hierarchy, Partition) {
        let spec = NetSpec::fig6_depth(n_res);
        let hier = Hierarchy::two_level(n_res, spec.h(), spec.coarsen).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let partition = Partition::contiguous(n_blocks, n_dev).unwrap();
        (spec, hier, partition)
    }

    #[test]
    fn mg_forward_is_valid_dag() {
        let (spec, hier, part) = setup(64, 4);
        let g = mg_forward(&spec, &hier, &part, 1, 2);
        g.validate().unwrap();
        assert!(g.n_tasks() > 0);
        assert!(g.total_flops() > 0.0);
    }

    #[test]
    fn single_device_mg_has_no_comm() {
        let (spec, hier, part) = setup(64, 1);
        let g = mg_forward(&spec, &hier, &part, 1, 2);
        assert_eq!(g.total_comm_bytes(), 0.0);
    }

    #[test]
    fn multi_device_mg_comm_grows_with_devices() {
        let (spec, hier, _) = setup(256, 1);
        let mut prev = 0.0;
        for n_dev in [2usize, 4, 8, 16] {
            let n_blocks = hier.fine().blocks(hier.coarsen).len();
            let part = Partition::contiguous(n_blocks, n_dev).unwrap();
            let g = mg_forward(&spec, &hier, &part, 1, 2);
            let bytes = g.total_comm_bytes();
            assert!(bytes > prev, "n_dev={n_dev}: {bytes} <= {prev}");
            prev = bytes;
        }
    }

    #[test]
    fn mg_work_is_cycles_times_sweep_work() {
        let (spec, hier, part) = setup(64, 2);
        let g1 = mg_forward(&spec, &hier, &part, 1, 1);
        let g2 = mg_forward(&spec, &hier, &part, 1, 2);
        assert!((g2.total_flops() / g1.total_flops() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn forward_cycles_equal_repeated_vcycles() {
        // mg_forward is exactly `cycles` × mg_vcycle in work and traffic —
        // the invariant the per-cycle live driver relies on
        let (spec, hier, part) = setup(64, 4);
        let v = mg_vcycle(&spec, &hier, &part, 1, RelaxKind::FCF);
        let f2 = mg_forward(&spec, &hier, &part, 1, 2);
        assert_eq!(f2.n_tasks(), 2 * v.n_tasks());
        assert_eq!(f2.n_comms(), 2 * v.n_comms());
        assert!((f2.total_flops() - 2.0 * v.total_flops()).abs() < 1e-6);
    }

    #[test]
    fn executable_graphs_carry_payloads() {
        let (spec, hier, part) = setup(32, 2);
        let v = mg_vcycle(&spec, &hier, &part, 1, RelaxKind::FCF);
        v.validate().unwrap();
        assert!(v.tasks.iter().all(|t| t.op.is_some()), "every task needs a payload");
        // kernels and comms get the right payload kinds
        for t in &v.tasks {
            match (&t.kind, t.op.unwrap()) {
                (TaskKind::Comm { .. }, TaskOp::Xfer) => {}
                (TaskKind::Kernel { .. }, TaskOp::Xfer) => panic!("kernel with Xfer payload"),
                (TaskKind::Comm { .. }, _) => panic!("comm with kernel payload"),
                _ => {}
            }
        }
        let r = residual_check(&spec, &hier, &part, 1);
        assert!(r
            .tasks
            .iter()
            .all(|t| matches!(t.op, Some(TaskOp::Residual { .. }) | Some(TaskOp::Xfer))));
    }

    #[test]
    fn war_hazards_are_encoded() {
        // the final f_relax of a cycle rewrites F-points that the residual
        // phase reads: the writer must depend on the reader (WAR), or a
        // dependency-driven executor could corrupt the residual inputs
        let (spec, hier, part) = setup(16, 2);
        let g = mg_vcycle(&spec, &hier, &part, 1, RelaxKind::FCF);
        let residual_ids: Vec<usize> = g
            .tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Kernel { label: "residual", .. }))
            .map(|t| t.id)
            .collect();
        assert!(!residual_ids.is_empty());
        // some later f_relax task must list a residual task as a dep
        let war = g.tasks.iter().any(|t| {
            matches!(t.kind, TaskKind::Kernel { label: "f_relax", .. })
                && t.deps.iter().any(|d| residual_ids.contains(d))
        });
        assert!(war, "no WAR edge from final f_relax to the residual readers");
    }

    #[test]
    fn serial_forward_flops_match_trunk() {
        let spec = NetSpec::fig6_depth(64);
        let g = serial_forward(&spec, 1, 1);
        let want = crate::model::cost::trunk_flops(&spec, 1);
        assert!((g.total_flops() - want).abs() / want < 1e-12);
        assert_eq!(g.total_comm_bytes(), 0.0);
        g.validate().unwrap();
    }

    #[test]
    fn pm_partitioned_has_boundary_comms() {
        let spec = NetSpec::fig6_depth(64);
        let g = serial_forward(&spec, 8, 1);
        assert_eq!(g.n_comms(), 7); // 7 partition boundaries
    }

    #[test]
    fn mg_does_more_flops_than_serial() {
        // MG is iterative: with 2 cycles it performs > 2x the serial work
        // (the paper's "4x slower on one GPU" effect)
        let (spec, hier, part) = setup(64, 1);
        let mg = mg_forward(&spec, &hier, &part, 1, 2);
        let serial = serial_forward(&spec, 1, 1);
        let ratio = mg.total_flops() / serial.total_flops();
        assert!(ratio > 2.0 && ratio < 10.0, "ratio {ratio}");
    }

    #[test]
    fn training_graph_has_param_grads_on_all_layers() {
        let (spec, hier, part) = setup(32, 2);
        let g = mg_training(&spec, &hier, &part, 1, 2);
        g.validate().unwrap();
        let n_pg = g
            .tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Kernel { label: "param_grad", .. }))
            .count();
        assert_eq!(n_pg, 32);
    }

    #[test]
    fn serial_training_fwd_bwd_chain() {
        let spec = NetSpec::fig6_depth(16);
        let g = serial_training(&spec, 2, 1);
        g.validate().unwrap();
        let fwd: f64 = g
            .tasks
            .iter()
            .filter_map(|t| match &t.kind {
                TaskKind::Kernel { label: "fwd", flops, .. } => Some(*flops),
                _ => None,
            })
            .sum();
        let bwd: f64 = g
            .tasks
            .iter()
            .filter_map(|t| match &t.kind {
                TaskKind::Kernel { label: "bwd", flops, .. } => Some(*flops),
                _ => None,
            })
            .sum();
        assert!((bwd / fwd - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fig7_schedule_scales() {
        // the 2B-param preset: schedule generation must handle 4k+ layers
        let spec = NetSpec::fig7();
        let hier = Hierarchy::two_level(spec.n_res(), spec.h(), spec.coarsen).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let part = Partition::contiguous(n_blocks, 64).unwrap();
        let g = mg_forward(&spec, &hier, &part, 1, 2);
        g.validate().unwrap();
        assert!(g.n_tasks() > 10_000);
        assert!(g.total_comm_bytes() > 0.0);
    }
}
