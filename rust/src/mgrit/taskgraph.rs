//! Schedule DAGs: the *single source of truth* for MGRIT execution order.
//!
//! One graph serves two consumers:
//! - the discrete-event cluster simulator (`sim::engine`) runs it in virtual
//!   time at paper scale (fig6/fig7 presets, 1–64 devices) using the cost
//!   annotations (`TaskKind`), and
//! - the live DAG executor (`coordinator::executor`) runs it on real tensors
//!   using the executable payloads (`TaskOp`), dispatching each task to a
//!   `StreamPool` worker the moment its dependencies retire — no per-phase
//!   barriers.
//!
//! Because both consume the *identical* graph, the simulated schedule and the
//! real schedule cannot drift. This holds for the forward solve **and for the
//! whole training step**: [`mg_train_step`] chains forward V-cycles → head →
//! adjoint V-cycles (the reversed linear propagator Ψᵀ of Günther et al.) →
//! per-layer parameter gradients → per-layer SGD updates in *one* DAG.
//!
//! Dependencies encode every hazard, not just read-after-write: a task that
//! overwrites a state the previous phase still reads carries write-after-read
//! edges to those readers, so any topological execution order produces
//! bit-identical results to the serial engine in `mgrit::fas` (and, for the
//! training graph, to the serial step in `train::mg_step_serial`).
//!
//! Graphs are **multi-instance**: every task carries an `instance` tag (the
//! micro-batch it belongs to), and [`mg_train_step_multi`] composes M
//! independent primal+adjoint training instances into ONE graph joined only
//! by per-layer [`TaskOp::ReduceGrad`] reduction trees and a single
//! [`TaskOp::ParamUpdate`] per layer — hybrid data×layer parallelism with no
//! inter-instance barrier: micro-batch k+1's forward V-cycles overlap
//! micro-batch k's adjoint/gradient wave on the shared (or grouped) devices.
//!
//! Generators:
//! - [`mg_vcycle`] / [`mg_vcycle_with`] — one executable V-cycle (what
//!   `ParallelMgrit` runs per MG iteration)
//! - [`residual_check`] — the fine-level residual evaluation used for the
//!   convergence test between cycles
//! - [`mg_forward`] — multi-cycle forward schedule
//! - [`mg_train_step`] — the whole training step as one executable graph
//! - [`mg_train_step_multi`] — M micro-batch training instances pipelined
//!   through one graph (per-layer `ReduceGrad` join, single `ParamUpdate`)
//! - [`mg_train_pipeline`] — K consecutive training steps **cross-step
//!   pipelined** under bounded staleness: step t reads parameter version
//!   max(0, t−S) from a snapshot ring, and the only cross-step edges are
//!   per-slot `ParamUpdate` → first-reader version-gap edges (or a full
//!   barrier for the drain-to-idle baseline)
//! - [`serial_forward`] / [`serial_training`] — single-stream sequential
//!   baseline (distributed = the paper's "Model Partitioned" / PM method)
//! - [`mg_forward_with`] / [`mg_serve`] — forward-only inference instances
//!   and their composed serving schedules (continuous batching vs
//!   batch-barrier admission; the live scheduler admits the same
//!   single-instance graphs dynamically)
//!
//! Building and inspecting a schedule needs no solver or pool — graphs are
//! pure data:
//!
//! ```
//! use resnet_mgrit::coordinator::Partition;
//! use resnet_mgrit::mgrit::{hierarchy::Hierarchy, taskgraph, RelaxKind};
//! use resnet_mgrit::model::NetSpec;
//!
//! let spec = NetSpec::fig6_depth(16);
//! let hier = Hierarchy::two_level(16, spec.h(), 4).unwrap();
//! let part = Partition::contiguous(hier.fine().blocks(4).len(), 2).unwrap();
//! let g = taskgraph::mg_vcycle(&spec, &hier, &part, 1, RelaxKind::FCF);
//! g.validate().unwrap();
//! assert!(g.n_tasks() > 0 && g.total_flops() > 0.0);
//! // every task is executable — the live executor and the simulator
//! // consume this identical graph
//! assert!(g.tasks.iter().all(|t| t.op.is_some()));
//! ```

use crate::coordinator::{InstanceGroups, Partition};
use crate::model::cost::{head_cost, layer_bwd_cost, layer_cost, opening_cost, state_bytes};
use crate::model::NetSpec;
use crate::Result;

use super::fas::RelaxKind;
use super::hierarchy::Hierarchy;

/// What a task occupies while it runs.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskKind {
    /// GPU kernel work: `flops` of the given class on `device`.
    Kernel {
        /// Phase label (`f_relax`, `adj_c_relax`, `param_grad`, …).
        label: &'static str,
        /// Efficiency class the perfmodel prices this kernel at.
        class: KernelClass,
        /// Work in floating-point operations.
        flops: f64,
    },
    /// A point-to-point activation transfer.
    Comm {
        /// Source device.
        src: usize,
        /// Destination device.
        dst: usize,
        /// Transfer size (bytes).
        bytes: f64,
    },
}

/// Kernel efficiency class (convolutions and GEMMs achieve very different
/// fractions of peak; the perfmodel assigns rates per class).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelClass {
    /// Convolution kernels (register-pressure-serialized per the paper).
    Conv,
    /// Dense GEMM kernels.
    Gemm,
    /// Elementwise / reduction epilogues.
    Light,
}

/// Which linear system a task belongs to: the forward propagation (Φ) or the
/// adjoint propagation (Ψ — each Φ replaced by its VJP, layers reversed via
/// μ^m := λ^{N−m} so the same FAS machinery applies).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sys {
    /// The forward propagation Φ.
    Primal,
    /// The adjoint propagation Ψ.
    Adjoint,
}

/// F-relaxation task granularity. `PerStep` emits one task per F-point (the
/// kernel-per-layer granularity of the paper's Fig 5 nvprof timeline);
/// `PerBlock` fuses each block's contiguous F-span into one [`TaskOp::BlockRun`]
/// task, which lets the live executor reach the solver's fused
/// `block_fprop` fast path (one PJRT block artifact instead of per-step
/// artifacts) at the cost of coarser scheduling.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// One task per F-point update.
    PerStep,
    /// One fused task per block F-span.
    PerBlock,
}

impl Granularity {
    /// Parse a CLI spelling (`per_step` | `per_block`).
    pub fn parse(s: &str) -> Result<Granularity> {
        match s {
            "per_step" | "per-step" | "step" => Ok(Granularity::PerStep),
            "per_block" | "per-block" | "block" => Ok(Granularity::PerBlock),
            other => anyhow::bail!("unknown granularity {other:?} (per_step|per_block)"),
        }
    }
}

/// Executable payload: which state slots a task reads and writes. `level`
/// indexes the MGRIT hierarchy; `j` is a point index on that level; `sys`
/// selects the forward (`u`) or adjoint (`μ`) slot set. Adjoint tasks apply
/// Ψ at the reversed fine layer index and additionally read the forward fine
/// state they linearize around.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskOp {
    /// `u[level][j] = Φ_{θ(j−1)}(u[level][j−1]) + g[level][j]` — the
    /// elementary update of F-relaxation, C-relaxation, and the coarse
    /// forward substitution (Ψ instead of Φ for the adjoint system).
    PointUpdate {
        /// Target system.
        sys: Sys,
        /// Hierarchy level.
        level: usize,
        /// Point index on that level.
        j: usize,
    },
    /// The fused F-span update of one block: points `j_first..=j_last` from
    /// point `j_first − 1` in one task (level 0 only, where the FAS
    /// right-hand side vanishes and the solver's `block_fprop` applies).
    BlockRun {
        /// Target system.
        sys: Sys,
        /// Hierarchy level (always 0).
        level: usize,
        /// First point of the fused span.
        j_first: usize,
        /// Last point of the fused span (inclusive).
        j_last: usize,
    },
    /// `r[level][j] = Φ_{θ(j−1)}(u[level][j−1]) + g[level][j] − u[level][j]`.
    Residual {
        /// Target system.
        sys: Sys,
        /// Hierarchy level.
        level: usize,
        /// Point index on that level.
        j: usize,
    },
    /// FAS restriction to `level+1`:
    /// `g[level+1][j] = r[level][j·c] + ū_H[j] − Φ_H(ū_H[j−1])` with
    /// `ū_H[j] = u[level][j·c]`; also injects `u[level+1][j] = ū_H[j]` and
    /// snapshots it for the later correction.
    Restrict {
        /// Target system.
        sys: Sys,
        /// Fine level being restricted (writes into `level + 1`).
        level: usize,
        /// Coarse point index.
        j: usize,
    },
    /// FAS correction: `u[level][j·c] += u[level+1][j] − ū_H[j]`.
    Correct {
        /// Target system.
        sys: Sys,
        /// Fine level being corrected.
        level: usize,
        /// Coarse point index.
        j: usize,
    },
    /// Head forward + VJP at the last fine state: produces the loss, the
    /// head parameter gradients, and ∂loss/∂u^N — which seeds *every* slot
    /// of the adjoint system (the constant-in-depth initial guess). Each
    /// instance has its own head (its own micro-batch loss).
    Head,
    /// Layer-local parameter gradient `gⁿ = h·(∂F/∂θⁿ)ᵀ λ^{n+1}` — fans out
    /// the moment its λ slot retires; embarrassingly parallel. Per instance.
    GradAccum {
        /// Trunk layer index.
        layer: usize,
    },
    /// One node of a layer's micro-batch gradient reduction tree:
    /// `dst = lhs + rhs` over (weight, bias) pairs; the `root` node
    /// additionally scales by 1/M (the micro-batch mean). Leaves read
    /// instance `GradAccum` slots, internal nodes read earlier tree nodes —
    /// the only tasks with cross-instance dependencies, so there is never an
    /// inter-instance barrier. Executed with the same `model::params`
    /// primitives as the serial reference → bit-identical reduction.
    ReduceGrad {
        /// Trunk layer index.
        layer: usize,
        /// Left operand.
        lhs: GradSrc,
        /// Right operand.
        rhs: GradSrc,
        /// Output tree-node id.
        node: usize,
        /// Whether this node is the tree root (applies the 1/M mean).
        root: bool,
    },
    /// Per-layer SGD update `θⁿ ← θⁿ − lr·ĝⁿ` into the fresh parameter slot,
    /// where ĝ is the instance gradient (M = 1) or the `ReduceGrad` root
    /// (M > 1). Exactly one per layer per composed graph.
    ParamUpdate {
        /// Trunk layer index.
        layer: usize,
    },
    /// The opening layer `u⁰ = relu(conv(y) + b_open)` of one **pipelined**
    /// training instance — the sole dependency-free task of its instance,
    /// evaluated against the instance's parameter *version* (the snapshot
    /// ring; see [`mg_train_pipeline`]). It seeds every primal state slot,
    /// mirroring how [`TaskOp::Head`] seeds the adjoint system, so the whole
    /// instance is ordered behind it. Plain (non-pipelined) training steps
    /// run the opening host-side instead.
    Opening,
    /// The opening layer's VJP of one pipelined training instance: reads the
    /// instance input `y` and λ⁰, against the same parameter version as the
    /// instance's [`TaskOp::Opening`], producing the opening `(dW, db)` pair
    /// that joins the pipeline's per-step reduction at slot `n_layers`.
    OpenGrad,
    /// Boundary transfer (accounting only in local execution).
    Xfer,
}

/// Operand of a [`TaskOp::ReduceGrad`] node: an instance's `GradAccum`
/// output, or an earlier internal node of the same layer's tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradSrc {
    /// Instance k's `GradAccum` output.
    Inst(usize),
    /// An earlier internal tree node.
    Node(usize),
}

/// One node of the schedule DAG. Its identity is the `(instance, id)` pair:
/// `id` is the graph-global topological index, `instance` the micro-batch
/// whose state slots the payload reads/writes (joint tasks — `ReduceGrad`,
/// the final `ParamUpdate`s and their transfers — carry instance 0).
#[derive(Debug, Clone)]
pub struct Task {
    /// Graph-global topological index.
    pub id: usize,
    /// Graph instance (micro-batch) this task's payload operates on.
    pub instance: usize,
    /// Executing device (for Comm: the destination device).
    pub device: usize,
    /// What the task occupies while it runs (cost annotation).
    pub kind: TaskKind,
    /// Ids of the tasks that must retire before this one dispatches.
    pub deps: Vec<usize>,
    /// Executable payload; `None` for cost-model-only tasks (baseline
    /// schedules the live executor does not run).
    pub op: Option<TaskOp>,
}

/// A schedule DAG plus bookkeeping to attach dependencies incrementally.
#[derive(Debug, Default)]
pub struct TaskGraph {
    /// The tasks, in id (topological) order.
    pub tasks: Vec<Task>,
}

impl TaskGraph {
    fn push(
        &mut self,
        device: usize,
        kind: TaskKind,
        deps: Vec<usize>,
        op: Option<TaskOp>,
    ) -> usize {
        let id = self.tasks.len();
        self.tasks.push(Task { id, instance: 0, device, kind, deps, op });
        id
    }

    /// Splice a single-instance sub-graph into this graph as instance
    /// `instance`, offsetting task ids, dependency ids and device ids (the
    /// instance's device-group offset). Returns the id offset.
    pub(crate) fn append_instance(
        &mut self,
        sub: TaskGraph,
        instance: usize,
        dev_offset: usize,
    ) -> usize {
        let off = self.tasks.len();
        for mut t in sub.tasks {
            t.id += off;
            t.instance = instance;
            t.device += dev_offset;
            if let TaskKind::Comm { src, dst, .. } = &mut t.kind {
                *src += dev_offset;
                *dst += dev_offset;
            }
            for d in &mut t.deps {
                *d += off;
            }
            self.tasks.push(t);
        }
        off
    }

    /// Splice an already-composed **multi-instance** sub-graph into this
    /// graph, offsetting task ids, dependency ids and device ids while
    /// *preserving* the sub-graph's per-task instance tags (shifted by
    /// `inst_offset`) — the composed-admission counterpart of
    /// [`TaskGraph::append_instance`], used to admit whole pipelined
    /// training graphs into an incremental session. Returns the id offset.
    pub(crate) fn append_composed(
        &mut self,
        sub: TaskGraph,
        inst_offset: usize,
        dev_offset: usize,
    ) -> usize {
        let off = self.tasks.len();
        for mut t in sub.tasks {
            t.id += off;
            t.instance += inst_offset;
            t.device += dev_offset;
            if let TaskKind::Comm { src, dst, .. } = &mut t.kind {
                *src += dev_offset;
                *dst += dev_offset;
            }
            for d in &mut t.deps {
                *d += off;
            }
            self.tasks.push(t);
        }
        off
    }

    /// Kernel task helper.
    fn kernel(
        &mut self,
        device: usize,
        label: &'static str,
        class: KernelClass,
        flops: f64,
        deps: Vec<usize>,
        op: Option<TaskOp>,
    ) -> usize {
        self.push(device, TaskKind::Kernel { label, class, flops }, deps, op)
    }

    /// Transfer `bytes` from src to dst (no task if same device).
    fn comm(
        &mut self,
        src: usize,
        dst: usize,
        bytes: f64,
        deps: Vec<usize>,
        op: Option<TaskOp>,
    ) -> Option<usize> {
        if src == dst {
            None
        } else {
            Some(self.push(dst, TaskKind::Comm { src, dst, bytes }, deps, op))
        }
    }

    /// Number of tasks in the graph.
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// Total kernel work (FLOPs) across all tasks.
    pub fn total_flops(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| match &t.kind {
                TaskKind::Kernel { flops, .. } => *flops,
                _ => 0.0,
            })
            .sum()
    }

    /// Total transfer volume (bytes) across all Comm tasks.
    pub fn total_comm_bytes(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| match &t.kind {
                TaskKind::Comm { bytes, .. } => *bytes,
                _ => 0.0,
            })
            .sum()
    }

    /// Number of Comm tasks.
    pub fn n_comms(&self) -> usize {
        self.tasks.iter().filter(|t| matches!(t.kind, TaskKind::Comm { .. })).count()
    }

    /// Number of Kernel tasks with the given label.
    pub fn n_kernels_labeled(&self, label: &str) -> usize {
        self.tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Kernel { label: l, .. } if l == label))
            .count()
    }

    /// Verify the graph is a DAG with in-range dependencies (deps always
    /// point backwards by construction; this asserts it).
    pub fn validate(&self) -> Result<()> {
        for t in &self.tasks {
            for &d in &t.deps {
                if d >= t.id {
                    anyhow::bail!("task {} depends on non-earlier task {}", t.id, d);
                }
            }
        }
        Ok(())
    }
}

/// Maps MGRIT points to devices (same rule as the parallel driver), through
/// a block → device map expanded once from [`Partition::spans`]. Adjoint
/// points map through the layer they correspond to (μ^m ↔ λ^{N−m} lives with
/// fine layer point N−m), so λ stays co-located with the layer whose VJP
/// produces it and parameter gradients are layer-local.
struct PointMap<'a> {
    hier: &'a Hierarchy,
    block_dev: Vec<usize>,
}

impl<'a> PointMap<'a> {
    fn new(hier: &'a Hierarchy, partition: &Partition) -> PointMap<'a> {
        let mut block_dev = vec![0usize; partition.n_blocks()];
        for (d, span) in partition.spans().iter().enumerate() {
            for b in span.clone() {
                block_dev[b] = d;
            }
        }
        PointMap { hier, block_dev }
    }

    fn device_of(&self, sys: Sys, level: usize, j: usize) -> usize {
        let fine_idx = j * self.hier.levels[level].stride;
        let fine_idx = match sys {
            Sys::Primal => fine_idx,
            Sys::Adjoint => (self.hier.fine().n_points - 1) - fine_idx,
        };
        let block = (fine_idx / self.hier.coarsen).min(self.block_dev.len() - 1);
        self.block_dev[block]
    }
}

/// The dependency frontier of one state slot: its last writer plus every
/// reader since that write. A new writer depends on all of them (RAW + WAR +
/// WAW), which is what makes any topological order bit-equivalent to serial.
#[derive(Debug, Clone, Default)]
struct Frontier {
    writer: Option<usize>,
    readers: Vec<usize>,
}

impl Frontier {
    /// Dependencies a writer of this slot must carry; resets the frontier to
    /// the new writer.
    fn begin_write(&mut self, deps: &mut Vec<usize>) {
        deps.append(&mut self.readers);
        if let Some(w) = self.writer {
            deps.push(w);
        }
    }
}

fn dedup(mut deps: Vec<usize>) -> Vec<usize> {
    deps.sort_unstable();
    deps.dedup();
    deps
}

/// Per-system dependency frontiers: the layer states `u`, the FAS right-hand
/// sides `g`, the C-point residuals `r` and the injection snapshots the
/// correction consumes.
struct SysSlots {
    u: Vec<Vec<Frontier>>,
    rhs: Vec<Vec<Frontier>>,
    res: Vec<Vec<Frontier>>,
    inj: Vec<Vec<Frontier>>,
}

impl SysSlots {
    fn new(hier: &Hierarchy) -> SysSlots {
        let mk = || -> Vec<Vec<Frontier>> {
            hier.levels.iter().map(|l| vec![Frontier::default(); l.n_points]).collect()
        };
        SysSlots { u: mk(), rhs: mk(), res: mk(), inj: mk() }
    }
}

/// Builder state for the MG schedules. `sys` selects which system (primal or
/// adjoint) subsequent cycle phases build tasks for; the two systems keep
/// independent frontier sets, and adjoint tasks additionally carry RAW edges
/// to the primal fine states they linearize around.
struct MgBuilder<'a> {
    g: TaskGraph,
    spec: &'a NetSpec,
    batch: usize,
    pm: PointMap<'a>,
    /// Cost multiplier for Φ applications (1 for forward, ~2 for adjoint).
    flop_scale: f64,
    /// Attach executable payloads? (false for cost-model-only stages)
    executable: bool,
    sys: Sys,
    gran: Granularity,
    /// Frontier slots: index 0 = primal, 1 = adjoint.
    slots: [SysSlots; 2],
}

impl<'a> MgBuilder<'a> {
    fn new(spec: &'a NetSpec, hier: &'a Hierarchy, partition: &'a Partition, batch: usize) -> Self {
        MgBuilder {
            g: TaskGraph::default(),
            spec,
            batch,
            pm: PointMap::new(hier, partition),
            flop_scale: 1.0,
            executable: true,
            sys: Sys::Primal,
            gran: Granularity::PerStep,
            slots: [SysSlots::new(hier), SysSlots::new(hier)],
        }
    }

    fn si(&self) -> usize {
        match self.sys {
            Sys::Primal => 0,
            Sys::Adjoint => 1,
        }
    }

    fn op(&self, op: TaskOp) -> Option<TaskOp> {
        if self.executable {
            Some(op)
        } else {
            None
        }
    }

    fn lbl(&self, primal: &'static str, adjoint: &'static str) -> &'static str {
        match self.sys {
            Sys::Primal => primal,
            Sys::Adjoint => adjoint,
        }
    }

    fn class_of(&self, fine_idx: usize) -> KernelClass {
        match self.spec.trunk[fine_idx.min(self.spec.n_res() - 1)] {
            crate::model::LayerKind::Conv { .. } => KernelClass::Conv,
            crate::model::LayerKind::Fc { .. } => KernelClass::Gemm,
        }
    }

    fn step_flops(&self, fine_idx: usize) -> f64 {
        self.flop_scale * layer_cost(self.spec, fine_idx.min(self.spec.n_res() - 1), self.batch).flops
    }

    fn bytes(&self) -> f64 {
        state_bytes(self.spec, self.batch)
    }

    /// Forward fine state index the adjoint step at (level, j) linearizes
    /// around (see [`Hierarchy::adjoint_state_index`] — shared with the
    /// executor's dispatch-time read).
    fn rev_state(&self, level: usize, j: usize) -> usize {
        self.pm.hier.adjoint_state_index(level, j)
    }

    /// Add the adjoint → primal-state RAW edge for a Ψ application at
    /// (level, j) and return the slot index for reader registration.
    fn adjoint_state_dep(&mut self, level: usize, j: usize, deps: &mut Vec<usize>) -> Option<usize> {
        if self.sys != Sys::Adjoint || !self.executable {
            return None;
        }
        let rev = self.rev_state(level, j);
        if let Some(w) = self.slots[0].u[0][rev].writer {
            deps.push(w);
        }
        Some(rev)
    }

    /// Φ-apply (Ψ for the adjoint system) at point j−1 → j, with boundary
    /// comm if the producer of u[j−1] lives on another device. Returns the
    /// new writer of point j.
    fn point_update(
        &mut self,
        level: usize,
        j: usize,
        p_label: &'static str,
        a_label: &'static str,
    ) -> usize {
        let sys = self.sys;
        let si = self.si();
        let dst = self.pm.device_of(sys, level, j);
        let src = self.pm.device_of(sys, level, j - 1);
        // data dependencies: u[level][j−1] and (FAS levels) g[level][j]
        let mut deps: Vec<usize> = Vec::new();
        if let Some(w) = self.slots[si].u[level][j - 1].writer {
            deps.push(w);
        }
        if level > 0 {
            if let Some(w) = self.slots[si].rhs[level][j].writer {
                deps.push(w);
            }
        }
        let comm =
            self.g.comm(src, dst, self.bytes(), dedup(deps.clone()), self.op(TaskOp::Xfer));
        if let Some(c) = comm {
            self.slots[si].u[level][j - 1].readers.push(c);
            deps = vec![c];
        }
        // adjoint: RAW edge to the forward state this Ψ linearizes around
        let rev = self.adjoint_state_dep(level, j, &mut deps);
        // write hazards on the target slot u[level][j]
        self.slots[si].u[level][j].begin_write(&mut deps);
        let fine_idx = self.pm.hier.levels[level].theta_idx(j - 1);
        let label = self.lbl(p_label, a_label);
        let t = self.g.kernel(
            dst,
            label,
            self.class_of(fine_idx),
            self.step_flops(fine_idx),
            dedup(deps),
            self.op(TaskOp::PointUpdate { sys, level, j }),
        );
        self.slots[si].u[level][j].writer = Some(t);
        self.slots[si].u[level][j - 1].readers.push(t);
        if level > 0 {
            self.slots[si].rhs[level][j].readers.push(t);
        }
        if let Some(rev) = rev {
            self.slots[0].u[0][rev].readers.push(t);
        }
        t
    }

    /// Fused F-span of one block: points `j_first..=j_last` from the block's
    /// C-point in a single task. Level 0 only (no FAS right-hand side), and
    /// always within one device (a block never crosses a partition).
    fn block_run(&mut self, level: usize, j_first: usize, j_last: usize) {
        debug_assert_eq!(level, 0, "BlockRun requires a vanishing right-hand side");
        let sys = self.sys;
        let si = self.si();
        let dev = self.pm.device_of(sys, level, j_first);
        let mut deps: Vec<usize> = Vec::new();
        if let Some(w) = self.slots[si].u[level][j_first - 1].writer {
            deps.push(w);
        }
        let mut revs: Vec<usize> = Vec::new();
        if sys == Sys::Adjoint && self.executable {
            for j in j_first..=j_last {
                let rev = self.rev_state(level, j);
                if let Some(w) = self.slots[0].u[0][rev].writer {
                    deps.push(w);
                }
                revs.push(rev);
            }
        }
        for j in j_first..=j_last {
            self.slots[si].u[level][j].begin_write(&mut deps);
        }
        let lvl = self.pm.hier.levels[level].clone();
        let flops: f64 = (j_first..=j_last).map(|j| self.step_flops(lvl.theta_idx(j - 1))).sum();
        let class = self.class_of(lvl.theta_idx(j_first - 1));
        let label = self.lbl("f_relax", "adj_f_relax");
        let t = self.g.kernel(
            dev,
            label,
            class,
            flops,
            dedup(deps),
            self.op(TaskOp::BlockRun { sys, level, j_first, j_last }),
        );
        self.slots[si].u[level][j_first - 1].readers.push(t);
        for j in j_first..=j_last {
            self.slots[si].u[level][j].writer = Some(t);
        }
        for rev in revs {
            self.slots[0].u[0][rev].readers.push(t);
        }
    }

    fn f_relax(&mut self, level: usize) {
        let lvl = self.pm.hier.levels[level].clone();
        let fuse = self.gran == Granularity::PerBlock && level == 0;
        for b in lvl.blocks(self.pm.hier.coarsen) {
            if b.n_fpoints() == 0 {
                continue;
            }
            if fuse {
                self.block_run(level, b.cpoint + 1, b.f_end);
            } else {
                for j in b.cpoint + 1..=b.f_end {
                    self.point_update(level, j, "f_relax", "adj_f_relax");
                }
            }
        }
    }

    fn c_relax(&mut self, level: usize) {
        let lvl = self.pm.hier.levels[level].clone();
        for cp in lvl.cpoints(self.pm.hier.coarsen) {
            if cp > 0 {
                self.point_update(level, cp, "c_relax", "adj_c_relax");
            }
        }
    }

    /// Residual at C-points > 0 into the per-point residual slots.
    fn residual(&mut self, level: usize) {
        let sys = self.sys;
        let si = self.si();
        let lvl = self.pm.hier.levels[level].clone();
        for cp in lvl.cpoints(self.pm.hier.coarsen) {
            if cp == 0 {
                continue;
            }
            let dst = self.pm.device_of(sys, level, cp);
            let src = self.pm.device_of(sys, level, cp - 1);
            let mut deps: Vec<usize> = Vec::new();
            if let Some(w) = self.slots[si].u[level][cp - 1].writer {
                deps.push(w);
            }
            if let Some(w) = self.slots[si].u[level][cp].writer {
                deps.push(w);
            }
            if level > 0 {
                if let Some(w) = self.slots[si].rhs[level][cp].writer {
                    deps.push(w);
                }
            }
            let comm =
                self.g.comm(src, dst, self.bytes(), dedup(deps.clone()), self.op(TaskOp::Xfer));
            if let Some(c) = comm {
                self.slots[si].u[level][cp - 1].readers.push(c);
                deps = vec![c];
            }
            let rev = self.adjoint_state_dep(level, cp, &mut deps);
            self.slots[si].res[level][cp].begin_write(&mut deps);
            let fine_idx = lvl.theta_idx(cp - 1);
            let label = self.lbl("residual", "adj_residual");
            let t = self.g.kernel(
                dst,
                label,
                self.class_of(fine_idx),
                self.step_flops(fine_idx),
                dedup(deps),
                self.op(TaskOp::Residual { sys, level, j: cp }),
            );
            self.slots[si].res[level][cp].writer = Some(t);
            self.slots[si].u[level][cp - 1].readers.push(t);
            self.slots[si].u[level][cp].readers.push(t);
            if level > 0 {
                self.slots[si].rhs[level][cp].readers.push(t);
            }
            if let Some(rev) = rev {
                self.slots[0].u[0][rev].readers.push(t);
            }
        }
    }

    /// FAS restriction to level+1: builds the coarse right-hand side from the
    /// residual slots and injects the C-point states as the coarse initial
    /// guess (+ snapshot for the correction).
    fn restrict(&mut self, level: usize) {
        let sys = self.sys;
        let si = self.si();
        let c = self.pm.hier.coarsen;
        let coarse = self.pm.hier.levels[level + 1].clone();
        for j in 1..coarse.n_points {
            let fine_j = j * c;
            let prev_fine = (j - 1) * c;
            let dst = self.pm.device_of(sys, level + 1, j);
            let src = self.pm.device_of(sys, level + 1, j - 1);
            let mut deps: Vec<usize> = Vec::new();
            if let Some(w) = self.slots[si].res[level][fine_j].writer {
                deps.push(w);
            }
            if let Some(w) = self.slots[si].u[level][fine_j].writer {
                deps.push(w);
            }
            if let Some(w) = self.slots[si].u[level][prev_fine].writer {
                deps.push(w);
            }
            let comm =
                self.g.comm(src, dst, self.bytes(), dedup(deps.clone()), self.op(TaskOp::Xfer));
            if let Some(cm) = comm {
                self.slots[si].u[level][prev_fine].readers.push(cm);
                deps = vec![cm];
            }
            // adjoint: the coarse Ψ_H application linearizes around a primal
            // fine state too
            let rev = self.adjoint_state_dep(level + 1, j, &mut deps);
            // write hazards on the three coarse slots this task produces
            self.slots[si].rhs[level + 1][j].begin_write(&mut deps);
            self.slots[si].u[level + 1][j].begin_write(&mut deps);
            self.slots[si].inj[level + 1][j].begin_write(&mut deps);
            let fine_idx = coarse.theta_idx(j - 1);
            let label = self.lbl("restrict", "adj_restrict");
            let t = self.g.kernel(
                dst,
                label,
                self.class_of(fine_idx),
                self.step_flops(fine_idx),
                dedup(deps),
                self.op(TaskOp::Restrict { sys, level, j }),
            );
            self.slots[si].rhs[level + 1][j].writer = Some(t);
            self.slots[si].u[level + 1][j].writer = Some(t);
            self.slots[si].inj[level + 1][j].writer = Some(t);
            self.slots[si].res[level][fine_j].readers.push(t);
            self.slots[si].u[level][fine_j].readers.push(t);
            self.slots[si].u[level][prev_fine].readers.push(t);
            if let Some(rev) = rev {
                self.slots[0].u[0][rev].readers.push(t);
            }
        }
    }

    /// Sequential exact solve on the coarsest level, *in place*: the forward
    /// substitution pipelines across the devices that own the points, with
    /// one boundary transfer per partition crossing (the paper's MPI
    /// C-relaxation pattern) — NOT a gather to one device, which would
    /// serialize O(n_points) messages through a single NIC.
    fn coarse_solve(&mut self, level: usize) {
        let n = self.pm.hier.levels[level].n_points;
        for j in 1..n {
            self.point_update(level, j, "coarse_solve", "adj_coarse_solve");
        }
    }

    /// Correction: elementwise C-point update after the coarse solve (the
    /// coarse point is co-located with its fine C-point by construction).
    fn correct(&mut self, level: usize) {
        let sys = self.sys;
        let si = self.si();
        let c = self.pm.hier.coarsen;
        let coarse_n = self.pm.hier.levels[level + 1].n_points;
        let act = self.bytes() / 4.0; // elements
        for j in 1..coarse_n {
            let fine_j = j * c;
            let dev = self.pm.device_of(sys, level, fine_j);
            let mut deps: Vec<usize> = Vec::new();
            if let Some(w) = self.slots[si].u[level + 1][j].writer {
                deps.push(w);
            }
            if let Some(w) = self.slots[si].inj[level + 1][j].writer {
                deps.push(w);
            }
            self.slots[si].u[level][fine_j].begin_write(&mut deps);
            let label = self.lbl("correct", "adj_correct");
            let t = self.g.kernel(
                dev,
                label,
                KernelClass::Light,
                2.0 * act,
                dedup(deps),
                self.op(TaskOp::Correct { sys, level, j }),
            );
            self.slots[si].u[level][fine_j].writer = Some(t);
            self.slots[si].u[level + 1][j].readers.push(t);
            self.slots[si].inj[level + 1][j].readers.push(t);
        }
    }

    fn vcycle(&mut self, level: usize, relax: RelaxKind) {
        if level == self.pm.hier.n_levels() - 1 {
            self.coarse_solve(level);
            return;
        }
        match relax {
            RelaxKind::F => self.f_relax(level),
            RelaxKind::FC => {
                self.f_relax(level);
                self.c_relax(level);
            }
            RelaxKind::FCF => {
                self.f_relax(level);
                self.c_relax(level);
                self.f_relax(level);
            }
        }
        self.residual(level);
        self.restrict(level);
        self.vcycle(level + 1, relax);
        self.correct(level);
        self.f_relax(level);
    }

    /// The head task (forward + VJP in one kernel on the device owning the
    /// last fine point) and the adjoint-system seeding: the head's output
    /// ∂loss/∂u^N becomes the initial guess of *every* adjoint slot, so every
    /// adjoint frontier starts at the head task.
    fn head(&mut self) -> usize {
        let n_fine = self.pm.hier.fine().n_points;
        let last_dev = self.pm.device_of(Sys::Primal, 0, n_fine - 1);
        let hc = head_cost(self.spec, self.batch);
        let deps: Vec<usize> = self.slots[0].u[0][n_fine - 1].writer.into_iter().collect();
        let ht = self.g.kernel(
            last_dev,
            "head",
            KernelClass::Gemm,
            3.0 * hc.flops,
            deps,
            self.op(TaskOp::Head),
        );
        self.slots[0].u[0][n_fine - 1].readers.push(ht);
        for l in 0..self.pm.hier.n_levels() {
            for j in 0..self.pm.hier.levels[l].n_points {
                self.slots[1].u[l][j].writer = Some(ht);
                self.slots[1].rhs[l][j].writer = Some(ht);
            }
        }
        ht
    }

    /// Per-layer gradient tasks. The gradient of layer i needs the forward
    /// state u[0][i] and λ^{i+1} = μ^{N−1−i}; it becomes ready the moment
    /// that μ slot's final writer retires — while adjoint relaxation of
    /// other partitions is still in flight. The matching SGD updates are
    /// emitted by the multi-instance composer (after the micro-batch
    /// gradient reduction join).
    fn grads(&mut self) {
        let n_fine = self.pm.hier.fine().n_points;
        let n_layers = n_fine - 1;
        for i in 0..n_layers {
            let dev = self.pm.device_of(Sys::Primal, 0, (i + 1).min(n_fine - 1));
            let mu = n_layers - 1 - i;
            let mut deps: Vec<usize> = Vec::new();
            if let Some(w) = self.slots[0].u[0][i].writer {
                deps.push(w);
            }
            if let Some(w) = self.slots[1].u[0][mu].writer {
                deps.push(w);
            }
            let c = layer_bwd_cost(self.spec, i, self.batch);
            let gt = self.g.kernel(
                dev,
                "param_grad",
                self.class_of(i),
                c.flops,
                dedup(deps),
                self.op(TaskOp::GradAccum { layer: i }),
            );
            self.slots[0].u[0][i].readers.push(gt);
            self.slots[1].u[0][mu].readers.push(gt);
        }
    }

    /// The in-graph opening task of one pipelined training instance: the
    /// instance's sole dependency-free root. Seeds every primal state slot
    /// (the primal mirror of [`MgBuilder::head`]'s adjoint seeding), so all
    /// instance work — and therefore every parameter read of the instance —
    /// is ordered behind it.
    fn opening(&mut self) -> usize {
        let dev = self.pm.device_of(Sys::Primal, 0, 0);
        let oc = opening_cost(self.spec, self.batch);
        let t = self.g.kernel(
            dev,
            "opening",
            KernelClass::Conv,
            oc.flops,
            Vec::new(),
            self.op(TaskOp::Opening),
        );
        for l in 0..self.pm.hier.n_levels() {
            for j in 0..self.pm.hier.levels[l].n_points {
                self.slots[0].u[l][j].writer = Some(t);
                self.slots[0].rhs[l][j].writer = Some(t);
            }
        }
        t
    }

    /// The opening VJP task of one pipelined training instance: reads λ⁰
    /// (the adjoint fine state μ^N = λ⁰) once its final writer retires.
    /// VJP cost ≈ 2× the opening forward cost, same class.
    fn open_grad(&mut self) -> usize {
        let n_last = self.pm.hier.fine().n_points - 1;
        let dev = self.pm.device_of(Sys::Adjoint, 0, n_last);
        let oc = opening_cost(self.spec, self.batch);
        let mut deps: Vec<usize> = Vec::new();
        if let Some(w) = self.slots[1].u[0][n_last].writer {
            deps.push(w);
        }
        let t = self.g.kernel(
            dev,
            "open_grad",
            KernelClass::Conv,
            2.0 * oc.flops,
            deps,
            self.op(TaskOp::OpenGrad),
        );
        self.slots[1].u[0][n_last].readers.push(t);
        t
    }
}

/// One step of the micro-batch gradient reduction: `node = lhs + rhs`, with
/// the root additionally scaled by 1/M.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReduceStep {
    /// Left operand.
    pub lhs: GradSrc,
    /// Right operand.
    pub rhs: GradSrc,
    /// Output tree-node id of this step.
    pub node: usize,
    /// Whether this step is the tree root (applies the 1/M mean).
    pub root: bool,
}

/// The balanced pairwise reduction plan over `m` instance gradients —
/// ⌈log₂ m⌉ rounds, m − 1 internal nodes, the last step marked `root`
/// (where the 1/M mean is applied). The live `ReduceGrad` tasks and the
/// serial reference `train::reduce_micro_grads` both execute THIS plan with
/// the same `model::params` primitives, which is what makes the pipelined
/// hybrid step bit-identical to the serial sum-over-micro-batches. Empty for
/// m ≤ 1 (nothing to reduce).
pub fn reduce_plan(m: usize) -> Vec<ReduceStep> {
    let mut cur: Vec<GradSrc> = (0..m).map(GradSrc::Inst).collect();
    let mut steps: Vec<ReduceStep> = Vec::new();
    let mut next_node = 0usize;
    while cur.len() > 1 {
        let mut nxt: Vec<GradSrc> = Vec::with_capacity((cur.len() + 1) / 2);
        for pair in cur.chunks(2) {
            if let [lhs, rhs] = *pair {
                let node = next_node;
                next_node += 1;
                steps.push(ReduceStep { lhs, rhs, node, root: false });
                nxt.push(GradSrc::Node(node));
            } else {
                // odd leftover carries into the next round
                nxt.push(pair[0]);
            }
        }
        cur = nxt;
    }
    if let Some(last) = steps.last_mut() {
        last.root = true;
    }
    steps
}

/// The collective algorithm joining the M micro-batch gradients per layer.
///
/// Every algorithm emits a plain `Vec<ReduceStep>` obeying the same shape
/// contract (see [`collective_plan`]), so the executor, the simulator, and
/// the serial bit-identity reference `train::reduce_micro_grads_plan` all
/// consume any plan unchanged — the choice only moves `(src, dst)` endpoints
/// and the association order of the floating-point sum.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Collective {
    /// Balanced pairwise tree (the library default — [`reduce_plan`],
    /// bit-for-bit the pre-topology behavior). Topology-blind: at M
    /// instances round-robined over G nodes, roughly M·(G−1)/G tree edges
    /// cross a node boundary.
    #[default]
    Tree,
    /// Sequential ring: the partial sum travels instance 0 → 1 → … → M−1,
    /// one hop per step. Minimizes concurrent link pressure (one transfer
    /// in flight per layer) at the price of an M−1-deep critical path.
    Ring,
    /// Hierarchical two-phase: balanced pairwise **inside** each node
    /// (co-located, so every phase-1 transfer is free), then a chain of the
    /// per-node partials into the lowest node — exactly G−1 inter-node
    /// hops per layer, the minimum for a single-rooted reduction.
    TwoPhase,
}

impl Collective {
    /// Parse a CLI spelling (`tree` | `ring` | `two-phase`).
    pub fn parse(s: &str) -> Result<Collective> {
        match s {
            "tree" | "flat" | "pairwise" => Ok(Collective::Tree),
            "ring" => Ok(Collective::Ring),
            "two-phase" | "two_phase" | "twophase" | "hierarchical" => Ok(Collective::TwoPhase),
            other => anyhow::bail!("unknown collective {other:?} (tree|ring|two-phase)"),
        }
    }

    /// The collective's report/CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            Collective::Tree => "tree",
            Collective::Ring => "ring",
            Collective::TwoPhase => "two-phase",
        }
    }

    /// Every shipped collective, in inventory order.
    pub fn all() -> [Collective; 3] {
        [Collective::Tree, Collective::Ring, Collective::TwoPhase]
    }
}

/// The topology-aware reduction plan over `m` instance gradients under
/// collective `c`, where `node_of[k]` is the cluster node hosting instance
/// `k` (for the canonical groups≡nodes configuration this is
/// `InstanceGroups::group_of`). Every plan satisfies the same **shape
/// contract**, which is what lets the executor's fixed
/// `vec![None; m - 1]` node-slot arrays and the serial reference execute any
/// of them unchanged:
///
/// - exactly `m − 1` steps (empty for `m ≤ 1`);
/// - step `i` has `node == i`, and `GradSrc::Node(n)` operands only
///   reference earlier steps (`n < i`);
/// - every instance `0..m` appears as an operand exactly once;
/// - the **last** step (and only it) is marked `root` — the 1/M mean.
///
/// The step order is fully deterministic per `(c, m, node_of)`: bit-identity
/// with the serial reference follows from executing the *same* plan with the
/// same `model::params` primitives, not from any cross-plan equivalence
/// (IEEE-754 addition is commutative but not associative, so different
/// collectives legitimately disagree in the last bits).
pub fn collective_plan(c: Collective, m: usize, node_of: &[usize]) -> Vec<ReduceStep> {
    debug_assert!(node_of.len() >= m, "node_of must cover every instance");
    match c {
        Collective::Tree => reduce_plan(m),
        Collective::Ring => {
            if m <= 1 {
                return Vec::new();
            }
            // the partial sum hops 0 → 1 → … → m−1: step i runs on instance
            // i+1's device (the lhs) and pulls the running partial to it
            (0..m - 1)
                .map(|i| ReduceStep {
                    lhs: GradSrc::Inst(i + 1),
                    rhs: if i == 0 { GradSrc::Inst(0) } else { GradSrc::Node(i - 1) },
                    node: i,
                    root: i == m - 2,
                })
                .collect()
        }
        Collective::TwoPhase => {
            if m <= 1 {
                return Vec::new();
            }
            // phase 1: balanced pairwise inside each node (ascending node
            // id, instances ascending) — co-located, so these transfers are
            // free; each node is left holding one partial
            let mut nodes: Vec<usize> = node_of[..m].to_vec();
            nodes.sort_unstable();
            nodes.dedup();
            let mut steps: Vec<ReduceStep> = Vec::new();
            let mut next_node = 0usize;
            let mut partials: Vec<GradSrc> = Vec::with_capacity(nodes.len());
            for &nd in &nodes {
                let mut cur: Vec<GradSrc> =
                    (0..m).filter(|&k| node_of[k] == nd).map(GradSrc::Inst).collect();
                while cur.len() > 1 {
                    let mut nxt: Vec<GradSrc> = Vec::with_capacity((cur.len() + 1) / 2);
                    for pair in cur.chunks(2) {
                        if let [lhs, rhs] = *pair {
                            let node = next_node;
                            next_node += 1;
                            steps.push(ReduceStep { lhs, rhs, node, root: false });
                            nxt.push(GradSrc::Node(node));
                        } else {
                            nxt.push(pair[0]);
                        }
                    }
                    cur = nxt;
                }
                partials.push(cur[0]);
            }
            // phase 2: chain the node partials into the lowest node — one
            // inter-node hop per remote node, G − 1 total
            let mut acc = partials[0];
            for &p in &partials[1..] {
                let node = next_node;
                next_node += 1;
                steps.push(ReduceStep { lhs: acc, rhs: p, node, root: false });
                acc = GradSrc::Node(node);
            }
            if let Some(last) = steps.last_mut() {
                last.root = true;
            }
            steps
        }
    }
}

/// Does an `(instance, label, t_start, t_end)` event stream show hybrid
/// pipelining — instance k+1 **forward** work in flight while instance k
/// **adjoint/gradient** work runs? A barriered runtime (finish instance k,
/// then start instance k+1) can never produce such a pair. Shared by the
/// live-trace assertion, the virtual-time assertion, and the hybrid
/// experiment report, so the label taxonomy lives in exactly one place.
pub fn events_show_pipeline_overlap(events: &[(usize, &str, f64, f64)]) -> bool {
    fn is_backward(l: &str) -> bool {
        l.starts_with("adj_") || l == "param_grad"
    }
    fn is_forward(l: &str) -> bool {
        !l.starts_with("adj_")
            && !matches!(l, "param_grad" | "head" | "reduce_grad" | "param_update" | "comm")
    }
    events.iter().filter(|(_, l, _, _)| is_backward(l)).any(|&(k, _, b0, b1)| {
        events
            .iter()
            .any(|&(kf, lf, f0, f1)| kf == k + 1 && is_forward(lf) && f0 < b1 && f1 > b0)
    })
}

/// One executable V-cycle (level 0 downwards) with the given relaxation
/// pattern — the graph `ParallelMgrit` executes per MG iteration and the
/// building block of [`mg_forward`]. Per-step F-relaxation granularity.
pub fn mg_vcycle(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    relax: RelaxKind,
) -> TaskGraph {
    mg_vcycle_with(spec, hier, partition, batch, relax, Granularity::PerStep)
}

/// As [`mg_vcycle`] with an explicit F-relaxation granularity.
pub fn mg_vcycle_with(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    relax: RelaxKind,
    gran: Granularity,
) -> TaskGraph {
    let mut b = MgBuilder::new(spec, hier, partition, batch);
    b.gran = gran;
    b.vcycle(0, relax);
    b.g
}

/// The fine-level residual evaluation (all C-points > 0) used for the
/// convergence check between cycles. Comm-accounting tasks are included so
/// the live driver's traffic ledger matches the paper's MPI pattern.
pub fn residual_check(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
) -> TaskGraph {
    let mut b = MgBuilder::new(spec, hier, partition, batch);
    b.residual(0);
    b.g
}

/// MG forward propagation schedule: `cycles` V-cycles (the paper's FCF
/// configuration).
pub fn mg_forward(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    cycles: usize,
) -> TaskGraph {
    mg_forward_with(spec, hier, partition, batch, cycles, RelaxKind::FCF, Granularity::PerStep)
}

/// As [`mg_forward`] with explicit relaxation pattern and F-relaxation
/// granularity — the forward-only (fig6a-style) instance graph the serving
/// runtime admits per scheduling decision: `cycles` early-stopped primal
/// V-cycles, no head, no adjoint, no parameter work.
///
/// `batch` is the instance's **leading dimension**. For a shape-coalesced
/// admission (`serving::policy::ShapeBatch`) it is the summed row count of
/// the coalesced requests: every kernel's cost annotation then carries the
/// batched FLOPs while the *task count* — and with it the per-kernel launch
/// overhead the paper's concurrency argument centers on — stays that of a
/// single instance, which is exactly the amortization shape batching buys.
/// The live executor ignores the annotation (the real tensors set the
/// executed sizes); the simulator prices it.
#[allow(clippy::too_many_arguments)]
pub fn mg_forward_with(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    cycles: usize,
    relax: RelaxKind,
    gran: Granularity,
) -> TaskGraph {
    let mut b = MgBuilder::new(spec, hier, partition, batch);
    b.gran = gran;
    for _ in 0..cycles {
        b.vcycle(0, relax);
    }
    b.g
}

/// How a composed serving schedule admits request instances (the virtual-time
/// model of the live scheduler's admission loop; see `serving`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Continuous batching with `window` instances in flight: request k's
    /// root tasks depend on request k−window's sink tasks — a new instance is
    /// injected the moment the oldest in-flight one retires, with no
    /// generation barrier. `window ≥ n_requests` means fully concurrent.
    Continuous {
        /// Maximum instances in flight.
        window: usize,
    },
    /// Batch-barrier admission (the baseline serving loop): requests are
    /// grouped into waves of `wave` instances, and every instance of wave
    /// w+1 waits for *all* sinks of wave w — the classic batched-inference
    /// generation barrier.
    BatchBarrier {
        /// Instances per wave.
        wave: usize,
    },
}

/// `n_requests` independent forward-only inference instances composed into
/// one schedule, joined only by *admission edges* per `policy` — the
/// deterministic virtual-time model of the serving loop (the live runtime
/// admits instances dynamically through `coordinator::ExecSession` instead).
///
/// Each instance is a full [`mg_forward_with`] graph over its own state slots
/// (instance-tagged tasks, all sharing one device set). Under
/// [`Admission::Continuous`] the only cross-instance edges are
/// `roots(k) ← sinks(k − window)`, so request k+1's V-cycles overlap request
/// k's tail; under [`Admission::BatchBarrier`] every instance of a wave waits
/// for the whole previous wave.
#[allow(clippy::too_many_arguments)]
pub fn mg_serve(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    cycles: usize,
    relax: RelaxKind,
    gran: Granularity,
    n_requests: usize,
    policy: Admission,
) -> Result<TaskGraph> {
    anyhow::ensure!(n_requests >= 1, "need at least one request");
    match policy {
        Admission::Continuous { window } => {
            anyhow::ensure!(window >= 1, "continuous admission needs window ≥ 1")
        }
        Admission::BatchBarrier { wave } => {
            anyhow::ensure!(wave >= 1, "batch-barrier admission needs wave ≥ 1")
        }
    }
    let mut g = TaskGraph::default();
    // sink task ids (no dependents within their instance) per instance —
    // "instance complete" in the admission model means all sinks retired
    let mut sinks: Vec<Vec<usize>> = Vec::with_capacity(n_requests);
    for k in 0..n_requests {
        let sub = mg_forward_with(spec, hier, partition, batch, cycles, relax, gran);
        let n_sub = sub.tasks.len();
        let off = g.append_instance(sub, k, 0);
        // admission edges onto this instance's root tasks
        let root_deps: Vec<usize> = match policy {
            Admission::Continuous { window } if k >= window => sinks[k - window].clone(),
            Admission::BatchBarrier { wave } if k >= wave => {
                let prev_wave = (k / wave - 1) * wave;
                (prev_wave..prev_wave + wave)
                    .flat_map(|i| sinks[i].iter().copied())
                    .collect()
            }
            _ => Vec::new(),
        };
        if !root_deps.is_empty() {
            for t in &mut g.tasks[off..off + n_sub] {
                if t.deps.is_empty() {
                    t.deps = root_deps.clone();
                }
            }
        }
        // sinks: tasks of this instance no later task of the instance reads
        // (admission deps point before `off` and are skipped)
        let mut has_dependent = vec![false; n_sub];
        for t in &g.tasks[off..off + n_sub] {
            for &d in &t.deps {
                if d >= off {
                    has_dependent[d - off] = true;
                }
            }
        }
        sinks.push((0..n_sub).filter(|&i| !has_dependent[i]).map(|i| off + i).collect());
    }
    Ok(g)
}

/// The whole training step as **one** executable task graph, with no
/// inter-phase barriers:
///
/// 1. `cycles` forward V-cycles over the primal system;
/// 2. one head task (forward + VJP) on the device owning the last state,
///    whose output seeds every adjoint slot;
/// 3. `cycles` adjoint V-cycles over the reversed linear propagator Ψᵀ
///    (VJP steps ≈ 2× forward flops), each Ψ application carrying a RAW
///    edge to the forward state it linearizes around — so adjoint work on
///    late layers starts while early partitions still finish forward work;
/// 4. one `GradAccum` + one `ParamUpdate` task per layer, released the
///    moment that layer's λ slot retires — gradient work on late layers
///    overlaps adjoint relaxation on early layers.
///
/// The live executor and `sim::simulate` consume this identical graph.
/// Executed against `coordinator::MultiExecState::initial_train`, the result
/// is bit-identical to the serial step in `train::mg_step_serial`.
///
/// This is the single-instance (M = 1) case of [`mg_train_step_multi`].
pub fn mg_train_step(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    cycles: usize,
    relax: RelaxKind,
    gran: Granularity,
) -> TaskGraph {
    let groups = InstanceGroups::new(1, partition.n_devices())
        .expect("single-group instance map");
    mg_train_step_multi(spec, hier, partition, &groups, batch, cycles, relax, gran, 1)
        .expect("single-instance training graph")
}

/// One training-instance task set (forward cycles → head → adjoint cycles →
/// per-layer gradients) as a standalone single-instance graph, plus the id
/// of each layer's `GradAccum` task.
fn train_instance_tasks(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    cycles: usize,
    relax: RelaxKind,
    gran: Granularity,
) -> (TaskGraph, Vec<usize>) {
    let mut b = MgBuilder::new(spec, hier, partition, batch);
    b.gran = gran;
    for _ in 0..cycles {
        b.vcycle(0, relax);
    }
    b.head();
    b.sys = Sys::Adjoint;
    b.flop_scale = 2.0;
    for _ in 0..cycles {
        b.vcycle(0, relax);
    }
    b.sys = Sys::Primal;
    b.flop_scale = 1.0;
    b.grads();
    let n_layers = hier.fine().n_points - 1;
    let mut grad_ids = vec![usize::MAX; n_layers];
    for t in &b.g.tasks {
        if let Some(TaskOp::GradAccum { layer }) = t.op {
            grad_ids[layer] = t.id;
        }
    }
    debug_assert!(grad_ids.iter().all(|&i| i != usize::MAX));
    (b.g, grad_ids)
}

/// M micro-batch training instances composed into **one** executable graph —
/// hybrid data×layer parallelism:
///
/// - every instance is a full primal+adjoint `mg_train_step` pipeline over
///   its own state slots (instance-tagged tasks, device ids offset by the
///   instance's device group);
/// - per layer, a [`reduce_plan`] tree of [`TaskOp::ReduceGrad`] tasks joins
///   the M `GradAccum` outputs into the micro-batch mean gradient (the root
///   scales by 1/M), with explicit Comm tasks where the tree hops across
///   device groups;
/// - exactly one [`TaskOp::ParamUpdate`] per layer consumes the reduced
///   gradient (or the lone instance gradient when M = 1).
///
/// There is **no inter-instance barrier**: the only cross-instance edges are
/// the reduction-tree inputs, so micro-batch k+1's forward V-cycles overlap
/// micro-batch k's adjoint and gradient wave. `batch` is the per-micro-batch
/// size (the cost annotations of each instance's kernels).
#[allow(clippy::too_many_arguments)]
pub fn mg_train_step_multi(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    groups: &InstanceGroups,
    batch: usize,
    cycles: usize,
    relax: RelaxKind,
    gran: Granularity,
    micro_batches: usize,
) -> Result<TaskGraph> {
    let plan = reduce_plan(micro_batches);
    mg_train_step_multi_plan(
        spec,
        hier,
        partition,
        groups,
        batch,
        cycles,
        relax,
        gran,
        micro_batches,
        &plan,
    )
}

/// [`mg_train_step_multi`] with an explicit reduction `plan` (any
/// [`collective_plan`] output) instead of the default balanced pairwise
/// tree. The plan's [shape contract](collective_plan) is what the builder
/// relies on: `m − 1` steps, `node == step index`, backwards `Node` refs,
/// last step `root`. Endpoint placement follows the *runs-where-lhs-lives*
/// rule — each `ReduceGrad` executes on its left operand's device and the
/// right operand travels as an explicit `Comm` (elided when co-located) —
/// so the plan controls (src, dst) endpoints purely through operand
/// ordering.
#[allow(clippy::too_many_arguments)]
pub fn mg_train_step_multi_plan(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    groups: &InstanceGroups,
    batch: usize,
    cycles: usize,
    relax: RelaxKind,
    gran: Granularity,
    micro_batches: usize,
    plan: &[ReduceStep],
) -> Result<TaskGraph> {
    anyhow::ensure!(micro_batches >= 1, "need at least one micro-batch");
    anyhow::ensure!(
        plan.len() == micro_batches - 1,
        "reduction plan has {} steps but {} micro-batches need {}",
        plan.len(),
        micro_batches,
        micro_batches - 1
    );
    anyhow::ensure!(
        groups.devices_per_group() == partition.n_devices(),
        "instance groups sized for {} devices per group but the partition uses {}",
        groups.devices_per_group(),
        partition.n_devices()
    );
    let n_layers = hier.fine().n_points - 1;
    let mut g = TaskGraph::default();
    // grad_ids[k][layer] = graph-global id of instance k's GradAccum task
    let mut grad_ids: Vec<Vec<usize>> = Vec::with_capacity(micro_batches);
    for k in 0..micro_batches {
        let (sub, ids) = train_instance_tasks(spec, hier, partition, batch, cycles, relax, gran);
        let off = g.append_instance(sub, k, groups.device_offset(k));
        grad_ids.push(ids.into_iter().map(|i| i + off).collect());
    }
    // producer task + device of a reduction-tree operand
    fn src_of(
        src: GradSrc,
        layer: usize,
        grad_ids: &[Vec<usize>],
        node_tasks: &[(usize, usize)],
        g: &TaskGraph,
    ) -> (usize, usize) {
        match src {
            GradSrc::Inst(k) => {
                let id = grad_ids[k][layer];
                (id, g.tasks[id].device)
            }
            GradSrc::Node(n) => node_tasks[n],
        }
    }
    // the per-layer join: reduction plan + one ParamUpdate
    for layer in 0..n_layers {
        let grad_bytes = layer_cost(spec, layer, batch).param_bytes;
        let elems = grad_bytes / 4.0;
        // (task id, device) of each internal node, indexed by node id
        let mut node_tasks: Vec<(usize, usize)> = Vec::with_capacity(plan.len());
        let mut last: Option<(usize, usize)> = None;
        for step in plan {
            let (lhs_id, lhs_dev) = src_of(step.lhs, layer, &grad_ids, &node_tasks, &g);
            let (rhs_id, rhs_dev) = src_of(step.rhs, layer, &grad_ids, &node_tasks, &g);
            // the node runs where its left operand lives; a right operand on
            // another device (cross-group) travels as an explicit transfer
            let dst = lhs_dev;
            let mut deps = vec![lhs_id];
            match g.comm(rhs_dev, dst, grad_bytes, vec![rhs_id], Some(TaskOp::Xfer)) {
                Some(c) => deps.push(c),
                None => deps.push(rhs_id),
            }
            let t = g.kernel(
                dst,
                "reduce_grad",
                KernelClass::Light,
                2.0 * elems,
                dedup(deps),
                Some(TaskOp::ReduceGrad {
                    layer,
                    lhs: step.lhs,
                    rhs: step.rhs,
                    node: step.node,
                    root: step.root,
                }),
            );
            node_tasks.push((t, dst));
            last = Some((t, dst));
        }
        // M = 1: update straight off the lone instance gradient (PR 2 shape)
        let (dep, dev) = match last {
            Some((t, d)) => (t, d),
            None => {
                let id = grad_ids[0][layer];
                (id, g.tasks[id].device)
            }
        };
        g.kernel(
            dev,
            "param_update",
            KernelClass::Light,
            2.0 * elems,
            vec![dep],
            Some(TaskOp::ParamUpdate { layer }),
        );
    }
    Ok(g)
}

/// Cross-step synchronization policy of a pipelined multi-step training
/// graph (see [`mg_train_pipeline`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipeSync {
    /// Drain-to-idle between steps: every task of step t waits for ALL of
    /// step t−1's parameter updates — the barrier-synced baseline
    /// (sequential SGD semantics, no cross-step overlap).
    Barrier,
    /// Bounded-staleness pipelining: step t reads parameter version
    /// `max(0, t − S)`, and the only cross-step edges are
    /// `ParamUpdate(t − S − 1, slot)` → the *first reader* of that slot's
    /// parameters in each step-t instance, plus the per-slot `ParamUpdate`
    /// chain. `Staleness(0)` keeps sequential SGD semantics — bit-identical
    /// to the barrier and to K sequential `train_step_micro` calls — while
    /// already overlapping step t+1's forward wave with step t's gradient
    /// tail wherever the per-slot first-reader edges allow.
    Staleness(usize),
}

/// The **parameter slots** a task's payload reads: trunk layer indices
/// `0..n_layers`, the opening pair at slot `n_layers`, the head (FC) pair at
/// slot `n_layers + 1`. Mirrors exactly which `(w, b)` pairs the live
/// executor fetches at dispatch time for each op, so the pipeline composer
/// (which adds a staleness edge on the *first* reader of each slot per
/// instance) and the executor's versioned parameter reads cannot drift
/// apart. Ops that touch no parameters (corrections, reductions, transfers)
/// return an empty list; `ParamUpdate` is excluded on purpose — its base
/// read is version-chained explicitly by the composer.
pub fn op_param_slots(op: &TaskOp, hier: &Hierarchy, n_layers: usize) -> Vec<usize> {
    match *op {
        TaskOp::PointUpdate { sys, level, j } | TaskOp::Residual { sys, level, j } => {
            match sys {
                Sys::Primal => vec![hier.levels[level].theta_idx(j - 1)],
                Sys::Adjoint => vec![hier.adjoint_state_index(level, j)],
            }
        }
        TaskOp::BlockRun { sys, level, j_first, j_last } => (j_first..=j_last)
            .map(|j| match sys {
                Sys::Primal => hier.levels[level].theta_idx(j - 1),
                Sys::Adjoint => hier.adjoint_state_index(level, j),
            })
            .collect(),
        TaskOp::Restrict { sys, level, j } => match sys {
            Sys::Primal => vec![hier.levels[level + 1].theta_idx(j - 1)],
            Sys::Adjoint => vec![hier.adjoint_state_index(level + 1, j)],
        },
        TaskOp::GradAccum { layer } => vec![layer],
        TaskOp::Opening | TaskOp::OpenGrad => vec![n_layers],
        TaskOp::Head => vec![n_layers + 1],
        TaskOp::Correct { .. }
        | TaskOp::ReduceGrad { .. }
        | TaskOp::ParamUpdate { .. }
        | TaskOp::Xfer => Vec::new(),
    }
}

/// One **pipelined** training-instance task set — like `train_instance_tasks`
/// but with the opening layer and its VJP in-graph ([`TaskOp::Opening`] /
/// [`TaskOp::OpenGrad`]), since a pipelined step must evaluate them against
/// its own parameter *version* rather than a host-side snapshot. Returns the
/// sub-graph plus the gradient-producer task id per parameter slot
/// (`0..n_layers` trunk `GradAccum`s, `n_layers` the `OpenGrad`,
/// `n_layers + 1` the `Head`, whose VJP yields the FC pair).
fn pipeline_instance_tasks(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    batch: usize,
    cycles: usize,
    relax: RelaxKind,
    gran: Granularity,
) -> (TaskGraph, Vec<usize>) {
    let mut b = MgBuilder::new(spec, hier, partition, batch);
    b.gran = gran;
    b.opening();
    for _ in 0..cycles {
        b.vcycle(0, relax);
    }
    let head_id = b.head();
    b.sys = Sys::Adjoint;
    b.flop_scale = 2.0;
    for _ in 0..cycles {
        b.vcycle(0, relax);
    }
    b.sys = Sys::Primal;
    b.flop_scale = 1.0;
    b.grads();
    let og = b.open_grad();
    let n_layers = hier.fine().n_points - 1;
    let mut grad_ids = vec![usize::MAX; n_layers + 2];
    for t in &b.g.tasks {
        if let Some(TaskOp::GradAccum { layer }) = t.op {
            grad_ids[layer] = t.id;
        }
    }
    grad_ids[n_layers] = og;
    grad_ids[n_layers + 1] = head_id;
    debug_assert!(grad_ids.iter().all(|&i| i != usize::MAX));
    (b.g, grad_ids)
}

/// K consecutive training steps of M micro-batch instances each, composed
/// into **one** executable graph with **cross-step pipelining under bounded
/// staleness** — asynchronous SGD over the multi-instance runtime:
///
/// - every step is a full [`mg_train_step_multi`]-shaped sub-graph, except
///   that the opening layer and its VJP run *in-graph*
///   ([`TaskOp::Opening`] / [`TaskOp::OpenGrad`]) and the per-step join
///   reduces **all** `n_layers + 2` parameter slots (trunk layers, opening,
///   head) — one `ParamUpdate` per slot per step, producing parameter
///   version `t + 1` from version `t` and step t's mean gradient;
/// - step t's tasks read parameter version `max(0, t − S)` (the snapshot
///   ring of the live executor); under [`PipeSync::Staleness`] the only
///   cross-step edges are `ParamUpdate(t − S − 1, slot)` → the first reader
///   of that slot in each step-t instance — so step t+1's forward V-cycles
///   launch against the step-t snapshot while step t's adjoint/GradAccum/
///   ReduceGrad tail is still draining — plus the per-slot `ParamUpdate`
///   chain (update t needs version t's slot as its base);
/// - under [`PipeSync::Barrier`] every root task of step t instead waits for
///   all of step t−1's updates: the drain-to-idle baseline the pipelined
///   makespan is compared against.
///
/// Instance tags are global (`t·M + k`); join tasks of step t carry
/// `t·M`, so the executor recovers the step as `instance / M`. The whole
/// cross-step graph is planned by the placement pass as ONE plan and scored
/// by the simulator unchanged.
#[allow(clippy::too_many_arguments)]
pub fn mg_train_pipeline(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    groups: &InstanceGroups,
    batch: usize,
    cycles: usize,
    relax: RelaxKind,
    gran: Granularity,
    micro_batches: usize,
    steps: usize,
    sync: PipeSync,
) -> Result<TaskGraph> {
    let plan = reduce_plan(micro_batches);
    mg_train_pipeline_plan(
        spec,
        hier,
        partition,
        groups,
        batch,
        cycles,
        relax,
        gran,
        micro_batches,
        steps,
        sync,
        &plan,
    )
}

/// [`mg_train_pipeline`] with an explicit per-slot reduction `plan` (any
/// [`collective_plan`] output) — the same plan joins every parameter slot of
/// every step, so collective choice composes orthogonally with cross-step
/// pipelining. Placement follows the *runs-where-lhs-lives* rule described
/// on [`mg_train_step_multi_plan`].
#[allow(clippy::too_many_arguments)]
pub fn mg_train_pipeline_plan(
    spec: &NetSpec,
    hier: &Hierarchy,
    partition: &Partition,
    groups: &InstanceGroups,
    batch: usize,
    cycles: usize,
    relax: RelaxKind,
    gran: Granularity,
    micro_batches: usize,
    steps: usize,
    sync: PipeSync,
    plan: &[ReduceStep],
) -> Result<TaskGraph> {
    anyhow::ensure!(steps >= 1, "need at least one pipelined step");
    anyhow::ensure!(micro_batches >= 1, "need at least one micro-batch");
    anyhow::ensure!(
        plan.len() == micro_batches - 1,
        "reduction plan has {} steps but {} micro-batches need {}",
        plan.len(),
        micro_batches,
        micro_batches - 1
    );
    anyhow::ensure!(
        groups.devices_per_group() == partition.n_devices(),
        "instance groups sized for {} devices per group but the partition uses {}",
        groups.devices_per_group(),
        partition.n_devices()
    );
    let n_layers = hier.fine().n_points - 1;
    let n_slots = n_layers + 2;
    let mut g = TaskGraph::default();
    // pu_ids[t][slot] = graph-global id of step t's ParamUpdate for `slot`
    let mut pu_ids: Vec<Vec<usize>> = Vec::with_capacity(steps);
    fn src_of(
        src: GradSrc,
        slot: usize,
        grad_ids: &[Vec<usize>],
        node_tasks: &[(usize, usize)],
        g: &TaskGraph,
    ) -> (usize, usize) {
        match src {
            GradSrc::Inst(k) => {
                let id = grad_ids[k][slot];
                (id, g.tasks[id].device)
            }
            GradSrc::Node(n) => node_tasks[n],
        }
    }
    for t in 0..steps {
        // grad_ids[k][slot] = id of step-t instance k's slot-gradient producer
        let mut grad_ids: Vec<Vec<usize>> = Vec::with_capacity(micro_batches);
        for k in 0..micro_batches {
            let (sub, ids) =
                pipeline_instance_tasks(spec, hier, partition, batch, cycles, relax, gran);
            let n_sub = sub.tasks.len();
            let off = g.append_instance(sub, t * micro_batches + k, groups.device_offset(k));
            grad_ids.push(ids.into_iter().map(|i| i + off).collect());
            match sync {
                PipeSync::Barrier if t > 0 => {
                    // the drain-to-idle baseline: the instance's root tasks
                    // (the Opening is the only dependency-free task of a
                    // pipelined instance) wait for the whole previous step's
                    // parameter join
                    let root_deps: Vec<usize> = pu_ids[t - 1].clone();
                    for task in &mut g.tasks[off..off + n_sub] {
                        if task.deps.is_empty() {
                            task.deps = root_deps.clone();
                        }
                    }
                }
                PipeSync::Staleness(s) if t >= s + 1 => {
                    // version-gap edges: the FIRST reader of each parameter
                    // slot in this instance waits for ParamUpdate(t−s−1, slot)
                    // — every later same-slot reader is already ordered
                    // behind it through the instance's hazard frontier chains
                    let src = &pu_ids[t - s - 1];
                    let mut seen = vec![false; n_slots];
                    let mut extra: Vec<(usize, usize)> = Vec::new();
                    for task in &g.tasks[off..off + n_sub] {
                        if let Some(op) = &task.op {
                            for slot in op_param_slots(op, hier, n_layers) {
                                if !seen[slot] {
                                    seen[slot] = true;
                                    extra.push((task.id, src[slot]));
                                }
                            }
                        }
                    }
                    for (id, dep) in extra {
                        g.tasks[id].deps.push(dep);
                    }
                }
                _ => {}
            }
        }
        // step-t parameter join: per-slot reduction tree + one chained update
        let join_start = g.tasks.len();
        let mut step_pu = Vec::with_capacity(n_slots);
        for slot in 0..n_slots {
            let grad_bytes = if slot < n_layers {
                layer_cost(spec, slot, batch).param_bytes
            } else if slot == n_layers {
                opening_cost(spec, batch).param_bytes
            } else {
                head_cost(spec, batch).param_bytes
            };
            let elems = grad_bytes / 4.0;
            let mut node_tasks: Vec<(usize, usize)> = Vec::with_capacity(plan.len());
            let mut last: Option<(usize, usize)> = None;
            for step in plan {
                let (lhs_id, lhs_dev) = src_of(step.lhs, slot, &grad_ids, &node_tasks, &g);
                let (rhs_id, rhs_dev) = src_of(step.rhs, slot, &grad_ids, &node_tasks, &g);
                let dst = lhs_dev;
                let mut deps = vec![lhs_id];
                match g.comm(rhs_dev, dst, grad_bytes, vec![rhs_id], Some(TaskOp::Xfer)) {
                    Some(c) => deps.push(c),
                    None => deps.push(rhs_id),
                }
                let id = g.kernel(
                    dst,
                    "reduce_grad",
                    KernelClass::Light,
                    2.0 * elems,
                    dedup(deps),
                    Some(TaskOp::ReduceGrad {
                        layer: slot,
                        lhs: step.lhs,
                        rhs: step.rhs,
                        node: step.node,
                        root: step.root,
                    }),
                );
                node_tasks.push((id, dst));
                last = Some((id, dst));
            }
            let (dep, dev) = match last {
                Some((id, d)) => (id, d),
                None => {
                    let id = grad_ids[0][slot];
                    (id, g.tasks[id].device)
                }
            };
            // the per-slot version chain: update t consumes version t's slot
            // as its base, so it must follow update t−1 of the same slot
            let mut deps = vec![dep];
            if t > 0 {
                deps.push(pu_ids[t - 1][slot]);
            }
            let id = g.kernel(
                dev,
                "param_update",
                KernelClass::Light,
                2.0 * elems,
                dedup(deps),
                Some(TaskOp::ParamUpdate { layer: slot }),
            );
            step_pu.push(id);
        }
        // join tasks belong to step t: tag them with the step's first
        // instance so the executor recovers `step = instance / M`
        for task in &mut g.tasks[join_start..] {
            task.instance = t * micro_batches;
        }
        pu_ids.push(step_pu);
    }
    Ok(g)
}

/// Sequential forward propagation partitioned across devices — one long
/// dependency chain with a transfer at every partition boundary. With
/// n_devices == 1 this is the pure serial baseline; with > 1 it is the
/// paper's "Model Partitioned" (PM) layer-wise parallelism.
pub fn serial_forward(spec: &NetSpec, n_devices: usize, batch: usize) -> TaskGraph {
    let mut g = TaskGraph::default();
    let n = spec.n_res();
    let part = Partition::contiguous(n, n_devices).expect("partition");
    let mut prev: Option<usize> = None;
    let mut prev_dev = part.device_of(0);
    for i in 0..n {
        let dev = part.device_of(i);
        let mut deps: Vec<usize> = prev.into_iter().collect();
        if dev != prev_dev {
            if let Some(c) = g.comm(prev_dev, dev, state_bytes(spec, batch), deps.clone(), None) {
                deps = vec![c];
            }
        }
        let cost = layer_cost(spec, i, batch);
        let class = match spec.trunk[i] {
            crate::model::LayerKind::Conv { .. } => KernelClass::Conv,
            crate::model::LayerKind::Fc { .. } => KernelClass::Gemm,
        };
        prev = Some(g.kernel(dev, "serial_fwd", class, cost.flops, deps, None));
        prev_dev = dev;
    }
    g
}

/// Sequential training step (forward + backward chains) across devices —
/// the PM training baseline of Fig 6b.
pub fn serial_training(spec: &NetSpec, n_devices: usize, batch: usize) -> TaskGraph {
    let mut g = TaskGraph::default();
    let n = spec.n_res();
    let part = Partition::contiguous(n, n_devices).expect("partition");
    let bytes = state_bytes(spec, batch);
    let class_of = |i: usize| match spec.trunk[i] {
        crate::model::LayerKind::Conv { .. } => KernelClass::Conv,
        crate::model::LayerKind::Fc { .. } => KernelClass::Gemm,
    };
    // forward chain
    let mut prev: Option<usize> = None;
    let mut prev_dev = part.device_of(0);
    for i in 0..n {
        let dev = part.device_of(i);
        let mut deps: Vec<usize> = prev.into_iter().collect();
        if dev != prev_dev {
            if let Some(c) = g.comm(prev_dev, dev, bytes, deps.clone(), None) {
                deps = vec![c];
            }
        }
        prev = Some(g.kernel(dev, "fwd", class_of(i), layer_cost(spec, i, batch).flops, deps, None));
        prev_dev = dev;
    }
    // head (fwd + vjp)
    let head = crate::model::cost::head_cost(spec, batch);
    let last_dev = part.device_of(n - 1);
    let h1 = g.kernel(
        last_dev,
        "head",
        KernelClass::Gemm,
        3.0 * head.flops,
        prev.into_iter().collect(),
        None,
    );
    // backward chain
    let mut prev = h1;
    let mut prev_dev = last_dev;
    for i in (0..n).rev() {
        let dev = part.device_of(i);
        let mut deps = vec![prev];
        if dev != prev_dev {
            if let Some(c) = g.comm(prev_dev, dev, bytes, deps.clone(), None) {
                deps = vec![c];
            }
        }
        prev = g.kernel(dev, "bwd", class_of(i), layer_bwd_cost(spec, i, batch).flops, deps, None);
        prev_dev = dev;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n_res: usize, n_dev: usize) -> (NetSpec, Hierarchy, Partition) {
        let spec = NetSpec::fig6_depth(n_res);
        let hier = Hierarchy::two_level(n_res, spec.h(), spec.coarsen).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let partition = Partition::contiguous(n_blocks, n_dev).unwrap();
        (spec, hier, partition)
    }

    #[test]
    fn mg_forward_is_valid_dag() {
        let (spec, hier, part) = setup(64, 4);
        let g = mg_forward(&spec, &hier, &part, 1, 2);
        g.validate().unwrap();
        assert!(g.n_tasks() > 0);
        assert!(g.total_flops() > 0.0);
    }

    #[test]
    fn single_device_mg_has_no_comm() {
        let (spec, hier, part) = setup(64, 1);
        let g = mg_forward(&spec, &hier, &part, 1, 2);
        assert_eq!(g.total_comm_bytes(), 0.0);
    }

    #[test]
    fn multi_device_mg_comm_grows_with_devices() {
        let (spec, hier, _) = setup(256, 1);
        let mut prev = 0.0;
        for n_dev in [2usize, 4, 8, 16] {
            let n_blocks = hier.fine().blocks(hier.coarsen).len();
            let part = Partition::contiguous(n_blocks, n_dev).unwrap();
            let g = mg_forward(&spec, &hier, &part, 1, 2);
            let bytes = g.total_comm_bytes();
            assert!(bytes > prev, "n_dev={n_dev}: {bytes} <= {prev}");
            prev = bytes;
        }
    }

    #[test]
    fn mg_work_is_cycles_times_sweep_work() {
        let (spec, hier, part) = setup(64, 2);
        let g1 = mg_forward(&spec, &hier, &part, 1, 1);
        let g2 = mg_forward(&spec, &hier, &part, 1, 2);
        assert!((g2.total_flops() / g1.total_flops() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn forward_cycles_equal_repeated_vcycles() {
        // mg_forward is exactly `cycles` × mg_vcycle in work and traffic —
        // the invariant the per-cycle live driver relies on
        let (spec, hier, part) = setup(64, 4);
        let v = mg_vcycle(&spec, &hier, &part, 1, RelaxKind::FCF);
        let f2 = mg_forward(&spec, &hier, &part, 1, 2);
        assert_eq!(f2.n_tasks(), 2 * v.n_tasks());
        assert_eq!(f2.n_comms(), 2 * v.n_comms());
        assert!((f2.total_flops() - 2.0 * v.total_flops()).abs() < 1e-6);
    }

    #[test]
    fn executable_graphs_carry_payloads() {
        let (spec, hier, part) = setup(32, 2);
        let v = mg_vcycle(&spec, &hier, &part, 1, RelaxKind::FCF);
        v.validate().unwrap();
        assert!(v.tasks.iter().all(|t| t.op.is_some()), "every task needs a payload");
        // kernels and comms get the right payload kinds
        for t in &v.tasks {
            match (&t.kind, t.op.unwrap()) {
                (TaskKind::Comm { .. }, TaskOp::Xfer) => {}
                (TaskKind::Kernel { .. }, TaskOp::Xfer) => panic!("kernel with Xfer payload"),
                (TaskKind::Comm { .. }, _) => panic!("comm with kernel payload"),
                _ => {}
            }
        }
        let r = residual_check(&spec, &hier, &part, 1);
        assert!(r
            .tasks
            .iter()
            .all(|t| matches!(t.op, Some(TaskOp::Residual { .. }) | Some(TaskOp::Xfer))));
    }

    #[test]
    fn war_hazards_are_encoded() {
        // the final f_relax of a cycle rewrites F-points that the residual
        // phase reads: the writer must depend on the reader (WAR), or a
        // dependency-driven executor could corrupt the residual inputs
        let (spec, hier, part) = setup(16, 2);
        let g = mg_vcycle(&spec, &hier, &part, 1, RelaxKind::FCF);
        let residual_ids: Vec<usize> = g
            .tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Kernel { label: "residual", .. }))
            .map(|t| t.id)
            .collect();
        assert!(!residual_ids.is_empty());
        // some later f_relax task must list a residual task as a dep
        let war = g.tasks.iter().any(|t| {
            matches!(t.kind, TaskKind::Kernel { label: "f_relax", .. })
                && t.deps.iter().any(|d| residual_ids.contains(d))
        });
        assert!(war, "no WAR edge from final f_relax to the residual readers");
    }

    #[test]
    fn per_block_granularity_fuses_f_spans() {
        let (spec, hier, part) = setup(64, 4);
        let per_step = mg_vcycle_with(&spec, &hier, &part, 1, RelaxKind::FCF, Granularity::PerStep);
        let per_block =
            mg_vcycle_with(&spec, &hier, &part, 1, RelaxKind::FCF, Granularity::PerBlock);
        per_block.validate().unwrap();
        // fused: fewer tasks, same total work (to f64 reassociation) and
        // identical traffic
        assert!(per_block.n_tasks() < per_step.n_tasks());
        let rel =
            (per_block.total_flops() - per_step.total_flops()).abs() / per_step.total_flops();
        assert!(rel < 1e-12, "fused flop total drifted: {rel}");
        assert_eq!(per_block.n_comms(), per_step.n_comms());
        // fine-level F-relaxation tasks carry BlockRun payloads
        assert!(per_block
            .tasks
            .iter()
            .any(|t| matches!(t.op, Some(TaskOp::BlockRun { level: 0, .. }))));
        // a BlockRun covers a whole block's F-span
        let spans_ok = per_block.tasks.iter().all(|t| match t.op {
            Some(TaskOp::BlockRun { j_first, j_last, .. }) => j_first <= j_last,
            _ => true,
        });
        assert!(spans_ok);
    }

    #[test]
    fn serial_forward_flops_match_trunk() {
        let spec = NetSpec::fig6_depth(64);
        let g = serial_forward(&spec, 1, 1);
        let want = crate::model::cost::trunk_flops(&spec, 1);
        assert!((g.total_flops() - want).abs() / want < 1e-12);
        assert_eq!(g.total_comm_bytes(), 0.0);
        g.validate().unwrap();
    }

    #[test]
    fn pm_partitioned_has_boundary_comms() {
        let spec = NetSpec::fig6_depth(64);
        let g = serial_forward(&spec, 8, 1);
        assert_eq!(g.n_comms(), 7); // 7 partition boundaries
    }

    #[test]
    fn mg_does_more_flops_than_serial() {
        // MG is iterative: with 2 cycles it performs > 2x the serial work
        // (the paper's "4x slower on one GPU" effect)
        let (spec, hier, part) = setup(64, 1);
        let mg = mg_forward(&spec, &hier, &part, 1, 2);
        let serial = serial_forward(&spec, 1, 1);
        let ratio = mg.total_flops() / serial.total_flops();
        assert!(ratio > 2.0 && ratio < 10.0, "ratio {ratio}");
    }

    #[test]
    fn training_graph_has_param_grads_on_all_layers() {
        let (spec, hier, part) = setup(32, 2);
        let g = mg_train_step(&spec, &hier, &part, 1, 2, RelaxKind::FCF, Granularity::PerStep);
        g.validate().unwrap();
        assert_eq!(g.n_kernels_labeled("param_grad"), 32);
        assert_eq!(g.n_kernels_labeled("param_update"), 32);
        assert_eq!(g.n_kernels_labeled("head"), 1);
        // fully executable: the live DAG executor runs the whole step
        assert!(g.tasks.iter().all(|t| t.op.is_some()));
    }

    #[test]
    fn training_graph_adjoint_mirrors_forward_structure() {
        let (spec, hier, part) = setup(32, 2);
        let g = mg_train_step(&spec, &hier, &part, 1, 2, RelaxKind::FCF, Granularity::PerStep);
        // the adjoint system runs the same cycle phases as the forward one
        for (p, a) in [
            ("f_relax", "adj_f_relax"),
            ("c_relax", "adj_c_relax"),
            ("residual", "adj_residual"),
            ("restrict", "adj_restrict"),
            ("correct", "adj_correct"),
            ("coarse_solve", "adj_coarse_solve"),
        ] {
            assert_eq!(
                g.n_kernels_labeled(p),
                g.n_kernels_labeled(a),
                "phase {p} vs {a} task counts differ"
            );
        }
        // adjoint Φ applications cost ~2× their forward counterparts
        let sum = |label: &str| -> f64 {
            g.tasks
                .iter()
                .filter_map(|t| match &t.kind {
                    TaskKind::Kernel { label: l, flops, .. } if *l == label => Some(*flops),
                    _ => None,
                })
                .sum()
        };
        assert!((sum("adj_f_relax") / sum("f_relax") - 2.0).abs() < 1e-9);
    }

    #[test]
    fn training_graph_grads_depend_on_adjoint_not_on_a_barrier() {
        // every param_grad must depend on (transitively reach) adjoint work,
        // but NOT on every adjoint task — the no-barrier property at the
        // graph level: at least one param_grad has an id smaller than the
        // largest adjoint task id would allow under full serialization
        let (spec, hier, part) = setup(32, 2);
        let g = mg_train_step(&spec, &hier, &part, 1, 2, RelaxKind::FCF, Granularity::PerStep);
        let adj_ids: Vec<usize> = g
            .tasks
            .iter()
            .filter(|t| matches!(t.kind, TaskKind::Kernel { label, .. } if label.starts_with("adj_")))
            .map(|t| t.id)
            .collect();
        let max_adj = *adj_ids.iter().max().unwrap();
        for t in g.tasks.iter().filter(|t| matches!(t.op, Some(TaskOp::GradAccum { .. }))) {
            // direct deps only; must NOT include every adjoint task
            assert!(t.deps.len() < adj_ids.len(), "param_grad {} is barrier-like", t.id);
            assert!(t.id > max_adj, "grads are built after the adjoint phase");
        }
    }

    #[test]
    fn training_graph_per_block_variant_validates() {
        let (spec, hier, part) = setup(32, 2);
        let g = mg_train_step(&spec, &hier, &part, 1, 2, RelaxKind::FCF, Granularity::PerBlock);
        g.validate().unwrap();
        assert!(g.tasks.iter().all(|t| t.op.is_some()));
        assert!(g
            .tasks
            .iter()
            .any(|t| matches!(t.op, Some(TaskOp::BlockRun { sys: Sys::Adjoint, .. }))));
    }

    #[test]
    fn reduce_plan_shapes() {
        assert!(reduce_plan(0).is_empty());
        assert!(reduce_plan(1).is_empty());
        for m in 2..=9usize {
            let plan = reduce_plan(m);
            // pairwise reduction: m − 1 internal nodes, exactly one root (the last)
            assert_eq!(plan.len(), m - 1, "m={m}");
            assert_eq!(plan.iter().filter(|s| s.root).count(), 1);
            assert!(plan.last().unwrap().root);
            // every instance leaf consumed exactly once
            let mut inst_uses = vec![0usize; m];
            for s in &plan {
                for src in [s.lhs, s.rhs] {
                    if let GradSrc::Inst(k) = src {
                        inst_uses[k] += 1;
                    }
                }
            }
            assert!(inst_uses.iter().all(|&c| c == 1), "m={m}: {inst_uses:?}");
            // node operands always refer to earlier steps
            for (i, s) in plan.iter().enumerate() {
                for src in [s.lhs, s.rhs] {
                    if let GradSrc::Node(n) = src {
                        assert!(n < i, "step {i} reads future node {n}");
                    }
                }
                assert_eq!(s.node, i);
            }
        }
    }

    /// The [`collective_plan`] shape contract every collective must satisfy
    /// (see its doc): m − 1 steps, node == step index, backwards Node refs,
    /// every instance exactly once, last-and-only-last step root.
    fn assert_plan_contract(plan: &[ReduceStep], m: usize, ctx: &str) {
        assert_eq!(plan.len(), m.saturating_sub(1), "{ctx}");
        if m <= 1 {
            return;
        }
        assert_eq!(plan.iter().filter(|s| s.root).count(), 1, "{ctx}");
        assert!(plan.last().unwrap().root, "{ctx}");
        let mut inst_uses = vec![0usize; m];
        for (i, s) in plan.iter().enumerate() {
            assert_eq!(s.node, i, "{ctx}");
            for src in [s.lhs, s.rhs] {
                match src {
                    GradSrc::Inst(k) => inst_uses[k] += 1,
                    GradSrc::Node(n) => assert!(n < i, "{ctx}: step {i} reads future node {n}"),
                }
            }
        }
        assert!(inst_uses.iter().all(|&c| c == 1), "{ctx}: {inst_uses:?}");
    }

    /// The cluster node each step's output lands on under the
    /// runs-where-lhs-lives placement rule, plus the number of operand
    /// fetches that cross a node boundary (= inter-node gradient transfers).
    fn cross_node_hops(plan: &[ReduceStep], node_of: &[usize]) -> usize {
        let mut out_node: Vec<usize> = Vec::with_capacity(plan.len());
        let mut hops = 0usize;
        for s in plan {
            let node_of_src = |src: GradSrc, out: &[usize]| match src {
                GradSrc::Inst(k) => node_of[k],
                GradSrc::Node(n) => out[n],
            };
            let dst = node_of_src(s.lhs, &out_node);
            if node_of_src(s.rhs, &out_node) != dst {
                hops += 1;
            }
            out_node.push(dst);
        }
        hops
    }

    #[test]
    fn collective_plans_satisfy_contract_at_odd_m() {
        // satellite: non-power-of-two M across every collective and several
        // node shapes, plus determinism (two generations are identical)
        for m in [3usize, 5, 7] {
            for n_nodes in [1usize, 2, 3] {
                let node_of: Vec<usize> = (0..m).map(|k| k % n_nodes).collect();
                for c in Collective::all() {
                    let ctx = format!("{} m={m} nodes={n_nodes}", c.name());
                    let plan = collective_plan(c, m, &node_of);
                    assert_plan_contract(&plan, m, &ctx);
                    assert_eq!(plan, collective_plan(c, m, &node_of), "{ctx}: nondeterministic");
                }
            }
        }
    }

    #[test]
    fn collective_plan_contract_property() {
        use crate::util::proptest_lite as pt;
        pt::check("collective-plan-contract", |rng| {
            let m = pt::gen_usize(rng, 1, 12);
            let n_nodes = pt::gen_usize(rng, 1, 4);
            // arbitrary (not just round-robin) instance→node assignment
            let node_of: Vec<usize> = (0..m).map(|_| pt::gen_usize(rng, 0, n_nodes - 1)).collect();
            for c in Collective::all() {
                let ctx = format!("{} m={m} node_of={node_of:?}", c.name());
                let plan = collective_plan(c, m, &node_of);
                assert_plan_contract(&plan, m, &ctx);
                assert_eq!(plan, collective_plan(c, m, &node_of), "{ctx}: nondeterministic");
            }
        });
    }

    #[test]
    fn collective_plan_tree_is_reduce_plan_and_flat_two_phase_matches() {
        for m in 1..=8usize {
            let flat = vec![0usize; m];
            assert_eq!(collective_plan(Collective::Tree, m, &flat), reduce_plan(m));
            // one node ⇒ two-phase degenerates to the same balanced pairwise
            assert_eq!(collective_plan(Collective::TwoPhase, m, &flat), reduce_plan(m));
        }
    }

    #[test]
    fn two_phase_needs_exactly_one_hop_per_remote_node() {
        // M=4 round-robin over 2 nodes: the flat tree pairs (0,1) and (2,3)
        // across nodes (2 hops) while two-phase reduces inside each node
        // first and crosses once
        let node_of = [0usize, 1, 0, 1];
        assert_eq!(cross_node_hops(&collective_plan(Collective::Tree, 4, &node_of), &node_of), 2);
        assert_eq!(
            cross_node_hops(&collective_plan(Collective::TwoPhase, 4, &node_of), &node_of),
            1
        );
        // general law: two-phase crosses exactly (#occupied nodes − 1) times
        for m in [3usize, 5, 7, 8] {
            for n_nodes in [2usize, 3, 4] {
                let node_of: Vec<usize> = (0..m).map(|k| k % n_nodes).collect();
                let occupied = node_of.iter().collect::<std::collections::BTreeSet<_>>().len();
                let plan = collective_plan(Collective::TwoPhase, m, &node_of);
                assert_eq!(cross_node_hops(&plan, &node_of), occupied - 1, "m={m} g={n_nodes}");
            }
        }
    }

    #[test]
    fn collective_parse_names_roundtrip() {
        for c in Collective::all() {
            assert_eq!(Collective::parse(c.name()).unwrap(), c);
        }
        assert_eq!(Collective::parse("hierarchical").unwrap(), Collective::TwoPhase);
        assert!(Collective::parse("allreduce").is_err());
        assert_eq!(Collective::default(), Collective::Tree);
    }

    #[test]
    fn multi_instance_graph_composes_and_validates() {
        let (spec, hier, part) = setup(32, 2);
        let groups = crate::coordinator::InstanceGroups::new(1, part.n_devices()).unwrap();
        for m in [1usize, 2, 3, 4] {
            let g = mg_train_step_multi(
                &spec, &hier, &part, &groups, 1, 2, RelaxKind::FCF, Granularity::PerStep, m,
            )
            .unwrap();
            g.validate().unwrap();
            assert!(g.tasks.iter().all(|t| t.op.is_some()));
            // per instance: one head, 32 grads; joint: m−1 reduces and one
            // update per layer
            assert_eq!(g.n_kernels_labeled("head"), m);
            assert_eq!(g.n_kernels_labeled("param_grad"), 32 * m);
            assert_eq!(g.n_kernels_labeled("reduce_grad"), 32 * (m - 1));
            assert_eq!(g.n_kernels_labeled("param_update"), 32);
            // instance tags: every instance id < m appears; joint tasks are 0
            let max_inst = g.tasks.iter().map(|t| t.instance).max().unwrap();
            assert_eq!(max_inst, m - 1);
        }
    }

    #[test]
    fn multi_instance_m1_matches_single_instance_graph() {
        // the M = 1 composition is the PR 2 training graph: same task
        // multiset, same work, same traffic
        let (spec, hier, part) = setup(32, 2);
        let g1 = mg_train_step(&spec, &hier, &part, 1, 2, RelaxKind::FCF, Granularity::PerStep);
        let groups = crate::coordinator::InstanceGroups::new(1, part.n_devices()).unwrap();
        let gm = mg_train_step_multi(
            &spec, &hier, &part, &groups, 1, 2, RelaxKind::FCF, Granularity::PerStep, 1,
        )
        .unwrap();
        assert_eq!(g1.n_tasks(), gm.n_tasks());
        assert!((g1.total_flops() - gm.total_flops()).abs() < 1e-9);
        assert_eq!(g1.n_comms(), gm.n_comms());
        assert!(gm.tasks.iter().all(|t| t.instance == 0));
    }

    #[test]
    fn cross_instance_edges_only_enter_the_reduction_join() {
        // the no-inter-instance-barrier property at the graph level: a
        // task outside the reduction join never depends on another
        // instance's task
        let (spec, hier, part) = setup(32, 2);
        let groups = crate::coordinator::InstanceGroups::new(1, part.n_devices()).unwrap();
        let g = mg_train_step_multi(
            &spec, &hier, &part, &groups, 1, 2, RelaxKind::FCF, Granularity::PerStep, 4,
        )
        .unwrap();
        let is_join = |t: &Task| {
            matches!(
                t.op,
                Some(TaskOp::ReduceGrad { .. }) | Some(TaskOp::ParamUpdate { .. })
            ) || (matches!(t.op, Some(TaskOp::Xfer))
                && g.tasks.iter().any(|u| {
                    matches!(u.op, Some(TaskOp::ReduceGrad { .. })) && u.deps.contains(&t.id)
                }))
        };
        for t in &g.tasks {
            if is_join(t) {
                continue;
            }
            for &d in &t.deps {
                assert_eq!(
                    g.tasks[d].instance, t.instance,
                    "task {} (inst {}) depends on task {d} (inst {})",
                    t.id, t.instance, g.tasks[d].instance
                );
            }
        }
        // and the join really does join: some ReduceGrad has deps from
        // different instances
        let crosses = g.tasks.iter().any(|t| {
            matches!(t.op, Some(TaskOp::ReduceGrad { .. }))
                && t.deps
                    .iter()
                    .map(|&d| g.tasks[d].instance)
                    .collect::<std::collections::BTreeSet<_>>()
                    .len()
                    > 1
        });
        assert!(crosses, "reduction tree never joins instances");
    }

    #[test]
    fn device_groups_shift_instances_and_add_reduce_comms() {
        // 2 groups × 2 devices: instance 1 runs on devices 2..4, and the
        // per-layer reduction tree hops across groups through Comm tasks
        let (spec, hier, _) = setup(32, 2);
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let part = Partition::contiguous(n_blocks, 2).unwrap();
        let groups = crate::coordinator::InstanceGroups::new(2, part.n_devices()).unwrap();
        let g = mg_train_step_multi(
            &spec, &hier, &part, &groups, 1, 2, RelaxKind::FCF, Granularity::PerStep, 2,
        )
        .unwrap();
        g.validate().unwrap();
        let inst1_devs: std::collections::BTreeSet<usize> = g
            .tasks
            .iter()
            .filter(|t| t.instance == 1 && !is_reduce_side(t, &g))
            .map(|t| t.device)
            .collect();
        assert!(inst1_devs.iter().all(|&d| d >= 2), "instance 1 leaked into group 0: {inst1_devs:?}");
        // cross-group gradient hops are explicit transfers feeding ReduceGrad
        let reduce_comm = g.tasks.iter().any(|t| {
            matches!(t.kind, TaskKind::Comm { .. })
                && g.tasks.iter().any(|u| {
                    matches!(u.op, Some(TaskOp::ReduceGrad { .. })) && u.deps.contains(&t.id)
                })
        });
        assert!(reduce_comm, "no cross-group transfer in the reduction tree");
    }

    fn is_reduce_side(t: &Task, g: &TaskGraph) -> bool {
        matches!(
            t.op,
            Some(TaskOp::ReduceGrad { .. }) | Some(TaskOp::ParamUpdate { .. })
        ) || (matches!(t.kind, TaskKind::Comm { .. })
            && g.tasks.iter().any(|u| {
                matches!(u.op, Some(TaskOp::ReduceGrad { .. })) && u.deps.contains(&t.id)
            }))
    }

    #[test]
    fn serial_training_fwd_bwd_chain() {
        let spec = NetSpec::fig6_depth(16);
        let g = serial_training(&spec, 2, 1);
        g.validate().unwrap();
        let fwd: f64 = g
            .tasks
            .iter()
            .filter_map(|t| match &t.kind {
                TaskKind::Kernel { label: "fwd", flops, .. } => Some(*flops),
                _ => None,
            })
            .sum();
        let bwd: f64 = g
            .tasks
            .iter()
            .filter_map(|t| match &t.kind {
                TaskKind::Kernel { label: "bwd", flops, .. } => Some(*flops),
                _ => None,
            })
            .sum();
        assert!((bwd / fwd - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fig7_schedule_scales() {
        // the 2B-param preset: schedule generation must handle 4k+ layers
        let spec = NetSpec::fig7();
        let hier = Hierarchy::two_level(spec.n_res(), spec.h(), spec.coarsen).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let part = Partition::contiguous(n_blocks, 64).unwrap();
        let g = mg_forward(&spec, &hier, &part, 1, 2);
        g.validate().unwrap();
        assert!(g.n_tasks() > 10_000);
        assert!(g.total_comm_bytes() > 0.0);
    }

    #[test]
    fn serve_graph_composes_instances_with_admission_edges() {
        let (spec, hier, part) = setup(32, 2);
        for n in [1usize, 3, 8] {
            let g = mg_serve(
                &spec, &hier, &part, 1, 2, RelaxKind::FCF, Granularity::PerStep, n,
                Admission::Continuous { window: 2 },
            )
            .unwrap();
            g.validate().unwrap();
            // n forward-only instances: no training ops anywhere
            assert!(g.tasks.iter().all(|t| t.op.is_some()));
            assert!(!g.tasks.iter().any(|t| matches!(
                t.op,
                Some(TaskOp::Head)
                    | Some(TaskOp::GradAccum { .. })
                    | Some(TaskOp::ReduceGrad { .. })
                    | Some(TaskOp::ParamUpdate { .. })
            )));
            let max_inst = g.tasks.iter().map(|t| t.instance).max().unwrap();
            assert_eq!(max_inst, n - 1);
            let single = mg_forward(&spec, &hier, &part, 1, 2);
            assert_eq!(g.n_tasks(), n * single.n_tasks());
        }
    }

    #[test]
    fn serve_continuous_window_bounds_cross_instance_edges() {
        let (spec, hier, part) = setup(32, 2);
        let window = 2usize;
        let g = mg_serve(
            &spec, &hier, &part, 1, 1, RelaxKind::F, Granularity::PerStep, 5,
            Admission::Continuous { window },
        )
        .unwrap();
        // a cross-instance dep only ever points `window` instances back
        let mut crossing = 0usize;
        for t in &g.tasks {
            for &d in &t.deps {
                let di = g.tasks[d].instance;
                if di != t.instance {
                    assert_eq!(t.instance, di + window, "task {} crosses {} → {}", t.id, t.instance, di);
                    crossing += 1;
                }
            }
        }
        assert!(crossing > 0, "window admission produced no cross-instance edges");
        // a window covering every request leaves the instances independent
        let free = mg_serve(
            &spec, &hier, &part, 1, 1, RelaxKind::F, Granularity::PerStep, 5,
            Admission::Continuous { window: 5 },
        )
        .unwrap();
        assert!(free
            .tasks
            .iter()
            .all(|t| t.deps.iter().all(|&d| free.tasks[d].instance == t.instance)));
    }

    #[test]
    fn serve_barrier_waves_depend_on_whole_previous_wave() {
        let (spec, hier, part) = setup(32, 2);
        let g = mg_serve(
            &spec, &hier, &part, 1, 1, RelaxKind::F, Granularity::PerStep, 4,
            Admission::BatchBarrier { wave: 2 },
        )
        .unwrap();
        g.validate().unwrap();
        // wave 1 (instances 2, 3): each root reaches sinks of BOTH instance 0
        // and instance 1
        for inst in [2usize, 3] {
            let roots: Vec<&Task> = g
                .tasks
                .iter()
                .filter(|t| t.instance == inst && t.deps.iter().any(|&d| g.tasks[d].instance != inst))
                .collect();
            assert!(!roots.is_empty(), "instance {inst} has no admission edges");
            for r in &roots {
                let srcs: std::collections::BTreeSet<usize> = r
                    .deps
                    .iter()
                    .map(|&d| g.tasks[d].instance)
                    .filter(|&i| i != inst)
                    .collect();
                assert_eq!(srcs, [0usize, 1].into_iter().collect(), "task {}", r.id);
            }
        }
        // continuous admission is a strict subset of the barrier constraints:
        // fewer cross-instance edges
        let c = mg_serve(
            &spec, &hier, &part, 1, 1, RelaxKind::F, Granularity::PerStep, 4,
            Admission::Continuous { window: 2 },
        )
        .unwrap();
        let n_cross = |g: &TaskGraph| {
            g.tasks
                .iter()
                .flat_map(|t| t.deps.iter().map(move |&d| (t.instance, g.tasks[d].instance)))
                .filter(|(a, b)| a != b)
                .count()
        };
        assert!(n_cross(&c) < n_cross(&g), "{} vs {}", n_cross(&c), n_cross(&g));
    }

    #[test]
    fn forward_with_matches_forward_default() {
        let (spec, hier, part) = setup(64, 4);
        let a = mg_forward(&spec, &hier, &part, 1, 2);
        let b = mg_forward_with(&spec, &hier, &part, 1, 2, RelaxKind::FCF, Granularity::PerStep);
        assert_eq!(a.n_tasks(), b.n_tasks());
        assert!((a.total_flops() - b.total_flops()).abs() < 1e-9);
        assert_eq!(a.n_comms(), b.n_comms());
    }

    #[test]
    fn fig7_training_schedule_scales() {
        let spec = NetSpec::fig7();
        let hier = Hierarchy::two_level(spec.n_res(), spec.h(), spec.coarsen).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let part = Partition::contiguous(n_blocks, 64).unwrap();
        let g = mg_train_step(&spec, &hier, &part, 1, 2, RelaxKind::FCF, Granularity::PerStep);
        g.validate().unwrap();
        assert_eq!(g.n_kernels_labeled("param_grad"), spec.n_res());
    }

    #[test]
    fn op_param_slots_mirrors_executor_reads() {
        let spec = NetSpec::fig6_depth(8);
        let hier = Hierarchy::two_level(8, spec.h(), 4).unwrap();
        let n_layers = 8usize;
        let s = |op: &TaskOp| op_param_slots(op, &hier, n_layers);
        // primal fine point j applies Φ at layer j−1
        assert_eq!(s(&TaskOp::PointUpdate { sys: Sys::Primal, level: 0, j: 3 }), vec![2]);
        // adjoint point j applies Ψ at the reversed fine layer
        assert_eq!(
            s(&TaskOp::PointUpdate { sys: Sys::Adjoint, level: 0, j: 3 }),
            vec![hier.adjoint_state_index(0, 3)]
        );
        // coarse-level updates stride through the fine layers
        assert_eq!(s(&TaskOp::PointUpdate { sys: Sys::Primal, level: 1, j: 2 }), vec![4]);
        // restrict applies the COARSE Φ_H of level+1
        assert_eq!(s(&TaskOp::Restrict { sys: Sys::Primal, level: 0, j: 1 }), vec![0]);
        // fused spans list every layer of the span
        assert_eq!(
            s(&TaskOp::BlockRun { sys: Sys::Primal, level: 0, j_first: 1, j_last: 3 }),
            vec![0, 1, 2]
        );
        // non-trunk slots: opening at n_layers, head at n_layers + 1
        assert_eq!(s(&TaskOp::Opening), vec![n_layers]);
        assert_eq!(s(&TaskOp::OpenGrad), vec![n_layers]);
        assert_eq!(s(&TaskOp::Head), vec![n_layers + 1]);
        assert_eq!(s(&TaskOp::GradAccum { layer: 5 }), vec![5]);
        // parameter-free ops
        assert!(s(&TaskOp::Correct { sys: Sys::Primal, level: 0, j: 1 }).is_empty());
        assert!(s(&TaskOp::ParamUpdate { layer: 0 }).is_empty());
        assert!(s(&TaskOp::Xfer).is_empty());
    }

    #[test]
    fn pipeline_graph_composes_and_validates() {
        let (spec, hier, part) = setup(32, 2);
        let groups = crate::coordinator::InstanceGroups::new(1, part.n_devices()).unwrap();
        let n_slots = 32 + 2;
        for sync in [PipeSync::Barrier, PipeSync::Staleness(0), PipeSync::Staleness(1)] {
            let g = mg_train_pipeline(
                &spec, &hier, &part, &groups, 1, 2, RelaxKind::FCF, Granularity::PerStep,
                2, 2, sync,
            )
            .unwrap();
            g.validate().unwrap();
            assert!(g.tasks.iter().all(|t| t.op.is_some()));
            // K = 2 steps × M = 2 instances: per-instance stages ×4, joint
            // stages reduce ALL n_layers + 2 slots per step
            assert_eq!(g.n_kernels_labeled("opening"), 4, "{sync:?}");
            assert_eq!(g.n_kernels_labeled("open_grad"), 4);
            assert_eq!(g.n_kernels_labeled("head"), 4);
            assert_eq!(g.n_kernels_labeled("param_grad"), 32 * 4);
            assert_eq!(g.n_kernels_labeled("reduce_grad"), n_slots * 2);
            assert_eq!(g.n_kernels_labeled("param_update"), n_slots * 2);
            // global instance tags 0..K·M
            let max_inst = g.tasks.iter().map(|t| t.instance).max().unwrap();
            assert_eq!(max_inst, 3);
        }
    }

    #[test]
    fn pipeline_staleness_edges_bound_version_gap() {
        // S = 1, M = 1, K = 4: the only cross-step edges are ParamUpdate
        // chains (gap 1) and first-reader version-gap edges from step
        // t − S − 1 = t − 2 — and each step t ≥ 2 carries exactly one such
        // edge per parameter slot
        let (spec, hier, part) = setup(32, 2);
        let groups = crate::coordinator::InstanceGroups::new(1, part.n_devices()).unwrap();
        let n_slots = 32 + 2;
        let g = mg_train_pipeline(
            &spec, &hier, &part, &groups, 1, 2, RelaxKind::FCF, Granularity::PerStep,
            1, 4, PipeSync::Staleness(1),
        )
        .unwrap();
        g.validate().unwrap();
        let mut gap_edges = vec![0usize; 4];
        for t in &g.tasks {
            let step = t.instance; // M = 1
            for &d in &t.deps {
                let dstep = g.tasks[d].instance;
                if dstep == step {
                    continue;
                }
                assert!(
                    matches!(g.tasks[d].op, Some(TaskOp::ParamUpdate { .. })),
                    "cross-step dep {} → {} is not a ParamUpdate",
                    t.id,
                    d
                );
                if matches!(t.op, Some(TaskOp::ParamUpdate { .. })) {
                    assert_eq!(step, dstep + 1, "update chain must link adjacent versions");
                } else {
                    assert_eq!(step, dstep + 2, "version-gap edge must span S + 1 steps");
                    gap_edges[step] += 1;
                }
            }
        }
        assert_eq!(gap_edges, vec![0, 0, n_slots, n_slots]);
    }

    #[test]
    fn pipeline_s0_serializes_readers_behind_previous_update() {
        // S = 0: step t's first reader of every slot waits for step t−1's
        // update of that slot — sequential SGD semantics with per-slot
        // (not whole-step) release
        let (spec, hier, part) = setup(32, 2);
        let groups = crate::coordinator::InstanceGroups::new(1, part.n_devices()).unwrap();
        let g = mg_train_pipeline(
            &spec, &hier, &part, &groups, 1, 2, RelaxKind::FCF, Granularity::PerStep,
            1, 2, PipeSync::Staleness(0),
        )
        .unwrap();
        let gap: Vec<(usize, usize)> = g
            .tasks
            .iter()
            .filter(|t| !matches!(t.op, Some(TaskOp::ParamUpdate { .. })))
            .flat_map(|t| {
                t.deps
                    .iter()
                    .filter(|&&d| g.tasks[d].instance != t.instance)
                    .map(move |&d| (t.instance, g.tasks[d].instance))
            })
            .collect();
        assert_eq!(gap.len(), 32 + 2);
        assert!(gap.iter().all(|&(a, b)| a == 1 && b == 0));
        // the edges land at the slot's first USE, not all on the root: step
        // 1's Opening waits for exactly ONE step-0 update (its own slot) —
        // under the barrier baseline it waits for ALL of them
        let cross_deps_of_opening = |g: &TaskGraph| {
            g.tasks
                .iter()
                .find(|t| matches!(t.op, Some(TaskOp::Opening)) && t.instance == 1)
                .map(|t| {
                    t.deps.iter().filter(|&&d| g.tasks[d].instance == 0).count()
                })
                .unwrap()
        };
        assert_eq!(cross_deps_of_opening(&g), 1);
        let bar = mg_train_pipeline(
            &spec, &hier, &part, &groups, 1, 2, RelaxKind::FCF, Granularity::PerStep,
            1, 2, PipeSync::Barrier,
        )
        .unwrap();
        assert_eq!(cross_deps_of_opening(&bar), 32 + 2);
    }
}
