//! Discrete-event multi-GPU cluster simulator — the substitute substrate for
//! the paper's TX-GAIA testbed. It executes the *real* schedule DAGs emitted
//! by `mgrit::taskgraph` (the same phase structure the live coordinator
//! runs) against the `perfmodel` device/network costs:
//!
//! - each device runs up to `max_concurrency` kernels at once (CUDA-stream
//!   concurrency, Fig 5) under processor sharing — co-resident kernels split
//!   the device's throughput, which is exactly the register-pressure
//!   serialization the paper observes for convolutions;
//! - each transfer occupies the source and destination NICs for
//!   latency + bytes/bandwidth (host-staged MPI over 25 GbE).
//!
//! Outputs: makespan, per-device busy time, total comm time, and a kernel
//! timeline trace (the nvprof analogue used for Fig 5).

pub mod engine;
pub mod timeline;

pub use engine::{
    simulate, simulate_prioritized, simulate_released, SimReport, SimSession, SimTraceEvent,
};
