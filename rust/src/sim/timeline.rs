//! Render a simulated (or live) kernel trace as an ASCII timeline — the
//! repo's answer to the paper's nvprof screenshot (Fig 5). Also exports the
//! trace as CSV for plotting.

use std::fmt::Write as _;

use super::engine::SimTraceEvent;

/// ASCII timeline of one device's kernel slots over `[t0, t1]`, one row per
/// stream slot, `width` characters wide. `#` marks kernel occupancy, `.`
/// idle; a final row marks comm activity touching the device.
pub fn ascii_timeline(
    trace: &[SimTraceEvent],
    device: usize,
    t0: f64,
    t1: f64,
    width: usize,
) -> String {
    assert!(t1 > t0 && width > 0);
    let n_slots = trace
        .iter()
        .filter(|e| e.device == device && !e.is_comm)
        .map(|e| e.slot + 1)
        .max()
        .unwrap_or(1);
    let mut rows = vec![vec![b'.'; width]; n_slots + 1];
    let col = |t: f64| -> usize {
        (((t - t0) / (t1 - t0) * width as f64).floor() as isize).clamp(0, width as isize - 1)
            as usize
    };
    for e in trace.iter().filter(|e| e.device == device) {
        if e.t_end < t0 || e.t_start > t1 || e.t_end.is_nan() {
            continue;
        }
        let (a, b) = (col(e.t_start.max(t0)), col(e.t_end.min(t1)));
        let row = if e.is_comm { n_slots } else { e.slot };
        let ch = if e.is_comm { b'~' } else { b'#' };
        for c in &mut rows[row][a..=b] {
            *c = ch;
        }
    }
    let mut out = String::new();
    let _ = writeln!(
        out,
        "device {device}  t = [{:.3} ms, {:.3} ms]",
        t0 * 1e3,
        t1 * 1e3
    );
    for (i, row) in rows.iter().enumerate() {
        let label = if i < n_slots { format!("stream {i}") } else { "comm    ".into() };
        let _ = writeln!(out, "  {label} |{}|", String::from_utf8_lossy(row));
    }
    out
}

/// CSV export: device,slot,label,is_comm,t_start,t_end.
pub fn trace_csv(trace: &[SimTraceEvent]) -> String {
    let mut out = String::from("device,slot,label,is_comm,t_start_s,t_end_s\n");
    for e in trace {
        let _ = writeln!(
            out,
            "{},{},{},{},{:.9},{:.9}",
            e.device, e.slot, e.label, e.is_comm as u8, e.t_start, e.t_end
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(device: usize, slot: usize, t0: f64, t1: f64, is_comm: bool) -> SimTraceEvent {
        SimTraceEvent {
            task: 0,
            device,
            slot,
            label: if is_comm { "comm" } else { "k" },
            is_comm,
            t_start: t0,
            t_end: t1,
        }
    }

    #[test]
    fn ascii_shows_occupancy() {
        let trace = vec![ev(0, 0, 0.0, 0.5, false), ev(0, 1, 0.25, 0.75, false)];
        let s = ascii_timeline(&trace, 0, 0.0, 1.0, 20);
        assert!(s.contains("stream 0 |##########"));
        assert!(s.contains("stream 1"));
        // slot 1 row: starts idle then kernels
        let line1 = s.lines().find(|l| l.contains("stream 1")).unwrap();
        assert!(line1.contains(".####"));
    }

    #[test]
    fn comm_row_uses_tilde() {
        let trace = vec![ev(0, 0, 0.0, 0.2, false), ev(0, 0, 0.4, 0.6, true)];
        let s = ascii_timeline(&trace, 0, 0.0, 1.0, 10);
        assert!(s.contains('~'));
    }

    #[test]
    fn other_devices_filtered() {
        let trace = vec![ev(1, 0, 0.0, 1.0, false)];
        let s = ascii_timeline(&trace, 0, 0.0, 1.0, 10);
        assert!(!s.contains('#'));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let trace = vec![ev(0, 2, 0.1, 0.2, false)];
        let csv = trace_csv(&trace);
        let mut lines = csv.lines();
        assert_eq!(lines.next().unwrap(), "device,slot,label,is_comm,t_start_s,t_end_s");
        assert!(lines.next().unwrap().starts_with("0,2,k,0,"));
    }
}
