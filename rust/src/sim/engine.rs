//! The event loop: executes a [`TaskGraph`] in virtual time on a
//! [`ClusterModel`].

use std::cmp::Ordering;
use std::collections::{BinaryHeap, VecDeque};

use anyhow::bail;

use crate::mgrit::taskgraph::{TaskGraph, TaskKind};
use crate::perfmodel::{ClusterModel, LinkTier};
use crate::Result;

/// One executed kernel or transfer (virtual-time nvprof line).
#[derive(Debug, Clone)]
pub struct SimTraceEvent {
    /// Graph task id — join back to `graph.tasks[task]` for the payload and
    /// the instance tag (cross-instance overlap assertions).
    pub task: usize,
    /// Device the task ran on (destination device for comms).
    pub device: usize,
    /// Stream slot on the device (0..max_concurrency); comms use slot 0.
    pub slot: usize,
    /// Phase label (`comm` for transfers).
    pub label: &'static str,
    /// Whether this event is a transfer rather than a kernel.
    pub is_comm: bool,
    /// Virtual start time (seconds).
    pub t_start: f64,
    /// Virtual end time (seconds).
    pub t_end: f64,
}

/// Result of one simulated run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// End-to-end virtual time (seconds).
    pub makespan_s: f64,
    /// Per-device union-of-kernel-intervals (compute-occupied seconds).
    pub device_busy_s: Vec<f64>,
    /// Sum of transfer durations (seconds of NIC occupancy, one-sided) —
    /// always `comm_intra_s + comm_inter_s`.
    pub comm_total_s: f64,
    /// Intra-node share of `comm_total_s` (same-node, cross-device hops;
    /// 0 on a flat one-device-per-node topology).
    pub comm_intra_s: f64,
    /// Inter-node share of `comm_total_s` (hops across a node boundary).
    pub comm_inter_s: f64,
    /// Bytes moved across node boundaries — the quantity the hierarchical
    /// two-phase collective exists to cut.
    pub cross_node_bytes: f64,
    /// Kernel tasks executed.
    pub n_kernels: usize,
    /// Transfers executed.
    pub n_comms: usize,
    /// Kernel/transfer timeline (only if `record_trace` was set).
    pub trace: Vec<SimTraceEvent>,
}

impl SimReport {
    /// Mean device compute occupancy in [0, 1].
    pub fn compute_fraction(&self) -> f64 {
        if self.makespan_s <= 0.0 || self.device_busy_s.is_empty() {
            return 0.0;
        }
        let mean_busy: f64 =
            self.device_busy_s.iter().sum::<f64>() / self.device_busy_s.len() as f64;
        mean_busy / self.makespan_s
    }

    /// 1 − compute fraction: the share of wall time a mean device spends
    /// stalled (communication + dependency waits) — the quantity behind the
    /// paper's "97 % of evaluation time consumed by communication" (Fig 6c).
    pub fn stall_fraction(&self) -> f64 {
        1.0 - self.compute_fraction()
    }

    /// Peak kernel concurrency observed on one device (Fig 5's "5-way").
    pub fn peak_concurrency(&self, device: usize) -> usize {
        let mut edges: Vec<(f64, i64)> = Vec::new();
        for e in self.trace.iter().filter(|e| !e.is_comm && e.device == device) {
            edges.push((e.t_start, 1));
            edges.push((e.t_end, -1));
        }
        edges.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut cur = 0i64;
        let mut peak = 0i64;
        for (_, d) in edges {
            cur += d;
            peak = peak.max(cur);
        }
        peak as usize
    }
}

struct RunningKernel {
    task: usize,
    /// Remaining launch/driver overhead (seconds). Launches on different
    /// stream slots proceed concurrently — the latency hiding that CUDA
    /// streams provide and the paper's concurrency argument relies on.
    launch_rem: f64,
    /// Remaining compute (exclusive-execution seconds); co-resident kernels
    /// in their compute phase share the device throughput (the paper's
    /// register-pressure serialization of convolutions).
    compute_rem: f64,
    slot: usize,
    trace_idx: Option<usize>,
}

impl RunningKernel {
    fn done(&self) -> bool {
        self.launch_rem <= 1e-12 && self.compute_rem <= 1e-12
    }
}

/// Per-tier NIC occupancy plus the transfer ledger, shared by the batch
/// engine ([`simulate`]) and the incremental [`SimSession`]: intra-node
/// transfers occupy per-device intra-link slots, inter-node transfers the
/// per-device fabric NICs — so same-node traffic no longer serializes
/// against cross-node traffic touching the same endpoint device.
#[derive(Debug)]
struct CommState {
    /// When each device's intra-node link is next free.
    intra_free: Vec<f64>,
    /// When each device's inter-node fabric NIC is next free.
    inter_free: Vec<f64>,
    intra_s: f64,
    inter_s: f64,
    cross_node_bytes: f64,
    n_comms: usize,
}

impl CommState {
    fn new(n_devices: usize) -> CommState {
        CommState {
            intra_free: vec![0.0; n_devices],
            inter_free: vec![0.0; n_devices],
            intra_s: 0.0,
            inter_s: 0.0,
            cross_node_bytes: 0.0,
            n_comms: 0,
        }
    }

    fn total_s(&self) -> f64 {
        self.intra_s + self.inter_s
    }

    /// Price and book one src ≠ dst transfer starting no earlier than `t`
    /// on its tier's NIC pair; returns (start, end).
    fn book(
        &mut self,
        cluster: &ClusterModel,
        src: usize,
        dst: usize,
        bytes: f64,
        t: f64,
    ) -> (f64, f64) {
        let tier = cluster.topo.tier(src, dst);
        let nic = match tier {
            LinkTier::Intra => &mut self.intra_free,
            LinkTier::Inter => &mut self.inter_free,
        };
        let start = t.max(nic[src]).max(nic[dst]);
        let dur = cluster.message_time(src, dst, bytes);
        nic[src] = start + dur;
        nic[dst] = start + dur;
        match tier {
            LinkTier::Intra => self.intra_s += dur,
            LinkTier::Inter => {
                self.inter_s += dur;
                self.cross_node_bytes += bytes;
            }
        }
        self.n_comms += 1;
        (start, start + dur)
    }
}

/// One entry of a device's prioritized ready queue: highest placement
/// dispatch priority pops first, ties break FIFO by per-device arrival
/// order (`seq`) — so the default all-zero priorities reproduce the legacy
/// FIFO queue bit-for-bit. Note the tie-break differs from the live
/// executor's global min-id heap on purpose: each models its own
/// substrate's legacy order (per-device stream queue vs one scheduler
/// thread), and a `Placement`'s priorities — not the tie-break — carry the
/// policy's decisions across both.
#[derive(Debug, Clone, Copy)]
struct ReadyEntry {
    pri: f64,
    seq: u64,
    task: usize,
}

impl PartialEq for ReadyEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for ReadyEntry {}

impl PartialOrd for ReadyEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ReadyEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        self.pri.total_cmp(&other.pri).then_with(|| other.seq.cmp(&self.seq))
    }
}

struct Device {
    running: Vec<RunningKernel>,
    ready: BinaryHeap<ReadyEntry>,
    next_seq: u64,
    slots: Vec<bool>,
    last_update: f64,
    busy_s: f64,
    busy_since: f64,
}

impl Device {
    fn new(max_conc: usize) -> Device {
        Device {
            running: Vec::new(),
            ready: BinaryHeap::new(),
            next_seq: 0,
            slots: vec![false; max_conc],
            last_update: 0.0,
            busy_s: 0.0,
            busy_since: 0.0,
        }
    }

    /// Enqueue a ready kernel at `pri` (FIFO among equal priorities).
    fn push_ready(&mut self, task: usize, pri: f64) {
        self.ready.push(ReadyEntry { pri, seq: self.next_seq, task });
        self.next_seq += 1;
    }

    /// Advance progress to time `t`: launch phases elapse concurrently;
    /// kernels past their launch share the compute throughput.
    fn advance(&mut self, t: f64) {
        let dt = (t - self.last_update).max(0.0);
        if dt > 0.0 && !self.running.is_empty() {
            let n_compute = self.running.iter().filter(|k| k.launch_rem <= 1e-12).count();
            for k in &mut self.running {
                if k.launch_rem > 1e-12 {
                    k.launch_rem -= dt;
                } else if n_compute > 0 {
                    k.compute_rem -= dt / n_compute as f64;
                }
            }
        }
        self.last_update = t;
    }

    /// Predicted time of this device's next state change (a launch phase
    /// ending, or a kernel completing its compute).
    fn next_completion(&self) -> f64 {
        if self.running.is_empty() {
            return f64::INFINITY;
        }
        let n_compute = self.running.iter().filter(|k| k.launch_rem <= 1e-12).count();
        let mut t = f64::INFINITY;
        for k in &self.running {
            let cand = if k.launch_rem > 1e-12 {
                k.launch_rem
            } else {
                k.compute_rem.max(0.0) * n_compute as f64
            };
            t = t.min(cand);
        }
        self.last_update + t
    }
}

/// Execute `graph` on `cluster` in virtual time.
pub fn simulate(graph: &TaskGraph, cluster: &ClusterModel, record_trace: bool) -> Result<SimReport> {
    simulate_core(graph, cluster, record_trace, &[], None)
}

/// As [`simulate`], with **per-task dispatch priorities** — the virtual-time
/// consumer of a `coordinator::placement::Placement`: when several kernels
/// are ready on one device, the highest-priority one takes the next free
/// stream slot (FIFO among equals). `None` (and all-equal priorities)
/// reproduces [`simulate`] exactly. Pair with the placement-rewritten graph:
/// `simulate_prioritized(&p.graph, &cluster, false, Some(&p.priority))`.
pub fn simulate_prioritized(
    graph: &TaskGraph,
    cluster: &ClusterModel,
    record_trace: bool,
    priority: Option<&[f64]>,
) -> Result<SimReport> {
    simulate_core(graph, cluster, record_trace, &[], priority)
}

/// As [`simulate`], with **per-instance release times**: a task of instance
/// `k` never dispatches before `release[k]` seconds of virtual time, even if
/// its dependencies are satisfied earlier. This is how the serving timeline
/// models request *arrivals*: instance k is request k, `release[k]` its
/// arrival time, and the admission edges of `mgrit::taskgraph::mg_serve`
/// model the scheduler's in-flight window. Instances beyond `release.len()`
/// (and an empty slice — the [`simulate`] default) release at t = 0.
pub fn simulate_released(
    graph: &TaskGraph,
    cluster: &ClusterModel,
    record_trace: bool,
    release: &[f64],
) -> Result<SimReport> {
    simulate_core(graph, cluster, record_trace, release, None)
}

/// The shared engine behind [`simulate`], [`simulate_released`], and
/// [`simulate_prioritized`]: release times gate dispatch, priorities order
/// each device's ready queue.
fn simulate_core(
    graph: &TaskGraph,
    cluster: &ClusterModel,
    record_trace: bool,
    release: &[f64],
    priority: Option<&[f64]>,
) -> Result<SimReport> {
    let n = graph.tasks.len();
    if let Some(p) = priority {
        if p.len() != n {
            bail!("priority slice has {} entries for a {n}-task graph", p.len());
        }
    }
    if n == 0 {
        return Ok(SimReport {
            makespan_s: 0.0,
            device_busy_s: vec![0.0; cluster.n_devices],
            comm_total_s: 0.0,
            comm_intra_s: 0.0,
            comm_inter_s: 0.0,
            cross_node_bytes: 0.0,
            n_kernels: 0,
            n_comms: 0,
            trace: Vec::new(),
        });
    }
    // dependency bookkeeping
    let mut indeg = vec![0usize; n];
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for t in &graph.tasks {
        if t.device >= cluster.n_devices {
            bail!("task {} targets device {} ≥ n_devices {}", t.id, t.device, cluster.n_devices);
        }
        indeg[t.id] = t.deps.len();
        for &d in &t.deps {
            dependents[d].push(t.id);
        }
    }

    let max_conc = cluster.device.max_concurrency;
    let mut devices: Vec<Device> = (0..cluster.n_devices).map(|_| Device::new(max_conc)).collect();
    let mut cs = CommState::new(cluster.n_devices);
    // in-flight comms: (t_end, task id)
    let mut comms: Vec<(f64, usize)> = Vec::new();
    let mut trace: Vec<SimTraceEvent> = Vec::new();
    let mut n_kernels = 0usize;
    let mut done = 0usize;
    let mut now = 0.0f64;

    // schedule one task whose deps are all satisfied
    #[allow(clippy::too_many_arguments)]
    fn dispatch(
        task_id: usize,
        t: f64,
        graph: &TaskGraph,
        cluster: &ClusterModel,
        devices: &mut [Device],
        cs: &mut CommState,
        comms: &mut Vec<(f64, usize)>,
        trace: &mut Vec<SimTraceEvent>,
        record_trace: bool,
        priority: Option<&[f64]>,
    ) {
        let task = &graph.tasks[task_id];
        match &task.kind {
            TaskKind::Kernel { .. } => {
                let pri = priority.map_or(0.0, |p| p[task_id]);
                devices[task.device].push_ready(task_id, pri);
            }
            TaskKind::Comm { src, dst, bytes } => {
                if src == dst {
                    // co-located endpoints (a placement rewrite): the
                    // transfer degenerates to a local handoff — zero time,
                    // no NIC occupancy, not counted in the comm ledger
                    comms.push((t, task_id));
                    return;
                }
                let (start, end) = cs.book(cluster, *src, *dst, *bytes, t);
                comms.push((end, task_id));
                if record_trace {
                    trace.push(SimTraceEvent {
                        task: task_id,
                        device: *dst,
                        slot: 0,
                        label: "comm",
                        is_comm: true,
                        t_start: start,
                        t_end: end,
                    });
                }
            }
        }
    }

    // start ready kernels on a device (after advancing it to `t`)
    fn fill_slots(
        d: usize,
        t: f64,
        graph: &TaskGraph,
        cluster: &ClusterModel,
        devices: &mut [Device],
        trace: &mut Vec<SimTraceEvent>,
        n_kernels: &mut usize,
        record_trace: bool,
    ) {
        let dev = &mut devices[d];
        while dev.running.len() < dev.slots.len() && !dev.ready.is_empty() {
            dev.advance(t);
            let task_id = dev.ready.pop().unwrap().task;
            let TaskKind::Kernel { label, class, flops } = &graph.tasks[task_id].kind else {
                unreachable!("ready queue holds kernels only");
            };
            let slot = dev.slots.iter().position(|s| !s).unwrap();
            dev.slots[slot] = true;
            if dev.running.is_empty() {
                dev.busy_since = t;
            }
            let trace_idx = if record_trace {
                trace.push(SimTraceEvent {
                    task: task_id,
                    device: d,
                    slot,
                    label,
                    is_comm: false,
                    t_start: t,
                    t_end: f64::NAN,
                });
                Some(trace.len() - 1)
            } else {
                None
            };
            let (launch, compute) = cluster.device.kernel_phases(*class, *flops);
            dev.running.push(RunningKernel { task: task_id, launch_rem: launch, compute_rem: compute, slot, trace_idx });
            *n_kernels += 1;
        }
    }

    // per-instance release (arrival) times: a ready task whose instance has
    // not arrived yet is *held* until virtual time reaches its release
    let rel = |inst: usize| release.get(inst).copied().unwrap_or(0.0);
    let mut held: Vec<(f64, usize)> = Vec::new();

    // initial dispatch
    for t in &graph.tasks {
        if indeg[t.id] == 0 {
            let r = rel(t.instance);
            if r > 0.0 {
                held.push((r, t.id));
            } else {
                dispatch(
                    t.id, 0.0, graph, cluster, &mut devices, &mut cs, &mut comms,
                    &mut trace, record_trace, priority,
                );
            }
        }
    }
    for d in 0..devices.len() {
        fill_slots(d, 0.0, graph, cluster, &mut devices, &mut trace, &mut n_kernels, record_trace);
    }

    while done < n {
        // next event: earliest comm completion or device kernel completion
        let mut t_next = f64::INFINITY;
        let mut which: Option<usize> = None; // Some(device) or None => comm
        for (d, dev) in devices.iter().enumerate() {
            let t = dev.next_completion();
            if t < t_next {
                t_next = t;
                which = Some(d);
            }
        }
        let mut comm_idx: Option<usize> = None;
        for (i, (t, _)) in comms.iter().enumerate() {
            if *t < t_next {
                t_next = *t;
                which = None;
                comm_idx = Some(i);
            }
        }
        // a pending release may be the next event (an idle system awaiting
        // the next request arrival)
        let mut release_due = false;
        for (t, _) in &held {
            if *t < t_next {
                t_next = *t;
                which = None;
                comm_idx = None;
                release_due = true;
            }
        }
        if !t_next.is_finite() {
            bail!("simulation deadlock: {done}/{n} tasks done, nothing runnable (cyclic deps?)");
        }
        now = t_next;

        if release_due {
            let mut i = 0;
            while i < held.len() {
                if held[i].0 <= now {
                    let (_, task_id) = held.swap_remove(i);
                    dispatch(
                        task_id, now, graph, cluster, &mut devices, &mut cs, &mut comms,
                        &mut trace, record_trace, priority,
                    );
                } else {
                    i += 1;
                }
            }
            for d in 0..devices.len() {
                fill_slots(d, now, graph, cluster, &mut devices, &mut trace, &mut n_kernels, record_trace);
            }
            continue;
        }

        let mut completed_tasks: Vec<usize> = Vec::new();
        match which {
            None => {
                let (_, task_id) = comms.swap_remove(comm_idx.unwrap());
                completed_tasks.push(task_id);
            }
            Some(d) => {
                let dev = &mut devices[d];
                dev.advance(now);
                // the event may be a launch-phase end (sharing change only)
                // or one or more kernel completions
                let mut i = 0;
                while i < dev.running.len() {
                    if dev.running[i].done() {
                        let k = dev.running.swap_remove(i);
                        dev.slots[k.slot] = false;
                        if let Some(ti) = k.trace_idx {
                            trace[ti].t_end = now;
                        }
                        completed_tasks.push(k.task);
                    } else {
                        i += 1;
                    }
                }
                if dev.running.is_empty() {
                    dev.busy_s += now - dev.busy_since;
                }
            }
        }

        for task_id in completed_tasks {
            done += 1;
            for &dep in &dependents[task_id] {
                indeg[dep] -= 1;
                if indeg[dep] == 0 {
                    let r = rel(graph.tasks[dep].instance);
                    if r > now {
                        held.push((r, dep));
                    } else {
                        dispatch(
                            dep, now, graph, cluster, &mut devices, &mut cs, &mut comms,
                            &mut trace, record_trace, priority,
                        );
                    }
                }
            }
        }
        for d in 0..devices.len() {
            fill_slots(d, now, graph, cluster, &mut devices, &mut trace, &mut n_kernels, record_trace);
        }
    }

    // close busy intervals (all devices idle at the end by construction)
    let device_busy_s = devices.iter().map(|d| d.busy_s).collect();
    Ok(SimReport {
        makespan_s: now,
        device_busy_s,
        comm_total_s: cs.total_s(),
        comm_intra_s: cs.intra_s,
        comm_inter_s: cs.inter_s,
        cross_node_bytes: cs.cross_node_bytes,
        n_kernels,
        n_comms: cs.n_comms,
        trace,
    })
}

/// An **incremental** virtual-time executor session — the simulator analogue
/// of `coordinator::executor::ExecSession`, built for policy-driven serving
/// where admission times are *decisions*, not inputs.
///
/// [`simulate_released`] needs the whole schedule (and every cross-instance
/// admission edge) up front, so it can only score policies expressible as
/// static graph edges. A `SimSession` instead holds the virtual cluster
/// state (device stream slots, NIC occupancy, in-flight comms) **across
/// calls**: [`SimSession::admit`] splices a self-contained instance graph
/// into the run at the *current* virtual time, [`SimSession::step`] advances
/// to the next completion event, and [`SimSession::advance_to`] idles the
/// cluster forward to a chosen time (the next request arrival or a batch
/// window expiring). A scheduler loop can therefore interleave decisions
/// with virtual-time execution exactly as the live `ServingRuntime`
/// interleaves them with wall-clock execution — which is what makes the
/// three serving policies scoreable on one deterministic timeline
/// (`serving::simulate_serving_policy`).
///
/// Everything is plain f64 event arithmetic over the same device model as
/// [`simulate`]: an instance admitted alone at t = 0 finishes at exactly
/// the makespan `simulate` reports for its graph.
pub struct SimSession<'a> {
    cluster: &'a crate::perfmodel::ClusterModel,
    record_trace: bool,
    graph: TaskGraph,
    indeg: Vec<usize>,
    dependents: Vec<Vec<usize>>,
    /// Per-task dispatch priority over the union graph (0.0 unless the
    /// instance was admitted via [`SimSession::admit_prioritized`]).
    priority: Vec<f64>,
    /// Unretired task count per instance; 0 ⇒ the instance is finished.
    remaining: Vec<usize>,
    /// Virtual completion time per finished instance (its last retirement).
    done_at: Vec<f64>,
    finished: VecDeque<usize>,
    devices: Vec<Device>,
    cs: CommState,
    /// In-flight comms: (t_end, task id).
    comms: Vec<(f64, usize)>,
    trace: Vec<SimTraceEvent>,
    n_kernels: usize,
    now: f64,
}

impl<'a> SimSession<'a> {
    /// An idle session over `cluster` at virtual time 0 — no instances, no
    /// tasks. `record_trace` keeps the kernel/comm timeline (the per-request
    /// completion times need it off the `done_at` ledger only, so traceless
    /// sessions stay cheap).
    pub fn new(cluster: &'a crate::perfmodel::ClusterModel, record_trace: bool) -> SimSession<'a> {
        let max_conc = cluster.device.max_concurrency;
        SimSession {
            cluster,
            record_trace,
            graph: TaskGraph::default(),
            indeg: Vec::new(),
            dependents: Vec::new(),
            priority: Vec::new(),
            remaining: Vec::new(),
            done_at: Vec::new(),
            finished: VecDeque::new(),
            devices: (0..cluster.n_devices).map(|_| Device::new(max_conc)).collect(),
            cs: CommState::new(cluster.n_devices),
            comms: Vec::new(),
            trace: Vec::new(),
            n_kernels: 0,
            now: 0.0,
        }
    }

    /// Current virtual time (seconds).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Instances admitted so far.
    pub fn n_instances(&self) -> usize {
        self.remaining.len()
    }

    /// Admit one self-contained instance graph at the current virtual time:
    /// its root tasks dispatch now, interleaving with whatever is already in
    /// flight. Returns the instance index.
    pub fn admit(&mut self, sub: TaskGraph) -> Result<usize> {
        self.admit_inner(sub, None)
    }

    /// As [`SimSession::admit`], with per-task dispatch priorities for the
    /// admitted instance — the session-mode consumer of a placement plan
    /// (`coordinator::placement::Placement`), mirroring
    /// `ExecSession::admit_prioritized` on the live side. `priority` must
    /// have one entry per task of `sub`.
    pub fn admit_prioritized(&mut self, sub: TaskGraph, priority: &[f64]) -> Result<usize> {
        if priority.len() != sub.tasks.len() {
            bail!(
                "priority slice has {} entries for a {}-task instance",
                priority.len(),
                sub.tasks.len()
            );
        }
        self.admit_inner(sub, Some(priority))
    }

    fn admit_inner(&mut self, sub: TaskGraph, priority: Option<&[f64]>) -> Result<usize> {
        sub.validate()?;
        for t in &sub.tasks {
            if t.device >= self.cluster.n_devices {
                bail!(
                    "task {} targets device {} ≥ n_devices {}",
                    t.id,
                    t.device,
                    self.cluster.n_devices
                );
            }
        }
        let inst = self.remaining.len();
        let n_sub = sub.tasks.len();
        let off = self.graph.append_instance(sub, inst, 0);
        self.indeg.resize(off + n_sub, 0);
        self.dependents.resize(off + n_sub, Vec::new());
        self.priority.resize(off + n_sub, 0.0);
        if let Some(p) = priority {
            self.priority[off..off + n_sub].copy_from_slice(p);
        }
        self.remaining.push(n_sub);
        self.done_at.push(self.now);
        for id in off..off + n_sub {
            self.indeg[id] = self.graph.tasks[id].deps.len();
            for k in 0..self.graph.tasks[id].deps.len() {
                let d = self.graph.tasks[id].deps[k];
                self.dependents[d].push(id);
            }
        }
        if n_sub == 0 {
            self.finished.push_back(inst);
            return Ok(inst);
        }
        let t = self.now;
        for id in off..off + n_sub {
            if self.indeg[id] == 0 {
                self.dispatch_at(id, t);
            }
        }
        self.fill_all(t);
        Ok(inst)
    }

    /// Admit an already-composed **multi-instance** graph (e.g. a pipelined
    /// K-step training graph from `mgrit::taskgraph::mg_train_pipeline`) as
    /// ONE unit: per-task instance tags are preserved, so each contained
    /// instance keeps its own completion ledger (`poll_finished` /
    /// `finished_at`), while the scheduler prices the whole composition —
    /// cross-step staleness edges included — against whatever else is in
    /// flight. Returns the session index of the sub-graph's instance 0;
    /// contained instance k lands at that index + k.
    pub fn admit_composed(&mut self, sub: TaskGraph) -> Result<usize> {
        self.admit_composed_inner(sub, None)
    }

    /// As [`SimSession::admit_composed`], with per-task dispatch priorities
    /// over the whole composed graph (one entry per task) — the sim-side
    /// consumer of a placement plan for a pipelined training graph.
    pub fn admit_composed_prioritized(
        &mut self,
        sub: TaskGraph,
        priority: &[f64],
    ) -> Result<usize> {
        if priority.len() != sub.tasks.len() {
            bail!(
                "priority slice has {} entries for a {}-task composed graph",
                priority.len(),
                sub.tasks.len()
            );
        }
        self.admit_composed_inner(sub, Some(priority))
    }

    fn admit_composed_inner(&mut self, sub: TaskGraph, priority: Option<&[f64]>) -> Result<usize> {
        sub.validate()?;
        for t in &sub.tasks {
            if t.device >= self.cluster.n_devices {
                bail!(
                    "task {} targets device {} ≥ n_devices {}",
                    t.id,
                    t.device,
                    self.cluster.n_devices
                );
            }
        }
        let n_inst = sub.tasks.iter().map(|t| t.instance + 1).max().unwrap_or(0);
        if n_inst == 0 {
            bail!("cannot admit an empty composed graph");
        }
        let first = self.remaining.len();
        let n_sub = sub.tasks.len();
        let mut counts = vec![0usize; n_inst];
        for t in &sub.tasks {
            counts[t.instance] += 1;
        }
        let off = self.graph.append_composed(sub, first, 0);
        self.indeg.resize(off + n_sub, 0);
        self.dependents.resize(off + n_sub, Vec::new());
        self.priority.resize(off + n_sub, 0.0);
        if let Some(p) = priority {
            self.priority[off..off + n_sub].copy_from_slice(p);
        }
        for (k, c) in counts.iter().enumerate() {
            self.remaining.push(*c);
            self.done_at.push(self.now);
            if *c == 0 {
                self.finished.push_back(first + k);
            }
        }
        for id in off..off + n_sub {
            self.indeg[id] = self.graph.tasks[id].deps.len();
            for k in 0..self.graph.tasks[id].deps.len() {
                let d = self.graph.tasks[id].deps[k];
                self.dependents[d].push(id);
            }
        }
        let t = self.now;
        for id in off..off + n_sub {
            if self.indeg[id] == 0 {
                self.dispatch_at(id, t);
            }
        }
        self.fill_all(t);
        Ok(first)
    }

    /// Route one dependency-free task: kernels queue on their device, comms
    /// occupy both endpoints of their tier's link (intra-node vs inter-node
    /// fabric) from `max(t, link free times)` — identical pricing to
    /// [`simulate_released`]'s dispatch (including the zero-cost co-located
    /// comm fast path).
    fn dispatch_at(&mut self, task_id: usize, t: f64) {
        let task = &self.graph.tasks[task_id];
        match &task.kind {
            TaskKind::Kernel { .. } => {
                let pri = self.priority[task_id];
                self.devices[task.device].push_ready(task_id, pri);
            }
            TaskKind::Comm { src, dst, bytes } => {
                if src == dst {
                    self.comms.push((t, task_id));
                    return;
                }
                let (start, end) = self.cs.book(self.cluster, *src, *dst, *bytes, t);
                self.comms.push((end, task_id));
                if self.record_trace {
                    self.trace.push(SimTraceEvent {
                        task: task_id,
                        device: *dst,
                        slot: 0,
                        label: "comm",
                        is_comm: true,
                        t_start: start,
                        t_end: end,
                    });
                }
            }
        }
    }

    /// Start ready kernels on every device's free stream slots at time `t`.
    fn fill_all(&mut self, t: f64) {
        for d in 0..self.devices.len() {
            let dev = &mut self.devices[d];
            while dev.running.len() < dev.slots.len() && !dev.ready.is_empty() {
                dev.advance(t);
                let task_id = dev.ready.pop().unwrap().task;
                let TaskKind::Kernel { label, class, flops } = &self.graph.tasks[task_id].kind
                else {
                    unreachable!("ready queue holds kernels only");
                };
                let slot = dev.slots.iter().position(|s| !s).unwrap();
                dev.slots[slot] = true;
                if dev.running.is_empty() {
                    dev.busy_since = t;
                }
                let trace_idx = if self.record_trace {
                    self.trace.push(SimTraceEvent {
                        task: task_id,
                        device: d,
                        slot,
                        label,
                        is_comm: false,
                        t_start: t,
                        t_end: f64::NAN,
                    });
                    Some(self.trace.len() - 1)
                } else {
                    None
                };
                let (launch, compute) = self.cluster.device.kernel_phases(*class, *flops);
                dev.running.push(RunningKernel {
                    task: task_id,
                    launch_rem: launch,
                    compute_rem: compute,
                    slot,
                    trace_idx,
                });
                self.n_kernels += 1;
            }
        }
    }

    /// Virtual time of the next completion event (a comm finishing, a launch
    /// phase ending, or a kernel completing), if anything is in flight.
    pub fn next_event_s(&self) -> Option<f64> {
        let mut t = f64::INFINITY;
        for dev in &self.devices {
            t = t.min(dev.next_completion());
        }
        for (tc, _) in &self.comms {
            t = t.min(*tc);
        }
        t.is_finite().then_some(t)
    }

    /// Advance to the next event and process its completions. `Ok(false)`
    /// when nothing is in flight (the session is idle); a non-idle session
    /// with unretired tasks and no next event is a dependency-cycle error.
    pub fn step(&mut self) -> Result<bool> {
        let mut t_next = f64::INFINITY;
        let mut which: Option<usize> = None; // Some(device) or None => comm
        for (d, dev) in self.devices.iter().enumerate() {
            let t = dev.next_completion();
            if t < t_next {
                t_next = t;
                which = Some(d);
            }
        }
        let mut comm_idx: Option<usize> = None;
        for (i, (t, _)) in self.comms.iter().enumerate() {
            if *t < t_next {
                t_next = *t;
                which = None;
                comm_idx = Some(i);
            }
        }
        if !t_next.is_finite() {
            // validated instance graphs are acyclic and self-contained, so an
            // idle cluster with unretired tasks is a bookkeeping bug, not a
            // schedule waiting on anything
            let outstanding: usize = self.remaining.iter().sum();
            if outstanding > 0 {
                bail!("sim session stalled with {outstanding} tasks unretired");
            }
            return Ok(false);
        }
        self.now = self.now.max(t_next);
        let now = self.now;

        let mut completed: Vec<usize> = Vec::new();
        match which {
            None => {
                let (_, task_id) = self.comms.swap_remove(comm_idx.unwrap());
                completed.push(task_id);
            }
            Some(d) => {
                let dev = &mut self.devices[d];
                dev.advance(now);
                let mut i = 0;
                while i < dev.running.len() {
                    if dev.running[i].done() {
                        let k = dev.running.swap_remove(i);
                        dev.slots[k.slot] = false;
                        if let Some(ti) = k.trace_idx {
                            self.trace[ti].t_end = now;
                        }
                        completed.push(k.task);
                    } else {
                        i += 1;
                    }
                }
                if dev.running.is_empty() {
                    dev.busy_s += now - dev.busy_since;
                }
            }
        }

        for task_id in completed {
            let inst = self.graph.tasks[task_id].instance;
            self.remaining[inst] -= 1;
            if self.remaining[inst] == 0 {
                self.done_at[inst] = now;
                self.finished.push_back(inst);
            }
            let deps = std::mem::take(&mut self.dependents[task_id]);
            for dep in deps {
                self.indeg[dep] -= 1;
                if self.indeg[dep] == 0 {
                    self.dispatch_at(dep, now);
                }
            }
        }
        self.fill_all(now);
        Ok(true)
    }

    /// Process every event up to and including time `t`, then set the clock
    /// to `t` (idling the cluster forward if nothing happens in between) —
    /// how the serving loop models "wait until the next arrival / window".
    /// The clock never moves backwards.
    pub fn advance_to(&mut self, t: f64) -> Result<()> {
        while let Some(e) = self.next_event_s() {
            if e > t {
                break;
            }
            self.step()?;
        }
        self.now = self.now.max(t);
        Ok(())
    }

    /// Run every in-flight and dependent task to completion.
    pub fn run_to_idle(&mut self) -> Result<()> {
        while self.step()? {}
        Ok(())
    }

    /// Next instance whose every task has retired (completion order), if any.
    pub fn poll_finished(&mut self) -> Option<usize> {
        self.finished.pop_front()
    }

    /// Virtual time a finished instance's last task retired; `None` while it
    /// is still in flight.
    pub fn finished_at(&self, inst: usize) -> Option<f64> {
        (self.remaining.get(inst).copied() == Some(0)).then(|| self.done_at[inst])
    }

    /// The kernel/comm timeline recorded so far (empty unless the session
    /// was created with `record_trace`).
    pub fn trace(&self) -> &[SimTraceEvent] {
        &self.trace
    }

    /// The graph task record behind a trace event's `task` id.
    pub fn task_instance(&self, task: usize) -> usize {
        self.graph.tasks[task].instance
    }

    /// Consume the session into the aggregate report (makespan = the final
    /// virtual clock).
    pub fn into_report(self) -> SimReport {
        SimReport {
            makespan_s: self.now,
            device_busy_s: self.devices.iter().map(|d| d.busy_s).collect(),
            comm_total_s: self.cs.total_s(),
            comm_intra_s: self.cs.intra_s,
            comm_inter_s: self.cs.inter_s,
            cross_node_bytes: self.cs.cross_node_bytes,
            n_kernels: self.n_kernels,
            n_comms: self.cs.n_comms,
            trace: self.trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Partition;
    use crate::mgrit::hierarchy::Hierarchy;
    use crate::mgrit::taskgraph;
    use crate::model::NetSpec;
    use crate::perfmodel::{ClusterModel, DeviceModel, NetworkModel};

    fn cluster(n: usize) -> ClusterModel {
        ClusterModel::tx_gaia(n)
    }

    #[test]
    fn serial_chain_time_is_sum_of_kernels() {
        let spec = NetSpec::fig6_depth(16);
        let g = taskgraph::serial_forward(&spec, 1, 1);
        let c = cluster(1);
        let rep = simulate(&g, &c, false).unwrap();
        // single chain, one device: makespan = Σ kernel times
        let expect: f64 = g
            .tasks
            .iter()
            .map(|t| match &t.kind {
                taskgraph::TaskKind::Kernel { class, flops, .. } => {
                    c.device.kernel_time(*class, *flops)
                }
                _ => 0.0,
            })
            .sum();
        assert!((rep.makespan_s - expect).abs() / expect < 1e-9);
        assert_eq!(rep.n_kernels, 16);
        assert_eq!(rep.n_comms, 0);
    }

    #[test]
    fn pm_adds_comm_time() {
        let spec = NetSpec::fig6_depth(64);
        let g1 = taskgraph::serial_forward(&spec, 1, 1);
        let g8 = taskgraph::serial_forward(&spec, 8, 1);
        let r1 = simulate(&g1, &cluster(1), false).unwrap();
        let r8 = simulate(&g8, &cluster(8), false).unwrap();
        // PM with 8 devices is *slower* than serial for inference: same
        // serial chain plus 7 transfers (the paper's PM pathology)
        assert!(r8.makespan_s > r1.makespan_s);
        assert_eq!(r8.n_comms, 7);
    }

    #[test]
    fn mg_scales_with_devices() {
        // at the paper's depth (fig6: N = 4,093) MG keeps speeding up well
        // past 4 devices; small depths saturate earlier (launch-bound layers)
        let spec = NetSpec::fig6();
        let n = spec.n_res();
        let hier = Hierarchy::two_level(n, spec.h(), 4).unwrap();
        let n_blocks = hier.fine().blocks(4).len();
        let mut prev = f64::INFINITY;
        for n_dev in [1usize, 4, 16] {
            let part = Partition::contiguous(n_blocks, n_dev).unwrap();
            let g = taskgraph::mg_forward(&spec, &hier, &part, 1, 2);
            let rep = simulate(&g, &cluster(n_dev), false).unwrap();
            assert!(
                rep.makespan_s < prev,
                "MG should speed up with devices: {n_dev} gpus {} s vs {prev} s",
                rep.makespan_s
            );
            prev = rep.makespan_s;
        }
    }

    #[test]
    fn concurrency_cap_respected_and_reached() {
        // one device, many independent kernels → peak concurrency == cap
        let spec = NetSpec::fig6_depth(64);
        let hier = Hierarchy::two_level(64, spec.h(), 4).unwrap();
        let part = Partition::contiguous(hier.fine().blocks(4).len(), 1).unwrap();
        let g = taskgraph::mg_forward(&spec, &hier, &part, 1, 1);
        let c = cluster(1);
        let rep = simulate(&g, &c, true).unwrap();
        let peak = rep.peak_concurrency(0);
        assert_eq!(peak, c.device.max_concurrency, "peak {peak}");
    }

    #[test]
    fn compute_shares_but_launches_overlap() {
        // two equal kernels on one device, independent: launches overlap
        // (CUDA-stream latency hiding), compute is processor-shared, so the
        // makespan is launch + 2×compute — strictly between 1× and 2× solo
        use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind, KernelClass};
        let mk = |id| Task {
            id,
            instance: 0,
            device: 0,
            kind: TaskKind::Kernel { label: "k", class: KernelClass::Gemm, flops: 1e9 },
            deps: vec![],
            op: None,
        };
        let g = TaskGraph { tasks: vec![mk(0), mk(1)] };
        let c = cluster(1);
        let (launch, compute) = c.device.kernel_phases(KernelClass::Gemm, 1e9);
        let rep = simulate(&g, &c, false).unwrap();
        let want = launch + 2.0 * compute;
        assert!(
            (rep.makespan_s - want).abs() / want < 1e-6,
            "{} vs {}",
            rep.makespan_s,
            want
        );
    }

    #[test]
    fn launch_bound_gemms_gain_from_concurrency() {
        // five tiny GEMMs: launches overlap (stream latency hiding), so
        // five concurrent kernels cost barely more than one solo
        use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind, KernelClass};
        let mk = |id| Task {
            id,
            instance: 0,
            device: 0,
            kind: TaskKind::Kernel { label: "k", class: KernelClass::Gemm, flops: 1e3 },
            deps: vec![],
            op: None,
        };
        let g = TaskGraph { tasks: (0..5).map(mk).collect() };
        let c = cluster(1);
        let solo = c.device.kernel_time(KernelClass::Gemm, 1e3);
        let rep = simulate(&g, &c, false).unwrap();
        assert!(rep.makespan_s < 1.5 * solo, "{} vs solo {}", rep.makespan_s, solo);
    }

    #[test]
    fn conv_kernels_serialize() {
        // the paper's register-pressure observation: concurrent convolution
        // kernels do NOT speed up — five convs take 5× one conv
        use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind, KernelClass};
        let mk = |id| Task {
            id,
            instance: 0,
            device: 0,
            kind: TaskKind::Kernel { label: "k", class: KernelClass::Conv, flops: 1e3 },
            deps: vec![],
            op: None,
        };
        let g = TaskGraph { tasks: (0..5).map(mk).collect() };
        let c = cluster(1);
        let solo = c.device.kernel_time(KernelClass::Conv, 1e3);
        let rep = simulate(&g, &c, false).unwrap();
        assert!(
            (rep.makespan_s - 5.0 * solo).abs() / solo < 1e-6,
            "{} vs {}",
            rep.makespan_s,
            5.0 * solo
        );
    }

    #[test]
    fn nic_serializes_messages() {
        use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind};
        // two messages from device 0 → 1, no deps: must serialize on the NICs
        let mk = |id| Task {
            id,
            instance: 0,
            device: 1,
            kind: TaskKind::Comm { src: 0, dst: 1, bytes: 3.125e6 },
            deps: vec![],
            op: None,
        };
        let g = TaskGraph { tasks: vec![mk(0), mk(1)] };
        let c = ClusterModel {
            n_devices: 2,
            device: DeviceModel::v100(),
            topo: crate::perfmodel::Topology::flat(2, NetworkModel::ethernet_25g()),
        };
        let one = c.message_time(0, 1, 3.125e6);
        let rep = simulate(&g, &c, false).unwrap();
        assert!((rep.makespan_s - 2.0 * one).abs() / one < 1e-6);
        // flat topology: everything is fabric traffic
        assert_eq!(rep.comm_intra_s, 0.0);
        assert!((rep.comm_inter_s - 2.0 * one).abs() / one < 1e-6);
        assert_eq!(rep.cross_node_bytes, 2.0 * 3.125e6);
    }

    #[test]
    fn tiered_nics_do_not_serialize_across_tiers() {
        use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind};
        // two nodes of two devices. Device 1 receives an intra-node message
        // (0 → 1) and an inter-node message (2 → 1) released together: on
        // the old single-NIC model they would serialize on device 1; with
        // per-tier links they overlap, so the makespan is the slower hop
        // alone — and the ledger tallies each on its own tier
        let bytes = 3.125e6;
        let mk = |id, src| Task {
            id,
            instance: 0,
            device: 1,
            kind: TaskKind::Comm { src, dst: 1, bytes },
            deps: vec![],
            op: None,
        };
        let g = TaskGraph { tasks: vec![mk(0, 0), mk(1, 2)] };
        let c = ClusterModel::tx_gaia_nodes(2, 2);
        let t_intra = c.message_time(0, 1, bytes);
        let t_inter = c.message_time(2, 1, bytes);
        assert!(t_intra < t_inter);
        let rep = simulate(&g, &c, false).unwrap();
        assert!((rep.makespan_s - t_inter).abs() / t_inter < 1e-9, "tiers serialized");
        assert!((rep.comm_intra_s - t_intra).abs() / t_intra < 1e-9);
        assert!((rep.comm_inter_s - t_inter).abs() / t_inter < 1e-9);
        assert_eq!(rep.comm_total_s, rep.comm_intra_s + rep.comm_inter_s);
        // only the inter hop's bytes cross a node boundary
        assert_eq!(rep.cross_node_bytes, bytes);
    }

    #[test]
    fn colocated_comms_stay_free_and_uncounted_under_topology() {
        use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind};
        // src == dst transfers (placement rewrites) remain zero-time local
        // handoffs on a multi-node topology: no ledger entry on either tier
        let g = TaskGraph {
            tasks: vec![Task {
                id: 0,
                instance: 0,
                device: 2,
                kind: TaskKind::Comm { src: 2, dst: 2, bytes: 1e9 },
                deps: vec![],
                op: None,
            }],
        };
        let rep = simulate(&g, &ClusterModel::tx_gaia_nodes(2, 2), true).unwrap();
        assert_eq!(rep.makespan_s, 0.0);
        assert_eq!((rep.n_comms, rep.trace.len()), (0, 0));
        assert_eq!(rep.comm_total_s, 0.0);
        assert_eq!(rep.comm_intra_s, 0.0);
        assert_eq!(rep.comm_inter_s, 0.0);
        assert_eq!(rep.cross_node_bytes, 0.0);
    }

    #[test]
    fn deadlock_is_detected() {
        use crate::mgrit::taskgraph::{Task, TaskGraph, TaskKind, KernelClass};
        // a task depending on itself can never run
        let g = TaskGraph {
            tasks: vec![Task {
                id: 0,
                instance: 0,
                device: 0,
                kind: TaskKind::Kernel { label: "k", class: KernelClass::Gemm, flops: 1.0 },
                deps: vec![0],
                op: None,
            }],
        };
        assert!(simulate(&g, &cluster(1), false).is_err());
    }

    #[test]
    fn training_graph_simulates_without_phase_barriers() {
        // the whole-training-step graph (the one the live executor runs)
        // scores in the simulator, and the virtual-time trace shows a
        // param_grad kernel starting before the adjoint phase has drained —
        // impossible under an inter-phase barrier
        use crate::mgrit::fas::RelaxKind;
        use crate::mgrit::taskgraph::Granularity;
        let spec = NetSpec::fig6_depth(64);
        let hier = Hierarchy::two_level(64, spec.h(), 4).unwrap();
        let part = Partition::contiguous(hier.fine().blocks(4).len(), 4).unwrap();
        let g = taskgraph::mg_train_step(
            &spec, &hier, &part, 1, 2, RelaxKind::FCF, Granularity::PerStep,
        );
        g.validate().unwrap();
        let rep = simulate(&g, &cluster(4), true).unwrap();
        assert_eq!(
            rep.n_kernels,
            g.tasks.iter().filter(|t| matches!(t.kind, TaskKind::Kernel { .. })).count()
        );
        let first_grad = rep
            .trace
            .iter()
            .filter(|e| e.label == "param_grad")
            .map(|e| e.t_start)
            .fold(f64::INFINITY, f64::min);
        let last_adj = rep
            .trace
            .iter()
            .filter(|e| e.label.starts_with("adj_"))
            .map(|e| e.t_end)
            .fold(f64::NEG_INFINITY, f64::max);
        assert!(first_grad.is_finite() && last_adj.is_finite());
        assert!(
            first_grad < last_adj,
            "gradients only started after the adjoint drained ({first_grad} vs {last_adj})"
        );
    }

    #[test]
    fn multi_instance_training_graph_pipelines_in_virtual_time() {
        // the hybrid tentpole, scored deterministically: two micro-batch
        // instances through ONE composed graph finish in less virtual time
        // than two back-to-back single-instance steps, and the trace shows
        // instance 1 forward kernels in flight while instance 0 adjoint
        // kernels run — impossible with an inter-instance barrier
        use crate::coordinator::InstanceGroups;
        use crate::mgrit::fas::RelaxKind;
        use crate::mgrit::taskgraph::Granularity;
        let spec = NetSpec::fig6_depth(64);
        let hier = Hierarchy::two_level(64, spec.h(), 4).unwrap();
        let part = Partition::contiguous(hier.fine().blocks(4).len(), 4).unwrap();
        let groups = InstanceGroups::new(1, part.n_devices()).unwrap();
        let g1 = taskgraph::mg_train_step(
            &spec, &hier, &part, 1, 2, RelaxKind::FCF, Granularity::PerStep,
        );
        let g2 = taskgraph::mg_train_step_multi(
            &spec, &hier, &part, &groups, 1, 2, RelaxKind::FCF, Granularity::PerStep, 2,
        )
        .unwrap();
        let r1 = simulate(&g1, &cluster(4), false).unwrap();
        let r2 = simulate(&g2, &cluster(4), true).unwrap();
        assert!(
            r2.makespan_s < 2.0 * r1.makespan_s,
            "no pipelining gain: {} vs 2×{}",
            r2.makespan_s,
            r1.makespan_s
        );
        // cross-instance overlap on the virtual timeline (shared predicate)
        let evs: Vec<(usize, &str, f64, f64)> = r2
            .trace
            .iter()
            .filter(|e| !e.is_comm)
            .map(|e| (g2.tasks[e.task].instance, e.label, e.t_start, e.t_end))
            .collect();
        assert!(
            taskgraph::events_show_pipeline_overlap(&evs),
            "instance 1 forward never overlapped instance 0 adjoint/gradient work"
        );
    }

    #[test]
    fn grouped_instances_score_on_disjoint_devices() {
        // 2 groups × 2 devices: the composed graph simulates on 4 devices
        // and the reduction join's cross-group hops appear as comm events
        use crate::coordinator::InstanceGroups;
        use crate::mgrit::fas::RelaxKind;
        use crate::mgrit::taskgraph::Granularity;
        let spec = NetSpec::fig6_depth(64);
        let hier = Hierarchy::two_level(64, spec.h(), 4).unwrap();
        let part = Partition::contiguous(hier.fine().blocks(4).len(), 2).unwrap();
        let groups = InstanceGroups::new(2, part.n_devices()).unwrap();
        let g = taskgraph::mg_train_step_multi(
            &spec, &hier, &part, &groups, 1, 2, RelaxKind::FCF, Granularity::PerStep, 2,
        )
        .unwrap();
        let single = taskgraph::mg_train_step(
            &spec, &hier, &part, 1, 2, RelaxKind::FCF, Granularity::PerStep,
        );
        // grouped instances add reduction-tree transfers on top of the
        // per-instance boundary traffic
        assert!(g.n_comms() > 2 * single.n_comms());
        let rep = simulate(&g, &cluster(groups.n_devices()), false).unwrap();
        assert_eq!(rep.n_comms, g.n_comms());
        assert!(rep.makespan_s > 0.0);
    }

    #[test]
    fn busy_fraction_bounded() {
        let spec = NetSpec::fig6_depth(128);
        let hier = Hierarchy::two_level(128, spec.h(), 4).unwrap();
        let part = Partition::contiguous(hier.fine().blocks(4).len(), 4).unwrap();
        let g = taskgraph::mg_forward(&spec, &hier, &part, 1, 2);
        let rep = simulate(&g, &cluster(4), false).unwrap();
        let f = rep.compute_fraction();
        assert!(f > 0.0 && f <= 1.0, "compute fraction {f}");
        assert!((rep.stall_fraction() + f - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_graph_ok() {
        let g = taskgraph::TaskGraph::default();
        let rep = simulate(&g, &cluster(1), false).unwrap();
        assert_eq!(rep.makespan_s, 0.0);
    }

    #[test]
    fn release_times_delay_instance_starts() {
        use crate::mgrit::taskgraph::{KernelClass, Task, TaskGraph, TaskKind};
        // two independent one-kernel instances; instance 1 arrives at t = 1 s
        let mk = |id, instance| Task {
            id,
            instance,
            device: 0,
            kind: TaskKind::Kernel { label: "k", class: KernelClass::Conv, flops: 1e6 },
            deps: vec![],
            op: None,
        };
        let g = TaskGraph { tasks: vec![mk(0, 0), mk(1, 1)] };
        let c = cluster(1);
        let solo = c.device.kernel_time(KernelClass::Conv, 1e6);
        // no releases: convs serialize back to back
        let r0 = simulate(&g, &c, true).unwrap();
        assert!((r0.makespan_s - 2.0 * solo).abs() / solo < 1e-6);
        // instance 1 released at 1 s: the device idles until the arrival,
        // and instance 1's kernel starts exactly at its release
        let r1 = simulate_released(&g, &c, true, &[0.0, 1.0]).unwrap();
        assert!((r1.makespan_s - (1.0 + solo)).abs() / solo < 1e-6, "{}", r1.makespan_s);
        let e1 = r1.trace.iter().find(|e| e.task == 1).unwrap();
        assert!((e1.t_start - 1.0).abs() < 1e-9, "started at {}", e1.t_start);
        // an empty release slice is the plain simulate() behavior, bitwise
        let r2 = simulate_released(&g, &c, false, &[]).unwrap();
        assert_eq!(r2.makespan_s, r0.makespan_s);
    }

    #[test]
    fn release_applies_to_downstream_ready_tasks_too() {
        use crate::mgrit::taskgraph::{KernelClass, Task, TaskGraph, TaskKind};
        // chain: task 0 (instance 0) → task 1 (instance 1, released late):
        // the dependent must wait for max(dep completion, its release)
        let g = TaskGraph {
            tasks: vec![
                Task {
                    id: 0,
                    instance: 0,
                    device: 0,
                    kind: TaskKind::Kernel { label: "k", class: KernelClass::Conv, flops: 1e6 },
                    deps: vec![],
                    op: None,
                },
                Task {
                    id: 1,
                    instance: 1,
                    device: 0,
                    kind: TaskKind::Kernel { label: "k", class: KernelClass::Conv, flops: 1e6 },
                    deps: vec![0],
                    op: None,
                },
            ],
        };
        let c = cluster(1);
        let solo = c.device.kernel_time(KernelClass::Conv, 1e6);
        let rep = simulate_released(&g, &c, true, &[0.0, 0.5]).unwrap();
        let e1 = rep.trace.iter().find(|e| e.task == 1).unwrap();
        assert!((e1.t_start - 0.5).abs() < 1e-9, "started at {}", e1.t_start);
        assert!((rep.makespan_s - (0.5 + solo)).abs() / solo < 1e-6);
    }

    #[test]
    fn serve_graph_latencies_are_deterministic_and_windowed() {
        // the serving schedule: composed forward-only instances + arrivals —
        // identical timelines across runs, and a tighter window can only
        // delay completions
        use crate::mgrit::fas::RelaxKind;
        use crate::mgrit::taskgraph::{Admission, Granularity};
        let spec = NetSpec::fig6_depth(64);
        let hier = Hierarchy::two_level(64, spec.h(), 4).unwrap();
        let part = Partition::contiguous(hier.fine().blocks(4).len(), 2).unwrap();
        let n = 6usize;
        let arrivals: Vec<f64> = (0..n).map(|k| k as f64 * 1e-4).collect();
        let mk = |window: usize| {
            taskgraph::mg_serve(
                &spec, &hier, &part, 1, 1, RelaxKind::FCF, Granularity::PerStep, n,
                Admission::Continuous { window },
            )
            .unwrap()
        };
        let completions = |g: &taskgraph::TaskGraph| -> Vec<f64> {
            let rep = simulate_released(g, &cluster(2), true, &arrivals).unwrap();
            let mut out = vec![0.0f64; n];
            for e in &rep.trace {
                let k = g.tasks[e.task].instance;
                out[k] = out[k].max(e.t_end);
            }
            out
        };
        let wide = mk(n);
        let a = completions(&wide);
        let b = completions(&wide);
        assert_eq!(a, b, "virtual serving timeline must be deterministic");
        // window-1 admission strictly serializes: completions are FIFO and
        // the tail request finishes later than with a wide window (early
        // requests may finish *earlier* — they never share the devices)
        let narrow = completions(&mk(1));
        for w in narrow.windows(2) {
            assert!(w[1] > w[0], "window-1 completions out of order: {narrow:?}");
        }
        assert!(
            narrow.last().unwrap() > a.last().unwrap(),
            "window 1 should hurt the tail: {} vs {}",
            narrow.last().unwrap(),
            a.last().unwrap()
        );
    }

    fn forward_graph(devices: usize) -> taskgraph::TaskGraph {
        use crate::mgrit::fas::RelaxKind;
        use crate::mgrit::taskgraph::Granularity;
        let spec = NetSpec::fig6_depth(32);
        let hier = Hierarchy::two_level(32, spec.h(), 4).unwrap();
        let part = Partition::contiguous(hier.fine().blocks(4).len(), devices).unwrap();
        taskgraph::mg_forward_with(
            &spec, &hier, &part, 1, 1, RelaxKind::FCF, Granularity::PerStep,
        )
    }

    #[test]
    fn sim_session_lone_instance_matches_batch_simulate() {
        // one instance admitted at t = 0 into an idle session must finish at
        // exactly the makespan the batch engine reports for the same graph —
        // the session adds incrementality, not a different cost model
        let g = forward_graph(2);
        let c = cluster(2);
        let want = simulate(&g, &c, false).unwrap();
        let mut s = SimSession::new(&c, false);
        let inst = s.admit(forward_graph(2)).unwrap();
        s.run_to_idle().unwrap();
        assert_eq!(s.poll_finished(), Some(inst));
        assert_eq!(s.finished_at(inst), Some(s.now()));
        let rep = s.into_report();
        assert_eq!(rep.makespan_s, want.makespan_s, "session drifted from batch simulate");
        assert_eq!(rep.n_kernels, want.n_kernels);
        assert_eq!(rep.n_comms, want.n_comms);
    }

    #[test]
    fn sim_session_concurrent_instances_overlap_and_stamp_completions() {
        let c = cluster(2);
        let mut s = SimSession::new(&c, true);
        let i0 = s.admit(forward_graph(2)).unwrap();
        let i1 = s.admit(forward_graph(2)).unwrap();
        s.run_to_idle().unwrap();
        let finished: Vec<usize> = std::iter::from_fn(|| s.poll_finished()).collect();
        assert_eq!(finished.len(), 2);
        let t0 = s.finished_at(i0).unwrap();
        let t1 = s.finished_at(i1).unwrap();
        // completion stamps equal each instance's latest trace t_end
        for (inst, t) in [(i0, t0), (i1, t1)] {
            let last = s
                .trace()
                .iter()
                .filter(|e| !e.is_comm && s.task_instance(e.task) == inst)
                .map(|e| e.t_end)
                .fold(f64::NEG_INFINITY, f64::max);
            assert_eq!(t, last, "instance {inst} stamp != last kernel retirement");
        }
        // two co-admitted instances share the cluster: both run before either
        // finishes (some kernel of each starts before the other's completion)
        let first_start = |inst: usize| {
            s.trace()
                .iter()
                .filter(|e| !e.is_comm && s.task_instance(e.task) == inst)
                .map(|e| e.t_start)
                .fold(f64::INFINITY, f64::min)
        };
        assert!(first_start(i1) < t0, "instance 1 never overlapped instance 0");
    }

    #[test]
    fn sim_session_staggered_admission_and_idle_advance() {
        let c = cluster(2);
        let mut s = SimSession::new(&c, true);
        assert!(s.next_event_s().is_none());
        assert!(!s.step().unwrap(), "idle session must report no work");
        // idle-advance models waiting for an arrival
        s.advance_to(0.5).unwrap();
        assert_eq!(s.now(), 0.5);
        let i0 = s.admit(forward_graph(2)).unwrap();
        // a second instance admitted later never runs anything earlier
        s.advance_to(s.now() + 1e-5).unwrap();
        let i1 = s.admit(forward_graph(2)).unwrap();
        s.run_to_idle().unwrap();
        let start_of = |inst: usize| {
            s.trace()
                .iter()
                .filter(|e| s.task_instance(e.task) == inst)
                .map(|e| e.t_start)
                .fold(f64::INFINITY, f64::min)
        };
        assert!(start_of(i0) >= 0.5, "work before the clock reached admission");
        assert!(start_of(i1) >= 0.5 + 1e-5);
        assert!(s.finished_at(i0).unwrap() <= s.finished_at(i1).unwrap());
        // the timeline is bit-reproducible
        let replay = |mut sess: SimSession| -> (f64, f64) {
            let a = sess.admit(forward_graph(2)).unwrap();
            let b = sess.admit(forward_graph(2)).unwrap();
            sess.run_to_idle().unwrap();
            (sess.finished_at(a).unwrap(), sess.finished_at(b).unwrap())
        };
        let x = replay(SimSession::new(&c, false));
        let y = replay(SimSession::new(&c, false));
        assert_eq!(x, y);
    }

    #[test]
    fn priorities_reorder_ready_kernels_and_zero_priorities_match_fifo() {
        use crate::mgrit::taskgraph::{KernelClass, Task, TaskGraph, TaskKind};
        // one device, one stream slot, three conv kernels (convs serialize):
        // FIFO runs them 0,1,2; priorities [0,1,2] must run them 2,1,0
        let mk = |id| Task {
            id,
            instance: 0,
            device: 0,
            kind: TaskKind::Kernel { label: "k", class: KernelClass::Conv, flops: 1e3 },
            deps: vec![],
            op: None,
        };
        let g = TaskGraph { tasks: (0..3).map(mk).collect() };
        let mut c = cluster(1);
        c.device.max_concurrency = 1;
        let fifo = simulate(&g, &c, true).unwrap();
        let order = |rep: &SimReport| {
            let mut ev: Vec<(f64, usize)> =
                rep.trace.iter().map(|e| (e.t_start, e.task)).collect();
            ev.sort_by(|a, b| a.0.total_cmp(&b.0));
            ev.into_iter().map(|(_, t)| t).collect::<Vec<_>>()
        };
        assert_eq!(order(&fifo), vec![0, 1, 2]);
        let zeros = simulate_prioritized(&g, &c, true, Some(&[0.0; 3])).unwrap();
        assert_eq!(order(&zeros), vec![0, 1, 2]);
        assert_eq!(zeros.makespan_s, fifo.makespan_s);
        let rev = simulate_prioritized(&g, &c, true, Some(&[0.0, 1.0, 2.0])).unwrap();
        assert_eq!(order(&rev), vec![2, 1, 0]);
        // priorities reorder, they never add or remove work
        assert_eq!(rev.makespan_s, fifo.makespan_s);
        // mis-sized priority slices are rejected
        assert!(simulate_prioritized(&g, &c, false, Some(&[0.0])).is_err());
    }

    #[test]
    fn co_located_comms_are_free_and_uncounted() {
        use crate::mgrit::taskgraph::{KernelClass, Task, TaskGraph, TaskKind};
        // kernel → src==dst comm → kernel: the comm must cost zero time,
        // occupy no NIC, and stay out of the comm ledger — in both the batch
        // engine and the incremental session
        let kern = |id, deps: Vec<usize>| Task {
            id,
            instance: 0,
            device: 0,
            kind: TaskKind::Kernel { label: "k", class: KernelClass::Conv, flops: 1e3 },
            deps,
            op: None,
        };
        let g = TaskGraph {
            tasks: vec![
                kern(0, vec![]),
                Task {
                    id: 1,
                    instance: 0,
                    device: 0,
                    kind: TaskKind::Comm { src: 0, dst: 0, bytes: 3.125e6 },
                    deps: vec![0],
                    op: None,
                },
                kern(2, vec![1]),
            ],
        };
        let c = cluster(2);
        let solo = c.device.kernel_time(KernelClass::Conv, 1e3);
        let rep = simulate(&g, &c, false).unwrap();
        assert_eq!(rep.n_comms, 0);
        assert_eq!(rep.comm_total_s, 0.0);
        assert!(
            (rep.makespan_s - 2.0 * solo).abs() / solo < 1e-6,
            "handoff not free: {} vs {}",
            rep.makespan_s,
            2.0 * solo
        );
        let mut s = SimSession::new(&c, false);
        let inst = s.admit(g).unwrap();
        s.run_to_idle().unwrap();
        assert_eq!(s.finished_at(inst).unwrap(), rep.makespan_s);
        let done = s.into_report();
        assert_eq!(done.n_comms, 0);
        assert_eq!(done.comm_total_s, 0.0);
    }

    #[test]
    fn session_prioritized_admission_matches_batch_prioritized_run() {
        // the same (graph, priority) pair scores identically through
        // simulate_prioritized and SimSession::admit_prioritized — the two
        // consumers of a placement plan can never drift
        let g = forward_graph(2);
        let c = cluster(2);
        let pri: Vec<f64> = g.tasks.iter().map(|t| t.id as f64).collect();
        let batch = simulate_prioritized(&g, &c, false, Some(&pri)).unwrap();
        let mut s = SimSession::new(&c, false);
        let inst = s.admit_prioritized(g.clone(), &pri).unwrap();
        s.run_to_idle().unwrap();
        assert_eq!(s.finished_at(inst).unwrap(), batch.makespan_s);
        let rep = s.into_report();
        assert_eq!(rep.n_kernels, batch.n_kernels);
        assert_eq!(rep.n_comms, batch.n_comms);
        // mis-sized priority slices are rejected at admission
        let mut s2 = SimSession::new(&c, false);
        assert!(s2.admit_prioritized(g, &[1.0]).is_err());
    }

    #[test]
    fn composed_admission_tracks_contained_instances() {
        // a composed pipelined graph admits as one unit but completes per
        // contained instance, and scores identically to the batch simulator
        let spec = NetSpec::micro();
        let hier = Hierarchy::two_level(4, spec.h(), 2).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let part = Partition::contiguous(n_blocks, 2).unwrap();
        let groups = crate::coordinator::InstanceGroups::new(1, 2).unwrap();
        let g = taskgraph::mg_train_pipeline(
            &spec,
            &hier,
            &part,
            &groups,
            1,
            1,
            crate::mgrit::fas::RelaxKind::FCF,
            taskgraph::Granularity::PerStep,
            1,
            2,
            taskgraph::PipeSync::Staleness(0),
        )
        .unwrap();
        let c = cluster(2);
        let batch = simulate(&g, &c, false).unwrap();
        let mut s = SimSession::new(&c, false);
        let first = s.admit_composed(g).unwrap();
        assert_eq!(s.n_instances(), 2);
        s.run_to_idle().unwrap();
        for k in 0..2 {
            assert!(s.finished_at(first + k).is_some(), "instance {k} unfinished");
        }
        // step 0's last retirement cannot come after step 1's
        assert!(s.finished_at(first).unwrap() <= s.finished_at(first + 1).unwrap());
        let rep = s.into_report();
        assert_eq!(rep.n_kernels, batch.n_kernels);
        assert_eq!(rep.makespan_s, batch.makespan_s);
    }

    #[test]
    fn pipelined_makespan_strictly_beats_barrier() {
        // the tentpole perf claim, scored in virtual time: a K = 3, M = 2
        // pipelined training graph at S = 1 overlaps step t+1's forward
        // V-cycles with step t's adjoint/reduction tail, so its makespan on
        // 2 devices is STRICTLY below the barrier-synced composition
        let spec = NetSpec::micro();
        let hier = Hierarchy::two_level(4, spec.h(), 2).unwrap();
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let part = Partition::contiguous(n_blocks, 2).unwrap();
        let groups = crate::coordinator::InstanceGroups::new(1, 2).unwrap();
        let run = |sync| {
            let g = taskgraph::mg_train_pipeline(
                &spec,
                &hier,
                &part,
                &groups,
                1,
                1,
                crate::mgrit::fas::RelaxKind::FCF,
                taskgraph::Granularity::PerStep,
                2,
                3,
                sync,
            )
            .unwrap();
            let c = cluster(2);
            let mut s = SimSession::new(&c, false);
            let first = s.admit_composed(g).unwrap();
            s.run_to_idle().unwrap();
            for k in 0..6 {
                assert!(s.finished_at(first + k).is_some(), "instance {k} unfinished");
            }
            s.into_report().makespan_s
        };
        let barrier = run(taskgraph::PipeSync::Barrier);
        let stale = run(taskgraph::PipeSync::Staleness(1));
        assert!(
            stale < barrier,
            "pipelined makespan {stale} s not strictly below barrier {barrier} s"
        );
    }
}
