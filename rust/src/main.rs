//! `mgrit` — the layer-parallel ResNet coordinator CLI.
//!
//! Subcommands:
//!   forward     MG vs serial forward propagation on real numerics
//!   train       SGD training (serial | MG layer-parallel | hybrid micro-batched), host or PJRT
//!   serve       continuous-batching inference serving through the live multi-instance runtime
//!   experiment  regenerate a paper figure: fig1|fig4|fig5|fig6a|fig6b|fig6c|fig7|hybrid|serve|placement|pipeline|topology|ablations
//!   sim         one simulated MG/PM run at a given GPU count
//!   bench       quick perf snapshot → BENCH_hotpath.json / BENCH_fig6bc.json / BENCH_placement.json / BENCH_pipeline.json / BENCH_topology.json / BENCH_recovery.json / BENCH_transport.json
//!   artifacts   check the AOT artifact manifest against the rust presets
//!   help        this text

use std::sync::Arc;

use anyhow::bail;

use resnet_mgrit::config::RunConfig;
use resnet_mgrit::coordinator::{ParallelMgrit, PlacementKind, TransportMode};
use resnet_mgrit::data::mnist;
use resnet_mgrit::experiments as exp;
use resnet_mgrit::mgrit::hierarchy::Hierarchy;
use resnet_mgrit::mgrit::{Collective, Granularity};
use resnet_mgrit::model::{NetParams, NetSpec};
use resnet_mgrit::solver::host::HostSolver;
use resnet_mgrit::solver::BlockSolver;
use resnet_mgrit::tensor::Tensor;
use resnet_mgrit::train;
use resnet_mgrit::util::args::Args;
use resnet_mgrit::util::prng::Rng;
use resnet_mgrit::util::Timer;
use resnet_mgrit::Result;

const HELP: &str = "mgrit — layer-parallel ResNet training via nonlinear multigrid

USAGE: mgrit <subcommand> [options]

  forward     --preset P --batch B --cycles C --devices D --tol T [--backend host|pjrt]
              [--placement min-id|heft|lookahead]
  train       --preset P --steps N --batch B --lr R --cycles C [--serial] [--backend host|pjrt]
              [--parallel N_DEVICES] [--granularity per_step|per_block] [--micro-batches M]
              [--pipeline-steps K] [--staleness S] [--placement min-id|heft|lookahead]
              [--nodes G] [--collective tree|ring|two-phase] [--transport shared|inproc]
              [--checkpoint-every N] [--checkpoint-path PATH] [--resume PATH]
                --parallel routes every step through the whole-training-step
                task graph (ParallelMgrit::train_step, host backend) and
                prints a one-line speed/parity report vs the serial MG step;
                --micro-batches M splits each batch into M micro-batches
                pipelined through ONE composed graph (hybrid data x layer
                parallelism; batch must divide by M; requires --parallel);
                --pipeline-steps K composes K consecutive training steps into
                ONE cross-step pipelined graph (requires --parallel) and
                --staleness S bounds how stale the parameters a step reads
                may be: S = 0 keeps sequential-SGD semantics bit-for-bit
                while still overlapping cross-step tails, S >= 1 trades
                bounded staleness for makespan (see `experiment pipeline`);
                --placement picks the scheduling & placement policy the
                graphs dispatch under (default heft — the policy-comparison
                winner; min-id is the static-partition legacy order; every
                policy is bit-identical, see `experiment placement`);
                --nodes G splits the workers into G node-level device
                groups (micro-batch instances round-robin across nodes;
                total workers = G x N_DEVICES) and --collective picks the
                gradient-reduction plan joining them: tree (flat pairwise,
                default), ring, or two-phase (reduce inside each node,
                cross the inter-node fabric once — see `experiment
                topology`); every collective is bit-identical to the
                serial reference executing the same plan;
                --transport inproc shards the live runtime into one worker
                pool per node behind the in-process transport: every
                cross-node transfer is serialized through per-NIC send
                queues instead of an Arc handoff (bit-identical outputs;
                default shared = the legacy single pool);
                --checkpoint-every N writes a step-boundary TrainCheckpoint
                to --checkpoint-path (default mgrit-checkpoint.json) every N
                completed steps (the pipelined loop checkpoints at window
                ends), and --resume PATH restarts an interrupted run from
                one — resumed training is bit-identical to never having
                stopped (requires --parallel)
  serve       --requests N --arrival-rate R --deadline-ms D [--preset P] [--devices D]
              [--cycles C] [--inflight W] [--relax F|FC|FCF] [--granularity per_step|per_block]
              [--policy fifo|edf|shape-batch] [--max-queue Q] [--max-batch B]
              [--batch-window-ms W] [--seed S] [--placement min-id|heft|lookahead]
              [--nodes G] [--transport shared|inproc]
              synthetic-load driver: N requests stream through the persistent
              multi-instance runtime as forward-only graph instances
              (continuous batching, window W; R = 0 [default] = all requests
              arrive at once; --seed S makes the synthetic load reproducible
              via per-request Rng::for_instance streams). --policy picks the
              admission scheduler: fifo (arrival order), edf (earliest
              deadline first, sheds hopeless requests), shape-batch (fuses
              up to B same-shape requests arriving within W ms into one
              batched instance); --max-queue bounds the admission queue
              (overflow is shed); --nodes G serves on the sharded runtime
              (one worker pool per node, layer partition spanning nodes,
              cross-node transfers through the in-process transport; G must
              divide the worker count) and --transport picks the substrate
              explicitly (--nodes > 1 implies inproc).
              Prints per-request latency, p50/p95/p99 +
              throughput + sheds, verifies every served output bit-for-bit
              against the serial per-request MGRIT reference, and asserts
              >= 2 instances overlapped in flight on the live ExecEvent
              trace whenever the load held two requests co-resident
  experiment  <fig1|fig4|fig5|fig6a|fig6b|fig6c|fig6t|fig7|hybrid|serve|placement|pipeline|topology|compound|ablations> [--quick]
              (serve prints the continuous-vs-barrier table AND the
               three-way FIFO/EDF/shape-batch policy comparison;
               placement scores min-id vs HEFT vs lookahead dispatch on
               the training graph and a serving drain;
               pipeline sweeps cross-step sync modes — barrier vs
               staleness 0/1/2 — reporting simulated + live makespan
               and the loss trajectory at each staleness bound;
               topology scores the gradient collectives — flat tree vs
               ring vs hierarchical two-phase — across node counts on
               the tiered cluster: makespan, cross-node bytes,
               utilization)
  sim         --preset P --gpus G [--training] [--cycles C]
  bench       [--out DIR] [--full]   quick perf snapshot; writes
              BENCH_hotpath.json + BENCH_fig6bc.json + BENCH_placement.json
              + BENCH_pipeline.json + BENCH_topology.json
              + BENCH_recovery.json + BENCH_transport.json into DIR (default .)
  bench-delta --prev DIR [--cur DIR]   diff BENCH_*.json medians against a
              previous run's records; prints GitHub ::warning:: annotations
              for suites regressing > 10% (advisory, exit 0)
  artifacts   [--artifacts-dir DIR]
  help
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("forward") => cmd_forward(args),
        Some("train") => cmd_train(args),
        Some("serve") => cmd_serve(args),
        Some("experiment") => cmd_experiment(args),
        Some("sim") => cmd_sim(args),
        Some("bench") => cmd_bench(args),
        Some("bench-delta") => cmd_bench_delta(args),
        Some("artifacts") => cmd_artifacts(args),
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other:?}\n{HELP}"),
    }
}

/// MG vs serial forward propagation (host backend, parallel coordinator).
fn cmd_forward(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let spec = Arc::new(NetSpec::by_name(&cfg.preset)?);
    let params = Arc::new(NetParams::init(&spec, cfg.seed)?);
    let n = spec.n_res();
    let h = spec.h();

    let mut rng = Rng::new(cfg.seed + 1);
    let (hh, ww) = spec.hw();
    let u0 = Tensor::randn(&[cfg.batch, spec.channels(), hh, ww], 0.5, &mut rng);

    // serial baseline
    let host = HostSolver::new(spec.clone(), params.clone())?;
    let t = Timer::start();
    let serial = host.block_fprop(0, 1, n, h, &u0)?;
    let serial_s = t.elapsed_s();

    // parallel MG over the dependency-driven DAG executor
    let hier = Hierarchy::build(n, h, spec.coarsen, cfg.max_levels, 8)?;
    let spec2 = spec.clone();
    let params2 = params.clone();
    let factory = move |_w: usize| HostSolver::new(spec2.clone(), params2.clone());
    // CLI default is the policy-comparison winner; the library default stays
    // min-id (see `mgrit experiment placement` for the head-to-head table)
    let placement = PlacementKind::parse(args.get_or("placement", "heft"))?;
    let mut driver = ParallelMgrit::new(factory, spec.clone(), hier, cfg.devices, cfg.batch)?;
    driver.set_placement(placement);
    let t = Timer::start();
    let (mg, stats, metrics) = driver.solve(&u0, &cfg.mgrit_options())?;
    let mg_s = t.elapsed_s();

    let err = resnet_mgrit::util::stats::rel_l2_err(
        mg.last().unwrap().data(),
        serial.last().unwrap().data(),
    );
    println!(
        "preset={} n_res={n} batch={} devices={} placement={}",
        spec.name,
        cfg.batch,
        cfg.devices,
        placement.name()
    );
    println!("serial forward     : {:.1} ms", serial_s * 1e3);
    println!(
        "MG forward         : {:.1} ms  ({} cycles, converged={}, ‖R‖={:.3e})",
        mg_s * 1e3,
        metrics.cycles,
        stats.converged,
        stats.residual_norms.last().copied().unwrap_or(f64::NAN)
    );
    println!("final-state rel err: {err:.3e}");
    println!(
        "comm: {} transfers, {} bytes (local run: accounting only)",
        metrics.comm_events, metrics.comm_bytes
    );
    for (label, secs) in &metrics.phases {
        println!("  phase {label:<14} {:.2} ms", secs * 1e3);
    }
    Ok(())
}

/// SGD training on synthetic MNIST (or idx files in --data-dir).
fn cmd_train(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let spec = Arc::new(NetSpec::by_name(&cfg.preset)?);
    let mut params = NetParams::init(&spec, cfg.seed)?;
    let (data, source) =
        mnist::load_or_synthesize(std::path::Path::new(&cfg.data_dir), 512, cfg.seed)?;
    let parallel = args.usize_or("parallel", 0)?;
    let granularity = Granularity::parse(args.get_or("granularity", "per_step"))?;
    let micro_batches = args.usize_or("micro-batches", 1)?;
    // heft by default: the CLI runs the policy-comparison winner, the
    // library keeps min-id (bit-identical either way)
    let placement = PlacementKind::parse(args.get_or("placement", "heft"))?;
    let nodes = args.usize_or("nodes", 1)?;
    let collective = Collective::parse(args.get_or("collective", "tree"))?;
    let transport = TransportMode::parse(args.get_or("transport", "shared"))?;
    let method = if args.flag("serial") {
        train::Method::Serial
    } else {
        train::Method::Mgrit { cycles: cfg.cycles }
    };
    println!(
        "training preset={} steps={} batch={} lr={} method={method:?} data={source} backend={}",
        spec.name, cfg.steps, cfg.batch, cfg.lr, cfg.backend
    );
    let tc = train::TrainConfig {
        steps: cfg.steps,
        batch: cfg.batch,
        lr: cfg.lr as f32,
        method,
        seed: cfg.seed,
    };
    let pipeline_steps = args.usize_or("pipeline-steps", 1)?;
    let staleness = args.usize_or("staleness", 0)?;
    let ckpt_every = args.usize_or("checkpoint-every", 0)?;
    let ckpt = train::CheckpointConfig {
        every: ckpt_every,
        path: (ckpt_every > 0).then(|| {
            std::path::PathBuf::from(args.get_or("checkpoint-path", "mgrit-checkpoint.json"))
        }),
        resume: args.get("resume").map(std::path::PathBuf::from),
    };
    if (ckpt.every > 0 || ckpt.resume.is_some()) && parallel == 0 {
        bail!("--checkpoint-every / --resume require --parallel (the graph-runtime loops)");
    }
    if let Some(p) = &ckpt.resume {
        println!("resuming from checkpoint {}", p.display());
    }
    if let Some(p) = &ckpt.path {
        println!("checkpointing every {} step(s) -> {}", ckpt.every, p.display());
    }
    if micro_batches != 1 && parallel == 0 {
        bail!("--micro-batches requires --parallel (the multi-instance graph runtime)");
    }
    if pipeline_steps > 1 && parallel == 0 {
        bail!("--pipeline-steps requires --parallel (the multi-instance graph runtime)");
    }
    if staleness > 0 && pipeline_steps <= 1 {
        bail!("--staleness only applies with --pipeline-steps K > 1");
    }
    if nodes == 0 {
        bail!("--nodes must be at least 1");
    }
    if (nodes > 1 || collective != Collective::Tree) && parallel == 0 {
        bail!("--nodes / --collective require --parallel (the multi-instance graph runtime)");
    }
    if transport != TransportMode::Shared && parallel == 0 {
        bail!("--transport requires --parallel (the multi-instance graph runtime)");
    }
    if parallel > 0 {
        // the layer-parallel path: every step is one whole-training-step
        // task graph over `parallel` worker streams (host numerics); with
        // --micro-batches M each step pipelines M micro-batch instances
        // through that one graph (hybrid data×layer parallelism)
        if args.flag("serial") {
            bail!("--parallel requires the MG method (drop --serial)");
        }
        if cfg.backend != "host" {
            bail!("--parallel runs on the host backend (PJRT contexts are per-thread)");
        }
        if pipeline_steps > 1 {
            // cross-step pipelining: K consecutive steps become ONE composed
            // graph; step t reads parameter version max(0, t − S) from the
            // snapshot ring (S = 0 is bit-identical to the sequential loop)
            use resnet_mgrit::mgrit::taskgraph::PipeSync;
            println!(
                "pipelined training: {parallel} devices x {nodes} nodes, \
                 K={pipeline_steps} steps/window, staleness {staleness}, \
                 granularity {granularity:?}, micro-batches {micro_batches}, \
                 placement {}, collective {}, transport {}",
                placement.name(),
                collective.name(),
                transport.name()
            );
            let logs = train::train_parallel_pipelined_sharded(
                &spec,
                &mut params,
                &data,
                &tc,
                parallel,
                granularity,
                micro_batches,
                placement,
                pipeline_steps,
                PipeSync::Staleness(staleness),
                nodes,
                collective,
                &ckpt,
                transport,
            )?;
            // |g| is harvested from each window's ReduceGrad roots — the
            // same reduced-gradient norm the per-step path reports
            for l in logs.iter().step_by((cfg.steps / 20).max(1)) {
                println!("  step {:>4}  loss {:.4}  |g| {:.3}", l.step, l.loss, l.grad_norm);
            }
            let exec = HostSolver::new(spec.clone(), Arc::new(params.clone()))?;
            let err = train::top1_error(&spec, &exec, &data, cfg.batch, 8)?;
            println!("final top-1 error: {:.1}%", err * 100.0);
            return Ok(());
        }
        println!(
            "parallel training: {parallel} devices x {nodes} nodes, \
             granularity {granularity:?}, micro-batches {micro_batches}, \
             placement {}, collective {}, transport {}",
            placement.name(),
            collective.name(),
            transport.name()
        );
        let logs = train::train_parallel_sharded(
            &spec, &mut params, &data, &tc, parallel, granularity, micro_batches, placement,
            nodes, collective, &ckpt, transport,
        )?;
        for l in logs.iter().step_by((cfg.steps / 20).max(1)) {
            println!("  step {:>4}  loss {:.4}  |g| {:.3}", l.step, l.loss, l.grad_norm);
        }
        println!(
            "{}",
            train::parity_report(
                &spec, &params, &data, cfg.batch, cfg.cycles, cfg.lr as f32, parallel,
                granularity, placement,
            )?
        );
        let exec = HostSolver::new(spec.clone(), Arc::new(params.clone()))?;
        let err = train::top1_error(&spec, &exec, &data, cfg.batch, 8)?;
        println!("final top-1 error: {:.1}%", err * 100.0);
        return Ok(());
    }
    // the pjrt backend degrades gracefully (warning + host solver) when
    // artifacts/ was never exported or no PJRT runtime is linked
    let pjrt_store = match cfg.backend.as_str() {
        "host" => None,
        "pjrt" => resnet_mgrit::runtime::ArtifactStore::open_or_fallback(&cfg.artifacts_dir)
            .map(std::rc::Rc::new),
        other => bail!("unknown backend {other}"),
    };
    let logs = match pjrt_store {
        Some(store) => {
            let spec2 = spec.clone();
            let batch = cfg.batch;
            train::train(&spec, &mut params, &data, &tc, move |p| {
                resnet_mgrit::solver::pjrt::PjrtSolver::new(
                    store.clone(),
                    spec2.clone(),
                    Arc::new(p.clone()),
                    batch,
                )
            })?
        }
        None => {
            let spec2 = spec.clone();
            train::train(&spec, &mut params, &data, &tc, move |p| {
                HostSolver::new(spec2.clone(), Arc::new(p.clone()))
            })?
        }
    };
    for l in logs.iter().step_by((cfg.steps / 20).max(1)) {
        println!("  step {:>4}  loss {:.4}  |g| {:.3}", l.step, l.loss, l.grad_norm);
    }
    let exec = HostSolver::new(spec.clone(), Arc::new(params.clone()))?;
    let err = train::top1_error(&spec, &exec, &data, cfg.batch, 8)?;
    println!("final top-1 error: {:.1}%", err * 100.0);
    Ok(())
}

/// Policy-driven continuous-batching inference serving through the live
/// multi-instance runtime: N synthetic requests stream through one
/// persistent pool as forward-only graph instances under the chosen
/// admission policy; every served output is checked bit-for-bit against
/// the serial per-request MGRIT reference, and the live `ExecEvent` trace
/// must show ≥ 2 request instances concurrently in flight.
fn cmd_serve(args: &Args) -> Result<()> {
    use resnet_mgrit::serving::{self, InferRequest, PolicyKind, ServeConfig, ServingRuntime};

    let cfg = RunConfig::from_args(args)?;
    let n_requests = args.usize_or("requests", 12)?;
    // 0 = burst: every request arrives at t = 0 (guarantees a contended pool)
    let rate = args.f64_or("arrival-rate", 0.0)?;
    let deadline_ms = args.f64_or("deadline-ms", 0.0)?;
    let deadline = (deadline_ms > 0.0).then_some(deadline_ms);
    let inflight = args.usize_or("inflight", 4)?;
    let max_batch = args.usize_or("max-batch", 4)?;
    let batch_window_ms = args.f64_or("batch-window-ms", 2.0)?;
    let policy = PolicyKind::parse(args.get_or("policy", "fifo"), max_batch, batch_window_ms)?;
    let placement = PlacementKind::parse(args.get_or("placement", "heft"))?;
    let max_queue = match args.usize_or("max-queue", 0)? {
        0 => None,
        q => Some(q),
    };
    anyhow::ensure!(n_requests >= 1, "--requests must be at least 1");
    let nodes = args.usize_or("nodes", 1)?;
    anyhow::ensure!(nodes >= 1, "--nodes must be at least 1");
    // --nodes > 1 implies the sharded substrate; --transport can also force
    // it at 1 node (loopback elision only) or be stated explicitly
    let transport = TransportMode::parse(
        args.get_or("transport", if nodes > 1 { "inproc" } else { "shared" }),
    )?;
    if nodes > 1 && transport == TransportMode::Shared {
        bail!("--nodes {nodes} requires --transport inproc (the sharded runtime)");
    }

    let spec = Arc::new(NetSpec::by_name(&cfg.preset)?);
    let params = Arc::new(NetParams::init(&spec, cfg.seed)?);
    let hier = Hierarchy::build(spec.n_res(), spec.h(), spec.coarsen, cfg.max_levels, 8)?;

    // synthetic open-loop load: request k arrives at k/rate with its own
    // deterministic input stream. Generated BEFORE the runtime so the
    // serving clock (the pool epoch) starts after setup — arrival offsets
    // and latencies must not absorb tensor-generation time
    let o = &spec.opening;
    let mut inputs = Vec::with_capacity(n_requests);
    let mut requests = Vec::with_capacity(n_requests);
    for k in 0..n_requests {
        let mut rng = Rng::for_instance(cfg.seed, k as u64);
        let input = Tensor::randn(&[1, o.in_channels, o.in_h, o.in_w], 0.5, &mut rng);
        let arrival_s = if rate > 0.0 { k as f64 / rate } else { 0.0 };
        inputs.push(input.clone());
        requests.push(InferRequest { id: k as u64, input, arrival_s, deadline_ms: deadline });
    }

    let spec2 = spec.clone();
    let params2 = params.clone();
    let factory = move |_w: usize| HostSolver::new(spec2.clone(), params2.clone());
    let serve_cfg = ServeConfig {
        cycles: cfg.cycles,
        relax: cfg.relax,
        granularity: Granularity::parse(args.get_or("granularity", "per_step"))?,
        max_inflight: inflight,
        policy,
        max_queue,
        placement,
    };
    let mut rt = match transport {
        TransportMode::Shared => {
            ServingRuntime::new(factory, spec.clone(), hier.clone(), cfg.devices, serve_cfg)?
        }
        TransportMode::InProc => ServingRuntime::new_sharded(
            factory,
            spec.clone(),
            hier.clone(),
            cfg.devices,
            nodes,
            serve_cfg,
        )?,
    };
    println!(
        "serving preset={} devices={} nodes={nodes} transport={} cycles={} inflight={inflight} \
         policy={} placement={} requests={n_requests} arrival_rate={rate}/s deadline={} \
         max_queue={} seed={}",
        spec.name,
        rt.partition().n_devices(),
        transport.name(),
        cfg.cycles,
        policy.name(),
        placement.name(),
        deadline.map(|d| format!("{d} ms")).unwrap_or_else(|| "none".into()),
        max_queue.map(|q| q.to_string()).unwrap_or_else(|| "unbounded".into()),
        cfg.seed,
    );
    for req in requests {
        rt.submit(req);
    }
    let report = rt.run()?;

    for r in &report.records {
        println!(
            "  req {:>3}  arrival {:>7.1} ms  latency {:>8.2} ms  pred {}  {}",
            r.id,
            r.arrival_s * 1e3,
            r.latency_ms,
            r.predicted.first().copied().unwrap_or(0),
            match (r.deadline_ms, r.missed_deadline) {
                (None, _) => "",
                (Some(_), false) => "deadline ok",
                (Some(_), true) => "DEADLINE MISS",
            }
        );
    }
    for s in &report.sheds {
        println!(
            "  req {:>3}  arrival {:>7.1} ms  SHED at {:>8.2} ms ({:?})",
            s.id,
            s.arrival_s * 1e3,
            s.shed_s * 1e3,
            s.reason
        );
    }
    println!("{}", report.summary.render());

    // correctness gate: every SERVED output bit-identical to the serial
    // per-request MGRIT reference (same hierarchy, same early-stopped
    // cycles) — shed requests have no output to compare, and coalesced
    // requests are compared per-request after the harvest fan-out
    let exec = HostSolver::new(spec.clone(), params)?;
    let opts = rt.mgrit_options();
    for r in &report.records {
        let (u_ref, logits_ref) =
            serving::serial_reference(&exec, &hier, &inputs[r.id as usize], &opts)?;
        anyhow::ensure!(
            r.output.data() == u_ref.data() && r.logits.data() == logits_ref.data(),
            "request {} output differs from the serial reference",
            r.id
        );
    }
    println!(
        "parity: all {}/{n_requests} served outputs bit-identical to the serial MGRIT reference \
         ({} shed)",
        report.records.len(),
        report.sheds.len()
    );
    if let Some(stats) = rt.pool().transport_stats() {
        println!(
            "transport: {} cross-node message(s), {} wire bytes, {} loopback elision(s)",
            stats.messages, stats.bytes, stats.loopback
        );
    }

    // concurrency gate: the continuous-batching property on the live
    // ExecEvent trace. It is a HARD assertion for a FIFO burst load (rate 0
    // — the default — queues every request up front, so with ≥ 2 in-flight
    // slots over ≥ 2 workers, kernel overlap must occur). Under a paced
    // arrival rate a fast pool can legitimately drain each request before
    // the next one's kernels start; under EDF shedding or a bounded queue
    // fewer than 2 instances may survive; under shape-batch the whole load
    // may coalesce into one instance — so there overlap is reported, not
    // required.
    let burst = rate <= 0.0;
    let fifo_unbounded = policy == PolicyKind::Fifo && max_queue.is_none();
    if n_requests >= 2 && inflight >= 2 && rt.partition().n_devices() >= 2 && burst
        && fifo_unbounded
    {
        anyhow::ensure!(
            report.shows_overlap(),
            "no two request instances were ever concurrently in flight"
        );
        println!(
            "concurrency: {} instances traced, cross-request overlap observed on the live trace",
            report.n_instances()
        );
    } else if report.shows_overlap() {
        println!(
            "concurrency: {} instances traced, cross-instance overlap observed on the live trace",
            report.n_instances()
        );
    } else {
        println!(
            "concurrency: no cross-instance kernel overlap under this load \
             (raise --requests/--inflight or lower --arrival-rate)"
        );
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let quick = args.flag("quick");
    let run_one = |name: &str| -> Result<()> {
        match name {
            "fig1" => println!("{}", exp::fig1::run().render()),
            "fig4" => {
                let depths: &[usize] =
                    if quick { &[64, 128, 256] } else { &[128, 512, 2048, 4096] };
                let cycles = if quick { 6 } else { 10 };
                println!("{}", exp::fig4::run(depths, cycles, 11)?.render());
            }
            "fig5" => {
                let (t, ascii) = exp::fig5::run(if quick { 256 } else { 0 })?;
                println!("{}", t.render());
                println!("{ascii}");
            }
            "fig6a" => {
                let gpus: &[usize] = if quick { &[1, 4, 24] } else { &exp::fig6::GPU_COUNTS };
                println!("{}", exp::fig6::fig6a(gpus)?.render());
            }
            "fig6b" => {
                let gpus: &[usize] = if quick { &[1, 4, 24] } else { &exp::fig6::GPU_COUNTS };
                println!("{}", exp::fig6::fig6b(gpus)?.render());
            }
            "fig6c" => {
                let gpus: &[usize] = if quick { &[4, 24] } else { &exp::fig6::GPU_COUNTS };
                println!("{}", exp::fig6::fig6c(gpus)?.render());
            }
            "fig6t" => {
                let (depth, devices) = if quick { (32, 2) } else { (64, 4) };
                let (t, ascii) = exp::fig6::training_timeline(depth, devices)?;
                println!("{}", t.render());
                println!("{ascii}");
            }
            "hybrid" => {
                let (depth, devices, micro) = if quick { (32, 2, 2) } else { (64, 4, 4) };
                println!("{}", exp::fig6::hybrid_timeline(depth, devices, micro)?.render());
            }
            "serve" => {
                let (depth, devices, n, window) =
                    if quick { (32, 2, 8, 2) } else { (64, 4, 32, 4) };
                println!(
                    "{}",
                    exp::serve::run(depth, devices, n, 20_000.0, window, Some(50.0))?.render()
                );
                // the three-way scheduler comparison on one matched burst
                // load (FIFO vs EDF vs shape-batch, deterministic sim)
                println!(
                    "{}",
                    exp::serve::policy_comparison(depth, devices, n, window, 4, 1.0)?.render()
                );
            }
            "placement" => {
                // min-id vs HEFT vs lookahead on the training graph and a
                // FIFO serving drain (deterministic virtual timeline)
                let (depth, devices, micro) = if quick { (32, 4, 2) } else { (64, 4, 2) };
                for t in exp::placement::run(depth, devices, micro)? {
                    println!("{}", t.render());
                }
            }
            "pipeline" => {
                // cross-step barrier vs bounded staleness: simulated
                // makespan sweep, live micro-preset window, loss trajectory
                let (depth, devices, k) = if quick { (32, 2, 3) } else { (64, 4, 4) };
                for t in exp::pipeline::run(depth, devices, k)? {
                    println!("{}", t.render());
                }
            }
            "topology" => {
                // flat tree vs ring vs hierarchical two-phase gradient
                // collectives across node counts (tiered virtual cluster)
                for t in exp::topology::run(quick)? {
                    println!("{}", t.render());
                }
            }
            "fig7" => {
                let gpus: &[usize] = if quick { &[1, 4, 64] } else { &exp::fig7::GPU_COUNTS };
                println!("{}", exp::fig7::run(gpus)?.render());
            }
            "compound" => {
                let devices = if quick { 16 } else { 64 };
                println!("{}", exp::compound::run("fig6", devices)?.render());
            }
            "ablations" => {
                println!("{}", exp::ablations::cycles_and_relax(20)?.render());
                println!("{}", exp::ablations::coarsening(21)?.render());
                println!("{}", exp::ablations::hierarchy_depth(16)?.render());
            }
            other => bail!("unknown experiment {other:?}"),
        }
        Ok(())
    };
    if which == "all" {
        for name in ["fig1", "fig4", "fig5", "fig6a", "fig6b", "fig6c", "fig6t", "fig7", "hybrid", "serve", "placement", "pipeline", "topology", "compound", "ablations"] {
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(which)
    }
}

/// Quick perf snapshot without `cargo bench`: emits the machine-readable
/// BENCH_hotpath.json / BENCH_fig6bc.json / BENCH_placement.json /
/// BENCH_pipeline.json / BENCH_topology.json / BENCH_recovery.json /
/// BENCH_transport.json perf-trajectory records into `--out` (default: the
/// current directory — the repo root in CI).
fn cmd_bench(args: &Args) -> Result<()> {
    let out = std::path::PathBuf::from(args.get_or("out", "."));
    if args.flag("full") {
        eprintln!("note: `bench` always runs in quick-iteration mode; use `cargo bench` for full runs");
    }
    let p1 = exp::perf::emit_hotpath(&out)?;
    let p2 = exp::perf::emit_fig6bc(&out)?;
    let p3 = exp::perf::emit_placement(&out)?;
    let p4 = exp::perf::emit_pipeline(&out)?;
    let p5 = exp::perf::emit_topology(&out)?;
    let p6 = exp::perf::emit_recovery(&out)?;
    let p7 = exp::perf::emit_transport(&out)?;
    println!(
        "perf records: {} , {} , {} , {} , {} , {} , {}",
        p1.display(),
        p2.display(),
        p3.display(),
        p4.display(),
        p5.display(),
        p6.display(),
        p7.display()
    );
    Ok(())
}

/// Diff freshly emitted BENCH_*.json medians against the previous run's
/// records, printing GitHub annotation lines for regressions > 10%. A
/// missing `--prev` is a usage error; any *analysis* failure (stale or
/// incompatible cached records, a schema change between runs) downgrades to
/// a `::notice::` line and exits 0 — the perf trajectory annotates the run,
/// it must never gate it.
fn cmd_bench_delta(args: &Args) -> Result<()> {
    let prev = std::path::PathBuf::from(
        args.get("prev").ok_or_else(|| anyhow::anyhow!("--prev DIR is required"))?,
    );
    let cur = std::path::PathBuf::from(args.get_or("cur", "."));
    match exp::perf::bench_delta(&prev, &cur) {
        Ok(lines) => {
            for line in lines {
                println!("{line}");
            }
        }
        Err(e) => println!("::notice title=bench delta skipped::{e:#}"),
    }
    Ok(())
}

fn cmd_sim(args: &Args) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let gpus = args.usize_or("gpus", cfg.devices)?;
    let training = args.flag("training");
    let spec = NetSpec::by_name(&cfg.preset)?;
    let mg = exp::fig6::simulate_mg(&spec, gpus, cfg.cycles, training)?;
    let pm = exp::fig6::simulate_pm(&spec, gpus, training)?;
    println!(
        "preset={} gpus={gpus} training={training} cycles={}",
        spec.name, cfg.cycles
    );
    println!(
        "MG : {:>10.3} ms  kernels={} comms={} compute_frac={:.3}",
        mg.makespan_s * 1e3,
        mg.n_kernels,
        mg.n_comms,
        mg.compute_fraction()
    );
    println!(
        "PM : {:>10.3} ms  kernels={} comms={} compute_frac={:.3}",
        pm.makespan_s * 1e3,
        pm.n_kernels,
        pm.n_comms,
        pm.compute_fraction()
    );
    println!("MG speedup vs PM: {:.2}x", pm.makespan_s / mg.makespan_s);
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts-dir", "artifacts");
    if !resnet_mgrit::runtime::Manifest::present_in(dir) {
        println!(
            "no AOT artifacts at {dir:?} — run `make artifacts` to export them. \
             The pjrt backend falls back to the host solver without them."
        );
        return Ok(());
    }
    let manifest = resnet_mgrit::runtime::Manifest::load(dir)?;
    println!("manifest: {} entries, {} presets", manifest.entries.len(), manifest.presets.len());
    for (name, info) in &manifest.presets {
        match NetSpec::by_name(name) {
            Ok(spec) => {
                manifest.check_spec(&spec)?;
                println!(
                    "  preset {name}: OK (C={} N={} c={} batches {:?})",
                    info.channels, info.n_res, info.block, info.batches
                );
            }
            Err(_) => println!("  preset {name}: no rust-side spec (skipped)"),
        }
    }
    Ok(())
}
