//! `BlockSolver` — the numerics boundary of the MGRIT engine.
//!
//! The engine (mgrit/) is pure coordination algebra: it never computes a
//! convolution itself, it asks a solver to apply the layer propagator
//! Φ(u) = u + h·F(u; θ_i) (and its adjoint). Three implementations:
//!
//! - [`host::HostSolver`] — pure-rust tensor ops; the CPU-numerics path and
//!   the oracle the artifact path is tested against.
//! - [`pjrt::PjrtSolver`] — executes the AOT JAX/Pallas artifacts through the
//!   PJRT C API; the production path (Python never runs at request time).
//! - cost-only evaluation for the 2B-parameter scaling studies lives in the
//!   simulator (`sim::run`), which consumes task graphs instead of tensors —
//!   no solver needed there.

pub mod host;
pub mod pjrt;

use crate::model::NetParams;
use crate::tensor::Tensor;
use crate::Result;

/// Applies residual-layer propagators by fine-level layer index. `h` is
/// passed per call because coarse MGRIT levels rescale it (H = c·h).
///
/// Deliberately NOT `Send`/`Sync`: the PJRT client types are single-threaded
/// (`Rc` + raw pointers). The parallel coordinator gives each worker thread
/// its *own* solver instance via a [`SolverFactory`] — exactly how the
/// paper's MPI implementation gives each rank its own CuDNN context.
pub trait BlockSolver {
    /// Φ_i(u) = u + h·F(u; θ_i).
    fn step(&self, fine_idx: usize, h: f32, u: &Tensor) -> Result<Tensor>;

    /// Propagate `count` consecutive layers starting at `start` with stride
    /// `stride` (coarse levels use stride = cˡ), returning every intermediate
    /// state (length `count`). Implementations may batch this (the PJRT
    /// solver executes a whole block artifact in one call).
    fn block_fprop(
        &self,
        start: usize,
        stride: usize,
        count: usize,
        h: f32,
        u0: &Tensor,
    ) -> Result<Vec<Tensor>> {
        let mut out = Vec::with_capacity(count);
        let mut u = u0.clone();
        for j in 0..count {
            u = self.step(start + j * stride, h, &u)?;
            out.push(u.clone());
        }
        Ok(out)
    }

    /// Adjoint propagator: λ + h·(∂F/∂u(u; θ_i))ᵀ λ, where `u` is the
    /// forward state at the *input* of layer i.
    fn adjoint_step(&self, fine_idx: usize, h: f32, u: &Tensor, lam: &Tensor) -> Result<Tensor>;

    /// Layer-local parameter gradient: ∂⟨λ, Φ_i(u)⟩/∂θ_i as (dW, db).
    fn param_grad(
        &self,
        fine_idx: usize,
        h: f32,
        u: &Tensor,
        lam: &Tensor,
    ) -> Result<(Tensor, Tensor)>;
}

/// A solver that also evaluates the non-trunk layers (opening, head) and
/// exposes its parameter snapshot — everything a whole-training-step task
/// graph needs beyond the trunk propagators. Implemented by `HostSolver`
/// and `PjrtSolver`; re-exported from `train` for the training loops.
pub trait NetExecutor: BlockSolver {
    /// Opening layer: raw input y → trunk state u^0.
    fn opening(&self, y: &Tensor) -> Result<Tensor>;
    /// Head forward: (logits, mean cross-entropy loss) at state u.
    fn head(&self, u: &Tensor, labels: &[i32]) -> Result<(Tensor, f64)>;
    /// Head VJP: (∂loss/∂u, dW_fc, db_fc) at state u.
    fn head_vjp(&self, u: &Tensor, labels: &[i32]) -> Result<(Tensor, Tensor, Tensor)>;
    /// Head logits only — the inference/serving epilogue, where no labels
    /// exist. Default: evaluate [`NetExecutor::head`] with placeholder
    /// labels and discard the loss; implementations with a logits-only
    /// entry point should override.
    fn logits(&self, u: &Tensor) -> Result<Tensor> {
        let batch = u.dims().first().copied().unwrap_or(1);
        Ok(self.head(u, &vec![0i32; batch])?.0)
    }
    /// The parameter snapshot this executor was built over.
    fn net_params(&self) -> &NetParams;
}

impl NetExecutor for host::HostSolver {
    fn opening(&self, y: &Tensor) -> Result<Tensor> {
        host::HostSolver::opening(self, y)
    }
    fn head(&self, u: &Tensor, labels: &[i32]) -> Result<(Tensor, f64)> {
        host::HostSolver::head(self, u, labels)
    }
    fn head_vjp(&self, u: &Tensor, labels: &[i32]) -> Result<(Tensor, Tensor, Tensor)> {
        host::HostSolver::head_vjp(self, u, labels)
    }
    fn net_params(&self) -> &NetParams {
        self.params()
    }
}

impl NetExecutor for pjrt::PjrtSolver {
    fn opening(&self, y: &Tensor) -> Result<Tensor> {
        pjrt::PjrtSolver::opening(self, y)
    }
    fn head(&self, u: &Tensor, labels: &[i32]) -> Result<(Tensor, f64)> {
        pjrt::PjrtSolver::head(self, u, labels)
    }
    fn head_vjp(&self, u: &Tensor, labels: &[i32]) -> Result<(Tensor, Tensor, Tensor)> {
        pjrt::PjrtSolver::head_vjp(self, u, labels)
    }
    fn net_params(&self) -> &NetParams {
        self.params()
    }
}

/// Builds one solver per worker thread (PJRT contexts are not `Send`, so
/// each worker constructs its own inside the thread — the moral equivalent
/// of the paper's per-MPI-rank CuDNN handle).
pub trait SolverFactory: Send + Clone + 'static {
    /// The solver type each worker owns.
    type Solver: BlockSolver;
    /// Construct worker `worker`'s solver (called inside its thread).
    fn build(&self, worker: usize) -> Result<Self::Solver>;
}

/// Factory from a plain closure.
impl<S, F> SolverFactory for F
where
    S: BlockSolver,
    F: Fn(usize) -> Result<S> + Send + Clone + 'static,
{
    type Solver = S;
    fn build(&self, worker: usize) -> Result<S> {
        self(worker)
    }
}
