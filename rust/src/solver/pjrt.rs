//! `BlockSolver` over the AOT JAX/Pallas artifacts, executed through PJRT.
//!
//! This is the production numerics path: every Φ application, adjoint step
//! and parameter gradient is an HLO executable compiled once from the
//! Pallas-kernel lowering (`python/compile/`), fed with parameter literals
//! packed on the rust side. Numerical agreement with [`super::host`] is
//! asserted by `tests/pjrt_roundtrip.rs`.

use std::collections::HashMap;
use std::rc::Rc;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail};

use super::BlockSolver;
use crate::model::spec::{LayerKind, NetSpec};
use crate::model::NetParams;
use crate::runtime::client::{
    labels_to_literal, literal_to_scalar, literal_to_tensor, scalar_literal, tensor_to_literal,
};
use crate::runtime::{ArtifactStore, EntryKey};
use crate::tensor::Tensor;
use crate::Result;

/// Executes layer propagators via the AOT artifacts of one preset at one
/// batch size.
pub struct PjrtSolver {
    store: Rc<ArtifactStore>,
    spec: Arc<NetSpec>,
    params: Arc<NetParams>,
    batch: usize,
    /// Cache of stacked block weights keyed by (start, stride): the block
    /// artifact takes θ for its c layers as one [c, C, C, k, k] tensor.
    packed: Mutex<HashMap<(usize, usize), (Tensor, Tensor)>>,
}

impl PjrtSolver {
    /// A solver executing AOT artifacts for `spec` at a fixed batch size.
    pub fn new(
        store: Rc<ArtifactStore>,
        spec: Arc<NetSpec>,
        params: Arc<NetParams>,
        batch: usize,
    ) -> Result<PjrtSolver> {
        let info = store.manifest.check_spec(&spec)?;
        if !info.batches.contains(&batch) {
            bail!(
                "preset {:?} exported for batches {:?}, not {batch}",
                spec.name,
                info.batches
            );
        }
        if spec.trunk.iter().any(|l| matches!(l, LayerKind::Fc { .. })) {
            bail!("PJRT solver supports conv trunks only (preset {:?})", spec.name);
        }
        if params.trunk.len() != spec.n_res() {
            bail!("params/spec trunk mismatch");
        }
        Ok(PjrtSolver { store, spec, params, batch, packed: Mutex::new(HashMap::new()) })
    }

    /// The network spec this solver evaluates.
    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }

    /// The parameter snapshot this solver was built over.
    pub fn params(&self) -> &NetParams {
        &self.params
    }

    /// The batch size the artifacts were lowered for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    fn key(&self, entry: &str) -> EntryKey {
        EntryKey::new(&self.spec.name, entry, self.batch)
    }

    fn check_batch(&self, u: &Tensor) -> Result<()> {
        if u.dims().first() != Some(&self.batch) {
            bail!("tensor batch {:?} != solver batch {}", u.dims().first(), self.batch);
        }
        Ok(())
    }

    /// Stack θ for a block's layers into the artifact's [c, …] layout.
    fn packed_block(&self, start: usize, stride: usize, count: usize) -> Result<(Tensor, Tensor)> {
        if let Some(p) = self.packed.lock().unwrap().get(&(start, stride)) {
            return Ok(p.clone());
        }
        let c = self.spec.channels();
        let k = match self.spec.trunk[start] {
            LayerKind::Conv { kernel, .. } => kernel,
            LayerKind::Fc { .. } => bail!("FC layer in conv trunk"),
        };
        let mut wdata = Vec::with_capacity(count * c * c * k * k);
        let mut bdata = Vec::with_capacity(count * c);
        for j in 0..count {
            let idx = start + j * stride;
            let (w, b) = self
                .params
                .trunk
                .get(idx)
                .ok_or_else(|| anyhow!("layer {idx} out of range"))?;
            wdata.extend_from_slice(w.data());
            bdata.extend_from_slice(b.data());
        }
        let ws = Tensor::new(vec![count, c, c, k, k], wdata)?;
        let bs = Tensor::new(vec![count, c], bdata)?;
        self.packed
            .lock()
            .unwrap()
            .insert((start, stride), (ws.clone(), bs.clone()));
        Ok((ws, bs))
    }

    // ------------------------------------------------------------------
    // non-trunk entry points (opening, head, serial baseline)
    // ------------------------------------------------------------------

    /// Opening layer via the `opening_fwd` artifact.
    pub fn opening(&self, y: &Tensor) -> Result<Tensor> {
        self.check_batch(y)?;
        let out = self.store.run(
            &self.key("opening_fwd"),
            &[
                tensor_to_literal(y)?,
                tensor_to_literal(&self.params.w_open)?,
                tensor_to_literal(&self.params.b_open)?,
            ],
        )?;
        literal_to_tensor(&out[0])
    }

    /// Classifier head via the `head_fwd` artifact: (logits, loss).
    pub fn head(&self, u: &Tensor, labels: &[i32]) -> Result<(Tensor, f64)> {
        self.check_batch(u)?;
        let out = self.store.run(
            &self.key("head_fwd"),
            &[
                tensor_to_literal(u)?,
                tensor_to_literal(&self.params.w_fc)?,
                tensor_to_literal(&self.params.b_fc)?,
                labels_to_literal(labels),
            ],
        )?;
        Ok((literal_to_tensor(&out[0])?, literal_to_scalar(&out[1])?))
    }

    /// Head gradient via the `head_vjp` artifact: (du, dwfc, dbfc).
    pub fn head_vjp(&self, u: &Tensor, labels: &[i32]) -> Result<(Tensor, Tensor, Tensor)> {
        self.check_batch(u)?;
        let out = self.store.run(
            &self.key("head_vjp"),
            &[
                tensor_to_literal(u)?,
                tensor_to_literal(&self.params.w_fc)?,
                tensor_to_literal(&self.params.b_fc)?,
                labels_to_literal(labels),
            ],
        )?;
        Ok((
            literal_to_tensor(&out[0])?,
            literal_to_tensor(&out[1])?,
            literal_to_tensor(&out[2])?,
        ))
    }

    /// Whole-network serial forward via the `serial_fwd` artifact
    /// (the sequential baseline): (logits, loss, u_final).
    pub fn serial_fwd(&self, y: &Tensor, labels: &[i32]) -> Result<(Tensor, f64, Tensor)> {
        self.check_batch(y)?;
        let n = self.spec.n_res();
        let (ws, bs) = self.packed_block(0, 1, n)?;
        let out = self.store.run(
            &self.key("serial_fwd"),
            &[
                tensor_to_literal(y)?,
                tensor_to_literal(&self.params.w_open)?,
                tensor_to_literal(&self.params.b_open)?,
                tensor_to_literal(&ws)?,
                tensor_to_literal(&bs)?,
                tensor_to_literal(&self.params.w_fc)?,
                tensor_to_literal(&self.params.b_fc)?,
                labels_to_literal(labels),
            ],
        )?;
        Ok((
            literal_to_tensor(&out[0])?,
            literal_to_scalar(&out[1])?,
            literal_to_tensor(&out[2])?,
        ))
    }
}

impl BlockSolver for PjrtSolver {
    fn step(&self, fine_idx: usize, h: f32, u: &Tensor) -> Result<Tensor> {
        self.check_batch(u)?;
        let (w, b) = self
            .params
            .trunk
            .get(fine_idx)
            .ok_or_else(|| anyhow!("layer {fine_idx} out of range"))?;
        let out = self.store.run(
            &self.key("step_fwd"),
            &[
                tensor_to_literal(u)?,
                tensor_to_literal(w)?,
                tensor_to_literal(b)?,
                scalar_literal(h),
            ],
        )?;
        literal_to_tensor(&out[0])
    }

    fn block_fprop(
        &self,
        start: usize,
        stride: usize,
        count: usize,
        h: f32,
        u0: &Tensor,
    ) -> Result<Vec<Tensor>> {
        self.check_batch(u0)?;
        // the block artifact is specialized for count == c (the coarsening
        // factor); other counts fall back to repeated single steps
        if count != self.spec.coarsen {
            let mut out = Vec::with_capacity(count);
            let mut u = u0.clone();
            for j in 0..count {
                u = self.step(start + j * stride, h, &u)?;
                out.push(u.clone());
            }
            return Ok(out);
        }
        let (ws, bs) = self.packed_block(start, stride, count)?;
        let out = self.store.run(
            &self.key("block_fwd"),
            &[
                tensor_to_literal(u0)?,
                tensor_to_literal(&ws)?,
                tensor_to_literal(&bs)?,
                scalar_literal(h),
            ],
        )?;
        // result is [c, B, C, H, W] — split along the leading axis
        let stacked = literal_to_tensor(&out[0])?;
        let inner: Vec<usize> = stacked.dims()[1..].to_vec();
        let stride_elems: usize = inner.iter().product();
        let mut states = Vec::with_capacity(count);
        for j in 0..count {
            let slice = &stacked.data()[j * stride_elems..(j + 1) * stride_elems];
            states.push(Tensor::new(inner.clone(), slice.to_vec())?);
        }
        Ok(states)
    }

    fn adjoint_step(&self, fine_idx: usize, h: f32, u: &Tensor, lam: &Tensor) -> Result<Tensor> {
        self.check_batch(u)?;
        let (w, b) = &self.params.trunk[fine_idx];
        let out = self.store.run(
            &self.key("adjoint_step"),
            &[
                tensor_to_literal(u)?,
                tensor_to_literal(w)?,
                tensor_to_literal(b)?,
                scalar_literal(h),
                tensor_to_literal(lam)?,
            ],
        )?;
        literal_to_tensor(&out[0])
    }

    fn param_grad(
        &self,
        fine_idx: usize,
        h: f32,
        u: &Tensor,
        lam: &Tensor,
    ) -> Result<(Tensor, Tensor)> {
        self.check_batch(u)?;
        let (w, b) = &self.params.trunk[fine_idx];
        let out = self.store.run(
            &self.key("step_param_grad"),
            &[
                tensor_to_literal(u)?,
                tensor_to_literal(w)?,
                tensor_to_literal(b)?,
                scalar_literal(h),
                tensor_to_literal(lam)?,
            ],
        )?;
        Ok((literal_to_tensor(&out[0])?, literal_to_tensor(&out[1])?))
    }
}

// PjrtSolver construction-validation tests are in tests/pjrt_roundtrip.rs
// (they need a live PJRT client and the artifacts directory).
