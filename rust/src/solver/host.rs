//! Pure-rust `BlockSolver`: applies layer propagators with the `tensor::ops`
//! kernels. This is the CPU-numerics reference path — the PJRT path is
//! required to agree with it to float tolerance (tests/pjrt_roundtrip.rs).

use std::sync::Arc;

use anyhow::bail;

use super::BlockSolver;
use crate::model::spec::{LayerKind, NetSpec};
use crate::model::NetParams;
use crate::tensor::{ops, vjp, Tensor};
use crate::Result;

/// Host solver: owns (a shared handle to) the spec and parameters.
#[derive(Clone)]
pub struct HostSolver {
    spec: Arc<NetSpec>,
    params: Arc<NetParams>,
}

impl HostSolver {
    /// A solver over a parameter snapshot (validated against `spec`).
    pub fn new(spec: Arc<NetSpec>, params: Arc<NetParams>) -> Result<HostSolver> {
        if params.trunk.len() != spec.n_res() {
            bail!(
                "params have {} trunk layers, spec {:?} has {}",
                params.trunk.len(),
                spec.name,
                spec.n_res()
            );
        }
        Ok(HostSolver { spec, params })
    }

    /// The network spec this solver evaluates.
    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }

    /// The parameter snapshot this solver was built over.
    pub fn params(&self) -> &NetParams {
        &self.params
    }

    fn layer(&self, i: usize) -> Result<(&LayerKind, &Tensor, &Tensor)> {
        if i >= self.spec.n_res() {
            bail!("layer index {i} out of range (n_res {})", self.spec.n_res());
        }
        let (w, b) = &self.params.trunk[i];
        Ok((&self.spec.trunk[i], w, b))
    }

    /// Opening layer: y [B,1,H,W] → u0 (not part of the MGRIT system).
    pub fn opening(&self, y: &Tensor) -> Result<Tensor> {
        let o = &self.spec.opening;
        let mut u = ops::conv2d(y, &self.params.w_open, o.pad)?;
        ops::add_bias(&mut u, &self.params.b_open)?;
        ops::relu(&mut u);
        Ok(u)
    }

    /// Classifier head: (logits, loss).
    pub fn head(&self, u: &Tensor, labels: &[i32]) -> Result<(Tensor, f64)> {
        ops::head_fwd(u, &self.params.w_fc, &self.params.b_fc, labels)
    }

    /// Head gradient: (du, dwfc, dbfc).
    pub fn head_vjp(&self, u: &Tensor, labels: &[i32]) -> Result<(Tensor, Tensor, Tensor)> {
        vjp::head_vjp(u, &self.params.w_fc, &self.params.b_fc, labels)
    }
}

impl BlockSolver for HostSolver {
    fn step(&self, fine_idx: usize, h: f32, u: &Tensor) -> Result<Tensor> {
        let (kind, w, b) = self.layer(fine_idx)?;
        match kind {
            LayerKind::Conv { kernel, .. } => ops::residual_step(u, w, b, h, kernel / 2),
            LayerKind::Fc { .. } => ops::residual_fc_step(u, w, b, h),
        }
    }

    fn adjoint_step(&self, fine_idx: usize, h: f32, u: &Tensor, lam: &Tensor) -> Result<Tensor> {
        let (kind, w, b) = self.layer(fine_idx)?;
        match kind {
            LayerKind::Conv { kernel, .. } => vjp::adjoint_step(u, w, b, h, kernel / 2, lam),
            LayerKind::Fc { .. } => Ok(vjp::residual_fc_step_vjp(u, w, b, h, lam)?.0),
        }
    }

    fn param_grad(
        &self,
        fine_idx: usize,
        h: f32,
        u: &Tensor,
        lam: &Tensor,
    ) -> Result<(Tensor, Tensor)> {
        let (kind, w, b) = self.layer(fine_idx)?;
        match kind {
            LayerKind::Conv { kernel, .. } => {
                let (_, dw, db) = vjp::residual_step_vjp(u, w, b, h, kernel / 2, lam)?;
                Ok((dw, db))
            }
            LayerKind::Fc { .. } => {
                let (_, dw, db) = vjp::residual_fc_step_vjp(u, w, b, h, lam)?;
                Ok((dw, db))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn micro_solver() -> HostSolver {
        let spec = Arc::new(NetSpec::micro());
        let params = Arc::new(NetParams::init(&spec, 3).unwrap());
        HostSolver::new(spec, params).unwrap()
    }

    #[test]
    fn step_matches_direct_ops() {
        let s = micro_solver();
        let mut rng = Rng::new(1);
        let u = Tensor::randn(&[2, 2, 6, 6], 1.0, &mut rng);
        let got = s.step(1, 0.25, &u).unwrap();
        let (w, b) = &s.params().trunk[1];
        let want = ops::residual_step(&u, w, b, 0.25, 1).unwrap();
        assert_eq!(got, want);
    }

    #[test]
    fn block_fprop_default_matches_repeated_step() {
        let s = micro_solver();
        let mut rng = Rng::new(2);
        let u0 = Tensor::randn(&[1, 2, 6, 6], 1.0, &mut rng);
        let states = s.block_fprop(0, 1, 3, 0.25, &u0).unwrap();
        let mut u = u0;
        for (j, st) in states.iter().enumerate() {
            u = s.step(j, 0.25, &u).unwrap();
            assert_eq!(st, &u);
        }
    }

    #[test]
    fn block_fprop_with_stride_skips_layers() {
        let s = micro_solver();
        let mut rng = Rng::new(3);
        let u0 = Tensor::randn(&[1, 2, 6, 6], 1.0, &mut rng);
        let states = s.block_fprop(0, 2, 2, 0.5, &u0).unwrap();
        let u1 = s.step(0, 0.5, &u0).unwrap();
        let u2 = s.step(2, 0.5, &u1).unwrap();
        assert_eq!(states, vec![u1, u2]);
    }

    #[test]
    fn out_of_range_layer_errors() {
        let s = micro_solver();
        let u = Tensor::zeros(&[1, 2, 6, 6]);
        assert!(s.step(99, 0.1, &u).is_err());
    }

    #[test]
    fn opening_and_head_shapes() {
        let s = micro_solver();
        let mut rng = Rng::new(4);
        let y = Tensor::randn(&[2, 1, 6, 6], 1.0, &mut rng);
        let u0 = s.opening(&y).unwrap();
        assert_eq!(u0.dims(), &[2, 2, 6, 6]);
        let (logits, loss) = s.head(&u0, &[0, 1]).unwrap();
        assert_eq!(logits.dims(), &[2, 10]);
        assert!(loss.is_finite());
    }

    #[test]
    fn param_mismatch_rejected() {
        let spec = Arc::new(NetSpec::micro());
        let mnist_params = Arc::new(NetParams::init(&NetSpec::mnist(), 1).unwrap());
        assert!(HostSolver::new(spec, mnist_params).is_err());
    }
}
