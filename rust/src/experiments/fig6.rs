//! Fig 6 — strong scaling of the 4,096-layer / 3.25 M-parameter network
//! (the `fig6` preset, parameter count reproduced exactly):
//!
//! - (a) single-image inference: serial vs MG over GPU counts;
//! - (b) training step: serial vs PM (model-partitioned) vs MG;
//! - (c) compute/communication decomposition of the MG and PM runs.
//!
//! All runs execute the real coordinator schedule in the cluster simulator
//! (V100 + 25 GbE model). Inference uses 1 V-cycle, training 2 (the paper's
//! early stopping); hierarchy is multilevel (the paper notes the coarsening
//! "can be applied repeatedly" — a two-level hierarchy leaves an O(N/c)
//! sequential coarse solve that caps scaling well below the paper's curves).

use std::sync::Arc;

use crate::coordinator::{ParallelMgrit, Partition, RunMetrics, TraceEvent};
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph::{self, Granularity};
use crate::mgrit::{MgritOptions, RelaxKind};
use crate::model::{NetParams, NetSpec};
use crate::perfmodel::ClusterModel;
use crate::sim;
use crate::solver::host::HostSolver;
use crate::tensor::Tensor;
use crate::util::json::{num, s};
use crate::Result;

use super::Table;

/// The hierarchy used for all simulated scaling figures.
pub fn sim_hierarchy(spec: &NetSpec) -> Result<Hierarchy> {
    Hierarchy::build(spec.n_res(), spec.h(), spec.coarsen, 8, spec.coarsen * 2)
}

/// One simulated MG run at `gpus` devices; returns the report.
pub fn simulate_mg(
    spec: &NetSpec,
    gpus: usize,
    cycles: usize,
    training: bool,
) -> Result<sim::SimReport> {
    let hier = sim_hierarchy(spec)?;
    let n_blocks = hier.fine().blocks(hier.coarsen).len();
    let part = Partition::contiguous(n_blocks, gpus)?;
    let g = if training {
        // the executable whole-training-step graph — identical to what the
        // live executor runs (forward + head + adjoint + grads + updates)
        taskgraph::mg_train_step(
            spec,
            &hier,
            &part,
            1,
            cycles,
            RelaxKind::FCF,
            Granularity::PerStep,
        )
    } else {
        taskgraph::mg_forward(spec, &hier, &part, 1, cycles)
    };
    sim::simulate(&g, &ClusterModel::tx_gaia(gpus), false)
}

/// One simulated serial/PM run at `gpus` devices.
pub fn simulate_pm(spec: &NetSpec, gpus: usize, training: bool) -> Result<sim::SimReport> {
    let g = if training {
        taskgraph::serial_training(spec, gpus, 1)
    } else {
        taskgraph::serial_forward(spec, gpus, 1)
    };
    sim::simulate(&g, &ClusterModel::tx_gaia(gpus), false)
}

/// Fig 6a: inference scaling (serial baseline vs MG, 1 cycle).
pub fn fig6a(gpu_counts: &[usize]) -> Result<Table> {
    let spec = NetSpec::fig6();
    let serial = simulate_pm(&spec, 1, false)?.makespan_s;
    let mut t = Table::new(
        "Fig 6a: single-image inference, 4096-layer/3.25M net (serial vs MG)",
        &["gpus", "serial_ms", "mg_ms", "speedup_vs_serial"],
    );
    for &g in gpu_counts {
        let mg = simulate_mg(&spec, g, 1, false)?.makespan_s;
        t.row(vec![
            num(g as f64),
            num(serial * 1e3),
            num(mg * 1e3),
            num(serial / mg),
        ]);
    }
    Ok(t)
}

/// Fig 6b: training-phase forward propagation (serial vs PM vs MG, 2
/// cycles — the paper's early-stopping count; both Fig 6 and Fig 7 captions
/// measure "strong scaling of forward propagation").
pub fn fig6b(gpu_counts: &[usize]) -> Result<Table> {
    let spec = NetSpec::fig6();
    let serial = simulate_pm(&spec, 1, false)?.makespan_s;
    let mut t = Table::new(
        "Fig 6b: training-phase fwd prop, 4096-layer/3.25M net (serial vs PM vs MG)",
        &["gpus", "serial_ms", "pm_ms", "mg_ms", "mg_speedup_vs_serial", "mg_speedup_vs_pm"],
    );
    for &g in gpu_counts {
        let pm = simulate_pm(&spec, g, false)?.makespan_s;
        let mg = simulate_mg(&spec, g, 2, false)?.makespan_s;
        t.row(vec![
            num(g as f64),
            num(serial * 1e3),
            num(pm * 1e3),
            num(mg * 1e3),
            num(serial / mg),
            num(pm / mg),
        ]);
    }
    Ok(t)
}

/// Fig 6c: timing decomposition — device compute occupancy vs stall
/// (communication + dependency wait) for the MG and PM training runs.
pub fn fig6c(gpu_counts: &[usize]) -> Result<Table> {
    let spec = NetSpec::fig6();
    let mut t = Table::new(
        "Fig 6c: compute vs communication/stall decomposition (training fwd prop)",
        &["gpus", "algo", "compute_fraction", "stall_fraction", "comm_total_ms"],
    );
    for &g in gpu_counts {
        let mg = simulate_mg(&spec, g, 2, false)?;
        t.row(vec![
            num(g as f64),
            s("mg"),
            num(mg.compute_fraction()),
            num(mg.stall_fraction()),
            num(mg.comm_total_s * 1e3),
        ]);
        let pm = simulate_pm(&spec, g, false)?;
        t.row(vec![
            num(g as f64),
            s("pm"),
            num(pm.compute_fraction()),
            num(pm.stall_fraction()),
            num(pm.comm_total_s * 1e3),
        ]);
    }
    Ok(t)
}

/// Build a live fig6-family training driver over `devices` host workers.
fn training_driver(
    depth: usize,
    devices: usize,
) -> Result<ParallelMgrit<impl crate::solver::SolverFactory<Solver = HostSolver>>> {
    let spec = Arc::new(NetSpec::fig6_depth(depth));
    let params = Arc::new(NetParams::init(&spec, 7)?);
    let spec2 = spec.clone();
    let factory = move |_w: usize| HostSolver::new(spec2.clone(), params.clone());
    let hier = Hierarchy::two_level(depth, spec.h(), spec.coarsen)?;
    ParallelMgrit::new(factory, spec, hier, devices, 1)
}

/// One real training-step input batch for a fig6-family spec.
fn training_batch(spec: &NetSpec) -> (Tensor, Vec<i32>) {
    training_batch_n(spec, 1)
}

/// A real training batch of `n` samples for a fig6-family spec (each sample
/// drawn from its own deterministic per-instance stream).
fn training_batch_n(spec: &NetSpec, n: usize) -> (Tensor, Vec<i32>) {
    let o = &spec.opening;
    let sample = o.in_channels * o.in_h * o.in_w;
    let mut data = Vec::with_capacity(n * sample);
    let mut labels = Vec::with_capacity(n);
    for k in 0..n {
        let mut rng = crate::util::prng::Rng::for_instance(8, k as u64);
        let y = Tensor::randn(&[1, o.in_channels, o.in_h, o.in_w], 0.5, &mut rng);
        data.extend_from_slice(y.data());
        labels.push((k % 10) as i32);
    }
    let y = Tensor::new(vec![n, o.in_channels, o.in_h, o.in_w], data).expect("batch tensor");
    (y, labels)
}

/// Execute one real whole-training-step graph (forward + head + adjoint +
/// gradients + SGD updates, one DAG) through the live executor on host
/// numerics; returns the loss, the run metrics, and the stream-pool trace.
pub fn live_training_timeline(
    depth: usize,
    devices: usize,
    cycles: usize,
) -> Result<(f64, RunMetrics, Vec<TraceEvent>)> {
    let drv = training_driver(depth, devices)?;
    let (y, labels) = training_batch(&NetSpec::fig6_depth(depth));
    let opts = MgritOptions::early_stopping(cycles);
    let out = drv.train_step(&y, &labels, &opts, 0.05)?;
    Ok((out.loss, out.metrics, drv.pool().trace()))
}

/// The training-step timeline, both ways: the schedule simulated on the
/// TX-GAIA model and the *observed* live-executor run — by construction the
/// *identical* graph (`drv.train_graph` feeds the simulator, the same
/// driver's `train_step` executes it) — including whether adjoint relaxation
/// and parameter-gradient work of different partitions overlapped (the
/// no-barrier property).
pub fn training_timeline(depth: usize, devices: usize) -> Result<(Table, String)> {
    let drv = training_driver(depth, devices)?;
    let opts = MgritOptions::early_stopping(2);
    let g = drv.train_graph(&opts);
    let rep =
        sim::simulate(&g, &ClusterModel::tx_gaia(drv.partition().n_devices()), true)?;
    let (y, labels) = training_batch(&NetSpec::fig6_depth(depth));
    let out = drv.train_step(&y, &labels, &opts, 0.05)?;
    let (loss, metrics, live) = (out.loss, out.metrics, drv.pool().trace());
    // adjoint/gradient cross-partition overlap on the observed trace
    let overlap = live
        .iter()
        .filter(|e| e.label == "param_grad")
        .any(|pg| {
            live.iter().any(|a| {
                a.label.starts_with("adj_") && a.worker != pg.worker && a.t_end > pg.t_start
            })
        });
    let mut t = Table::new(
        "Fig 6 training-step timeline: simulated vs observed (one graph, no phase barriers)",
        &[
            "depth",
            "devices",
            "sim_makespan_ms",
            "sim_kernels",
            "observed_busy_ms",
            "observed_comms",
            "adj_grad_overlap",
            "loss",
        ],
    );
    t.row(vec![
        num(depth as f64),
        num(devices as f64),
        num(rep.makespan_s * 1e3),
        num(rep.n_kernels as f64),
        num(metrics.total_s() * 1e3),
        num(metrics.comm_events as f64),
        s(if overlap { "yes" } else { "no" }),
        num(loss),
    ]);
    let mut ascii = String::from("observed (live DAG executor, whole training step):\n");
    ascii.push_str(&super::fig5::live_ascii(&live, 96));
    Ok((t, ascii))
}

/// The hybrid data×layer timeline: M micro-batch instances pipelined through
/// ONE composed training graph (`ParallelMgrit::train_step_micro`) —
/// simulated on the TX-GAIA model and observed on the live executor, both
/// from the identical graph. Reports the pipelined virtual makespan against
/// M sequential single-instance steps (the pipelining gain) and whether
/// instance k+1 forward work overlapped instance k adjoint work on the live
/// run (the no-inter-instance-barrier property).
pub fn hybrid_timeline(depth: usize, devices: usize, micro: usize) -> Result<Table> {
    let drv = training_driver(depth, devices)?;
    let opts = MgritOptions::early_stopping(2);
    let g1 = drv.train_graph(&opts);
    let gm = drv.train_graph_micro(&opts, micro)?;
    let cluster = ClusterModel::tx_gaia(drv.partition().n_devices());
    let seq = sim::simulate(&g1, &cluster, false)?.makespan_s * micro as f64;
    let pipe = sim::simulate(&gm, &cluster, false)?.makespan_s;
    // the live run: one real hybrid step on a batch of `micro` samples
    let (y, labels) = training_batch_n(&NetSpec::fig6_depth(depth), micro);
    let out = drv.train_step_micro(&y, &labels, &opts, 0.05, micro)?;
    let evs: Vec<(usize, &str, f64, f64)> = out
        .metrics
        .events
        .iter()
        .map(|e| (e.instance, e.label, e.t_start, e.t_end))
        .collect();
    let overlap = taskgraph::events_show_pipeline_overlap(&evs);
    let mut t = Table::new(
        "Hybrid data×layer: M micro-batches pipelined through one graph",
        &[
            "depth",
            "devices",
            "micro_batches",
            "sim_sequential_ms",
            "sim_pipelined_ms",
            "pipelining_gain",
            "live_fwd_adj_overlap",
            "loss",
        ],
    );
    t.row(vec![
        num(depth as f64),
        num(devices as f64),
        num(micro as f64),
        num(seq * 1e3),
        num(pipe * 1e3),
        num(seq / pipe),
        s(if overlap { "yes" } else { "no" }),
        num(out.loss),
    ]);
    Ok(t)
}

/// The paper's sampled GPU counts for Fig 6.
pub const GPU_COUNTS: [usize; 8] = [1, 2, 3, 4, 8, 12, 16, 24];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6a_shape_matches_paper() {
        // paper: MG ~4x slower on 1 GPU; faster than serial by ≥1.25x at 4
        // GPUs; ~4x at 24 GPUs
        let t = fig6a(&[1, 4, 8, 24]).unwrap();
        let speedup = |i: usize| t.rows[i][3].as_f64().unwrap();
        assert!(speedup(0) < 0.5, "1 GPU: MG must be slower ({})", speedup(0));
        assert!(speedup(1) > 0.7, "4 GPUs: MG near crossover ({})", speedup(1));
        assert!(speedup(2) > 1.0, "8 GPUs: MG must win ({})", speedup(2));
        assert!(speedup(3) > 2.5, "24 GPUs: MG must win big ({})", speedup(3));
        assert!(speedup(3) > speedup(2) && speedup(2) > speedup(1));
    }

    #[test]
    fn fig6b_mg_beats_pm_at_four_gpus() {
        let t = fig6b(&[4, 16]).unwrap();
        let vs_pm = |i: usize| t.rows[i][5].as_f64().unwrap();
        assert!(vs_pm(1) > 1.0, "16 GPUs: MG must beat PM ({})", vs_pm(1));
        assert!(vs_pm(1) > vs_pm(0), "PM gap must widen with GPUs");
    }

    #[test]
    fn training_sim_includes_adjoint_and_grads() {
        // the simulated training run scores the same whole-step graph the
        // live executor runs: more kernels and flops than the forward run
        let spec = NetSpec::fig6_depth(64);
        let fwd = simulate_mg(&spec, 4, 2, false).unwrap();
        let trn = simulate_mg(&spec, 4, 2, true).unwrap();
        assert!(trn.n_kernels > 2 * fwd.n_kernels, "{} vs {}", trn.n_kernels, fwd.n_kernels);
        assert!(trn.makespan_s > fwd.makespan_s);
    }

    #[test]
    fn training_timeline_renders_and_overlaps() {
        let (t, ascii) = training_timeline(32, 2).unwrap();
        assert_eq!(t.rows.len(), 1);
        assert!(ascii.contains('#'));
        // loss is finite
        assert!(t.rows[0][7].as_f64().unwrap().is_finite());
    }

    #[test]
    fn hybrid_timeline_shows_pipelining_gain() {
        let t = hybrid_timeline(32, 2, 2).unwrap();
        assert_eq!(t.rows.len(), 1);
        // the pipelined composed graph beats M sequential steps in virtual time
        assert!(t.rows[0][5].as_f64().unwrap() > 1.0);
        assert!(t.rows[0][7].as_f64().unwrap().is_finite());
    }

    #[test]
    fn fig6c_stall_grows_with_gpus() {
        let t = fig6c(&[2, 16]).unwrap();
        let pm_stall: Vec<f64> = t
            .rows
            .iter()
            .filter(|r| r[1].as_str().unwrap() == "pm")
            .map(|r| r[3].as_f64().unwrap())
            .collect();
        assert!(pm_stall[1] > pm_stall[0], "PM stall fraction must grow: {pm_stall:?}");
        // PM at 16 GPUs is almost entirely stalled (the paper's 97 % at 64)
        assert!(pm_stall[1] > 0.85, "{pm_stall:?}");
    }
}
