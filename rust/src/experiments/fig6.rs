//! Fig 6 — strong scaling of the 4,096-layer / 3.25 M-parameter network
//! (the `fig6` preset, parameter count reproduced exactly):
//!
//! - (a) single-image inference: serial vs MG over GPU counts;
//! - (b) training step: serial vs PM (model-partitioned) vs MG;
//! - (c) compute/communication decomposition of the MG and PM runs.
//!
//! All runs execute the real coordinator schedule in the cluster simulator
//! (V100 + 25 GbE model). Inference uses 1 V-cycle, training 2 (the paper's
//! early stopping); hierarchy is multilevel (the paper notes the coarsening
//! "can be applied repeatedly" — a two-level hierarchy leaves an O(N/c)
//! sequential coarse solve that caps scaling well below the paper's curves).

use crate::coordinator::Partition;
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph;
use crate::model::NetSpec;
use crate::perfmodel::ClusterModel;
use crate::sim;
use crate::util::json::{num, s};
use crate::Result;

use super::Table;

/// The hierarchy used for all simulated scaling figures.
pub fn sim_hierarchy(spec: &NetSpec) -> Result<Hierarchy> {
    Hierarchy::build(spec.n_res(), spec.h(), spec.coarsen, 8, spec.coarsen * 2)
}

/// One simulated MG run at `gpus` devices; returns the report.
pub fn simulate_mg(
    spec: &NetSpec,
    gpus: usize,
    cycles: usize,
    training: bool,
) -> Result<sim::SimReport> {
    let hier = sim_hierarchy(spec)?;
    let n_blocks = hier.fine().blocks(hier.coarsen).len();
    let part = Partition::contiguous(n_blocks, gpus)?;
    let g = if training {
        taskgraph::mg_training(spec, &hier, &part, 1, cycles)
    } else {
        taskgraph::mg_forward(spec, &hier, &part, 1, cycles)
    };
    sim::simulate(&g, &ClusterModel::tx_gaia(gpus), false)
}

/// One simulated serial/PM run at `gpus` devices.
pub fn simulate_pm(spec: &NetSpec, gpus: usize, training: bool) -> Result<sim::SimReport> {
    let g = if training {
        taskgraph::serial_training(spec, gpus, 1)
    } else {
        taskgraph::serial_forward(spec, gpus, 1)
    };
    sim::simulate(&g, &ClusterModel::tx_gaia(gpus), false)
}

/// Fig 6a: inference scaling (serial baseline vs MG, 1 cycle).
pub fn fig6a(gpu_counts: &[usize]) -> Result<Table> {
    let spec = NetSpec::fig6();
    let serial = simulate_pm(&spec, 1, false)?.makespan_s;
    let mut t = Table::new(
        "Fig 6a: single-image inference, 4096-layer/3.25M net (serial vs MG)",
        &["gpus", "serial_ms", "mg_ms", "speedup_vs_serial"],
    );
    for &g in gpu_counts {
        let mg = simulate_mg(&spec, g, 1, false)?.makespan_s;
        t.row(vec![
            num(g as f64),
            num(serial * 1e3),
            num(mg * 1e3),
            num(serial / mg),
        ]);
    }
    Ok(t)
}

/// Fig 6b: training-phase forward propagation (serial vs PM vs MG, 2
/// cycles — the paper's early-stopping count; both Fig 6 and Fig 7 captions
/// measure "strong scaling of forward propagation").
pub fn fig6b(gpu_counts: &[usize]) -> Result<Table> {
    let spec = NetSpec::fig6();
    let serial = simulate_pm(&spec, 1, false)?.makespan_s;
    let mut t = Table::new(
        "Fig 6b: training-phase fwd prop, 4096-layer/3.25M net (serial vs PM vs MG)",
        &["gpus", "serial_ms", "pm_ms", "mg_ms", "mg_speedup_vs_serial", "mg_speedup_vs_pm"],
    );
    for &g in gpu_counts {
        let pm = simulate_pm(&spec, g, false)?.makespan_s;
        let mg = simulate_mg(&spec, g, 2, false)?.makespan_s;
        t.row(vec![
            num(g as f64),
            num(serial * 1e3),
            num(pm * 1e3),
            num(mg * 1e3),
            num(serial / mg),
            num(pm / mg),
        ]);
    }
    Ok(t)
}

/// Fig 6c: timing decomposition — device compute occupancy vs stall
/// (communication + dependency wait) for the MG and PM training runs.
pub fn fig6c(gpu_counts: &[usize]) -> Result<Table> {
    let spec = NetSpec::fig6();
    let mut t = Table::new(
        "Fig 6c: compute vs communication/stall decomposition (training fwd prop)",
        &["gpus", "algo", "compute_fraction", "stall_fraction", "comm_total_ms"],
    );
    for &g in gpu_counts {
        let mg = simulate_mg(&spec, g, 2, false)?;
        t.row(vec![
            num(g as f64),
            s("mg"),
            num(mg.compute_fraction()),
            num(mg.stall_fraction()),
            num(mg.comm_total_s * 1e3),
        ]);
        let pm = simulate_pm(&spec, g, false)?;
        t.row(vec![
            num(g as f64),
            s("pm"),
            num(pm.compute_fraction()),
            num(pm.stall_fraction()),
            num(pm.comm_total_s * 1e3),
        ]);
    }
    Ok(t)
}

/// The paper's sampled GPU counts for Fig 6.
pub const GPU_COUNTS: [usize; 8] = [1, 2, 3, 4, 8, 12, 16, 24];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6a_shape_matches_paper() {
        // paper: MG ~4x slower on 1 GPU; faster than serial by ≥1.25x at 4
        // GPUs; ~4x at 24 GPUs
        let t = fig6a(&[1, 4, 8, 24]).unwrap();
        let speedup = |i: usize| t.rows[i][3].as_f64().unwrap();
        assert!(speedup(0) < 0.5, "1 GPU: MG must be slower ({})", speedup(0));
        assert!(speedup(1) > 0.7, "4 GPUs: MG near crossover ({})", speedup(1));
        assert!(speedup(2) > 1.0, "8 GPUs: MG must win ({})", speedup(2));
        assert!(speedup(3) > 2.5, "24 GPUs: MG must win big ({})", speedup(3));
        assert!(speedup(3) > speedup(2) && speedup(2) > speedup(1));
    }

    #[test]
    fn fig6b_mg_beats_pm_at_four_gpus() {
        let t = fig6b(&[4, 16]).unwrap();
        let vs_pm = |i: usize| t.rows[i][5].as_f64().unwrap();
        assert!(vs_pm(1) > 1.0, "16 GPUs: MG must beat PM ({})", vs_pm(1));
        assert!(vs_pm(1) > vs_pm(0), "PM gap must widen with GPUs");
    }

    #[test]
    fn fig6c_stall_grows_with_gpus() {
        let t = fig6c(&[2, 16]).unwrap();
        let pm_stall: Vec<f64> = t
            .rows
            .iter()
            .filter(|r| r[1].as_str().unwrap() == "pm")
            .map(|r| r[3].as_f64().unwrap())
            .collect();
        assert!(pm_stall[1] > pm_stall[0], "PM stall fraction must grow: {pm_stall:?}");
        // PM at 16 GPUs is almost entirely stalled (the paper's 97 % at 64)
        assert!(pm_stall[1] > 0.85, "{pm_stall:?}");
    }
}
