//! One module per paper figure/table. Each experiment returns structured
//! rows (printed as a table and embeddable in bench JSON reports) so the
//! benches under `rust/benches/` and the `mgrit experiment <id>` CLI share
//! one implementation.

pub mod ablations;
pub mod compound;
pub mod fig1;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod perf;
pub mod pipeline;
pub mod placement;
pub mod serve;
pub mod topology;

use crate::util::json::Json;

/// A labelled table of rows (column names + row values).
#[derive(Debug, Clone)]
pub struct Table {
    /// Table heading.
    pub title: String,
    /// Column names.
    pub columns: Vec<String>,
    /// Row values, aligned with `columns`.
    pub rows: Vec<Vec<Json>>,
}

impl Table {
    /// An empty table with the given columns.
    pub fn new(title: &str, columns: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (arity-checked).
    pub fn row(&mut self, values: Vec<Json>) {
        assert_eq!(values.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(values);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut cells: Vec<Vec<String>> = vec![self.columns.clone()];
        for r in &self.rows {
            cells.push(r.iter().map(fmt_json).collect());
        }
        let n_cols = self.columns.len();
        let widths: Vec<usize> = (0..n_cols)
            .map(|c| cells.iter().map(|r| r[c].len()).max().unwrap_or(0))
            .collect();
        let mut out = format!("== {} ==\n", self.title);
        for (i, r) in cells.iter().enumerate() {
            let line: Vec<String> =
                r.iter().zip(&widths).map(|(v, w)| format!("{v:>w$}")).collect();
            out.push_str("  ");
            out.push_str(&line.join("  "));
            out.push('\n');
            if i == 0 {
                out.push_str("  ");
                out.push_str(&widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
                out.push('\n');
            }
        }
        out
    }

    /// Rows as JSON objects (column name → value).
    pub fn to_json_rows(&self) -> Vec<Json> {
        self.rows
            .iter()
            .map(|r| {
                Json::Obj(
                    self.columns
                        .iter()
                        .cloned()
                        .zip(r.iter().cloned())
                        .collect(),
                )
            })
            .collect()
    }
}

fn fmt_json(j: &Json) -> String {
    match j {
        Json::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e12 {
                format!("{}", *n as i64)
            } else if n.abs() >= 0.01 && n.abs() < 1e6 {
                format!("{n:.3}")
            } else {
                format!("{n:.3e}")
            }
        }
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, s};

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["gpus", "time_s", "algo"]);
        t.row(vec![num(1.0), num(0.0123), s("serial")]);
        t.row(vec![num(64.0), num(1.5e-7), s("mg")]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("gpus"));
        assert!(r.contains("serial"));
        let json = t.to_json_rows();
        assert_eq!(json.len(), 2);
        assert_eq!(json[0].get("algo").unwrap().as_str().unwrap(), "serial");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec![num(1.0)]);
    }
}
