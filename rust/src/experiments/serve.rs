//! The serving report: continuous batching vs batch-barrier admission on
//! the deterministic virtual timeline (V100 + 25 GbE cost model).
//!
//! Same synthetic open-loop load (n requests at a fixed arrival rate, one
//! forward-only MGRIT instance each), two admission policies with the same
//! in-flight budget:
//!
//! - **continuous** — request k admitted the moment request k−W retires
//!   (`taskgraph::Admission::Continuous`): the serving loop the live
//!   `serving::ServingRuntime` runs;
//! - **barrier** — requests admitted in waves of W, every wave waiting for
//!   the whole previous wave (`taskgraph::Admission::BatchBarrier`): the
//!   classic batched-inference baseline.
//!
//! Continuous admission removes the wave-tail idle time (each wave's
//! sequential coarse-solve tail leaves devices idle that the next requests
//! could fill), which shows up as lower p95/p99 latency and higher
//! throughput at equal budget.

use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph::Admission;
use crate::model::NetSpec;
use crate::serving::{simulate_serving, SimServeConfig};
use crate::util::json::{num, s};
use crate::Result;

use super::Table;

/// Run the serving comparison: `n_requests` at `arrival_rate_rps` through
/// `devices` virtual GPUs, one row per admission policy at the same
/// in-flight budget `window`.
pub fn run(
    depth: usize,
    devices: usize,
    n_requests: usize,
    arrival_rate_rps: f64,
    window: usize,
    deadline_ms: Option<f64>,
) -> Result<Table> {
    let spec = NetSpec::fig6_depth(depth);
    let hier = Hierarchy::two_level(depth, spec.h(), spec.coarsen)?;
    let mut t = Table::new(
        "Serving: continuous batching vs batch-barrier admission (virtual timeline)",
        &[
            "mode",
            "requests",
            "inflight",
            "arrival_rps",
            "p50_ms",
            "p95_ms",
            "p99_ms",
            "throughput_rps",
            "makespan_ms",
            "deadline_misses",
        ],
    );
    for (name, admission) in [
        ("continuous", Admission::Continuous { window }),
        ("barrier", Admission::BatchBarrier { wave: window }),
    ] {
        let cfg = SimServeConfig {
            n_requests,
            arrival_rate_rps,
            deadline_ms,
            admission,
            ..Default::default()
        };
        let out = simulate_serving(&spec, &hier, devices, &cfg)?;
        t.row(vec![
            s(name),
            num(n_requests as f64),
            num(window as f64),
            num(arrival_rate_rps),
            num(out.summary.p50_ms),
            num(out.summary.p95_ms),
            num(out.summary.p99_ms),
            num(out.summary.throughput_rps),
            num(out.makespan_s * 1e3),
            num(out.summary.deadline_misses as f64),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_report_has_both_modes_and_continuous_wins_the_tail() {
        let t = run(64, 4, 12, 20_000.0, 4, Some(50.0)).unwrap();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0].as_str().unwrap(), "continuous");
        assert_eq!(t.rows[1][0].as_str().unwrap(), "barrier");
        let p99 = |i: usize| t.rows[i][6].as_f64().unwrap();
        assert!(p99(0) <= p99(1) * 1.01, "continuous p99 {} vs barrier {}", p99(0), p99(1));
        // deterministic rerun produces the same table values
        let t2 = run(64, 4, 12, 20_000.0, 4, Some(50.0)).unwrap();
        for (a, b) in t.rows.iter().zip(&t2.rows) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_string(), y.to_string());
            }
        }
    }
}
