//! The serving reports on the deterministic virtual timeline (V100 + 25 GbE
//! cost model):
//!
//! 1. [`run`] — continuous batching vs batch-barrier admission. Same
//!    synthetic open-loop load, two *admission-edge* schedules at the same
//!    in-flight budget:
//!    - **continuous** — request k admitted the moment request k−W retires
//!      (`taskgraph::Admission::Continuous`): the serving loop the live
//!      `serving::ServingRuntime` runs;
//!    - **barrier** — requests admitted in waves of W, every wave waiting
//!      for the whole previous wave (`taskgraph::Admission::BatchBarrier`):
//!      the classic batched-inference baseline.
//!    Continuous admission removes the wave-tail idle time, which shows up
//!    as lower p95/p99 latency and higher throughput at equal budget.
//!
//! 2. [`policy_comparison`] — the three-way scheduler comparison (FIFO vs
//!    EDF vs shape-batch, `serving::policy`) on ONE matched burst load with
//!    mixed deadline budgets, scored by the policy-driven virtual-time loop
//!    (`serving::simulate_serving_policy` over `sim::SimSession`). The load
//!    is constructed so deadline pressure is real but meetable: a FIFO probe
//!    measures the drain's position-wise latencies, and the tight budget is
//!    placed between what early and late admission positions achieve —
//!    so EDF (which admits tight-budget requests first) strictly reduces
//!    deadline misses vs FIFO on the same load, and shape-batch shows the
//!    launch-amortization effect of coalescing.

use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph::Admission;
use crate::model::NetSpec;
use crate::serving::{
    simulate_serving, simulate_serving_policy, PolicyKind, SimPolicyConfig, SimRequest,
    SimServeConfig,
};
use crate::util::json::{num, s};
use crate::Result;

use super::Table;

/// Run the serving comparison: `n_requests` at `arrival_rate_rps` through
/// `devices` virtual GPUs, one row per admission policy at the same
/// in-flight budget `window`.
pub fn run(
    depth: usize,
    devices: usize,
    n_requests: usize,
    arrival_rate_rps: f64,
    window: usize,
    deadline_ms: Option<f64>,
) -> Result<Table> {
    let spec = NetSpec::fig6_depth(depth);
    let hier = Hierarchy::two_level(depth, spec.h(), spec.coarsen)?;
    let mut t = Table::new(
        "Serving: continuous batching vs batch-barrier admission (virtual timeline)",
        &[
            "mode",
            "requests",
            "inflight",
            "arrival_rps",
            "p50_ms",
            "p95_ms",
            "p99_ms",
            "throughput_rps",
            "makespan_ms",
            "deadline_misses",
        ],
    );
    for (name, admission) in [
        ("continuous", Admission::Continuous { window }),
        ("barrier", Admission::BatchBarrier { wave: window }),
    ] {
        let cfg = SimServeConfig {
            n_requests,
            arrival_rate_rps,
            deadline_ms,
            admission,
            ..Default::default()
        };
        let out = simulate_serving(&spec, &hier, devices, &cfg)?;
        t.row(vec![
            s(name),
            num(n_requests as f64),
            num(window as f64),
            num(arrival_rate_rps),
            num(out.summary.p50_ms),
            num(out.summary.p95_ms),
            num(out.summary.p99_ms),
            num(out.summary.throughput_rps),
            num(out.makespan_s * 1e3),
            num(out.summary.deadline_misses as f64),
        ]);
    }
    Ok(t)
}

/// The matched deadline-mixed burst load behind [`policy_comparison`]:
/// `n_requests` arriving at t = 0, the last `m` carrying a tight budget
/// placed strictly between the latencies of the first `m` and the last `m`
/// admission positions (measured by a deadline-free FIFO probe on the same
/// cluster), the rest a loose budget no drain order can miss. Returns
/// `(requests, tight_ms, m)`.
pub fn deadline_mixed_burst(
    spec: &NetSpec,
    hier: &Hierarchy,
    devices: usize,
    cfg: &SimPolicyConfig,
    n_requests: usize,
) -> Result<(Vec<SimRequest>, f64, usize)> {
    anyhow::ensure!(n_requests >= 4, "need at least 4 requests for a mixed load");
    let probe = simulate_serving_policy(
        spec,
        hier,
        devices,
        cfg,
        &SimRequest::open_loop(n_requests, 0.0, None),
        PolicyKind::Fifo,
    )?;
    let mut lat: Vec<f64> = probe.completed.iter().map(|r| r.latency_ms).collect();
    lat.sort_by(|a, b| a.total_cmp(b));
    // the largest tight group m whose m fastest positions all beat the m
    // slowest positions — the strict gap the tight budget sits in
    let m = (1..=n_requests / 2)
        .rev()
        .find(|&m| lat[m - 1] < lat[n_requests - m])
        .ok_or_else(|| anyhow::anyhow!("degenerate probe: all completions equal"))?;
    let tight_ms = (lat[m - 1] + lat[n_requests - m]) / 2.0;
    let loose_ms = lat[n_requests - 1] * 10.0 + 1e3;
    let reqs: Vec<SimRequest> = (0..n_requests)
        .map(|k| SimRequest {
            id: k as u64,
            arrival_s: 0.0,
            deadline_ms: Some(if k >= n_requests - m { tight_ms } else { loose_ms }),
            rows: 1,
        })
        .collect();
    Ok((reqs, tight_ms, m))
}

/// The three-way policy comparison: FIFO vs EDF vs shape-batch on one
/// matched [`deadline_mixed_burst`] load, one row per policy with tail
/// latency, throughput, makespan, deadline misses, sheds, and the admitted
/// instance count (under coalescing, fewer than requests).
pub fn policy_comparison(
    depth: usize,
    devices: usize,
    n_requests: usize,
    window: usize,
    max_batch: usize,
    batch_window_ms: f64,
) -> Result<Table> {
    let spec = NetSpec::fig6_depth(depth);
    let hier = Hierarchy::two_level(depth, spec.h(), spec.coarsen)?;
    let cfg = SimPolicyConfig { max_inflight: window, ..Default::default() };
    let (reqs, tight_ms, m) = deadline_mixed_burst(&spec, &hier, devices, &cfg, n_requests)?;
    let mut t = Table::new(
        &format!(
            "Serving: FIFO vs EDF vs shape-batch on one burst load \
             ({m}/{n_requests} requests with a {tight_ms:.2} ms budget; virtual timeline)"
        ),
        &[
            "policy",
            "requests",
            "completed",
            "instances",
            "p50_ms",
            "p95_ms",
            "p99_ms",
            "throughput_rps",
            "makespan_ms",
            "misses",
            "sheds",
        ],
    );
    for kind in [
        PolicyKind::Fifo,
        PolicyKind::Edf,
        PolicyKind::ShapeBatch { max_batch, window_ms: batch_window_ms },
    ] {
        let out = simulate_serving_policy(&spec, &hier, devices, &cfg, &reqs, kind)?;
        t.row(vec![
            s(out.policy),
            num(n_requests as f64),
            num(out.completed.len() as f64),
            num(out.instances as f64),
            num(out.summary.p50_ms),
            num(out.summary.p95_ms),
            num(out.summary.p99_ms),
            num(out.summary.throughput_rps),
            num(out.makespan_s * 1e3),
            num(out.summary.deadline_misses as f64),
            num(out.summary.sheds as f64),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_report_has_both_modes_and_continuous_wins_the_tail() {
        let t = run(64, 4, 12, 20_000.0, 4, Some(50.0)).unwrap();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[0][0].as_str().unwrap(), "continuous");
        assert_eq!(t.rows[1][0].as_str().unwrap(), "barrier");
        let p99 = |i: usize| t.rows[i][6].as_f64().unwrap();
        assert!(p99(0) <= p99(1) * 1.01, "continuous p99 {} vs barrier {}", p99(0), p99(1));
        // deterministic rerun produces the same table values
        let t2 = run(64, 4, 12, 20_000.0, 4, Some(50.0)).unwrap();
        for (a, b) in t.rows.iter().zip(&t2.rows) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_string(), y.to_string());
            }
        }
    }

    #[test]
    fn policy_table_edf_strictly_reduces_misses_on_the_burst_load() {
        // the acceptance claim: on one matched burst load in the
        // deterministic sim, EDF strictly reduces deadline misses vs FIFO
        let t = policy_comparison(64, 4, 12, 4, 4, 1.0).unwrap();
        assert_eq!(t.rows.len(), 3);
        let policy = |i: usize| t.rows[i][0].as_str().unwrap().to_string();
        assert_eq!(policy(0), "fifo");
        assert_eq!(policy(1), "edf");
        assert_eq!(policy(2), "shape-batch");
        let misses = |i: usize| t.rows[i][9].as_f64().unwrap();
        assert!(
            misses(1) < misses(0),
            "EDF must strictly reduce misses: edf {} vs fifo {}",
            misses(1),
            misses(0)
        );
        assert!(misses(0) >= 1.0, "the load must pressure FIFO into missing");
        // every policy served or shed all requests; FIFO/EDF never coalesce,
        // shape-batch admits fewer instances than requests
        let completed = |i: usize| t.rows[i][2].as_f64().unwrap();
        let sheds = |i: usize| t.rows[i][10].as_f64().unwrap();
        for i in 0..3 {
            assert_eq!(completed(i) + sheds(i), 12.0, "row {i} lost requests");
        }
        let instances = |i: usize| t.rows[i][3].as_f64().unwrap();
        assert_eq!(instances(0), completed(0));
        assert!(instances(2) < completed(2), "shape-batch never coalesced");
        // deterministic rerun reproduces the table exactly
        let t2 = policy_comparison(64, 4, 12, 4, 4, 1.0).unwrap();
        for (a, b) in t.rows.iter().zip(&t2.rows) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_string(), y.to_string());
            }
        }
    }
}
