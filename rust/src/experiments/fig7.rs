//! Fig 7 — the 2.07 B-parameter, 4,115-layer network (`fig7` preset,
//! parameter count reproduced exactly): MG vs the traditional layer-wise
//! "Model Partitioned" parallelism over 1–64 GPUs, plus the compute:total
//! ratio the paper quotes (92.8 % at 4 GPUs → 34.5 % at 64).
//!
//! This preset is cost-model-only (8 GiB of parameters); the simulator runs
//! the same schedules the coordinator would execute.

use crate::model::NetSpec;
use crate::util::json::num;
use crate::Result;

use super::fig6::{simulate_mg, simulate_pm};
use super::Table;

/// Fig 7 main curve: PM vs MG training-step time + MG compute ratio.
pub fn run(gpu_counts: &[usize]) -> Result<Table> {
    let spec = NetSpec::fig7();
    let mut t = Table::new(
        "Fig 7: 4115-layer / 2.07B-param net — MG vs Model-Partitioned (fwd prop)",
        &["gpus", "pm_ms", "mg_ms", "mg_speedup_vs_pm", "mg_compute_fraction"],
    );
    for &g in gpu_counts {
        // both curves measure forward propagation (the figure captions'
        // quantity); MG uses the paper's 2 early-stopping cycles
        let pm = simulate_pm(&spec, g, false)?;
        let mg = simulate_mg(&spec, g, 2, false)?;
        t.row(vec![
            num(g as f64),
            num(pm.makespan_s * 1e3),
            num(mg.makespan_s * 1e3),
            num(pm.makespan_s / mg.makespan_s),
            num(mg.compute_fraction()),
        ]);
    }
    Ok(t)
}

/// The paper's sampled GPU counts for Fig 7.
pub const GPU_COUNTS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mg_wins_from_four_gpus_and_gap_widens() {
        let t = run(&[1, 16, 64]).unwrap();
        let speedup = |i: usize| t.rows[i][3].as_f64().unwrap();
        assert!(speedup(0) < 1.0, "1 GPU: MG slower ({})", speedup(0));
        assert!(speedup(1) > 1.0, "16 GPUs: MG must win ({})", speedup(1));
        assert!(speedup(2) > 3.5, "64 GPUs: MG must win big ({})", speedup(2));
        assert!(speedup(2) > speedup(1));
    }

    #[test]
    fn compute_ratio_declines_with_gpus() {
        // the paper's 92.8 % (4 GPUs) → 34.5 % (64 GPUs) trend
        let t = run(&[4, 64]).unwrap();
        let f4 = t.rows[0][4].as_f64().unwrap();
        let f64_ = t.rows[1][4].as_f64().unwrap();
        assert!(f4 > f64_, "compute fraction must decline: {f4} vs {f64_}");
        assert!(f4 > 0.5, "4 GPUs should be compute-dominated: {f4}");
        assert!(f64_ < 0.65, "64 GPUs should be comm-affected: {f64_}");
    }
}
