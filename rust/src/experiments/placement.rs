//! The placement-policy comparison: min-id vs HEFT vs one-step-lookahead
//! dispatch (`coordinator::placement`), scored head-to-head on the
//! deterministic virtual cluster (V100 + 25 GbE cost model).
//!
//! Two workloads, the two the live stack actually runs:
//!
//! 1. [`training_comparison`] — the multi-instance training graph
//!    (`taskgraph::mg_train_step_multi`, M micro-batches pipelined through
//!    one composed graph): each policy plans the graph once, then the plan
//!    (rewritten devices + dispatch priorities) is scored by
//!    `sim::simulate_prioritized`. This is the workload where HEFT's
//!    upward-rank ordering and min-EFT placement pay: critical-path kernels
//!    dispatch ahead of leaf work, and co-locating a comm's endpoints turns
//!    the transfer into a free local handoff.
//! 2. [`serving_comparison`] — an open-loop FIFO serving drain
//!    (`serving::simulate_serving_policy`) with each admitted instance graph
//!    planned by the policy, as the live `ServingRuntime` does per
//!    admission.
//!
//! Columns report the planner's own serial-device estimate next to the
//! simulated makespan, mean device utilization (Σ busy / (makespan ×
//! devices)), and the comm ledger (priced events and total transfer time) —
//! the quantities the placement decision trades against each other.

use crate::coordinator::placement::{self, PlacementKind};
use crate::coordinator::{InstanceGroups, Partition};
use crate::mgrit::fas::RelaxKind;
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph::{self, Granularity};
use crate::model::NetSpec;
use crate::perfmodel::ClusterModel;
use crate::serving::{simulate_serving_policy, PolicyKind, SimPolicyConfig, SimRequest};
use crate::sim;
use crate::util::json::{num, s};
use crate::Result;

use super::Table;

/// Score every shipped placement policy on the M-micro-batch training graph
/// at each device count in `devices`: one row per (devices, policy) with the
/// planner estimate, simulated makespan, utilization, comm ledger, and the
/// speedup over the min-id baseline at the same device count.
pub fn training_comparison(
    depth: usize,
    devices: &[usize],
    micro_batches: usize,
) -> Result<Table> {
    let spec = NetSpec::fig6_depth(depth);
    let hier = Hierarchy::two_level(depth, spec.h(), spec.coarsen)?;
    let n_blocks = hier.fine().blocks(hier.coarsen).len();
    let mut t = Table::new(
        &format!(
            "Placement: min-id vs HEFT vs lookahead on the {micro_batches}-micro-batch \
             training graph (depth {depth}; virtual timeline)"
        ),
        &[
            "devices",
            "policy",
            "est_makespan_ms",
            "sim_makespan_ms",
            "utilization",
            "comm_ms",
            "comm_events",
            "speedup_vs_min_id",
        ],
    );
    for &n_dev in devices {
        let part = Partition::contiguous(n_blocks, n_dev)?;
        let groups = InstanceGroups::new(1, part.n_devices())?;
        let graph = taskgraph::mg_train_step_multi(
            &spec,
            &hier,
            &part,
            &groups,
            1,
            2,
            RelaxKind::FCF,
            Granularity::PerStep,
            micro_batches,
        )?;
        let cluster = ClusterModel::tx_gaia(part.n_devices());
        let mut base_ms = f64::NAN;
        for kind in PlacementKind::all() {
            let plan = placement::plan(kind.build().as_ref(), &graph, &cluster)?;
            let rep =
                sim::simulate_prioritized(&plan.graph, &cluster, false, Some(&plan.priority))?;
            let busy: f64 = rep.device_busy_s.iter().sum();
            let util = if rep.makespan_s > 0.0 {
                busy / (rep.makespan_s * cluster.n_devices as f64)
            } else {
                0.0
            };
            let mk_ms = rep.makespan_s * 1e3;
            if kind == PlacementKind::MinId {
                base_ms = mk_ms;
            }
            t.row(vec![
                num(part.n_devices() as f64),
                s(kind.name()),
                num(plan.est_makespan_s * 1e3),
                num(mk_ms),
                num(util),
                num(rep.comm_total_s * 1e3),
                num(rep.n_comms as f64),
                num(base_ms / mk_ms),
            ]);
        }
    }
    Ok(t)
}

/// Score every shipped placement policy on an open-loop FIFO serving drain:
/// one row per policy with tail latency, throughput, and drain makespan —
/// the per-admission planning path of the live `ServingRuntime`.
pub fn serving_comparison(
    depth: usize,
    devices: usize,
    n_requests: usize,
    window: usize,
    arrival_rate_rps: f64,
) -> Result<Table> {
    let spec = NetSpec::fig6_depth(depth);
    let hier = Hierarchy::two_level(depth, spec.h(), spec.coarsen)?;
    let reqs = SimRequest::open_loop(n_requests, arrival_rate_rps, None);
    let mut t = Table::new(
        &format!(
            "Placement: serving drain under FIFO admission ({n_requests} requests, \
             window {window}; virtual timeline)"
        ),
        &[
            "policy",
            "requests",
            "p50_ms",
            "p95_ms",
            "p99_ms",
            "throughput_rps",
            "makespan_ms",
        ],
    );
    for kind in PlacementKind::all() {
        let cfg = SimPolicyConfig {
            max_inflight: window,
            placement: kind,
            ..Default::default()
        };
        let out = simulate_serving_policy(&spec, &hier, devices, &cfg, &reqs, PolicyKind::Fifo)?;
        t.row(vec![
            s(kind.name()),
            num(out.completed.len() as f64),
            num(out.summary.p50_ms),
            num(out.summary.p95_ms),
            num(out.summary.p99_ms),
            num(out.summary.throughput_rps),
            num(out.makespan_s * 1e3),
        ]);
    }
    Ok(t)
}

/// Both placement tables with the default shapes the CLI uses: the training
/// comparison at 2 and 4 devices with 2 micro-batches, and the serving drain
/// at `devices`.
pub fn run(depth: usize, devices: usize, micro_batches: usize) -> Result<Vec<Table>> {
    Ok(vec![
        training_comparison(depth, &[2, devices.max(2)], micro_batches)?,
        serving_comparison(depth, devices, 8, 3, 20_000.0)?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_table_heft_strictly_beats_min_id_at_two_plus_devices() {
        // the acceptance claim, in the experiment table itself: on the M ≥ 2
        // multi-instance training graph at ≥ 2 devices, HEFT's simulated
        // makespan is strictly below min-id's
        let t = training_comparison(64, &[2, 4], 2).unwrap();
        assert_eq!(t.rows.len(), 6);
        for dev_rows in t.rows.chunks(3) {
            let name = |i: usize| dev_rows[i][1].as_str().unwrap().to_string();
            assert_eq!(name(0), "min-id");
            assert_eq!(name(1), "heft");
            assert_eq!(name(2), "lookahead");
            let mk = |i: usize| dev_rows[i][3].as_f64().unwrap();
            let n_dev = dev_rows[0][0].as_f64().unwrap();
            assert!(
                mk(1) < mk(0),
                "heft must strictly beat min-id at {n_dev} devices: {} vs {}",
                mk(1),
                mk(0)
            );
            // the speedup column agrees with the makespans
            let sp = dev_rows[1][7].as_f64().unwrap();
            assert!((sp - mk(0) / mk(1)).abs() < 1e-9);
            assert!(sp > 1.0);
            // utilization is a fraction
            for r in dev_rows {
                let u = r[4].as_f64().unwrap();
                assert!((0.0..=1.0 + 1e-9).contains(&u), "utilization {u} out of range");
            }
        }
        // deterministic rerun reproduces the table exactly
        let t2 = training_comparison(64, &[2, 4], 2).unwrap();
        for (a, b) in t.rows.iter().zip(&t2.rows) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_string(), y.to_string());
            }
        }
    }

    #[test]
    fn serving_table_covers_every_policy_and_loses_nothing() {
        let t = serving_comparison(64, 2, 6, 3, 20_000.0).unwrap();
        assert_eq!(t.rows.len(), 3);
        for (i, kind) in PlacementKind::all().iter().enumerate() {
            assert_eq!(t.rows[i][0].as_str().unwrap(), kind.name());
            assert_eq!(t.rows[i][1].as_f64().unwrap(), 6.0, "{} lost requests", kind.name());
            assert!(t.rows[i][6].as_f64().unwrap() > 0.0);
        }
    }
}
