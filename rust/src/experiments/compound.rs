//! Extension (paper §V): MG layer-parallelism *combined* with data
//! parallelism — "multiplicative-compounding parallelism".
//!
//! R model replicas each run the MG training-phase forward over G GPUs
//! (R·G devices total); replicas are embarrassingly parallel during the
//! solve and synchronize gradients with a ring all-reduce at the end of the
//! step. The experiment sweeps (R, G) splits of a fixed device budget and
//! reports which split wins — the compounding claim is that the best split
//! uses *both* axes once either one saturates.

use crate::coordinator::Partition;
use crate::mgrit::taskgraph;
use crate::model::{cost, NetSpec};
use crate::perfmodel::ClusterModel;
use crate::sim;
use crate::util::json::num;
use crate::Result;

use super::fig6::sim_hierarchy;
use super::Table;

/// Ring all-reduce time for `bytes` of gradients over `r` replicas:
/// 2·(r−1)/r · bytes / bandwidth + 2·(r−1)·latency.
fn allreduce_s(cluster: &ClusterModel, r: usize, bytes: f64) -> f64 {
    if r <= 1 {
        return 0.0;
    }
    let n = cluster.fabric();
    2.0 * (r as f64 - 1.0) / r as f64 * bytes / n.bandwidth_bps
        + 2.0 * (r as f64 - 1.0) * n.latency_s
}

/// Simulated time of one data×layer-parallel training-phase forward step:
/// max over replicas (identical) + gradient all-reduce across replicas.
pub fn step_time(spec: &NetSpec, replicas: usize, gpus_per_replica: usize) -> Result<f64> {
    let hier = sim_hierarchy(spec)?;
    let n_blocks = hier.fine().blocks(hier.coarsen).len();
    let part = Partition::contiguous(n_blocks, gpus_per_replica)?;
    let g = taskgraph::mg_forward(spec, &hier, &part, 1, 2);
    let rep = sim::simulate(&g, &ClusterModel::tx_gaia(gpus_per_replica), false)?;
    // gradient volume: the parameters each replica's partition owns are
    // reduced with the peers holding the same shard → bytes per device is
    // params/gpus_per_replica; the ring runs across replicas
    let cluster = ClusterModel::tx_gaia(replicas * gpus_per_replica);
    let grad_bytes = 4.0 * spec.param_count() as f64 / gpus_per_replica as f64;
    Ok(rep.makespan_s + allreduce_s(&cluster, replicas, grad_bytes))
}

/// Sweep all (R, G) factorizations of a device budget.
pub fn run(spec_name: &str, total_devices: usize) -> Result<Table> {
    let spec = NetSpec::by_name(spec_name)?;
    let mut t = Table::new(
        &format!(
            "Compound parallelism ({spec_name}, {total_devices} devices): data replicas × MG GPUs"
        ),
        &["replicas", "gpus_per_replica", "step_ms", "throughput_steps_per_s"],
    );
    let mut g = 1;
    while g <= total_devices {
        if total_devices % g == 0 {
            let r = total_devices / g;
            let s = step_time(&spec, r, g)?;
            // data parallelism multiplies per-step samples by R: report
            // sample-normalized throughput (steps/s × replicas)
            t.row(vec![
                num(r as f64),
                num(g as f64),
                num(s * 1e3),
                num(r as f64 / s),
            ]);
        }
        g *= 2;
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allreduce_zero_for_one_replica() {
        let c = ClusterModel::tx_gaia(8);
        assert_eq!(allreduce_s(&c, 1, 1e9), 0.0);
        assert!(allreduce_s(&c, 4, 1e9) > 0.0);
    }

    #[test]
    fn allreduce_bandwidth_term_bounded() {
        // the ring moves < 2x the buffer regardless of replica count
        let c = ClusterModel::tx_gaia(64);
        let t8 = allreduce_s(&c, 8, 1e9);
        let t64 = allreduce_s(&c, 64, 1e9);
        let wire = 2.0 * 1e9 / c.fabric().bandwidth_bps;
        assert!(t8 < wire + 8.0 * 2.0 * c.fabric().latency_s);
        assert!(t64 < wire + 64.0 * 2.0 * c.fabric().latency_s);
    }

    #[test]
    fn compounding_beats_pure_layer_parallelism_at_scale() {
        // at 64 devices on the fig6 net, pure layer parallelism (1×64) has
        // saturated; some mixed split must give higher sample throughput
        let t = run("fig6", 64).unwrap();
        let pure_lp = t
            .rows
            .iter()
            .find(|r| r[1].as_f64().unwrap() == 64.0)
            .unwrap()[3]
            .as_f64()
            .unwrap();
        let best = t
            .rows
            .iter()
            .map(|r| r[3].as_f64().unwrap())
            .fold(0.0, f64::max);
        assert!(
            best > 1.2 * pure_lp,
            "no compounding win: best {best} vs pure-LP {pure_lp}"
        );
    }

    #[test]
    fn sweep_covers_all_factorizations() {
        let t = run("fig6", 16).unwrap();
        // 1x16, 2x8, 4x4, 8x2, 16x1
        assert_eq!(t.rows.len(), 5);
        for r in &t.rows {
            let reps = r[0].as_f64().unwrap();
            let gpus = r[1].as_f64().unwrap();
            assert_eq!(reps * gpus, 16.0);
        }
    }
}
