//! Fig 4 — residual convergence vs MG cycles for several network depths:
//! the layer-independent-convergence property. Real numerics (HostSolver),
//! the paper's c = 4 / FCF configuration.
//!
//! The paper runs to ‖R‖ ≤ 1e-9 in (presumably) fp32 with unit-scale
//! states; our states have comparable scale and the norms floor at the same
//! f32 round-off region. The claim under test is the *depth-independence* of
//! the contraction rate, asserted in the tests below.

use std::sync::Arc;

use crate::mgrit::{self, MgritOptions};
use crate::model::{LayerKind, NetParams, NetSpec, OpeningSpec};
use crate::solver::host::HostSolver;
use crate::tensor::Tensor;
use crate::util::json::num;
use crate::util::prng::Rng;
use crate::Result;

use super::Table;

/// A fig6-family network slimmed (3×3 kernels, 12×12 field) so the deep
/// sweeps run in seconds on the host path; MGRIT convergence depends on the
/// ODE discretization (h·‖∂F‖), not on the per-layer FLOP count.
pub fn convergence_spec(n_res: usize) -> NetSpec {
    NetSpec {
        name: format!("fig4x{n_res}"),
        opening: OpeningSpec { in_channels: 1, out_channels: 4, kernel: 3, pad: 1, in_h: 12, in_w: 12 },
        trunk: vec![LayerKind::Conv { channels: 4, kernel: 3 }; n_res],
        n_classes: 10,
        t_final: 4.0,
        coarsen: 4,
    }
}

/// One convergence history.
pub struct History {
    /// Network depth (residual layers).
    pub depth: usize,
    /// ‖R_h‖ after each cycle.
    pub norms: Vec<f64>,
}

/// Run the sweep; returns per-depth residual histories.
pub fn histories(depths: &[usize], cycles: usize, seed: u64) -> Result<Vec<History>> {
    let mut out = Vec::new();
    for &n in depths {
        let spec = Arc::new(convergence_spec(n));
        let params = Arc::new(NetParams::init(&spec, seed)?);
        let solver = HostSolver::new(spec.clone(), params)?;
        let mut rng = Rng::new(seed + n as u64);
        let u0 = Tensor::randn(&[1, 4, 12, 12], 0.5, &mut rng);
        let opts = MgritOptions { max_cycles: cycles, tol: 0.0, ..Default::default() };
        let (_, stats) = mgrit::solve_forward(&solver, n, spec.h(), &u0, &opts)?;
        out.push(History { depth: n, norms: stats.residual_norms });
    }
    Ok(out)
}

/// The figure as a table: one row per (depth, cycle).
pub fn run(depths: &[usize], cycles: usize, seed: u64) -> Result<Table> {
    let hs = histories(depths, cycles, seed)?;
    let mut t = Table::new(
        "Fig 4: ‖R_h‖ vs MG cycle — depth-independent convergence (c=4, FCF)",
        &["depth", "cycle", "residual_norm", "contraction"],
    );
    for h in &hs {
        for (i, &norm) in h.norms.iter().enumerate() {
            let contraction = if i == 0 { f64::NAN } else { norm / h.norms[i - 1] };
            t.row(vec![
                num(h.depth as f64),
                num((i + 1) as f64),
                num(norm),
                num(contraction),
            ]);
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn convergence_is_depth_independent() {
        // the paper's headline property: contraction factor per cycle is
        // essentially the same at every depth
        let hs = histories(&[32, 128, 512], 3, 11).unwrap();
        let rate = |h: &History| (h.norms[2] / h.norms[0]).powf(0.5);
        let rates: Vec<f64> = hs.iter().map(rate).collect();
        for r in &rates {
            assert!(*r < 0.5, "cycle contraction too weak: {rates:?}");
        }
        let spread = rates.iter().cloned().fold(0.0, f64::max)
            / rates.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(spread < 5.0, "contraction varies too much with depth: {rates:?}");
    }

    #[test]
    fn norms_head_to_machine_floor() {
        let hs = histories(&[64], 8, 12).unwrap();
        let h = &hs[0];
        assert!(h.norms.last().unwrap() < &1e-4, "{:?}", h.norms);
        // monotone non-increasing (tiny floor jitter allowed)
        for w in h.norms.windows(2) {
            assert!(w[1] <= w[0] * 1.05, "{:?}", h.norms);
        }
    }

    #[test]
    fn table_has_all_rows() {
        let t = run(&[16, 32], 2, 13).unwrap();
        assert_eq!(t.rows.len(), 4);
    }
}
