//! Fig 5 — kernel-concurrency timeline within one device during an MG cycle
//! (the paper's nvprof screenshot). We run the simulated schedule for the
//! fig6 preset on one device with the V100's 5-slot stream model and render
//! the timeline; the claim under test is that the MG schedule exposes
//! enough independent blocks to fill all five slots.

use crate::coordinator::Partition;
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph;
use crate::model::NetSpec;
use crate::perfmodel::ClusterModel;
use crate::sim::{self, SimReport};
use crate::util::json::num;
use crate::Result;

use super::Table;

/// Simulate one MG cycle of the fig6 net on a single device with trace.
pub fn simulate_timeline(depth: usize) -> Result<SimReport> {
    let spec = if depth == 0 { NetSpec::fig6() } else { NetSpec::fig6_depth(depth) };
    let hier = Hierarchy::two_level(spec.n_res(), spec.h(), spec.coarsen)?;
    let n_blocks = hier.fine().blocks(hier.coarsen).len();
    let part = Partition::contiguous(n_blocks, 1)?;
    let g = taskgraph::mg_forward(&spec, &hier, &part, 1, 1);
    sim::simulate(&g, &ClusterModel::tx_gaia(1), true)
}

/// The figure: peak concurrency + occupancy, plus the rendered timeline.
pub fn run(depth: usize) -> Result<(Table, String)> {
    let rep = simulate_timeline(depth)?;
    let mut t = Table::new(
        "Fig 5: kernel concurrency within one device (MG cycle, 5 stream slots)",
        &["peak_concurrency", "n_kernels", "makespan_ms", "compute_fraction"],
    );
    t.row(vec![
        num(rep.peak_concurrency(0) as f64),
        num(rep.n_kernels as f64),
        num(rep.makespan_s * 1e3),
        num(rep.compute_fraction()),
    ]);
    // render the early window where F-relaxation saturates the slots
    let t1 = rep.makespan_s * 0.02;
    let ascii = sim::timeline::ascii_timeline(&rep.trace, 0, 0.0, t1.max(1e-6), 96);
    Ok((t, ascii))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_way_concurrency_achieved() {
        // the paper's observation: 5-way kernel concurrency on one V100
        let rep = simulate_timeline(256).unwrap();
        assert_eq!(rep.peak_concurrency(0), 5);
    }

    #[test]
    fn single_device_fully_busy() {
        let rep = simulate_timeline(128).unwrap();
        assert!(rep.compute_fraction() > 0.95, "{}", rep.compute_fraction());
        assert_eq!(rep.n_comms, 0);
    }

    #[test]
    fn timeline_renders() {
        let (t, ascii) = run(64).unwrap();
        assert_eq!(t.rows.len(), 1);
        assert!(ascii.contains("stream 0"));
        assert!(ascii.contains('#'));
    }
}
