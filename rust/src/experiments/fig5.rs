//! Fig 5 — kernel-concurrency timeline within one device during an MG cycle
//! (the paper's nvprof screenshot), shown two ways:
//!
//! 1. **Simulated**: the fig6-preset schedule on one device with the V100's
//!    5-slot stream model; the claim under test is that the MG schedule
//!    exposes enough independent blocks to fill all five slots.
//! 2. **Observed**: the *real* DAG executor running the identical schedule
//!    on host kernels over worker threads — the concurrency timeline is a
//!    property of the live runtime, not only of the simulation.

use std::sync::Arc;

use crate::coordinator::{ParallelMgrit, Partition, RunMetrics, TraceEvent};
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph;
use crate::mgrit::MgritOptions;
use crate::model::{NetParams, NetSpec};
use crate::perfmodel::ClusterModel;
use crate::sim::{self, SimReport, SimTraceEvent};
use crate::solver::host::HostSolver;
use crate::tensor::Tensor;
use crate::util::json::num;
use crate::Result;

use super::Table;

/// Simulate one MG cycle of the fig6 net on a single device with trace.
pub fn simulate_timeline(depth: usize) -> Result<SimReport> {
    let spec = if depth == 0 { NetSpec::fig6() } else { NetSpec::fig6_depth(depth) };
    let hier = Hierarchy::two_level(spec.n_res(), spec.h(), spec.coarsen)?;
    let n_blocks = hier.fine().blocks(hier.coarsen).len();
    let part = Partition::contiguous(n_blocks, 1)?;
    let g = taskgraph::mg_forward(&spec, &hier, &part, 1, 1);
    sim::simulate(&g, &ClusterModel::tx_gaia(1), true)
}

/// Execute one real MG cycle through the dependency-driven DAG executor
/// (host kernels, `devices` worker threads) and return the run metrics plus
/// the stream-pool kernel trace.
pub fn live_timeline(depth: usize, devices: usize) -> Result<(RunMetrics, Vec<TraceEvent>)> {
    let spec = Arc::new(NetSpec::fig6_depth(depth));
    let params = Arc::new(NetParams::init(&spec, 5)?);
    let spec2 = spec.clone();
    let factory = move |_w: usize| HostSolver::new(spec2.clone(), params.clone());
    let hier = Hierarchy::two_level(depth, spec.h(), spec.coarsen)?;
    let drv = ParallelMgrit::new(factory, spec.clone(), hier, devices, 1)?;
    let mut rng = crate::util::prng::Rng::new(6);
    let (hh, ww) = spec.hw();
    let u0 = Tensor::randn(&[1, spec.channels(), hh, ww], 0.5, &mut rng);
    let opts = MgritOptions { max_cycles: 1, tol: 0.0, ..Default::default() };
    let (_, _, metrics) = drv.solve(&u0, &opts)?;
    Ok((metrics, drv.pool().trace()))
}

/// Render a live stream-pool trace as an ASCII timeline (one row per worker
/// thread — the CPU analogue of one stream slot).
pub fn live_ascii(trace: &[TraceEvent], width: usize) -> String {
    if trace.is_empty() {
        return "  (empty trace)\n".to_string();
    }
    let evs: Vec<SimTraceEvent> = trace
        .iter()
        .map(|e| SimTraceEvent {
            task: 0,
            device: 0,
            slot: e.worker,
            label: e.label,
            is_comm: false,
            t_start: e.t_start,
            t_end: e.t_end,
        })
        .collect();
    let t0 = evs.iter().map(|e| e.t_start).fold(f64::INFINITY, f64::min);
    let mut t1 = evs.iter().map(|e| e.t_end).fold(f64::NEG_INFINITY, f64::max);
    if !(t1 > t0) {
        t1 = t0 + 1e-9;
    }
    sim::timeline::ascii_timeline(&evs, 0, t0, t1, width)
}

/// The figure: peak concurrency + occupancy, the rendered simulated
/// timeline, and the observed live-executor timeline.
pub fn run(depth: usize) -> Result<(Table, String)> {
    let rep = simulate_timeline(depth)?;
    let mut t = Table::new(
        "Fig 5: kernel concurrency within one device (MG cycle, 5 stream slots)",
        &["peak_concurrency", "n_kernels", "makespan_ms", "compute_fraction"],
    );
    t.row(vec![
        num(rep.peak_concurrency(0) as f64),
        num(rep.n_kernels as f64),
        num(rep.makespan_s * 1e3),
        num(rep.compute_fraction()),
    ]);
    // render the early window where F-relaxation saturates the slots
    let t1 = rep.makespan_s * 0.02;
    let mut ascii = sim::timeline::ascii_timeline(&rep.trace, 0, 0.0, t1.max(1e-6), 96);
    // the observed counterpart: the same schedule on the real DAG executor
    let live_depth = if depth == 0 || depth > 64 { 64 } else { depth };
    let (_, live) = live_timeline(live_depth, 4)?;
    ascii.push_str(&format!(
        "\nobserved (live DAG executor, depth {live_depth}, 4 workers, host kernels):\n"
    ));
    ascii.push_str(&live_ascii(&live, 96));
    Ok((t, ascii))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_way_concurrency_achieved() {
        // the paper's observation: 5-way kernel concurrency on one V100
        let rep = simulate_timeline(256).unwrap();
        assert_eq!(rep.peak_concurrency(0), 5);
    }

    #[test]
    fn single_device_fully_busy() {
        let rep = simulate_timeline(128).unwrap();
        assert!(rep.compute_fraction() > 0.95, "{}", rep.compute_fraction());
        assert_eq!(rep.n_comms, 0);
    }

    #[test]
    fn timeline_renders() {
        let (t, ascii) = run(64).unwrap();
        assert_eq!(t.rows.len(), 1);
        assert!(ascii.contains("stream 0"));
        assert!(ascii.contains('#'));
        assert!(ascii.contains("observed (live DAG executor"));
    }

    #[test]
    fn live_timeline_uses_multiple_workers() {
        let (metrics, trace) = live_timeline(64, 4).unwrap();
        assert_eq!(metrics.cycles, 1);
        let workers: std::collections::BTreeSet<usize> =
            trace.iter().map(|e| e.worker).collect();
        assert!(workers.len() >= 2, "trace stuck on workers {workers:?}");
        let ascii = live_ascii(&trace, 80);
        assert!(ascii.contains('#'));
    }
}
