//! Topology-aware collectives: gradient-reduction plans scored across node
//! counts (DESIGN.md §6d).
//!
//! One table: for each node count the three [`Collective`] plans run the same
//! hybrid training-step graph (`taskgraph::mg_train_step_multi_plan`, M = 2
//! micro-batch instances per node, round-robined) on the tiered virtual
//! cluster (`ClusterModel::tx_gaia_nodes`: PCIe inside a node, 25 GbE
//! between nodes). Columns report the simulated makespan, the bytes that
//! crossed the node boundary, the intra-/inter-tier transfer seconds, and
//! device utilization. This is the acceptance-criterion table — at ≥ 2 nodes
//! the hierarchical two-phase plan strictly beats the flat pairwise tree on
//! both cross-node bytes and makespan.

use crate::coordinator::{InstanceGroups, Partition};
use crate::mgrit::fas::RelaxKind;
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph::{self, collective_plan, Collective, Granularity};
use crate::model::NetSpec;
use crate::perfmodel::ClusterModel;
use crate::sim;
use crate::util::json::{num, s};
use crate::Result;

use super::Table;

/// The node counts the full sweep covers. The 16/32/64 tail is where the
/// collective plans separate hardest: the flat tree's cross-node traffic is
/// set by the instance round-robin while two-phase pays exactly one
/// boundary crossing per non-root node per parameter slot.
pub const NODE_COUNTS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// Simulated collective comparison: one row per (node count, collective).
///
/// Each row round-robins M = 2·nodes micro-batch instances over `nodes`
/// instance groups of `devices_per_node` devices, builds the training-step
/// graph under the named reduction plan, and prices it on the two-tier
/// cluster. `cross_node_mb` counts only transfers whose endpoints live on
/// different nodes; co-located reduces are free and do not appear in either
/// tier column.
pub fn sweep(
    depth: usize,
    devices_per_node: usize,
    node_counts: &[usize],
) -> Result<Table> {
    let spec = NetSpec::fig6_depth(depth);
    let hier = Hierarchy::two_level(depth, spec.h(), 4)?;
    let n_blocks = hier.fine().blocks(4).len();
    let mut t = Table::new(
        &format!(
            "Topology-aware collectives: simulated gradient reduction (depth {depth}, \
             {devices_per_node} devices/node, 2 micro-batches/node; virtual timeline)"
        ),
        &[
            "nodes",
            "collective",
            "micro",
            "sim_makespan_ms",
            "cross_node_mb",
            "comm_inter_ms",
            "comm_intra_ms",
            "utilization",
        ],
    );
    for &nodes in node_counts {
        let part = Partition::contiguous(n_blocks, devices_per_node)?;
        let groups = InstanceGroups::new(nodes, devices_per_node)?;
        let cluster = ClusterModel::tx_gaia_nodes(nodes, devices_per_node);
        let micro = 2 * nodes;
        let node_of: Vec<usize> = (0..micro).map(|k| k % nodes).collect();
        for c in Collective::all() {
            let plan = collective_plan(c, micro, &node_of);
            let g = taskgraph::mg_train_step_multi_plan(
                &spec,
                &hier,
                &part,
                &groups,
                1,
                2,
                RelaxKind::FCF,
                Granularity::PerStep,
                micro,
                &plan,
            )?;
            let rep = sim::simulate(&g, &cluster, false)?;
            let n_dev = rep.device_busy_s.len().max(1) as f64;
            let util = rep.device_busy_s.iter().sum::<f64>() / (n_dev * rep.makespan_s);
            t.row(vec![
                num(nodes as f64),
                s(c.name()),
                num(micro as f64),
                num(rep.makespan_s * 1e3),
                num(rep.cross_node_bytes / 1e6),
                num(rep.comm_inter_s * 1e3),
                num(rep.comm_intra_s * 1e3),
                num(util),
            ]);
        }
    }
    Ok(t)
}

/// The sweep with the CLI's default shapes: the full depth and node ladder,
/// or a two-node quick variant for CI smoke runs.
pub fn run(quick: bool) -> Result<Vec<Table>> {
    let (depth, devices_per_node) = if quick { (32, 2) } else { (64, 2) };
    let node_counts: &[usize] = if quick { &[1, 2] } else { &NODE_COUNTS };
    Ok(vec![sweep(depth, devices_per_node, node_counts)?])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn col(t: &Table, name: &str) -> usize {
        t.columns.iter().position(|c| c == name).unwrap()
    }

    #[test]
    fn two_phase_row_beats_tree_row_at_two_nodes() {
        // the acceptance criterion, read off the experiment table itself
        let t = sweep(32, 2, &[1, 2]).unwrap();
        assert_eq!(t.rows.len(), 2 * Collective::all().len());
        let nodes_c = col(&t, "nodes");
        let coll_c = col(&t, "collective");
        let mk_c = col(&t, "sim_makespan_ms");
        let mb_c = col(&t, "cross_node_mb");
        let find = |nodes: f64, name: &str| {
            t.rows
                .iter()
                .find(|r| {
                    r[nodes_c].as_f64().unwrap() == nodes
                        && r[coll_c].as_str().unwrap() == name
                })
                .unwrap()
        };
        // single node: every plan stays inside the box — zero cross-node bytes
        for c in Collective::all() {
            let r = find(1.0, c.name());
            assert_eq!(r[mb_c].as_f64().unwrap(), 0.0, "{} leaked bytes at 1 node", c.name());
            assert!(r[mk_c].as_f64().unwrap() > 0.0);
        }
        // two nodes: two-phase strictly beats the flat tree on both axes
        let tree = find(2.0, "tree");
        let two = find(2.0, "two-phase");
        assert!(tree[mb_c].as_f64().unwrap() > 0.0, "tree must cross at 2 nodes");
        assert!(
            two[mb_c].as_f64().unwrap() < tree[mb_c].as_f64().unwrap(),
            "two-phase must cut cross-node bytes"
        );
        assert!(
            two[mk_c].as_f64().unwrap() < tree[mk_c].as_f64().unwrap(),
            "two-phase must cut the makespan"
        );
        // utilization is a fraction
        let u_c = col(&t, "utilization");
        for r in &t.rows {
            let u = r[u_c].as_f64().unwrap();
            assert!(u > 0.0 && u <= 1.0 + 1e-12, "utilization {u} out of range");
        }
    }

    #[test]
    fn two_phase_cross_node_bytes_scale_linearly_past_eight_nodes() {
        // the 16/32/64-node extension's acceptance property: under the
        // hierarchical two-phase plan each non-root node crosses the fabric
        // exactly once per parameter slot, so cross-node bytes grow as
        // (G − 1) — cross(G)/cross(2) == G − 1 — all the way up the ladder,
        // while the flat tree keeps paying strictly more at every size
        let t = sweep(32, 2, &[2, 4, 8, 16]).unwrap();
        let nodes_c = col(&t, "nodes");
        let coll_c = col(&t, "collective");
        let mb_c = col(&t, "cross_node_mb");
        let cross = |nodes: f64, name: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| {
                    r[nodes_c].as_f64().unwrap() == nodes
                        && r[coll_c].as_str().unwrap() == name
                })
                .unwrap()[mb_c]
                .as_f64()
                .unwrap()
        };
        let base = cross(2.0, "two-phase");
        assert!(base > 0.0, "two-phase must cross at 2 nodes");
        for nodes in [4.0, 8.0, 16.0] {
            let ratio = cross(nodes, "two-phase") / base;
            let expect = nodes - 1.0;
            assert!(
                (ratio - expect).abs() < 1e-6,
                "two-phase cross bytes at {nodes} nodes: ratio {ratio}, expected {expect}"
            );
            assert!(
                cross(nodes, "two-phase") < cross(nodes, "tree"),
                "two-phase must stay under the flat tree at {nodes} nodes"
            );
        }
    }
}
