//! Ablations over the design choices DESIGN.md calls out: MG cycle count,
//! coarsening factor c, relaxation pattern, and hierarchy depth. Real
//! numerics (HostSolver) for convergence quality, the simulator for cost —
//! together they expose the accuracy/throughput trade-off behind the
//! paper's "two cycles suffice".

use std::sync::Arc;

use crate::coordinator::Partition;
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::{self, taskgraph, MgritOptions, RelaxKind};
use crate::model::{NetParams, NetSpec};
use crate::perfmodel::ClusterModel;
use crate::sim;
use crate::solver::host::HostSolver;
use crate::solver::BlockSolver;
use crate::tensor::Tensor;
use crate::util::json::{num, s};
use crate::util::prng::Rng;
use crate::Result;

use super::Table;

fn state_error_after(
    solver: &HostSolver,
    u0: &Tensor,
    n: usize,
    opts: &MgritOptions,
) -> Result<(f64, usize)> {
    let h = solver.spec().h();
    let (mg, stats) = mgrit::solve_forward(solver, n, h, u0, opts)?;
    let serial = solver.block_fprop(0, 1, n, h, u0)?;
    let err = crate::util::stats::rel_l2_err(
        mg.last().unwrap().data(),
        serial.last().unwrap().data(),
    );
    Ok((err, stats.phi_evals))
}

/// Accuracy-vs-work ablation over cycle count and relaxation kind.
pub fn cycles_and_relax(seed: u64) -> Result<Table> {
    let spec = Arc::new(NetSpec::mnist());
    let params = Arc::new(NetParams::init(&spec, seed)?);
    let solver = HostSolver::new(spec.clone(), params)?;
    let mut rng = Rng::new(seed + 1);
    let u0 = Tensor::randn(&[1, 8, 28, 28], 0.5, &mut rng);
    let n = spec.n_res();

    let mut t = Table::new(
        "Ablation: cycles × relaxation — final-state error vs Φ-evaluations",
        &["cycles", "relax", "state_rel_err", "phi_evals", "work_vs_serial"],
    );
    for cycles in [1usize, 2, 3] {
        for (relax, name) in [(RelaxKind::F, "F"), (RelaxKind::FC, "FC"), (RelaxKind::FCF, "FCF")]
        {
            let opts = MgritOptions { max_cycles: cycles, tol: 0.0, relax, ..Default::default() };
            let (err, evals) = state_error_after(&solver, &u0, n, &opts)?;
            t.row(vec![
                num(cycles as f64),
                s(name),
                num(err),
                num(evals as f64),
                num(evals as f64 / n as f64),
            ]);
        }
    }
    Ok(t)
}

/// Coarsening-factor ablation: convergence per cycle vs c.
pub fn coarsening(seed: u64) -> Result<Table> {
    let mut t = Table::new(
        "Ablation: coarsening factor c — contraction per cycle (depth 64)",
        &["c", "cycle1_norm", "cycle3_norm", "contraction_per_cycle"],
    );
    for c in [2usize, 4, 8, 16] {
        let mut spec = NetSpec::fig6_depth(64);
        spec.coarsen = c;
        let spec = Arc::new(spec);
        let params = Arc::new(NetParams::init(&spec, seed)?);
        let solver = HostSolver::new(spec.clone(), params)?;
        let mut rng = Rng::new(seed + c as u64);
        let u0 = Tensor::randn(&[1, 4, 24, 24], 0.5, &mut rng);
        let hier = Hierarchy::two_level(64, spec.h(), c)?;
        let opts = MgritOptions { max_cycles: 3, tol: 0.0, ..Default::default() };
        let (_, stats) = mgrit::fas::solve_forward_with(&solver, &hier, &u0, &opts)?;
        let n1 = stats.residual_norms[0];
        let n3 = stats.residual_norms[2];
        t.row(vec![num(c as f64), num(n1), num(n3), num((n3 / n1).sqrt())]);
    }
    Ok(t)
}

/// Two-level vs multilevel hierarchy: simulated makespan at scale.
pub fn hierarchy_depth(gpus: usize) -> Result<Table> {
    let spec = NetSpec::fig6();
    let mut t = Table::new(
        "Ablation: hierarchy depth — simulated MG time (fig6 preset)",
        &["max_levels", "n_levels", "makespan_ms", "comm_ms"],
    );
    for max_levels in [2usize, 3, 5, 8] {
        let hier = Hierarchy::build(spec.n_res(), spec.h(), spec.coarsen, max_levels, 8)?;
        let n_blocks = hier.fine().blocks(hier.coarsen).len();
        let part = Partition::contiguous(n_blocks, gpus)?;
        let g = taskgraph::mg_forward(&spec, &hier, &part, 1, 2);
        let rep = sim::simulate(&g, &ClusterModel::tx_gaia(gpus), false)?;
        t.row(vec![
            num(max_levels as f64),
            num(hier.n_levels() as f64),
            num(rep.makespan_s * 1e3),
            num(rep.comm_total_s * 1e3),
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn more_cycles_reduce_state_error() {
        let t = cycles_and_relax(20).unwrap();
        // FCF rows at cycles 1, 2, 3
        let fcf: Vec<f64> = t
            .rows
            .iter()
            .filter(|r| r[1].as_str().unwrap() == "FCF")
            .map(|r| r[2].as_f64().unwrap())
            .collect();
        assert_eq!(fcf.len(), 3);
        assert!(fcf[1] < fcf[0]);
        assert!(fcf[2] <= fcf[1] * 1.5);
        // the paper's early-stopping claim: 2 FCF cycles give a few-percent
        // state error — accurate enough for training gradients
        assert!(fcf[1] < 5e-2, "2-cycle error {}", fcf[1]);
    }

    #[test]
    fn fcf_stronger_than_f_per_cycle() {
        let t = cycles_and_relax(21).unwrap();
        let get = |cycles: f64, relax: &str| {
            t.rows
                .iter()
                .find(|r| r[0].as_f64().unwrap() == cycles && r[1].as_str().unwrap() == relax)
                .unwrap()[2]
                .as_f64()
                .unwrap()
        };
        assert!(get(2.0, "FCF") <= get(2.0, "F"));
    }

    #[test]
    fn multilevel_faster_than_two_level_at_scale() {
        let t = hierarchy_depth(16).unwrap();
        let two = t.rows[0][2].as_f64().unwrap();
        let deep = t.rows.last().unwrap()[2].as_f64().unwrap();
        assert!(
            deep < two,
            "multilevel should beat two-level at 16 GPUs: {deep} vs {two}"
        );
    }

    #[test]
    fn coarsening_table_complete() {
        let t = coarsening(22).unwrap();
        assert_eq!(t.rows.len(), 4);
        for r in &t.rows {
            assert!(r[3].as_f64().unwrap() < 1.0, "no contraction: {r:?}");
        }
    }
}
