//! Cross-step pipelined training: bounded-staleness asynchronous SGD scored
//! against the cross-step barrier (DESIGN.md §7).
//!
//! Three tables, all over the same `PipeSync` sweep (barrier, then
//! staleness S ∈ {0, 1, 2}):
//!
//! 1. [`sim_makespan`] — the composed K-step pipeline graph
//!    (`taskgraph::mg_train_pipeline`) priced on the deterministic virtual
//!    cluster (V100 + 25 GbE): the throughput side of the trade, with the
//!    speedup of each staleness level over the barrier baseline. This is
//!    the acceptance-criterion table — at ≥ 2 devices the S ≥ 1 pipeline's
//!    makespan is strictly below the barrier's.
//! 2. [`live_makespan`] — the same window executed for real through
//!    `ParallelMgrit::train_pipeline` (host numerics): wall-clock makespan
//!    from the instance-tagged `ExecEvent` trace, the snapshot ring's
//!    live-depth high-water mark, and the window's final loss. With S = 0
//!    the losses are bit-identical to the sequential step loop.
//! 3. [`convergence`] — the accuracy side: per-step loss trajectories of
//!    `train::train_parallel_pipelined` at S = 0 / 1 / 2 on one synthetic
//!    dataset with step-keyed batches (`data::StepSampler`), so any
//!    divergence between columns is *caused by staleness*, never by data
//!    order.

use std::sync::Arc;

use crate::coordinator::{InstanceGroups, ParallelMgrit, Partition, PlacementKind};
use crate::data::SyntheticDigits;
use crate::mgrit::fas::RelaxKind;
use crate::mgrit::hierarchy::Hierarchy;
use crate::mgrit::taskgraph::{self, Granularity, PipeSync};
use crate::mgrit::MgritOptions;
use crate::model::{NetParams, NetSpec};
use crate::perfmodel::ClusterModel;
use crate::sim;
use crate::solver::host::HostSolver;
use crate::tensor::Tensor;
use crate::train::{self, Method, TrainConfig};
use crate::util::json::{num, s};
use crate::util::prng::Rng;
use crate::Result;

use super::Table;

/// The sync modes every pipeline table sweeps: the cross-step barrier
/// baseline plus bounded staleness S ∈ {0, 1, 2}.
pub const SYNC_SWEEP: [PipeSync; 4] = [
    PipeSync::Barrier,
    PipeSync::Staleness(0),
    PipeSync::Staleness(1),
    PipeSync::Staleness(2),
];

fn sync_label(sync: PipeSync) -> String {
    match sync {
        PipeSync::Barrier => "barrier".to_string(),
        PipeSync::Staleness(st) => format!("staleness-{st}"),
    }
}

/// Simulated makespan of the K-step pipelined training graph per sync mode:
/// one row per [`SYNC_SWEEP`] entry with the composed graph's task count,
/// the virtual-timeline makespan, and the speedup over the barrier row.
pub fn sim_makespan(
    spec: &NetSpec,
    hier: &Hierarchy,
    devices: usize,
    batch: usize,
    k_steps: usize,
    micro_batches: usize,
) -> Result<Table> {
    let n_blocks = hier.fine().blocks(hier.coarsen).len();
    let part = Partition::contiguous(n_blocks, devices)?;
    let groups = InstanceGroups::new(1, part.n_devices())?;
    let cluster = ClusterModel::tx_gaia(part.n_devices());
    let mut t = Table::new(
        &format!(
            "Pipelined training: simulated makespan (K = {k_steps} steps x {micro_batches} \
             micro-batches, {} devices; virtual timeline)",
            part.n_devices()
        ),
        &["sync", "tasks", "sim_makespan_ms", "speedup_vs_barrier"],
    );
    let mut barrier_ms = f64::NAN;
    for sync in SYNC_SWEEP {
        let g = taskgraph::mg_train_pipeline(
            spec,
            hier,
            &part,
            &groups,
            batch,
            2,
            RelaxKind::FCF,
            Granularity::PerStep,
            micro_batches,
            k_steps,
            sync,
        )?;
        let rep = sim::simulate(&g, &cluster, false)?;
        let ms = rep.makespan_s * 1e3;
        if sync == PipeSync::Barrier {
            barrier_ms = ms;
        }
        t.row(vec![
            s(&sync_label(sync)),
            num(g.tasks.len() as f64),
            num(ms),
            num(barrier_ms / ms),
        ]);
    }
    Ok(t)
}

/// Live makespan of the K-step pipelined window per sync mode, executed for
/// real over `devices` host workers on the micro preset: wall-clock span of
/// the instance-tagged `ExecEvent` trace, the snapshot ring's peak depth
/// (≤ S + 2), and the window's final loss. The same `seed` feeds every row,
/// so the S = 0 row's losses are bit-identical to the barrier row's.
pub fn live_makespan(
    devices: usize,
    batch: usize,
    k_steps: usize,
    micro_batches: usize,
    seed: u64,
) -> Result<Table> {
    let spec = Arc::new(NetSpec::micro());
    let hier = Hierarchy::two_level(spec.n_res(), spec.h(), 2)?;
    let o = &spec.opening;
    let mut rng = Rng::new(seed);
    let y = Tensor::randn(&[k_steps * batch, o.in_channels, o.in_h, o.in_w], 0.5, &mut rng);
    let labels: Vec<i32> = (0..k_steps * batch).map(|i| (i % 10) as i32).collect();
    let opts = MgritOptions::early_stopping(2);
    let mut t = Table::new(
        &format!(
            "Pipelined training: live makespan (micro preset, K = {k_steps} steps x \
             {micro_batches} micro-batches, {devices} devices; wall clock)"
        ),
        &["sync", "live_makespan_ms", "peak_ring_depth", "final_loss"],
    );
    for sync in SYNC_SWEEP {
        let params = NetParams::init(&spec, seed + 1)?;
        let spec2 = spec.clone();
        let snap = Arc::new(params);
        let factory = move |_w: usize| HostSolver::new(spec2.clone(), snap.clone());
        let drv =
            ParallelMgrit::new(factory, spec.clone(), hier.clone(), devices, k_steps * batch)?;
        let out = drv.train_pipeline(&y, &labels, &opts, 0.05, micro_batches, k_steps, sync)?;
        let t0 = out.metrics.events.iter().map(|e| e.t_start).fold(f64::INFINITY, f64::min);
        let t1 = out.metrics.events.iter().map(|e| e.t_end).fold(f64::NEG_INFINITY, f64::max);
        let span_ms = if out.metrics.events.is_empty() { 0.0 } else { (t1 - t0) * 1e3 };
        t.row(vec![
            s(&sync_label(sync)),
            num(span_ms),
            num(out.peak_ring_depth as f64),
            num(out.losses.last().copied().unwrap_or(f64::NAN)),
        ]);
    }
    Ok(t)
}

/// Loss trajectories under bounded staleness: one row per training step with
/// the per-step loss at S = 0, 1, and 2 (K-step windows, `devices` workers).
/// Every column trains from the same initial parameters on the same
/// step-keyed batches, so column differences isolate the staleness effect.
pub fn convergence(
    steps: usize,
    batch: usize,
    k_steps: usize,
    devices: usize,
) -> Result<Table> {
    // mnist geometry with a short trunk — the train-loop test spec
    let spec = {
        let mut sp = NetSpec::mnist();
        sp.trunk.truncate(8);
        sp.t_final = 0.5;
        Arc::new(sp)
    };
    let ds = SyntheticDigits::new(29).dataset(40);
    let cfg = TrainConfig {
        steps,
        batch,
        lr: 0.05,
        method: Method::Mgrit { cycles: 2 },
        seed: 9,
    };
    let mut traces: Vec<Vec<f64>> = Vec::new();
    for staleness in [0usize, 1, 2] {
        let mut params = NetParams::init(&spec, 31)?;
        let logs = train::train_parallel_pipelined(
            &spec,
            &mut params,
            &ds,
            &cfg,
            devices,
            Granularity::PerStep,
            1,
            PlacementKind::MinId,
            k_steps,
            PipeSync::Staleness(staleness),
        )?;
        traces.push(logs.iter().map(|l| l.loss).collect());
    }
    let mut t = Table::new(
        &format!(
            "Pipelined training: loss trajectory vs staleness ({steps} steps, batch {batch}, \
             K = {k_steps}, {devices} devices)"
        ),
        &["step", "loss_s0", "loss_s1", "loss_s2"],
    );
    for i in 0..steps {
        t.row(vec![
            num(i as f64),
            num(traces[0][i]),
            num(traces[1][i]),
            num(traces[2][i]),
        ]);
    }
    Ok(t)
}

/// All three pipeline tables with the CLI's default shapes: the simulated
/// sweep on the depth-`depth` fig6 spec, the live sweep on the micro preset,
/// and the convergence trajectories on the short-trunk training spec.
pub fn run(depth: usize, devices: usize, k_steps: usize) -> Result<Vec<Table>> {
    let spec = NetSpec::fig6_depth(depth);
    let hier = Hierarchy::two_level(depth, spec.h(), spec.coarsen)?;
    Ok(vec![
        sim_makespan(&spec, &hier, devices, 1, k_steps, 2)?,
        live_makespan(2, 2, k_steps, 2, 17)?,
        convergence(6, 4, k_steps.max(2), 2)?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_table_pipelined_strictly_beats_barrier_on_micro_shape() {
        // the acceptance criterion, read off the experiment table itself, on
        // the shape the engine test proves strict: micro spec, 2 devices,
        // K = 3 steps x 2 micro-batches
        let spec = NetSpec::micro();
        let hier = Hierarchy::two_level(spec.n_res(), spec.h(), 2).unwrap();
        let t = sim_makespan(&spec, &hier, 2, 1, 3, 2).unwrap();
        assert_eq!(t.rows.len(), SYNC_SWEEP.len());
        let label = |i: usize| t.rows[i][0].as_str().unwrap().to_string();
        assert_eq!(label(0), "barrier");
        assert_eq!(label(1), "staleness-0");
        let mk = |i: usize| t.rows[i][2].as_f64().unwrap();
        for i in 0..t.rows.len() {
            assert!(mk(i) > 0.0, "row {i} has no makespan");
        }
        // S = 0 relaxes barrier edges to per-slot first-reader edges: never
        // slower; S >= 1 overlaps whole steps: strictly faster than barrier
        assert!(mk(1) <= mk(0) + 1e-12, "S=0 slower than barrier: {} vs {}", mk(1), mk(0));
        for i in [2, 3] {
            assert!(
                mk(i) < mk(0),
                "{} must strictly beat barrier: {} vs {}",
                label(i),
                mk(i),
                mk(0)
            );
        }
        // the speedup column agrees with the makespans
        let sp = t.rows[2][3].as_f64().unwrap();
        assert!((sp - mk(0) / mk(2)).abs() < 1e-9);
        assert!(sp > 1.0);
        // deterministic rerun reproduces the table exactly
        let t2 = sim_makespan(&spec, &hier, 2, 1, 3, 2).unwrap();
        for (a, b) in t.rows.iter().zip(&t2.rows) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_string(), y.to_string());
            }
        }
    }

    #[test]
    fn live_table_rows_complete_with_bounded_ring() {
        let t = live_makespan(2, 1, 2, 1, 23).unwrap();
        assert_eq!(t.rows.len(), SYNC_SWEEP.len());
        for (i, row) in t.rows.iter().enumerate() {
            assert!(row[1].as_f64().unwrap() > 0.0, "row {i} has no live span");
            let peak = row[2].as_f64().unwrap();
            assert!(peak >= 1.0 && peak <= 4.0, "row {i} ring depth {peak} out of bounds");
            assert!(row[3].as_f64().unwrap().is_finite(), "row {i} loss not finite");
        }
        // barrier and S = 0 share sequential SGD semantics: identical loss
        assert_eq!(
            t.rows[0][3].as_f64().unwrap(),
            t.rows[1][3].as_f64().unwrap(),
            "barrier and staleness-0 final losses must be bit-identical"
        );
    }

    #[test]
    fn convergence_trajectories_are_finite_and_start_together() {
        let t = convergence(4, 4, 2, 2).unwrap();
        assert_eq!(t.rows.len(), 4);
        for row in &t.rows {
            for col in 1..4 {
                assert!(row[col].as_f64().unwrap().is_finite());
            }
        }
        // step 0 of every staleness level reads the same version-0
        // parameters on the same step-keyed batch: identical loss
        let first = &t.rows[0];
        assert_eq!(first[1].as_f64().unwrap(), first[2].as_f64().unwrap());
        assert_eq!(first[1].as_f64().unwrap(), first[3].as_f64().unwrap());
    }
}
